//! The shared storage behind real [`DataBuf`](super::DataBuf)s: a
//! reference-counted slab of elements plus a table of outstanding *read
//! leases*.
//!
//! ## Why leases
//!
//! Zero-copy block transport means a sent block is a `(slab, offset, len)`
//! view of the sender's working vector, read by the receiving rank's thread
//! while the sender keeps mutating *other* ranges of the same vector. Rust
//! cannot express "disjoint ranges of one allocation, touched from two
//! threads" with references alone, so the slab owns its storage as raw
//! parts and hands out range-scoped slices derived from the base pointer:
//!
//! * every live view holds a **lease** `(off, len)` registered in the
//!   slab's table for the view's whole lifetime — all reads through a view
//!   are covered by its lease;
//! * the single **exclusive** handle (the one created by
//!   [`Slab::from_vec`] or by a copy-on-write) may mutate a range only
//!   after checking, under the table lock, that no lease overlaps it; on
//!   overlap it must copy out first (see `RealBuf::writable` in the parent
//!   module).
//!
//! New overlapping leases cannot appear between the check and the
//! mutation: leases are created only by `extract`/`clone` on an existing
//! handle, sub-views stay inside their parent's leased range, and creating
//! a view from the exclusive handle needs `&self` — which the mutation's
//! `&mut self` excludes. Lease *releases* from other threads during a
//! mutation are harmless (they only shrink the set of readers).
//!
//! The table is a `Mutex<Vec<..>>`: it holds a handful of entries (one per
//! in-flight block), and the three touches per block (register, check,
//! release) replace a heap allocation and a memcpy — the trade the whole
//! zero-copy transport is built on.

use std::mem::ManuallyDrop;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::ops::Elem;

/// One outstanding read lease: `(id, off, len)` in elements.
#[derive(Clone, Copy, Debug)]
struct Lease {
    id: u64,
    off: usize,
    len: usize,
}

/// A reference-counted element slab with range-lease bookkeeping.
///
/// Storage is the raw parts of a `Vec<E>`; `Drop` reassembles the vector
/// and returns it to the thread-local [`pool`](super::pool) — receives
/// recycle buffers on the *receiving* rank's free list, which is exactly
/// the per-rank receive-side pooling the transport wants.
pub(crate) struct Slab<E: Elem> {
    ptr: *mut E,
    len: usize,
    cap: usize,
    leases: Mutex<Vec<Lease>>,
    next_lease: AtomicU64,
}

// SAFETY: `E: Elem` is `Copy + Send + Sync`; concurrent access to the raw
// storage is governed by the lease discipline documented on the module —
// readers hold leases, the single exclusive handle checks them before
// writing, and disjoint-range slices derived from the base pointer never
// alias.
unsafe impl<E: Elem> Send for Slab<E> {}
unsafe impl<E: Elem> Sync for Slab<E> {}

impl<E: Elem> Slab<E> {
    /// Take ownership of a vector's storage.
    pub(crate) fn from_vec(v: Vec<E>) -> Slab<E> {
        let mut v = ManuallyDrop::new(v);
        Slab {
            ptr: v.as_mut_ptr(),
            len: v.len(),
            cap: v.capacity(),
            leases: Mutex::new(Vec::new()),
            next_lease: AtomicU64::new(0),
        }
    }

    /// Initialized length in elements.
    pub(crate) fn len(&self) -> usize {
        self.len
    }

    /// Register a read lease over `[off, off + len)`; returns its id.
    pub(crate) fn lease(&self, off: usize, len: usize) -> u64 {
        debug_assert!(off + len <= self.len);
        let id = self.next_lease.fetch_add(1, Ordering::Relaxed);
        self.leases.lock().unwrap().push(Lease { id, off, len });
        id
    }

    /// Release a lease previously returned by [`Slab::lease`].
    pub(crate) fn release(&self, id: u64) {
        let mut leases = self.leases.lock().unwrap();
        if let Some(i) = leases.iter().position(|l| l.id == id) {
            leases.swap_remove(i);
        }
    }

    /// True if any outstanding lease other than `own` overlaps
    /// `[off, off + len)`. Empty ranges never overlap.
    pub(crate) fn overlaps(&self, off: usize, len: usize, own: Option<u64>) -> bool {
        if len == 0 {
            return false;
        }
        self.leases
            .lock()
            .unwrap()
            .iter()
            .any(|l| Some(l.id) != own && l.len != 0 && l.off < off + len && off < l.off + l.len)
    }

    /// Read `[off, off + len)`.
    ///
    /// # Safety
    /// The caller must guarantee no concurrent mutation of the range — by
    /// holding a lease covering it, or by holding `&`/`&mut` on the slab's
    /// exclusive handle (the only possible writer).
    pub(crate) unsafe fn read(&self, off: usize, len: usize) -> &[E] {
        debug_assert!(off + len <= self.len);
        // SAFETY: `ptr` is the base of a live allocation of `self.len`
        // initialized elements (from_vec), so `ptr + off .. ptr + off + len`
        // is in bounds; freedom from concurrent mutation is the caller's
        // contract above.
        unsafe { std::slice::from_raw_parts(self.ptr.add(off), len) }
    }

    /// Mutably access `[off, off + len)`.
    ///
    /// # Safety
    /// The caller must be the slab's exclusive handle, hold it mutably,
    /// and have verified via [`Slab::overlaps`] that no lease covers the
    /// range.
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn write(&self, off: usize, len: usize) -> &mut [E] {
        debug_assert!(off + len <= self.len);
        // SAFETY: in-bounds range of a live allocation as in `read`;
        // exclusivity (no overlapping lease, no second writer) is the
        // caller's contract above, so handing out `&mut` cannot alias.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(off), len) }
    }

    /// Consume the slab, reclaiming the storage as a `Vec` without copying.
    pub(crate) fn into_vec(self) -> Vec<E> {
        let this = ManuallyDrop::new(self);
        // SAFETY: the raw parts came from a Vec in `from_vec`; ManuallyDrop
        // prevents the Drop impl from also reclaiming them.
        unsafe { Vec::from_raw_parts(this.ptr, this.len, this.cap) }
    }
}

impl<E: Elem> Drop for Slab<E> {
    fn drop(&mut self) {
        // SAFETY: same provenance argument as `into_vec`; after this the
        // slab's pointer is never touched again.
        let v = unsafe { Vec::from_raw_parts(self.ptr, self.len, self.cap) };
        super::pool::recycle(v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_reads() {
        let s = Slab::from_vec(vec![1i32, 2, 3, 4]);
        assert_eq!(s.len(), 4);
        // SAFETY: `s` is owned by this thread; no writer exists.
        assert_eq!(unsafe { s.read(1, 2) }, &[2, 3]);
        assert_eq!(s.into_vec(), vec![1, 2, 3, 4]);
    }

    #[test]
    fn lease_overlap_detection() {
        let s = Slab::from_vec(vec![0i32; 10]);
        let id = s.lease(2, 4); // [2, 6)
        assert!(s.overlaps(0, 3, None)); // [0,3) ∩ [2,6)
        assert!(s.overlaps(5, 5, None)); // [5,10) ∩ [2,6)
        assert!(!s.overlaps(6, 4, None)); // adjacent, no overlap
        assert!(!s.overlaps(0, 2, None));
        assert!(!s.overlaps(0, 10, Some(id))); // own lease excluded
        s.release(id);
        assert!(!s.overlaps(0, 10, None));
    }

    #[test]
    fn zero_len_ranges_never_overlap() {
        let s = Slab::from_vec(vec![0i32; 4]);
        let _id = s.lease(0, 4);
        assert!(!s.overlaps(2, 0, None));
        let e = Slab::from_vec(Vec::<i32>::new());
        let _eid = e.lease(0, 0);
        assert!(!e.overlaps(0, 0, None));
    }

    #[test]
    fn disjoint_write_while_leased() {
        let s = Slab::from_vec(vec![0i32; 8]);
        let id = s.lease(0, 4);
        assert!(!s.overlaps(4, 4, None));
        // SAFETY: range [4,8) is checked disjoint from the lease above.
        unsafe { s.write(4, 4) }.copy_from_slice(&[9, 9, 9, 9]);
        s.release(id);
        // SAFETY: the write above completed and `s` is single-threaded here.
        assert_eq!(unsafe { s.read(0, 8) }, &[0, 0, 0, 0, 9, 9, 9, 9]);
    }
}
