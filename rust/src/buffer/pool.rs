//! Per-rank (thread-local) capacity-bucketed free lists for slab storage,
//! plus the allocation/copy counters that make the zero-copy transport's
//! behavior observable.
//!
//! Every rank of a world runs on its own OS thread, so a `thread_local!`
//! pool *is* a per-rank pool with no synchronization at all. Buffers enter
//! the pool when a [`Slab`](super::slab::Slab) drops — which happens on the
//! thread that dropped the last view, i.e. usually the **receiving** rank —
//! and leave it whenever that rank next needs storage (a copy-on-write, a
//! send-time snapshot, a zeroed result buffer). In steady state a pipelined
//! collective therefore runs with zero allocator traffic: the paper's
//! `O(b)` per-phase allocations become `O(1)`.
//!
//! Buckets are powers of two by *capacity in elements*; a request is served
//! from the smallest bucket whose capacity fits. The pool is bounded
//! ([`MAX_PER_BUCKET`], [`MAX_POOLED_BYTES`] per bucket entry) so a one-off
//! giant vector cannot pin memory forever.

use std::any::{Any, TypeId};
use std::cell::{Cell, RefCell};
use std::collections::HashMap;

use crate::ops::Elem;

/// Free-list entries kept per capacity class.
const MAX_PER_BUCKET: usize = 8;

/// Largest single buffer the pool will retain (bytes). Bigger ones go back
/// to the allocator — they are whole working vectors, not pipeline blocks.
const MAX_POOLED_BYTES: usize = 64 << 20;

/// Number of power-of-two capacity classes (2^0 .. 2^47 elements).
const CLASSES: usize = 48;

struct Pool<E: Elem> {
    buckets: Vec<Vec<Vec<E>>>,
}

impl<E: Elem> Pool<E> {
    fn new() -> Pool<E> {
        Pool {
            buckets: (0..CLASSES).map(|_| Vec::new()).collect(),
        }
    }

    fn class(cap: usize) -> usize {
        (usize::BITS - cap.max(1).next_power_of_two().leading_zeros()) as usize - 1
    }

    /// A vector with `capacity >= cap`, recycled if possible. The returned
    /// vector has length 0.
    fn get(&mut self, cap: usize) -> Option<Vec<E>> {
        let lo = Self::class(cap);
        for c in lo..CLASSES.min(lo + 2) {
            // a class is a capacity floor, not a guarantee: scan the whole
            // bucket (≤ MAX_PER_BUCKET entries) for the first fit
            let bucket = &mut self.buckets[c];
            if let Some(i) = bucket.iter().position(|v| v.capacity() >= cap) {
                let mut v = bucket.swap_remove(i);
                v.clear();
                return Some(v);
            }
        }
        None
    }

    fn put(&mut self, v: Vec<E>) {
        let cap = v.capacity();
        if cap == 0 || cap * E::BYTES > MAX_POOLED_BYTES {
            return;
        }
        let c = Self::class(cap).min(CLASSES - 1);
        if self.buckets[c].len() < MAX_PER_BUCKET {
            self.buckets[c].push(v);
        }
    }
}

thread_local! {
    /// One pool per element type per thread (rank).
    static POOLS: RefCell<HashMap<TypeId, Box<dyn Any>>> = RefCell::new(HashMap::new());
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
    static POOL_RECYCLED: Cell<u64> = const { Cell::new(0) };
    static BYTES_COPIED: Cell<u64> = const { Cell::new(0) };
}

fn with_pool<E: Elem, R>(f: impl FnOnce(&mut Pool<E>) -> R) -> R {
    POOLS.with(|pools| {
        let mut pools = pools.borrow_mut();
        let pool = pools
            .entry(TypeId::of::<E>())
            .or_insert_with(|| Box::new(Pool::<E>::new()))
            .downcast_mut::<Pool<E>>()
            .expect("pool type keyed by TypeId");
        f(pool)
    })
}

/// A zero-length vector with capacity for at least `cap` elements, served
/// from this rank's free list when possible. Counts an alloc on miss, a
/// recycle on hit.
pub(crate) fn acquire<E: Elem>(cap: usize) -> Vec<E> {
    if let Some(v) = with_pool::<E, _>(|p| p.get(cap)) {
        POOL_RECYCLED.with(|c| c.set(c.get() + 1));
        v
    } else {
        ALLOCS.with(|c| c.set(c.get() + 1));
        Vec::with_capacity(cap)
    }
}

/// Return a vector's storage to this rank's free list.
pub(crate) fn recycle<E: Elem>(v: Vec<E>) {
    with_pool::<E, _>(|p| p.put(v));
}

/// Charge `n` copied bytes to this rank's counter (CoW and snapshots).
pub(crate) fn charge_copy(bytes: usize) {
    BYTES_COPIED.with(|c| c.set(c.get() + bytes as u64));
}

/// Snapshot of one rank's buffer-layer counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BufStats {
    /// Slab allocations that missed the pool and hit the system allocator.
    pub allocs: u64,
    /// Slab allocations served from the free list.
    pub pool_recycled: u64,
    /// Bytes memcpy'd by the buffer layer (copy-on-write, snapshots,
    /// `into_vec` fallbacks) — *not* reduction work.
    pub bytes_copied: u64,
}

/// Read this thread's counters without resetting them.
pub fn stats() -> BufStats {
    BufStats {
        allocs: ALLOCS.with(Cell::get),
        pool_recycled: POOL_RECYCLED.with(Cell::get),
        bytes_copied: BYTES_COPIED.with(Cell::get),
    }
}

/// Read and reset this thread's counters (rank threads call this when a
/// world finishes so the next run starts from zero).
pub fn take_stats() -> BufStats {
    let s = stats();
    ALLOCS.with(|c| c.set(0));
    POOL_RECYCLED.with(|c| c.set(0));
    BYTES_COPIED.with(|c| c.set(0));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_recycle_roundtrip() {
        let before = stats();
        let v: Vec<i32> = acquire(100);
        assert!(v.capacity() >= 100);
        assert!(v.is_empty());
        recycle(v);
        let v2: Vec<i32> = acquire(80); // same class (128) serves 80
        assert!(v2.capacity() >= 80);
        let after = stats();
        assert_eq!(after.pool_recycled - before.pool_recycled, 1);
        assert_eq!(after.allocs - before.allocs, 1);
    }

    #[test]
    fn distinct_elem_types_do_not_mix() {
        let v: Vec<i64> = acquire(16);
        recycle(v);
        // an i32 request of the same class must not see the i64 storage
        // as a type confusion — it simply comes from the i32 pool
        let w: Vec<i32> = acquire(16);
        assert!(w.capacity() >= 16);
    }

    #[test]
    fn class_is_monotone() {
        assert_eq!(Pool::<i32>::class(1), 0);
        assert_eq!(Pool::<i32>::class(2), 1);
        assert_eq!(Pool::<i32>::class(3), 2);
        assert_eq!(Pool::<i32>::class(4), 2);
        assert_eq!(Pool::<i32>::class(1024), 10);
    }

    #[test]
    fn charge_copy_accumulates() {
        let before = stats().bytes_copied;
        charge_copy(40);
        charge_copy(2);
        assert_eq!(stats().bytes_copied - before, 42);
    }
}
