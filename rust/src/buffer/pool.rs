//! Per-rank (thread-local) capacity-bucketed free lists for slab storage,
//! per node-group shared overflow arenas ([`ShardPool`]), the
//! allocation/copy counters that make the zero-copy transport's behavior
//! observable, and (under the `debug-cow` feature) per-copy attribution of
//! *which* collective and call site triggered each memcpy.
//!
//! Every rank of a world runs on its own OS thread, so a `thread_local!`
//! pool *is* a per-rank pool with no synchronization at all. Buffers enter
//! the pool when a [`Slab`](super::slab::Slab) drops — which happens on the
//! thread that dropped the last view, i.e. usually the **receiving** rank —
//! and leave it whenever that rank next needs storage (a copy-on-write, a
//! send-time snapshot, a zeroed result buffer). In steady state a pipelined
//! collective therefore runs with zero allocator traffic: the paper's
//! `O(b)` per-phase allocations become `O(1)`.
//!
//! When the thread-local list overflows or misses, the fallback is the
//! rank's **node-group shard pool** (bound by `run_world` from the world's
//! shard layout), not the global allocator: storage freed by one rank of a
//! node group is reclaimed by its neighbors, and different shards never
//! contend on a shared arena. Only a miss in *both* tiers hits the system
//! allocator (counted in `allocs`).
//!
//! Buckets are powers of two by *capacity in elements*; a request is served
//! from the smallest bucket whose capacity fits. Both tiers are bounded
//! ([`MAX_PER_BUCKET`] / [`SHARD_PER_BUCKET`], [`MAX_POOLED_BYTES`] per
//! entry) so a one-off giant vector cannot pin memory forever.

use std::any::{Any, TypeId};
use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::ops::Elem;

/// Free-list entries kept per capacity class in a rank's local pool.
const MAX_PER_BUCKET: usize = 8;

/// Free-list entries kept per capacity class in a shard (node group) pool —
/// it backs many ranks, so it holds more before dropping storage.
const SHARD_PER_BUCKET: usize = 64;

/// Largest single buffer the pool will retain (bytes). Bigger ones go back
/// to the allocator — they are whole working vectors, not pipeline blocks.
const MAX_POOLED_BYTES: usize = 64 << 20;

/// Number of power-of-two capacity classes (2^0 .. 2^47 elements).
const CLASSES: usize = 48;

struct Pool<E: Elem> {
    buckets: Vec<Vec<Vec<E>>>,
    per_bucket: usize,
}

impl<E: Elem> Pool<E> {
    fn new(per_bucket: usize) -> Pool<E> {
        Pool {
            buckets: (0..CLASSES).map(|_| Vec::new()).collect(),
            per_bucket,
        }
    }

    fn class(cap: usize) -> usize {
        (usize::BITS - cap.max(1).next_power_of_two().leading_zeros()) as usize - 1
    }

    /// A vector with `capacity >= cap`, recycled if possible. The returned
    /// vector has length 0.
    fn get(&mut self, cap: usize) -> Option<Vec<E>> {
        let lo = Self::class(cap);
        for c in lo..CLASSES.min(lo + 2) {
            // a class is a capacity floor, not a guarantee: scan the whole
            // bucket (≤ per_bucket entries) for the first fit
            let bucket = &mut self.buckets[c];
            if let Some(i) = bucket.iter().position(|v| v.capacity() >= cap) {
                let mut v = bucket.swap_remove(i);
                v.clear();
                return Some(v);
            }
        }
        None
    }

    /// Keep `v` if there is room; hand it back (for donation to the next
    /// tier) when the bucket is full. Empty or oversized vectors are
    /// dropped outright (`None`) — they are not worth pooling anywhere.
    fn put(&mut self, v: Vec<E>) -> Option<Vec<E>> {
        let cap = v.capacity();
        if cap == 0 || cap * E::BYTES > MAX_POOLED_BYTES {
            return None;
        }
        let c = Self::class(cap).min(CLASSES - 1);
        if self.buckets[c].len() < self.per_bucket {
            self.buckets[c].push(v);
            None
        } else {
            Some(v)
        }
    }
}

/// Per-element-type pools, keyed by `TypeId`.
type PoolMap = HashMap<TypeId, Box<dyn Any + Send>>;

/// A shared overflow arena for one node group (registry shard) of a world:
/// the second tier between the per-rank thread-local free lists and the
/// system allocator. One instance exists per shard, so large sharded
/// worlds never serialize buffer recycling on a single arena.
pub struct ShardPool {
    inner: Mutex<PoolMap>,
}

impl ShardPool {
    pub fn new() -> ShardPool {
        ShardPool {
            inner: Mutex::new(PoolMap::new()),
        }
    }

    fn with_pool<E: Elem, R>(&self, f: impl FnOnce(&mut Pool<E>) -> R) -> R {
        let mut map = self.inner.lock().unwrap();
        let pool = map
            .entry(TypeId::of::<E>())
            .or_insert_with(|| Box::new(Pool::<E>::new(SHARD_PER_BUCKET)) as Box<dyn Any + Send>)
            .downcast_mut::<Pool<E>>()
            .expect("shard pool type keyed by TypeId");
        f(pool)
    }

    fn get<E: Elem>(&self, cap: usize) -> Option<Vec<E>> {
        self.with_pool(|p: &mut Pool<E>| p.get(cap))
    }

    fn put<E: Elem>(&self, v: Vec<E>) {
        self.with_pool(move |p: &mut Pool<E>| {
            let _ = p.put(v); // overflow past the shard tier is dropped
        });
    }
}

impl Default for ShardPool {
    fn default() -> ShardPool {
        ShardPool::new()
    }
}

/// Where a buffer-layer copy was charged from: the collective (and, for
/// the known snapshot points, the call site) active when `charge_copy`
/// ran. Only populated under the `debug-cow` feature.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CowEvent {
    /// Site label, e.g. `"dpdr/dual-exchange"`; `"untracked"` when the
    /// copy happened outside any labelled scope.
    pub site: &'static str,
    /// Bytes copied by this event.
    pub bytes: u64,
}

thread_local! {
    /// One pool per element type per thread (rank).
    static POOLS: RefCell<HashMap<TypeId, Box<dyn Any>>> = RefCell::new(HashMap::new());
    /// The node-group overflow arena this rank thread is bound to.
    static SHARD: RefCell<Option<Arc<ShardPool>>> = const { RefCell::new(None) };
    /// The label copies are currently attributed to (see [`cow_site`]).
    static COW_SITE: Cell<&'static str> = const { Cell::new("") };
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
    static POOL_RECYCLED: Cell<u64> = const { Cell::new(0) };
    static BYTES_COPIED: Cell<u64> = const { Cell::new(0) };
}

#[cfg(feature = "debug-cow")]
thread_local! {
    static COW_LOG: RefCell<Vec<CowEvent>> = const { RefCell::new(Vec::new()) };
}

/// Bind (or unbind, with `None`) this rank thread's node-group overflow
/// arena. `run_world` binds each rank thread to its shard's pool; threads
/// outside a world run with the thread-local tier only.
pub(crate) fn bind_shard_pool(pool: Option<Arc<ShardPool>>) {
    SHARD.with(|s| *s.borrow_mut() = pool);
}

/// Label buffer-layer copies with `label` until the returned guard drops
/// (the previous label is restored — scopes nest). Cheap enough to leave
/// on unconditionally; the per-copy log behind it only exists under the
/// `debug-cow` feature.
pub fn cow_site(label: &'static str) -> CowSiteGuard {
    CowSiteGuard {
        prev: COW_SITE.with(|c| c.replace(label)),
    }
}

/// Scope guard of [`cow_site`].
pub struct CowSiteGuard {
    prev: &'static str,
}

impl Drop for CowSiteGuard {
    fn drop(&mut self) {
        COW_SITE.with(|c| c.set(self.prev));
    }
}

fn with_pool<E: Elem, R>(f: impl FnOnce(&mut Pool<E>) -> R) -> R {
    POOLS.with(|pools| {
        let mut pools = pools.borrow_mut();
        let pool = pools
            .entry(TypeId::of::<E>())
            .or_insert_with(|| Box::new(Pool::<E>::new(MAX_PER_BUCKET)))
            .downcast_mut::<Pool<E>>()
            .expect("pool type keyed by TypeId");
        f(pool)
    })
}

/// A zero-length vector with capacity for at least `cap` elements, served
/// from this rank's free list — or its node group's shard pool — when
/// possible. Counts an alloc only when both tiers miss, a recycle on
/// either hit.
pub(crate) fn acquire<E: Elem>(cap: usize) -> Vec<E> {
    if let Some(v) = with_pool::<E, _>(|p| p.get(cap)) {
        POOL_RECYCLED.with(|c| c.set(c.get() + 1));
        return v;
    }
    if let Some(v) = SHARD.with(|s| s.borrow().as_ref().and_then(|sp| sp.get::<E>(cap))) {
        POOL_RECYCLED.with(|c| c.set(c.get() + 1));
        return v;
    }
    ALLOCS.with(|c| c.set(c.get() + 1));
    Vec::with_capacity(cap)
}

/// Return a vector's storage to this rank's free list; overflow is donated
/// to the node group's shard pool instead of being dropped.
pub(crate) fn recycle<E: Elem>(v: Vec<E>) {
    if let Some(overflow) = with_pool::<E, _>(|p| p.put(v)) {
        SHARD.with(|s| {
            if let Some(sp) = s.borrow().as_ref() {
                sp.put(overflow);
            }
        });
    }
}

/// Charge `n` copied bytes to this rank's counter (CoW and snapshots).
/// Under `debug-cow`, also record the active [`cow_site`] label so the
/// copy names its caller.
pub(crate) fn charge_copy(bytes: usize) {
    BYTES_COPIED.with(|c| c.set(c.get() + bytes as u64));
    #[cfg(feature = "debug-cow")]
    if bytes > 0 {
        let site = COW_SITE.with(Cell::get);
        let site = if site.is_empty() { "untracked" } else { site };
        COW_LOG.with(|l| {
            l.borrow_mut().push(CowEvent {
                site,
                bytes: bytes as u64,
            })
        });
    }
}

/// Snapshot of one rank's buffer-layer counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BufStats {
    /// Slab allocations that missed the pool and hit the system allocator.
    pub allocs: u64,
    /// Slab allocations served from the free list.
    pub pool_recycled: u64,
    /// Bytes memcpy'd by the buffer layer (copy-on-write, snapshots,
    /// `into_vec` fallbacks) — *not* reduction work.
    pub bytes_copied: u64,
}

/// Read this thread's counters without resetting them.
pub fn stats() -> BufStats {
    BufStats {
        allocs: ALLOCS.with(Cell::get),
        pool_recycled: POOL_RECYCLED.with(Cell::get),
        bytes_copied: BYTES_COPIED.with(Cell::get),
    }
}

/// Read and reset this thread's counters (rank threads call this when a
/// world finishes so the next run starts from zero).
pub fn take_stats() -> BufStats {
    let s = stats();
    ALLOCS.with(|c| c.set(0));
    POOL_RECYCLED.with(|c| c.set(0));
    BYTES_COPIED.with(|c| c.set(0));
    s
}

/// Drain this thread's copy-attribution log. Always callable; the log is
/// only populated when the crate is built with the `debug-cow` feature, so
/// without it this returns an empty vector.
pub fn take_cow_log() -> Vec<CowEvent> {
    #[cfg(feature = "debug-cow")]
    {
        COW_LOG.with(|l| std::mem::take(&mut *l.borrow_mut()))
    }
    #[cfg(not(feature = "debug-cow"))]
    {
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_recycle_roundtrip() {
        let before = stats();
        let v: Vec<i32> = acquire(100);
        assert!(v.capacity() >= 100);
        assert!(v.is_empty());
        recycle(v);
        let v2: Vec<i32> = acquire(80); // same class (128) serves 80
        assert!(v2.capacity() >= 80);
        let after = stats();
        assert_eq!(after.pool_recycled - before.pool_recycled, 1);
        assert_eq!(after.allocs - before.allocs, 1);
    }

    #[test]
    fn distinct_elem_types_do_not_mix() {
        let v: Vec<i64> = acquire(16);
        recycle(v);
        // an i32 request of the same class must not see the i64 storage
        // as a type confusion — it simply comes from the i32 pool
        let w: Vec<i32> = acquire(16);
        assert!(w.capacity() >= 16);
    }

    #[test]
    fn shard_pool_absorbs_local_overflow_and_serves_misses() {
        let shard = Arc::new(ShardPool::new());
        bind_shard_pool(Some(Arc::clone(&shard)));
        // overflow the local bucket for one capacity class: the extras
        // must land in the shard pool, not the floor
        let cap = 1 << 20; // distinctive class, unlikely noise from other tests
        for _ in 0..MAX_PER_BUCKET + 3 {
            recycle::<i64>(Vec::with_capacity(cap));
        }
        assert!(shard.get::<i64>(cap).is_some()); // donated overflow is there
        // a local miss falls through to the shard tier and counts a recycle
        shard.put::<i64>(Vec::with_capacity(2 * cap));
        let before = stats();
        let v: Vec<i64> = acquire(2 * cap);
        assert!(v.capacity() >= 2 * cap);
        let after = stats();
        assert_eq!(after.pool_recycled - before.pool_recycled, 1);
        assert_eq!(after.allocs, before.allocs);
        bind_shard_pool(None);
    }

    #[test]
    fn unbound_threads_keep_the_old_single_tier_behavior() {
        bind_shard_pool(None);
        let before = stats();
        let v: Vec<i32> = acquire(1 << 21); // larger than anything pooled here
        assert!(v.capacity() >= 1 << 21);
        assert_eq!(stats().allocs - before.allocs, 1);
    }

    #[test]
    fn cow_site_scopes_nest_and_restore() {
        let _a = cow_site("outer");
        assert_eq!(COW_SITE.with(Cell::get), "outer");
        {
            let _b = cow_site("inner");
            assert_eq!(COW_SITE.with(Cell::get), "inner");
        }
        assert_eq!(COW_SITE.with(Cell::get), "outer");
    }

    #[cfg(feature = "debug-cow")]
    #[test]
    fn cow_log_attributes_copies_to_the_active_site() {
        let _ = take_cow_log();
        {
            let _s = cow_site("test/site");
            charge_copy(40);
        }
        charge_copy(0); // zero-byte charges are not logged
        charge_copy(2); // outside any scope → "untracked"
        let log = take_cow_log();
        assert_eq!(log.len(), 2);
        assert_eq!(log[0], CowEvent { site: "test/site", bytes: 40 });
        assert_eq!(log[1].site, "untracked");
        assert!(take_cow_log().is_empty()); // drained
    }

    #[test]
    fn class_is_monotone() {
        assert_eq!(Pool::<i32>::class(1), 0);
        assert_eq!(Pool::<i32>::class(2), 1);
        assert_eq!(Pool::<i32>::class(3), 2);
        assert_eq!(Pool::<i32>::class(4), 2);
        assert_eq!(Pool::<i32>::class(1024), 10);
    }

    #[test]
    fn charge_copy_accumulates() {
        let before = stats().bytes_copied;
        charge_copy(40);
        charge_copy(2);
        assert_eq!(stats().bytes_copied - before, 42);
    }
}
