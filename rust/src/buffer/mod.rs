//! Data buffers that can be *real* (carrying elements) or *phantom*
//! (carrying only a length) — with zero-copy block views over a shared
//! slab on the real path.
//!
//! ## Phantom buffers
//!
//! Regenerating the paper's Table 2 means running p = 288 ranks on vectors
//! of up to 8 388 608 `int` elements. With real data that is ~9.7 GB of
//! live buffers *per algorithm run* — pointless, because the quantity being
//! reproduced is *time in the α-β cost model*, not the sums themselves.
//! Phantom buffers let the exact same algorithm code run the full protocol
//! (every sendrecv, every round, every block boundary) while messages carry
//! only sizes; reduction cost is still charged (γ·n) by the virtual clock.
//! Correctness of the data path is established separately by the real-mode
//! test battery at smaller (p, m).
//!
//! ## Real buffers: slab + view
//!
//! A real buffer is a `(slab, offset, len)` **view** of a reference-counted
//! element [`Slab`](slab::Slab). The owner of a vector holds the slab's
//! single *exclusive* (writable) view; [`DataBuf::extract`] / the
//! [`DataBuf::block`] alias carve out sub-views that share the slab without
//! copying — sending a pipeline block is a refcount bump, not a memcpy.
//! The receiving rank reduces straight out of the sender's slab and drops
//! the view; steady-state block transport is copy-free and allocation-free
//! (see [`pool`] for the free lists that absorb the remaining cold-path
//! allocations, and `RankMetrics::{allocs, bytes_copied, pool_recycled}`
//! for the counters that prove it).
//!
//! Mutation keeps the old value semantics via copy-on-write:
//!
//! * a non-exclusive view that is mutated first copies its range into a
//!   fresh slab (the view had no write rights);
//! * the exclusive view checks the slab's lease table (see [`slab`]) and
//!   copies out only if an in-flight view overlaps the range being
//!   written — which preserves MPI send semantics exactly: a sent block
//!   always reads as its send-time contents, never as later updates.
//!
//! Collectives that *knowingly* overwrite a range right after sending it
//! (the dual-root exchange, the recursive-doubling butterfly) use
//! [`DataBuf::extract_owned`] / [`DataBuf::snapshot`] to pay one pooled
//! block copy up front instead of a whole-vector CoW.

pub mod pool;
mod slab;

pub use pool::{BufStats, CowEvent, ShardPool};

use std::mem::ManuallyDrop;
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::ops::{Elem, ReduceOp, Side};
use slab::Slab;

/// How many scheduler yields the exclusive view waits for an overlapping
/// in-flight lease to clear before falling back to copy-on-write. Protocol
/// conflicts are transient (the receiver is about to consume the block), so
/// a short wait usually avoids the copy entirely.
const COW_SPINS: usize = 32;

/// A view of a shared real slab: the storage behind `DataBuf::Real`.
///
/// Fields are private; construct through [`DataBuf::real`],
/// [`DataBuf::extract`], or [`DataBuf::clone`]. A `RealBuf` is either the
/// slab's single *exclusive* (writable) handle or a read-only view holding
/// a registered lease for its whole lifetime.
pub struct RealBuf<E: Elem> {
    slab: Arc<Slab<E>>,
    off: usize,
    len: usize,
    /// `None` ⇒ exclusive writable handle; `Some(id)` ⇒ read lease.
    lease: Option<u64>,
}

impl<E: Elem> RealBuf<E> {
    fn from_vec(v: Vec<E>) -> RealBuf<E> {
        let len = v.len();
        RealBuf {
            slab: Arc::new(Slab::from_vec(v)),
            off: 0,
            len,
            lease: None,
        }
    }

    fn len(&self) -> usize {
        self.len
    }

    fn as_slice(&self) -> &[E] {
        // SAFETY: views hold a lease over [off, off+len) for their whole
        // lifetime; the exclusive handle is the only possible writer and
        // is borrowed shared here.
        unsafe { self.slab.read(self.off, self.len) }
    }

    /// A read-only sub-view `[lo, hi)` sharing this buffer's slab.
    fn view(&self, lo: usize, hi: usize) -> RealBuf<E> {
        debug_assert!(lo <= hi && hi <= self.len);
        let off = self.off + lo;
        let len = hi - lo;
        let lease = self.slab.lease(off, len);
        RealBuf {
            slab: Arc::clone(&self.slab),
            off,
            len,
            lease: Some(lease),
        }
    }

    /// True if this buffer shares its slab (a view, or an exclusive handle
    /// with live views of it elsewhere).
    fn is_shared(&self) -> bool {
        self.lease.is_some() || Arc::strong_count(&self.slab) > 1
    }

    /// Replace this handle with an exclusive copy of its range.
    fn cow(&mut self) {
        let mut v = pool::acquire::<E>(self.len);
        v.extend_from_slice(self.as_slice());
        pool::charge_copy(self.len * E::BYTES);
        if let Some(id) = self.lease.take() {
            self.slab.release(id);
        }
        self.slab = Arc::new(Slab::from_vec(v));
        self.off = 0;
    }

    /// Writable access to `[lo, lo + n)`, copying out first if this handle
    /// is a read-only view or an in-flight view overlaps the range.
    fn writable(&mut self, lo: usize, n: usize) -> &mut [E] {
        debug_assert!(lo + n <= self.len);
        if self.lease.is_some() {
            self.cow();
        } else if n > 0 && self.slab.overlaps(self.off + lo, n, None) {
            let mut spins = 0;
            while spins < COW_SPINS && self.slab.overlaps(self.off + lo, n, None) {
                std::thread::yield_now();
                spins += 1;
            }
            if self.slab.overlaps(self.off + lo, n, None) {
                self.cow();
            }
        }
        // SAFETY: self is now the exclusive handle and no lease overlaps
        // the range (checked above, and new overlapping leases cannot be
        // created while we hold &mut self — see the slab module docs).
        unsafe { self.slab.write(self.off + lo, n) }
    }

    /// An exclusive (owned) copy of `[lo, hi)`, storage drawn from the
    /// rank's free list.
    fn snapshot_range(&self, lo: usize, hi: usize) -> RealBuf<E> {
        debug_assert!(lo <= hi && hi <= self.len);
        let mut v = pool::acquire::<E>(hi - lo);
        v.extend_from_slice(&self.as_slice()[lo..hi]);
        pool::charge_copy((hi - lo) * E::BYTES);
        RealBuf::from_vec(v)
    }

    fn into_vec(self) -> Vec<E> {
        if self.lease.is_some() || self.off != 0 || self.len != self.slab.len() {
            // a sub-view: copy out; the lease is released by Drop *after*
            // the read, so the range cannot be mutated under us
            pool::charge_copy(self.len * E::BYTES);
            return self.as_slice().to_vec();
        }
        let this = ManuallyDrop::new(self);
        // SAFETY: lease is None so the skipped Drop would only release the
        // Arc, whose ownership we take here.
        let slab = unsafe { std::ptr::read(&this.slab) };
        match Arc::try_unwrap(slab) {
            Ok(s) => s.into_vec(),
            Err(arc) => {
                // views of this slab are still in flight: leave them the
                // storage and copy out
                pool::charge_copy(arc.len() * E::BYTES);
                // SAFETY: we held the exclusive handle, so no writer
                // exists; remaining handles are read-only views.
                unsafe { arc.read(0, arc.len()) }.to_vec()
            }
        }
    }
}

impl<E: Elem> Clone for RealBuf<E> {
    fn clone(&self) -> RealBuf<E> {
        self.view(0, self.len)
    }
}

impl<E: Elem> Drop for RealBuf<E> {
    fn drop(&mut self) {
        if let Some(id) = self.lease.take() {
            self.slab.release(id);
        }
    }
}

impl<E: Elem> std::fmt::Debug for RealBuf<E> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RealBuf")
            .field("len", &self.len)
            .field("off", &self.off)
            .field("view", &self.lease.is_some())
            .finish()
    }
}

impl<E: Elem> PartialEq for RealBuf<E> {
    fn eq(&self, other: &RealBuf<E>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

/// A vector of `E` that either physically exists or is a counted phantom.
#[derive(Clone, Debug)]
pub enum DataBuf<E: Elem> {
    /// Real data: a (possibly shared) view of an element slab.
    Real(RealBuf<E>),
    /// Only a length; contents are never materialized.
    Phantom(usize),
}

impl<E: Elem> PartialEq for DataBuf<E> {
    fn eq(&self, other: &DataBuf<E>) -> bool {
        match (self, other) {
            (DataBuf::Real(a), DataBuf::Real(b)) => a == b,
            (DataBuf::Phantom(a), DataBuf::Phantom(b)) => a == b,
            _ => false,
        }
    }
}

impl<E: Elem> DataBuf<E> {
    /// A real buffer taking ownership of a vector (becomes the slab's
    /// exclusive handle).
    pub fn real(v: Vec<E>) -> Self {
        DataBuf::Real(RealBuf::from_vec(v))
    }

    /// A real zero-filled buffer of length `n`, storage drawn from the
    /// rank's free list.
    pub fn real_zeroed(n: usize) -> Self {
        let mut v = pool::acquire::<E>(n);
        v.resize(n, E::zero());
        DataBuf::Real(RealBuf::from_vec(v))
    }

    /// A phantom buffer of length `n`.
    pub fn phantom(n: usize) -> Self {
        DataBuf::Phantom(n)
    }

    /// An empty buffer in the same mode as `self` (the "void block" of the
    /// paper's implementation sketch).
    pub fn empty_like(&self) -> Self {
        match self {
            DataBuf::Real(_) => DataBuf::real(Vec::new()),
            DataBuf::Phantom(_) => DataBuf::Phantom(0),
        }
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        match self {
            DataBuf::Real(b) => b.len(),
            DataBuf::Phantom(n) => *n,
        }
    }

    /// True if the buffer holds zero elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True for the phantom variant.
    pub fn is_phantom(&self) -> bool {
        matches!(self, DataBuf::Phantom(_))
    }

    /// True for a real buffer that shares storage with other live buffers
    /// (a zero-copy view, or a slab with views in flight).
    pub fn is_shared(&self) -> bool {
        match self {
            DataBuf::Real(b) => b.is_shared(),
            DataBuf::Phantom(_) => false,
        }
    }

    /// Wire size in bytes (drives the β term of the cost model).
    pub fn bytes(&self) -> usize {
        self.len() * E::BYTES
    }

    /// Borrow real contents; `None` for phantoms.
    pub fn as_slice(&self) -> Option<&[E]> {
        match self {
            DataBuf::Real(b) => Some(b.as_slice()),
            DataBuf::Phantom(_) => None,
        }
    }

    /// Mutably borrow real contents; `None` for phantoms. Copies out first
    /// if the buffer is a shared view (copy-on-write).
    pub fn as_mut_slice(&mut self) -> Option<&mut [E]> {
        match self {
            DataBuf::Real(b) => {
                let n = b.len();
                Some(b.writable(0, n))
            }
            DataBuf::Phantom(_) => None,
        }
    }

    /// Consume into a vector; errors on phantoms. Zero-copy when the
    /// buffer is the sole owner of its full slab.
    pub fn into_vec(self) -> Result<Vec<E>> {
        match self {
            DataBuf::Real(b) => Ok(b.into_vec()),
            DataBuf::Phantom(_) => Err(Error::BufferMode(
                "into_vec on a phantom buffer".into(),
            )),
        }
    }

    /// The sub-range `[lo, hi)` as a buffer of the same mode — for real
    /// buffers a **zero-copy view** sharing this buffer's slab.
    ///
    /// This is the "send a block" primitive: blocks leave the pipelining
    /// array as reference-counted views, not copies. The sent block reads
    /// as its send-time contents even if the source range is later
    /// overwritten (copy-on-write triggers on the writer's side).
    pub fn extract(&self, lo: usize, hi: usize) -> Result<DataBuf<E>> {
        if lo > hi || hi > self.len() {
            return Err(Error::Config(format!(
                "extract [{lo}, {hi}) out of bounds for len {}",
                self.len()
            )));
        }
        Ok(match self {
            DataBuf::Real(b) => DataBuf::Real(b.view(lo, hi)),
            DataBuf::Phantom(_) => DataBuf::Phantom(hi - lo),
        })
    }

    /// Alias of [`DataBuf::extract`] under the pipeline vocabulary: block
    /// `[lo, hi)` of the working vector as a zero-copy view.
    pub fn block(&self, lo: usize, hi: usize) -> Result<DataBuf<E>> {
        self.extract(lo, hi)
    }

    /// The sub-range `[lo, hi)` as an **owned** buffer (one pooled block
    /// copy). Use instead of [`DataBuf::extract`] when the caller will
    /// overwrite `[lo, hi)` before the receiver can possibly have consumed
    /// the block — e.g. the dual-root exchange reduces into the very block
    /// it just sent — where a view would force a whole-vector
    /// copy-on-write.
    pub fn extract_owned(&self, lo: usize, hi: usize) -> Result<DataBuf<E>> {
        if lo > hi || hi > self.len() {
            return Err(Error::Config(format!(
                "extract [{lo}, {hi}) out of bounds for len {}",
                self.len()
            )));
        }
        Ok(match self {
            DataBuf::Real(b) => DataBuf::Real(b.snapshot_range(lo, hi)),
            DataBuf::Phantom(_) => DataBuf::Phantom(hi - lo),
        })
    }

    /// An owned send-time copy of the whole buffer
    /// (`extract_owned(0, len)`).
    pub fn snapshot(&self) -> DataBuf<E> {
        self.extract_owned(0, self.len())
            .expect("full-range extract cannot be out of bounds")
    }

    /// Overwrite the sub-range `[lo, lo+incoming.len())` with `incoming`
    /// (the "receive a result block from the parent" primitive).
    pub fn write_at(&mut self, lo: usize, incoming: &DataBuf<E>) -> Result<()> {
        let n = incoming.len();
        if lo + n > self.len() {
            return Err(Error::Config(format!(
                "write_at [{lo}, {}) out of bounds for len {}",
                lo + n,
                self.len()
            )));
        }
        match (self, incoming) {
            (DataBuf::Real(dst), DataBuf::Real(src)) => {
                let s = src.as_slice();
                dst.writable(lo, n).copy_from_slice(s);
                Ok(())
            }
            (DataBuf::Phantom(_), DataBuf::Phantom(_)) => Ok(()),
            _ => Err(Error::BufferMode(
                "write_at mixing real and phantom buffers".into(),
            )),
        }
    }

    /// Reduce `incoming` into the sub-range `[lo, lo+incoming.len())`:
    /// `self[lo..] ← incoming ⊙ self[lo..]` (Side::Left) or the mirror.
    ///
    /// This is `MPI_Reduce_local` restricted to one pipeline block — on the
    /// zero-copy path it reads straight out of the sender's slab, and the
    /// arithmetic operators dispatch the element loop through the pluggable
    /// reduce-backend layer (scalar / SIMD / PJRT — see
    /// [`crate::ops::backend`]). For phantom buffers it is a no-op (the
    /// virtual clock charges γ·n at the call site).
    pub fn reduce_at<O: ReduceOp<E> + ?Sized>(
        &mut self,
        lo: usize,
        incoming: &DataBuf<E>,
        op: &O,
        side: Side,
    ) -> Result<()> {
        let n = incoming.len();
        if lo + n > self.len() {
            return Err(Error::Config(format!(
                "reduce_at [{lo}, {}) out of bounds for len {}",
                lo + n,
                self.len()
            )));
        }
        match (self, incoming) {
            (DataBuf::Real(dst), DataBuf::Real(src)) => {
                let s = src.as_slice();
                op.reduce_into(dst.writable(lo, n), s, side);
                Ok(())
            }
            (DataBuf::Phantom(_), DataBuf::Phantom(_)) => Ok(()),
            _ => Err(Error::BufferMode(
                "reduce_at mixing real and phantom buffers".into(),
            )),
        }
    }

    /// Fused two-incoming reduction into the sub-range `[lo, lo+n)`:
    /// `self[lo..] ← t1 ⊙ (t0 ⊙ self[lo..])` — exactly two successive
    /// [`Side::Left`] [`reduce_at`](DataBuf::reduce_at) calls collapsed
    /// into one pass (bitwise-identical by construction). This is the
    /// inner-node shape of the paper's Algorithm 1: a rank with two
    /// children folds both received blocks into its partial result every
    /// round. Both incomings are read zero-copy out of their senders'
    /// slabs; for phantom buffers it is a no-op (the call site charges
    /// γ·2n to the virtual clock).
    pub fn reduce_at3<O: ReduceOp<E> + ?Sized>(
        &mut self,
        lo: usize,
        t0: &DataBuf<E>,
        t1: &DataBuf<E>,
        op: &O,
    ) -> Result<()> {
        let n = t0.len();
        if t1.len() != n {
            return Err(Error::Config(format!(
                "reduce_at3 incoming length mismatch: t0 {} vs t1 {}",
                n,
                t1.len()
            )));
        }
        if lo + n > self.len() {
            return Err(Error::Config(format!(
                "reduce_at3 [{lo}, {}) out of bounds for len {}",
                lo + n,
                self.len()
            )));
        }
        match (self, t0, t1) {
            (DataBuf::Real(dst), DataBuf::Real(s0), DataBuf::Real(s1)) => {
                op.reduce_into3(dst.writable(lo, n), s0.as_slice(), s1.as_slice());
                Ok(())
            }
            (DataBuf::Phantom(_), DataBuf::Phantom(_), DataBuf::Phantom(_)) => Ok(()),
            _ => Err(Error::BufferMode(
                "reduce_at3 mixing real and phantom buffers".into(),
            )),
        }
    }

    /// Whole-buffer in-place reduction (used by the non-pipelined baselines).
    pub fn reduce_all<O: ReduceOp<E> + ?Sized>(
        &mut self,
        incoming: &DataBuf<E>,
        op: &O,
        side: Side,
    ) -> Result<()> {
        if incoming.len() != self.len() {
            return Err(Error::Config(format!(
                "reduce_all length mismatch {} vs {}",
                self.len(),
                incoming.len()
            )));
        }
        self.reduce_at(0, incoming, op, side)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{Mat2, Mat2Op, SumOp};

    #[test]
    fn real_roundtrip() {
        let b = DataBuf::real(vec![1i32, 2, 3]);
        assert_eq!(b.len(), 3);
        assert_eq!(b.bytes(), 12);
        assert!(!b.is_phantom());
        assert_eq!(b.as_slice().unwrap(), &[1, 2, 3]);
        assert_eq!(b.into_vec().unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn phantom_basics() {
        let b: DataBuf<i32> = DataBuf::phantom(5);
        assert_eq!(b.len(), 5);
        assert!(b.is_phantom());
        assert!(b.as_slice().is_none());
        assert!(b.clone().into_vec().is_err());
        assert_eq!(b.extract(1, 4).unwrap(), DataBuf::phantom(3));
    }

    #[test]
    fn extract_and_write() {
        let b = DataBuf::real(vec![10i32, 20, 30, 40]);
        let blk = b.extract(1, 3).unwrap();
        assert_eq!(blk.as_slice().unwrap(), &[20, 30]);
        let mut dst = DataBuf::real(vec![0i32; 4]);
        dst.write_at(2, &blk).unwrap();
        assert_eq!(dst.as_slice().unwrap(), &[0, 0, 20, 30]);
    }

    #[test]
    fn extract_is_zero_copy_view() {
        let b = DataBuf::real(vec![1i32, 2, 3, 4]);
        let blk = b.extract(0, 2).unwrap();
        assert!(blk.is_shared());
        assert!(b.is_shared()); // views of its slab are live
        drop(blk);
        assert!(!b.is_shared());
    }

    #[test]
    fn extract_owned_is_independent() {
        let mut b = DataBuf::real(vec![1i32, 2, 3, 4]);
        let blk = b.extract_owned(0, 2).unwrap();
        assert!(!blk.is_shared());
        b.as_mut_slice().unwrap()[0] = 99;
        assert_eq!(blk.as_slice().unwrap(), &[1, 2]); // unaffected
    }

    #[test]
    fn writer_cow_preserves_send_time_contents() {
        let mut b = DataBuf::real(vec![1i32, 2, 3, 4]);
        let sent = b.extract(0, 4).unwrap(); // full-range in-flight view
        b.as_mut_slice().unwrap()[0] = 77; // overlapping write → CoW
        assert_eq!(sent.as_slice().unwrap(), &[1, 2, 3, 4]); // send-time data
        assert_eq!(b.as_slice().unwrap(), &[77, 2, 3, 4]);
    }

    #[test]
    fn disjoint_write_keeps_sharing() {
        let mut b = DataBuf::real(vec![1i32, 2, 3, 4]);
        let blk = b.extract(0, 2).unwrap();
        // write outside the view's range: no CoW, the slab stays shared
        if let DataBuf::Real(rb) = &mut b {
            rb.writable(2, 2).copy_from_slice(&[8, 9]);
        }
        assert!(b.is_shared());
        assert_eq!(blk.as_slice().unwrap(), &[1, 2]);
        assert_eq!(b.as_slice().unwrap(), &[1, 2, 8, 9]);
    }

    #[test]
    fn view_of_view_nests() {
        let b = DataBuf::real(vec![0i32, 1, 2, 3, 4, 5]);
        let v = b.extract(2, 6).unwrap();
        let vv = v.extract(1, 3).unwrap();
        assert_eq!(vv.as_slice().unwrap(), &[3, 4]);
    }

    #[test]
    fn extract_bounds_checked() {
        let b = DataBuf::real(vec![1i32]);
        assert!(b.extract(0, 2).is_err());
        assert!(b.extract(2, 2).is_err());
        assert!(b.extract_owned(0, 2).is_err());
        let mut d = DataBuf::real(vec![1i32]);
        assert!(d.write_at(1, &DataBuf::real(vec![5])).is_err());
    }

    #[test]
    fn reduce_at_left() {
        let mut acc = DataBuf::real(vec![1i32, 2, 3, 4]);
        let inc = DataBuf::real(vec![10i32, 20]);
        acc.reduce_at(1, &inc, &SumOp, Side::Left).unwrap();
        assert_eq!(acc.as_slice().unwrap(), &[1, 12, 23, 4]);
    }

    #[test]
    fn reduce_at3_matches_two_left_reduces() {
        // non-commutative witness: fused must be exactly t1 ⊙ (t0 ⊙ y)
        let y = Mat2([1, 2, 3, 4]);
        let t0 = Mat2([5, 6, 7, 8]);
        let t1 = Mat2([9, 10, 11, 12]);
        let mut two = DataBuf::real(vec![Mat2::IDENT, y, Mat2::IDENT]);
        two.reduce_at(1, &DataBuf::real(vec![t0]), &Mat2Op, Side::Left)
            .unwrap();
        two.reduce_at(1, &DataBuf::real(vec![t1]), &Mat2Op, Side::Left)
            .unwrap();
        let mut fused = DataBuf::real(vec![Mat2::IDENT, y, Mat2::IDENT]);
        fused
            .reduce_at3(1, &DataBuf::real(vec![t0]), &DataBuf::real(vec![t1]), &Mat2Op)
            .unwrap();
        assert_eq!(fused.as_slice().unwrap(), two.as_slice().unwrap());

        // phantom path is a no-op, mixed modes are typed errors
        let mut ph: DataBuf<i32> = DataBuf::phantom(4);
        ph.reduce_at3(0, &DataBuf::phantom(2), &DataBuf::phantom(2), &SumOp)
            .unwrap();
        let mut real = DataBuf::real(vec![1i32, 2]);
        assert!(real
            .reduce_at3(0, &DataBuf::phantom(2), &DataBuf::phantom(2), &SumOp)
            .is_err());
        // mismatched incoming lengths and out-of-bounds are typed errors
        assert!(real
            .reduce_at3(0, &DataBuf::real(vec![1]), &DataBuf::real(vec![1, 2]), &SumOp)
            .is_err());
        assert!(real
            .reduce_at3(1, &DataBuf::real(vec![1, 2]), &DataBuf::real(vec![3, 4]), &SumOp)
            .is_err());
    }

    #[test]
    fn reduce_side_matters() {
        let a = Mat2([1, 2, 3, 4]);
        let t = Mat2([0, 1, 1, 0]);
        let mut left = DataBuf::real(vec![a]);
        left.reduce_all(&DataBuf::real(vec![t]), &Mat2Op, Side::Left)
            .unwrap();
        assert_eq!(left.as_slice().unwrap()[0], t.mul(a));
        let mut right = DataBuf::real(vec![a]);
        right
            .reduce_all(&DataBuf::real(vec![t]), &Mat2Op, Side::Right)
            .unwrap();
        assert_eq!(right.as_slice().unwrap()[0], a.mul(t));
    }

    #[test]
    fn mode_mixing_rejected() {
        let mut r = DataBuf::real(vec![1i32, 2]);
        let p: DataBuf<i32> = DataBuf::phantom(2);
        assert!(r.write_at(0, &p).is_err());
        assert!(r.reduce_all(&p, &SumOp, Side::Left).is_err());
    }

    #[test]
    fn empty_like_preserves_mode() {
        let r = DataBuf::real(vec![1i32]);
        let e = r.empty_like();
        assert!(!e.is_phantom());
        assert!(e.is_empty());
        let p: DataBuf<i32> = DataBuf::phantom(3);
        assert!(matches!(p.empty_like(), DataBuf::Phantom(0)));
    }

    #[test]
    fn into_vec_with_views_in_flight_copies() {
        let b = DataBuf::real(vec![4i32, 5, 6]);
        let v = b.extract(0, 2).unwrap();
        let out = b.into_vec().unwrap();
        assert_eq!(out, vec![4, 5, 6]);
        assert_eq!(v.as_slice().unwrap(), &[4, 5]); // view survives
    }

    #[test]
    fn view_into_vec_copies_range() {
        let b = DataBuf::real(vec![7i32, 8, 9]);
        let v = b.extract(1, 3).unwrap();
        assert_eq!(v.into_vec().unwrap(), vec![8, 9]);
        assert_eq!(b.as_slice().unwrap(), &[7, 8, 9]);
    }

    #[test]
    fn clone_is_view_and_mutation_cows() {
        let b = DataBuf::real(vec![1i32, 2]);
        let mut c = b.clone();
        assert!(c.is_shared());
        c.as_mut_slice().unwrap()[1] = 5; // view mutation → its own slab
        assert_eq!(b.as_slice().unwrap(), &[1, 2]);
        assert_eq!(c.as_slice().unwrap(), &[1, 5]);
    }

    #[test]
    fn pool_counters_track_snapshot_traffic() {
        let before = pool::stats();
        let b = DataBuf::real(vec![0i32; 64]);
        let s = b.snapshot();
        drop(s); // storage goes to the free list
        let s2 = b.snapshot(); // served from the free list
        drop(s2);
        let after = pool::stats();
        assert_eq!(after.bytes_copied - before.bytes_copied, 2 * 64 * 4);
        assert!(after.pool_recycled > before.pool_recycled);
    }
}
