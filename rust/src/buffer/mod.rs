//! Data buffers that can be *real* (carrying elements) or *phantom*
//! (carrying only a length).
//!
//! Why: regenerating the paper's Table 2 means running p = 288 ranks on
//! vectors of up to 8 388 608 `int` elements. With real data that is
//! ~9.7 GB of live buffers *per algorithm run* — pointless, because the
//! quantity being reproduced is *time in the α-β cost model*, not the sums
//! themselves. Phantom buffers let the exact same algorithm code run the
//! full protocol (every sendrecv, every round, every block boundary) while
//! messages carry only sizes; reduction cost is still charged (γ·n) by the
//! virtual clock. Correctness of the data path is established separately by
//! the real-mode test battery at smaller (p, m).

use crate::error::{Error, Result};
use crate::ops::{Elem, ReduceOp, Side};

/// A vector of `E` that either physically exists or is a counted phantom.
#[derive(Clone, Debug, PartialEq)]
pub enum DataBuf<E: Elem> {
    /// Real data.
    Real(Vec<E>),
    /// Only a length; contents are never materialized.
    Phantom(usize),
}

impl<E: Elem> DataBuf<E> {
    /// A real buffer from a vector.
    pub fn real(v: Vec<E>) -> Self {
        DataBuf::Real(v)
    }

    /// A real zero-filled buffer of length `n`.
    pub fn real_zeroed(n: usize) -> Self {
        DataBuf::Real(vec![E::zero(); n])
    }

    /// A phantom buffer of length `n`.
    pub fn phantom(n: usize) -> Self {
        DataBuf::Phantom(n)
    }

    /// An empty buffer in the same mode as `self` (the "void block" of the
    /// paper's implementation sketch).
    pub fn empty_like(&self) -> Self {
        match self {
            DataBuf::Real(_) => DataBuf::Real(Vec::new()),
            DataBuf::Phantom(_) => DataBuf::Phantom(0),
        }
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        match self {
            DataBuf::Real(v) => v.len(),
            DataBuf::Phantom(n) => *n,
        }
    }

    /// True if the buffer holds zero elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True for the phantom variant.
    pub fn is_phantom(&self) -> bool {
        matches!(self, DataBuf::Phantom(_))
    }

    /// Wire size in bytes (drives the β term of the cost model).
    pub fn bytes(&self) -> usize {
        self.len() * E::BYTES
    }

    /// Borrow real contents; `None` for phantoms.
    pub fn as_slice(&self) -> Option<&[E]> {
        match self {
            DataBuf::Real(v) => Some(v),
            DataBuf::Phantom(_) => None,
        }
    }

    /// Mutably borrow real contents; `None` for phantoms.
    pub fn as_mut_slice(&mut self) -> Option<&mut [E]> {
        match self {
            DataBuf::Real(v) => Some(v),
            DataBuf::Phantom(_) => None,
        }
    }

    /// Consume into a vector; errors on phantoms.
    pub fn into_vec(self) -> Result<Vec<E>> {
        match self {
            DataBuf::Real(v) => Ok(v),
            DataBuf::Phantom(_) => Err(Error::BufferMode(
                "into_vec on a phantom buffer".into(),
            )),
        }
    }

    /// Copy out the sub-range `[lo, hi)` as a new buffer of the same mode.
    ///
    /// This is the "send a block" primitive: blocks leave the pipelining
    /// array as standalone messages.
    pub fn extract(&self, lo: usize, hi: usize) -> Result<DataBuf<E>> {
        if lo > hi || hi > self.len() {
            return Err(Error::Config(format!(
                "extract [{lo}, {hi}) out of bounds for len {}",
                self.len()
            )));
        }
        Ok(match self {
            DataBuf::Real(v) => DataBuf::Real(v[lo..hi].to_vec()),
            DataBuf::Phantom(_) => DataBuf::Phantom(hi - lo),
        })
    }

    /// Overwrite the sub-range `[lo, lo+incoming.len())` with `incoming`
    /// (the "receive a result block from the parent" primitive).
    pub fn write_at(&mut self, lo: usize, incoming: &DataBuf<E>) -> Result<()> {
        let n = incoming.len();
        if lo + n > self.len() {
            return Err(Error::Config(format!(
                "write_at [{lo}, {}) out of bounds for len {}",
                lo + n,
                self.len()
            )));
        }
        match (self, incoming) {
            (DataBuf::Real(dst), DataBuf::Real(src)) => {
                dst[lo..lo + n].copy_from_slice(src);
                Ok(())
            }
            (DataBuf::Phantom(_), DataBuf::Phantom(_)) => Ok(()),
            _ => Err(Error::BufferMode(
                "write_at mixing real and phantom buffers".into(),
            )),
        }
    }

    /// Reduce `incoming` into the sub-range `[lo, lo+incoming.len())`:
    /// `self[lo..] ← incoming ⊙ self[lo..]` (Side::Left) or the mirror.
    ///
    /// This is `MPI_Reduce_local` restricted to one pipeline block. For
    /// phantom buffers it is a no-op (the virtual clock charges γ·n at the
    /// call site).
    pub fn reduce_at<O: ReduceOp<E> + ?Sized>(
        &mut self,
        lo: usize,
        incoming: &DataBuf<E>,
        op: &O,
        side: Side,
    ) -> Result<()> {
        let n = incoming.len();
        if lo + n > self.len() {
            return Err(Error::Config(format!(
                "reduce_at [{lo}, {}) out of bounds for len {}",
                lo + n,
                self.len()
            )));
        }
        match (self, incoming) {
            (DataBuf::Real(dst), DataBuf::Real(src)) => {
                op.reduce_into(&mut dst[lo..lo + n], src, side);
                Ok(())
            }
            (DataBuf::Phantom(_), DataBuf::Phantom(_)) => Ok(()),
            _ => Err(Error::BufferMode(
                "reduce_at mixing real and phantom buffers".into(),
            )),
        }
    }

    /// Whole-buffer in-place reduction (used by the non-pipelined baselines).
    pub fn reduce_all<O: ReduceOp<E> + ?Sized>(
        &mut self,
        incoming: &DataBuf<E>,
        op: &O,
        side: Side,
    ) -> Result<()> {
        if incoming.len() != self.len() {
            return Err(Error::Config(format!(
                "reduce_all length mismatch {} vs {}",
                self.len(),
                incoming.len()
            )));
        }
        self.reduce_at(0, incoming, op, side)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{Mat2, Mat2Op, SumOp};

    #[test]
    fn real_roundtrip() {
        let b = DataBuf::real(vec![1i32, 2, 3]);
        assert_eq!(b.len(), 3);
        assert_eq!(b.bytes(), 12);
        assert!(!b.is_phantom());
        assert_eq!(b.as_slice().unwrap(), &[1, 2, 3]);
        assert_eq!(b.into_vec().unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn phantom_basics() {
        let b: DataBuf<i32> = DataBuf::phantom(5);
        assert_eq!(b.len(), 5);
        assert!(b.is_phantom());
        assert!(b.as_slice().is_none());
        assert!(b.clone().into_vec().is_err());
        assert_eq!(b.extract(1, 4).unwrap(), DataBuf::phantom(3));
    }

    #[test]
    fn extract_and_write() {
        let b = DataBuf::real(vec![10i32, 20, 30, 40]);
        let blk = b.extract(1, 3).unwrap();
        assert_eq!(blk.as_slice().unwrap(), &[20, 30]);
        let mut dst = DataBuf::real(vec![0i32; 4]);
        dst.write_at(2, &blk).unwrap();
        assert_eq!(dst.as_slice().unwrap(), &[0, 0, 20, 30]);
    }

    #[test]
    fn extract_bounds_checked() {
        let b = DataBuf::real(vec![1i32]);
        assert!(b.extract(0, 2).is_err());
        assert!(b.extract(2, 2).is_err());
        let mut d = DataBuf::real(vec![1i32]);
        assert!(d.write_at(1, &DataBuf::real(vec![5])).is_err());
    }

    #[test]
    fn reduce_at_left() {
        let mut acc = DataBuf::real(vec![1i32, 2, 3, 4]);
        let inc = DataBuf::real(vec![10i32, 20]);
        acc.reduce_at(1, &inc, &SumOp, Side::Left).unwrap();
        assert_eq!(acc.as_slice().unwrap(), &[1, 12, 23, 4]);
    }

    #[test]
    fn reduce_side_matters() {
        let a = Mat2([1, 2, 3, 4]);
        let t = Mat2([0, 1, 1, 0]);
        let mut left = DataBuf::real(vec![a]);
        left.reduce_all(&DataBuf::real(vec![t]), &Mat2Op, Side::Left)
            .unwrap();
        assert_eq!(left.as_slice().unwrap()[0], t.mul(a));
        let mut right = DataBuf::real(vec![a]);
        right
            .reduce_all(&DataBuf::real(vec![t]), &Mat2Op, Side::Right)
            .unwrap();
        assert_eq!(right.as_slice().unwrap()[0], a.mul(t));
    }

    #[test]
    fn mode_mixing_rejected() {
        let mut r = DataBuf::real(vec![1i32, 2]);
        let p: DataBuf<i32> = DataBuf::phantom(2);
        assert!(r.write_at(0, &p).is_err());
        assert!(r.reduce_all(&p, &SumOp, Side::Left).is_err());
    }

    #[test]
    fn empty_like_preserves_mode() {
        let r = DataBuf::real(vec![1i32]);
        assert!(matches!(r.empty_like(), DataBuf::Real(v) if v.is_empty()));
        let p: DataBuf<i32> = DataBuf::phantom(3);
        assert!(matches!(p.empty_like(), DataBuf::Phantom(0)));
    }
}
