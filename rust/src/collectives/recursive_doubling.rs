//! Recursive-doubling allreduce — the latency-optimal algorithm vendor
//! libraries use for small messages (`⌈log2 p⌉·(α + βm)`), and the
//! small-count branch of our emulated "native" `MPI_Allreduce`.
//!
//! Non-power-of-two `p` is handled by the standard pre/post fold: the first
//! `2·rem` ranks pair up (`rem = p − 2^⌊log2 p⌋`), odd partners fold their
//! vector into the even ones, the folded group of `2^K` *effective* ranks
//! runs the butterfly, and results are copied back out.
//!
//! Order preservation: effective rank `e` covers the original rank interval
//! `[2e, 2e+1]` (folded pair) or `[e + rem]`; these intervals are ascending
//! and contiguous, and at every butterfly step the partner's interval is
//! the complementary half of an aligned power-of-two window, so combining
//! with `Left`/`Right` chosen by comparison keeps exact rank order.

use crate::buffer::DataBuf;
use crate::comm::Comm;
use crate::error::Result;
use crate::ops::{Elem, ReduceOp, Side};

/// Map an effective rank back to the original rank that carries it.
fn carrier(e: usize, rem: usize) -> usize {
    if e < rem {
        2 * e
    } else {
        e + rem
    }
}

/// Recursive-doubling allreduce.
pub fn allreduce_recursive_doubling<E: Elem, O: ReduceOp<E>>(
    comm: &mut impl Comm<E>,
    x: DataBuf<E>,
    op: &O,
) -> Result<DataBuf<E>> {
    let p = comm.size();
    let mut y = x;
    if p == 1 || y.is_empty() {
        return Ok(y);
    }
    let rank = comm.rank();
    let k = crate::util::log2_floor(p) as usize;
    let pow = 1usize << k;
    let rem = p - pow;

    // pre-fold: ranks [0, 2·rem) pair (2i, 2i+1); odd folds into even
    let eff: Option<usize> = if rank < 2 * rem {
        if rank % 2 == 0 {
            let t = comm.recv(rank + 1)?;
            comm.charge_compute(t.bytes());
            y.reduce_all(&t, op, Side::Right)?; // partner is the next rank up
            Some(rank / 2)
        } else {
            comm.send(rank - 1, y.clone())?;
            None
        }
    } else {
        Some(rank - rem)
    };

    // butterfly over the 2^K effective ranks
    if let Some(e) = eff {
        for bit in 0..k {
            let partner_e = e ^ (1usize << bit);
            let partner = carrier(partner_e, rem);
            // Owned send-time snapshot, not a view: both partners reduce
            // over their whole vector right after the exchange, so a
            // shared view would make each wait on the other's in-flight
            // lease and degrade to the same full copy anyway — snapshot()
            // pays it up front from the free list, with no stall.
            let _site = crate::buffer::pool::cow_site("rd/butterfly-snapshot");
            let t = comm.sendrecv(partner, y.snapshot())?;
            let side = if partner_e < e { Side::Left } else { Side::Right };
            comm.charge_compute(t.bytes());
            y.reduce_all(&t, op, side)?;
        }
    }

    // post-fold: evens hand the finished vector back to their odd partner
    if rank < 2 * rem {
        if rank % 2 == 0 {
            comm.send(rank + 1, y.clone())?;
        } else {
            y = comm.recv(rank - 1)?;
        }
    }
    Ok(y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{run_allreduce_i32, RunSpec};
    use crate::comm::{run_world, Timing};
    use crate::model::AlgoKind;
    use crate::ops::{SeqCheckOp, Span};
    use crate::pipeline::Blocks;

    #[test]
    fn correct_powers_of_two() {
        for p in [1usize, 2, 4, 8, 16, 32] {
            let spec = RunSpec::new(p, 19);
            let expected = spec.expected_sum_i32();
            let report = run_allreduce_i32(AlgoKind::RecursiveDoubling, &spec, Timing::Real)
                .unwrap();
            for buf in report.results {
                assert_eq!(buf.as_slice().unwrap(), &expected[..], "p={p}");
            }
        }
    }

    #[test]
    fn correct_non_powers() {
        for p in [3usize, 5, 6, 7, 9, 11, 13, 20, 25] {
            let spec = RunSpec::new(p, 19);
            let expected = spec.expected_sum_i32();
            let report = run_allreduce_i32(AlgoKind::RecursiveDoubling, &spec, Timing::Real)
                .unwrap();
            for buf in report.results {
                assert_eq!(buf.as_slice().unwrap(), &expected[..], "p={p}");
            }
        }
    }

    #[test]
    fn order_witness_including_fold() {
        for p in [2usize, 3, 6, 8, 10, 16, 21] {
            let report = run_world::<Span, _, _>(p, Timing::Real, move |comm| {
                let x = DataBuf::real(vec![Span::rank(comm.rank() as u32); 4]);
                let blocks = Blocks::by_count(4, 1);
                let _ = &blocks;
                allreduce_recursive_doubling(comm, x, &SeqCheckOp)
            })
            .unwrap();
            for buf in report.results {
                for s in buf.as_slice().unwrap() {
                    assert_eq!(*s, Span::of(0, p as u32 - 1), "p={p}");
                }
            }
        }
    }

    #[test]
    fn virtual_cost_logp() {
        use crate::model::{ComputeCost, CostModel, LinkCost};
        let timing = Timing::Virtual(
            CostModel::Uniform(LinkCost::new(1e-6, 0.0)),
            ComputeCost::new(0.0),
        );
        let spec = RunSpec::new(16, 100).phantom(true);
        let t = run_allreduce_i32(AlgoKind::RecursiveDoubling, &spec, timing)
            .unwrap()
            .max_vtime_us;
        assert!((t - 4.0).abs() < 1e-6, "t={t}"); // log2(16) · α
    }
}
