//! Optimal *non-pipelined* allreduce: reduce-scatter + allgather over
//! circulant graphs (Träff 2024, arXiv 2410.14234) — correct for **any**
//! p, not only powers of two.
//!
//! Both phases run `q = ⌈log₂ p⌉` rounds. Round `k` of the allgather is
//! the classic Bruck dissemination step on the circulant graph with skip
//! `2^k`: rank `r` receives from `(r + 2^k) mod p` the block of
//! `s_k = min(2^k, p − 2^k)` segments starting at `r + 2^k`. The
//! reduce-scatter is that exchange *reversed* (rounds `k = q−1 … 0`,
//! arrows flipped), so each rank `r` ends up with segment `r` fully
//! reduced — the same doubling trick recursive halving uses, but with no
//! power-of-two fold: ragged rank counts pay at most one extra round,
//! never the `2βm` fold penalty.
//!
//! Cost: `2⌈log₂ p⌉·α + 2·((p−1)/p)·βm` for **every** p — the provably
//! optimal non-pipelined latency at bandwidth-optimal volume. Compare the
//! ring's `2(p−1)α` at the same βm: in the latency-dominated small-m
//! regime (where the Pipelining Lemma says *don't* pipeline) this is the
//! algorithm to beat, which is why the autotuned oracle
//! (`crate::model::tuner`) picks it for mid-size messages on dedicated
//! links.
//!
//! Segments accumulate in circulant (rotated) order, so like the ring
//! this is a *commutative-only* algorithm
//! ([`AlgoKind::order_preserving`](crate::model::AlgoKind) is false).

use crate::buffer::DataBuf;
use crate::comm::Comm;
use crate::error::Result;
use crate::ops::{Elem, ReduceOp, Side};
use crate::pipeline::Blocks;

/// `⌈log₂ p⌉` for `p ≥ 2`.
fn log2_ceil(p: usize) -> usize {
    (usize::BITS - (p - 1).leading_zeros()) as usize
}

/// Absolute element ranges of the `count` consecutive segments starting
/// at segment `start` (mod `p`): one contiguous piece, or two when the
/// run wraps past segment `p − 1`. Empty pieces are dropped.
fn run_pieces(segs: &Blocks, p: usize, start: usize, count: usize) -> Vec<(usize, usize)> {
    let start = start % p;
    let mut pieces = Vec::with_capacity(2);
    if start + count <= p {
        pieces.push((segs.range(start).0, segs.range(start + count - 1).1));
    } else {
        pieces.push((segs.range(start).0, segs.range(p - 1).1));
        pieces.push((0, segs.range(start + count - p - 1).1));
    }
    pieces.retain(|&(lo, hi)| hi > lo);
    pieces
}

/// Concatenate the pieces of a (possibly wrapped) segment run into one
/// send buffer. A single piece is a zero-copy view; a wrapped run copies
/// (or stays phantom — only the total length travels).
fn gather_run<E: Elem>(y: &DataBuf<E>, pieces: &[(usize, usize)]) -> Result<DataBuf<E>> {
    if pieces.len() == 1 {
        let (lo, hi) = pieces[0];
        return y.block(lo, hi);
    }
    let n: usize = pieces.iter().map(|&(lo, hi)| hi - lo).sum();
    if y.is_phantom() {
        return Ok(DataBuf::phantom(n));
    }
    let mut out = DataBuf::real_zeroed(n);
    let mut off = 0;
    for &(lo, hi) in pieces {
        out.write_at(off, &y.block(lo, hi)?)?;
        off += hi - lo;
    }
    Ok(out)
}

/// Non-pipelined circulant-graph allreduce (reduce-scatter + allgather).
pub fn allreduce_nonpipelined<E: Elem, O: ReduceOp<E>>(
    comm: &mut impl Comm<E>,
    x: DataBuf<E>,
    op: &O,
) -> Result<DataBuf<E>> {
    let p = comm.size();
    let mut y = x;
    if p == 1 || y.is_empty() {
        return Ok(y);
    }
    let rank = comm.rank();
    let q = log2_ceil(p);
    let segs = Blocks::segments(y.len(), p);

    // --- reduce-scatter: reversed dissemination, rounds q−1 … 0. After
    // round k, rank r's segments {r … r+2^k−1} each hold the partial over
    // the 2·min(2^k, …) ranks the forward step would have gathered from;
    // after round 0, segment r is the full reduction. -----------------------
    for k in (0..q).rev() {
        let skip = 1usize << k;
        let s_k = skip.min(p - skip);
        let send_to = (rank + skip) % p;
        let recv_from = (rank + p - skip) % p;
        let send = gather_run(&y, &run_pieces(&segs, p, rank + skip, s_k))?;
        let got = comm.sendrecv_pair(send_to, send, recv_from)?;
        // incoming covers circulant predecessors of this rank: left operand
        let mut off = 0;
        for (lo, hi) in run_pieces(&segs, p, rank, s_k) {
            let piece = got.block(off, off + (hi - lo))?;
            off += hi - lo;
            comm.charge_compute(piece.bytes());
            y.reduce_at(lo, &piece, op, Side::Left)?;
        }
    }

    // --- allgather: Bruck dissemination, rounds 0 … q−1. Before round k,
    // rank r owns finished segments {r … r+2^k−1}; it ships the first s_k
    // of them backwards by 2^k and receives the run ahead of its own. ------
    for k in 0..q {
        let skip = 1usize << k;
        let s_k = skip.min(p - skip);
        let send_to = (rank + p - skip) % p;
        let recv_from = (rank + skip) % p;
        let send = gather_run(&y, &run_pieces(&segs, p, rank, s_k))?;
        let got = comm.sendrecv_pair(send_to, send, recv_from)?;
        let mut off = 0;
        for (lo, hi) in run_pieces(&segs, p, rank + skip, s_k) {
            let piece = got.block(off, off + (hi - lo))?;
            off += hi - lo;
            y.write_at(lo, &piece)?;
        }
    }
    Ok(y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{run_allreduce_i32, RunSpec};
    use crate::comm::Timing;
    use crate::model::AlgoKind;

    #[test]
    fn correct_various_p() {
        // non-powers-of-two exercise the wrapped (two-piece) runs
        for p in [1usize, 2, 3, 4, 5, 6, 7, 8, 11, 12, 16, 17] {
            let spec = RunSpec::new(p, 37); // m not divisible by p
            let expected = spec.expected_sum_i32();
            let report = run_allreduce_i32(AlgoKind::NonPipelined, &spec, Timing::Real).unwrap();
            for (r, buf) in report.results.into_iter().enumerate() {
                assert_eq!(buf.as_slice().unwrap(), &expected[..], "p={p} rank={r}");
            }
        }
    }

    #[test]
    fn m_smaller_than_p() {
        // some segments are empty; wrapped runs may drop pieces entirely
        for (p, m) in [(9usize, 4usize), (13, 5), (6, 1)] {
            let spec = RunSpec::new(p, m);
            let expected = spec.expected_sum_i32();
            let report = run_allreduce_i32(AlgoKind::NonPipelined, &spec, Timing::Real).unwrap();
            for buf in report.results {
                assert_eq!(buf.as_slice().unwrap(), &expected[..], "p={p} m={m}");
            }
        }
    }

    #[test]
    fn virtual_cost_latency_bound() {
        use crate::model::{ComputeCost, CostModel, LinkCost};
        // β = 0: T = 2⌈log₂ p⌉·α exactly — p = 10 → 8 rounds
        let timing = Timing::Virtual(
            CostModel::Uniform(LinkCost::new(1e-6, 0.0)),
            ComputeCost::new(0.0),
        );
        let spec = RunSpec::new(10, 100).phantom(true);
        let t = run_allreduce_i32(AlgoKind::NonPipelined, &spec, timing)
            .unwrap()
            .max_vtime_us;
        assert!((t - 8.0).abs() < 1e-6, "t={t}");
    }

    #[test]
    fn run_pieces_wraps_to_two() {
        let segs = Blocks::segments(12, 4); // 4 segments of 3
        assert_eq!(run_pieces(&segs, 4, 1, 2), vec![(3, 9)]);
        assert_eq!(run_pieces(&segs, 4, 3, 2), vec![(9, 12), (0, 3)]);
        // start reduced mod p
        assert_eq!(run_pieces(&segs, 4, 5, 1), vec![(3, 6)]);
    }
}
