//! The reduction-to-all algorithms: the paper's contribution
//! ([`allreduce_dpdr`]) and every baseline of its evaluation, plus the
//! two-tree and scan extensions it cites.
//!
//! All algorithms are written against the [`Comm`] trait, so the same code
//! runs under real wall-clock timing and under the virtual-clock cluster
//! simulation, with real or phantom payloads.

pub mod dpdr;
pub mod hierarchical;
pub mod native_switch;
pub mod nonpipelined;
pub mod pipetree;
pub mod rabenseifner;
pub mod recursive_doubling;
pub mod reduce_bcast;
pub mod ring;
pub mod scan_dp;
pub mod twotree;

pub use dpdr::{allreduce_dpdr, allreduce_dpdr_single};
pub use hierarchical::allreduce_hier;
pub use native_switch::allreduce_native_switch;
pub use nonpipelined::allreduce_nonpipelined;
pub use pipetree::allreduce_pipetree;
pub use rabenseifner::allreduce_rabenseifner;
pub use recursive_doubling::allreduce_recursive_doubling;
pub use reduce_bcast::{allreduce_reduce_bcast, bcast_binomial, reduce_binomial};
pub use ring::allreduce_ring;
pub use scan_dp::scan_pipelined;
pub use twotree::allreduce_twotree;

use crate::buffer::DataBuf;
use crate::comm::{run_world, Comm, ThreadComm, Timing, WorldReport};
use crate::error::{Error, Result};
use crate::model::{tuner, AlgoKind, CostModel, NetParams};
use crate::ops::{Elem, ReduceBackend, ReduceOp, SumOp};
use crate::pipeline::{Blocks, SchedKind};
use crate::topo::Mapping;
use crate::util::XorShift64;

/// Dispatch a *flat* collective by [`AlgoKind`] on any communicator
/// (including a sub-communicator). `AlgoKind::Hier` needs a node layout
/// and a world endpoint — dispatch it through [`allreduce_on`].
/// `AlgoKind::Scan` runs the pipelined inclusive prefix scan — rank `r`
/// gets `x_0 ⊙ … ⊙ x_r`, not the reduction-to-all.
pub fn allreduce<E: Elem, O: ReduceOp<E>>(
    algo: AlgoKind,
    comm: &mut impl Comm<E>,
    x: DataBuf<E>,
    op: &O,
    blocks: &Blocks,
) -> Result<DataBuf<E>> {
    // label buffer-layer copies with the collective that caused them
    let _site = crate::buffer::pool::cow_site(algo.name());
    match algo {
        AlgoKind::Dpdr => allreduce_dpdr(comm, x, op, blocks),
        AlgoKind::DpdrSingle => allreduce_dpdr_single(comm, x, op, blocks),
        AlgoKind::PipeTree => allreduce_pipetree(comm, x, op, blocks),
        AlgoKind::ReduceBcast => allreduce_reduce_bcast(comm, x, op),
        AlgoKind::NativeSwitch => allreduce_native_switch(comm, x, op),
        AlgoKind::TwoTree => allreduce_twotree(comm, x, op, blocks),
        AlgoKind::Ring => allreduce_ring(comm, x, op),
        AlgoKind::RecursiveDoubling => allreduce_recursive_doubling(comm, x, op),
        AlgoKind::Rabenseifner => allreduce_rabenseifner(comm, x, op),
        AlgoKind::Scan => scan_pipelined(comm, x, op, blocks),
        AlgoKind::NonPipelined => allreduce_nonpipelined(comm, x, op),
        AlgoKind::Hier => Err(Error::Config(
            "hier is node-aware: dispatch it with allreduce_on(algo, comm, …, mapping)".into(),
        )),
        AlgoKind::Auto => Err(Error::Config(
            "auto resolves against a run's timing: dispatch it through allreduce_on".into(),
        )),
    }
}

/// The cost model `AlgoKind::Auto` resolves against: the virtual clock's
/// own model, or the hydra reference machine when running on wall time
/// (there the pick is a heuristic, not a simulation-faithful choice).
fn resolution_model(timing: Timing) -> CostModel {
    match timing {
        Timing::Virtual(model, _) => model,
        Timing::Real => CostModel::hydra_uniform(),
    }
}

/// Dispatch an allreduce by [`AlgoKind`] on a world endpoint, including
/// the node-aware [`AlgoKind::Hier`] (which splits the world by `mapping`;
/// all other algorithms ignore it).
pub fn allreduce_on<E: Elem, O: ReduceOp<E>>(
    algo: AlgoKind,
    comm: &mut ThreadComm<E>,
    x: DataBuf<E>,
    op: &O,
    blocks: &Blocks,
    mapping: Mapping,
) -> Result<DataBuf<E>> {
    let algo = if algo == AlgoKind::Auto {
        // resolve against the run's own timing — SPMD-deterministic: every
        // rank sees the same (p, bytes, model) and picks the same algorithm
        let model = resolution_model(comm.timing());
        let pick = tuner::auto_pick(comm.size(), x.len() * E::BYTES, &model);
        comm.metrics_mut().auto_picks += 1;
        pick
    } else {
        algo
    };
    if algo == AlgoKind::Hier {
        let _site = crate::buffer::pool::cow_site(algo.name());
        return allreduce_hier(comm, x, op, blocks, mapping);
    }
    allreduce(algo, comm, x, op, blocks)
}

/// Parameters of one collective run.
#[derive(Clone, Copy, Debug)]
pub struct RunSpec {
    /// Number of ranks.
    pub p: usize,
    /// Elements per rank vector.
    pub m: usize,
    /// Pipeline block size in elements (the paper's b = 16000 default).
    pub block_elems: usize,
    /// Use phantom (size-only) payloads — for large-scale simulation.
    pub phantom: bool,
    /// Seed for deterministic input generation (real payloads).
    pub seed: u64,
    /// Rank → node layout, used by the node-aware `AlgoKind::Hier` (other
    /// algorithms ignore it). Defaults to the paper's 8 ranks per node.
    pub mapping: Mapping,
    /// Which kernel executes the block-wise ⊙ on every rank (scalar /
    /// SIMD / PJRT; see [`crate::ops::backend`]). All backends are bitwise
    /// identical, so this is a pure performance knob.
    pub reduce_backend: ReduceBackend,
    /// Shared network resources for virtual timing (NIC ports per node,
    /// per-level edge capacities). Non-dedicated values upgrade the
    /// run's cost model to [`CostModel::Congested`](crate::model) over
    /// `mapping` (overriding the model's own net params); the default
    /// dedicated value leaves the timing exactly as given. Ignored under
    /// real timing.
    pub net: NetParams,
    /// Block-count schedule for pipelined algorithms: the fixed
    /// `block_elems` partition (default), the Pipelining-Lemma optimum, or
    /// the greedy discrete optimum — see [`RunSpec::blocks_for`].
    pub sched: SchedKind,
}

impl RunSpec {
    pub fn new(p: usize, m: usize) -> RunSpec {
        RunSpec {
            p,
            m,
            block_elems: crate::pipeline::PAPER_BLOCK_ELEMS,
            phantom: false,
            seed: 0xD7D2,
            mapping: Mapping::Block { ranks_per_node: 8 },
            reduce_backend: ReduceBackend::Auto,
            net: NetParams::dedicated(),
            sched: SchedKind::Fixed,
        }
    }

    pub fn sched(mut self, sched: SchedKind) -> RunSpec {
        self.sched = sched;
        self
    }

    pub fn mapping(mut self, mapping: Mapping) -> RunSpec {
        self.mapping = mapping;
        self
    }

    pub fn net(mut self, net: NetParams) -> RunSpec {
        self.net = net;
        self
    }

    /// The effective timing of a run under this spec: `timing` upgraded
    /// to the congestion-aware model when the spec carries non-dedicated
    /// [`NetParams`] (the spec's `mapping` supplies the node layout if
    /// the model has none).
    pub fn effective_timing(&self, timing: Timing) -> Timing {
        timing.with_net(self.net, self.mapping)
    }

    pub fn reduce_backend(mut self, backend: ReduceBackend) -> RunSpec {
        self.reduce_backend = backend;
        self
    }

    pub fn block_elems(mut self, block_elems: usize) -> RunSpec {
        self.block_elems = block_elems;
        self
    }

    pub fn phantom(mut self, phantom: bool) -> RunSpec {
        self.phantom = phantom;
        self
    }

    pub fn seed(mut self, seed: u64) -> RunSpec {
        self.seed = seed;
        self
    }

    /// The block partition this spec induces.
    pub fn blocks(&self) -> Result<Blocks> {
        Blocks::by_size(self.m, self.block_elems)
    }

    /// The block partition for `algo` under this spec's schedule, priced
    /// against `timing` (pass the *effective* timing). `Fixed` is
    /// [`RunSpec::blocks`]; `Lemma`/`Greedy` apply `algo`'s step structure
    /// to the model's inter-node link (real timing prices against the
    /// hydra reference machine). `Auto` resolves to its concrete pick
    /// first; non-pipelined algorithms fall back to the fixed partition,
    /// which they ignore anyway. Element size is the harness's i32.
    pub fn blocks_for(&self, algo: AlgoKind, timing: Timing) -> Result<Blocks> {
        let model = resolution_model(timing);
        let algo = if algo == AlgoKind::Auto {
            tuner::auto_pick(self.p, self.m * 4, &model)
        } else {
            algo
        };
        let (_intra, inter) = model.link_levels();
        match (self.sched, algo.step_structure(self.p)) {
            (SchedKind::Lemma, Some((a, c))) => Ok(Blocks::lemma_optimal(self.m, 4, a, c, inter)),
            (SchedKind::Greedy, Some((a, c))) => {
                Ok(Blocks::greedy_optimal(self.m, 4, a, c, inter))
            }
            _ => self.blocks(),
        }
    }

    /// Deterministic input vector of rank `r` (real mode).
    pub fn input_i32(&self, rank: usize) -> Vec<i32> {
        XorShift64::new(self.seed ^ (rank as u64).wrapping_mul(0x9E37_79B9))
            .small_i32_vec(self.m)
    }

    /// The oracle: the element-wise sum over all rank inputs.
    pub fn expected_sum_i32(&self) -> Vec<i32> {
        let mut acc = vec![0i32; self.m];
        for r in 0..self.p {
            for (a, v) in acc.iter_mut().zip(self.input_i32(r)) {
                *a = a.wrapping_add(v);
            }
        }
        acc
    }

    /// All prefix-scan oracles in one O(p·m) pass: entry `r` is the
    /// element-wise sum over rank inputs `0 ..= r` (what
    /// [`AlgoKind::Scan`] leaves on rank `r`).
    pub fn expected_prefixes_i32(&self) -> Vec<Vec<i32>> {
        let mut acc = vec![0i32; self.m];
        let mut out = Vec::with_capacity(self.p);
        for r in 0..self.p {
            for (a, v) in acc.iter_mut().zip(self.input_i32(r)) {
                *a = a.wrapping_add(v);
            }
            out.push(acc.clone());
        }
        out
    }

    /// The prefix-scan oracle for one `rank`. Checking every rank? Use
    /// [`RunSpec::expected_prefixes_i32`] (this is O(p·m) per call).
    pub fn expected_prefix_i32(&self, rank: usize) -> Vec<i32> {
        if self.p == 0 {
            return vec![0i32; self.m];
        }
        self.expected_prefixes_i32()
            .swap_remove(rank.min(self.p - 1))
    }

    /// The per-rank oracles of `algo`, one O(p·m) pass for the whole
    /// world: the rank prefixes for the scan, the shared allreduce sum
    /// for every reduction-to-all kind.
    pub fn expected_i32_per_rank(&self, algo: AlgoKind) -> Vec<Vec<i32>> {
        let mut prefixes = self.expected_prefixes_i32();
        if algo != AlgoKind::Scan {
            let sum = prefixes.pop().unwrap_or_default();
            prefixes = vec![sum; self.p];
        }
        prefixes
    }

    /// The per-rank oracle for any [`AlgoKind`]: the allreduce sum for
    /// the reduction-to-all algorithms, the rank prefix for the scan.
    pub fn expected_i32(&self, algo: AlgoKind, rank: usize) -> Vec<i32> {
        if algo == AlgoKind::Scan {
            self.expected_prefix_i32(rank)
        } else {
            self.expected_sum_i32()
        }
    }
}

/// Run an i32 `MPI_SUM` allreduce world (the paper's Table 2 setting) and
/// return per-rank results plus timing.
pub fn run_allreduce_i32(
    algo: AlgoKind,
    spec: &RunSpec,
    timing: Timing,
) -> Result<WorldReport<DataBuf<i32>>> {
    let spec = *spec;
    let timing = spec.effective_timing(timing);
    let blocks = spec.blocks_for(algo, timing)?;
    run_world::<i32, _, _>(spec.p, timing, move |comm: &mut ThreadComm<i32>| {
        // every rank dispatches its block reductions through the spec's
        // backend (scoped: the rank thread returns to `Auto` afterwards)
        let _backend = crate::ops::backend::scope(spec.reduce_backend);
        let x = if spec.phantom {
            DataBuf::phantom(spec.m)
        } else {
            DataBuf::real(spec.input_i32(comm.rank()))
        };
        allreduce_on(algo, comm, x, &SumOp, &blocks, spec.mapping)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runspec_oracle_is_rank_count_sensitive() {
        let s2 = RunSpec::new(2, 8);
        let s3 = RunSpec::new(3, 8);
        assert_ne!(s2.expected_sum_i32(), s3.expected_sum_i32());
        assert_eq!(s2.input_i32(0), s2.input_i32(0)); // deterministic
        assert_ne!(s2.input_i32(0), s2.input_i32(1)); // distinct per rank
    }

    #[test]
    fn scan_dispatches_with_prefix_oracle() {
        let spec = RunSpec::new(5, 12).block_elems(4);
        let report = run_allreduce_i32(AlgoKind::Scan, &spec, Timing::Real).unwrap();
        for (rank, buf) in report.results.into_iter().enumerate() {
            assert_eq!(
                buf.into_vec().unwrap(),
                spec.expected_prefix_i32(rank),
                "rank {rank}"
            );
        }
        // the last rank's prefix is the full reduction
        assert_eq!(spec.expected_prefix_i32(4), spec.expected_sum_i32());
        // the algo-aware oracle branches per kind
        assert_eq!(
            spec.expected_i32(AlgoKind::Scan, 2),
            spec.expected_prefix_i32(2)
        );
        assert_eq!(spec.expected_i32(AlgoKind::Dpdr, 2), spec.expected_sum_i32());
    }
}
