//! Node-aware hierarchical reduction-to-all (`AlgoKind::Hier`): exploit a
//! clustered machine's two-level structure (cheap intra-node links,
//! expensive inter-node links) instead of treating the world as flat —
//! the §3 open question of the paper, answered in the style of Bienz,
//! Olson & Gropp (*Node-Aware Improvements to Allreduce*) and Kolmakov &
//! Zhang (*A Generalization of the Allreduce Operation*).
//!
//! Two shapes, chosen per node layout:
//!
//! * **Segment-parallel** (all node groups the same power-of-two size `k`):
//!   intra-node *reduce-scatter* by recursive halving leaves each rank
//!   owning `1/k` of the node's partial sum; each of the `k` cross-node
//!   groups (the `i`-th rank of every node) then runs the paper's
//!   doubly-pipelined dual-root allreduce on its segment **concurrently**;
//!   an intra-node *allgather* reassembles the vector. Inter-node β-cost
//!   per rank drops from `3βm` to `3βm/k` — the node-aware win — while the
//!   intra phases add only `≈ 2·β_intra·m`. Under a congestion-aware cost
//!   model with fewer NIC ports than segments, the concurrent launch is
//!   throttled into waves of `NetParams::ports_per_node` segment groups
//!   (see the phase-2 comment in `hier_segment_parallel`).
//! * **Leader-based** (ragged or non-power-of-two groups): intra-node
//!   binomial reduce to the node leader, dpdr among the leaders, intra-node
//!   binomial broadcast. Robust for any `p` / layout, including `p` not
//!   divisible by the node size and single-rank nodes.
//!
//! Both shapes combine node contributions in node order with rank order
//! inside each node, so under a `Block` mapping (contiguous ranks per
//! node) the reduction order is exactly rank order; for non-contiguous
//! mappings (round robin) the operator must be commutative, as with
//! `AlgoKind::Ring` — `AlgoKind::Hier::order_preserving()` is
//! conservatively `false`. For commutative operators the result is
//! bitwise identical to flat [`allreduce_dpdr`] on any layout.
//!
//! The collectives run on borrowed sub-communicators ([`ThreadComm::sub`])
//! over [`Group`]s derived deterministically from `(p, mapping)` — no
//! communication is needed to agree on the hierarchy.

use crate::buffer::DataBuf;
use crate::comm::{Comm, Group, ThreadComm, Timing};
use crate::error::Result;
use crate::ops::{Elem, ReduceOp, Side};
use crate::pipeline::Blocks;
use crate::topo::Mapping;

use super::dpdr::allreduce_dpdr;
use super::reduce_bcast::{bcast_binomial, reduce_binomial};

/// Element range `[lo, hi)` covered by segment indices `[slo, shi)`.
fn elem_range(segs: &Blocks, slo: usize, shi: usize) -> (usize, usize) {
    debug_assert!(slo < shi);
    (segs.range(slo).0, segs.range(shi - 1).1)
}

/// Node-aware hierarchical allreduce over the node layout of `mapping`.
///
/// `blocks` is the global pipeline partition; the segment-parallel shape
/// re-blocks each `m/k` segment at the same block *count* for its
/// cross-node dpdr. Requires associativity of `op` plus commutativity when node
/// groups are not contiguous rank ranges (see module docs).
pub fn allreduce_hier<E: Elem, O: ReduceOp<E>>(
    comm: &mut ThreadComm<E>,
    x: DataBuf<E>,
    op: &O,
    blocks: &Blocks,
    mapping: Mapping,
) -> Result<DataBuf<E>> {
    let p = comm.size();
    if p == 1 || x.is_empty() {
        return Ok(x);
    }
    let node_groups = Group::by_node(p, mapping);
    if node_groups.len() == 1 {
        // one node: the hierarchy degenerates to the flat algorithm
        return allreduce_dpdr(comm, x, op, blocks);
    }
    let me = comm.rank();
    let gi = node_groups
        .iter()
        .position(|g| g.contains(me))
        .expect("node groups partition the world");
    let k = node_groups[gi].size();
    let uniform = node_groups.iter().all(|g| g.size() == k);
    if uniform && k > 1 && k.is_power_of_two() {
        hier_segment_parallel(comm, x, op, blocks, &node_groups, gi)
    } else {
        hier_leader(comm, x, op, blocks, &node_groups, gi)
    }
}

/// Leader shape: intra-node reduce → dpdr among node leaders → intra-node
/// bcast. Handles every layout (ragged tail nodes, k = 1, k not a power
/// of two); its inter-node traffic is the full vector, so it wins on
/// latency (the leader world is `n ≪ p` ranks) rather than bandwidth.
fn hier_leader<E: Elem, O: ReduceOp<E>>(
    comm: &mut ThreadComm<E>,
    x: DataBuf<E>,
    op: &O,
    blocks: &Blocks,
    node_groups: &[Group],
    gi: usize,
) -> Result<DataBuf<E>> {
    let group = &node_groups[gi];
    let me = comm.rank();
    let mut y = x;
    {
        // binomial reduce onto local rank 0 keeps rank order exactly
        let mut sub = comm.sub(group)?;
        reduce_binomial(&mut sub, &mut y, op, 0)?;
    }
    if me == group.members()[0] {
        let leaders = Group::leaders(node_groups)?;
        let mut sub = comm.sub(&leaders)?;
        y = allreduce_dpdr(&mut sub, y, op, blocks)?;
    }
    {
        let mut sub = comm.sub(group)?;
        bcast_binomial(&mut sub, &mut y, 0)?;
    }
    Ok(y)
}

/// One segment group's cross-node dpdr: the `e`-th rank of every node
/// reduces the owned element range `[mlo, mhi)` with its peers. Factored
/// out of [`hier_segment_parallel`] so the congestion-aware wave throttle
/// can launch it per wave.
#[allow(clippy::too_many_arguments)]
fn cross_dpdr<E: Elem, O: ReduceOp<E>>(
    comm: &mut ThreadComm<E>,
    y: &mut DataBuf<E>,
    op: &O,
    blocks: &Blocks,
    node_groups: &[Group],
    e: usize,
    mlo: usize,
    mhi: usize,
) -> Result<()> {
    // the i-th rank of every node, in node order
    let cross = Group::new(
        node_groups
            .iter()
            .map(|g| g.members()[e])
            .collect::<Vec<_>>(),
    )?;
    let mut sub = comm.sub(&cross)?;
    // owned snapshot, not a view: dpdr reduces into the segment it is
    // handed, and a view would force a whole-vector copy-on-write
    let _site = crate::buffer::pool::cow_site("hier/cross-dpdr");
    let seg = y.extract_owned(mlo, mhi)?;
    // keep the global pipeline *depth* (block count), not block size:
    // the segment is m/k elements, so same-size blocks would collapse
    // the cross-node pipeline to b/k stages and squander the overlap
    // the α-term is paid for
    let seg_blocks = Blocks::by_count(mhi - mlo, blocks.count());
    let out = allreduce_dpdr(&mut sub, seg, op, &seg_blocks)?;
    y.write_at(mlo, &out)?;
    Ok(())
}

/// Segment-parallel shape for uniform power-of-two node groups: halving
/// reduce-scatter inside the node, dpdr across nodes per owned segment
/// (all `k` segment groups concurrently over disjoint links), doubling
/// allgather inside the node. The halving pairs by the *lowest* bit first
/// (as in [`super::rabenseifner`]), which keeps every accumulated interval
/// aligned and contiguous and the local reduction order exact.
fn hier_segment_parallel<E: Elem, O: ReduceOp<E>>(
    comm: &mut ThreadComm<E>,
    x: DataBuf<E>,
    op: &O,
    blocks: &Blocks,
    node_groups: &[Group],
    gi: usize,
) -> Result<DataBuf<E>> {
    let group = &node_groups[gi];
    let me = comm.rank();
    let e = group.local_rank(me).expect("gi is this rank's node group");
    let k = group.size();
    let mut y = x;
    let segs = Blocks::segments(y.len(), k);

    // --- phase 1: intra-node reduce-scatter (recursive halving) ----------
    let (mut slo, mut shi) = (0usize, k);
    let mut levels: Vec<(usize, usize, usize)> = Vec::new(); // (bit, parent_lo, parent_hi)
    {
        let mut sub = comm.sub(group)?;
        let mut bit = 1usize;
        while bit < k {
            let partner_e = e ^ bit;
            levels.push((bit, slo, shi));
            let smid = slo + (shi - slo) / 2;
            let (keep, give) = if e & bit == 0 {
                ((slo, smid), (smid, shi))
            } else {
                ((smid, shi), (slo, smid))
            };
            let (glo, ghi) = elem_range(&segs, give.0, give.1);
            let send = y.extract(glo, ghi)?;
            let got = sub.sendrecv(partner_e, send)?;
            let (klo, _khi) = elem_range(&segs, keep.0, keep.1);
            let side = if partner_e < e { Side::Left } else { Side::Right };
            sub.charge_compute(got.bytes());
            y.reduce_at(klo, &got, op, side)?;
            (slo, shi) = keep;
            bit <<= 1;
        }
    }
    debug_assert_eq!(shi - slo, 1); // this rank owns one segment

    // --- phase 2: dpdr across nodes on the owned segment ------------------
    //
    // All k segment groups are *logically* concurrent, but each node's
    // inter-node transfers share its NIC: under a congestion-aware cost
    // model with `ports_per_node < k` the segment-parallel launch is
    // throttled into waves of at most `ports_per_node` concurrent
    // segment-dpdrs per node (ROADMAP: "congestion-aware hier"). Waves
    // are separated by intra-node barriers, so a node never *initiates*
    // more concurrent inter-node streams than it has ports — trading a
    // little latency (one barrier per wave) for bounded NIC pressure.
    // With unlimited ports (or real timing) the throttle disengages and
    // the phase is exactly the previous fully-concurrent launch.
    let (mlo, mhi) = elem_range(&segs, slo, shi);
    let ports = match comm.timing() {
        Timing::Virtual(model, _) => model.net_params().ports_per_node,
        Timing::Real => 0,
    };
    let waves = if ports > 0 && ports < k {
        k.div_ceil(ports)
    } else {
        1
    };
    if waves == 1 {
        cross_dpdr(comm, &mut y, op, blocks, node_groups, e, mlo, mhi)?;
    } else {
        let my_wave = e / ports;
        for w in 0..waves {
            if w == my_wave {
                cross_dpdr(comm, &mut y, op, blocks, node_groups, e, mlo, mhi)?;
            }
            if w + 1 < waves {
                comm.sub(group)?.barrier()?;
            }
        }
    }

    // --- phase 3: intra-node allgather (replay the halving in reverse) ---
    {
        let mut sub = comm.sub(group)?;
        while let Some((bit, plo, phi)) = levels.pop() {
            let partner_e = e ^ bit;
            let (xlo, xhi) = elem_range(&segs, slo, shi);
            let send = y.extract(xlo, xhi)?;
            let got = sub.sendrecv(partner_e, send)?;
            // the partner owns the other half of the parent range
            let pmid = plo + (phi - plo) / 2;
            let (sib_lo, sib_hi) = if slo == plo { (pmid, phi) } else { (plo, pmid) };
            let (wlo, _whi) = elem_range(&segs, sib_lo, sib_hi);
            y.write_at(wlo, &got)?;
            (slo, shi) = (plo, phi);
        }
    }
    Ok(y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{run_allreduce_i32, RunSpec};
    use crate::comm::{run_world, Timing};
    use crate::model::{AlgoKind, ComputeCost, CostModel, LinkCost};
    use crate::ops::{SeqCheckOp, Span};

    fn check_against_flat(p: usize, m: usize, block: usize, mapping: Mapping) {
        let spec = RunSpec::new(p, m).block_elems(block).mapping(mapping);
        let expected = spec.expected_sum_i32();
        let flat = run_allreduce_i32(AlgoKind::Dpdr, &spec, Timing::Real).unwrap();
        let hier = run_allreduce_i32(AlgoKind::Hier, &spec, Timing::Real).unwrap();
        for (rank, (h, f)) in hier.results.into_iter().zip(flat.results).enumerate() {
            let h = h.into_vec().unwrap();
            assert_eq!(h, f.into_vec().unwrap(), "hier != dpdr at rank {rank}");
            assert_eq!(h, expected, "hier != oracle at rank {rank} ({p},{m},{block})");
        }
    }

    #[test]
    fn segment_parallel_path_matches_flat() {
        // uniform power-of-two nodes: 3 nodes × 4, 2 × 8, 4 × 2
        check_against_flat(12, 57, 10, Mapping::Block { ranks_per_node: 4 });
        check_against_flat(16, 64, 16, Mapping::Block { ranks_per_node: 8 });
        check_against_flat(8, 9, 3, Mapping::Block { ranks_per_node: 2 });
    }

    #[test]
    fn leader_path_matches_flat() {
        // ragged tail (10 = 4+4+2), non-power-of-two nodes (9 = 3+3+3),
        // single-rank nodes (k = 1)
        check_against_flat(10, 33, 8, Mapping::Block { ranks_per_node: 4 });
        check_against_flat(9, 40, 7, Mapping::Block { ranks_per_node: 3 });
        check_against_flat(5, 21, 4, Mapping::Block { ranks_per_node: 1 });
    }

    #[test]
    fn single_node_world_degenerates_to_flat() {
        check_against_flat(6, 30, 5, Mapping::Block { ranks_per_node: 8 });
    }

    #[test]
    fn round_robin_layout_correct_for_commutative_ops() {
        check_against_flat(12, 45, 9, Mapping::RoundRobin { nodes: 3 });
        check_against_flat(7, 20, 6, Mapping::RoundRobin { nodes: 4 });
    }

    #[test]
    fn tiny_vectors_empty_segments() {
        // m < k: some cross-node groups run on empty segments
        check_against_flat(8, 3, 2, Mapping::Block { ranks_per_node: 4 });
        check_against_flat(16, 1, 1, Mapping::Block { ranks_per_node: 4 });
    }

    #[test]
    fn zero_elements_is_noop() {
        let spec = RunSpec::new(6, 0).mapping(Mapping::Block { ranks_per_node: 2 });
        let report = run_allreduce_i32(AlgoKind::Hier, &spec, Timing::Real).unwrap();
        for buf in report.results {
            assert_eq!(buf.len(), 0);
        }
    }

    #[test]
    fn order_witness_block_mapping() {
        // contiguous node groups: both shapes must visit ranks in exactly
        // ascending order (SeqCheckOp poisons any other combination)
        for (p, k) in [(8usize, 2usize), (12, 4), (10, 4), (9, 3), (6, 6)] {
            let mapping = Mapping::Block { ranks_per_node: k };
            let blocks = Blocks::by_count(12, 3);
            let report = run_world::<Span, _, _>(p, Timing::Real, move |comm| {
                let x = DataBuf::real(vec![Span::rank(comm.rank() as u32); 12]);
                allreduce_hier(comm, x, &SeqCheckOp, &blocks, mapping)
            })
            .unwrap();
            for buf in report.results {
                for s in buf.as_slice().unwrap() {
                    assert_eq!(*s, Span::of(0, p as u32 - 1), "p={p} k={k}");
                }
            }
        }
    }

    #[test]
    fn phantom_real_vtime_identical() {
        let spec = RunSpec::new(12, 500)
            .block_elems(64)
            .mapping(Mapping::Block { ranks_per_node: 4 });
        let t = |ph: bool| {
            run_allreduce_i32(AlgoKind::Hier, &spec.phantom(ph), Timing::hydra())
                .unwrap()
                .max_vtime_us
        };
        assert_eq!(t(false).to_bits(), t(true).to_bits());
    }

    #[test]
    fn port_capped_waves_stay_correct_and_never_accelerate() {
        use crate::model::NetParams;
        // uniform power-of-two nodes with ports < k: the segment-parallel
        // launch is throttled into waves. Payloads must stay bitwise
        // correct and the capped run can only be slower than dedicated.
        let mapping = Mapping::Block { ranks_per_node: 4 };
        let base = CostModel::Hierarchical {
            intra: LinkCost::new(0.3e-6, 0.08e-9),
            inter: LinkCost::new(1.0e-6, 0.70e-9),
            mapping,
        };
        let dedicated = Timing::Virtual(base, ComputeCost::new(0.25e-9));
        let spec = RunSpec::new(8, 96).block_elems(8).mapping(mapping);
        let expected = spec.expected_sum_i32();
        let free = run_allreduce_i32(AlgoKind::Hier, &spec, dedicated).unwrap();
        for ports in [1usize, 2] {
            let net = NetParams::ports(ports);
            let capped = Timing::Virtual(
                base.with_net(net, mapping),
                ComputeCost::new(0.25e-9),
            );
            let report = run_allreduce_i32(AlgoKind::Hier, &spec, capped).unwrap();
            for (rank, buf) in report.results.into_iter().enumerate() {
                assert_eq!(
                    buf.into_vec().unwrap(),
                    expected,
                    "ports={ports} rank={rank}"
                );
            }
            assert!(
                report.max_vtime_us >= free.max_vtime_us - 1e-9,
                "ports={ports}: capped {} < dedicated {}",
                report.max_vtime_us,
                free.max_vtime_us
            );
        }
    }

    #[test]
    fn node_aware_beats_flat_under_two_level_costs() {
        // β_intra ≪ β_inter, segment-parallel shape: the inter-node β-term
        // drops by ~k, so hier must beat flat dpdr at bandwidth-bound m
        let mapping = Mapping::Block { ranks_per_node: 8 };
        let timing = Timing::Virtual(
            CostModel::Hierarchical {
                intra: LinkCost::new(0.3e-6, 0.08e-9),
                inter: LinkCost::new(1.0e-6, 0.70e-9),
                mapping,
            },
            ComputeCost::new(0.25e-9),
        );
        let spec = RunSpec::new(64, 400_000)
            .block_elems(16_000)
            .mapping(mapping)
            .phantom(true);
        let flat = run_allreduce_i32(AlgoKind::Dpdr, &spec, timing).unwrap().max_vtime_us;
        let hier = run_allreduce_i32(AlgoKind::Hier, &spec, timing).unwrap().max_vtime_us;
        assert!(
            hier < flat,
            "node-aware should win at large m: hier={hier} flat={flat}"
        );
    }
}
