//! Pipelined binary-tree prefix scan (inclusive `MPI_Scan`), after Sanders
//! & Träff [5] — the paper's Algorithm 1 "follows the same idea as" this
//! doubly-pipelined scan, so we ship it as the natural extension example.
//!
//! On the post-order tree, the subtree of node `i` covers the consecutive
//! ranks `[i′, i]`, so the inclusive prefix of rank `i` is
//! `prefix-excl(i′) ⊙ (subtree sum of i)`:
//!
//! * **up phase** (pipelined): node `i` computes, per block, the partial
//!   sums `t1 ⊙ t0 ⊙ x_i` of its subtree and streams them to its parent,
//!   retaining the second child's contribution `t1` per block;
//! * **down phase** (pipelined): node `i` receives `P = prefix-excl(i′)`
//!   from its parent (void/identity at the root), forwards `P` to the
//!   second child (same `i′`), forwards `P ⊙ t1` to the first child (whose
//!   range starts at `i″ + 1`), and finishes its own blocks as `P ⊙ U`
//!   where `U` is the up-phase subtree sum.

use crate::buffer::DataBuf;
use crate::comm::Comm;
use crate::error::Result;
use crate::ops::{Elem, ReduceOp, Side};
use crate::pipeline::Blocks;
use crate::topo::PostOrderTree;

/// Inclusive prefix scan: rank `r` ends with `x_0 ⊙ … ⊙ x_r`.
pub fn scan_pipelined<E: Elem, O: ReduceOp<E>>(
    comm: &mut impl Comm<E>,
    x: DataBuf<E>,
    op: &O,
    blocks: &Blocks,
) -> Result<DataBuf<E>> {
    let p = comm.size();
    let mut y = x; // becomes U (subtree sums), then the result
    if p == 1 || y.is_empty() {
        return Ok(y);
    }
    let tree = PostOrderTree::new(0, p - 1)?;
    let rank = comm.rank();
    let parent = tree.parent(rank);
    let [c0, c1] = tree.children(rank);
    let b = blocks.count();

    // ---- up phase: per block, U ← t1 ⊙ t0 ⊙ x; keep t1 ------------------
    let mut kept_t1: Vec<DataBuf<E>> = Vec::with_capacity(if c1.is_some() { b } else { 0 });
    for j in 0..b {
        if let Some(ch) = c0 {
            let t0 = comm.recv(ch)?;
            let (lo, _) = blocks.range(j);
            comm.charge_compute(t0.bytes());
            y.reduce_at(lo, &t0, op, Side::Left)?;
        }
        if let Some(ch) = c1 {
            let t1 = comm.recv(ch)?;
            let (lo, _) = blocks.range(j);
            comm.charge_compute(t1.bytes());
            y.reduce_at(lo, &t1, op, Side::Left)?;
            // Retain an owned copy, not the received view: kept blocks
            // live until the down phase, and holding a lease on the
            // child's slab that long would force the child into
            // copy-on-write when it finalizes the same block. The view
            // itself drops here, so the up-phase transfer stays zero-copy.
            let _site = crate::buffer::pool::cow_site("scan/kept-block");
            kept_t1.push(t1.snapshot());
        }
        if let Some(par) = parent {
            let (lo, hi) = blocks.range(j);
            comm.send(par, y.extract(lo, hi)?)?;
        }
    }

    // ---- down phase: receive prefix-excl, forward, finish ---------------
    for j in 0..b {
        let (lo, hi) = blocks.range(j);
        // P = prefix of everything before my subtree (None at the root)
        let prefix: Option<DataBuf<E>> = match parent {
            Some(par) => {
                let pfx = comm.recv(par)?;
                if pfx.is_empty() {
                    None // the root sent a void marker: nothing before us
                } else {
                    Some(pfx)
                }
            }
            None => None,
        };
        // second child's range starts where mine does: forward P as-is
        if let Some(ch) = c1 {
            match &prefix {
                Some(pfx) => comm.send(ch, pfx.clone())?,
                None => comm.send(ch, y.empty_like())?,
            }
        }
        // first child's range starts after the second child's: P ⊙ t1
        if let Some(ch) = c0 {
            let mut fwd = match &prefix {
                Some(pfx) => pfx.clone(),
                None => y.empty_like(),
            };
            if let Some(t1) = kept_t1.get(j) {
                if fwd.is_empty() {
                    fwd = t1.clone();
                } else {
                    comm.charge_compute(t1.bytes());
                    fwd.reduce_all(t1, op, Side::Right)?;
                }
            }
            comm.send(ch, fwd)?;
        }
        // my own result: P ⊙ U
        if let Some(pfx) = prefix {
            comm.charge_compute(pfx.bytes());
            y.reduce_at(lo, &pfx, op, Side::Left)?;
        }
        let _ = hi;
    }
    Ok(y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{run_world, Timing};
    use crate::ops::{SeqCheckOp, Span, SumOp};
    use crate::util::XorShift64;

    #[test]
    fn inclusive_scan_matches_oracle() {
        for p in 1..=14usize {
            let m = 13;
            let blocks = Blocks::by_count(m, 4);
            let inputs: Vec<Vec<i32>> = (0..p)
                .map(|r| XorShift64::new(77 + r as u64).small_i32_vec(m))
                .collect();
            let inputs_for_world = inputs.clone();
            let report = run_world::<i32, _, _>(p, Timing::Real, move |comm| {
                let x = DataBuf::real(inputs_for_world[comm.rank()].clone());
                scan_pipelined(comm, x, &SumOp, &blocks)
            })
            .unwrap();
            let mut acc = vec![0i32; m];
            for (r, buf) in report.results.into_iter().enumerate() {
                for (a, v) in acc.iter_mut().zip(&inputs[r]) {
                    *a = a.wrapping_add(*v);
                }
                assert_eq!(buf.as_slice().unwrap(), &acc[..], "p={p} rank={r}");
            }
        }
    }

    #[test]
    fn order_witness() {
        for p in [2usize, 5, 9, 16] {
            let m = 6;
            let blocks = Blocks::by_count(m, 2);
            let report = run_world::<Span, _, _>(p, Timing::Real, move |comm| {
                let x = DataBuf::real(vec![Span::rank(comm.rank() as u32); m]);
                scan_pipelined(comm, x, &SeqCheckOp, &blocks)
            })
            .unwrap();
            for (r, buf) in report.results.into_iter().enumerate() {
                for s in buf.as_slice().unwrap() {
                    assert_eq!(*s, Span::of(0, r as u32), "p={p} r={r}");
                }
            }
        }
    }
}
