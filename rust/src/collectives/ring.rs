//! Ring allreduce: reduce-scatter around the ring, then allgather — the
//! classic bandwidth-optimal-per-link, latency-heavy algorithm
//! (`2(p−1)α + 2·((p−1)/p)·βm`). Vendor libraries pick it (often with
//! segmentation) for mid-to-large messages; in our emulated "native"
//! `MPI_Allreduce` it is the *mid-range* branch, whose `2(p−1)α` latency at
//! p = 288 reproduces the pathological plateau the paper observed in
//! Open MPI 4.0.5 (§2: "excessively poor in a midrange of counts").
//!
//! The reduce-scatter accumulates each segment in ring order starting at
//! its owner's successor, i.e. as a *rotation* of rank order — fine for
//! commutative operators, which is why [`AlgoKind::order_preserving`]
//! (crate::model::AlgoKind) is false for the ring, mirroring MPI practice.

use crate::buffer::DataBuf;
use crate::comm::Comm;
use crate::error::Result;
use crate::ops::{Elem, ReduceOp, Side};
use crate::pipeline::Blocks;

/// Ring allreduce (reduce-scatter + allgather).
pub fn allreduce_ring<E: Elem, O: ReduceOp<E>>(
    comm: &mut impl Comm<E>,
    x: DataBuf<E>,
    op: &O,
) -> Result<DataBuf<E>> {
    let p = comm.size();
    let mut y = x;
    if p == 1 || y.is_empty() {
        return Ok(y);
    }
    let rank = comm.rank();
    let right = (rank + 1) % p;
    let left = (rank + p - 1) % p;
    let segs = Blocks::segments(y.len(), p);

    let seg_buf = |y: &DataBuf<E>, s: usize| -> Result<DataBuf<E>> {
        let (lo, hi) = segs.range(s);
        y.block(lo, hi)
    };

    // --- reduce-scatter: after step t, rank r holds the partial of segment
    // (r − t − 1) accumulated over ranks (r − t − 1 … r). ------------------
    for t in 0..p - 1 {
        let send_seg = (rank + p - t) % p;
        let recv_seg = (rank + p - t - 1) % p;
        let send = seg_buf(&y, send_seg)?;
        let got = comm.sendrecv_pair(right, send, left)?;
        let (lo, _hi) = segs.range(recv_seg);
        comm.charge_compute(got.bytes());
        // incoming covers the ring-predecessors of this rank: left operand
        y.reduce_at(lo, &got, op, Side::Left)?;
    }

    // --- allgather: circulate the finished segments ------------------------
    // rank r now owns finished segment (r + 1) mod p
    for t in 0..p - 1 {
        let send_seg = (rank + 1 + p - t) % p;
        let recv_seg = (rank + p - t) % p;
        let send = seg_buf(&y, send_seg)?;
        let got = comm.sendrecv_pair(right, send, left)?;
        let (lo, _hi) = segs.range(recv_seg);
        y.write_at(lo, &got)?;
    }
    Ok(y)
}

#[cfg(test)]
mod tests {
    use crate::collectives::{run_allreduce_i32, RunSpec};
    use crate::comm::Timing;
    use crate::model::AlgoKind;

    #[test]
    fn correct_various_p() {
        for p in [1usize, 2, 3, 4, 5, 7, 8, 12, 17] {
            let spec = RunSpec::new(p, 37); // m not divisible by p
            let expected = spec.expected_sum_i32();
            let report = run_allreduce_i32(AlgoKind::Ring, &spec, Timing::Real).unwrap();
            for (r, buf) in report.results.into_iter().enumerate() {
                assert_eq!(buf.as_slice().unwrap(), &expected[..], "p={p} rank={r}");
            }
        }
    }

    #[test]
    fn m_smaller_than_p() {
        // some segments are empty
        let spec = RunSpec::new(9, 4);
        let expected = spec.expected_sum_i32();
        let report = run_allreduce_i32(AlgoKind::Ring, &spec, Timing::Real).unwrap();
        for buf in report.results {
            assert_eq!(buf.as_slice().unwrap(), &expected[..]);
        }
    }

    #[test]
    fn virtual_cost_latency_bound() {
        use crate::model::{ComputeCost, CostModel, LinkCost};
        // β = 0: T = 2(p−1)·α exactly
        let timing = Timing::Virtual(
            CostModel::Uniform(LinkCost::new(1e-6, 0.0)),
            ComputeCost::new(0.0),
        );
        let spec = RunSpec::new(10, 100).phantom(true);
        let t = run_allreduce_i32(AlgoKind::Ring, &spec, timing)
            .unwrap()
            .max_vtime_us;
        assert!((t - 18.0).abs() < 1e-6, "t={t}");
    }
}
