//! Two-tree allreduce (Sanders, Speck, Träff [4]) — the full-bandwidth
//! `O(log p + √(m log p)) + 2βm` scheme the paper cites in §1.2 as the
//! best-known pipelined binary-tree algorithm. Our A5 ablation compares it
//! against the dual-root algorithm (`3βm`) and the single tree (`4βm`).
//!
//! Structure: two in-order binary trees T1/T2 over ranks `[0, p−2]` such
//! that (almost) no rank is interior in both ([`TwoTree`]); rank `p−1` is
//! the root *driver*. Even-indexed pipeline blocks travel through T1,
//! odd-indexed through T2.
//!
//! **Scheduling.** The original algorithm time-slots the two trees with an
//! explicit edge coloring. Our message-passing substrate is asynchronous,
//! so we need a schedule whose *blocking receives never form a cross-tree
//! cycle* (the two parent relations together are cyclic: X can be P's
//! T2-parent while P is X's T1-parent — naive lockstep supersteps deadlock
//! there; see the `interior_cycle_shape` regression test):
//!
//! * **Reduce** (per superstep `s`; a rank is interior in tree `Ti`, leaf
//!   in `Tl`):
//!   `op1: Send(raw Tl block s, Tl.parent) ‖ Recv(t, Ti.c0)`,
//!   `op2: Send(reduced Ti block s−1, Ti.parent) ‖ Recv(t, Ti.c1)`.
//!   Every send is posted before its op blocks, and blocking receives wait
//!   only on the rank's own interior-tree *children* — dependencies
//!   strictly descend one tree, grounding out at leaves.
//! * **Broadcast** (per *block*, eager): on receiving final block `g` from
//!   the `tree(g)` parent, a rank immediately forwards it
//!   (`Send(c1, g)`, then `Send(c0, g) ‖ Recv(block g+1)`), so a block's
//!   dependency chain lives entirely inside its own tree's ancestor path.
//!
//! The reduce phase runs at ~1 port-slot per block and the broadcast at
//! ~1.5 (the deadlock-free pairing gives up one overlap the coloring would
//! exploit), so the measured β-term is ≈ 2.5βm — between the paper's ideal
//! `2βm` and the dual-root `3βm`, which is exactly what A5 reports.

use crate::buffer::DataBuf;
use crate::comm::Comm;
use crate::error::Result;
use crate::ops::{Elem, ReduceOp, Side};
use crate::pipeline::Blocks;
use crate::topo::twotree::{Half, TwoTree};

/// Per-tree view of the block sequence: tree T1 carries global blocks
/// `0, 2, 4, …`, tree T2 carries `1, 3, 5, …`.
#[derive(Clone, Copy)]
struct TreeBlocks {
    offset: usize, // 0 for T1, 1 for T2
    count: usize,  // number of blocks this tree carries
}

impl TreeBlocks {
    fn new(half: Half, total: usize) -> TreeBlocks {
        match half {
            Half::T1 => TreeBlocks {
                offset: 0,
                count: (total + 1) / 2,
            },
            Half::T2 => TreeBlocks {
                offset: 1,
                count: total / 2,
            },
        }
    }

    /// Global block index of this tree's `s`-th block.
    fn global(&self, s: usize) -> usize {
        self.offset + 2 * s
    }
}

/// The tree a global block index travels through.
fn half_of(g: usize) -> Half {
    if g % 2 == 0 {
        Half::T1
    } else {
        Half::T2
    }
}

/// Extract the global block `g` of `y` (void if out of range).
fn block<E: Elem>(y: &DataBuf<E>, blocks: &Blocks, g: usize) -> Result<DataBuf<E>> {
    if g >= blocks.count() {
        return Ok(y.empty_like());
    }
    let (lo, hi) = blocks.range(g);
    y.block(lo, hi)
}

struct TreeCtx {
    parent: usize,
    children: [Option<usize>; 2],
    tb: TreeBlocks,
}

impl TreeCtx {
    fn new(tt: &TwoTree, half: Half, rank: usize, total_blocks: usize) -> TreeCtx {
        let role = tt.role(half, rank);
        TreeCtx {
            parent: role.parent,
            children: role.children,
            tb: TreeBlocks::new(half, total_blocks),
        }
    }

    fn is_leaf(&self) -> bool {
        self.children == [None, None]
    }
}

/// Reduce-phase pipeline of a rank that is interior in exactly one tree
/// (`ti`), leaf in the other.
///
/// Epoch `k` handles the interior tree's `k`-th block `g_k`:
///
/// ```text
/// op_a: Send(reduced g_{k−1}, ti.parent) ‖ Recv(t, ti.c0);  combine Left
/// op_b: Send(next raw leaf-tree block, tl.parent) ‖ Recv(t, ti.c1); Right
/// ```
///
/// Deadlock-freedom: a rank's blocking receives wait only for its
/// interior-tree children's contributions of block `g_k`; an interior
/// child posts that in *its* epoch `k+1` op_a (which only waits on the
/// same tree, one level deeper), and a leaf child posts its raw block as
/// an op_b rider — rides are always posted before their op blocks, and
/// the ridden raw block for tree-block `g` is posted during an epoch
/// handling a block `< g` of the *other* tree. Every dependency therefore
/// either descends one tree at equal block index or strictly decreases the
/// block index, grounding out at block 0 — no cross-tree cycle is possible
/// (lockstep superstep schedules deadlock here; see the p = 11 cycle in
/// the module history and the `deep_world_regression` test).
fn reduce_interior<E: Elem, O: ReduceOp<E>>(
    comm: &mut impl Comm<E>,
    y: &mut DataBuf<E>,
    op: &O,
    blocks: &Blocks,
    ti: &TreeCtx,
    tl: &TreeCtx,
) -> Result<()> {
    let ci = ti.tb.count;
    let cl = tl.tb.count;
    // Leaf-raw sends have no data dependency (they are the rank's own
    // input), so we give them a W-epoch head start. Without it, a leaf
    // parent's epoch-k receive waits on a raw posted at the *sender's*
    // epoch k — a zero-slack cross-tree dependency whose timestamp chains
    // cascade across O(p) ranks per epoch and inflate the virtual time to
    // Θ(p·βm). With W ≥ 2 every cross-tree hop points W epochs into the
    // past and chains cannot accumulate. Costs W small early sends.
    const W: usize = 32;
    let mut leaf_sent = 0usize; // leaf-tree blocks posted so far
    while leaf_sent < cl.min(W) {
        let g = tl.tb.global(leaf_sent);
        leaf_sent += 1;
        comm.send(tl.parent, block(y, blocks, g)?)?;
    }
    for k in 0..=ci {
        let g_k = ti.tb.global(k.min(ci.saturating_sub(1)));
        let dn_active = k < ci;
        // op_a: parent send of the previous reduced block ‖ c0 recv
        let up = k >= 1;
        let c0 = ti.children[0].filter(|_| dn_active);
        match (up, c0) {
            (true, Some(c)) => {
                let send = block(y, blocks, ti.tb.global(k - 1))?;
                let t = comm.sendrecv_pair(ti.parent, send, c)?;
                let (lo, _) = blocks.range(g_k);
                comm.charge_compute(t.bytes());
                y.reduce_at(lo, &t, op, Side::Left)?;
            }
            (true, None) => comm.send(ti.parent, block(y, blocks, ti.tb.global(k - 1))?)?,
            (false, Some(c)) => {
                let t = comm.recv(c)?;
                let (lo, _) = blocks.range(g_k);
                comm.charge_compute(t.bytes());
                y.reduce_at(lo, &t, op, Side::Left)?;
            }
            (false, None) => {}
        }
        // op_b: next leaf-tree raw block rides along ‖ c1 recv
        let ride = if leaf_sent < cl && tl.tb.global(leaf_sent) <= g_k + 1 + 2 * W {
            let g = tl.tb.global(leaf_sent);
            leaf_sent += 1;
            Some(block(y, blocks, g)?)
        } else {
            None
        };
        let c1 = ti.children[1].filter(|_| dn_active);
        match (ride, c1) {
            (Some(raw), Some(c)) => {
                let t = comm.sendrecv_pair(tl.parent, raw, c)?;
                let (lo, _) = blocks.range(g_k);
                comm.charge_compute(t.bytes());
                y.reduce_at(lo, &t, op, Side::Right)?;
            }
            (Some(raw), None) => comm.send(tl.parent, raw)?,
            (None, Some(c)) => {
                let t = comm.recv(c)?;
                let (lo, _) = blocks.range(g_k);
                comm.charge_compute(t.bytes());
                y.reduce_at(lo, &t, op, Side::Right)?;
            }
            (None, None) => {}
        }
    }
    // flush leaf-tree raw blocks the epochs did not cover (small b)
    while leaf_sent < cl {
        let g = tl.tb.global(leaf_sent);
        leaf_sent += 1;
        comm.send(tl.parent, block(y, blocks, g)?)?;
    }
    Ok(())
}

/// Two-tree allreduce.
pub fn allreduce_twotree<E: Elem, O: ReduceOp<E>>(
    comm: &mut impl Comm<E>,
    x: DataBuf<E>,
    op: &O,
    blocks: &Blocks,
) -> Result<DataBuf<E>> {
    let p = comm.size();
    let mut y = x;
    if p == 1 || y.is_empty() {
        return Ok(y);
    }
    if p == 2 {
        // degenerate: a single exchange per block (both trees are rank 0);
        // owned snapshot because both ranks immediately reduce over the
        // range they just sent (see the dual-root exchange in dpdr)
        let _site = crate::buffer::pool::cow_site("twotree/p2-exchange");
        let t = comm.sendrecv(1 - comm.rank(), y.snapshot())?;
        let side = if comm.rank() == 0 { Side::Right } else { Side::Left };
        comm.charge_compute(t.bytes());
        y.reduce_all(&t, op, side)?;
        return Ok(y);
    }
    let tt = TwoTree::new(p)?;
    let rank = comm.rank();
    let b = blocks.count();
    if rank == tt.driver() {
        // ---- driver: drain both roots (reduce), then feed them (bcast) --
        for g in 0..b {
            let t = comm.recv(tt.root(half_of(g)))?;
            let (lo, _) = blocks.range(g);
            comm.charge_compute(t.bytes());
            // incoming covers ranks [0, p−2]; the driver is rank p−1
            y.reduce_at(lo, &t, op, Side::Left)?;
        }
        for g in 0..b {
            comm.send(tt.root(half_of(g)), block(&y, blocks, g)?)?;
        }
        return Ok(y);
    }

    let t1 = TreeCtx::new(&tt, Half::T1, rank, b);
    let t2 = TreeCtx::new(&tt, Half::T2, rank, b);

    // ---- reduce phase -----------------------------------------------------
    match (t1.is_leaf(), t2.is_leaf()) {
        (false, true) => reduce_interior(comm, &mut y, op, blocks, &t1, &t2)?,
        (true, false) => reduce_interior(comm, &mut y, op, blocks, &t2, &t1)?,
        (true, true) => {
            // leaf in both trees: raw posts only, never blocks
            for g in 0..b {
                let parent = match half_of(g) {
                    Half::T1 => t1.parent,
                    Half::T2 => t2.parent,
                };
                comm.send(parent, block(&y, blocks, g)?)?;
            }
        }
        (false, false) => unreachable!(
            "two-tree construction guarantees interior-disjointness"
        ),
    }

    // ---- broadcast phase (tree-decoupled streaming) -----------------------
    // A rank streams its *interior* tree: receive block k from the interior
    // parent, forward to the children — c0's copy rides the receive of
    // block k+1, c1's copy rides the receive of one of the rank's own
    // *leaf-tree* blocks. Blocking receives therefore only ever wait on a
    // parent (interior stream) or on a message whose producers are strictly
    // tree-ancestors (leaf stream): no dependency ever re-enters the
    // rank's own subtree, so there are no cycles AND no cross-tree rate
    // coupling — an earlier per-global-block serial loop was deadlock-free
    // but let each rank's interior forwarding be gated by its leaf-tree
    // receipts, throttling the whole stream to Θ(log p · βm).
    match (t1.is_leaf(), t2.is_leaf()) {
        (false, true) | (true, false) => {
            let (ti, tl) = if !t1.is_leaf() { (&t1, &t2) } else { (&t2, &t1) };
            let (ci, cl) = (ti.tb.count, tl.tb.count);
            let mut leaf_got = 0usize;
            if ci > 0 {
                let first = comm.recv(ti.parent)?;
                let (lo, _) = blocks.range(ti.tb.global(0));
                y.write_at(lo, &first)?;
            }
            for k in 0..ci {
                let g = ti.tb.global(k);
                // op1: forward to c0 ‖ receive the next interior block
                match (ti.children[0], k + 1 < ci) {
                    (Some(c), true) => {
                        let r = comm.sendrecv_pair(c, block(&y, blocks, g)?, ti.parent)?;
                        let (lo, _) = blocks.range(ti.tb.global(k + 1));
                        y.write_at(lo, &r)?;
                    }
                    (Some(c), false) => comm.send(c, block(&y, blocks, g)?)?,
                    (None, true) => {
                        let r = comm.recv(ti.parent)?;
                        let (lo, _) = blocks.range(ti.tb.global(k + 1));
                        y.write_at(lo, &r)?;
                    }
                    (None, false) => {}
                }
                // op2: forward to c1 ‖ receive one leaf-tree block.
                // The leaf stream is consumed LAG epochs behind the
                // interior stream: with zero lag, a chain of leaf-parent
                // dependencies can re-enter this rank's own subtree at the
                // same epoch and deadlock (observed at p = 17); every hop
                // of a lagged chain moves ≥ LAG epochs into the past, so
                // chains ground out in the prologue.
                const LAG: usize = 8;
                let leaf_due = leaf_got < cl && leaf_got + LAG <= k;
                match (ti.children[1], leaf_due) {
                    (Some(c), true) => {
                        let r = comm.sendrecv_pair(c, block(&y, blocks, g)?, tl.parent)?;
                        let (lo, _) = blocks.range(tl.tb.global(leaf_got));
                        y.write_at(lo, &r)?;
                        leaf_got += 1;
                    }
                    (Some(c), false) => comm.send(c, block(&y, blocks, g)?)?,
                    (None, true) => {
                        let r = comm.recv(tl.parent)?;
                        let (lo, _) = blocks.range(tl.tb.global(leaf_got));
                        y.write_at(lo, &r)?;
                        leaf_got += 1;
                    }
                    (None, false) => {}
                }
            }
            // drain leaf-tree blocks not covered by op2 rides
            while leaf_got < cl {
                let r = comm.recv(tl.parent)?;
                let (lo, _) = blocks.range(tl.tb.global(leaf_got));
                y.write_at(lo, &r)?;
                leaf_got += 1;
            }
        }
        (true, true) => {
            // leaf in both trees: pure sink; each parent's stream arrives
            // in its own order
            for t in [&t1, &t2] {
                for k in 0..t.tb.count {
                    let r = comm.recv(t.parent)?;
                    let (lo, _) = blocks.range(t.tb.global(k));
                    y.write_at(lo, &r)?;
                }
            }
        }
        (false, false) => unreachable!(),
    }
    Ok(y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{run_allreduce_i32, RunSpec};
    use crate::comm::{run_world, Timing};
    use crate::model::AlgoKind;
    use crate::ops::{SeqCheckOp, Span};

    fn check_sum(p: usize, m: usize, block_elems: usize) {
        let spec = RunSpec::new(p, m).block_elems(block_elems);
        let expected = spec.expected_sum_i32();
        let report = run_allreduce_i32(AlgoKind::TwoTree, &spec, Timing::Real).unwrap();
        for (rank, buf) in report.results.into_iter().enumerate() {
            assert_eq!(
                buf.as_slice().unwrap(),
                &expected[..],
                "p={p} m={m} blk={block_elems} rank={rank}"
            );
        }
    }

    #[test]
    fn correct_small_worlds() {
        for p in 1..=12 {
            check_sum(p, 24, 6);
        }
    }

    #[test]
    fn interior_cycle_shape() {
        // p = 5 contains the mutual-parent shape (a rank that is another's
        // T1-parent while being its T2-child); a lockstepped schedule
        // deadlocks here — regression guard for the eager schedule.
        check_sum(5, 40, 4);
        check_sum(5, 40, 40);
    }

    #[test]
    fn correct_larger_and_odd_blockings() {
        check_sum(17, 55, 7);
        check_sum(24, 100, 9);
        check_sum(31, 64, 64); // single block → all through T1
    }

    #[test]
    fn order_witness_noncommutative() {
        for p in [3usize, 4, 5, 9, 14, 21] {
            let m = 12;
            let blocks = Blocks::by_count(m, 4);
            let report = run_world::<Span, _, _>(p, Timing::Real, move |comm| {
                let x = DataBuf::real(vec![Span::rank(comm.rank() as u32); m]);
                allreduce_twotree(comm, x, &SeqCheckOp, &blocks)
            })
            .unwrap();
            for buf in report.results {
                for sp in buf.as_slice().unwrap() {
                    assert_eq!(*sp, Span::of(0, p as u32 - 1), "p={p}");
                }
            }
        }
    }

    #[test]
    fn beta_term_between_2m_and_3m() {
        use crate::model::{ComputeCost, CostModel, LinkCost};
        // α = 0, pure bandwidth: two-tree ≈ 2.5βm (see module docs), well
        // under pipetree's ≈ 4βm.
        let timing = Timing::Virtual(
            CostModel::Uniform(LinkCost::new(0.0, 1e-9)),
            ComputeCost::new(0.0),
        );
        let spec = RunSpec::new(33, 320_000).block_elems(1_000).phantom(true);
        let t_tt = run_allreduce_i32(AlgoKind::TwoTree, &spec, timing)
            .unwrap()
            .max_vtime_us;
        let t_pt = run_allreduce_i32(AlgoKind::PipeTree, &spec, timing)
            .unwrap()
            .max_vtime_us;
        let m_bytes = 320_000.0 * 4.0;
        let beta_m = m_bytes * 1e-9 * 1e6;
        assert!(
            t_tt < 3.0 * beta_m,
            "two-tree {t_tt} should be under 3βm = {}",
            3.0 * beta_m
        );
        assert!(t_tt < 0.8 * t_pt, "two-tree {t_tt} vs pipetree {t_pt}");
    }
}
