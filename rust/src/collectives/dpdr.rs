//! **Algorithm 1** of the paper: the doubly-pipelined, dual-root
//! reduction-to-all ("User-Allreduce2").
//!
//! Per processor `i` at depth `d_i` in its post-order binary tree, for
//! rounds `j = 0, 1, …, b + d_i`:
//!
//! ```text
//! Send(Y[j-(d_i+1)], child0) ‖ Recv(t, child0);   Y[j] ← t ⊙ Y[j]
//! Send(Y[j-(d_i+1)], child1) ‖ Recv(t, child1);   Y[j] ← t ⊙ Y[j]
//! if root:   Send(Y[j], dual) ‖ Recv(t, dual);    Y[j] ← Y[j] ⊙ t   (lower root)
//!                                                 Y[j] ← t ⊙ Y[j]   (upper root)
//! else:      Send(Y[j], parent) ‖ Recv(Y[j-d_i], parent)
//! ```
//!
//! Blocks with index `< 0` or `≥ b` are *void* (zero elements). Following
//! the paper's implementation sketch (§1.3), we skip an exchange entirely
//! when **both** directions are void; the activity predicate depends only
//! on `(j, b, depth)`, which both endpoints know (the parent knows its
//! child's depth is its own + 1), so skipping is symmetric and the
//! `MPI_Get_elements`-style dynamic termination of the paper's C code is
//! replaced by an equivalent static rule:
//!
//! * edge (parent `d`, child `d+1`), round `j`: active iff
//!   `j < b` (up-flowing partial block `j`) **or** `d+1 ≤ j < b + d + 1`
//!   (down-flowing result block `j − (d+1)`);
//! * dual edge, round `j`: active iff `j < b`.
//!
//! Every exchange is a single bidirectional [`Comm::sendrecv`] — this is
//! exactly the "three communication steps per round" structure whose cost
//! the paper bounds by `(4h − 3 + 3(b − 1))(α + β·m/b)`.

use crate::buffer::DataBuf;
use crate::comm::Comm;
use crate::error::Result;
use crate::ops::{Elem, ReduceOp, Side};
use crate::pipeline::Blocks;
use crate::topo::DualRootForest;

/// Extract block `k` of `y` if `0 ≤ k < b`, else a void block.
/// (`k` arrives as `isize` because the algorithm indexes `j − (d+1)`.)
fn block_or_void<E: Elem>(y: &DataBuf<E>, blocks: &Blocks, k: isize) -> Result<DataBuf<E>> {
    if k < 0 || k as usize >= blocks.count() {
        Ok(y.empty_like())
    } else {
        let (lo, hi) = blocks.range(k as usize);
        y.block(lo, hi)
    }
}

/// The doubly-pipelined, dual-root reduction-to-all.
///
/// Consumes the local input vector `x` (the `Y` array of Algorithm 1) and
/// returns the reduction `⊙_{k=0}^{p-1} x_k`, identical on every rank.
/// Requires only associativity of `op`.
pub fn allreduce_dpdr<E: Elem, O: ReduceOp<E>>(
    comm: &mut impl Comm<E>,
    x: DataBuf<E>,
    op: &O,
    blocks: &Blocks,
) -> Result<DataBuf<E>> {
    let p = comm.size();
    if p == 1 || x.is_empty() {
        return Ok(x);
    }
    let forest = DualRootForest::new(p)?;
    let role = forest.role(comm.rank())?;
    run_rounds(comm, x, op, blocks, role)
}

/// The §1.2 variant with a **single** doubly-pipelined tree: same round
/// structure, no dual exchange (the root's block is final once both
/// children are combined). The paper: *"all non-leaves, including the
/// root, perform at most two applications of the ⊙ operator per round.
/// On the other hand, … the latency … is slightly higher (by a small
/// constant term)"* — the A6 ablation quantifies both effects.
pub fn allreduce_dpdr_single<E: Elem, O: ReduceOp<E>>(
    comm: &mut impl Comm<E>,
    x: DataBuf<E>,
    op: &O,
    blocks: &Blocks,
) -> Result<DataBuf<E>> {
    let p = comm.size();
    if p == 1 || x.is_empty() {
        return Ok(x);
    }
    let tree = crate::topo::PostOrderTree::new(0, p - 1)?;
    let rank = comm.rank();
    let role = crate::topo::NodeRole {
        tree: crate::topo::TreeId::A,
        depth: tree.depth(rank),
        children: tree.children(rank),
        parent: tree.parent(rank),
        dual: None, // no dual: the root finalizes blocks by itself
        lower_root: false,
    };
    run_rounds(comm, x, op, blocks, role)
}

/// The per-processor round loop of Algorithm 1, parameterized by the
/// rank's role (dual-root forest or single tree).
fn run_rounds<E: Elem, O: ReduceOp<E>>(
    comm: &mut impl Comm<E>,
    x: DataBuf<E>,
    op: &O,
    blocks: &Blocks,
    role: crate::topo::NodeRole,
) -> Result<DataBuf<E>> {
    let mut y = x;
    let d = role.depth;
    let b = blocks.count();

    // Loop bound from Algorithm 1: j = 0 … b + d_i. Rounds past a step's
    // activity window are skipped by the per-edge predicates below.
    for j in 0..=(b + d) {
        // --- steps 1 & 2: the two children -------------------------------
        let up_active = j < b; // child's partial block j flows up
        let down_idx = j as isize - (d as isize + 1); // result block down
        let down_active = down_idx >= 0 && (down_idx as usize) < b;
        if let (true, Some(c0), Some(c1)) = (up_active, role.children[0], role.children[1]) {
            // Fused inner round: both children's partial blocks arrive
            // this round, so fold them in one pass — Y[j] ← t1 ⊙ (t0 ⊙
            // Y[j]) via the arity-3 kernel. The sendrecv/charge sequence
            // is exactly the two-reduce form's (⊙ never touches the
            // clock), so virtual times are bitwise unchanged; the
            // down-flowing block j−(d+1) is disjoint from block j, so the
            // second send reads the same bytes it did before the fusion.
            let t0 = comm.sendrecv(c0, block_or_void(&y, blocks, down_idx)?)?;
            comm.charge_compute(t0.bytes());
            let t1 = comm.sendrecv(c1, block_or_void(&y, blocks, down_idx)?)?;
            comm.charge_compute(t1.bytes());
            let (lo, _hi) = blocks.range(j);
            y.reduce_at3(lo, &t0, &t1, op)?;
        } else {
            for child in role.children.into_iter().flatten() {
                if !up_active && !down_active {
                    continue; // both directions void — skipped symmetrically
                }
                let send = block_or_void(&y, blocks, down_idx)?;
                let t = comm.sendrecv(child, send)?;
                if up_active {
                    // post-order reduction: Y[j] ← t ⊙ Y[j]
                    let (lo, _hi) = blocks.range(j);
                    comm.charge_compute(t.bytes());
                    y.reduce_at(lo, &t, op, Side::Left)?;
                }
            }
        }

        // --- step 3: dual root, or parent ---------------------------------
        if let Some(dual) = role.dual {
            if j < b {
                let (lo, hi) = blocks.range(j);
                // Owned send, not a view: the root reduces into block j in
                // this very round while the dual still holds the sent
                // block, and both roots do so symmetrically — sharing here
                // would make each root wait on the other's in-flight view
                // and fall back to a whole-vector copy-on-write. One pooled
                // block copy is the cheap side of that trade.
                let _site = crate::buffer::pool::cow_site("dpdr/dual-exchange");
                let send = y.extract_owned(lo, hi)?;
                let t = comm.sendrecv(dual, send)?;
                // lower root holds the rank-prefix [0, q): its own partial
                // stands on the left of the dual's.
                let side = if role.lower_root { Side::Right } else { Side::Left };
                comm.charge_compute(t.bytes());
                y.reduce_at(lo, &t, op, side)?;
            }
        } else if let Some(parent) = role.parent {
            let up_active = j < b; // own partial block j flows up
            let down_idx = j as isize - d as isize; // result block j − d down
            let down_active = down_idx >= 0 && (down_idx as usize) < b;
            if up_active || down_active {
                let send = block_or_void(&y, blocks, if up_active { j as isize } else { -1 })?;
                let r = comm.sendrecv(parent, send)?;
                if down_active {
                    let (lo, _hi) = blocks.range(down_idx as usize);
                    y.write_at(lo, &r)?;
                }
            }
        }
    }
    Ok(y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{run_allreduce_i32, RunSpec};
    use crate::comm::{run_world, Timing};
    use crate::model::AlgoKind;
    use crate::ops::{Span, SeqCheckOp, SumOp};

    fn check_sum(p: usize, m: usize, block_elems: usize) {
        let spec = RunSpec::new(p, m).block_elems(block_elems);
        let expected = spec.expected_sum_i32();
        let report = run_allreduce_i32(AlgoKind::Dpdr, &spec, Timing::Real).unwrap();
        for (rank, buf) in report.results.into_iter().enumerate() {
            assert_eq!(
                buf.as_slice().unwrap(),
                &expected[..],
                "p={p} m={m} block={block_elems} rank={rank}"
            );
        }
    }

    #[test]
    fn correct_small_worlds() {
        for p in 1..=10 {
            check_sum(p, 17, 5);
        }
    }

    #[test]
    fn correct_perfect_forest() {
        // p + 2 = 2^h sweet spots
        for p in [2usize, 6, 14, 30] {
            check_sum(p, 64, 8);
        }
    }

    #[test]
    fn correct_single_block() {
        check_sum(7, 9, 100); // b = 1
    }

    #[test]
    fn correct_block_eq_element() {
        check_sum(5, 6, 1); // b = m: maximal pipelining
    }

    #[test]
    fn correct_deep_pipeline_b_less_than_depth() {
        // b small, trees deep: rounds where startup (j < d+1) skips edges
        check_sum(30, 4, 2);
    }

    #[test]
    fn zero_elements_is_noop() {
        let spec = RunSpec::new(6, 0);
        let report = run_allreduce_i32(AlgoKind::Dpdr, &spec, Timing::Real).unwrap();
        for buf in report.results {
            assert_eq!(buf.len(), 0);
        }
    }

    #[test]
    fn order_witness_noncommutative() {
        // SeqCheckOp poisons any out-of-rank-order combination; surviving
        // with Span::of(0, p-1) proves the post-order/dual-root reduction
        // order is exactly rank order.
        for p in [2usize, 3, 5, 8, 14, 23, 30] {
            let m = 10;
            let blocks = Blocks::by_count(m, 3);
            let report = run_world::<Span, _, _>(p, Timing::Real, move |comm| {
                let x = DataBuf::real(vec![Span::rank(comm.rank() as u32); m]);
                allreduce_dpdr(comm, x, &SeqCheckOp, &blocks)
            })
            .unwrap();
            for buf in report.results {
                for s in buf.as_slice().unwrap() {
                    assert_eq!(*s, Span::of(0, p as u32 - 1), "p={p}");
                }
            }
        }
    }

    #[test]
    fn steady_state_block_path_is_zero_copy() {
        // The tentpole invariant of the zero-copy transport: across all
        // pipeline epochs, non-root ranks move blocks purely as slab views
        // (no memcpy, no allocator traffic), and the dual roots' per-epoch
        // snapshots are absorbed by the receive-side pool after warm-up.
        let spec = RunSpec::new(14, 4_000).block_elems(100); // 40 epochs
        let report = run_allreduce_i32(AlgoKind::Dpdr, &spec, Timing::Real).unwrap();
        let forest = crate::topo::DualRootForest::new(14).unwrap();
        for (rank, m) in report.metrics.iter().enumerate() {
            let is_root = forest.role(rank).unwrap().dual.is_some();
            if !is_root {
                assert_eq!(m.bytes_copied, 0, "rank {rank} copied bytes");
                assert_eq!(m.allocs, 0, "rank {rank} hit the allocator");
            } else {
                // one pooled block copy per epoch by design (see the dual
                // exchange), but allocator traffic stays O(1), not O(b)
                assert!(m.allocs <= 4, "root {rank}: {} allocs", m.allocs);
                assert!(m.pool_recycled > 0, "root {rank} never recycled");
            }
        }
    }

    #[test]
    fn phantom_runs_full_protocol() {
        let spec = RunSpec::new(14, 1000).block_elems(100).phantom(true);
        let report = run_allreduce_i32(AlgoKind::Dpdr, &spec, Timing::hydra()).unwrap();
        assert!(report.max_vtime_us > 0.0);
        for buf in report.results {
            assert_eq!(buf.len(), 1000);
            assert!(buf.is_phantom());
        }
    }

    #[test]
    fn phantom_and_real_same_virtual_time() {
        let real = RunSpec::new(10, 500).block_elems(64);
        let phant = real.phantom(true);
        let t_real = run_allreduce_i32(AlgoKind::Dpdr, &real, Timing::hydra())
            .unwrap()
            .max_vtime_us;
        let t_phant = run_allreduce_i32(AlgoKind::Dpdr, &phant, Timing::hydra())
            .unwrap()
            .max_vtime_us;
        assert!((t_real - t_phant).abs() < 1e-9, "{t_real} vs {t_phant}");
    }

    #[test]
    fn sum_various_block_counts_match() {
        for b in [1usize, 2, 3, 5, 10, 50] {
            let m = 50;
            let blocks = Blocks::by_count(m, b);
            let report = run_world::<i32, _, _>(9, Timing::Real, move |comm| {
                let x = DataBuf::real(vec![comm.rank() as i32 + 1; m]);
                allreduce_dpdr(comm, x, &SumOp, &blocks)
            })
            .unwrap();
            let expected = (1..=9).sum::<i32>();
            for buf in report.results {
                assert!(buf.as_slice().unwrap().iter().all(|&v| v == expected));
            }
        }
    }
}
