//! The emulated "native `MPI_Allreduce`" (evaluation item 1).
//!
//! Vendor MPI libraries select among several allreduce algorithms by
//! message size (and communicator size). The paper observed that Open MPI
//! 4.0.5 on Hydra is the best choice at small **and** large counts but
//! "excessively poor in a midrange of counts, where it is the worst
//! implementation by a sometimes large factor", attributing it to "a bad
//! switch of algorithm" (§2). We reproduce the *mechanism* — a count-based
//! switcher like Open MPI's tuned-collectives decision function — and its
//! signature: recursive doubling below 8 KiB (latency-optimal, wins the
//! small counts), the ring above it (β-term `2βm·(p−1)/p`, the best large-
//! count β-term, hence native wins big counts over the `3βm` dual-root
//! algorithm), with the pathology emerging exactly where the ring's
//! `2(p−1)·α` latency dominates: at p = 288 that is the flat ~0.6 ms
//! plateau across Table 2's mid-range (2 500 … 25 000 elements), just like
//! the ~1.1 ms plateau the paper measured.
//!
//! (Rabenseifner would also give a `2βm` β-term, but at p = 288 its
//! non-power-of-two pre/post fold moves full vectors for 64 ranks — an
//! extra `2βm` on their critical path — which is precisely why real
//! libraries prefer the ring there; see `benches/twotree_ablation.rs`.)

use super::recursive_doubling::allreduce_recursive_doubling;
use super::ring::allreduce_ring;
use crate::buffer::DataBuf;
use crate::comm::Comm;
use crate::error::Result;
use crate::ops::{Elem, ReduceOp};

/// Payload-size threshold (bytes) of the switcher.
pub const SMALL_MAX_BYTES: usize = 8 * 1024;

/// Which branch the switcher takes for a given payload.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum NativeBranch {
    RecursiveDoubling,
    Ring,
}

/// The branch selected for `m_bytes` of payload.
pub fn native_branch(m_bytes: usize) -> NativeBranch {
    if m_bytes <= SMALL_MAX_BYTES {
        NativeBranch::RecursiveDoubling
    } else {
        NativeBranch::Ring
    }
}

/// Count-switching allreduce, emulating a vendor `MPI_Allreduce`.
pub fn allreduce_native_switch<E: Elem, O: ReduceOp<E>>(
    comm: &mut impl Comm<E>,
    x: DataBuf<E>,
    op: &O,
) -> Result<DataBuf<E>> {
    match native_branch(x.bytes()) {
        NativeBranch::RecursiveDoubling => allreduce_recursive_doubling(comm, x, op),
        NativeBranch::Ring => allreduce_ring(comm, x, op),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{run_allreduce_i32, RunSpec};
    use crate::comm::Timing;
    use crate::model::AlgoKind;

    #[test]
    fn branch_thresholds() {
        assert_eq!(native_branch(0), NativeBranch::RecursiveDoubling);
        assert_eq!(native_branch(8 * 1024), NativeBranch::RecursiveDoubling);
        assert_eq!(native_branch(8 * 1024 + 1), NativeBranch::Ring);
        assert_eq!(native_branch(100 << 20), NativeBranch::Ring);
    }

    #[test]
    fn correct_across_branches() {
        // m values that hit all three branches (i32 = 4 bytes)
        for m in [16usize, 1_000, 10_000, 100_000, 300_000] {
            let spec = RunSpec::new(6, m);
            let expected = spec.expected_sum_i32();
            let report = run_allreduce_i32(AlgoKind::NativeSwitch, &spec, Timing::Real).unwrap();
            for buf in report.results {
                assert_eq!(buf.as_slice().unwrap(), &expected[..], "m={m}");
            }
        }
    }

    #[test]
    fn midrange_pathology_in_model() {
        // at p = 126, 2 500 elements (10 kB → ring branch, 2(p−1)α latency),
        // native is much worse than plain reduce+bcast — the Table 2
        // signature at the paper's count 2 500.
        let spec = RunSpec::new(126, 2_500).phantom(true);
        let t_native = run_allreduce_i32(AlgoKind::NativeSwitch, &spec, Timing::hydra())
            .unwrap()
            .max_vtime_us;
        let t_rb = run_allreduce_i32(AlgoKind::ReduceBcast, &spec, Timing::hydra())
            .unwrap()
            .max_vtime_us;
        assert!(
            t_native > 1.5 * t_rb,
            "native {t_native} should be pathological vs redbcast {t_rb}"
        );
    }
}
