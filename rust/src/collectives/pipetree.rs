//! **User-Allreduce1**: pipelined reduce followed by pipelined broadcast on
//! a single post-order binary tree (evaluation item 3 of the paper).
//!
//! Per §1.2, with blocks of `m/b` elements the cost is
//! `2(2h + 2(b−1))(α + β·m/b)` — two *phases*, each 2 steps per block:
//! within a phase, the parent-bound (resp. child-bound) transfer of the
//! previous block overlaps the child-bound (resp. parent-bound) receive of
//! the current one via the full-duplex [`Comm::sendrecv_pair`]. The
//! algorithm does *not* overlap the two phases — that is precisely what
//! the doubly-pipelined dual-root algorithm adds, buying `3βm` vs `4βm`.
//!
//! Reduce phase, node at depth `d`, round `j = 0 … b`:
//! ```text
//! S1: Send(acc[j−1], parent) ‖ Recv(t, child0);  acc[j] ← t ⊙ acc[j]
//! S2:                          Recv(t, child1);  acc[j] ← t ⊙ acc[j]
//! ```
//! Broadcast phase, round `j = 0 … b`:
//! ```text
//! S1: Send(y[j−1], child0) ‖ Recv(y[j], parent)
//! S2: Send(y[j−1], child1)
//! ```

use crate::buffer::DataBuf;
use crate::comm::Comm;
use crate::error::Result;
use crate::ops::{Elem, ReduceOp, Side};
use crate::pipeline::Blocks;
use crate::topo::PostOrderTree;

/// Pipelined single-tree reduce + broadcast allreduce.
pub fn allreduce_pipetree<E: Elem, O: ReduceOp<E>>(
    comm: &mut impl Comm<E>,
    x: DataBuf<E>,
    op: &O,
    blocks: &Blocks,
) -> Result<DataBuf<E>> {
    let p = comm.size();
    let mut y = x;
    if p == 1 || y.is_empty() {
        return Ok(y);
    }
    let tree = PostOrderTree::new(0, p - 1)?;
    let rank = comm.rank();
    let parent = tree.parent(rank);
    let [c0, c1] = tree.children(rank);
    let b = blocks.count();

    // --- phase 1: pipelined reduction toward the root (rank p−1) ---------
    for j in 0..=b {
        let up_active = j >= 1; // acc block j−1 goes up
        let dn_active = j < b; // children's partial block j comes in
        // S1: parent-send ‖ child0-recv (full duplex)
        match (parent.filter(|_| up_active), c0.filter(|_| dn_active)) {
            (Some(par), Some(ch)) => {
                let (lo, hi) = blocks.range(j - 1);
                let send = y.extract(lo, hi)?;
                let t = comm.sendrecv_pair(par, send, ch)?;
                let (lo_j, _) = blocks.range(j);
                comm.charge_compute(t.bytes());
                y.reduce_at(lo_j, &t, op, Side::Left)?;
            }
            (Some(par), None) => {
                let (lo, hi) = blocks.range(j - 1);
                comm.send(par, y.extract(lo, hi)?)?;
            }
            (None, Some(ch)) => {
                let t = comm.recv(ch)?;
                let (lo_j, _) = blocks.range(j);
                comm.charge_compute(t.bytes());
                y.reduce_at(lo_j, &t, op, Side::Left)?;
            }
            (None, None) => {}
        }
        // S2: child1-recv
        if let Some(ch) = c1.filter(|_| dn_active) {
            let t = comm.recv(ch)?;
            let (lo_j, _) = blocks.range(j);
            comm.charge_compute(t.bytes());
            y.reduce_at(lo_j, &t, op, Side::Left)?;
        }
    }

    // --- phase 2: pipelined broadcast from the root -----------------------
    for j in 0..=b {
        let dn_active = j < b; // final block j arrives from parent
        let up_active = j >= 1; // final block j−1 goes to the children
        // S1: child0-send ‖ parent-recv
        match (c0.filter(|_| up_active), parent.filter(|_| dn_active)) {
            (Some(ch), Some(par)) => {
                let (lo, hi) = blocks.range(j - 1);
                let send = y.extract(lo, hi)?;
                let r = comm.sendrecv_pair(ch, send, par)?;
                let (lo_j, _) = blocks.range(j);
                y.write_at(lo_j, &r)?;
            }
            (Some(ch), None) => {
                let (lo, hi) = blocks.range(j - 1);
                comm.send(ch, y.extract(lo, hi)?)?;
            }
            (None, Some(par)) => {
                let r = comm.recv(par)?;
                let (lo_j, _) = blocks.range(j);
                y.write_at(lo_j, &r)?;
            }
            (None, None) => {}
        }
        // S2: child1-send
        if let Some(ch) = c1.filter(|_| up_active) {
            let (lo, hi) = blocks.range(j - 1);
            comm.send(ch, y.extract(lo, hi)?)?;
        }
    }
    Ok(y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{run_allreduce_i32, RunSpec};
    use crate::comm::{run_world, Timing};
    use crate::model::AlgoKind;
    use crate::ops::{SeqCheckOp, Span};

    fn check_sum(p: usize, m: usize, block_elems: usize) {
        let spec = RunSpec::new(p, m).block_elems(block_elems);
        let expected = spec.expected_sum_i32();
        let report = run_allreduce_i32(AlgoKind::PipeTree, &spec, Timing::Real).unwrap();
        for (rank, buf) in report.results.into_iter().enumerate() {
            assert_eq!(
                buf.as_slice().unwrap(),
                &expected[..],
                "p={p} m={m} block={block_elems} rank={rank}"
            );
        }
    }

    #[test]
    fn correct_small_worlds() {
        for p in 1..=10 {
            check_sum(p, 17, 5);
        }
    }

    #[test]
    fn correct_various_blockings() {
        for blk in [1usize, 3, 7, 64] {
            check_sum(13, 40, blk);
        }
    }

    #[test]
    fn order_witness_noncommutative() {
        for p in [2usize, 3, 7, 15, 24] {
            let m = 8;
            let blocks = Blocks::by_count(m, 4);
            let report = run_world::<Span, _, _>(p, Timing::Real, move |comm| {
                let x = DataBuf::real(vec![Span::rank(comm.rank() as u32); m]);
                allreduce_pipetree(comm, x, &SeqCheckOp, &blocks)
            })
            .unwrap();
            for buf in report.results {
                for s in buf.as_slice().unwrap() {
                    assert_eq!(*s, Span::of(0, p as u32 - 1), "p={p}");
                }
            }
        }
    }

    #[test]
    fn dpdr_beats_pipetree_in_model_at_large_m() {
        // The headline comparison (Table 2 large counts): with the same
        // block size, doubly-pipelined < pipelined reduce+bcast.
        let spec = RunSpec::new(30, 200_000).block_elems(16_000).phantom(true);
        let t_pipe = run_allreduce_i32(AlgoKind::PipeTree, &spec, Timing::hydra())
            .unwrap()
            .max_vtime_us;
        let t_dpdr = run_allreduce_i32(AlgoKind::Dpdr, &spec, Timing::hydra())
            .unwrap()
            .max_vtime_us;
        assert!(
            t_dpdr < t_pipe,
            "dpdr {t_dpdr} us should beat pipetree {t_pipe} us"
        );
    }
}
