//! Rabenseifner's allreduce: reduce-scatter by recursive vector halving,
//! then allgather by recursive doubling — `2·⌈log2 p⌉·α + 2·((p−1)/p)·βm`.
//! This is what good MPI libraries use for large messages, and the
//! large-count branch of our emulated "native" `MPI_Allreduce`: its
//! `2βm` β-term is why the paper's native MPI beats even the
//! doubly-pipelined algorithm (`3βm`) at the largest counts (Table 2).
//!
//! Non-power-of-two `p` uses the same pre/post fold as recursive doubling.
//! Segment bookkeeping is aligned to [`Blocks`] boundaries, so arbitrary
//! `m` (including `m < p`) works; order is preserved the same way as in
//! recursive doubling (aligned complementary intervals + `Left`/`Right`
//! by partner position).

use crate::buffer::DataBuf;
use crate::comm::Comm;
use crate::error::Result;
use crate::ops::{Elem, ReduceOp, Side};
use crate::pipeline::Blocks;

fn carrier(e: usize, rem: usize) -> usize {
    if e < rem {
        2 * e
    } else {
        e + rem
    }
}

/// Element range `[lo, hi)` covered by segment indices `[slo, shi)`.
fn elem_range(segs: &Blocks, slo: usize, shi: usize) -> (usize, usize) {
    debug_assert!(slo < shi);
    (segs.range(slo).0, segs.range(shi - 1).1)
}

/// Rabenseifner (reduce-scatter + allgather) allreduce.
pub fn allreduce_rabenseifner<E: Elem, O: ReduceOp<E>>(
    comm: &mut impl Comm<E>,
    x: DataBuf<E>,
    op: &O,
) -> Result<DataBuf<E>> {
    let p = comm.size();
    let mut y = x;
    if p == 1 || y.is_empty() {
        return Ok(y);
    }
    let rank = comm.rank();
    let k = crate::util::log2_floor(p) as usize;
    let pow = 1usize << k;
    let rem = p - pow;

    // pre-fold (as recursive doubling)
    let eff: Option<usize> = if rank < 2 * rem {
        if rank % 2 == 0 {
            let t = comm.recv(rank + 1)?;
            comm.charge_compute(t.bytes());
            y.reduce_all(&t, op, Side::Right)?;
            Some(rank / 2)
        } else {
            comm.send(rank - 1, y.clone())?;
            None
        }
    } else {
        Some(rank - rem)
    };

    if let Some(e) = eff {
        let segs = Blocks::segments(y.len(), pow);

        // --- reduce-scatter: recursive halving, LSB → MSB -----------------
        // Partnering by the *lowest* bit first pairs adjacent effective
        // ranks, so at every step the accumulated contribution covers the
        // aligned contiguous interval [e & !(2bit−1), …) — this is what
        // makes the whole algorithm order-preserving (unlike the textbook
        // MSB-first halving, which combines rank e with e + p/2 first).
        let (mut slo, mut shi) = (0usize, pow);
        let mut levels: Vec<(usize, usize, usize)> = Vec::new(); // (bit, parent_lo, parent_hi)
        let mut bit = 1usize;
        while bit < pow {
            let partner_e = e ^ bit;
            let partner = carrier(partner_e, rem);
            levels.push((bit, slo, shi));
            let smid = slo + (shi - slo) / 2;
            let keep_low = e & bit == 0;
            let (keep, give) = if keep_low {
                ((slo, smid), (smid, shi))
            } else {
                ((smid, shi), (slo, smid))
            };
            let (glo, ghi) = elem_range(&segs, give.0, give.1);
            let send = y.extract(glo, ghi)?;
            let got = comm.sendrecv(partner, send)?;
            let (klo, _khi) = elem_range(&segs, keep.0, keep.1);
            let side = if partner_e < e { Side::Left } else { Side::Right };
            comm.charge_compute(got.bytes());
            y.reduce_at(klo, &got, op, side)?;
            (slo, shi) = keep;
            bit <<= 1;
        }
        debug_assert_eq!(shi - slo, 1); // rank e owns one (bit-reversed) segment

        // --- allgather: replay the halving in reverse, merging back -------
        while let Some((bit, plo, phi)) = levels.pop() {
            let partner_e = e ^ bit;
            let partner = carrier(partner_e, rem);
            let (mlo, mhi) = elem_range(&segs, slo, shi);
            let send = y.extract(mlo, mhi)?;
            let got = comm.sendrecv(partner, send)?;
            // the partner owns the other half of the parent range
            let pmid = plo + (phi - plo) / 2;
            let (sib_lo, sib_hi) = if slo == plo { (pmid, phi) } else { (plo, pmid) };
            let (wlo, _whi) = elem_range(&segs, sib_lo, sib_hi);
            y.write_at(wlo, &got)?;
            (slo, shi) = (plo, phi);
        }
    }

    // post-fold
    if rank < 2 * rem {
        if rank % 2 == 0 {
            comm.send(rank + 1, y.clone())?;
        } else {
            y = comm.recv(rank - 1)?;
        }
    }
    Ok(y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{run_allreduce_i32, RunSpec};
    use crate::comm::{run_world, Timing};
    use crate::model::AlgoKind;
    use crate::ops::{SeqCheckOp, Span};

    #[test]
    fn correct_powers_of_two() {
        for p in [1usize, 2, 4, 8, 16] {
            let spec = RunSpec::new(p, 53);
            let expected = spec.expected_sum_i32();
            let report = run_allreduce_i32(AlgoKind::Rabenseifner, &spec, Timing::Real).unwrap();
            for buf in report.results {
                assert_eq!(buf.as_slice().unwrap(), &expected[..], "p={p}");
            }
        }
    }

    #[test]
    fn correct_non_powers() {
        for p in [3usize, 5, 6, 7, 9, 12, 19, 24] {
            let spec = RunSpec::new(p, 53);
            let expected = spec.expected_sum_i32();
            let report = run_allreduce_i32(AlgoKind::Rabenseifner, &spec, Timing::Real).unwrap();
            for buf in report.results {
                assert_eq!(buf.as_slice().unwrap(), &expected[..], "p={p}");
            }
        }
    }

    #[test]
    fn tiny_vectors() {
        // m < p: empty segments must flow as void blocks
        for (p, m) in [(8usize, 3usize), (16, 1), (6, 2)] {
            let spec = RunSpec::new(p, m);
            let expected = spec.expected_sum_i32();
            let report = run_allreduce_i32(AlgoKind::Rabenseifner, &spec, Timing::Real).unwrap();
            for buf in report.results {
                assert_eq!(buf.as_slice().unwrap(), &expected[..], "p={p} m={m}");
            }
        }
    }

    #[test]
    fn order_witness() {
        for p in [2usize, 4, 6, 8, 11, 16] {
            let report = run_world::<Span, _, _>(p, Timing::Real, move |comm| {
                let x = DataBuf::real(vec![Span::rank(comm.rank() as u32); 16]);
                allreduce_rabenseifner(comm, x, &SeqCheckOp)
            })
            .unwrap();
            for buf in report.results {
                for s in buf.as_slice().unwrap() {
                    assert_eq!(*s, Span::of(0, p as u32 - 1), "p={p}");
                }
            }
        }
    }

    #[test]
    fn virtual_beta_term_is_2m() {
        use crate::model::{ComputeCost, CostModel, LinkCost};
        // α = 0: T ≈ 2·βm·(p−1)/p
        let timing = Timing::Virtual(
            CostModel::Uniform(LinkCost::new(0.0, 1e-9)),
            ComputeCost::new(0.0),
        );
        let spec = RunSpec::new(16, 160_000).phantom(true);
        let t = run_allreduce_i32(AlgoKind::Rabenseifner, &spec, timing)
            .unwrap()
            .max_vtime_us;
        let m_bytes = 160_000.0 * 4.0;
        let predicted = 2.0 * m_bytes * 1e-9 * (15.0 / 16.0) * 1e6;
        assert!(
            (t - predicted).abs() / predicted < 0.05,
            "t={t} predicted={predicted}"
        );
    }
}
