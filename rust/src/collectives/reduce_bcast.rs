//! Non-pipelined `MPI_Reduce` + `MPI_Bcast` on binomial trees (evaluation
//! item 2 of the paper) — the way an MPI library implements the two calls
//! for mid-sized messages, and, per the paper (§2), the worst way to do a
//! reduction-to-all for large counts: `2·⌈log2 p⌉·(α + βm)`, i.e. a β-term
//! of `2·log2(p)·βm` with no pipelining at all.

use crate::buffer::DataBuf;
use crate::comm::Comm;
use crate::error::Result;
use crate::ops::{Elem, ReduceOp, Side};
use crate::topo::BinomialTree;

/// Binomial-tree reduction of `y` onto `root`; other ranks' buffers hold
/// partial garbage afterwards (as with `MPI_Reduce`).
///
/// Children are drained in increasing subtree-size order; each child's
/// contribution covers the virtual-rank interval *above* the accumulator's,
/// so `acc ← acc ⊙ t` keeps rank order (exact for `root == 0`; other roots
/// rotate the order and need a commutative `op`, as in MPI practice).
pub fn reduce_binomial<E: Elem, O: ReduceOp<E>>(
    comm: &mut impl Comm<E>,
    y: &mut DataBuf<E>,
    op: &O,
    root: usize,
) -> Result<()> {
    let p = comm.size();
    if p == 1 || y.is_empty() {
        return Ok(());
    }
    let tree = BinomialTree::new(p, root);
    let rank = comm.rank();
    for child in tree.children(rank) {
        let t = comm.recv(child)?;
        comm.charge_compute(t.bytes());
        y.reduce_all(&t, op, Side::Right)?;
    }
    if let Some(parent) = tree.parent(rank) {
        comm.send(parent, y.clone())?;
    }
    Ok(())
}

/// Binomial-tree broadcast of `root`'s buffer.
pub fn bcast_binomial<E: Elem>(
    comm: &mut impl Comm<E>,
    y: &mut DataBuf<E>,
    root: usize,
) -> Result<()> {
    let p = comm.size();
    if p == 1 || y.is_empty() {
        return Ok(());
    }
    let tree = BinomialTree::new(p, root);
    let rank = comm.rank();
    if let Some(parent) = tree.parent(rank) {
        *y = comm.recv(parent)?;
    }
    // largest subtrees first, so they start early
    for child in tree.children(rank).into_iter().rev() {
        comm.send(child, y.clone())?;
    }
    Ok(())
}

/// `MPI_Reduce` to rank 0 followed by `MPI_Bcast` from rank 0.
pub fn allreduce_reduce_bcast<E: Elem, O: ReduceOp<E>>(
    comm: &mut impl Comm<E>,
    x: DataBuf<E>,
    op: &O,
) -> Result<DataBuf<E>> {
    let mut y = x;
    reduce_binomial(comm, &mut y, op, 0)?;
    bcast_binomial(comm, &mut y, 0)?;
    Ok(y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{run_allreduce_i32, RunSpec};
    use crate::comm::{run_world, Timing};
    use crate::model::AlgoKind;
    use crate::ops::{SeqCheckOp, Span, SumOp};

    #[test]
    fn correct_small_worlds() {
        for p in 1..=12 {
            let spec = RunSpec::new(p, 23);
            let expected = spec.expected_sum_i32();
            let report = run_allreduce_i32(AlgoKind::ReduceBcast, &spec, Timing::Real).unwrap();
            for buf in report.results {
                assert_eq!(buf.as_slice().unwrap(), &expected[..], "p={p}");
            }
        }
    }

    #[test]
    fn reduce_only_lands_on_root() {
        let report = run_world::<i32, _, _>(7, Timing::Real, |comm| {
            let mut y = DataBuf::real(vec![1i32; 5]);
            reduce_binomial(comm, &mut y, &SumOp, 0)?;
            Ok((comm.rank(), y))
        })
        .unwrap();
        for (rank, buf) in report.results {
            if rank == 0 {
                assert!(buf.as_slice().unwrap().iter().all(|&v| v == 7));
            }
        }
    }

    #[test]
    fn bcast_from_nonzero_root() {
        let report = run_world::<i32, _, _>(9, Timing::Real, |comm| {
            let mut y = if comm.rank() == 4 {
                DataBuf::real(vec![42i32; 3])
            } else {
                DataBuf::real(vec![0i32; 3])
            };
            bcast_binomial(comm, &mut y, 4)?;
            Ok(y)
        })
        .unwrap();
        for buf in report.results {
            assert_eq!(buf.as_slice().unwrap(), &[42, 42, 42]);
        }
    }

    #[test]
    fn order_witness_root0() {
        // root 0: binomial reduce is order-preserving
        for p in [2usize, 5, 8, 13] {
            let report = run_world::<Span, _, _>(p, Timing::Real, move |comm| {
                let x = DataBuf::real(vec![Span::rank(comm.rank() as u32); 4]);
                allreduce_reduce_bcast(comm, x, &SeqCheckOp)
            })
            .unwrap();
            for buf in report.results {
                for s in buf.as_slice().unwrap() {
                    assert_eq!(*s, Span::of(0, p as u32 - 1), "p={p}");
                }
            }
        }
    }

    #[test]
    fn virtual_cost_is_2logp_alpha_beta_m() {
        // p = 8, no pipelining: T = 2·3·(α + β·m·4B)
        use crate::model::{ComputeCost, CostModel, LinkCost};
        let timing = Timing::Virtual(
            CostModel::Uniform(LinkCost::new(1e-6, 1e-9)),
            ComputeCost::new(0.0),
        );
        let spec = RunSpec::new(8, 1000).phantom(true);
        let t = run_allreduce_i32(AlgoKind::ReduceBcast, &spec, timing)
            .unwrap()
            .max_vtime_us;
        let predicted = 2.0 * 3.0 * (1.0 + 4000.0 * 1e-3); // µs
        // the binomial tree critical path can be slightly shorter than the
        // naive bound; allow 25%
        assert!(
            (t - predicted).abs() / predicted < 0.25,
            "measured {t} vs predicted {predicted}"
        );
    }
}
