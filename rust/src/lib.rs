//! # dpdr — Doubly-Pipelined, Dual-Root Reduction-to-All
//!
//! A full reproduction of J. L. Träff, *"A Doubly-pipelined, Dual-root
//! Reduction-to-all Algorithm and Implementation"* (2021): the algorithm,
//! every baseline of its evaluation, the linear-cost (α-β-γ) cluster
//! simulator they are measured on, an mpicroscope-style benchmark harness,
//! and a PJRT-backed reduction engine whose kernels are AOT-compiled from
//! JAX/Pallas (see `python/compile/`).
//!
//! ## Quick start
//!
//! ```no_run
//! use dpdr::prelude::*;
//!
//! // 14 ranks (p + 2 = 2^4: both trees perfect), 100k ints, 1k-int blocks.
//! let spec = RunSpec::new(14, 100_000).block_elems(1_000);
//! let report = dpdr::collectives::run_allreduce_i32(
//!     AlgoKind::Dpdr, &spec, Timing::hydra()).unwrap();
//! println!("simulated time: {:.2} us", report.max_vtime_us);
//! ```
//!
//! See `examples/` for runnable end-to-end drivers and `benches/` for the
//! reproductions of the paper's Table 2 / Figure 1.

// Every unsafe operation inside an `unsafe fn` must be wrapped in its own
// `unsafe {}` block with a SAFETY comment — the fn-level `unsafe` only
// states the caller's obligations, it does not discharge the body's.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod buffer;
pub mod cli;
pub mod collectives;
pub mod comm;
pub mod error;
pub mod harness;
pub mod metrics;
pub mod model;
pub mod nbc;
pub mod obs;
pub mod ops;
pub mod pipeline;
pub mod proptest;
pub mod runtime;
pub mod schedule;
pub mod topo;
pub mod util;

/// Commonly used items.
pub mod prelude {
    pub use crate::buffer::DataBuf;
    pub use crate::collectives::RunSpec;
    pub use crate::comm::{
        Comm, FaultPlan, Group, LinkOccupancy, RankMetrics, SubComm, ThreadComm, Timing,
        WorldReport,
    };
    pub use crate::error::{Error, Result};
    pub use crate::model::{AlgoKind, ComputeCost, CostModel, LinkCost, NetParams};
    pub use crate::nbc::{
        run_soak, Engine, EngineKind, FusePolicy, NbcConfig, Request, SoakReport, SoakSpec,
    };
    pub use crate::ops::{Elem, MaxOp, MinOp, OpKind, ProdOp, ReduceBackend, ReduceOp, Side, SumOp};
    pub use crate::topo::{DualRootForest, Mapping, PostOrderTree};
}
