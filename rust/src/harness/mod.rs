//! The benchmark harness — a faithful clone of the *mpicroscope*
//! methodology the paper uses (§2, [6]): the running time of an experiment
//! is **the minimum over a number of measurement rounds of the completion
//! time of the slowest rank**, with individual measurements synchronized by
//! barriers; data points are the exact, exponentially distributed count
//! series of Table 2.

pub mod table;

pub use table::{render_markdown, render_tsv, Row};

use crate::buffer::DataBuf;
use crate::collectives::{allreduce_on, RunSpec};
use crate::comm::{run_world, Comm, RankMetrics, ThreadComm, Timing};
use crate::error::Result;
use crate::model::AlgoKind;
use crate::ops::SumOp;

/// The exact element-count series of the paper's Table 2
/// (`MPI_INT` elements, 0 … 40 000 000 bytes, exponentially distributed
/// as chosen by mpicroscope).
pub const TABLE2_COUNTS: [usize; 30] = [
    0, 1, 2, 8, 15, 21, 25, 87, 150, 212, 250, 875, 1_500, 2_125, 2_500, 8_750, 15_000, 21_250,
    25_000, 87_500, 150_000, 212_500, 250_000, 875_000, 1_500_000, 2_125_000, 2_500_000,
    4_597_152, 6_694_304, 8_388_608,
];

/// One measured experiment.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    pub algo: AlgoKind,
    pub count: usize,
    /// min-over-rounds of max-over-ranks completion time, µs.
    pub time_us: f64,
    /// measurement rounds taken.
    pub rounds: usize,
}

/// Run `rounds` barrier-synchronized measurements of `algo` under `spec`
/// and return the mpicroscope statistic (min over rounds of the slowest
/// rank's time).
///
/// Under virtual timing a single round is exact (the simulation is
/// deterministic), but the full protocol is kept so the harness measures
/// real (wall-clock) worlds identically.
pub fn measure(
    algo: AlgoKind,
    spec: &RunSpec,
    timing: Timing,
    rounds: usize,
) -> Result<Measurement> {
    Ok(measure_with_metrics(algo, spec, timing, rounds)?.0)
}

/// [`measure`], additionally returning the world's aggregated
/// [`RankMetrics`] (accumulated over all `rounds`) — so callers can report
/// traffic and reduce-backend dispatch counts for the *same* run the
/// timing came from, instead of paying for a second instrumented run.
pub fn measure_with_metrics(
    algo: AlgoKind,
    spec: &RunSpec,
    timing: Timing,
    rounds: usize,
) -> Result<(Measurement, RankMetrics)> {
    let spec = *spec;
    // a spec carrying non-dedicated NetParams upgrades the cost model to
    // the congestion-aware form
    let timing = spec.effective_timing(timing);
    let rounds = rounds.max(1);
    // schedule-aware partition: Fixed is the spec's block size; Lemma /
    // Greedy price the algorithm's step structure against the run's model
    let blocks = spec.blocks_for(algo, timing)?;
    let report = run_world::<i32, _, _>(spec.p, timing, move |comm: &mut ThreadComm<i32>| {
        let _backend = crate::ops::backend::scope(spec.reduce_backend);
        let mut times = Vec::with_capacity(rounds);
        for _ in 0..rounds {
            let x = if spec.phantom {
                DataBuf::phantom(spec.m)
            } else {
                DataBuf::real(spec.input_i32(comm.rank()))
            };
            comm.barrier()?; // synchronized start (mpicroscope, [2])
            comm.reset_time();
            let _y = allreduce_on(algo, comm, x, &SumOp, &blocks, spec.mapping)?;
            times.push(comm.time_us());
        }
        Ok(times)
    })?;
    // per round: slowest rank; overall: fastest round
    let mut best = f64::INFINITY;
    for round in 0..rounds {
        let slowest = report
            .results
            .iter()
            .map(|times| times[round])
            .fold(f64::NEG_INFINITY, f64::max);
        best = best.min(slowest);
    }
    let totals = report.total_metrics();
    Ok((
        Measurement {
            algo,
            count: spec.m,
            time_us: best,
            rounds,
        },
        totals,
    ))
}

/// Measure a whole count series for several algorithms (one Table-2-style
/// column per algorithm). `base_spec.m` is overridden per count.
pub fn measure_series(
    algos: &[AlgoKind],
    counts: &[usize],
    base_spec: &RunSpec,
    timing: Timing,
    rounds: usize,
) -> Result<Vec<Row>> {
    let mut rows = Vec::with_capacity(counts.len());
    for &count in counts {
        let mut cells = Vec::with_capacity(algos.len());
        for &algo in algos {
            let spec = RunSpec { m: count, ..*base_spec };
            cells.push(measure(algo, &spec, timing, rounds)?.time_us);
        }
        rows.push(Row {
            count,
            times_us: cells,
        });
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_series_shape() {
        assert_eq!(TABLE2_COUNTS.len(), 30);
        assert_eq!(TABLE2_COUNTS[0], 0);
        assert_eq!(*TABLE2_COUNTS.last().unwrap(), 8_388_608);
        // strictly increasing
        assert!(TABLE2_COUNTS.windows(2).all(|w| w[0] < w[1]));
        // max payload = 8.4M ints ≈ 33.5 MB < the paper's 40 MB range cap
        assert!(TABLE2_COUNTS.iter().all(|&c| c * 4 <= 40_000_000));
    }

    #[test]
    fn measure_virtual_deterministic() {
        let spec = RunSpec::new(6, 4_000).phantom(true);
        let a = measure(AlgoKind::Dpdr, &spec, Timing::hydra(), 1).unwrap();
        let b = measure(AlgoKind::Dpdr, &spec, Timing::hydra(), 3).unwrap();
        assert!((a.time_us - b.time_us).abs() < 1e-9);
        assert!(a.time_us > 0.0);
    }

    #[test]
    fn measure_series_rows() {
        let spec = RunSpec::new(4, 0).phantom(true);
        let rows = measure_series(
            &[AlgoKind::Dpdr, AlgoKind::ReduceBcast],
            &[0, 64, 256],
            &spec,
            Timing::hydra(),
            1,
        )
        .unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].count, 0);
        assert_eq!(rows[0].times_us.len(), 2);
        // larger counts cost more
        assert!(rows[2].times_us[0] >= rows[1].times_us[0]);
    }
}
