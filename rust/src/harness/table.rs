//! Table rendering: the paper-style raw-data table (markdown) and a
//! gnuplot/TSV series for the Figure-1 style log-log plot.

use crate::model::AlgoKind;
use crate::util::{fmt_us, with_thousands};

/// One row: a count and one time per algorithm column.
#[derive(Clone, Debug)]
pub struct Row {
    pub count: usize,
    pub times_us: Vec<f64>,
}

/// Markdown table in the layout of the paper's Table 2.
pub fn render_markdown(algos: &[AlgoKind], rows: &[Row]) -> String {
    let mut out = String::new();
    out.push_str("| Elements (count) |");
    for a in algos {
        out.push_str(&format!(" {} |", a.label()));
    }
    out.push('\n');
    out.push_str("|---|");
    for _ in algos {
        out.push_str("---|");
    }
    out.push('\n');
    for row in rows {
        out.push_str(&format!("| {} |", with_thousands(row.count as u64)));
        for t in &row.times_us {
            out.push_str(&format!(" {} |", fmt_us(*t)));
        }
        out.push('\n');
    }
    out
}

/// Tab-separated series: `count<TAB>t_algo1<TAB>t_algo2…` with a `#` header
/// — directly plottable (`gnuplot> plot "out.tsv" using 1:2 …`), the
/// Figure 1 format.
pub fn render_tsv(algos: &[AlgoKind], rows: &[Row]) -> String {
    let mut out = String::from("#count");
    for a in algos {
        out.push('\t');
        out.push_str(a.name());
    }
    out.push('\n');
    for row in rows {
        out.push_str(&row.count.to_string());
        for t in &row.times_us {
            out.push_str(&format!("\t{:.3}", t));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> (Vec<AlgoKind>, Vec<Row>) {
        (
            vec![AlgoKind::NativeSwitch, AlgoKind::Dpdr],
            vec![
                Row {
                    count: 0,
                    times_us: vec![0.29, 0.19],
                },
                Row {
                    count: 8_388_608,
                    times_us: vec![56249.24, 73116.03],
                },
            ],
        )
    }

    #[test]
    fn markdown_layout() {
        let (algos, rows) = sample();
        let md = render_markdown(&algos, &rows);
        assert!(md.contains("| Elements (count) | MPI_Allreduce | Doubly pipelined |"));
        assert!(md.contains("| 8 388 608 | 56249.24 | 73116.03 |"));
        assert_eq!(md.lines().count(), 4);
    }

    #[test]
    fn tsv_layout() {
        let (algos, rows) = sample();
        let tsv = render_tsv(&algos, &rows);
        let mut lines = tsv.lines();
        assert_eq!(lines.next().unwrap(), "#count\tnative\tdpdr");
        assert_eq!(lines.next().unwrap(), "0\t0.290\t0.190");
    }
}
