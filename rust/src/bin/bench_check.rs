//! Bench regression gate: compare a fresh `BENCH_transport.json` (written
//! by `cargo bench --bench transport_micro`) against the committed
//! baseline and fail if the transport regressed.
//!
//! Checked (the ROADMAP's perf-trajectory invariants):
//!
//! * `large_block.mb_per_sec` — large-block throughput must not drop more
//!   than `--tolerance` (default 10%);
//! * `dpdr_real_p14_m200k.bytes_copied` — the zero-copy invariant: copied
//!   bytes must not grow more than the tolerance (plus a small absolute
//!   slack for near-zero baselines).
//!
//! ```text
//! cargo run --release --bin bench_check                 # gate against baseline
//! cargo run --release --bin bench_check -- --write-baseline   # (re)record it
//! ```
//!
//! A missing baseline is not a failure: the first machine with a Rust
//! toolchain records one with `--write-baseline` and commits it; until
//! then the gate reports and passes, so CI bootstraps cleanly.

use dpdr::cli::Args;

/// Extract the number following `"field":` inside the object introduced by
/// `"obj"`. Enough JSON for the flat two-level records our benches write —
/// no dependency needed (the build environment is offline by design). The
/// field search is bounded at the object's closing brace, so a field
/// missing from the named object is reported missing rather than silently
/// read from a later object.
fn num_after(text: &str, obj: &str, field: &str) -> Option<f64> {
    let oi = text.find(&format!("\"{obj}\""))?;
    let rest = &text[oi..];
    let close = rest.find('}').unwrap_or(rest.len());
    let scope = &rest[..close];
    let fi = scope.find(&format!("\"{field}\""))?;
    let scope = &scope[fi..];
    let ci = scope.find(':')?;
    let scope = scope[ci + 1..].trim_start();
    let end = scope
        .find(|c: char| c == ',' || c == '}' || c.is_whitespace())
        .unwrap_or(scope.len());
    scope[..end].parse().ok()
}

struct Gate {
    failures: Vec<String>,
}

impl Gate {
    /// `fresh` must be at least `(1 − tol) ×` baseline (throughput-like).
    fn check_floor(&mut self, what: &str, fresh: f64, base: f64, tol: f64) {
        let floor = base * (1.0 - tol);
        let verdict = if fresh < floor { "REGRESSED" } else { "ok" };
        println!("{what}: baseline {base:.1}, fresh {fresh:.1}, floor {floor:.1} — {verdict}");
        if fresh < floor {
            self.failures
                .push(format!("{what} regressed: {fresh:.1} < {floor:.1}"));
        }
    }

    /// `fresh` must be at most `(1 + tol) ×` baseline `+ slack` (cost-like).
    fn check_ceiling(&mut self, what: &str, fresh: f64, base: f64, tol: f64, slack: f64) {
        let ceil = base * (1.0 + tol) + slack;
        let verdict = if fresh > ceil { "REGRESSED" } else { "ok" };
        println!("{what}: baseline {base:.1}, fresh {fresh:.1}, ceiling {ceil:.1} — {verdict}");
        if fresh > ceil {
            self.failures
                .push(format!("{what} regressed: {fresh:.1} > {ceil:.1}"));
        }
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &["write-baseline", "help"]).expect("args");
    let fresh_path = args.raw("fresh").unwrap_or("BENCH_transport.json").to_string();
    let base_path = args.raw("baseline").unwrap_or("BENCH_baseline.json").to_string();
    let tol: f64 = args.get("tolerance", 0.10).expect("tolerance");

    let fresh = match std::fs::read_to_string(&fresh_path) {
        Ok(s) => s,
        Err(e) => {
            eprintln!(
                "bench_check: cannot read {fresh_path}: {e}\n\
                 run `cargo bench --bench transport_micro` first"
            );
            std::process::exit(2);
        }
    };

    if args.switch("write-baseline") {
        std::fs::write(&base_path, &fresh).expect("write baseline");
        println!("bench_check: recorded {base_path} from {fresh_path}");
        return;
    }

    let base = match std::fs::read_to_string(&base_path) {
        Ok(s) => s,
        Err(_) => {
            println!(
                "bench_check: no baseline at {base_path} — gate passes (bootstrap).\n\
                 Record one with `cargo run --release --bin bench_check -- --write-baseline` \
                 and commit it to arm the gate."
            );
            return;
        }
    };

    let pick = |text: &str, obj: &str, field: &str| -> f64 {
        num_after(text, obj, field).unwrap_or_else(|| {
            eprintln!("bench_check: {obj}.{field} missing from a report");
            std::process::exit(2);
        })
    };

    let mut gate = Gate { failures: Vec::new() };
    gate.check_floor(
        "large_block.mb_per_sec",
        pick(&fresh, "large_block", "mb_per_sec"),
        pick(&base, "large_block", "mb_per_sec"),
        tol,
    );
    gate.check_ceiling(
        "dpdr_real_p14_m200k.bytes_copied",
        pick(&fresh, "dpdr_real_p14_m200k", "bytes_copied"),
        pick(&base, "dpdr_real_p14_m200k", "bytes_copied"),
        tol,
        4096.0, // absolute slack so a near-zero baseline is not a hair trigger
    );
    // informational (no gate): small-block rate and allocator traffic
    if let (Some(f), Some(b)) = (
        num_after(&fresh, "small_block", "msgs_per_sec"),
        num_after(&base, "small_block", "msgs_per_sec"),
    ) {
        println!("small_block.msgs_per_sec: baseline {b:.0}, fresh {f:.0} (informational)");
    }
    if let (Some(f), Some(b)) = (
        num_after(&fresh, "dpdr_real_p14_m200k", "allocs"),
        num_after(&base, "dpdr_real_p14_m200k", "allocs"),
    ) {
        println!("dpdr_real_p14_m200k.allocs: baseline {b:.0}, fresh {f:.0} (informational)");
    }

    if gate.failures.is_empty() {
        println!("bench_check: OK (tolerance {:.0}%)", tol * 100.0);
    } else {
        for f in &gate.failures {
            eprintln!("bench_check: {f}");
        }
        std::process::exit(1);
    }
}

#[cfg(test)]
mod tests {
    use super::num_after;

    const SAMPLE: &str = r#"{
  "small_block": {"elems": 4, "us_per_sendrecv": 0.5100, "msgs_per_sec": 1960784, "mb_per_sec": 0.1},
  "large_block": {"elems": 262144, "us_per_sendrecv": 1.9, "msgs_per_sec": 526316, "mb_per_sec": 1103.9},
  "dpdr_real_p14_m200k": {"bytes_copied": 183296, "allocs": 40, "pool_recycled": 258, "bytes_sent": 11200000}
}"#;

    #[test]
    fn extracts_nested_numbers() {
        assert_eq!(num_after(SAMPLE, "large_block", "mb_per_sec"), Some(1103.9));
        assert_eq!(
            num_after(SAMPLE, "dpdr_real_p14_m200k", "bytes_copied"),
            Some(183296.0)
        );
        assert_eq!(num_after(SAMPLE, "small_block", "elems"), Some(4.0));
        assert_eq!(num_after(SAMPLE, "missing", "mb_per_sec"), None);
        assert_eq!(num_after(SAMPLE, "large_block", "missing"), None);
        // the search must not bleed into a later object's fields
        assert_eq!(num_after(SAMPLE, "small_block", "bytes_copied"), None);
    }
}
