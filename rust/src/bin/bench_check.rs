//! Bench regression gate: compare fresh bench reports against the
//! committed baselines and fail if a perf-trajectory invariant regressed.
//!
//! Checked:
//!
//! * `large_block.mb_per_sec` (`BENCH_transport.json`, written by
//!   `cargo bench --bench transport_micro`) — large-block transport
//!   throughput must not drop more than `--tolerance` (default 10%);
//! * `dpdr_real_p14_m200k.bytes_copied` — the zero-copy invariant: copied
//!   bytes must not grow more than the tolerance (plus a small absolute
//!   slack for near-zero baselines);
//! * `reduce_f32_sum_large.simd_mb_s` (`BENCH_reduce.json`, written by
//!   `cargo bench --bench reduce_backend`) — large-block SIMD reduce
//!   bandwidth must not drop more than the tolerance;
//! * `congestion_36x32.hier_speedup_ports1` (`BENCH_congestion.json`,
//!   written by `cargo bench --bench congestion_ablation`) — the
//!   node-aware hierarchical allreduce must keep beating flat dpdr at
//!   one NIC port per node on the 36×32 world;
//! * `fusion_headline.speedup` (`BENCH_fusion.json`, written by
//!   `cargo bench --bench fusion_overlap`) — the nbc fusion layer's
//!   coalesced small-message allreduce must keep beating back-to-back
//!   sequential ops;
//! * `autotune_headline.small_m_speedup_vs_dpdr` and
//!   `autotune_headline.auto_vs_best_worst_ratio` (`BENCH_autotune.json`,
//!   written by `cargo bench --bench autotune_ablation`) — the `auto`
//!   selection oracle must keep beating always-dpdr at the smallest
//!   message size and must stay within a bounded ratio of the best fixed
//!   candidate at every size;
//! * `progress_headline.schedule_ops_per_sec` and
//!   `progress_headline.schedule_worker_peak` (`BENCH_progress.json`,
//!   written by `cargo bench --bench progress_scaling`) — the
//!   compiled-schedule engine must sustain the K=256 batch above the
//!   committed throughput floor while spawning zero worker threads.
//!
//! ```text
//! cargo run --release --bin bench_check                 # gate against baselines
//! cargo run --release --bin bench_check -- --write-baseline   # (re)record them
//! ```
//!
//! The committed baselines (`BENCH_baseline.json`,
//! `BENCH_reduce_baseline.json`, `BENCH_congestion_baseline.json`,
//! `BENCH_fusion_baseline.json`, `BENCH_progress_baseline.json`,
//! `BENCH_autotune_baseline.json`) are
//! deliberately conservative floors / generous ceilings recorded to
//! *arm* the gate on any CI hardware; re-record with `--write-baseline`
//! on a reference machine to tighten them. A missing baseline or fresh
//! report is not a failure (the gate notes it and passes), so CI
//! bootstraps cleanly.
//!
//! The tolerance is configurable without a code change: `--tolerance
//! 0.08` on the command line, or the `DPDR_BENCH_TOLERANCE` environment
//! variable (the flag wins; default 0.10) — so the deliberately
//! conservative committed baselines can be tightened per machine.

use dpdr::cli::Args;

/// Extract the number following `"field":` inside the object introduced by
/// `"obj"`. Enough JSON for the flat two-level records our benches write —
/// no dependency needed (the build environment is offline by design). The
/// field search is bounded at the object's closing brace, so a field
/// missing from the named object is reported missing rather than silently
/// read from a later object.
fn num_after(text: &str, obj: &str, field: &str) -> Option<f64> {
    let oi = text.find(&format!("\"{obj}\""))?;
    let rest = &text[oi..];
    let close = rest.find('}').unwrap_or(rest.len());
    let scope = &rest[..close];
    let fi = scope.find(&format!("\"{field}\""))?;
    let scope = &scope[fi..];
    let ci = scope.find(':')?;
    let scope = scope[ci + 1..].trim_start();
    let end = scope
        .find(|c: char| c == ',' || c == '}' || c.is_whitespace())
        .unwrap_or(scope.len());
    scope[..end].parse().ok()
}

struct Gate {
    failures: Vec<String>,
}

impl Gate {
    /// `fresh` must be at least `(1 − tol) ×` baseline (throughput-like).
    fn check_floor(&mut self, what: &str, fresh: f64, base: f64, tol: f64) {
        let floor = base * (1.0 - tol);
        let verdict = if fresh < floor { "REGRESSED" } else { "ok" };
        println!("{what}: baseline {base:.1}, fresh {fresh:.1}, floor {floor:.1} — {verdict}");
        if fresh < floor {
            self.failures
                .push(format!("{what} regressed: {fresh:.1} < {floor:.1}"));
        }
    }

    /// `fresh` must be at most `(1 + tol) ×` baseline `+ slack` (cost-like).
    fn check_ceiling(&mut self, what: &str, fresh: f64, base: f64, tol: f64, slack: f64) {
        let ceil = base * (1.0 + tol) + slack;
        let verdict = if fresh > ceil { "REGRESSED" } else { "ok" };
        println!("{what}: baseline {base:.1}, fresh {fresh:.1}, ceiling {ceil:.1} — {verdict}");
        if fresh > ceil {
            self.failures
                .push(format!("{what} regressed: {fresh:.1} > {ceil:.1}"));
        }
    }
}

/// Load `path`, or `None` with a bootstrap note naming the producing
/// command.
fn read_report(path: &str, produce_hint: &str) -> Option<String> {
    match std::fs::read_to_string(path) {
        Ok(s) => Some(s),
        Err(_) => {
            println!("bench_check: no report at {path} — skipped ({produce_hint})");
            None
        }
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &["write-baseline", "help"]).expect("args");
    let fresh_path = args.raw("fresh").unwrap_or("BENCH_transport.json").to_string();
    let base_path = args.raw("baseline").unwrap_or("BENCH_baseline.json").to_string();
    let reduce_fresh_path = args
        .raw("reduce-fresh")
        .unwrap_or("BENCH_reduce.json")
        .to_string();
    let reduce_base_path = args
        .raw("reduce-baseline")
        .unwrap_or("BENCH_reduce_baseline.json")
        .to_string();
    let congestion_fresh_path = args
        .raw("congestion-fresh")
        .unwrap_or("BENCH_congestion.json")
        .to_string();
    let congestion_base_path = args
        .raw("congestion-baseline")
        .unwrap_or("BENCH_congestion_baseline.json")
        .to_string();
    let fusion_fresh_path = args
        .raw("fusion-fresh")
        .unwrap_or("BENCH_fusion.json")
        .to_string();
    let fusion_base_path = args
        .raw("fusion-baseline")
        .unwrap_or("BENCH_fusion_baseline.json")
        .to_string();
    let progress_fresh_path = args
        .raw("progress-fresh")
        .unwrap_or("BENCH_progress.json")
        .to_string();
    let progress_base_path = args
        .raw("progress-baseline")
        .unwrap_or("BENCH_progress_baseline.json")
        .to_string();
    let autotune_fresh_path = args
        .raw("autotune-fresh")
        .unwrap_or("BENCH_autotune.json")
        .to_string();
    let autotune_base_path = args
        .raw("autotune-baseline")
        .unwrap_or("BENCH_autotune_baseline.json")
        .to_string();
    // tolerance: flag > env > 10% default, so per-machine tightening needs
    // no code change
    let env_tol = std::env::var("DPDR_BENCH_TOLERANCE")
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or(0.10);
    let tol: f64 = args.get("tolerance", env_tol).expect("tolerance");

    let fresh = read_report(&fresh_path, "run `cargo bench --bench transport_micro`");
    let reduce_fresh = read_report(&reduce_fresh_path, "run `cargo bench --bench reduce_backend`");
    let congestion_fresh = read_report(
        &congestion_fresh_path,
        "run `cargo bench --bench congestion_ablation`",
    );
    let fusion_fresh = read_report(&fusion_fresh_path, "run `cargo bench --bench fusion_overlap`");
    let progress_fresh = read_report(
        &progress_fresh_path,
        "run `cargo bench --bench progress_scaling`",
    );
    let autotune_fresh = read_report(
        &autotune_fresh_path,
        "run `cargo bench --bench autotune_ablation`",
    );
    if fresh.is_none()
        && reduce_fresh.is_none()
        && congestion_fresh.is_none()
        && fusion_fresh.is_none()
        && progress_fresh.is_none()
        && autotune_fresh.is_none()
    {
        eprintln!("bench_check: no fresh reports at all — run the benches first");
        std::process::exit(2);
    }

    if args.switch("write-baseline") {
        if let Some(f) = &fresh {
            std::fs::write(&base_path, f).expect("write baseline");
            println!("bench_check: recorded {base_path} from {fresh_path}");
        }
        if let Some(f) = &reduce_fresh {
            std::fs::write(&reduce_base_path, f).expect("write reduce baseline");
            println!("bench_check: recorded {reduce_base_path} from {reduce_fresh_path}");
        }
        if let Some(f) = &congestion_fresh {
            std::fs::write(&congestion_base_path, f).expect("write congestion baseline");
            println!(
                "bench_check: recorded {congestion_base_path} from {congestion_fresh_path}"
            );
        }
        if let Some(f) = &fusion_fresh {
            std::fs::write(&fusion_base_path, f).expect("write fusion baseline");
            println!("bench_check: recorded {fusion_base_path} from {fusion_fresh_path}");
        }
        if let Some(f) = &progress_fresh {
            std::fs::write(&progress_base_path, f).expect("write progress baseline");
            println!("bench_check: recorded {progress_base_path} from {progress_fresh_path}");
        }
        if let Some(f) = &autotune_fresh {
            std::fs::write(&autotune_base_path, f).expect("write autotune baseline");
            println!("bench_check: recorded {autotune_base_path} from {autotune_fresh_path}");
        }
        return;
    }

    let pick = |text: &str, obj: &str, field: &str| -> f64 {
        num_after(text, obj, field).unwrap_or_else(|| {
            eprintln!("bench_check: {obj}.{field} missing from a report");
            std::process::exit(2);
        })
    };

    let mut gate = Gate { failures: Vec::new() };
    let mut armed = 0usize;

    if let Some(fresh) = &fresh {
        match std::fs::read_to_string(&base_path) {
            Ok(base) => {
                armed += 1;
                gate.check_floor(
                    "large_block.mb_per_sec",
                    pick(fresh, "large_block", "mb_per_sec"),
                    pick(&base, "large_block", "mb_per_sec"),
                    tol,
                );
                gate.check_ceiling(
                    "dpdr_real_p14_m200k.bytes_copied",
                    pick(fresh, "dpdr_real_p14_m200k", "bytes_copied"),
                    pick(&base, "dpdr_real_p14_m200k", "bytes_copied"),
                    tol,
                    4096.0, // absolute slack: a near-zero baseline is not a hair trigger
                );
                // informational (no gate): small-block rate and allocator traffic
                if let (Some(f), Some(b)) = (
                    num_after(fresh, "small_block", "msgs_per_sec"),
                    num_after(&base, "small_block", "msgs_per_sec"),
                ) {
                    println!(
                        "small_block.msgs_per_sec: baseline {b:.0}, fresh {f:.0} (informational)"
                    );
                }
                if let (Some(f), Some(b)) = (
                    num_after(fresh, "dpdr_real_p14_m200k", "allocs"),
                    num_after(&base, "dpdr_real_p14_m200k", "allocs"),
                ) {
                    println!(
                        "dpdr_real_p14_m200k.allocs: baseline {b:.0}, fresh {f:.0} (informational)"
                    );
                }
            }
            Err(_) => println!(
                "bench_check: no baseline at {base_path} — transport gate passes (bootstrap).\n\
                 Record one with `cargo run --release --bin bench_check -- --write-baseline` \
                 and commit it to arm the gate."
            ),
        }
    }

    if let Some(fresh) = &reduce_fresh {
        match std::fs::read_to_string(&reduce_base_path) {
            Ok(base) => {
                armed += 1;
                gate.check_floor(
                    "reduce_f32_sum_large.simd_mb_s",
                    pick(fresh, "reduce_f32_sum_large", "simd_mb_s"),
                    pick(&base, "reduce_f32_sum_large", "simd_mb_s"),
                    tol,
                );
                if let Some(speedup) = num_after(fresh, "reduce_f32_sum_large", "simd_speedup") {
                    println!(
                        "reduce_f32_sum_large.simd_speedup: {speedup:.2}x over scalar \
                         (informational)"
                    );
                }
            }
            Err(_) => println!(
                "bench_check: no baseline at {reduce_base_path} — reduce gate passes (bootstrap)."
            ),
        }
    }

    if let Some(fresh) = &congestion_fresh {
        match std::fs::read_to_string(&congestion_base_path) {
            Ok(base) => {
                armed += 1;
                // the node-aware win at one NIC port per node must hold
                // (the committed baseline is a conservative 1.0 — parity)
                gate.check_floor(
                    "congestion_36x32.hier_speedup_ports1",
                    pick(fresh, "congestion_36x32", "hier_speedup_ports1"),
                    pick(&base, "congestion_36x32", "hier_speedup_ports1"),
                    tol,
                );
                if let (Some(f), Some(b)) = (
                    num_after(fresh, "congestion_36x32", "flat_slowdown_ports1"),
                    num_after(&base, "congestion_36x32", "flat_slowdown_ports1"),
                ) {
                    println!(
                        "congestion_36x32.flat_slowdown_ports1: baseline {b:.2}, \
                         fresh {f:.2} (informational)"
                    );
                }
            }
            Err(_) => println!(
                "bench_check: no baseline at {congestion_base_path} — congestion gate \
                 passes (bootstrap)."
            ),
        }
    }

    if let Some(fresh) = &fusion_fresh {
        match std::fs::read_to_string(&fusion_base_path) {
            Ok(base) => {
                armed += 1;
                // fused small-message allreduce must keep beating the
                // back-to-back sequential loop (the committed baseline is
                // a conservative 1.0 — parity)
                gate.check_floor(
                    "fusion_headline.speedup",
                    pick(fresh, "fusion_headline", "speedup"),
                    pick(&base, "fusion_headline", "speedup"),
                    tol,
                );
                if let Some(s) = num_after(fresh, "overlap_congested_m1024_k8", "slowdown") {
                    println!(
                        "overlap_congested_m1024_k8.slowdown: {s:.2}x at 1 port/node \
                         (informational)"
                    );
                }
            }
            Err(_) => println!(
                "bench_check: no baseline at {fusion_base_path} — fusion gate passes \
                 (bootstrap)."
            ),
        }
    }

    if let Some(fresh) = &autotune_fresh {
        match std::fs::read_to_string(&autotune_base_path) {
            Ok(base) => {
                armed += 1;
                // the selection oracle must keep beating always-dpdr at
                // the smallest message size (the committed baseline is a
                // conservative floor well below the modelled win) ...
                gate.check_floor(
                    "autotune_headline.small_m_speedup_vs_dpdr",
                    pick(fresh, "autotune_headline", "small_m_speedup_vs_dpdr"),
                    pick(&base, "autotune_headline", "small_m_speedup_vs_dpdr"),
                    tol,
                );
                // ... and its worst pick must stay within a bounded ratio
                // of the best fixed candidate at every swept size
                gate.check_ceiling(
                    "autotune_headline.auto_vs_best_worst_ratio",
                    pick(fresh, "autotune_headline", "auto_vs_best_worst_ratio"),
                    pick(&base, "autotune_headline", "auto_vs_best_worst_ratio"),
                    tol,
                    0.05,
                );
                if let Some(s) = num_after(fresh, "autotune_headline", "large_m_speedup_vs_rd") {
                    println!(
                        "autotune_headline.large_m_speedup_vs_rd: {s:.2}x (informational)"
                    );
                }
            }
            Err(_) => println!(
                "bench_check: no baseline at {autotune_base_path} — autotune gate passes \
                 (bootstrap)."
            ),
        }
    }

    if let Some(fresh) = &progress_fresh {
        match std::fs::read_to_string(&progress_base_path) {
            Ok(base) => {
                armed += 1;
                // the compiled-schedule engine must hold its K=256
                // throughput floor (the committed baseline is a
                // conservative 1 op/s — any completing run passes) ...
                gate.check_floor(
                    "progress_headline.schedule_ops_per_sec",
                    pick(fresh, "progress_headline", "schedule_ops_per_sec"),
                    pick(&base, "progress_headline", "schedule_ops_per_sec"),
                    tol,
                );
                // ... and must never spawn a worker thread: ceiling 0
                // with sub-1 slack, so any nonzero peak fails the gate
                gate.check_ceiling(
                    "progress_headline.schedule_worker_peak",
                    pick(fresh, "progress_headline", "schedule_worker_peak"),
                    pick(&base, "progress_headline", "schedule_worker_peak"),
                    tol,
                    0.5,
                );
                if let (Some(t), Some(s)) = (
                    num_after(fresh, "progress_k256", "threaded_ops_s"),
                    num_after(fresh, "progress_k256", "schedule_ops_s"),
                ) {
                    println!(
                        "progress_k256: threaded {t:.0} ops/s vs schedule {s:.0} ops/s \
                         (informational)"
                    );
                }
            }
            Err(_) => println!(
                "bench_check: no baseline at {progress_base_path} — progress gate passes \
                 (bootstrap)."
            ),
        }
    }

    if gate.failures.is_empty() {
        println!(
            "bench_check: OK ({armed} gate group(s) armed, tolerance {:.0}%)",
            tol * 100.0
        );
    } else {
        for f in &gate.failures {
            eprintln!("bench_check: {f}");
        }
        std::process::exit(1);
    }
}

#[cfg(test)]
mod tests {
    use super::num_after;

    const SAMPLE: &str = r#"{
  "small_block": {"elems": 4, "us_per_sendrecv": 0.5100, "msgs_per_sec": 1960784, "mb_per_sec": 0.1},
  "large_block": {"elems": 262144, "us_per_sendrecv": 1.9, "msgs_per_sec": 526316, "mb_per_sec": 1103.9},
  "dpdr_real_p14_m200k": {"bytes_copied": 183296, "allocs": 40, "pool_recycled": 258, "bytes_sent": 11200000}
}"#;

    #[test]
    fn extracts_nested_numbers() {
        assert_eq!(num_after(SAMPLE, "large_block", "mb_per_sec"), Some(1103.9));
        assert_eq!(
            num_after(SAMPLE, "dpdr_real_p14_m200k", "bytes_copied"),
            Some(183296.0)
        );
        assert_eq!(num_after(SAMPLE, "small_block", "elems"), Some(4.0));
        assert_eq!(num_after(SAMPLE, "missing", "mb_per_sec"), None);
        assert_eq!(num_after(SAMPLE, "large_block", "missing"), None);
        // the search must not bleed into a later object's fields
        assert_eq!(num_after(SAMPLE, "small_block", "bytes_copied"), None);
    }
}
