//! The autotuned algorithm-selection oracle behind [`AlgoKind::Auto`].
//!
//! The portfolio of §1–§2 plus the non-pipelined optimum (Träff 2024)
//! covers three regimes — latency-dominated (recursive doubling),
//! bandwidth-dominated non-pipelined (circulant RS+AG), and pipelined
//! (dpdr and friends) — and no single member wins everywhere. Instead of
//! hand-coding crossover thresholds, [`generate`] *measures* every
//! candidate at every grid point through the virtual-clock harness
//! (exactly the runs `dpdr run` would do) and persists the winners as a
//! versioned decision table, `TUNE_table.json`, committed next to the
//! crate and embedded at compile time via `include_str!`.
//!
//! At dispatch, [`auto_pick`] consults the table when the run's cost
//! model matches the one the table was swept under (uniform, dedicated,
//! same α/β); otherwise it falls back to the closed-form predictions of
//! [`predicted_time_us_net`](crate::model::predicted_time_us_net) — so
//! `Auto` degrades to the analytic argmin on models nobody tuned for,
//! and never fails. Selection is a pure function of `(p, m_bytes,
//! model)`, identical on every rank: SPMD-safe by construction.
//!
//! `dpdr tune --check` regenerates the sweep and diffs the *decisions*
//! against the embedded table, so CI catches silent drift between the
//! simulator and the committed winners. Measured times are allowed to
//! wiggle; the argmin is not (ties are broken by an ε-margin in
//! candidate order, which absorbs sub-nanosecond float noise).

use std::sync::OnceLock;

use crate::collectives::RunSpec;
use crate::comm::Timing;
use crate::error::{Error, Result};
use crate::model::{lemma, AlgoKind, CostModel, LinkCost};
use crate::pipeline::SchedKind;

/// Every candidate the sweep races, in tie-break priority order (an
/// earlier entry keeps a tie): the cheap latency-optimal algorithms
/// first, then bandwidth-optimal, then the pipelined family.
pub const CANDIDATES: [AlgoKind; 7] = [
    AlgoKind::RecursiveDoubling,
    AlgoKind::NonPipelined,
    AlgoKind::Rabenseifner,
    AlgoKind::Ring,
    AlgoKind::Dpdr,
    AlgoKind::TwoTree,
    AlgoKind::PipeTree,
];

/// The order-preserving subset, for callers that must not reassociate
/// across ranks (the non-blocking fusion layer reduces partially-filled
/// float batches): ring and the circulant RS+AG accumulate segments in
/// rotated order and are excluded.
pub const ORDERED_CANDIDATES: [AlgoKind; 5] = [
    AlgoKind::RecursiveDoubling,
    AlgoKind::Rabenseifner,
    AlgoKind::Dpdr,
    AlgoKind::TwoTree,
    AlgoKind::PipeTree,
];

/// Bump when the sweep grid, candidate set, or entry format changes.
pub const TABLE_VERSION: u32 = 1;

/// Tie margin (µs): a later candidate must beat the incumbent by more
/// than this to take a grid point. Absorbs float-rounding near-ties
/// (e.g. Rabenseifner vs the circulant RS+AG at power-of-two p, which
/// exchange byte-identical volumes) so regenerated winners are stable.
const TIE_EPS_US: f64 = 1e-3;

/// Rank counts the sweep covers: every p ≤ 16 (ragged counts included —
/// the fold penalty moves crossovers), then sparse powers of two.
pub fn grid_p() -> Vec<usize> {
    let mut g: Vec<usize> = (2..=16).collect();
    g.extend([24, 32]);
    g
}

/// Message sizes (bytes) the sweep covers, log-spaced across the
/// latency → bandwidth → pipelining regimes. Lookups snap to the
/// nearest grid size in log-space.
pub const GRID_M_BYTES: [usize; 7] = [4, 64, 1024, 4096, 16_384, 262_144, 4_194_304];

/// One swept grid point: the winning algorithm and its measured time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TuneEntry {
    pub p: usize,
    pub m_bytes: usize,
    pub algo: AlgoKind,
    /// Winner's virtual-clock time (µs); informational — `--check`
    /// compares decisions, not times.
    pub best_us: f64,
}

/// A versioned decision table: the cost-model fingerprint it was swept
/// under, plus the per-grid-point winners.
#[derive(Clone, Debug, PartialEq)]
pub struct TuneTable {
    pub version: u32,
    /// Link start-up latency (seconds) of the swept uniform model.
    pub alpha: f64,
    /// Per-byte link time (seconds).
    pub beta: f64,
    /// Per-byte reduction time (seconds).
    pub gamma: f64,
    pub entries: Vec<TuneEntry>,
}

fn rel_close(a: f64, b: f64) -> bool {
    (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(f64::MIN_POSITIVE)
}

impl TuneTable {
    /// Does `link` match the model this table was swept under?
    pub fn link_matches(&self, link: LinkCost) -> bool {
        rel_close(self.alpha, link.alpha) && rel_close(self.beta, link.beta)
    }

    /// Table-driven pick: exact-p rows, nearest `m_bytes` in log-space
    /// (ties to the smaller size). `None` when `p` is off-grid — the
    /// caller falls back to the analytic model rather than trusting a
    /// neighbouring rank count (the fold penalty is not monotone in p).
    pub fn lookup(&self, p: usize, m_bytes: usize) -> Option<AlgoKind> {
        let target = (m_bytes.max(1) as f64).ln();
        let mut best: Option<(f64, AlgoKind)> = None;
        for e in self.entries.iter().filter(|e| e.p == p) {
            let d = ((e.m_bytes.max(1) as f64).ln() - target).abs();
            // strict < keeps the earlier (smaller-m) row on exact ties
            if best.map_or(true, |(bd, _)| d < bd) {
                best = Some((d, e.algo));
            }
        }
        best.map(|(_, a)| a)
    }

    /// Same winners at every grid point (version and link fingerprint
    /// included, measured times excluded) — the `--check` predicate.
    pub fn same_decisions(&self, other: &TuneTable) -> bool {
        self.version == other.version
            && rel_close(self.alpha, other.alpha)
            && rel_close(self.beta, other.beta)
            && rel_close(self.gamma, other.gamma)
            && self.entries.len() == other.entries.len()
            && self
                .entries
                .iter()
                .zip(&other.entries)
                .all(|(a, b)| a.p == b.p && a.m_bytes == b.m_bytes && a.algo == b.algo)
    }

    /// Hand-rolled, dependency-free JSON (the `ScheduleCert` idiom):
    /// one entry per line, so the parser can scan line-by-line and the
    /// committed file diffs cleanly.
    pub fn to_json(&self) -> String {
        let entries: Vec<String> = self
            .entries
            .iter()
            .map(|e| {
                format!(
                    "    {{\"p\": {}, \"m_bytes\": {}, \"algo\": \"{}\", \"best_us\": {:.3}}}",
                    e.p,
                    e.m_bytes,
                    e.algo.name(),
                    e.best_us
                )
            })
            .collect();
        format!(
            "{{\n  \"version\": {},\n  \"alpha\": {:e},\n  \"beta\": {:e},\n  \"gamma\": {:e},\n  \"entries\": [\n{}\n  ]\n}}\n",
            self.version,
            self.alpha,
            self.beta,
            self.gamma,
            entries.join(",\n")
        )
    }

    /// Parse the writer's format back. Tolerant line-oriented scan: the
    /// header keys are located anywhere before the entry list; every
    /// line containing `"m_bytes"` is one entry.
    pub fn parse(text: &str) -> Result<TuneTable> {
        let bad = |what: &str| Error::Config(format!("tune table: missing or malformed {what}"));
        let version = num_after(text, "\"version\":").ok_or_else(|| bad("version"))? as u32;
        let alpha = num_after(text, "\"alpha\":").ok_or_else(|| bad("alpha"))?;
        let beta = num_after(text, "\"beta\":").ok_or_else(|| bad("beta"))?;
        let gamma = num_after(text, "\"gamma\":").ok_or_else(|| bad("gamma"))?;
        let mut entries = Vec::new();
        for line in text.lines().filter(|l| l.contains("\"m_bytes\"")) {
            let p = num_after(line, "\"p\":").ok_or_else(|| bad("entry p"))? as usize;
            let m_bytes = num_after(line, "\"m_bytes\":").ok_or_else(|| bad("entry m_bytes"))? as usize;
            let name = str_after(line, "\"algo\":").ok_or_else(|| bad("entry algo"))?;
            let algo = AlgoKind::parse(&name)
                .ok_or_else(|| Error::Config(format!("tune table: unknown algo {name:?}")))?;
            let best_us = num_after(line, "\"best_us\":").ok_or_else(|| bad("entry best_us"))?;
            entries.push(TuneEntry { p, m_bytes, algo, best_us });
        }
        if entries.is_empty() {
            return Err(bad("entries"));
        }
        Ok(TuneTable { version, alpha, beta, gamma, entries })
    }
}

fn num_after(s: &str, key: &str) -> Option<f64> {
    let rest = &s[s.find(key)? + key.len()..];
    let rest = rest.trim_start();
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || matches!(c, '+' | '-' | '.' | 'e' | 'E')))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

fn str_after(s: &str, key: &str) -> Option<String> {
    let rest = &s[s.find(key)? + key.len()..];
    let open = rest.find('"')?;
    let rest = &rest[open + 1..];
    Some(rest[..rest.find('"')?].to_string())
}

/// The committed table, embedded at compile time.
pub fn embedded() -> Result<TuneTable> {
    TuneTable::parse(include_str!("../../TUNE_table.json"))
}

/// The table `auto_pick` consults: `$DPDR_TUNE_TABLE` (a path) when
/// set — so deployments can retune without rebuilding — else the
/// embedded copy. Parsed once; a missing/bad override disables the
/// table (analytic fallback) rather than erroring at dispatch.
fn table() -> Option<&'static TuneTable> {
    static TABLE: OnceLock<Option<TuneTable>> = OnceLock::new();
    TABLE
        .get_or_init(|| match std::env::var("DPDR_TUNE_TABLE") {
            Ok(path) => std::fs::read_to_string(&path)
                .ok()
                .and_then(|t| TuneTable::parse(&t).ok()),
            Err(_) => embedded().ok(),
        })
        .as_ref()
}

/// Analytic argmin over `pool` under `model`: each pipelined candidate
/// is priced at its Lemma-optimal block count, the rest at b = 1.
fn model_pick(p: usize, m_bytes: usize, model: &CostModel, pool: &[AlgoKind]) -> AlgoKind {
    let (_intra, inter) = model.link_levels();
    let mut best = (pool[0], f64::INFINITY);
    for &algo in pool {
        let b = match algo.step_structure(p) {
            Some((a, c)) => {
                lemma::optimal_time(a, c, inter.alpha, inter.beta, m_bytes as f64, usize::MAX).0
            }
            None => 1,
        };
        let t = crate::model::predicted_time_us_net(algo, p, m_bytes, b, model);
        if t < best.1 {
            best = (algo, t);
        }
    }
    best.0
}

/// Resolve [`AlgoKind::Auto`]: the tuned table when `model` is the
/// dedicated uniform model it was swept under, the analytic prediction
/// otherwise. Deterministic in `(p, m_bytes, model)` — every rank of an
/// SPMD run resolves identically.
pub fn auto_pick(p: usize, m_bytes: usize, model: &CostModel) -> AlgoKind {
    if p <= 1 {
        return AlgoKind::Dpdr; // degenerate world: any algo is a no-op
    }
    if model.net_params().is_dedicated() {
        if let Some(link) = model.as_uniform() {
            if let Some(t) = table() {
                if t.link_matches(link) {
                    if let Some(algo) = t.lookup(p, m_bytes) {
                        return algo;
                    }
                }
            }
        }
    }
    model_pick(p, m_bytes, model, &CANDIDATES)
}

/// [`auto_pick`] restricted to order-preserving candidates (analytic
/// only — the table's winners include commutative-only algorithms, and
/// filtering its argmin would not be the constrained optimum anyway).
pub fn auto_pick_ordered(p: usize, m_bytes: usize, model: &CostModel) -> AlgoKind {
    if p <= 1 {
        return AlgoKind::Dpdr;
    }
    model_pick(p, m_bytes, model, &ORDERED_CANDIDATES)
}

/// Sweep the full grid through the virtual-clock harness (phantom
/// payloads, Lemma block schedule, hydra uniform model — one exact
/// round per point) and return the winners. This is what `dpdr tune`
/// runs; the committed `TUNE_table.json` is its output.
pub fn generate() -> Result<TuneTable> {
    let timing = Timing::hydra();
    let (model, gamma) = match timing {
        Timing::Virtual(model, compute) => (model, compute.gamma),
        Timing::Real => unreachable!("Timing::hydra is virtual"),
    };
    let link = model.link_levels().1;
    let mut entries = Vec::new();
    for &p in &grid_p() {
        for &m_bytes in &GRID_M_BYTES {
            let m = (m_bytes / 4).max(1); // i32 grid: sizes are 4-aligned
            let spec = RunSpec::new(p, m).phantom(true).sched(SchedKind::Lemma);
            let mut best: Option<(AlgoKind, f64)> = None;
            for &algo in &CANDIDATES {
                let t = crate::harness::measure(algo, &spec, timing, 1)?.time_us;
                match best {
                    // keep the incumbent unless beaten by > ε
                    Some((_, bt)) if t >= bt - TIE_EPS_US => {}
                    _ => best = Some((algo, t)),
                }
            }
            let (algo, best_us) = best.expect("CANDIDATES is non-empty");
            entries.push(TuneEntry { p, m_bytes, algo, best_us });
        }
    }
    Ok(TuneTable {
        version: TABLE_VERSION,
        alpha: link.alpha,
        beta: link.beta,
        gamma,
        entries,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_table() -> TuneTable {
        TuneTable {
            version: TABLE_VERSION,
            alpha: 1.0e-6,
            beta: 0.70e-9,
            gamma: 0.25e-9,
            entries: vec![
                TuneEntry { p: 4, m_bytes: 64, algo: AlgoKind::RecursiveDoubling, best_us: 2.5 },
                TuneEntry { p: 4, m_bytes: 4096, algo: AlgoKind::NonPipelined, best_us: 9.0 },
                TuneEntry { p: 4, m_bytes: 4_194_304, algo: AlgoKind::NonPipelined, best_us: 4000.0 },
            ],
        }
    }

    #[test]
    fn json_roundtrip_preserves_decisions() {
        let t = toy_table();
        let back = TuneTable::parse(&t.to_json()).unwrap();
        assert!(t.same_decisions(&back));
        assert!((back.entries[1].best_us - 9.0).abs() < 1e-9);
        assert!(rel_close(back.beta, 0.70e-9));
    }

    #[test]
    fn lookup_snaps_in_log_space() {
        let t = toy_table();
        // 64B and below → the 64B row; 100KB is log-nearer 4KB than 4MB
        assert_eq!(t.lookup(4, 4), Some(AlgoKind::RecursiveDoubling));
        assert_eq!(t.lookup(4, 100_000), Some(AlgoKind::NonPipelined));
        assert_eq!(t.lookup(4, 100_000_000), Some(AlgoKind::NonPipelined));
        // off-grid p: no guess
        assert_eq!(t.lookup(5, 64), None);
    }

    #[test]
    fn embedded_table_is_valid_and_full() {
        let t = embedded().expect("committed TUNE_table.json must parse");
        assert_eq!(t.version, TABLE_VERSION);
        assert_eq!(t.entries.len(), grid_p().len() * GRID_M_BYTES.len());
        assert!(t.link_matches(LinkCost::new(1.0e-6, 0.70e-9)));
        // regime structure the sweep must reproduce: latency-dominated
        // small messages go to recursive doubling, bandwidth-dominated
        // large ones to the circulant non-pipelined optimum
        for &p in &grid_p() {
            assert_eq!(t.lookup(p, 64), Some(AlgoKind::RecursiveDoubling), "p={p}");
            assert_eq!(t.lookup(p, 4_194_304), Some(AlgoKind::NonPipelined), "p={p}");
        }
    }

    #[test]
    fn auto_pick_degenerate_and_fallback() {
        assert_eq!(auto_pick(1, 1024, &CostModel::hydra_uniform()), AlgoKind::Dpdr);
        // hierarchical model: table does not apply, analytic argmin must
        // still return a real (non-Auto) candidate
        let hier = CostModel::hydra_hier();
        let pick = auto_pick(8, 1024, &hier);
        assert!(CANDIDATES.contains(&pick));
        let ordered = auto_pick_ordered(8, 1024, &hier);
        assert!(ORDERED_CANDIDATES.contains(&ordered));
        assert!(ordered.order_preserving());
    }

    #[test]
    fn auto_pick_uses_table_on_hydra() {
        let model = CostModel::hydra_uniform();
        assert_eq!(auto_pick(8, 64, &model), AlgoKind::RecursiveDoubling);
        assert_eq!(auto_pick(8, 4_194_304, &model), AlgoKind::NonPipelined);
    }

    #[test]
    fn generate_matches_embedded_smoke() {
        // a 1-point re-sweep equals the committed decision (the full
        // `tune --check` runs the whole grid in CI)
        let t = embedded().unwrap();
        let timing = Timing::hydra();
        let spec = RunSpec::new(4, 1).phantom(true).sched(SchedKind::Lemma);
        let mut best: Option<(AlgoKind, f64)> = None;
        for &algo in &CANDIDATES {
            let tm = crate::harness::measure(algo, &spec, timing, 1).unwrap().time_us;
            match best {
                Some((_, bt)) if tm >= bt - 1e-3 => {}
                _ => best = Some((algo, tm)),
            }
        }
        assert_eq!(t.lookup(4, 4), Some(best.unwrap().0));
    }
}
