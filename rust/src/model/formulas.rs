//! Closed-form predicted running times (§1.2 of the paper) for every
//! algorithm we implement; the A1/A2 benches compare these against the
//! virtual-clock measurements.

use super::{lemma, paper_h, CostModel, LinkCost};
use crate::util::log2_ceil;

/// The algorithms of the evaluation (plus extensions).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum AlgoKind {
    /// Doubly-pipelined, dual-root reduction-to-all (User-Allreduce2).
    Dpdr,
    /// §1.2 variant: doubly-pipelined on a SINGLE tree (no dual root).
    DpdrSingle,
    /// Pipelined reduce + pipelined bcast on a single binary tree
    /// (User-Allreduce1).
    PipeTree,
    /// Non-pipelined binomial `MPI_Reduce` + `MPI_Bcast`.
    ReduceBcast,
    /// "Native" vendor-style allreduce (count-based algorithm switching).
    NativeSwitch,
    /// Two-tree allreduce (Sanders/Speck/Träff [4]), the 2βm reference.
    TwoTree,
    /// Ring (reduce-scatter + allgather around a ring).
    Ring,
    /// Recursive doubling.
    RecursiveDoubling,
    /// Reduce-scatter (halving) + allgather (doubling), Rabenseifner.
    Rabenseifner,
    /// Node-aware hierarchical allreduce (intra-node reduce-scatter, dpdr
    /// across nodes per segment, intra-node allgather) — see
    /// `collectives::hierarchical`.
    Hier,
    /// Pipelined inclusive prefix scan (`MPI_Scan`, Sanders/Träff [5]) —
    /// see `collectives::scan_dp`. Not a reduction-to-all: rank `r` ends
    /// with `x_0 ⊙ … ⊙ x_r`, so oracles are per rank.
    Scan,
    /// Träff-2024 optimal non-pipelined reduce-scatter + allgather over
    /// circulant graphs (any p, no power-of-two fold) — see
    /// `collectives::nonpipelined`.
    NonPipelined,
    /// Autotuned: resolve to the predicted-fastest concrete algorithm for
    /// the run's (p, m, network) at dispatch time, via the decision table
    /// in `model::tuner` (model-predicted fallback off-table). Dispatch it
    /// through `allreduce_on` — resolution needs the run's timing.
    Auto,
}

impl AlgoKind {
    pub fn parse(s: &str) -> Option<AlgoKind> {
        Some(match s {
            "dpdr" => AlgoKind::Dpdr,
            "dpsingle" => AlgoKind::DpdrSingle,
            "pipetree" => AlgoKind::PipeTree,
            "redbcast" => AlgoKind::ReduceBcast,
            "native" => AlgoKind::NativeSwitch,
            "twotree" => AlgoKind::TwoTree,
            "ring" => AlgoKind::Ring,
            "rd" => AlgoKind::RecursiveDoubling,
            "rab" => AlgoKind::Rabenseifner,
            "hier" => AlgoKind::Hier,
            "scan" => AlgoKind::Scan,
            "nonpipelined" => AlgoKind::NonPipelined,
            "auto" => AlgoKind::Auto,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            AlgoKind::Dpdr => "dpdr",
            AlgoKind::DpdrSingle => "dpsingle",
            AlgoKind::PipeTree => "pipetree",
            AlgoKind::ReduceBcast => "redbcast",
            AlgoKind::NativeSwitch => "native",
            AlgoKind::TwoTree => "twotree",
            AlgoKind::Ring => "ring",
            AlgoKind::RecursiveDoubling => "rd",
            AlgoKind::Rabenseifner => "rab",
            AlgoKind::Hier => "hier",
            AlgoKind::Scan => "scan",
            AlgoKind::NonPipelined => "nonpipelined",
            AlgoKind::Auto => "auto",
        }
    }

    /// Table-2 style column label.
    pub fn label(self) -> &'static str {
        match self {
            AlgoKind::Dpdr => "Doubly pipelined",
            AlgoKind::DpdrSingle => "Doubly pipelined (1 tree)",
            AlgoKind::PipeTree => "Pipelined",
            AlgoKind::ReduceBcast => "MPI_Reduce+MPI_Bcast",
            AlgoKind::NativeSwitch => "MPI_Allreduce",
            AlgoKind::TwoTree => "Two-tree",
            AlgoKind::Ring => "Ring",
            AlgoKind::RecursiveDoubling => "Recursive doubling",
            AlgoKind::Rabenseifner => "Rabenseifner",
            AlgoKind::Hier => "Hierarchical (node-aware)",
            AlgoKind::Scan => "Prefix scan (pipelined)",
            AlgoKind::NonPipelined => "Non-pipelined RS+AG (Träff 2024)",
            AlgoKind::Auto => "Autotuned",
        }
    }

    /// True if the algorithm preserves rank order (safe for non-commutative
    /// operators). Ring's reduce-scatter rotates the product, so it is
    /// commutative-only, matching MPI library practice; the hierarchical
    /// allreduce preserves order only under contiguous (Block) node
    /// layouts, so it is conservatively commutative-only too. The circulant
    /// non-pipelined reduce-scatter also accumulates in rotated order.
    /// `Auto` may resolve to any candidate, so it is conservatively
    /// commutative-only (`tuner::auto_pick_ordered` restricts the pool
    /// when order matters). The prefix scan combines strictly in rank
    /// order by construction.
    pub fn order_preserving(self) -> bool {
        !matches!(
            self,
            AlgoKind::Ring | AlgoKind::Hier | AlgoKind::NonPipelined | AlgoKind::Auto
        )
    }

    /// The `(A, C)` step structure `A + C·b` of the pipelined algorithms
    /// (`None` for the non-pipelined ones). From §1.2:
    /// dpdr: `4h − 3 + 3(b − 1) = (4h − 6) + 3b`;
    /// pipetree: `2(2h + 2(b − 1)) = (4h − 4) + 4b`;
    /// twotree (both halves streaming): `≈ (4h) + 2b`;
    /// scan (coarse): up and down phases of ≤ 3 steps per block each over
    /// ~h tree levels → `≈ (6h − 6) + 6b` (block-choice estimate only —
    /// the scan is an extension, not part of the paper's evaluation).
    pub fn step_structure(self, p: usize) -> Option<(f64, f64)> {
        let h = paper_h(p) as f64;
        match self {
            AlgoKind::Dpdr => Some((4.0 * h - 6.0, 3.0)),
            // single tree over p ranks: height one more than the dual-root
            // halves, no dual exchange: ~4(h−1) fixed steps (paper: "slightly
            // higher by a small constant term")
            AlgoKind::DpdrSingle => Some((4.0 * h - 4.0, 3.0)),
            AlgoKind::PipeTree => Some((4.0 * h - 4.0, 4.0)),
            AlgoKind::TwoTree => Some((4.0 * h, 2.0)),
            AlgoKind::Scan => Some((6.0 * h - 6.0, 6.0)),
            _ => None,
        }
    }
}

/// Predicted time in **microseconds** for `m_bytes` payload over `p` ranks
/// with `b` pipeline blocks (ignored by non-pipelined algorithms), under
/// uniform link cost `link`.
pub fn predicted_time_us(
    algo: AlgoKind,
    p: usize,
    m_bytes: usize,
    b: usize,
    link: LinkCost,
) -> f64 {
    if p <= 1 {
        return 0.0;
    }
    let (alpha, beta) = (link.alpha, link.beta);
    let m = m_bytes as f64;
    let logp = log2_ceil(p) as f64;
    let b = b.max(1) as f64;
    let secs = match algo {
        AlgoKind::Dpdr
        | AlgoKind::DpdrSingle
        | AlgoKind::PipeTree
        | AlgoKind::TwoTree
        | AlgoKind::Scan => {
            let (a, c) = algo.step_structure(p).unwrap();
            lemma::time_at(a, c, alpha, beta, m, b)
        }
        AlgoKind::ReduceBcast => 2.0 * logp * (alpha + beta * m),
        AlgoKind::NonPipelined => {
            return predicted_time_us_nonpipelined(p, m_bytes, link);
        }
        AlgoKind::Auto => {
            // the oracle's model-side prediction: the best candidate's time
            return super::tuner::CANDIDATES
                .iter()
                .map(|&a| predicted_time_us(a, p, m_bytes, b as usize, link))
                .fold(f64::INFINITY, f64::min);
        }
        AlgoKind::RecursiveDoubling => logp * (alpha + beta * m),
        AlgoKind::Ring => {
            let pf = p as f64;
            2.0 * (pf - 1.0) * alpha + 2.0 * ((pf - 1.0) / pf) * beta * m
        }
        AlgoKind::Rabenseifner => {
            let pf = p as f64;
            2.0 * logp * alpha + 2.0 * ((pf - 1.0) / pf) * beta * m
        }
        AlgoKind::NativeSwitch => {
            // the switcher's branches (see collectives::native_switch)
            let branch = if m_bytes <= 8 * 1024 {
                AlgoKind::RecursiveDoubling
            } else {
                AlgoKind::Ring
            };
            return predicted_time_us(branch, p, m_bytes, 1, link);
        }
        AlgoKind::Hier => {
            // uniform-link degenerate case of the two-level form, at the
            // paper's default 8 ranks per node
            return predicted_time_us_hier(p, 8, m_bytes, b as usize, link, link);
        }
    };
    secs * 1e6
}

/// Predicted time in **microseconds** for the Träff-2024 optimal
/// non-pipelined allreduce: `q = ⌈log₂ p⌉` circulant rounds per phase,
/// bandwidth-optimal volume for **any** p (no power-of-two fold):
///
/// ```text
/// T_np = 2⌈log₂ p⌉·α + 2·((p−1)/p)·β·m
/// ```
///
/// Identical to Rabenseifner's closed form at powers of two, strictly
/// better where recursive halving would pay the ragged-p fold.
pub fn predicted_time_us_nonpipelined(p: usize, m_bytes: usize, link: LinkCost) -> f64 {
    if p <= 1 {
        return 0.0;
    }
    let pf = p as f64;
    let logp = log2_ceil(p) as f64;
    let secs =
        2.0 * logp * link.alpha + 2.0 * ((pf - 1.0) / pf) * link.beta * m_bytes as f64;
    secs * 1e6
}

/// Predicted time in **microseconds** for the node-aware hierarchical
/// allreduce over `p` ranks in nodes of `ppn`, with two-level link costs:
/// intra-node reduce-scatter + allgather (`2·log2(ppn)` steps, `≈ 2·β·m`
/// bytes on intra links) around a dpdr across the `⌈p/ppn⌉` nodes on
/// `m/ppn`-byte segments over inter links — the `3βm/ppn` inter β-term
/// that makes node-aware decomposition win the bandwidth regime.
pub fn predicted_time_us_hier(
    p: usize,
    ppn: usize,
    m_bytes: usize,
    b: usize,
    intra: LinkCost,
    inter: LinkCost,
) -> f64 {
    if p <= 1 {
        return 0.0;
    }
    let ppn = ppn.clamp(1, p);
    let nodes = p.div_ceil(ppn);
    if nodes <= 1 {
        return predicted_time_us(AlgoKind::Dpdr, p, m_bytes, b, intra);
    }
    let m = m_bytes as f64;
    let k = ppn as f64;
    let logk = log2_ceil(ppn) as f64;
    // intra: halving reduce-scatter + doubling allgather, m(1−1/k) each way
    let intra_secs = 2.0 * (logk * intra.alpha + intra.beta * m * (1.0 - 1.0 / k));
    // inter: dpdr over the node count on an m/k segment
    let cross_us = predicted_time_us(
        AlgoKind::Dpdr,
        nodes,
        (m_bytes as f64 / k).ceil() as usize,
        b.max(1),
        inter,
    );
    intra_secs * 1e6 + cross_us
}

/// Estimated inter-node bytes the *busiest* node injects per direction,
/// as a multiple of the per-rank payload `m` — the numerator of the NIC
/// serialization floor. Rough, structure-derived constants (validated
/// against `benches/congestion_ablation.rs`):
///
/// * flat pipelined trees (dpdr, dpsingle, pipetree, twotree): the node
///   hosting the top of the post-order tree terminates several large
///   subtrees' cross-node edges, each carrying the full `m` up and the
///   full result down → `≈ 4m`;
/// * the node-aware hierarchical algorithm: `k` segment-dpdr's at `m/k`
///   each, with the node's ranks in an inner tree position → `≈ 3m`;
/// * ring with a block mapping: one boundary edge per direction → `≈ 2m`.
///
/// `None` when we have no estimate (the caller falls back to the
/// dedicated prediction).
fn inter_streams_per_node(algo: AlgoKind) -> Option<f64> {
    match algo {
        AlgoKind::Dpdr | AlgoKind::DpdrSingle | AlgoKind::PipeTree | AlgoKind::TwoTree => {
            Some(4.0)
        }
        AlgoKind::Hier => Some(3.0),
        AlgoKind::Ring => Some(2.0),
        _ => None,
    }
}

/// Predicted time in **microseconds** under a (possibly congestion-aware)
/// cost model: the dedicated-link closed form of the underlying two-level
/// model, floored by the busiest node's NIC serialization bound —
/// `streams · β_inter · m / ports` for the algorithm's estimated per-node
/// inter-node byte volume (see [`inter_streams_per_node`]). With
/// unlimited ports (or for algorithms without an estimate) this *is* the
/// dedicated prediction. Bounded edge capacities are not modelled here:
/// backpressure shifts *when* bytes move, not how many cross the NIC.
pub fn predicted_time_us_net(
    algo: AlgoKind,
    p: usize,
    m_bytes: usize,
    b: usize,
    model: &CostModel,
) -> f64 {
    if p <= 1 {
        return 0.0;
    }
    let (intra, inter) = model.link_levels();
    let ppn = model
        .mapping()
        .map(|mp| mp.shards(p).iter().map(Vec::len).max().unwrap_or(p))
        .unwrap_or(p);
    let base = match algo {
        AlgoKind::Hier => predicted_time_us_hier(p, ppn, m_bytes, b, intra, inter),
        _ => predicted_time_us(algo, p, m_bytes, b, inter),
    };
    let ports = model.net_params().ports_per_node;
    if ports == 0 {
        return base;
    }
    match inter_streams_per_node(algo) {
        Some(streams) => {
            let floor_us = streams * inter.beta * m_bytes as f64 / ports as f64 * 1e6;
            base.max(floor_us)
        }
        None => base,
    }
}

/// Predicted time in **microseconds** for one *fused* small-message
/// allreduce: `n` pending operations of `Σ = total_bytes` combined bytes
/// coalesced into a single doubly-pipelined dpdr at the Pipelining-Lemma
/// optimal block count — the whole point of fusion is that the α-chain
/// `(4h − 6)α` is paid **once** for the batch instead of once per
/// operation, while the β-term is the same `3β·Σm` either way:
///
/// ```text
/// T_fused(Σ) ≈ (4h − 6)α + 3βΣ + 2√(3(4h − 6)αβΣ)
/// ```
pub fn predicted_time_us_fused(p: usize, total_bytes: usize, link: LinkCost) -> f64 {
    if p <= 1 {
        return 0.0;
    }
    let (a, c) = AlgoKind::Dpdr
        .step_structure(p)
        .expect("dpdr is pipelined");
    let (_b, secs) = lemma::optimal_time(
        a,
        c,
        link.alpha,
        link.beta,
        total_bytes as f64,
        usize::MAX,
    );
    secs * 1e6
}

/// Predicted speedup of fusing `n_ops` same-sized small allreduces
/// (`m_bytes` each) over running them back to back, both at their
/// respective lemma-optimal block counts. Tends to `n_ops` as
/// `m_bytes → 0` (pure α-amortization) and to 1 as `m_bytes → ∞` (the
/// β-term dominates and is conserved by fusion).
pub fn predicted_fusion_speedup(p: usize, m_bytes: usize, n_ops: usize, link: LinkCost) -> f64 {
    if p <= 1 || n_ops == 0 {
        return 1.0;
    }
    let sequential = n_ops as f64 * predicted_time_us_fused(p, m_bytes, link);
    let fused = predicted_time_us_fused(p, m_bytes * n_ops, link);
    if fused <= 0.0 {
        return 1.0;
    }
    sequential / fused
}

#[cfg(test)]
mod tests {
    use super::*;

    const LINK: LinkCost = LinkCost {
        alpha: 1.0e-6,
        beta: 0.7e-9,
    };

    #[test]
    fn dpdr_beats_pipetree_at_large_m() {
        // β-term: 3βm vs 4βm — at the per-algorithm optimal b the ratio
        // tends to 4/3 (paper §1.2).
        let p = 286; // 2^h − 2 shape near the paper's 288
        let m = 400_000_000; // large
        let (a1, c1) = AlgoKind::Dpdr.step_structure(p).unwrap();
        let (a2, c2) = AlgoKind::PipeTree.step_structure(p).unwrap();
        let (_b1, t1) = lemma::optimal_time(a1, c1, LINK.alpha, LINK.beta, m as f64, usize::MAX);
        let (_b2, t2) = lemma::optimal_time(a2, c2, LINK.alpha, LINK.beta, m as f64, usize::MAX);
        let ratio = t2 / t1;
        assert!(ratio > 1.25 && ratio < 4.0 / 3.0 + 0.01, "ratio={ratio}");
    }

    #[test]
    fn redbcast_worst_at_large_m() {
        let p = 288;
        let m = 33_554_432; // 8.4M ints
        let t_rb = predicted_time_us(AlgoKind::ReduceBcast, p, m, 1, LINK);
        let t_dp = predicted_time_us(AlgoKind::Dpdr, p, m, 2048, LINK);
        assert!(t_rb > 2.0 * t_dp, "rb={t_rb} dp={t_dp}");
    }

    #[test]
    fn zero_and_tiny() {
        assert_eq!(predicted_time_us(AlgoKind::Dpdr, 1, 123, 4, LINK), 0.0);
        let t = predicted_time_us(AlgoKind::Dpdr, 288, 4, 1, LINK);
        assert!(t > 0.0 && t < 100.0);
    }

    #[test]
    fn parse_names() {
        for a in [
            AlgoKind::Dpdr,
            AlgoKind::DpdrSingle,
            AlgoKind::PipeTree,
            AlgoKind::ReduceBcast,
            AlgoKind::NativeSwitch,
            AlgoKind::TwoTree,
            AlgoKind::Ring,
            AlgoKind::RecursiveDoubling,
            AlgoKind::Rabenseifner,
            AlgoKind::Hier,
            AlgoKind::Scan,
            AlgoKind::NonPipelined,
            AlgoKind::Auto,
        ] {
            assert_eq!(AlgoKind::parse(a.name()), Some(a));
        }
        assert_eq!(AlgoKind::parse("nope"), None);
    }

    #[test]
    fn fused_prediction_amortizes_alpha() {
        let p = 288;
        // tiny per-op payloads: fusing k ops approaches a k× win
        let s = predicted_fusion_speedup(p, 64, 8, LINK);
        assert!(s > 5.0 && s <= 8.0, "s={s}");
        // huge payloads: β dominates, fusion is a wash
        let s = predicted_fusion_speedup(p, 40_000_000, 8, LINK);
        assert!(s > 0.9 && s < 1.2, "s={s}");
        // monotone in op count for small payloads
        let s2 = predicted_fusion_speedup(p, 1024, 2, LINK);
        let s8 = predicted_fusion_speedup(p, 1024, 8, LINK);
        assert!(s8 > s2, "s2={s2} s8={s8}");
        // degenerate cases
        assert_eq!(predicted_fusion_speedup(1, 64, 8, LINK), 1.0);
        assert_eq!(predicted_fusion_speedup(p, 64, 0, LINK), 1.0);
        assert_eq!(predicted_time_us_fused(1, 64, LINK), 0.0);
        // the fused form is exactly the dpdr lemma optimum on Σm
        let t = predicted_time_us_fused(288, 8 * 1024, LINK);
        assert!(t > 0.0 && t.is_finite());
    }

    #[test]
    fn scan_prediction_reasonable() {
        // the scan estimate behaves like a pipelined tree: more expensive
        // than dpdr (more steps per block), finite, monotone in m
        let t_scan = predicted_time_us(AlgoKind::Scan, 288, 4_000_000, 64, LINK);
        let t_dpdr = predicted_time_us(AlgoKind::Dpdr, 288, 4_000_000, 64, LINK);
        assert!(t_scan > t_dpdr, "scan={t_scan} dpdr={t_dpdr}");
        assert!(t_scan < 100.0 * t_dpdr);
        assert_eq!(predicted_time_us(AlgoKind::Scan, 1, 100, 4, LINK), 0.0);
    }

    #[test]
    fn hier_two_level_beats_flat_dpdr_beta_term() {
        // β_intra ≪ β_inter, large m: the 3βm/ppn inter term must beat
        // flat dpdr's 3βm by roughly the node width
        let intra = LinkCost::new(0.3e-6, 0.08e-9);
        let inter = LinkCost::new(1.0e-6, 0.70e-9);
        let m = 40_000_000;
        let t_hier = predicted_time_us_hier(1152, 32, m, 64, intra, inter);
        let t_flat = predicted_time_us(AlgoKind::Dpdr, 1152, m, 64, inter);
        assert!(t_hier < t_flat / 2.0, "hier={t_hier} flat={t_flat}");
        // degenerate cases stay sane
        assert_eq!(predicted_time_us_hier(1, 8, m, 4, intra, inter), 0.0);
        assert!(predicted_time_us_hier(8, 8, m, 4, intra, inter) > 0.0);
    }

    #[test]
    fn predicted_net_floors_flat_but_spares_hier() {
        use crate::model::NetParams;
        use crate::topo::Mapping;
        let intra = LinkCost::new(0.3e-6, 0.08e-9);
        let inter = LinkCost::new(1.0e-6, 0.70e-9);
        let mapping = Mapping::Block { ranks_per_node: 32 };
        let model = |ports: usize| CostModel::Congested {
            intra,
            inter,
            mapping,
            net: NetParams::ports(ports),
        };
        let dedicated = CostModel::Hierarchical {
            intra,
            inter,
            mapping,
        };
        let (p, m, b) = (1152usize, 10_000_000usize, 157usize);
        let base_flat = predicted_time_us_net(AlgoKind::Dpdr, p, m, b, &dedicated);
        // unlimited ports: identical to the dedicated prediction
        assert_eq!(
            predicted_time_us_net(AlgoKind::Dpdr, p, m, b, &model(0)),
            base_flat
        );
        // one port: the 4βm floor binds for the flat tree
        let flat_1 = predicted_time_us_net(AlgoKind::Dpdr, p, m, b, &model(1));
        assert!(flat_1 > base_flat, "{flat_1} vs base {base_flat}");
        assert!((flat_1 - 4.0 * inter.beta * m as f64 * 1e6).abs() < 1e-6);
        // hier's floor (3βm) is lower than flat's, and the prediction is
        // monotone in the port count
        let hier_1 = predicted_time_us_net(AlgoKind::Hier, p, m, b, &model(1));
        assert!(hier_1 < flat_1);
        let flat_4 = predicted_time_us_net(AlgoKind::Dpdr, p, m, b, &model(4));
        assert!(flat_4 <= flat_1);
        // algorithms without a stream estimate fall back to the dedicated form
        let rb_1 = predicted_time_us_net(AlgoKind::ReduceBcast, p, m, b, &model(1));
        let rb_0 = predicted_time_us_net(AlgoKind::ReduceBcast, p, m, b, &model(0));
        assert_eq!(rb_1, rb_0);
        // degenerate world
        assert_eq!(predicted_time_us_net(AlgoKind::Dpdr, 1, m, b, &model(1)), 0.0);
    }

    #[test]
    fn nonpipelined_prediction_and_auto_lower_bound() {
        // closed form at p = 10: q = 4 rounds per phase
        let t = predicted_time_us(AlgoKind::NonPipelined, 10, 4096, 1, LINK);
        let expect = (2.0 * 4.0 * LINK.alpha + 2.0 * 0.9 * LINK.beta * 4096.0) * 1e6;
        assert!((t - expect).abs() < 1e-9, "t={t} expect={expect}");
        // no ragged-p fold: ≤ ring's latency everywhere, ≥ 0
        let t_ring = predicted_time_us(AlgoKind::Ring, 10, 4096, 1, LINK);
        assert!(t > 0.0 && t < t_ring);
        // Auto's model prediction is the min over candidates — never above
        // any single candidate, zero on the degenerate world
        let ta = predicted_time_us(AlgoKind::Auto, 10, 4096, 1, LINK);
        assert!(ta > 0.0 && ta <= t + 1e-12, "ta={ta} t={t}");
        assert_eq!(predicted_time_us(AlgoKind::Auto, 1, 4096, 1, LINK), 0.0);
        assert_eq!(predicted_time_us_nonpipelined(1, 4096, LINK), 0.0);
    }

    #[test]
    fn ring_bandwidth_optimal_beta_term() {
        let p = 64;
        let m = 100_000_000;
        let t_ring = predicted_time_us(AlgoKind::Ring, p, m, 1, LINK);
        // β-term ≈ 2βm(p−1)/p < 3βm: ring wins on pure bandwidth at huge m
        let t_dp = predicted_time_us(AlgoKind::Dpdr, p, m, 8192, LINK);
        assert!(t_ring < t_dp);
    }
}
