//! The linear (α-β-γ) communication cost model the paper analyses, its
//! hierarchical (intra-/inter-node) extension, the closed-form running-time
//! formulas of §1.2, and the "Pipelining Lemma" block-count optimizer.

pub mod formulas;
pub mod lemma;

pub use formulas::{predicted_time_us, predicted_time_us_hier, AlgoKind};
pub use lemma::{optimal_block_count, optimal_time};

use crate::topo::{node_of, Mapping};

/// Cost of one link direction: `α + β · bytes` seconds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkCost {
    /// Start-up latency in seconds.
    pub alpha: f64,
    /// Per-byte transfer time in seconds.
    pub beta: f64,
}

impl LinkCost {
    pub fn new(alpha: f64, beta: f64) -> LinkCost {
        LinkCost { alpha, beta }
    }

    /// Time to move `bytes` over this link (bidirectional exchanges use the
    /// max of the two payload sizes — telephone model).
    pub fn xfer(&self, bytes: usize) -> f64 {
        self.alpha + self.beta * bytes as f64
    }
}

/// Per-element-wise-reduction compute cost: `γ · bytes` seconds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ComputeCost {
    pub gamma: f64,
}

impl ComputeCost {
    pub fn new(gamma: f64) -> ComputeCost {
        ComputeCost { gamma }
    }

    pub fn reduce(&self, bytes: usize) -> f64 {
        self.gamma * bytes as f64
    }
}

/// The machine model used by the virtual clock.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CostModel {
    /// Uniform links, the model of the paper's analysis.
    Uniform(LinkCost),
    /// Clustered machine: cheap intra-node links, expensive inter-node ones.
    /// Which is which follows from the rank→node `mapping`.
    Hierarchical {
        intra: LinkCost,
        inter: LinkCost,
        mapping: Mapping,
    },
}

impl CostModel {
    /// Our simulated "Hydra" defaults, calibrated so the α term (the paper's
    /// small-count rows, tens of µs at p=288) and the β term (the large-count
    /// rows, ~73 ms for the doubly-pipelined algorithm at 8.4M ints) land in
    /// the paper's range. See EXPERIMENTS.md §Calibration.
    pub fn hydra_uniform() -> CostModel {
        CostModel::Uniform(LinkCost::new(1.0e-6, 0.70e-9))
    }

    /// Hierarchical Hydra: 8 ranks per node share memory (fast links),
    /// inter-node OmniPath links as in [`Self::hydra_uniform`].
    pub fn hydra_hier() -> CostModel {
        CostModel::Hierarchical {
            intra: LinkCost::new(0.3e-6, 0.08e-9),
            inter: LinkCost::new(1.0e-6, 0.70e-9),
            mapping: Mapping::Block { ranks_per_node: 8 },
        }
    }

    /// Hierarchical Hydra at full node width: the paper's machine is 36
    /// nodes × 32 cores, so p = 1152 with 32-rank node groups — the layout
    /// the `hierarchy_ablation` bench and the node-aware `AlgoKind::Hier`
    /// ablations run on.
    pub fn hydra_hier32() -> CostModel {
        CostModel::Hierarchical {
            intra: LinkCost::new(0.3e-6, 0.08e-9),
            inter: LinkCost::new(1.0e-6, 0.70e-9),
            mapping: Mapping::Block { ranks_per_node: 32 },
        }
    }

    /// The rank → node layout, when the model distinguishes one. This is
    /// what `run_world` uses to align the transport's registry/pool shards
    /// with the simulated machine's nodes.
    pub fn mapping(&self) -> Option<Mapping> {
        match *self {
            CostModel::Uniform(_) => None,
            CostModel::Hierarchical { mapping, .. } => Some(mapping),
        }
    }

    /// The two link levels `(intra, inter)` — equal for a uniform model.
    pub fn link_levels(&self) -> (LinkCost, LinkCost) {
        match *self {
            CostModel::Uniform(l) => (l, l),
            CostModel::Hierarchical { intra, inter, .. } => (intra, inter),
        }
    }

    /// The link cost between two ranks.
    pub fn link(&self, a: usize, b: usize) -> LinkCost {
        match *self {
            CostModel::Uniform(l) => l,
            CostModel::Hierarchical {
                intra,
                inter,
                mapping,
            } => {
                if node_of(mapping, a) == node_of(mapping, b) {
                    intra
                } else {
                    inter
                }
            }
        }
    }

    /// Time for an exchange of `bytes` between `a` and `b`.
    pub fn xfer(&self, a: usize, b: usize, bytes: usize) -> f64 {
        self.link(a, b).xfer(bytes)
    }

    /// The uniform link parameters, if uniform.
    pub fn as_uniform(&self) -> Option<LinkCost> {
        match *self {
            CostModel::Uniform(l) => Some(l),
            _ => None,
        }
    }
}

/// The paper's `h`: `p + 2 = 2^h` generalized to arbitrary `p` as
/// `h = ⌈log2(p + 2)⌉`; used by the §1.2 formulas.
pub fn paper_h(p: usize) -> usize {
    crate::util::log2_ceil(p + 2) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_xfer_linear() {
        let l = LinkCost::new(1e-6, 1e-9);
        assert!((l.xfer(0) - 1e-6).abs() < 1e-15);
        assert!((l.xfer(1000) - 2e-6).abs() < 1e-15);
    }

    #[test]
    fn hierarchical_picks_links() {
        let m = CostModel::Hierarchical {
            intra: LinkCost::new(1e-7, 1e-10),
            inter: LinkCost::new(1e-6, 1e-9),
            mapping: Mapping::Block { ranks_per_node: 4 },
        };
        assert_eq!(m.link(0, 3), LinkCost::new(1e-7, 1e-10));
        assert_eq!(m.link(3, 4), LinkCost::new(1e-6, 1e-9));
        assert!(m.as_uniform().is_none());
        assert_eq!(m.mapping(), Some(Mapping::Block { ranks_per_node: 4 }));
        assert_eq!(
            m.link_levels(),
            (LinkCost::new(1e-7, 1e-10), LinkCost::new(1e-6, 1e-9))
        );
        let u = CostModel::hydra_uniform();
        assert_eq!(u.mapping(), None);
        assert_eq!(u.link_levels().0, u.link_levels().1);
        assert_eq!(
            CostModel::hydra_hier32().mapping(),
            Some(Mapping::Block { ranks_per_node: 32 })
        );
    }

    #[test]
    fn paper_h_matches_sweet_spots() {
        // p = 2^h − 2 ⇒ h
        assert_eq!(paper_h(2), 2);
        assert_eq!(paper_h(6), 3);
        assert_eq!(paper_h(14), 4);
        assert_eq!(paper_h(254), 8);
        // general p rounds up
        assert_eq!(paper_h(288), 9);
    }

    #[test]
    fn compute_cost() {
        let c = ComputeCost::new(2e-10);
        assert!((c.reduce(1000) - 2e-7).abs() < 1e-18);
    }
}
