//! The linear (α-β-γ) communication cost model the paper analyses, its
//! hierarchical (intra-/inter-node) extension, the closed-form running-time
//! formulas of §1.2, and the "Pipelining Lemma" block-count optimizer.

pub mod formulas;
pub mod lemma;
pub mod tuner;

pub use formulas::{
    predicted_fusion_speedup, predicted_time_us, predicted_time_us_fused,
    predicted_time_us_hier, predicted_time_us_net, predicted_time_us_nonpipelined, AlgoKind,
};
pub use lemma::{optimal_block_count, optimal_time};
pub use tuner::{auto_pick, auto_pick_ordered, TuneTable};

use crate::topo::{node_of, Mapping};

/// Shared network-resource parameters of the congestion-aware model: how
/// many concurrent inter-node transfers a node's NIC sustains per
/// direction, and how deep each directed edge's virtual injection queue
/// is. `0` always means *unlimited* — the dedicated-link idealization the
/// paper's analysis (and [`CostModel::Uniform`] / [`CostModel::Hierarchical`])
/// assume.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct NetParams {
    /// Concurrent inter-node transfers per node and direction (the egress
    /// and ingress timelines each get this many ports). `0` = a dedicated
    /// port per rank, exactly the paper's model. Intra-node transfers
    /// never touch the NIC.
    pub ports_per_node: usize,
    /// Virtual injection-queue capacity (messages in flight) of intra-node
    /// edges; `0` = unbounded. Posting to a full queue advances the
    /// sender's clock to the time the receiver drained the oldest message.
    pub edge_capacity_intra: usize,
    /// Injection-queue capacity of inter-node edges; `0` = unbounded.
    pub edge_capacity_inter: usize,
}

impl NetParams {
    /// The dedicated-link idealization: unlimited everything (the
    /// congestion layer disengages entirely).
    pub const DEDICATED: NetParams = NetParams {
        ports_per_node: 0,
        edge_capacity_intra: 0,
        edge_capacity_inter: 0,
    };

    pub fn dedicated() -> NetParams {
        NetParams::DEDICATED
    }

    /// `ports_per_node` ports, unbounded edges.
    pub fn ports(ports_per_node: usize) -> NetParams {
        NetParams {
            ports_per_node,
            ..NetParams::DEDICATED
        }
    }

    /// Set both per-level edge capacities.
    pub fn edge_capacity(mut self, cap: usize) -> NetParams {
        self.edge_capacity_intra = cap;
        self.edge_capacity_inter = cap;
        self
    }

    /// True when every resource is unlimited — the fabric then adds no
    /// accounting at all and virtual clocks are the scalar scheme exactly.
    pub fn is_dedicated(&self) -> bool {
        self.ports_per_node == 0
            && self.edge_capacity_intra == 0
            && self.edge_capacity_inter == 0
    }
}

/// Cost of one link direction: `α + β · bytes` seconds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkCost {
    /// Start-up latency in seconds.
    pub alpha: f64,
    /// Per-byte transfer time in seconds.
    pub beta: f64,
}

impl LinkCost {
    pub fn new(alpha: f64, beta: f64) -> LinkCost {
        LinkCost { alpha, beta }
    }

    /// Time to move `bytes` over this link (bidirectional exchanges use the
    /// max of the two payload sizes — telephone model).
    pub fn xfer(&self, bytes: usize) -> f64 {
        self.alpha + self.beta * bytes as f64
    }
}

/// Per-element-wise-reduction compute cost: `γ · bytes` seconds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ComputeCost {
    pub gamma: f64,
}

impl ComputeCost {
    pub fn new(gamma: f64) -> ComputeCost {
        ComputeCost { gamma }
    }

    pub fn reduce(&self, bytes: usize) -> f64 {
        self.gamma * bytes as f64
    }
}

/// The machine model used by the virtual clock.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CostModel {
    /// Uniform links, the model of the paper's analysis.
    Uniform(LinkCost),
    /// Clustered machine: cheap intra-node links, expensive inter-node ones.
    /// Which is which follows from the rank→node `mapping`.
    Hierarchical {
        intra: LinkCost,
        inter: LinkCost,
        mapping: Mapping,
    },
    /// Congestion-aware clustered machine: two-level links as in
    /// [`CostModel::Hierarchical`], plus shared network resources
    /// ([`NetParams`]): every node's inter-node transfers serialize
    /// through `ports_per_node` NIC ports per direction, and each directed
    /// edge has a finite virtual injection queue. With
    /// `NetParams::dedicated()` this is [`CostModel::Hierarchical`]
    /// exactly (bit-identical virtual clocks — pinned by
    /// `tests/congestion.rs`).
    Congested {
        intra: LinkCost,
        inter: LinkCost,
        mapping: Mapping,
        net: NetParams,
    },
}

impl CostModel {
    /// Our simulated "Hydra" defaults, calibrated so the α term (the paper's
    /// small-count rows, tens of µs at p=288) and the β term (the large-count
    /// rows, ~73 ms for the doubly-pipelined algorithm at 8.4M ints) land in
    /// the paper's range. See EXPERIMENTS.md §Calibration.
    pub fn hydra_uniform() -> CostModel {
        CostModel::Uniform(LinkCost::new(1.0e-6, 0.70e-9))
    }

    /// Hierarchical Hydra: 8 ranks per node share memory (fast links),
    /// inter-node OmniPath links as in [`Self::hydra_uniform`].
    pub fn hydra_hier() -> CostModel {
        CostModel::Hierarchical {
            intra: LinkCost::new(0.3e-6, 0.08e-9),
            inter: LinkCost::new(1.0e-6, 0.70e-9),
            mapping: Mapping::Block { ranks_per_node: 8 },
        }
    }

    /// Hierarchical Hydra at full node width: the paper's machine is 36
    /// nodes × 32 cores, so p = 1152 with 32-rank node groups — the layout
    /// the `hierarchy_ablation` bench and the node-aware `AlgoKind::Hier`
    /// ablations run on.
    pub fn hydra_hier32() -> CostModel {
        CostModel::Hierarchical {
            intra: LinkCost::new(0.3e-6, 0.08e-9),
            inter: LinkCost::new(1.0e-6, 0.70e-9),
            mapping: Mapping::Block { ranks_per_node: 32 },
        }
    }

    /// [`Self::hydra_hier32`] with shared network resources: the 36×32
    /// machine where each node's inter-node transfers contend for
    /// `ports_per_node` full-duplex NIC ports and every edge has a finite
    /// injection queue — the setting of the congestion ablation.
    pub fn hydra_congested32(net: NetParams) -> CostModel {
        CostModel::hydra_hier32().with_net(net, Mapping::Block { ranks_per_node: 32 })
    }

    /// The rank → node layout, when the model distinguishes one. This is
    /// what `run_world` uses to align the transport's registry/pool shards
    /// with the simulated machine's nodes.
    pub fn mapping(&self) -> Option<Mapping> {
        match *self {
            CostModel::Uniform(_) => None,
            CostModel::Hierarchical { mapping, .. } => Some(mapping),
            CostModel::Congested { mapping, .. } => Some(mapping),
        }
    }

    /// The two link levels `(intra, inter)` — equal for a uniform model.
    pub fn link_levels(&self) -> (LinkCost, LinkCost) {
        match *self {
            CostModel::Uniform(l) => (l, l),
            CostModel::Hierarchical { intra, inter, .. }
            | CostModel::Congested { intra, inter, .. } => (intra, inter),
        }
    }

    /// The shared-resource parameters — [`NetParams::dedicated`] for the
    /// idealized (non-congested) models.
    pub fn net_params(&self) -> NetParams {
        match *self {
            CostModel::Congested { net, .. } => net,
            _ => NetParams::dedicated(),
        }
    }

    /// Upgrade this model to the congestion-aware form with the given
    /// resource limits. A model without a node layout (uniform links)
    /// takes `default_mapping` — ports need a node concept even when both
    /// link levels are equal. A dedicated `net` is the identity: the
    /// model (and the transport fast path) stay exactly as they are.
    pub fn with_net(self, net: NetParams, default_mapping: Mapping) -> CostModel {
        if net.is_dedicated() {
            return self;
        }
        let (intra, inter) = self.link_levels();
        let mapping = self.mapping().unwrap_or(default_mapping);
        CostModel::Congested {
            intra,
            inter,
            mapping,
            net,
        }
    }

    /// The link cost between two ranks.
    pub fn link(&self, a: usize, b: usize) -> LinkCost {
        match *self {
            CostModel::Uniform(l) => l,
            CostModel::Hierarchical {
                intra,
                inter,
                mapping,
            }
            | CostModel::Congested {
                intra,
                inter,
                mapping,
                ..
            } => {
                if node_of(mapping, a) == node_of(mapping, b) {
                    intra
                } else {
                    inter
                }
            }
        }
    }

    /// Time for an exchange of `bytes` between `a` and `b`.
    pub fn xfer(&self, a: usize, b: usize, bytes: usize) -> f64 {
        self.link(a, b).xfer(bytes)
    }

    /// The uniform link parameters, if uniform.
    pub fn as_uniform(&self) -> Option<LinkCost> {
        match *self {
            CostModel::Uniform(l) => Some(l),
            _ => None,
        }
    }
}

/// The paper's `h`: `p + 2 = 2^h` generalized to arbitrary `p` as
/// `h = ⌈log2(p + 2)⌉`; used by the §1.2 formulas.
pub fn paper_h(p: usize) -> usize {
    crate::util::log2_ceil(p + 2) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_xfer_linear() {
        let l = LinkCost::new(1e-6, 1e-9);
        assert!((l.xfer(0) - 1e-6).abs() < 1e-15);
        assert!((l.xfer(1000) - 2e-6).abs() < 1e-15);
    }

    #[test]
    fn hierarchical_picks_links() {
        let m = CostModel::Hierarchical {
            intra: LinkCost::new(1e-7, 1e-10),
            inter: LinkCost::new(1e-6, 1e-9),
            mapping: Mapping::Block { ranks_per_node: 4 },
        };
        assert_eq!(m.link(0, 3), LinkCost::new(1e-7, 1e-10));
        assert_eq!(m.link(3, 4), LinkCost::new(1e-6, 1e-9));
        assert!(m.as_uniform().is_none());
        assert_eq!(m.mapping(), Some(Mapping::Block { ranks_per_node: 4 }));
        assert_eq!(
            m.link_levels(),
            (LinkCost::new(1e-7, 1e-10), LinkCost::new(1e-6, 1e-9))
        );
        let u = CostModel::hydra_uniform();
        assert_eq!(u.mapping(), None);
        assert_eq!(u.link_levels().0, u.link_levels().1);
        assert_eq!(
            CostModel::hydra_hier32().mapping(),
            Some(Mapping::Block { ranks_per_node: 32 })
        );
    }

    #[test]
    fn paper_h_matches_sweet_spots() {
        // p = 2^h − 2 ⇒ h
        assert_eq!(paper_h(2), 2);
        assert_eq!(paper_h(6), 3);
        assert_eq!(paper_h(14), 4);
        assert_eq!(paper_h(254), 8);
        // general p rounds up
        assert_eq!(paper_h(288), 9);
    }

    #[test]
    fn compute_cost() {
        let c = ComputeCost::new(2e-10);
        assert!((c.reduce(1000) - 2e-7).abs() < 1e-18);
    }

    #[test]
    fn net_params_dedicated_and_builders() {
        assert!(NetParams::dedicated().is_dedicated());
        assert!(NetParams::default().is_dedicated());
        let n = NetParams::ports(2);
        assert!(!n.is_dedicated());
        assert_eq!(n.edge_capacity_inter, 0);
        let n = NetParams::dedicated().edge_capacity(3);
        assert!(!n.is_dedicated());
        assert_eq!(n.edge_capacity_intra, 3);
        assert_eq!(n.edge_capacity_inter, 3);
        assert_eq!(n.ports_per_node, 0);
    }

    #[test]
    fn congested_model_accessors() {
        let mapping = Mapping::Block { ranks_per_node: 4 };
        let net = NetParams::ports(1).edge_capacity(2);
        let intra = LinkCost::new(1e-7, 1e-10);
        let inter = LinkCost::new(1e-6, 1e-9);
        let m = CostModel::Congested {
            intra,
            inter,
            mapping,
            net,
        };
        assert_eq!(m.mapping(), Some(mapping));
        assert_eq!(m.link_levels(), (intra, inter));
        assert_eq!(m.link(0, 3), intra);
        assert_eq!(m.link(3, 4), inter);
        assert_eq!(m.net_params(), net);
        assert!(m.as_uniform().is_none());
        // the idealized models report dedicated resources
        assert!(CostModel::hydra_uniform().net_params().is_dedicated());
        assert!(CostModel::hydra_hier32().net_params().is_dedicated());
    }

    #[test]
    fn with_net_upgrades_and_is_identity_when_dedicated() {
        let mapping = Mapping::Block { ranks_per_node: 8 };
        let u = CostModel::hydra_uniform();
        // dedicated net: identity, the fast path stays engaged
        assert_eq!(u.with_net(NetParams::dedicated(), mapping), u);
        // non-dedicated: uniform links become a two-equal-level congested
        // model over the default mapping
        let net = NetParams::ports(2);
        let c = u.with_net(net, mapping);
        assert_eq!(c.net_params(), net);
        assert_eq!(c.mapping(), Some(mapping));
        let (intra, inter) = c.link_levels();
        assert_eq!(intra, inter);
        assert_eq!(Some(inter), u.as_uniform());
        // a hierarchical model keeps its own mapping, not the default
        let h = CostModel::hydra_hier32().with_net(net, mapping);
        assert_eq!(h.mapping(), Some(Mapping::Block { ranks_per_node: 32 }));
        // re-upgrading replaces the net params
        let c2 = c.with_net(NetParams::ports(7), mapping);
        assert_eq!(c2.net_params(), NetParams::ports(7));
        // hydra_congested32 carries the 36×32 links + the given net
        let hc = CostModel::hydra_congested32(NetParams::ports(1));
        assert_eq!(hc.net_params(), NetParams::ports(1));
        assert_eq!(hc.link_levels(), CostModel::hydra_hier32().link_levels());
    }
}
