//! The "Pipelining Lemma" (paper §1.2): balancing the block-count terms.
//!
//! A pipelined algorithm that takes `(A + C·b)` communication steps on
//! blocks of `m/b` elements costs
//!
//! ```text
//! T(b) = (A + C·b)(α + β·m/b) = Aα + Cβm + Aβm/b + Cαb
//! ```
//!
//! which is minimized at `b* = sqrt(A·β·m / (C·α))`, giving
//!
//! ```text
//! T(b*) = Aα + Cβm + 2·sqrt(A·C·α·β·m).
//! ```
//!
//! For the doubly-pipelined dual-root algorithm `A = 4h − 6`, `C = 3`
//! (from `4h − 3 + 3(b − 1)`), which is exactly the paper's
//! `(4k−6)α + 2√(3(4k−6)αβm) + 3βm`.

/// The continuous optimum block count `b*` for step structure `A + C·b`
/// over a payload of `m_bytes` bytes. Returns at least 1.
pub fn optimal_block_count(a_steps: f64, c_steps: f64, alpha: f64, beta: f64, m_bytes: f64) -> f64 {
    if m_bytes <= 0.0 || alpha <= 0.0 {
        return 1.0;
    }
    let b = (a_steps * beta * m_bytes / (c_steps * alpha)).sqrt();
    b.max(1.0)
}

/// `T(b)` for step structure `A + C·b` (seconds).
pub fn time_at(a_steps: f64, c_steps: f64, alpha: f64, beta: f64, m_bytes: f64, b: f64) -> f64 {
    (a_steps + c_steps * b) * (alpha + beta * m_bytes / b)
}

/// The optimal time `T(b*)`, with `b*` clamped to `[1, m_elems]` and rounded
/// to the better of the two neighbouring integers (blocks are integral).
pub fn optimal_time(
    a_steps: f64,
    c_steps: f64,
    alpha: f64,
    beta: f64,
    m_bytes: f64,
    max_blocks: usize,
) -> (usize, f64) {
    let b_star = optimal_block_count(a_steps, c_steps, alpha, beta, m_bytes)
        .min(max_blocks.max(1) as f64);
    let lo = b_star.floor().max(1.0);
    let hi = b_star.ceil().min(max_blocks.max(1) as f64).max(1.0);
    let t_lo = time_at(a_steps, c_steps, alpha, beta, m_bytes, lo);
    let t_hi = time_at(a_steps, c_steps, alpha, beta, m_bytes, hi);
    if t_lo <= t_hi {
        (lo as usize, t_lo)
    } else {
        (hi as usize, t_hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn optimum_is_a_minimum() {
        let (a, c, al, be, m) = (30.0, 3.0, 1e-6, 1e-9, 4e7);
        let b = optimal_block_count(a, c, al, be, m);
        let t = time_at(a, c, al, be, m, b);
        for factor in [0.5, 0.8, 1.25, 2.0] {
            assert!(time_at(a, c, al, be, m, b * factor) >= t - 1e-15);
        }
    }

    #[test]
    fn closed_form_matches_paper_shape() {
        // T(b*) = Aα + Cβm + 2 sqrt(ACαβm)
        let (a, c, al, be, m) = (26.0, 3.0, 2e-6, 0.5e-9, 1e8);
        let b = optimal_block_count(a, c, al, be, m);
        let t = time_at(a, c, al, be, m, b);
        let closed = a * al + c * be * m + 2.0 * (a * c * al * be * m).sqrt();
        assert!((t - closed).abs() / closed < 1e-12);
    }

    #[test]
    fn degenerate_inputs() {
        assert_eq!(optimal_block_count(10.0, 3.0, 1e-6, 1e-9, 0.0), 1.0);
        let (b, _t) = optimal_time(10.0, 3.0, 1e-6, 1e-9, 4.0, 1);
        assert_eq!(b, 1);
    }

    #[test]
    fn integral_rounding_picks_better_neighbor() {
        let (a, c, al, be, m) = (30.0, 3.0, 1e-6, 1e-9, 4e7);
        let (b, t) = optimal_time(a, c, al, be, m, usize::MAX);
        assert!(b >= 1);
        assert!(t <= time_at(a, c, al, be, m, (b + 1) as f64) + 1e-15);
        if b > 1 {
            assert!(t <= time_at(a, c, al, be, m, (b - 1) as f64) + 1e-15);
        }
    }

    #[test]
    fn grows_with_message_size() {
        let b1 = optimal_block_count(30.0, 3.0, 1e-6, 1e-9, 1e6);
        let b2 = optimal_block_count(30.0, 3.0, 1e-6, 1e-9, 1e8);
        assert!(b2 > b1);
        // sqrt scaling
        assert!((b2 / b1 - 10.0).abs() < 1e-9);
    }
}
