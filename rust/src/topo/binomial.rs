//! Binomial trees, the shape behind the non-pipelined `MPI_Reduce` /
//! `MPI_Bcast` baselines (evaluation item 2 in the paper).
//!
//! Ranks are virtualized around `root` (`vrank = (rank − root) mod p`), the
//! standard MPI library trick. With `root = 0` the reduction order is
//! rank-ascending (see `collectives::reduce_bcast`), which the
//! non-commutative tests rely on.

/// A binomial tree over `p` ranks rooted at `root`.
#[derive(Clone, Copy, Debug)]
pub struct BinomialTree {
    pub p: usize,
    pub root: usize,
}

impl BinomialTree {
    pub fn new(p: usize, root: usize) -> BinomialTree {
        debug_assert!(p >= 1 && root < p);
        BinomialTree { p, root }
    }

    #[inline]
    fn vrank(&self, rank: usize) -> usize {
        (rank + self.p - self.root) % self.p
    }

    #[inline]
    fn unvrank(&self, v: usize) -> usize {
        (v + self.root) % self.p
    }

    /// Parent of `rank` (`None` for the root): clear the lowest set bit of
    /// the virtual rank.
    pub fn parent(&self, rank: usize) -> Option<usize> {
        let v = self.vrank(rank);
        if v == 0 {
            return None;
        }
        let lsb = v & v.wrapping_neg();
        Some(self.unvrank(v & !lsb))
    }

    /// Children of `rank`, in *increasing virtual-rank distance* order:
    /// `v + 1, v + 2, v + 4, …` below the next power-of-two boundary.
    pub fn children(&self, rank: usize) -> Vec<usize> {
        let v = self.vrank(rank);
        let mut out = Vec::new();
        let mut bit = 1usize;
        // children are v | bit for bit below v's lowest set bit (root: all bits)
        let limit = if v == 0 { self.p.next_power_of_two() } else { v & v.wrapping_neg() };
        while bit < limit {
            let c = v | bit;
            if c < self.p {
                out.push(self.unvrank(c));
            }
            bit <<= 1;
        }
        out
    }

    /// Number of communication rounds (`⌈log2 p⌉`).
    pub fn rounds(&self) -> usize {
        crate::util::log2_ceil(self.p) as usize
    }

    /// The inclusive virtual-rank interval covered by `rank`'s subtree:
    /// `[v, min(v + lsb(v), p) − 1]` (used by order-preserving reduction).
    pub fn subtree_vrange(&self, rank: usize) -> (usize, usize) {
        let v = self.vrank(rank);
        let span = if v == 0 {
            self.p
        } else {
            v & v.wrapping_neg()
        };
        (v, (v + span).min(self.p) - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn root_has_log_children() {
        let t = BinomialTree::new(8, 0);
        assert_eq!(t.children(0), vec![1, 2, 4]);
        assert_eq!(t.parent(0), None);
        assert_eq!(t.rounds(), 3);
    }

    #[test]
    fn parent_child_symmetry() {
        for p in 1..=40usize {
            for root in [0, p / 2, p - 1] {
                let t = BinomialTree::new(p, root);
                for r in 0..p {
                    for c in t.children(r) {
                        assert_eq!(t.parent(c), Some(r), "p={p} root={root} r={r} c={c}");
                    }
                    if let Some(par) = t.parent(r) {
                        assert!(t.children(par).contains(&r));
                    }
                }
                // exactly p-1 edges
                let edges: usize = (0..p).map(|r| t.children(r).len()).sum();
                assert_eq!(edges, p - 1);
            }
        }
    }

    #[test]
    fn subtree_ranges_partition() {
        let t = BinomialTree::new(13, 0);
        // children of root partition [1, 12]
        let mut covered = vec![false; 13];
        covered[0] = true;
        for c in t.children(0) {
            let (lo, hi) = t.subtree_vrange(c);
            for v in lo..=hi {
                assert!(!covered[v]);
                covered[v] = true;
            }
        }
        assert!(covered.iter().all(|&x| x));
    }

    #[test]
    fn non_zero_root() {
        let t = BinomialTree::new(6, 4);
        assert_eq!(t.parent(4), None);
        // all other ranks reach the root
        for r in 0..6 {
            if r == 4 {
                continue;
            }
            let mut cur = r;
            let mut hops = 0;
            while let Some(p) = t.parent(cur) {
                cur = p;
                hops += 1;
                assert!(hops <= 10);
            }
            assert_eq!(cur, 4);
        }
    }
}
