//! Two-tree topology after Sanders, Speck, Träff [4] ("Two-tree algorithms
//! for full bandwidth broadcast, reduction and scan", ParCo 2009) — the
//! `2βm` comparison point the paper cites in §1.2.
//!
//! Construction over `n = p − 1` ranks (rank `p − 1` is the root driver):
//! both trees are **in-order numbered** (left subtree < node < right
//! subtree, so rank-order reductions need only associativity), but they
//! root their ranges at opposite parities:
//!
//! * **T1** is *odd-rooted*: every interior node sits at an odd index
//!   (ranges are rooted at the odd index nearest their middle; a range
//!   that closes on its root produces a unary interior node);
//! * **T2** is *even-rooted*: every interior node sits at an even index.
//!
//! Interiors are therefore disjoint for **every** `p` — the load-balance
//! behind the `2βm` bandwidth argument, and also what keeps the
//! collective's blocking schedule acyclic (see `collectives::twotree`):
//! two mutually parent/child double-interior ranks would deadlock it.

use crate::error::{Error, Result};

/// One of the two trees.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Half {
    T1,
    T2,
}

/// Per-rank, per-tree role.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct TwoTreeRole {
    /// Parent rank in this tree (the tree root's parent is `p−1`).
    pub parent: usize,
    /// Children in this tree (in-order: lower subtree first).
    pub children: [Option<usize>; 2],
    /// Depth below the root driver (root has depth 1).
    pub depth: usize,
}

/// The two-tree topology over `p ≥ 2` ranks; rank `p−1` is the root driver.
#[derive(Clone, Debug)]
pub struct TwoTree {
    pub p: usize,
    t1: Vec<TwoTreeRole>,
    t2: Vec<TwoTreeRole>,
    root1: usize,
    root2: usize,
}

/// Build an in-order tree over `[lo, hi]` whose interior nodes all have
/// index parity `parity`; returns the root. Single-index ranges become
/// leaves regardless of parity.
fn build_parity(
    lo: usize,
    hi: usize,
    parity: usize,
    depth: usize,
    parent: usize,
    roles: &mut [TwoTreeRole],
) -> usize {
    if lo == hi {
        roles[lo].parent = parent;
        roles[lo].depth = depth;
        return lo;
    }
    let mut mid = (lo + hi) / 2;
    if mid % 2 != parity {
        mid += 1; // ≤ hi because (lo+hi)/2 < hi when lo < hi
    }
    debug_assert!(mid <= hi);
    roles[mid].parent = parent;
    roles[mid].depth = depth;
    if mid > lo {
        let c0 = build_parity(lo, mid - 1, parity, depth + 1, mid, roles);
        roles[mid].children[0] = Some(c0);
    }
    if mid < hi {
        let c1 = build_parity(mid + 1, hi, parity, depth + 1, mid, roles);
        roles[mid].children[1] = Some(c1);
    }
    mid
}

impl TwoTree {
    pub fn new(p: usize) -> Result<TwoTree> {
        if p < 2 {
            return Err(Error::Config(format!("two-tree needs p >= 2, got {p}")));
        }
        let n = p - 1; // ranks in each tree
        let driver = p - 1;
        let blank = TwoTreeRole {
            parent: usize::MAX,
            children: [None, None],
            depth: 0,
        };
        let mut t1 = vec![blank; p];
        let mut t2 = vec![blank; p];
        let root1 = build_parity(0, n - 1, 1, 1, driver, &mut t1);
        let root2 = build_parity(0, n - 1, 0, 1, driver, &mut t2);
        Ok(TwoTree {
            p,
            t1,
            t2,
            root1,
            root2,
        })
    }

    /// The root driver rank (`p − 1`).
    pub fn driver(&self) -> usize {
        self.p - 1
    }

    /// Root of the given tree half.
    pub fn root(&self, half: Half) -> usize {
        match half {
            Half::T1 => self.root1,
            Half::T2 => self.root2,
        }
    }

    /// Role of `rank` in the given tree (`rank < p − 1`).
    pub fn role(&self, half: Half, rank: usize) -> TwoTreeRole {
        debug_assert!(rank < self.p - 1);
        match half {
            Half::T1 => self.t1[rank],
            Half::T2 => self.t2[rank],
        }
    }

    /// True if `rank` is a leaf in the given tree.
    pub fn is_leaf(&self, half: Half, rank: usize) -> bool {
        self.role(half, rank).children == [None, None]
    }

    /// Tree height (max depth over ranks), per half.
    pub fn height(&self, half: Half) -> usize {
        let roles = match half {
            Half::T1 => &self.t1,
            Half::T2 => &self.t2,
        };
        roles[..self.p - 1].iter().map(|r| r.depth).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check(p: usize) {
        let tt = TwoTree::new(p).unwrap();
        let n = p - 1;
        for half in [Half::T1, Half::T2] {
            // every non-driver rank has a parent path to the driver
            for r in 0..n {
                let mut cur = r;
                let mut hops = 0;
                while cur != tt.driver() {
                    cur = tt.role(half, cur).parent;
                    hops += 1;
                    assert!(hops <= 2 * p, "p={p}: cycle from {r}");
                }
            }
            // parent/child symmetry + edge count
            let mut edges = 0;
            for r in 0..n {
                for c in tt.role(half, r).children.into_iter().flatten() {
                    assert_eq!(tt.role(half, c).parent, r);
                    edges += 1;
                }
            }
            assert_eq!(edges, n - 1); // plus the root-driver edge
            assert_eq!(tt.role(half, tt.root(half)).parent, tt.driver());
            // height is logarithmic (parity-rooting costs at most ~1 level)
            assert!(
                tt.height(half) <= crate::util::log2_ceil(n + 1) as usize + 2,
                "p={p}: height {}",
                tt.height(half)
            );
        }
    }

    #[test]
    fn structural_invariants() {
        for p in 2..=64 {
            check(p);
        }
        check(127);
        check(128);
        check(289);
    }

    #[test]
    fn interior_disjointness_exact() {
        // The defining property, for EVERY p: T1 interiors are odd, T2
        // interiors are even, so no rank is interior in both trees.
        for p in 2..=128usize {
            let tt = TwoTree::new(p).unwrap();
            for r in 0..p - 1 {
                if !tt.is_leaf(Half::T1, r) {
                    assert_eq!(r % 2, 1, "p={p}: T1 interior {r} not odd");
                }
                if !tt.is_leaf(Half::T2, r) {
                    assert_eq!(r % 2, 0, "p={p}: T2 interior {r} not even");
                }
                assert!(
                    tt.is_leaf(Half::T1, r) || tt.is_leaf(Half::T2, r),
                    "p={p}: rank {r} interior in both trees"
                );
            }
        }
    }

    #[test]
    fn in_order_orientation_both_trees() {
        // children[0] subtree < node < children[1] subtree — this is what
        // lets the collective preserve rank order for non-commutative ops.
        for p in [3usize, 5, 9, 16, 33, 64] {
            let tt = TwoTree::new(p).unwrap();
            for half in [Half::T1, Half::T2] {
                for r in 0..p - 1 {
                    let role = tt.role(half, r);
                    if let Some(c0) = role.children[0] {
                        assert!(c0 < r, "p={p} {half:?} r={r}");
                    }
                    if let Some(c1) = role.children[1] {
                        assert!(c1 > r, "p={p} {half:?} r={r}");
                    }
                }
            }
        }
    }

    #[test]
    fn tiny() {
        let tt = TwoTree::new(2).unwrap();
        assert_eq!(tt.driver(), 1);
        assert_eq!(tt.root(Half::T1), 0);
        assert!(tt.is_leaf(Half::T1, 0));
        assert!(tt.is_leaf(Half::T2, 0));
    }
}
