//! Process topologies: the paper's post-order binary trees and dual-root
//! forest, plus the tree/graph shapes needed by the baseline algorithms
//! (binomial trees, ring, hypercube neighborhoods, two-tree) and the
//! rank→node mappings of a clustered machine.

pub mod binomial;
pub mod dualroot;
pub mod mapping;
pub mod postorder;
pub mod twotree;

pub use binomial::BinomialTree;
pub use dualroot::{DualRootForest, NodeRole, TreeId};
pub use mapping::{node_of, Mapping};
pub use postorder::PostOrderTree;
pub use twotree::TwoTree;
