//! Rank → compute-node mappings for hierarchical (clustered) machines.
//!
//! The paper's Hydra runs place 8 MPI processes on each of 36 nodes and
//! §3 explicitly leaves "the role of the hierarchical structure (network
//! and nodes)" as an open question — our A4 ablation answers it in-model:
//! the hierarchical cost model charges different (α, β) for intra-node vs
//! inter-node edges, and the mapping decides which edges are which.

/// How consecutive ranks are laid out over nodes.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Mapping {
    /// Ranks 0..k-1 on node 0, k..2k-1 on node 1, … ("by node", the common
    /// default; k = ranks per node).
    Block { ranks_per_node: usize },
    /// Rank r on node r mod n ("round robin" / cyclic over n nodes).
    RoundRobin { nodes: usize },
}

/// The node hosting `rank` under `mapping`.
pub fn node_of(mapping: Mapping, rank: usize) -> usize {
    match mapping {
        Mapping::Block { ranks_per_node } => {
            debug_assert!(ranks_per_node > 0);
            rank / ranks_per_node
        }
        Mapping::RoundRobin { nodes } => {
            debug_assert!(nodes > 0);
            rank % nodes
        }
    }
}

impl Mapping {
    /// Parse "block:8" / "rr:36".
    pub fn parse(s: &str) -> Option<Mapping> {
        let (kind, n) = s.split_once(':')?;
        let n: usize = n.parse().ok().filter(|&n| n > 0)?;
        match kind {
            "block" => Some(Mapping::Block { ranks_per_node: n }),
            "rr" => Some(Mapping::RoundRobin { nodes: n }),
            _ => None,
        }
    }

    /// True when `a` and `b` share a node.
    pub fn same_node(self, a: usize, b: usize) -> bool {
        node_of(self, a) == node_of(self, b)
    }

    /// The node groups of a `p`-rank world under this mapping: one entry
    /// per *populated* node, ordered by node id, members ascending. Every
    /// rank appears in exactly one group — this partition is what the
    /// sharded registry and the communicator-group layer (`comm::Group`)
    /// both build on, so edge-table shards and `allreduce_hier` node
    /// groups always agree.
    pub fn shards(self, p: usize) -> Vec<Vec<usize>> {
        let mut by_node: std::collections::BTreeMap<usize, Vec<usize>> =
            std::collections::BTreeMap::new();
        for r in 0..p {
            by_node.entry(node_of(self, r)).or_default().push(r);
        }
        by_node.into_values().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_mapping() {
        let m = Mapping::Block { ranks_per_node: 8 };
        assert_eq!(node_of(m, 0), 0);
        assert_eq!(node_of(m, 7), 0);
        assert_eq!(node_of(m, 8), 1);
        assert_eq!(node_of(m, 287), 35); // the paper's 36x8 layout
        assert!(m.same_node(0, 7));
        assert!(!m.same_node(7, 8));
    }

    #[test]
    fn round_robin_mapping() {
        let m = Mapping::RoundRobin { nodes: 36 };
        assert_eq!(node_of(m, 0), 0);
        assert_eq!(node_of(m, 36), 0);
        assert_eq!(node_of(m, 37), 1);
        assert!(m.same_node(1, 37));
        assert!(!m.same_node(1, 2));
    }

    #[test]
    fn shards_partition_exactly() {
        // block, ragged tail: 10 ranks over nodes of 4
        let m = Mapping::Block { ranks_per_node: 4 };
        let s = m.shards(10);
        assert_eq!(s, vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7], vec![8, 9]]);
        // round robin interleaves
        let m = Mapping::RoundRobin { nodes: 3 };
        let s = m.shards(7);
        assert_eq!(s, vec![vec![0, 3, 6], vec![1, 4], vec![2, 5]]);
        // more nodes than ranks: only populated nodes appear
        let m = Mapping::RoundRobin { nodes: 8 };
        assert_eq!(m.shards(3).len(), 3);
        // empty world
        assert!(Mapping::Block { ranks_per_node: 4 }.shards(0).is_empty());
    }

    #[test]
    fn parse() {
        assert_eq!(
            Mapping::parse("block:8"),
            Some(Mapping::Block { ranks_per_node: 8 })
        );
        assert_eq!(Mapping::parse("rr:36"), Some(Mapping::RoundRobin { nodes: 36 }));
        assert_eq!(Mapping::parse("block:0"), None);
        assert_eq!(Mapping::parse("weird:3"), None);
        assert_eq!(Mapping::parse("block8"), None);
    }
}
