//! Post-order numbered, as-balanced-as-possible binary trees.
//!
//! The paper (§1.1): *"the subtree rooted at some processor i consists of
//! successively numbered processors [i′, …, i″] and [i″+1, …, i−1] for some
//! child processors i′, i″ < i. The first child of processor i is processor
//! i−1, and the second child is processor i″."*
//!
//! Consequences we rely on:
//! * the root of the range `[lo, hi]` is `hi` (post-order: root last);
//! * every subtree covers a *consecutive* rank interval, so reductions
//!   combined as `(second-child) ⊙ (first-child) ⊙ own` need only
//!   associativity — verified by `SeqCheckOp` tests;
//! * for a perfect tree (`n = 2^k − 1`) the height is `k − 1`.

use crate::error::{Error, Result};

/// A post-order numbered binary tree over the inclusive rank range
/// `[lo, hi]`.
#[derive(Clone, Debug)]
pub struct PostOrderTree {
    /// Lowest rank in the tree.
    pub lo: usize,
    /// Highest rank in the tree; also the root (post-order).
    pub hi: usize,
    /// Height of the tree (max depth; a single node has height 0).
    pub height: usize,
    parent: Vec<Option<usize>>,
    /// `[first_child, second_child]` per node. The first child is `i − 1`
    /// (covering the upper sub-range), the second child the root of the
    /// lower sub-range, matching the paper's numbering.
    children: Vec<[Option<usize>; 2]>,
    depth: Vec<usize>,
}

impl PostOrderTree {
    /// Build the tree over `[lo, hi]`.
    pub fn new(lo: usize, hi: usize) -> Result<PostOrderTree> {
        if lo > hi {
            return Err(Error::Config(format!(
                "post-order tree range [{lo}, {hi}] is empty"
            )));
        }
        let n = hi - lo + 1;
        let mut t = PostOrderTree {
            lo,
            hi,
            height: 0,
            parent: vec![None; n],
            children: vec![[None, None]; n],
            depth: vec![0; n],
        };
        t.build(lo, hi, 0, None);
        t.height = t.depth.iter().copied().max().unwrap_or(0);
        Ok(t)
    }

    /// Recursive construction: root of `[lo, hi]` is `hi`; the remaining
    /// `[lo, hi-1]` splits into a lower (second-child) part of
    /// `⌊(n−1)/2⌋` nodes and an upper (first-child) part rooted at `hi−1`.
    fn build(&mut self, lo: usize, hi: usize, depth: usize, parent: Option<usize>) {
        let i = self.idx(hi);
        self.parent[i] = parent;
        self.depth[i] = depth;
        let rest = hi - lo; // nodes below the root
        if rest == 0 {
            return; // leaf
        }
        let n_second = (rest) / 2; // size of the lower, second-child subtree
        if n_second == 0 {
            // only the first child (i − 1) exists
            self.children[i] = [Some(hi - 1), None];
            self.build(lo, hi - 1, depth + 1, Some(hi));
        } else {
            let mid = lo + n_second - 1; // second child root (covers [lo, mid])
            self.children[i] = [Some(hi - 1), Some(mid)];
            self.build(mid + 1, hi - 1, depth + 1, Some(hi)); // first child
            self.build(lo, mid, depth + 1, Some(hi)); // second child
        }
    }

    #[inline]
    fn idx(&self, rank: usize) -> usize {
        debug_assert!(self.contains(rank));
        rank - self.lo
    }

    /// Number of nodes.
    pub fn size(&self) -> usize {
        self.hi - self.lo + 1
    }

    /// The root rank (`hi`, by post-order numbering).
    pub fn root(&self) -> usize {
        self.hi
    }

    /// True if `rank` belongs to this tree.
    pub fn contains(&self, rank: usize) -> bool {
        (self.lo..=self.hi).contains(&rank)
    }

    /// Parent of `rank`, `None` for the root.
    pub fn parent(&self, rank: usize) -> Option<usize> {
        self.parent[self.idx(rank)]
    }

    /// `[first_child, second_child]` of `rank` (either may be `None`).
    pub fn children(&self, rank: usize) -> [Option<usize>; 2] {
        self.children[self.idx(rank)]
    }

    /// Depth of `rank` (root is 0).
    pub fn depth(&self, rank: usize) -> usize {
        self.depth[self.idx(rank)]
    }

    /// True if `rank` has no children.
    pub fn is_leaf(&self, rank: usize) -> bool {
        self.children[self.idx(rank)] == [None, None]
    }

    /// The consecutive rank interval covered by the subtree of `rank`
    /// (test/diagnostic helper; O(subtree)).
    pub fn subtree_range(&self, rank: usize) -> (usize, usize) {
        match self.children(rank) {
            [None, None] => (rank, rank),
            [Some(_c0), None] => {
                // first child covers [x, rank-1]
                let lo = self.leftmost(rank);
                (lo, rank)
            }
            [Some(_), Some(_)] | [None, Some(_)] => (self.leftmost(rank), rank),
        }
    }

    fn leftmost(&self, rank: usize) -> usize {
        let mut r = rank;
        loop {
            let ch = self.children(r);
            // the lowest-numbered descendant is through the second child if
            // present, else the first child
            match (ch[1], ch[0]) {
                (Some(c), _) => r = c,
                (None, Some(c)) => r = c,
                (None, None) => return r,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_invariants(t: &PostOrderTree) {
        // root is hi, depth 0, no parent
        assert_eq!(t.root(), t.hi);
        assert_eq!(t.depth(t.root()), 0);
        assert!(t.parent(t.root()).is_none());
        for r in t.lo..=t.hi {
            // parent/child symmetry
            if let Some(p) = t.parent(r) {
                assert!(t.children(p).contains(&Some(r)));
                assert_eq!(t.depth(r), t.depth(p) + 1);
            }
            for c in t.children(r).into_iter().flatten() {
                assert_eq!(t.parent(c), Some(r));
                assert!(c < r, "post-order: children numbered below parent");
            }
            // first child, when present, is r-1 (paper §1.1)
            if let Some(c0) = t.children(r)[0] {
                assert_eq!(c0, r - 1);
            }
            // subtree ranges are consecutive and properly nested
            let (lo, hi) = t.subtree_range(r);
            assert_eq!(hi, r, "post-order root of subtree is its max rank");
            assert!(lo >= t.lo);
            if let [Some(c0), Some(c1)] = t.children(r) {
                let (l0, h0) = t.subtree_range(c0);
                let (l1, h1) = t.subtree_range(c1);
                // second child covers [lo, mid], first child [mid+1, r-1]
                assert_eq!(l1, lo);
                assert_eq!(h1 + 1, l0);
                assert_eq!(h0, r - 1);
            }
        }
        assert_eq!(t.height, (t.lo..=t.hi).map(|r| t.depth(r)).max().unwrap());
    }

    #[test]
    fn singleton() {
        let t = PostOrderTree::new(5, 5).unwrap();
        assert!(t.is_leaf(5));
        assert_eq!(t.height, 0);
        check_invariants(&t);
    }

    #[test]
    fn pair() {
        let t = PostOrderTree::new(0, 1).unwrap();
        assert_eq!(t.children(1), [Some(0), None]);
        assert_eq!(t.height, 1);
        check_invariants(&t);
    }

    #[test]
    fn perfect_trees_have_log_height() {
        for k in 1..=9usize {
            let n = (1usize << k) - 1;
            let t = PostOrderTree::new(0, n - 1).unwrap();
            assert_eq!(t.height, k - 1, "n={n}");
            check_invariants(&t);
        }
    }

    #[test]
    fn arbitrary_sizes_invariants() {
        for n in 1..=64usize {
            let t = PostOrderTree::new(0, n - 1).unwrap();
            check_invariants(&t);
            // balanced: height within ceil(log2(n+1))-1 .. ceil(log2(n+1))
            let hmin = (usize::BITS - (n as usize).leading_zeros()) as usize - 1;
            assert!(
                t.height <= hmin + 1,
                "n={n}: height {} too large (min {hmin})",
                t.height
            );
        }
    }

    #[test]
    fn offset_range() {
        let t = PostOrderTree::new(10, 20).unwrap();
        assert_eq!(t.root(), 20);
        assert!(t.contains(10) && t.contains(20) && !t.contains(9));
        check_invariants(&t);
    }

    #[test]
    fn empty_range_rejected() {
        assert!(PostOrderTree::new(3, 2).is_err());
    }

    #[test]
    fn paper_seven_node_example() {
        // n = 7 perfect: [0..6], root 6, children 5 and 2;
        // 5 covers [3,5] with children 4,3; 2 covers [0,2] with children 1,0.
        let t = PostOrderTree::new(0, 6).unwrap();
        assert_eq!(t.children(6), [Some(5), Some(2)]);
        assert_eq!(t.children(5), [Some(4), Some(3)]);
        assert_eq!(t.children(2), [Some(1), Some(0)]);
        assert!(t.is_leaf(0) && t.is_leaf(1) && t.is_leaf(3) && t.is_leaf(4));
        assert_eq!(t.subtree_range(5), (3, 5));
        assert_eq!(t.subtree_range(2), (0, 2));
    }
}
