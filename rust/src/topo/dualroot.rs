//! The paper's dual-root forest: two post-order binary trees with
//! communicating roots.
//!
//! Ranks `[0, q)` form tree **A** (root `q−1`, the *lower* root), ranks
//! `[q, p)` form tree **B** (root `p−1`, the *upper* root). The split is as
//! even as possible; for the paper's sweet spot `p + 2 = 2^h` both trees
//! are perfect with height `h − 2`.
//!
//! At the dual exchange the lower root computes `Y[j] ⊙ t` and the upper
//! root `t ⊙ Y[j]` so that the result is the in-rank-order product
//! `(⊙_{0..q-1} x_k) ⊙ (⊙_{q..p-1} x_k)` (paper, Algorithm 1 line 9).

use super::postorder::PostOrderTree;
use crate::error::{Error, Result};

/// Which of the two trees a rank belongs to.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum TreeId {
    A,
    B,
}

/// Everything a rank needs to know to run Algorithm 1.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct NodeRole {
    pub tree: TreeId,
    /// Depth within the own tree (root = 0).
    pub depth: usize,
    /// `[first_child, second_child]`; first child is `rank − 1` when present.
    pub children: [Option<usize>; 2],
    /// Parent within the own tree; `None` for the two roots.
    pub parent: Option<usize>,
    /// The other tree's root, set only on the two roots.
    pub dual: Option<usize>,
    /// True on the lower-numbered root (tree A's root): it combines the
    /// dual's contribution on the right.
    pub lower_root: bool,
}

/// The dual-root forest over `p` ranks (`p ≥ 2`).
#[derive(Clone, Debug)]
pub struct DualRootForest {
    pub a: PostOrderTree,
    pub b: PostOrderTree,
    p: usize,
}

impl DualRootForest {
    /// Build the forest; tree A gets `⌈p/2⌉` ranks.
    pub fn new(p: usize) -> Result<DualRootForest> {
        if p < 2 {
            return Err(Error::Config(format!(
                "dual-root forest needs p >= 2, got {p}"
            )));
        }
        let q = (p + 1) / 2;
        Ok(DualRootForest {
            a: PostOrderTree::new(0, q - 1)?,
            b: PostOrderTree::new(q, p - 1)?,
            p,
        })
    }

    /// Number of ranks.
    pub fn size(&self) -> usize {
        self.p
    }

    /// First rank of tree B (== size of tree A).
    pub fn split(&self) -> usize {
        self.b.lo
    }

    /// The two roots `(lower, upper)`.
    pub fn roots(&self) -> (usize, usize) {
        (self.a.root(), self.b.root())
    }

    /// Max height over the two trees.
    pub fn height(&self) -> usize {
        self.a.height.max(self.b.height)
    }

    /// The tree containing `rank`.
    pub fn tree_of(&self, rank: usize) -> &PostOrderTree {
        if rank < self.b.lo {
            &self.a
        } else {
            &self.b
        }
    }

    /// Per-rank role for Algorithm 1.
    pub fn role(&self, rank: usize) -> Result<NodeRole> {
        if rank >= self.p {
            return Err(Error::Config(format!(
                "rank {rank} out of range for p={}",
                self.p
            )));
        }
        let (tree_id, tree) = if rank < self.b.lo {
            (TreeId::A, &self.a)
        } else {
            (TreeId::B, &self.b)
        };
        let is_root = rank == tree.root();
        let dual = if is_root {
            Some(if tree_id == TreeId::A {
                self.b.root()
            } else {
                self.a.root()
            })
        } else {
            None
        };
        Ok(NodeRole {
            tree: tree_id,
            depth: tree.depth(rank),
            children: tree.children(rank),
            parent: tree.parent(rank),
            dual,
            lower_root: is_root && tree_id == TreeId::A,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_forests() {
        let f = DualRootForest::new(2).unwrap();
        assert_eq!(f.roots(), (0, 1));
        let r0 = f.role(0).unwrap();
        assert!(r0.lower_root);
        assert_eq!(r0.dual, Some(1));
        assert_eq!(r0.children, [None, None]);
        let r1 = f.role(1).unwrap();
        assert!(!r1.lower_root);
        assert_eq!(r1.dual, Some(0));
    }

    #[test]
    fn three_ranks() {
        let f = DualRootForest::new(3).unwrap();
        // q = 2: A = [0,1] root 1, B = [2,2] root 2
        assert_eq!(f.roots(), (1, 2));
        assert_eq!(f.role(0).unwrap().parent, Some(1));
        assert_eq!(f.role(1).unwrap().children, [Some(0), None]);
        assert_eq!(f.role(2).unwrap().children, [None, None]);
        assert_eq!(f.role(2).unwrap().dual, Some(1));
    }

    #[test]
    fn paper_sweet_spot_is_perfect() {
        // p + 2 = 2^h → both trees perfect with height h − 2
        for h in 2..=10usize {
            let p = (1usize << h) - 2;
            let f = DualRootForest::new(p).unwrap();
            assert_eq!(f.a.size(), f.b.size());
            assert_eq!(f.a.height, h - 2, "p={p}");
            assert_eq!(f.b.height, h - 2, "p={p}");
        }
    }

    #[test]
    fn roles_are_consistent() {
        for p in 2..=65usize {
            let f = DualRootForest::new(p).unwrap();
            let (lo_root, hi_root) = f.roots();
            assert_eq!(hi_root, p - 1);
            let mut roots_seen = 0;
            for r in 0..p {
                let role = f.role(r).unwrap();
                if role.dual.is_some() {
                    roots_seen += 1;
                    assert!(role.parent.is_none());
                    assert!(r == lo_root || r == hi_root);
                } else {
                    assert!(role.parent.is_some());
                }
                if role.lower_root {
                    assert_eq!(r, lo_root);
                }
                // first child is rank-1 when present
                if let Some(c0) = role.children[0] {
                    assert_eq!(c0, r - 1);
                }
            }
            assert_eq!(roots_seen, 2);
        }
    }

    #[test]
    fn p1_rejected() {
        assert!(DualRootForest::new(1).is_err());
        assert!(DualRootForest::new(0).is_err());
    }

    #[test]
    fn split_sizes_balanced() {
        for p in 2..=64usize {
            let f = DualRootForest::new(p).unwrap();
            let qa = f.a.size();
            let qb = f.b.size();
            assert!(qa == qb || qa == qb + 1, "p={p}: {qa} vs {qb}");
            assert_eq!(qa + qb, p);
        }
    }
}
