//! Summary statistics and series containers used by the benchmark harness.

/// Online summary statistics over f64 samples.
#[derive(Clone, Debug, Default)]
pub struct Stats {
    samples: Vec<f64>,
}

impl Stats {
    pub fn new() -> Stats {
        Stats::default()
    }

    pub fn push(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples
            .iter()
            .copied()
            .fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let mean = self.mean();
        let var = self
            .samples
            .iter()
            .map(|v| (v - mean) * (v - mean))
            .sum::<f64>()
            / (n - 1) as f64;
        var.sqrt()
    }

    /// q-quantile by linear interpolation, q in [0, 1].
    pub fn quantile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            sorted[lo]
        } else {
            sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
        }
    }

    pub fn median(&self) -> f64 {
        self.quantile(0.5)
    }

    /// Exact q-quantile: the sample at sorted index `⌊(n−1)·q⌋`, never an
    /// interpolated value. Latency reports quote this form so every figure
    /// is a time that was actually observed (interpolation between two
    /// iterations has no physical meaning).
    pub fn quantile_exact(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pos = (q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64).floor() as usize;
        sorted[pos]
    }

    pub fn p50(&self) -> f64 {
        self.quantile_exact(0.50)
    }

    pub fn p90(&self) -> f64 {
        self.quantile_exact(0.90)
    }

    pub fn p99(&self) -> f64 {
        self.quantile_exact(0.99)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_stats() {
        let mut s = Stats::new();
        for v in [4.0, 1.0, 3.0, 2.0] {
            s.push(v);
        }
        assert_eq!(s.count(), 4);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.median(), 2.5);
        assert!((s.stddev() - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn quantiles() {
        let mut s = Stats::new();
        for v in 0..=100 {
            s.push(v as f64);
        }
        assert_eq!(s.quantile(0.0), 0.0);
        assert_eq!(s.quantile(1.0), 100.0);
        assert_eq!(s.quantile(0.25), 25.0);
    }

    #[test]
    fn exact_percentiles_are_observed_samples() {
        let mut s = Stats::new();
        for v in [5.0, 1.0, 9.0, 3.0, 7.0] {
            s.push(v);
        }
        // n=5: ⌊4·0.5⌋=2, ⌊4·0.9⌋=3, ⌊4·0.99⌋=3 over sorted [1,3,5,7,9]
        assert_eq!(s.p50(), 5.0);
        assert_eq!(s.p90(), 7.0);
        assert_eq!(s.p99(), 7.0);
        // every exact quantile must be a pushed sample, q across the range
        for q in [0.0, 0.1, 0.33, 0.66, 0.95, 1.0] {
            assert!([1.0, 3.0, 5.0, 7.0, 9.0].contains(&s.quantile_exact(q)));
        }
        assert!(Stats::new().p99().is_nan());
    }

    #[test]
    fn empty_and_single() {
        let s = Stats::new();
        assert!(s.mean().is_nan());
        let mut s = Stats::new();
        s.push(7.0);
        assert_eq!(s.median(), 7.0);
        assert_eq!(s.stddev(), 0.0);
    }
}
