//! Spawn a world of `p` rank threads and run a closure per rank.
//!
//! A world's transport state is sharded by node group (see
//! [`ShardedRegistry`](super::thread)): under a hierarchical cost model the
//! shard layout is derived from the model's rank → node [`Mapping`]
//! automatically, so the edge-table and buffer-pool arenas align with the
//! simulated machine's nodes; [`run_world_sharded`] pins an explicit
//! layout. Sharding is invisible to the cost model — virtual times are
//! bit-identical across layouts — but observable in the per-shard metrics
//! ([`WorldReport::shard_metrics`]).

use std::sync::Arc;
use std::thread;

use super::barrier::VBarrier;
use super::fault::FaultPlan;
use super::metrics::RankMetrics;
use super::net::{Fabric, LinkOccupancy};
use super::thread::{ShardedRegistry, ThreadComm, Timing};
use super::Comm;
use crate::buffer::pool::{CowEvent, ShardPool};
use crate::error::{Error, Result};
use crate::ops::Elem;
use crate::topo::Mapping;

/// The outcome of a world run.
#[derive(Debug)]
pub struct WorldReport<R> {
    /// Per-rank closure results, indexed by rank.
    pub results: Vec<R>,
    /// Max over ranks of the final virtual clock, in µs (0 for real timing).
    pub max_vtime_us: f64,
    /// Wall-clock duration of the whole run, in µs.
    pub wall_us: f64,
    /// Per-rank traffic counters (each tagged with its `shard_id`).
    pub metrics: Vec<RankMetrics>,
    /// Per-rank copy-attribution events — empty unless the crate is built
    /// with the `debug-cow` feature (see `buffer::pool::take_cow_log`).
    pub cow_events: Vec<Vec<CowEvent>>,
    /// Per-node NIC occupancy (reserved transfer time and transfer counts
    /// per direction), indexed by node id under the cost model's mapping.
    /// Empty unless the run used a congestion-aware model with finite
    /// ports.
    pub net_occupancy: Vec<LinkOccupancy>,
}

impl<R> WorldReport<R> {
    /// Aggregate counters over all ranks.
    pub fn total_metrics(&self) -> RankMetrics {
        let mut total = RankMetrics::default();
        for m in &self.metrics {
            total.merge(m);
        }
        total
    }

    /// Aggregate counters per registry shard (node group), indexed by
    /// shard id. Every rank contributes to exactly one shard — leader
    /// ranks included once, in their home shard — so the shard aggregates
    /// sum to [`WorldReport::total_metrics`].
    pub fn shard_metrics(&self) -> Vec<RankMetrics> {
        let shards = self
            .metrics
            .iter()
            .map(|m| m.shard_id as usize)
            .max()
            .map_or(0, |s| s + 1);
        let mut out: Vec<RankMetrics> = (0..shards)
            .map(|s| RankMetrics {
                shard_id: s as u32,
                ..RankMetrics::default()
            })
            .collect();
        for m in &self.metrics {
            out[m.shard_id as usize].merge(m);
        }
        out
    }
}

/// The shard layout implied by a timing mode: a hierarchical (or
/// congestion-aware) cost model shards by its node mapping, everything
/// else runs one flat shard.
fn implied_mapping(timing: Timing) -> Option<Mapping> {
    match timing {
        Timing::Virtual(model, _) => model.mapping(),
        Timing::Real => None,
    }
}

/// The network-resource fabric implied by a timing mode: inert unless
/// the virtual cost model carries finite [`NetParams`](crate::model).
/// Real timing always gets the inert fabric — congestion is a
/// virtual-clock feature (a real run takes the time it takes), and an
/// active fabric would otherwise wait on drain times no real-mode
/// receiver records.
fn implied_fabric(p: usize, timing: Timing) -> Fabric {
    if let Timing::Virtual(model, _) = timing {
        let net = model.net_params();
        if !net.is_dedicated() {
            if let Some(mapping) = model.mapping() {
                return Fabric::new(p, net, mapping);
            }
        }
    }
    Fabric::dedicated()
}

/// Run `f(rank_endpoint)` on `p` threads and collect results, sharding the
/// transport by the cost model's node mapping (if any).
///
/// Threads get 1 MiB stacks (the collectives are iterative, not recursive),
/// so worlds up to the paper's p = 1152 are cheap. A panic or error on any
/// rank tears the world down: channel disconnects propagate as
/// `Error::Disconnected` to peers, and the first rank error is returned.
pub fn run_world<E, R, F>(p: usize, timing: Timing, f: F) -> Result<WorldReport<R>>
where
    E: Elem,
    R: Send + 'static,
    F: Fn(&mut ThreadComm<E>) -> Result<R> + Send + Sync + 'static,
{
    run_world_sharded(p, timing, implied_mapping(timing), f)
}

/// [`run_world`] under a deterministic fault-injection plan: every
/// endpoint of the world applies `faults` to its traffic (see
/// [`FaultPlan`]). With an inert plan this is exactly `run_world`.
pub fn run_world_faulty<E, R, F>(
    p: usize,
    timing: Timing,
    faults: FaultPlan,
    f: F,
) -> Result<WorldReport<R>>
where
    E: Elem,
    R: Send + 'static,
    F: Fn(&mut ThreadComm<E>) -> Result<R> + Send + Sync + 'static,
{
    run_world_inner(p, timing, implied_mapping(timing), faults, f)
}

/// [`run_world`] with an explicit shard layout: `Some(mapping)` backs the
/// world with one edge-table + buffer-pool shard per node group of the
/// mapping, `None` runs the flat single-shard world.
pub fn run_world_sharded<E, R, F>(
    p: usize,
    timing: Timing,
    mapping: Option<Mapping>,
    f: F,
) -> Result<WorldReport<R>>
where
    E: Elem,
    R: Send + 'static,
    F: Fn(&mut ThreadComm<E>) -> Result<R> + Send + Sync + 'static,
{
    run_world_inner(p, timing, mapping, FaultPlan::none(), f)
}

fn run_world_inner<E, R, F>(
    p: usize,
    timing: Timing,
    mapping: Option<Mapping>,
    faults: FaultPlan,
    f: F,
) -> Result<WorldReport<R>>
where
    E: Elem,
    R: Send + 'static,
    F: Fn(&mut ThreadComm<E>) -> Result<R> + Send + Sync + 'static,
{
    if p == 0 {
        return Err(Error::Config("world size must be >= 1".into()));
    }
    let registry = Arc::new(ShardedRegistry::with_faults(
        p,
        mapping,
        implied_fabric(p, timing),
        faults,
    ));
    let barrier = Arc::new(VBarrier::new(p));
    // one shared overflow arena per shard: storage a rank's thread-local
    // free list cannot hold is donated to (and reclaimed from) its node
    // group, never a global arena
    let shard_pools: Vec<Arc<ShardPool>> = (0..registry.shard_count())
        .map(|_| Arc::new(ShardPool::new()))
        .collect();
    let f = Arc::new(f);
    let start = std::time::Instant::now();

    let mut handles = Vec::with_capacity(p);
    for rank in 0..p {
        let registry = Arc::clone(&registry);
        let barrier = Arc::clone(&barrier);
        let pool = Arc::clone(&shard_pools[registry.shard_of(rank)]);
        let f = Arc::clone(&f);
        let handle = thread::Builder::new()
            .name(format!("rank-{rank}"))
            .stack_size(1 << 20)
            .spawn(move || {
                // poison the world on both error returns and panics, so
                // peers blocked in recv abort promptly
                struct PoisonOnUnwind<E: Elem>(Arc<ShardedRegistry<E>>);
                impl<E: Elem> Drop for PoisonOnUnwind<E> {
                    fn drop(&mut self) {
                        if std::thread::panicking() {
                            self.0.poison();
                        }
                    }
                }
                let guard = PoisonOnUnwind(Arc::clone(&registry));
                // rank threads are fresh per world, but reset the buffer
                // and reduce-backend counters anyway so harvested stats
                // cover exactly this run
                let _ = crate::buffer::pool::take_stats();
                let _ = crate::buffer::pool::take_cow_log();
                let _ = crate::ops::backend::take_stats();
                crate::buffer::pool::bind_shard_pool(Some(pool));
                crate::obs::bind_rank(rank);
                let mut comm = ThreadComm::new(rank, p, Arc::clone(&registry), barrier, timing);
                let result = match f(&mut comm) {
                    Ok(r) => r,
                    Err(e) => {
                        registry.poison();
                        return Err(e);
                    }
                };
                drop(guard);
                let mut metrics = comm.metrics().clone();
                metrics.absorb_buffer_stats(&crate::buffer::pool::take_stats());
                metrics.absorb_backend_stats(&crate::ops::backend::take_stats());
                let cow = crate::buffer::pool::take_cow_log();
                Ok::<_, Error>((result, comm.vtime(), metrics, cow))
            })
            .map_err(Error::Io)?;
        handles.push(handle);
    }

    let mut results = Vec::with_capacity(p);
    let mut metrics = Vec::with_capacity(p);
    let mut cow_events = Vec::with_capacity(p);
    let mut max_vtime = 0.0f64;
    let mut first_err: Option<Error> = None;
    for (rank, handle) in handles.into_iter().enumerate() {
        match handle.join() {
            Ok(Ok((r, vtime, m, cow))) => {
                max_vtime = max_vtime.max(vtime);
                results.push(r);
                metrics.push(m);
                cow_events.push(cow);
            }
            Ok(Err(e)) => {
                // Disconnected errors are usually poison fallout from some
                // other rank's failure — prefer reporting the root cause.
                match (&first_err, &e) {
                    (None, _) | (Some(Error::Disconnected { .. }), _)
                        if !matches!(e, Error::Disconnected { .. })
                            || first_err.is_none() =>
                    {
                        first_err = Some(e)
                    }
                    _ => {}
                }
            }
            Err(_) => {
                let e = Error::Protocol(format!("rank {rank} panicked"));
                if !matches!(first_err, Some(ref f) if !matches!(f, Error::Disconnected { .. }))
                {
                    first_err = Some(e);
                }
            }
        }
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    Ok(WorldReport {
        results,
        max_vtime_us: max_vtime * 1e6,
        wall_us: start.elapsed().as_secs_f64() * 1e6,
        metrics,
        cow_events,
        net_occupancy: registry.fabric().occupancy(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::buffer::DataBuf;
    use crate::comm::Comm;
    use crate::model::{ComputeCost, CostModel, LinkCost};

    #[test]
    fn ranks_see_distinct_ids() {
        let report = run_world::<i32, _, _>(5, Timing::Real, |comm| Ok(comm.rank())).unwrap();
        assert_eq!(report.results, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn neighbor_exchange_world() {
        // even ranks exchange with rank+1
        let report = run_world::<i32, _, _>(6, Timing::Real, |comm| {
            let r = comm.rank();
            let peer = if r % 2 == 0 { r + 1 } else { r - 1 };
            let got = comm.sendrecv(peer, DataBuf::real(vec![r as i32]))?;
            Ok(got.into_vec()?[0])
        })
        .unwrap();
        assert_eq!(report.results, vec![1, 0, 3, 2, 5, 4]);
        let total = report.total_metrics();
        assert_eq!(total.sendrecvs, 6);
        assert_eq!(total.bytes_sent, 24);
    }

    #[test]
    fn virtual_time_ping_chain() {
        // rank 0 -> 1 -> 2: rank 1 finishes receiving at α and its forward
        // occupies [α, 2α]; rank 2's receive completes at 2α (store &
        // forward, ports busy back-to-back)
        let cost = CostModel::Uniform(LinkCost::new(1e-6, 0.0));
        let timing = Timing::Virtual(cost, ComputeCost::new(0.0));
        let report = run_world::<i32, _, _>(3, timing, |comm| {
            match comm.rank() {
                0 => comm.send(1, DataBuf::real(vec![1]))?,
                1 => {
                    let b = comm.recv(0)?;
                    comm.send(2, b)?;
                }
                _ => {
                    comm.recv(1)?;
                }
            }
            Ok(())
        })
        .unwrap();
        assert!((report.max_vtime_us - 2.0).abs() < 1e-6);
    }

    #[test]
    fn error_propagates() {
        let r = run_world::<i32, _, _>(2, Timing::Real, |comm| {
            if comm.rank() == 0 {
                Err(crate::error::Error::Protocol("boom".into()))
            } else {
                // rank 1 blocks on a recv that will disconnect
                let _ = comm.recv(0);
                Ok(())
            }
        });
        assert!(r.is_err());
    }

    #[test]
    fn barrier_syncs_clocks() {
        let cost = CostModel::Uniform(LinkCost::new(1e-6, 0.0));
        let timing = Timing::Virtual(cost, ComputeCost::new(0.0));
        let report = run_world::<i32, _, _>(4, timing, |comm| {
            // rank r does r sends' worth of local charge via compute? use
            // sendrecv pairs instead: rank 0/1 exchange twice; 2/3 once.
            let r = comm.rank();
            let peer = r ^ 1;
            let n = if r < 2 { 2 } else { 1 };
            for _ in 0..n {
                comm.sendrecv(peer, DataBuf::real(vec![0i32]))?;
            }
            comm.barrier()?;
            Ok(comm.time_us())
        })
        .unwrap();
        // all clocks equal the max (2µs) after the barrier
        for t in report.results {
            assert!((t - 2.0).abs() < 1e-9, "t={t}");
        }
    }

    #[test]
    fn hierarchical_timing_shards_the_world() {
        // a hierarchical cost model implies the shard layout: 6 ranks on
        // nodes of 2 → 3 shards, tagged in the per-rank metrics
        let timing = Timing::Virtual(
            CostModel::Hierarchical {
                intra: LinkCost::new(1e-7, 0.0),
                inter: LinkCost::new(1e-6, 0.0),
                mapping: Mapping::Block { ranks_per_node: 2 },
            },
            ComputeCost::new(0.0),
        );
        let report = run_world::<i32, _, _>(6, timing, |comm| {
            let r = comm.rank();
            let peer = if r % 2 == 0 { r + 1 } else { r - 1 };
            let got = comm.sendrecv(peer, DataBuf::real(vec![r as i32]))?;
            Ok(got.into_vec()?[0])
        })
        .unwrap();
        let shard_ids: Vec<u32> = report.metrics.iter().map(|m| m.shard_id).collect();
        assert_eq!(shard_ids, vec![0, 0, 1, 1, 2, 2]);
        let per_shard = report.shard_metrics();
        assert_eq!(per_shard.len(), 3);
        for (s, m) in per_shard.iter().enumerate() {
            assert_eq!(m.shard_id, s as u32);
            assert_eq!(m.sendrecvs, 2); // one exchange per member
            assert_eq!(m.bytes_sent, 8);
        }
        // shard aggregates sum to the world total — no double counting
        let total = report.total_metrics();
        let summed: u64 = per_shard.iter().map(|m| m.bytes_sent).sum();
        assert_eq!(summed, total.bytes_sent);
    }

    #[test]
    fn faulty_world_payloads_match_fault_free() {
        // every fault mode at once: delivered payloads must be identical
        // to the clean run (dedup + reassembly restore the exact streams)
        let run = |faults: FaultPlan| {
            run_world_faulty::<i32, _, _>(4, Timing::Real, faults, |comm| {
                let r = comm.rank();
                let a = comm.sendrecv(r ^ 1, DataBuf::real(vec![r as i32; 8]))?;
                let b = comm.sendrecv(r ^ 2, DataBuf::real(vec![(r * 10) as i32; 4]))?;
                Ok((a.into_vec()?, b.into_vec()?))
            })
            .unwrap()
            .results
        };
        let clean = run(FaultPlan::none());
        let faulty = run(FaultPlan::seeded(11)
            .delay(0.3, 10.0)
            .duplicate(0.3)
            .reorder(0.3)
            .transient_drop(0.2, 12, 5.0)
            .stall(3, 20.0));
        assert_eq!(clean, faulty);
    }

    #[test]
    fn sharding_does_not_change_virtual_time() {
        // the registry layout is invisible to the cost model: same world,
        // flat vs sharded transport, bit-identical clocks
        let timing = Timing::Virtual(
            CostModel::Uniform(LinkCost::new(1e-6, 1e-9)),
            ComputeCost::new(0.0),
        );
        let run = |mapping: Option<Mapping>| {
            run_world_sharded::<i32, _, _>(8, timing, mapping, |comm| {
                // one intra-pair and one cross-pair exchange per rank
                comm.sendrecv(comm.rank() ^ 1, DataBuf::real(vec![comm.rank() as i32; 100]))?;
                comm.sendrecv(comm.rank() ^ 4, DataBuf::real(vec![0i32; 50]))?;
                Ok(comm.time_us())
            })
            .unwrap()
        };
        let flat = run(None);
        let sharded = run(Some(Mapping::Block { ranks_per_node: 2 }));
        assert_eq!(
            flat.max_vtime_us.to_bits(),
            sharded.max_vtime_us.to_bits()
        );
        for (a, b) in flat.results.iter().zip(&sharded.results) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
