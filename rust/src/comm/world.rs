//! Spawn a world of `p` rank threads and run a closure per rank.

use std::sync::Arc;
use std::thread;

use super::barrier::VBarrier;
use super::metrics::RankMetrics;
use super::thread::{Registry, ThreadComm, Timing};
use super::Comm;
use crate::error::{Error, Result};
use crate::ops::Elem;

/// The outcome of a world run.
#[derive(Debug)]
pub struct WorldReport<R> {
    /// Per-rank closure results, indexed by rank.
    pub results: Vec<R>,
    /// Max over ranks of the final virtual clock, in µs (0 for real timing).
    pub max_vtime_us: f64,
    /// Wall-clock duration of the whole run, in µs.
    pub wall_us: f64,
    /// Per-rank traffic counters.
    pub metrics: Vec<RankMetrics>,
}

impl<R> WorldReport<R> {
    /// Aggregate counters over all ranks.
    pub fn total_metrics(&self) -> RankMetrics {
        let mut total = RankMetrics::default();
        for m in &self.metrics {
            total.merge(m);
        }
        total
    }
}

/// Run `f(rank_endpoint)` on `p` threads and collect results.
///
/// Threads get 1 MiB stacks (the collectives are iterative, not recursive),
/// so worlds up to the paper's p = 1152 are cheap. A panic or error on any
/// rank tears the world down: channel disconnects propagate as
/// `Error::Disconnected` to peers, and the first rank error is returned.
pub fn run_world<E, R, F>(p: usize, timing: Timing, f: F) -> Result<WorldReport<R>>
where
    E: Elem,
    R: Send + 'static,
    F: Fn(&mut ThreadComm<E>) -> Result<R> + Send + Sync + 'static,
{
    if p == 0 {
        return Err(Error::Config("world size must be >= 1".into()));
    }
    let registry = Arc::new(Registry::new(p));
    let barrier = Arc::new(VBarrier::new(p));
    let f = Arc::new(f);
    let start = std::time::Instant::now();

    let mut handles = Vec::with_capacity(p);
    for rank in 0..p {
        let registry = Arc::clone(&registry);
        let barrier = Arc::clone(&barrier);
        let f = Arc::clone(&f);
        let handle = thread::Builder::new()
            .name(format!("rank-{rank}"))
            .stack_size(1 << 20)
            .spawn(move || {
                // poison the world on both error returns and panics, so
                // peers blocked in recv abort promptly
                struct PoisonOnUnwind<E: Elem>(Arc<Registry<E>>);
                impl<E: Elem> Drop for PoisonOnUnwind<E> {
                    fn drop(&mut self) {
                        if std::thread::panicking() {
                            self.0.poison();
                        }
                    }
                }
                let guard = PoisonOnUnwind(Arc::clone(&registry));
                // rank threads are fresh per world, but reset the buffer
                // counters anyway so harvested stats cover exactly this run
                let _ = crate::buffer::pool::take_stats();
                let mut comm = ThreadComm::new(rank, p, Arc::clone(&registry), barrier, timing);
                let result = match f(&mut comm) {
                    Ok(r) => r,
                    Err(e) => {
                        registry.poison();
                        return Err(e);
                    }
                };
                drop(guard);
                let mut metrics = comm.metrics().clone();
                metrics.absorb_buffer_stats(&crate::buffer::pool::take_stats());
                Ok::<_, Error>((result, comm.vtime(), metrics))
            })
            .map_err(Error::Io)?;
        handles.push(handle);
    }

    let mut results = Vec::with_capacity(p);
    let mut metrics = Vec::with_capacity(p);
    let mut max_vtime = 0.0f64;
    let mut first_err: Option<Error> = None;
    for (rank, handle) in handles.into_iter().enumerate() {
        match handle.join() {
            Ok(Ok((r, vtime, m))) => {
                max_vtime = max_vtime.max(vtime);
                results.push(r);
                metrics.push(m);
            }
            Ok(Err(e)) => {
                // Disconnected errors are usually poison fallout from some
                // other rank's failure — prefer reporting the root cause.
                match (&first_err, &e) {
                    (None, _) | (Some(Error::Disconnected { .. }), _)
                        if !matches!(e, Error::Disconnected { .. })
                            || first_err.is_none() =>
                    {
                        first_err = Some(e)
                    }
                    _ => {}
                }
            }
            Err(_) => {
                let e = Error::Protocol(format!("rank {rank} panicked"));
                if !matches!(first_err, Some(ref f) if !matches!(f, Error::Disconnected { .. }))
                {
                    first_err = Some(e);
                }
            }
        }
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    Ok(WorldReport {
        results,
        max_vtime_us: max_vtime * 1e6,
        wall_us: start.elapsed().as_secs_f64() * 1e6,
        metrics,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::buffer::DataBuf;
    use crate::comm::Comm;
    use crate::model::{ComputeCost, CostModel, LinkCost};

    #[test]
    fn ranks_see_distinct_ids() {
        let report = run_world::<i32, _, _>(5, Timing::Real, |comm| Ok(comm.rank())).unwrap();
        assert_eq!(report.results, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn neighbor_exchange_world() {
        // even ranks exchange with rank+1
        let report = run_world::<i32, _, _>(6, Timing::Real, |comm| {
            let r = comm.rank();
            let peer = if r % 2 == 0 { r + 1 } else { r - 1 };
            let got = comm.sendrecv(peer, DataBuf::real(vec![r as i32]))?;
            Ok(got.into_vec()?[0])
        })
        .unwrap();
        assert_eq!(report.results, vec![1, 0, 3, 2, 5, 4]);
        let total = report.total_metrics();
        assert_eq!(total.sendrecvs, 6);
        assert_eq!(total.bytes_sent, 24);
    }

    #[test]
    fn virtual_time_ping_chain() {
        // rank 0 -> 1 -> 2: rank 1 finishes receiving at α and its forward
        // occupies [α, 2α]; rank 2's receive completes at 2α (store &
        // forward, ports busy back-to-back)
        let cost = CostModel::Uniform(LinkCost::new(1e-6, 0.0));
        let timing = Timing::Virtual(cost, ComputeCost::new(0.0));
        let report = run_world::<i32, _, _>(3, timing, |comm| {
            match comm.rank() {
                0 => comm.send(1, DataBuf::real(vec![1]))?,
                1 => {
                    let b = comm.recv(0)?;
                    comm.send(2, b)?;
                }
                _ => {
                    comm.recv(1)?;
                }
            }
            Ok(())
        })
        .unwrap();
        assert!((report.max_vtime_us - 2.0).abs() < 1e-6);
    }

    #[test]
    fn error_propagates() {
        let r = run_world::<i32, _, _>(2, Timing::Real, |comm| {
            if comm.rank() == 0 {
                Err(crate::error::Error::Protocol("boom".into()))
            } else {
                // rank 1 blocks on a recv that will disconnect
                let _ = comm.recv(0);
                Ok(())
            }
        });
        assert!(r.is_err());
    }

    #[test]
    fn barrier_syncs_clocks() {
        let cost = CostModel::Uniform(LinkCost::new(1e-6, 0.0));
        let timing = Timing::Virtual(cost, ComputeCost::new(0.0));
        let report = run_world::<i32, _, _>(4, timing, |comm| {
            // rank r does r sends' worth of local charge via compute? use
            // sendrecv pairs instead: rank 0/1 exchange twice; 2/3 once.
            let r = comm.rank();
            let peer = r ^ 1;
            let n = if r < 2 { 2 } else { 1 };
            for _ in 0..n {
                comm.sendrecv(peer, DataBuf::real(vec![0i32]))?;
            }
            comm.barrier()?;
            Ok(comm.time_us())
        })
        .unwrap();
        // all clocks equal the max (2µs) after the barrier
        for t in report.results {
            assert!((t - 2.0).abs() < 1e-9, "t={t}");
        }
    }
}
