//! The shared network-resource layer under the transport: per-node NIC
//! port timelines and bounded per-edge virtual injection queues.
//!
//! The decentralized scalar-clock scheme (each message carries its
//! sender's clock, the receiver takes a `max`) models every link as
//! dedicated: a rank's transfer can never be delayed by *third-party*
//! traffic. The [`Fabric`] closes that gap. Under a
//! [`CostModel::Congested`](crate::model::CostModel) model:
//!
//! * every **node** owns two port timelines (egress and ingress, a
//!   full-duplex NIC) with `ports_per_node` ports each. An inter-node
//!   transfer reserves the earliest-free port at or after its request
//!   time, so `k` ranks of one node doing simultaneous inter-node
//!   transfers serialize when `k > ports`. Intra-node transfers bypass
//!   the NIC (they are memory traffic).
//! * every directed **edge** has a virtual injection queue of finite
//!   capacity. A message occupies its slot from post until the receiver
//!   finishes receiving it; posting to a full queue advances the
//!   sender's clock to the drain time of the message whose slot it
//!   reuses — finite-NIC-queue backpressure. Because the drain time is
//!   computed by the receiver, the *simulating* sender thread
//!   wall-blocks until that value exists; the wait is bounded by the
//!   same poison polling and watchdog as a blocking receive.
//!
//! With unlimited resources ([`NetParams::is_dedicated`]) the fabric is
//! inert and the transport's timing formulas are the scalar scheme,
//! bit for bit (pinned by `tests/congestion.rs`).
//!
//! **Determinism.** Port reservations are resolved in arrival order
//! under a mutex. Reservation *outcomes* are deterministic functions of
//! the request sequence, but when two ranks race to the same NIC at the
//! same wall instant the sequence itself can vary run to run, so
//! congested virtual times carry scheduling noise of the contention
//! resolution (dedicated runs stay exactly deterministic, and payload
//! *results* are always bitwise deterministic). The congestion bench
//! gate therefore compares against a deliberately conservative
//! baseline.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use crate::model::NetParams;
use crate::obs;
use crate::topo::{node_of, Mapping};

/// Record a [`Stall`](obs::EventKind::Stall) span on `rank`'s timeline:
/// virtual time lost between `from_s` (when the transfer *wanted* to
/// start) and `until_s` (when the fabric actually admitted it). `cause`
/// is one of [`obs::stall_cause`]. No-op unless tracing is enabled and
/// the interval is non-empty — callers on the hot path pay only the
/// relaxed [`obs::enabled`] load.
pub(crate) fn trace_stall(
    rank: usize,
    peer: usize,
    tag: u32,
    cause: u32,
    from_s: f64,
    until_s: f64,
) {
    if !obs::enabled() || until_s <= from_s {
        return;
    }
    let ev = obs::Event::new(obs::EventKind::Stall, rank)
        .peer(peer)
        .tag(tag)
        .aux(cause)
        .span_s(from_s, until_s)
        .wall(obs::wall_now_ns());
    obs::record(ev);
}

/// Recover a fabric lock even if a rank thread panicked while holding it:
/// timeline and queue updates are all-or-nothing under the guard, and the
/// world-level poison flag handles teardown — a secondary panic here would
/// only mask the root cause.
fn relock<T>(r: Result<MutexGuard<'_, T>, PoisonError<MutexGuard<'_, T>>>) -> MutexGuard<'_, T> {
    r.unwrap_or_else(|p| p.into_inner())
}

/// Aggregate occupancy of one simulated node's NIC timelines over a
/// world run (µs of reserved transfer time and transfer counts, per
/// direction). Collected into
/// [`WorldReport::net_occupancy`](super::WorldReport) — empty under a
/// dedicated (non-congested) model.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LinkOccupancy {
    /// Node id under the cost model's mapping.
    pub node: usize,
    /// Total egress transfer time reserved on this node's NIC, µs.
    pub egress_busy_us: f64,
    /// Total ingress transfer time reserved on this node's NIC, µs.
    pub ingress_busy_us: f64,
    /// Number of inter-node transfers leaving this node.
    pub egress_transfers: u64,
    /// Number of inter-node transfers arriving at this node.
    pub ingress_transfers: u64,
}

/// One direction of a node's NIC: `ports` independent timelines; a
/// reservation takes the earliest-free port at or after its request.
struct PortTimeline {
    /// Next-free virtual time per port.
    free: Vec<f64>,
    /// Accumulated reserved transfer seconds.
    busy: f64,
    transfers: u64,
}

impl PortTimeline {
    fn new(ports: usize) -> PortTimeline {
        PortTimeline {
            free: vec![0.0; ports],
            busy: 0.0,
            transfers: 0,
        }
    }

    /// Reserve the earliest-free port: the transfer starts at
    /// `max(request, earliest free)` and occupies that port for `dur`.
    fn reserve(&mut self, request: f64, dur: f64) -> f64 {
        let mut idx = 0;
        for (i, &f) in self.free.iter().enumerate() {
            if f < self.free[idx] {
                idx = i;
            }
        }
        let start = request.max(self.free[idx]);
        self.free[idx] = start + dur;
        self.busy += dur;
        self.transfers += 1;
        start
    }
}

/// One node's full-duplex NIC.
struct NodeNic {
    egress: Mutex<PortTimeline>,
    ingress: Mutex<PortTimeline>,
}

impl NodeNic {
    fn new(ports: usize) -> NodeNic {
        NodeNic {
            egress: Mutex::new(PortTimeline::new(ports)),
            ingress: Mutex::new(PortTimeline::new(ports)),
        }
    }
}

/// The world's shared network resources. Inert (`!is_active`) under a
/// dedicated model: every method is then an identity/no-op and the
/// transport's hot path pays a single boolean check.
pub(crate) struct Fabric {
    net: NetParams,
    /// Rank → node id under the *cost model's* mapping (which may differ
    /// from the registry's shard layout). Empty when inert.
    node_of: Box<[u32]>,
    /// One NIC per node; empty when `ports_per_node` is unlimited.
    nics: Box<[NodeNic]>,
}

impl Fabric {
    /// The inert fabric of a dedicated (or real-time) world.
    pub(super) fn dedicated() -> Fabric {
        Fabric {
            net: NetParams::dedicated(),
            node_of: Box::new([]),
            nics: Box::new([]),
        }
    }

    /// Build the fabric for a `size`-rank world under `net` with the node
    /// layout `mapping`. Dedicated `net` yields the inert fabric.
    pub(super) fn new(size: usize, net: NetParams, mapping: Mapping) -> Fabric {
        if net.is_dedicated() {
            return Fabric::dedicated();
        }
        let node_of: Box<[u32]> = (0..size)
            .map(|r| node_of(mapping, r) as u32)
            .collect();
        let nodes = node_of.iter().copied().max().map_or(0, |n| n as usize + 1);
        let nics: Box<[NodeNic]> = if net.ports_per_node > 0 {
            (0..nodes).map(|_| NodeNic::new(net.ports_per_node)).collect()
        } else {
            Box::new([])
        };
        Fabric {
            net,
            node_of,
            nics,
        }
    }

    /// True when any resource is finite — the transport then routes its
    /// virtual timing through the fabric.
    pub(crate) fn is_active(&self) -> bool {
        !self.net.is_dedicated()
    }

    fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of[a] == self.node_of[b]
    }

    /// The injection-queue capacity of edge `src → dst` (0 = unbounded).
    pub(crate) fn edge_capacity(&self, src: usize, dst: usize) -> usize {
        if !self.is_active() {
            return 0;
        }
        if self.same_node(src, dst) {
            self.net.edge_capacity_intra
        } else {
            self.net.edge_capacity_inter
        }
    }

    /// Reserve an egress slot on `src`'s node for a transfer to `dst`:
    /// returns the transfer's start time `≥ request`. Identity for
    /// intra-node transfers and unlimited ports.
    pub(crate) fn reserve_egress(&self, src: usize, dst: usize, request: f64, dur: f64) -> f64 {
        if self.nics.is_empty() || self.same_node(src, dst) {
            return request;
        }
        let nic = &self.nics[self.node_of[src] as usize];
        relock(nic.egress.lock()).reserve(request, dur)
    }

    /// Reserve an ingress slot on `dst`'s node for a transfer from `src`.
    pub(crate) fn reserve_ingress(&self, src: usize, dst: usize, request: f64, dur: f64) -> f64 {
        if self.nics.is_empty() || self.same_node(src, dst) {
            return request;
        }
        let nic = &self.nics[self.node_of[dst] as usize];
        relock(nic.ingress.lock()).reserve(request, dur)
    }

    /// Per-node NIC occupancy aggregates (empty when no NICs are
    /// modelled).
    pub(super) fn occupancy(&self) -> Vec<LinkOccupancy> {
        self.nics
            .iter()
            .enumerate()
            .map(|(node, nic)| {
                let e = relock(nic.egress.lock());
                let i = relock(nic.ingress.lock());
                LinkOccupancy {
                    node,
                    egress_busy_us: e.busy * 1e6,
                    ingress_busy_us: i.busy * 1e6,
                    egress_transfers: e.transfers,
                    ingress_transfers: i.transfers,
                }
            })
            .collect()
    }
}

/// The sender's view of one post on a bounded edge.
pub(super) struct SlotGrant {
    /// Virtual drain time of the message whose FIFO slot this post
    /// reuses — present once more than `capacity` messages were posted.
    /// The sender's clock may not run ahead of it (backpressure).
    pub(super) freed_at: Option<f64>,
    /// Posted-but-undrained messages at post time, this one included.
    pub(super) depth: u64,
}

/// Why a slot acquisition gave up.
pub(super) enum SlotError {
    /// The world was poisoned while waiting.
    Poisoned,
    /// The watchdog deadline passed — likely protocol deadlock under
    /// backpressure.
    TimedOut,
}

/// Capacities at or above this are treated as unbounded for drain-time
/// recording: no realistic run posts 2³² messages on one directed edge,
/// so such a queue can never fill, and recording every drain of an
/// effectively-unbounded queue would otherwise retain one timestamp per
/// message for the world's lifetime. `post` and `drain` compare against
/// the same constant, so the slot bookkeeping stays consistent.
const EFFECTIVELY_UNBOUNDED: u64 = 1 << 32;

/// True when `capacity` means a queue that records drain times (finite
/// and small enough to ever fill).
fn records_drains(capacity: usize) -> bool {
    capacity > 0 && (capacity as u64) < EFFECTIVELY_UNBOUNDED
}

#[derive(Default)]
struct QueueState {
    posted: u64,
    drained: u64,
    /// Drain times of taken messages not yet consumed by a backpressured
    /// post. FIFO; each post past the capacity pops exactly one front, so
    /// the front always is drain `#(post_index − capacity)`. Length is
    /// `drained − max(0, posted − capacity)`, i.e. bounded by the
    /// capacity once posts outnumber it (and capacities too large to
    /// ever fill skip recording entirely — see [`EFFECTIVELY_UNBOUNDED`]).
    drains: VecDeque<f64>,
}

/// The virtual injection queue of one directed edge. There is exactly
/// one posting thread (the source rank) and one draining thread (the
/// destination rank), both touching the state in their own program
/// order, which is what makes the FIFO slot correspondence exact.
pub(super) struct EdgeQueue {
    state: Mutex<QueueState>,
    cv: Condvar,
}

impl EdgeQueue {
    pub(super) fn new() -> EdgeQueue {
        EdgeQueue {
            state: Mutex::new(QueueState::default()),
            cv: Condvar::new(),
        }
    }

    /// Register a post. With `capacity == 0` this only tracks the queue
    /// depth; otherwise it wall-blocks (in `poll` slices, aborting on
    /// poison or at `deadline`) until the receiver drained the message
    /// whose slot this post needs, and returns that drain time.
    pub(super) fn post(
        &self,
        capacity: usize,
        poisoned: &dyn Fn() -> bool,
        deadline: Instant,
        poll: Duration,
    ) -> Result<SlotGrant, SlotError> {
        let mut st = relock(self.state.lock());
        let index = st.posted;
        st.posted += 1;
        let depth = st.posted - st.drained;
        if !records_drains(capacity) || index < capacity as u64 {
            return Ok(SlotGrant {
                freed_at: None,
                depth,
            });
        }
        loop {
            if let Some(t) = st.drains.pop_front() {
                let depth = st.posted - st.drained;
                return Ok(SlotGrant {
                    freed_at: Some(t),
                    depth,
                });
            }
            if poisoned() {
                return Err(SlotError::Poisoned);
            }
            if Instant::now() > deadline {
                return Err(SlotError::TimedOut);
            }
            let (guard, _timeout) = match self.cv.wait_timeout(st, poll) {
                Ok(pair) => pair,
                Err(p) => p.into_inner(),
            };
            st = guard;
        }
    }

    /// Record that the receiver finished receiving the oldest in-flight
    /// message at virtual time `vtime` (takes happen in FIFO order).
    pub(super) fn drain(&self, capacity: usize, vtime: f64) {
        let mut st = relock(self.state.lock());
        st.drained += 1;
        if records_drains(capacity) {
            st.drains.push_back(vtime);
            self.cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedicated_fabric_is_inert() {
        let f = Fabric::dedicated();
        assert!(!f.is_active());
        let f = Fabric::new(
            8,
            NetParams::dedicated(),
            Mapping::Block { ranks_per_node: 2 },
        );
        assert!(!f.is_active());
        assert!(f.occupancy().is_empty());
    }

    #[test]
    fn port_timeline_serializes() {
        let mut t = PortTimeline::new(1);
        assert_eq!(t.reserve(0.0, 10.0), 0.0);
        // the port is busy until 10: a request at 3 starts at 10
        assert_eq!(t.reserve(3.0, 5.0), 10.0);
        // a request after the backlog starts on time
        assert_eq!(t.reserve(20.0, 1.0), 20.0);
        assert_eq!(t.transfers, 3);
        assert!((t.busy - 16.0).abs() < 1e-12);
    }

    #[test]
    fn port_timeline_picks_earliest_free_port() {
        let mut t = PortTimeline::new(2);
        assert_eq!(t.reserve(0.0, 10.0), 0.0); // port 0 busy till 10
        assert_eq!(t.reserve(1.0, 10.0), 1.0); // port 1 busy till 11
        // both busy: earliest free is port 0 at 10
        assert_eq!(t.reserve(2.0, 1.0), 10.0);
    }

    #[test]
    fn fabric_reserves_only_inter_node() {
        let f = Fabric::new(4, NetParams::ports(1), Mapping::Block { ranks_per_node: 2 });
        assert!(f.is_active());
        // intra-node: identity, no NIC involvement
        assert_eq!(f.reserve_egress(0, 1, 5.0, 10.0), 5.0);
        assert_eq!(f.reserve_ingress(0, 1, 5.0, 10.0), 5.0);
        // inter-node: serialized through node 0's single egress port
        assert_eq!(f.reserve_egress(0, 2, 0.0, 10.0), 0.0);
        assert_eq!(f.reserve_egress(1, 3, 2.0, 10.0), 10.0);
        // ingress is an independent timeline (full duplex)
        assert_eq!(f.reserve_ingress(2, 0, 1.0, 4.0), 1.0);
        let occ = f.occupancy();
        assert_eq!(occ.len(), 2);
        assert!((occ[0].egress_busy_us - 20.0 * 1e6).abs() < 1e-3);
        assert_eq!(occ[0].egress_transfers, 2);
        assert_eq!(occ[0].ingress_transfers, 1);
        assert_eq!(occ[1].egress_transfers, 0);
    }

    #[test]
    fn edge_capacity_levels() {
        let net = NetParams {
            ports_per_node: 0,
            edge_capacity_intra: 7,
            edge_capacity_inter: 2,
        };
        let f = Fabric::new(4, net, Mapping::Block { ranks_per_node: 2 });
        assert!(f.is_active());
        assert_eq!(f.edge_capacity(0, 1), 7);
        assert_eq!(f.edge_capacity(0, 2), 2);
        assert_eq!(f.edge_capacity(3, 2), 7);
        assert_eq!(Fabric::dedicated().edge_capacity(0, 1), 0);
    }

    #[test]
    fn edge_queue_fifo_slots() {
        let q = EdgeQueue::new();
        let never = || false;
        let deadline = Instant::now() + Duration::from_secs(5);
        let poll = Duration::from_millis(5);
        // capacity 2: first two posts are free
        let g = q.post(2, &never, deadline, poll).unwrap();
        assert!(g.freed_at.is_none());
        assert_eq!(g.depth, 1);
        let g = q.post(2, &never, deadline, poll).unwrap();
        assert!(g.freed_at.is_none());
        assert_eq!(g.depth, 2);
        // drains recorded: post 2 reuses message 0's slot, post 3 message 1's
        q.drain(2, 11.0);
        q.drain(2, 22.0);
        let g = q.post(2, &never, deadline, poll).unwrap();
        assert_eq!(g.freed_at, Some(11.0));
        let g = q.post(2, &never, deadline, poll).unwrap();
        assert_eq!(g.freed_at, Some(22.0));
    }

    #[test]
    fn edge_queue_unbounded_tracks_depth_only() {
        let q = EdgeQueue::new();
        let never = || false;
        let deadline = Instant::now() + Duration::from_secs(5);
        let poll = Duration::from_millis(5);
        for i in 0..10u64 {
            let g = q.post(0, &never, deadline, poll).unwrap();
            assert!(g.freed_at.is_none());
            assert_eq!(g.depth, i + 1);
        }
        q.drain(0, 1.0);
        let g = q.post(0, &never, deadline, poll).unwrap();
        assert_eq!(g.depth, 10);
    }

    #[test]
    fn effectively_unbounded_capacity_skips_drain_recording() {
        assert!(records_drains(1));
        assert!(records_drains((1 << 32) - 1));
        assert!(!records_drains(0));
        assert!(!records_drains(1 << 32));
        // a huge capacity behaves like unbounded: posts never wait and
        // drains retain nothing
        let q = EdgeQueue::new();
        let never = || false;
        let deadline = Instant::now() + Duration::from_secs(5);
        let poll = Duration::from_millis(5);
        for _ in 0..4 {
            q.post(1 << 40, &never, deadline, poll).unwrap();
            q.drain(1 << 40, 9.0);
        }
        assert!(q.state.lock().unwrap().drains.is_empty());
        let g = q.post(1 << 40, &never, deadline, poll).unwrap();
        assert!(g.freed_at.is_none());
        assert_eq!(g.depth, 1);
    }

    #[test]
    fn edge_queue_blocks_until_drained() {
        use std::sync::Arc;
        let q = Arc::new(EdgeQueue::new());
        let never = || false;
        let deadline = Instant::now() + Duration::from_secs(30);
        let poll = Duration::from_millis(5);
        q.post(1, &never, deadline, poll).unwrap();
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            q2.drain(1, 7.5);
        });
        // blocks until the drain lands, then returns its time
        let g = q.post(1, &never, deadline, poll).unwrap();
        assert_eq!(g.freed_at, Some(7.5));
        h.join().unwrap();
    }

    #[test]
    fn edge_queue_post_aborts_on_poison_and_deadline() {
        let q = EdgeQueue::new();
        let poll = Duration::from_millis(2);
        let deadline = Instant::now() + Duration::from_secs(5);
        q.post(1, &|| false, deadline, poll).unwrap();
        // poison aborts the wait
        match q.post(1, &|| true, deadline, poll) {
            Err(SlotError::Poisoned) => {}
            _ => panic!("expected poison abort"),
        }
        // an expired deadline times out (fresh queue, slot 0 free, slot 1 waits)
        let q = EdgeQueue::new();
        q.post(1, &|| false, deadline, poll).unwrap();
        let past = Instant::now() - Duration::from_millis(1);
        match q.post(1, &|| false, past, poll) {
            Err(SlotError::TimedOut) => {}
            _ => panic!("expected timeout"),
        }
    }
}
