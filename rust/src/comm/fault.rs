//! Deterministic fault injection for the virtual-clock transport.
//!
//! A [`FaultPlan`] sits under [`Comm`](super::Comm): every message post
//! rolls a seeded hash of `(seed, src, dst, tag, seq, salt)` and may be
//! delayed, duplicated, reordered (held back one message within its
//! `(src, dst, tag)` stream), or transiently dropped — in which case the
//! sender retransmits with bounded backoff. Whole ranks can be slowed
//! down ("stragglers"). All of it perturbs *virtual time and delivery
//! order only*: sequence numbers let the receiver deduplicate and
//! reassemble the exact per-tag FIFO stream, so payloads stay bitwise
//! identical to the fault-free run — unless retries are exhausted, which
//! surfaces as [`Error::RetriesExhausted`](crate::error::Error) and
//! poisons the world (the defined teardown path, never a hang).
//!
//! Determinism: the roll depends only on the plan seed and the message
//! identity, never on wall time or scheduling, so a seeded faulty run is
//! exactly reproducible (pinned by `tests/serving.rs`).

/// Salt values separating the independent fault decisions per message.
const SALT_DELAY: u64 = 1;
const SALT_DELAY_MAG: u64 = 2;
const SALT_DUP: u64 = 3;
const SALT_REORDER: u64 = 4;
const SALT_DROP: u64 = 5;

/// A seeded, deterministic fault-injection plan for one world.
///
/// `FaultPlan::none()` (the `Default`) is inert and compiled out of the
/// transport hot path by a single `is_active()` check.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultPlan {
    /// Seed mixed into every per-message roll.
    pub seed: u64,
    /// Probability a message's virtual arrival is delayed.
    pub delay_prob: f64,
    /// Maximum injected delay in virtual µs (actual delay is uniform in
    /// `[0, delay_us]` per message).
    pub delay_us: f64,
    /// Probability a message is delivered twice (same sequence number;
    /// the receiver drops the duplicate).
    pub dup_prob: f64,
    /// Probability a message is held back and delivered after its
    /// successor within the same `(src, dst, tag)` stream.
    pub reorder_prob: f64,
    /// Probability any single transmission attempt is dropped; the
    /// sender retries with linear backoff up to `max_retries` times.
    pub drop_prob: f64,
    /// Retransmit attempts before giving up with `RetriesExhausted`.
    pub max_retries: u32,
    /// Virtual µs of backoff per retransmit attempt (linear: attempt `k`
    /// waits `k · backoff_us`).
    pub backoff_us: f64,
    /// Every `stall_every`-th rank (1-based: ranks where
    /// `(rank + 1) % stall_every == 0`) is a straggler; 0 disables.
    pub stall_every: usize,
    /// Virtual µs a straggler rank adds to each of its sends.
    pub stall_us: f64,
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan::none()
    }
}

impl FaultPlan {
    /// The inert plan: no faults, zero transport overhead.
    pub const fn none() -> FaultPlan {
        FaultPlan {
            seed: 0,
            delay_prob: 0.0,
            delay_us: 0.0,
            dup_prob: 0.0,
            reorder_prob: 0.0,
            drop_prob: 0.0,
            max_retries: 6,
            backoff_us: 5.0,
            stall_every: 0,
            stall_us: 0.0,
        }
    }

    /// A plan with the given seed and no faults yet (compose with the
    /// builder methods below).
    pub const fn seeded(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            ..FaultPlan::none()
        }
    }

    pub const fn delay(mut self, prob: f64, max_us: f64) -> FaultPlan {
        self.delay_prob = prob;
        self.delay_us = max_us;
        self
    }

    pub const fn duplicate(mut self, prob: f64) -> FaultPlan {
        self.dup_prob = prob;
        self
    }

    pub const fn reorder(mut self, prob: f64) -> FaultPlan {
        self.reorder_prob = prob;
        self
    }

    /// Transient drops with sequence-numbered retransmit.
    pub const fn transient_drop(mut self, prob: f64, max_retries: u32, backoff_us: f64) -> FaultPlan {
        self.drop_prob = prob;
        self.max_retries = max_retries;
        self.backoff_us = backoff_us;
        self
    }

    /// Make every `every`-th rank a straggler adding `us` virtual µs per
    /// send.
    pub const fn stall(mut self, every: usize, us: f64) -> FaultPlan {
        self.stall_every = every;
        self.stall_us = us;
        self
    }

    /// True if any fault mode is enabled — the transport consults this
    /// once per endpoint and skips all fault bookkeeping when inert.
    pub fn is_active(&self) -> bool {
        self.delay_prob > 0.0
            || self.dup_prob > 0.0
            || self.reorder_prob > 0.0
            || self.drop_prob > 0.0
            || (self.stall_every > 0 && self.stall_us > 0.0)
    }

    /// True if `rank` is a designated straggler under this plan.
    pub fn stalled(&self, rank: usize) -> bool {
        self.stall_every > 0 && (rank + 1) % self.stall_every == 0
    }

    /// The deterministic roll in `[0, 1)` for one `(message, decision)`
    /// pair. splitmix64-style finalizer over the identity tuple: good
    /// avalanche, no state, identical on every rank.
    pub fn roll(&self, src: usize, dst: usize, tag: u32, seq: u64, salt: u64) -> f64 {
        let mut z = self
            .seed
            .wrapping_add((src as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .wrapping_add((dst as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F))
            .wrapping_add((tag as u64).wrapping_mul(0x1656_67B1_9E37_79F9))
            .wrapping_add(seq.wrapping_mul(0xD6E8_FEB8_6659_FD93))
            .wrapping_add(salt.wrapping_mul(0xFF51_AFD7_ED55_8CCD));
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        (z >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Injected delay in virtual µs for this message (0 if the delay
    /// roll misses).
    pub fn delay_for(&self, src: usize, dst: usize, tag: u32, seq: u64) -> f64 {
        if self.delay_prob > 0.0 && self.roll(src, dst, tag, seq, SALT_DELAY) < self.delay_prob {
            self.delay_us * self.roll(src, dst, tag, seq, SALT_DELAY_MAG)
        } else {
            0.0
        }
    }

    /// Should this message be delivered twice?
    pub fn duplicates(&self, src: usize, dst: usize, tag: u32, seq: u64) -> bool {
        self.dup_prob > 0.0 && self.roll(src, dst, tag, seq, SALT_DUP) < self.dup_prob
    }

    /// Should this message be held back behind its successor?
    pub fn reorders(&self, src: usize, dst: usize, tag: u32, seq: u64) -> bool {
        self.reorder_prob > 0.0 && self.roll(src, dst, tag, seq, SALT_REORDER) < self.reorder_prob
    }

    /// Is transmission attempt `attempt` (0-based) of this message
    /// dropped?
    pub fn drops(&self, src: usize, dst: usize, tag: u32, seq: u64, attempt: u32) -> bool {
        self.drop_prob > 0.0
            && self.roll(src, dst, tag, seq, SALT_DROP.wrapping_add(attempt as u64))
                < self.drop_prob
    }

    /// Parse a CLI fault list: comma-separated mode names with preset
    /// magnitudes — `delay`, `dup`, `reorder`, `transient-drop`, `stall`,
    /// `all` — e.g. `--faults transient-drop,stall`. Returns `None` on an
    /// unknown mode.
    pub fn parse(list: &str, seed: u64) -> Option<FaultPlan> {
        let mut plan = FaultPlan::seeded(seed);
        for mode in list.split(',') {
            match mode.trim() {
                "" | "none" => {}
                "delay" => plan = plan.delay(0.05, 20.0),
                "dup" => plan = plan.duplicate(0.02),
                "reorder" => plan = plan.reorder(0.02),
                "transient-drop" => plan = plan.transient_drop(0.01, 6, 5.0),
                "stall" => plan = plan.stall(4, 50.0),
                "all" => {
                    plan = plan
                        .delay(0.05, 20.0)
                        .duplicate(0.02)
                        .reorder(0.02)
                        .transient_drop(0.01, 6, 5.0)
                        .stall(4, 50.0)
                }
                _ => return None,
            }
        }
        Some(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inert_by_default() {
        assert!(!FaultPlan::none().is_active());
        assert!(!FaultPlan::default().is_active());
        assert!(FaultPlan::seeded(7).delay(0.1, 5.0).is_active());
        assert!(FaultPlan::seeded(7).stall(4, 10.0).is_active());
        // a stall period with zero magnitude is still inert
        assert!(!FaultPlan::seeded(7).stall(4, 0.0).is_active());
    }

    #[test]
    fn rolls_are_deterministic_and_uniform_ish() {
        let p = FaultPlan::seeded(42).delay(0.5, 10.0);
        let a = p.roll(1, 2, 3, 4, 5);
        let b = p.roll(1, 2, 3, 4, 5);
        assert_eq!(a.to_bits(), b.to_bits());
        assert!((0.0..1.0).contains(&a));
        // different identity -> different roll (avalanche sanity)
        assert_ne!(a.to_bits(), p.roll(1, 2, 3, 5, 5).to_bits());
        assert_ne!(a.to_bits(), p.roll(2, 1, 3, 4, 5).to_bits());
        // the empirical rate tracks the probability
        let hits = (0..10_000)
            .filter(|&s| p.roll(0, 1, 1, s, SALT_DELAY) < 0.5)
            .count();
        assert!((4_000..6_000).contains(&hits), "hits={hits}");
    }

    #[test]
    fn stall_marks_every_nth_rank() {
        let p = FaultPlan::seeded(1).stall(4, 10.0);
        let stalled: Vec<usize> = (0..8).filter(|&r| p.stalled(r)).collect();
        assert_eq!(stalled, vec![3, 7]);
        assert!(!FaultPlan::none().stalled(3));
    }

    #[test]
    fn parse_modes() {
        let p = FaultPlan::parse("transient-drop,stall", 7).unwrap();
        assert!(p.drop_prob > 0.0 && p.stall_every > 0 && p.is_active());
        assert_eq!(p.seed, 7);
        let p = FaultPlan::parse("all", 1).unwrap();
        assert!(p.delay_prob > 0.0 && p.dup_prob > 0.0 && p.reorder_prob > 0.0);
        assert!(!FaultPlan::parse("none", 1).unwrap().is_active());
        assert!(FaultPlan::parse("bogus", 1).is_none());
    }
}
