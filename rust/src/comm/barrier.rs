//! A reusable barrier that additionally computes the maximum of a value
//! contributed by each participant — used to advance all virtual clocks to
//! the global maximum at an `MPI_Barrier` and by the harness to collect the
//! slowest-rank completion time — plus a [`BarrierTable`] that hands every
//! communicator *group* its own lazily created barrier, so sub-communicator
//! barriers have exactly the world barrier's semantics.

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};

struct Inner {
    count: usize,
    generation: u64,
    max: f64,
    result: f64,
}

/// A counting barrier over `n` threads that reduces `max` over the values
/// passed to [`VBarrier::wait`].
pub struct VBarrier {
    n: usize,
    inner: Mutex<Inner>,
    cv: Condvar,
}

impl VBarrier {
    pub fn new(n: usize) -> VBarrier {
        assert!(n >= 1);
        VBarrier {
            n,
            inner: Mutex::new(Inner {
                count: 0,
                generation: 0,
                max: f64::NEG_INFINITY,
                result: 0.0,
            }),
            cv: Condvar::new(),
        }
    }

    /// Block until all `n` participants have called `wait`; returns the
    /// maximum of all contributed values.
    ///
    /// Safe for repeated use: a thread cannot enter generation `g+1` before
    /// returning from generation `g`, so the published result is stable
    /// until everyone has read it.
    pub fn wait(&self, value: f64) -> f64 {
        let mut inner = self.inner.lock().unwrap();
        let gen = inner.generation;
        inner.max = inner.max.max(value);
        inner.count += 1;
        if inner.count == self.n {
            inner.result = inner.max;
            inner.max = f64::NEG_INFINITY;
            inner.count = 0;
            inner.generation += 1;
            self.cv.notify_all();
            inner.result
        } else {
            while inner.generation == gen {
                inner = self.cv.wait(inner).unwrap();
            }
            inner.result
        }
    }
}

/// Lazily created, shared barriers keyed by a group's exact member list
/// plus the communication *tag* of the endpoints synchronizing on it.
///
/// All members of a [`Group`](super::Group) that call a group barrier must
/// agree on the member list (they derive it from the same `Group` value),
/// so the list itself is the rendezvous key: the first caller creates the
/// `VBarrier`, everyone else finds it. The tag keeps concurrent
/// nonblocking operations apart: two in-flight collectives over the *same*
/// group (different tag-space leases — see [`crate::nbc`]) must not share
/// barrier generations, or their waits would interleave. Entries live for
/// the world's lifetime — a table entry is ~the member vector plus one
/// barrier, and the set of distinct `(group, tag)` pairs a run uses is
/// small (node groups × in-flight operations).
pub(super) struct BarrierTable {
    /// Two-level map (member list → tag → barrier) so the hit path — the
    /// common case once a group's barrier exists — looks up with the
    /// borrowed `&[usize]` and allocates nothing.
    inner: Mutex<HashMap<Vec<usize>, HashMap<u32, Arc<VBarrier>>>>,
}

impl BarrierTable {
    pub(super) fn new() -> BarrierTable {
        BarrierTable {
            inner: Mutex::new(HashMap::new()),
        }
    }

    /// The barrier shared by exactly the ranks in `members` on `tag`
    /// (created on first touch; `VBarrier` is reusable across generations).
    pub(super) fn get(&self, members: &[usize], tag: u32) -> Arc<VBarrier> {
        let mut map = self.inner.lock().unwrap();
        if let Some(tags) = map.get_mut(members) {
            if let Some(b) = tags.get(&tag) {
                return Arc::clone(b);
            }
            let b = Arc::new(VBarrier::new(members.len()));
            tags.insert(tag, Arc::clone(&b));
            return b;
        }
        let b = Arc::new(VBarrier::new(members.len()));
        let mut tags = HashMap::new();
        tags.insert(tag, Arc::clone(&b));
        map.insert(members.to_vec(), tags);
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn single_thread() {
        let b = VBarrier::new(1);
        assert_eq!(b.wait(3.5), 3.5);
        assert_eq!(b.wait(1.0), 1.0); // reusable, max reset
    }

    #[test]
    fn computes_max_across_threads() {
        let n = 8;
        let b = Arc::new(VBarrier::new(n));
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let b = Arc::clone(&b);
                thread::spawn(move || b.wait(i as f64))
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), (n - 1) as f64);
        }
    }

    #[test]
    fn table_is_keyed_by_member_list_and_tag() {
        let t = BarrierTable::new();
        let a = t.get(&[0, 2, 4], 0);
        let b = t.get(&[0, 2, 4], 0);
        assert!(Arc::ptr_eq(&a, &b)); // same group + tag → same barrier
        let c = t.get(&[0, 2], 0);
        assert!(!Arc::ptr_eq(&a, &c)); // different group → its own barrier
        let d = t.get(&[0, 2, 4], 7);
        assert!(!Arc::ptr_eq(&a, &d)); // different tag → its own barrier
        // a single-member group's barrier never blocks
        assert_eq!(t.get(&[7], 0).wait(1.5), 1.5);
    }

    #[test]
    fn repeated_generations() {
        let n = 4;
        let b = Arc::new(VBarrier::new(n));
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let b = Arc::clone(&b);
                thread::spawn(move || {
                    let mut results = Vec::new();
                    for round in 0..100u32 {
                        results.push(b.wait((round * 10 + i as u32) as f64));
                    }
                    results
                })
            })
            .collect();
        for h in handles {
            let results = h.join().unwrap();
            for (round, r) in results.into_iter().enumerate() {
                assert_eq!(r, (round * 10 + n - 1) as f64);
            }
        }
    }
}
