//! A reusable barrier that additionally computes the maximum of a value
//! contributed by each participant — used to advance all virtual clocks to
//! the global maximum at an `MPI_Barrier` and by the harness to collect the
//! slowest-rank completion time.

use std::sync::{Condvar, Mutex};

struct Inner {
    count: usize,
    generation: u64,
    max: f64,
    result: f64,
}

/// A counting barrier over `n` threads that reduces `max` over the values
/// passed to [`VBarrier::wait`].
pub struct VBarrier {
    n: usize,
    inner: Mutex<Inner>,
    cv: Condvar,
}

impl VBarrier {
    pub fn new(n: usize) -> VBarrier {
        assert!(n >= 1);
        VBarrier {
            n,
            inner: Mutex::new(Inner {
                count: 0,
                generation: 0,
                max: f64::NEG_INFINITY,
                result: 0.0,
            }),
            cv: Condvar::new(),
        }
    }

    /// Block until all `n` participants have called `wait`; returns the
    /// maximum of all contributed values.
    ///
    /// Safe for repeated use: a thread cannot enter generation `g+1` before
    /// returning from generation `g`, so the published result is stable
    /// until everyone has read it.
    pub fn wait(&self, value: f64) -> f64 {
        let mut inner = self.inner.lock().unwrap();
        let gen = inner.generation;
        inner.max = inner.max.max(value);
        inner.count += 1;
        if inner.count == self.n {
            inner.result = inner.max;
            inner.max = f64::NEG_INFINITY;
            inner.count = 0;
            inner.generation += 1;
            self.cv.notify_all();
            inner.result
        } else {
            while inner.generation == gen {
                inner = self.cv.wait(inner).unwrap();
            }
            inner.result
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    #[test]
    fn single_thread() {
        let b = VBarrier::new(1);
        assert_eq!(b.wait(3.5), 3.5);
        assert_eq!(b.wait(1.0), 1.0); // reusable, max reset
    }

    #[test]
    fn computes_max_across_threads() {
        let n = 8;
        let b = Arc::new(VBarrier::new(n));
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let b = Arc::clone(&b);
                thread::spawn(move || b.wait(i as f64))
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), (n - 1) as f64);
        }
    }

    #[test]
    fn repeated_generations() {
        let n = 4;
        let b = Arc::new(VBarrier::new(n));
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let b = Arc::clone(&b);
                thread::spawn(move || {
                    let mut results = Vec::new();
                    for round in 0..100u32 {
                        results.push(b.wait((round * 10 + i as u32) as f64));
                    }
                    results
                })
            })
            .collect();
        for h in handles {
            let results = h.join().unwrap();
            for (round, r) in results.into_iter().enumerate() {
                assert_eq!(r, (round * 10 + n - 1) as f64);
            }
        }
    }
}
