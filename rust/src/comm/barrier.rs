//! A reusable barrier that additionally computes the maximum of a value
//! contributed by each participant — used to advance all virtual clocks to
//! the global maximum at an `MPI_Barrier` and by the harness to collect the
//! slowest-rank completion time — plus a [`BarrierTable`] that hands every
//! communicator *group* its own lazily created barrier, so sub-communicator
//! barriers have exactly the world barrier's semantics.

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::Duration;

/// Recover a lock even if a participant panicked while holding it — the
/// barrier's state transitions are all-or-nothing under the guard, so the
/// data is consistent; the *world*-level poison flag (checked by
/// [`VBarrier::wait_abortable`]) handles the semantic fallout.
fn relock<T>(r: Result<MutexGuard<'_, T>, PoisonError<MutexGuard<'_, T>>>) -> MutexGuard<'_, T> {
    r.unwrap_or_else(|p| p.into_inner())
}

/// Why an abortable barrier wait gave up.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(super) enum BarrierAbort {
    /// The world was poisoned while waiting (a peer died).
    Poisoned,
    /// The watchdog deadline elapsed with peers still missing.
    TimedOut,
}

struct Inner {
    count: usize,
    generation: u64,
    max: f64,
    result: f64,
}

/// A counting barrier over `n` threads that reduces `max` over the values
/// passed to [`VBarrier::wait`].
pub struct VBarrier {
    n: usize,
    inner: Mutex<Inner>,
    cv: Condvar,
}

impl VBarrier {
    pub fn new(n: usize) -> VBarrier {
        assert!(n >= 1);
        VBarrier {
            n,
            inner: Mutex::new(Inner {
                count: 0,
                generation: 0,
                max: f64::NEG_INFINITY,
                result: 0.0,
            }),
            cv: Condvar::new(),
        }
    }

    /// Block until all `n` participants have called `wait`; returns the
    /// maximum of all contributed values.
    ///
    /// Safe for repeated use: a thread cannot enter generation `g+1` before
    /// returning from generation `g`, so the published result is stable
    /// until everyone has read it.
    pub fn wait(&self, value: f64) -> f64 {
        let mut inner = relock(self.inner.lock());
        let gen = inner.generation;
        inner.max = inner.max.max(value);
        inner.count += 1;
        if inner.count == self.n {
            inner.result = inner.max;
            inner.max = f64::NEG_INFINITY;
            inner.count = 0;
            inner.generation += 1;
            self.cv.notify_all();
            inner.result
        } else {
            while inner.generation == gen {
                inner = relock(self.cv.wait(inner));
            }
            inner.result
        }
    }

    /// [`wait`](VBarrier::wait) that gives up instead of blocking forever:
    /// polls `poisoned()` every `poll` while waiting and aborts after
    /// `deadline` with peers still missing. On abort this participant's
    /// contribution stays registered, so a late-but-alive peer completing
    /// the generation still unblocks everyone else — the aborting thread
    /// just stops listening (the world is being torn down anyway).
    pub(super) fn wait_abortable(
        &self,
        value: f64,
        poisoned: impl Fn() -> bool,
        poll: Duration,
        deadline: Duration,
    ) -> Result<f64, BarrierAbort> {
        let start = std::time::Instant::now();
        let mut inner = relock(self.inner.lock());
        let gen = inner.generation;
        inner.max = inner.max.max(value);
        inner.count += 1;
        if inner.count == self.n {
            inner.result = inner.max;
            inner.max = f64::NEG_INFINITY;
            inner.count = 0;
            inner.generation += 1;
            self.cv.notify_all();
            return Ok(inner.result);
        }
        while inner.generation == gen {
            let (guard, _timeout) = match self.cv.wait_timeout(inner, poll) {
                Ok(pair) => pair,
                Err(p) => p.into_inner(),
            };
            inner = guard;
            if inner.generation != gen {
                break;
            }
            if poisoned() {
                return Err(BarrierAbort::Poisoned);
            }
            if start.elapsed() >= deadline {
                return Err(BarrierAbort::TimedOut);
            }
        }
        Ok(inner.result)
    }
}

/// Lazily created, shared barriers keyed by a group's exact member list
/// plus the communication *tag* of the endpoints synchronizing on it.
///
/// All members of a [`Group`](super::Group) that call a group barrier must
/// agree on the member list (they derive it from the same `Group` value),
/// so the list itself is the rendezvous key: the first caller creates the
/// `VBarrier`, everyone else finds it. The tag keeps concurrent
/// nonblocking operations apart: two in-flight collectives over the *same*
/// group (different tag-space leases — see [`crate::nbc`]) must not share
/// barrier generations, or their waits would interleave. Entries live for
/// the world's lifetime — a table entry is ~the member vector plus one
/// barrier, and the set of distinct `(group, tag)` pairs a run uses is
/// small (node groups × in-flight operations).
pub(super) struct BarrierTable {
    /// Two-level map (member list → tag → barrier) so the hit path — the
    /// common case once a group's barrier exists — looks up with the
    /// borrowed `&[usize]` and allocates nothing.
    inner: Mutex<HashMap<Vec<usize>, HashMap<u32, Arc<VBarrier>>>>,
}

impl BarrierTable {
    pub(super) fn new() -> BarrierTable {
        BarrierTable {
            inner: Mutex::new(HashMap::new()),
        }
    }

    /// The barrier shared by exactly the ranks in `members` on `tag`
    /// (created on first touch; `VBarrier` is reusable across generations).
    pub(super) fn get(&self, members: &[usize], tag: u32) -> Arc<VBarrier> {
        let mut map = relock(self.inner.lock());
        if let Some(tags) = map.get_mut(members) {
            if let Some(b) = tags.get(&tag) {
                return Arc::clone(b);
            }
            let b = Arc::new(VBarrier::new(members.len()));
            tags.insert(tag, Arc::clone(&b));
            return b;
        }
        let b = Arc::new(VBarrier::new(members.len()));
        let mut tags = HashMap::new();
        tags.insert(tag, Arc::clone(&b));
        map.insert(members.to_vec(), tags);
        b
    }

    /// Drop every barrier registered on one of `tags` (epoch reclamation:
    /// all ranks have agreed those tags' endpoints are drained and gone).
    /// Empty member-list entries are removed too, so the table's footprint
    /// is bounded by the *live* `(group, tag)` set.
    pub(super) fn remove_tags(&self, tags: &HashSet<u32>) {
        let mut map = relock(self.inner.lock());
        for per_tag in map.values_mut() {
            per_tag.retain(|t, _| !tags.contains(t));
        }
        map.retain(|_, per_tag| !per_tag.is_empty());
    }

    /// Number of live `(group, tag)` barrier entries (observability for
    /// the soak harness's memory-flatness checks).
    pub(super) fn entries(&self) -> usize {
        relock(self.inner.lock()).values().map(|t| t.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn single_thread() {
        let b = VBarrier::new(1);
        assert_eq!(b.wait(3.5), 3.5);
        assert_eq!(b.wait(1.0), 1.0); // reusable, max reset
    }

    #[test]
    fn computes_max_across_threads() {
        let n = 8;
        let b = Arc::new(VBarrier::new(n));
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let b = Arc::clone(&b);
                thread::spawn(move || b.wait(i as f64))
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), (n - 1) as f64);
        }
    }

    #[test]
    fn table_is_keyed_by_member_list_and_tag() {
        let t = BarrierTable::new();
        let a = t.get(&[0, 2, 4], 0);
        let b = t.get(&[0, 2, 4], 0);
        assert!(Arc::ptr_eq(&a, &b)); // same group + tag → same barrier
        let c = t.get(&[0, 2], 0);
        assert!(!Arc::ptr_eq(&a, &c)); // different group → its own barrier
        let d = t.get(&[0, 2, 4], 7);
        assert!(!Arc::ptr_eq(&a, &d)); // different tag → its own barrier
        // a single-member group's barrier never blocks
        assert_eq!(t.get(&[7], 0).wait(1.5), 1.5);
    }

    #[test]
    fn abortable_wait_completes_when_everyone_shows_up() {
        let n = 4;
        let b = Arc::new(VBarrier::new(n));
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let b = Arc::clone(&b);
                thread::spawn(move || {
                    b.wait_abortable(
                        i as f64,
                        || false,
                        Duration::from_millis(5),
                        Duration::from_secs(10),
                    )
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), Ok((n - 1) as f64));
        }
    }

    #[test]
    fn abortable_wait_aborts_on_poison_and_timeout() {
        let b = VBarrier::new(2); // nobody else ever arrives
        let r = b.wait_abortable(
            1.0,
            || true,
            Duration::from_millis(1),
            Duration::from_secs(10),
        );
        assert_eq!(r, Err(BarrierAbort::Poisoned));
        let b = VBarrier::new(2);
        let r = b.wait_abortable(
            1.0,
            || false,
            Duration::from_millis(1),
            Duration::from_millis(20),
        );
        assert_eq!(r, Err(BarrierAbort::TimedOut));
    }

    #[test]
    fn remove_tags_reclaims_entries() {
        let t = BarrierTable::new();
        let _ = t.get(&[0, 1], 1);
        let _ = t.get(&[0, 1], 2);
        let _ = t.get(&[0, 1, 2], 2);
        let _ = t.get(&[0, 1], 0);
        assert_eq!(t.entries(), 4);
        let gone: HashSet<u32> = [1, 2].into_iter().collect();
        t.remove_tags(&gone);
        assert_eq!(t.entries(), 1); // only ([0,1], 0) survives
        // a reclaimed (group, tag) re-creates a fresh, usable barrier
        assert_eq!(t.get(&[9], 1).wait(2.5), 2.5);
    }

    #[test]
    fn repeated_generations() {
        let n = 4;
        let b = Arc::new(VBarrier::new(n));
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let b = Arc::clone(&b);
                thread::spawn(move || {
                    let mut results = Vec::new();
                    for round in 0..100u32 {
                        results.push(b.wait((round * 10 + i as u32) as f64));
                    }
                    results
                })
            })
            .collect();
        for h in handles {
            let results = h.join().unwrap();
            for (round, r) in results.into_iter().enumerate() {
                assert_eq!(r, (round * 10 + n - 1) as f64);
            }
        }
    }
}
