//! The thread-backed communicator endpoint.
//!
//! Each rank owns a `ThreadComm`. Point-to-point channels (`std::sync::mpsc`,
//! one per directed pair *and tag*) live in a [`ShardedRegistry`]: one
//! dense, local edge table per *node group* (shard) for the default tag 0
//! plus a sparse, striped table for cross-shard and tagged edges. A flat
//! world is the one-shard special case. Delivery is FIFO per
//! `(src, dst, tag)`; distinct tags never reorder each other, which is
//! what lets the nonblocking engine ([`crate::nbc`]) keep several
//! collectives in flight on one world. Endpoints
//! cache the `Arc<Edge>` per peer, so after the first touch of an edge a
//! post is a plain vector index — no registry mutex, no `HashMap` hashing,
//! and no `Sender` clone per post. The mpsc channels are unbounded, so a
//! post never blocks on transport capacity and the blocking structure of
//! the algorithms (which the paper designed for `MPI_Sendrecv`) cannot
//! deadlock as long as every posted receive is eventually matched.
//!
//! Under a congestion-aware cost model ([`CostModel::Congested`]) the
//! *virtual* timing of every operation routes through the world's
//! [`Fabric`](super::net): edges acquire bounded-injection-queue slots
//! (backpressure advances the sender's clock to the drain time of the
//! slot it reuses — and wall-blocks the simulating thread until the
//! receiver computed that time, bounded by the same poison polling and
//! watchdog as a blocking receive), and inter-node transfers reserve
//! start times on the sender node's egress and the receiver node's
//! ingress NIC port timelines. With a dedicated model the fabric is
//! inert and every formula below is the decentralized scalar-clock
//! scheme, bit for bit.
//!
//! Sharding matters at scale: the old single dense `p × p` table preallocates
//! `p²` slots from one arena (256 MiB of slots at p = 4096), while the
//! sharded form preallocates only `Σ kᵢ²` intra-node slots (one independent
//! arena per node group) and materializes cross-node edges on demand — the
//! collectives only ever touch O(p log p) of them.
//!
//! Messages carry [`DataBuf`]s directly — with the zero-copy buffer layer
//! (see [`crate::buffer`]) a posted block is a reference-counted view of
//! the sender's slab, so the steady-state block path moves no payload
//! bytes at all: the receiver reduces straight out of the sender's memory.

use std::any::{Any, TypeId};
use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::Instant;

use super::barrier::{BarrierAbort, BarrierTable, VBarrier};
use super::fault::FaultPlan;
use super::group::{Group, SubComm};
use super::metrics::RankMetrics;
use super::net::{EdgeQueue, Fabric, SlotError};
use super::Comm;
use crate::buffer::DataBuf;
use crate::error::{Error, Result};
use crate::model::{ComputeCost, CostModel, NetParams};
use crate::obs;
use crate::ops::Elem;
use crate::topo::Mapping;

/// How time is accounted.
#[derive(Clone, Copy, Debug)]
pub enum Timing {
    /// Wall-clock (the run is the measurement).
    Real,
    /// Virtual clocks charged under the given cost model (the run is a
    /// simulation of the paper's cluster).
    Virtual(CostModel, ComputeCost),
}

impl Timing {
    /// Virtual timing with the calibrated "Hydra" uniform model and the
    /// default γ.
    pub fn hydra() -> Timing {
        Timing::Virtual(CostModel::hydra_uniform(), ComputeCost::new(0.25e-9))
    }

    pub fn is_virtual(&self) -> bool {
        matches!(self, Timing::Virtual(..))
    }

    /// Upgrade a virtual timing to the congestion-aware model (see
    /// [`CostModel::with_net`]); `default_mapping` supplies the node
    /// layout when the cost model has none. Identity for real timing
    /// (congestion is a virtual-clock feature — real runs take the time
    /// they take) and for dedicated `net`.
    pub fn with_net(self, net: NetParams, default_mapping: Mapping) -> Timing {
        match self {
            Timing::Virtual(model, compute) => {
                Timing::Virtual(model.with_net(net, default_mapping), compute)
            }
            Timing::Real => Timing::Real,
        }
    }
}

/// Recover a lock even if another endpoint's thread panicked while
/// holding it: registry tables mutate under the guard all-or-nothing, so
/// the data is consistent — the world-level poison flag handles the
/// semantic fallout, and lock recovery keeps teardown itself from
/// cascading panics.
fn relock<T>(r: Result<MutexGuard<'_, T>, PoisonError<MutexGuard<'_, T>>>) -> MutexGuard<'_, T> {
    r.unwrap_or_else(|p| p.into_inner())
}

/// A message on the wire: payload plus the virtual time the transfer
/// leaves the sender (ignored under real timing). Under the dedicated
/// model this is the sender's clock at the time of posting; under a
/// congested model it is the fabric-admitted start time (after
/// backpressure and the egress-port reservation). The payload is
/// typically a zero-copy view of the sender's slab. `seq` numbers the
/// `(src, dst, tag)` stream so a fault-injected transport ([`FaultPlan`])
/// can duplicate and reorder deliveries while the receiver still
/// reassembles the exact FIFO stream; always 0 when faults are inert.
struct Msg<E: Elem> {
    vtime: f64,
    seq: u64,
    data: DataBuf<E>,
}

/// One directed channel of the edge table.
///
/// The `Sender` sits here unguarded: `std::sync::mpsc::Sender` is `Sync`
/// (Rust ≥ 1.72), so endpoints send through a shared reference without
/// cloning. The `Receiver` half is claimed exactly once by the destination
/// rank. The mpsc channel itself stays unbounded — `queue` is the
/// *virtual* injection queue of the congestion model, touched only when
/// the world's fabric is active.
struct Edge<E: Elem> {
    sender: Sender<Msg<E>>,
    receiver: Mutex<Option<Receiver<Msg<E>>>>,
    queue: EdgeQueue,
}

fn new_edge<E: Elem>() -> Arc<Edge<E>> {
    let (s, r) = channel();
    Arc::new(Edge {
        sender: s,
        receiver: Mutex::new(Some(r)),
        queue: EdgeQueue::new(),
    })
}

/// One node group's dense intra-shard edge table over *local* indices —
/// its own independent allocation, so large worlds stop serializing p²
/// slots through a single arena. Slot `(ls, ld)` lives at `ls * k + ld`;
/// each slot is a lazily initialized `OnceLock` and lookup after first
/// touch is lock-free.
struct ShardTable<E: Elem> {
    size: usize,
    edges: Box<[OnceLock<Arc<Edge<E>>>]>,
}

impl<E: Elem> ShardTable<E> {
    fn new(size: usize) -> ShardTable<E> {
        ShardTable {
            size,
            edges: (0..size * size).map(|_| OnceLock::new()).collect(),
        }
    }

    fn edge(&self, ls: usize, ld: usize) -> &Arc<Edge<E>> {
        debug_assert!(ls < self.size && ld < self.size);
        self.edges[ls * self.size + ld].get_or_init(new_edge)
    }
}

/// Lock stripes of the sparse cross-shard / tagged edge table.
const INTER_STRIPES: usize = 64;

/// One stripe's worth of sparse edges, keyed by global `(src, dst, tag)`.
type InterMap<E> = HashMap<(usize, usize, u32), Arc<Edge<E>>>;

/// Sparse edges, keyed by global `(src, dst, tag)` and created on first
/// touch: the cross-shard edges of the default tag 0 plus *every* edge of
/// a non-zero tag (tagged traffic is nonblocking-collective traffic —
/// a handful of in-flight operations touching O(p log p) pairs each, so
/// dense per-tag tables would be pure waste). The stripe lock is only
/// taken on an endpoint's *first* touch of an edge — after that the
/// endpoint's `Arc` cache serves lookups without any shared state.
struct InterTable<E: Elem> {
    stripes: Box<[Mutex<InterMap<E>>]>,
}

impl<E: Elem> InterTable<E> {
    fn new() -> InterTable<E> {
        InterTable {
            stripes: (0..INTER_STRIPES)
                .map(|_| Mutex::new(InterMap::new()))
                .collect(),
        }
    }

    fn edge(&self, src: usize, dst: usize, tag: u32) -> Arc<Edge<E>> {
        let h = src
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(dst)
            .wrapping_add((tag as usize).wrapping_mul(0x517C_C1B7_2722_0A95));
        let mut map = relock(self.stripes[h % INTER_STRIPES].lock());
        Arc::clone(map.entry((src, dst, tag)).or_insert_with(new_edge))
    }

    /// Drop every edge registered on one of `tags`. Sound only after all
    /// ranks agreed the tags are drained (see
    /// [`ShardedRegistry::reclaim_tags`]); a later re-touch of a removed
    /// `(src, dst, tag)` creates a fresh edge with a fresh, claimable
    /// receiver, which is exactly what tag recycling needs.
    fn remove_tags(&self, tags: &HashSet<u32>) {
        for stripe in self.stripes.iter() {
            relock(stripe.lock()).retain(|k, _| !tags.contains(&k.2));
        }
    }

    /// Number of live sparse edges (observability: the soak harness
    /// checks this stays flat across epochs).
    fn entries(&self) -> usize {
        self.stripes.iter().map(|s| relock(s.lock()).len()).sum()
    }
}

/// The channel registry backing one logical world: one [`ShardTable`] per
/// node group plus the sparse [`InterTable`] for cross-shard edges, with
/// rank → (shard, local index) translation, the per-group barrier table,
/// and the world poison flag.
///
/// `new(p, None)` is the flat world (a single shard — the previous dense
/// `Registry` exactly); `new(p, Some(mapping))` shards by the mapping's
/// node groups, which is how `run_world` aligns the transport's arenas
/// with the cost model's node layout.
pub(crate) struct ShardedRegistry<E: Elem> {
    size: usize,
    /// Global rank → shard id.
    shard_of: Box<[u32]>,
    /// Global rank → local index within its shard.
    local_of: Box<[u32]>,
    shards: Box<[ShardTable<E>]>,
    inter: InterTable<E>,
    /// The world's shared network resources (NIC port timelines, edge
    /// capacities) — inert unless the cost model is congestion-aware.
    fabric: Fabric,
    /// Per-group barriers for sub-communicators (see [`BarrierTable`]).
    barriers: BarrierTable,
    /// The world's fault-injection plan (inert by default); endpoints
    /// copy it at construction.
    faults: FaultPlan,
    /// Set when any rank fails; blocked receivers notice within
    /// [`POISON_POLL`] and abort instead of waiting forever (the registry
    /// itself keeps unclaimed `Sender`s alive, so a dead peer would not
    /// disconnect the channel).
    poisoned: std::sync::atomic::AtomicBool,
    /// World-shared singletons anchored by type (see
    /// [`ShardedRegistry::anchored`]): the schedule engine's progress
    /// core lives here so all ranks of a world drive one shared state
    /// without threading it through every construction path.
    anchor: Mutex<HashMap<TypeId, Arc<dyn Any + Send + Sync>>>,
}

/// Poll interval for poison detection on blocked receives.
const POISON_POLL: std::time::Duration = std::time::Duration::from_millis(20);

/// The watchdog never exceeds this (~136 years — effectively disabled):
/// `Instant + Duration` panics on overflow for huge durations, and an
/// operator setting an enormous `DPDR_RECV_TIMEOUT_SECS` means "never
/// fire", not "panic on the first blocking wait".
const MAX_WATCHDOG_SECS: u64 = 1 << 32;

/// Watchdog budget in seconds: the env-configurable base, scaled up with
/// the world size — a p = 1152 world legitimately has protocol phases
/// (and, under bounded edges, backpressure stalls) that outlast a small
/// world's budget on a loaded CI machine. The base covers worlds up to
/// 512 ranks; every further 512 ranks add another base's worth.
fn watchdog_secs(base: u64, world: usize) -> u64 {
    base.saturating_mul(1 + world as u64 / 512)
        .min(MAX_WATCHDOG_SECS)
}

/// How long a blocked receive (or a backpressured post) may wall-block
/// before we declare a protocol deadlock. The base (default 60 s) comes
/// from `DPDR_RECV_TIMEOUT_SECS` — read per endpoint construction, so
/// tests and operators can adjust it between worlds — and is scaled with
/// the world size by [`watchdog_secs`].
fn recv_watchdog(world: usize) -> std::time::Duration {
    let base = std::env::var("DPDR_RECV_TIMEOUT_SECS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(60);
    std::time::Duration::from_secs(watchdog_secs(base, world))
}

impl<E: Elem> ShardedRegistry<E> {
    /// A registry with the inert (dedicated) fabric — the idealized
    /// transport of the paper's model.
    pub(super) fn new(size: usize, mapping: Option<Mapping>) -> ShardedRegistry<E> {
        ShardedRegistry::with_fabric(size, mapping, Fabric::dedicated())
    }

    /// A registry whose virtual timing routes through `fabric` (built by
    /// `run_world` from the cost model's [`NetParams`]).
    pub(super) fn with_fabric(
        size: usize,
        mapping: Option<Mapping>,
        fabric: Fabric,
    ) -> ShardedRegistry<E> {
        ShardedRegistry::with_faults(size, mapping, fabric, FaultPlan::none())
    }

    /// The fully general registry: fabric plus a fault-injection plan
    /// applied by every endpoint of this world.
    pub(super) fn with_faults(
        size: usize,
        mapping: Option<Mapping>,
        fabric: Fabric,
        faults: FaultPlan,
    ) -> ShardedRegistry<E> {
        let groups: Vec<Vec<usize>> = match mapping {
            Some(m) => m.shards(size),
            None => vec![(0..size).collect()],
        };
        let mut shard_of = vec![0u32; size];
        let mut local_of = vec![0u32; size];
        let mut shards = Vec::with_capacity(groups.len());
        for (si, g) in groups.iter().enumerate() {
            for (li, &r) in g.iter().enumerate() {
                shard_of[r] = si as u32;
                local_of[r] = li as u32;
            }
            shards.push(ShardTable::new(g.len()));
        }
        ShardedRegistry {
            size,
            shard_of: shard_of.into_boxed_slice(),
            local_of: local_of.into_boxed_slice(),
            shards: shards.into_boxed_slice(),
            inter: InterTable::new(),
            fabric,
            barriers: BarrierTable::new(),
            faults,
            poisoned: std::sync::atomic::AtomicBool::new(false),
            anchor: Mutex::new(HashMap::new()),
        }
    }

    /// The world's network-resource fabric.
    pub(crate) fn fabric(&self) -> &Fabric {
        &self.fabric
    }

    /// The world-shared singleton of type `T`, created by `init` on first
    /// touch. All ranks calling with the same `T` get the same `Arc` —
    /// the schedule engine anchors its per-world progress core here.
    pub(crate) fn anchored<T: Any + Send + Sync>(&self, init: impl FnOnce() -> T) -> Arc<T> {
        let mut map = relock(self.anchor.lock());
        let entry = map
            .entry(TypeId::of::<T>())
            .or_insert_with(|| Arc::new(init()) as Arc<dyn Any + Send + Sync>);
        Arc::clone(entry)
            .downcast::<T>()
            .expect("anchored entry keyed by TypeId matches its type")
    }

    /// Number of shards (node groups) backing this world.
    pub(super) fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The shard (node group) hosting `rank`.
    pub(super) fn shard_of(&self, rank: usize) -> usize {
        self.shard_of[rank] as usize
    }

    /// Mark the world failed (called when a rank errors or panics).
    pub(crate) fn poison(&self) {
        self.poisoned
            .store(true, std::sync::atomic::Ordering::Release);
    }

    pub(crate) fn is_poisoned(&self) -> bool {
        self.poisoned.load(std::sync::atomic::Ordering::Acquire)
    }

    /// The edge `(src, dst)` on `tag`, creating its channel on first
    /// touch: dense shard-local slot when both ends share a node group
    /// *and* the tag is the default 0 (the blocking-collective hot path,
    /// unchanged), sparse striped entry otherwise. Per-edge delivery is
    /// FIFO *per tag*: each `(src, dst, tag)` triple owns its own mpsc
    /// channel, so messages of different tags never reorder each other.
    /// Endpoints cache the returned `Arc` per peer, so this runs once per
    /// (endpoint, peer) pair.
    fn edge(&self, src: usize, dst: usize, tag: u32) -> Arc<Edge<E>> {
        debug_assert!(src < self.size && dst < self.size);
        let (ss, sd) = (self.shard_of[src], self.shard_of[dst]);
        if tag == 0 && ss == sd {
            Arc::clone(self.shards[ss as usize].edge(
                self.local_of[src] as usize,
                self.local_of[dst] as usize,
            ))
        } else {
            self.inter.edge(src, dst, tag)
        }
    }

    /// Claim the receive half of edge `(src, dst)` on `tag`; each
    /// endpoint may do this exactly once — which is why a tag may never
    /// be reused by a later operation within a world *epoch* (see the
    /// tag-lifecycle rules in [`crate::nbc`]; after
    /// [`ShardedRegistry::reclaim_tags`] the edge is gone and a re-touch
    /// creates a fresh, claimable one). A double claim is a protocol
    /// error, not a panic: under serving traffic it means a tag was
    /// recycled before its quiesce point, and the caller surfaces it.
    fn receiver(&self, src: usize, dst: usize, tag: u32) -> Result<Receiver<Msg<E>>> {
        relock(self.edge(src, dst, tag).receiver.lock())
            .take()
            .ok_or_else(|| {
                Error::Protocol(format!(
                    "receiver ({src}, {dst}, tag {tag}) claimed twice — \
                     one endpoint per rank and tag"
                ))
            })
    }

    /// The barrier shared by exactly the ranks in `members` on `tag`.
    fn group_barrier(&self, members: &[usize], tag: u32) -> Arc<VBarrier> {
        self.barriers.get(members, tag)
    }

    /// Drop every sparse edge and every group barrier registered on one
    /// of `tags`, returning the channel map to its pre-lease footprint.
    ///
    /// Soundness contract (enforced by the nbc engine's quiesce): *all*
    /// ranks have joined the workers of every operation leased on these
    /// tags and then synchronized on a world barrier — so every message
    /// on the tags is consumed, no endpoint holds a cached `Arc<Edge>`
    /// for them (worker forks died with their ops), and no rank can post
    /// on them again until the tag is re-leased. Removal is idempotent.
    pub(super) fn reclaim_tags(&self, tags: &HashSet<u32>) {
        self.inter.remove_tags(tags);
        self.barriers.remove_tags(tags);
    }

    /// Live sparse (tagged + cross-shard) edge entries.
    pub(super) fn tagged_entries(&self) -> usize {
        self.inter.entries()
    }

    /// Live `(group, tag)` barrier entries.
    pub(super) fn barrier_entries(&self) -> usize {
        self.barriers.entries()
    }
}

/// One rank's endpoint.
///
/// An endpoint is bound to one message *tag* (default 0). All endpoints of
/// one rank share the world's registry — and therefore its congestion
/// fabric: NIC port timelines are per *node*, so concurrent operations on
/// different tags contend for the same ports — but each tag owns disjoint
/// channels, receive claims, and injection queues. [`ThreadComm::fork_tagged`]
/// derives an endpoint for another tag; the nonblocking engine
/// ([`crate::nbc`]) runs each in-flight collective on its own fork.
pub struct ThreadComm<E: Elem> {
    rank: usize,
    size: usize,
    /// The message tag this endpoint sends and receives on.
    tag: u32,
    registry: Arc<ShardedRegistry<E>>,
    barrier: Arc<VBarrier>,
    /// Cached outgoing edges, indexed by destination rank (first touch
    /// resolves through the registry; afterwards a post is a vector index).
    tx: Vec<Option<Arc<Edge<E>>>>,
    /// Claimed incoming channels, indexed by source rank.
    rx: Vec<Option<Receiver<Msg<E>>>>,
    /// Cached incoming edges (for drain recording on the congested
    /// fabric), indexed by source rank. Only populated when the fabric is
    /// active.
    rx_edges: Vec<Option<Arc<Edge<E>>>>,
    timing: Timing,
    /// The absolute virtual clock. Never rewound: [`Comm::reset_time`]
    /// moves `origin` instead, so fabric reservations (absolute times)
    /// stay consistent across harness rounds.
    vtime: f64,
    /// Subtracted by [`Comm::time_us`]; set by [`Comm::reset_time`].
    origin: f64,
    start: Instant,
    /// Watchdog budget for blocking waits, scaled to this world's size.
    watchdog: std::time::Duration,
    /// Cached world barrier of a tagged fork (`tag != 0` cannot share the
    /// rank endpoints' `barrier` generations); resolved through the
    /// group-barrier table on first use so repeated barriers allocate
    /// nothing.
    tagged_world_barrier: Option<Arc<VBarrier>>,
    /// The world's fault plan, copied from the registry. When inert the
    /// four per-peer fault vectors below stay *empty* (zero footprint,
    /// one branch on the hot path).
    faults: FaultPlan,
    /// Next sequence number per destination peer.
    tx_seq: Vec<u64>,
    /// Next expected sequence number per source peer.
    rx_want: Vec<u64>,
    /// Early (reordered-ahead) messages parked until their predecessors
    /// arrive, per source peer.
    rx_held: Vec<BTreeMap<u64, Msg<E>>>,
    /// A message held back by the reorder fault, per destination peer —
    /// sent after its successor, or at the next flush point (blocking
    /// receive, barrier, endpoint drop) so it can never be lost or
    /// deadlock a reply cycle.
    tx_held: Vec<Option<Msg<E>>>,
    /// Tracing sequence counters (`crate::obs`), allocated lazily on
    /// the first traced transfer so the disabled path stays
    /// allocation-free. Independent of the fault-layer `tx_seq` (which
    /// is 0-sized when faults are inert).
    obs_seq: Option<Box<ObsSeqs>>,
    metrics: RankMetrics,
}

/// Per-peer send/recv sequence counters for trace flow linking: the
/// k-th traced send on a `(rank, tag) → peer` stream pairs with the
/// k-th traced receive on the peer's endpoint. Counted per endpoint in
/// program order, so they are deterministic under virtual timing.
struct ObsSeqs {
    tx: Vec<u64>,
    rx: Vec<u64>,
}

impl<E: Elem> ThreadComm<E> {
    pub(super) fn new(
        rank: usize,
        size: usize,
        registry: Arc<ShardedRegistry<E>>,
        barrier: Arc<VBarrier>,
        timing: Timing,
    ) -> ThreadComm<E> {
        let shard_id = registry.shard_of(rank) as u32;
        let faults = registry.faults;
        let fp = if faults.is_active() { size } else { 0 };
        ThreadComm {
            rank,
            size,
            tag: 0,
            registry,
            barrier,
            tx: (0..size).map(|_| None).collect(),
            rx: (0..size).map(|_| None).collect(),
            rx_edges: (0..size).map(|_| None).collect(),
            timing,
            vtime: 0.0,
            origin: 0.0,
            start: Instant::now(),
            watchdog: recv_watchdog(size),
            tagged_world_barrier: None,
            faults,
            tx_seq: vec![0; fp],
            rx_want: vec![0; fp],
            rx_held: (0..fp).map(|_| BTreeMap::new()).collect(),
            tx_held: (0..fp).map(|_| None).collect(),
            obs_seq: None,
            metrics: RankMetrics {
                shard_id,
                ..RankMetrics::default()
            },
        }
    }

    /// Derive an endpoint for the same rank on another message `tag`.
    ///
    /// The fork shares the world's registry (channels are created lazily in
    /// the tag's own namespace) and congestion fabric, inherits this
    /// endpoint's timing mode and *current* virtual clock, and starts with
    /// fresh metrics — the nonblocking engine merges them back with
    /// [`ThreadComm::absorb_child`] when the operation completes. Each
    /// `(rank, tag)` pair may claim its receive channels only once, so a
    /// tag must be forked by at most one operation per world (the engine's
    /// tag-space leases guarantee this).
    pub fn fork_tagged(&self, tag: u32) -> ThreadComm<E> {
        let fp = if self.faults.is_active() { self.size } else { 0 };
        ThreadComm {
            rank: self.rank,
            size: self.size,
            tag,
            registry: Arc::clone(&self.registry),
            barrier: Arc::clone(&self.barrier),
            tx: (0..self.size).map(|_| None).collect(),
            rx: (0..self.size).map(|_| None).collect(),
            rx_edges: (0..self.size).map(|_| None).collect(),
            timing: self.timing,
            vtime: self.vtime,
            origin: self.origin,
            start: Instant::now(),
            watchdog: self.watchdog,
            tagged_world_barrier: None,
            faults: self.faults,
            tx_seq: vec![0; fp],
            rx_want: vec![0; fp],
            rx_held: (0..fp).map(|_| BTreeMap::new()).collect(),
            tx_held: (0..fp).map(|_| None).collect(),
            obs_seq: None,
            metrics: RankMetrics {
                shard_id: self.metrics.shard_id,
                ..RankMetrics::default()
            },
        }
    }

    /// The message tag this endpoint is bound to (0 for world endpoints).
    pub fn tag(&self) -> u32 {
        self.tag
    }

    /// Fold a completed child operation (a [`ThreadComm::fork_tagged`]
    /// endpoint that ran on a worker thread) back into this endpoint: its
    /// traffic counters merge in, and under virtual timing this rank's
    /// clock advances to the operation's completion time — MPI wait
    /// semantics: waiting on a request ends no earlier than the request.
    pub(crate) fn absorb_child(&mut self, metrics: &RankMetrics, child_vtime: f64) {
        self.metrics.merge(metrics);
        if self.timing.is_virtual() && child_vtime > self.vtime {
            self.vtime = child_vtime;
        }
    }

    /// Crate-internal mutable access to the metrics record (the nbc layer
    /// accounts fusion and in-flight peaks here).
    pub(crate) fn metrics_mut(&mut self) -> &mut RankMetrics {
        &mut self.metrics
    }

    /// The world's channel registry (the schedule engine anchors its
    /// shared progress core there and routes fabric reservations and
    /// poison checks through it).
    pub(crate) fn registry(&self) -> &Arc<ShardedRegistry<E>> {
        &self.registry
    }

    /// This endpoint's blocking-wait watchdog budget.
    pub(crate) fn watchdog(&self) -> std::time::Duration {
        self.watchdog
    }

    /// Mark the whole world failed (a nonblocking worker uses this when
    /// its collective errors, so peers blocked on the operation abort
    /// instead of running into the watchdog).
    pub(crate) fn poison_world(&self) {
        self.registry.poison();
    }

    /// Has this world been poisoned (a rank failed or panicked)?
    pub(crate) fn world_poisoned(&self) -> bool {
        self.registry.is_poisoned()
    }

    /// Return the channel and barrier entries of `tags` to the registry
    /// (epoch reclamation; see [`ShardedRegistry::reclaim_tags`] for the
    /// soundness contract the caller must have established).
    pub(crate) fn reclaim_tags(&self, tags: &[u32]) {
        let set: HashSet<u32> = tags.iter().copied().collect();
        self.registry.reclaim_tags(&set);
    }

    /// Live sparse (tagged + cross-shard) channel entries in this world's
    /// registry — the quantity epoch reclamation keeps bounded.
    pub fn tagged_entries(&self) -> usize {
        self.registry.tagged_entries()
    }

    /// Live `(group, tag)` barrier entries in this world's registry.
    pub fn barrier_entries(&self) -> usize {
        self.registry.barrier_entries()
    }

    /// The fault-injection plan this world runs under (inert by default).
    pub fn fault_plan(&self) -> FaultPlan {
        self.faults
    }

    /// Borrow a sub-communicator scoped to `group` (this rank must be a
    /// member). The sub-communicator relabels ranks to `0..group.size()`
    /// and shares this endpoint's clock, metrics, and channels — it is a
    /// view, not a second endpoint, so collectives written against
    /// [`Comm`] run unchanged on rank subsets.
    pub fn sub<'a>(&'a mut self, group: &'a Group) -> Result<SubComm<'a, E>> {
        SubComm::new(self, group)
    }

    /// Synchronize exactly the ranks in `members` (each must call this
    /// with the same list, on endpoints of the same tag); under virtual
    /// timing the member clocks advance to the group maximum, mirroring
    /// the world [`Comm::barrier`].
    pub(super) fn group_barrier_wait(&mut self, members: &[usize]) -> Result<()> {
        self.flush_tx_held();
        let bar = self.registry.group_barrier(members, self.tag);
        let max = self.barrier_wait_abortable(&bar)?;
        if self.timing.is_virtual() {
            self.vtime = max;
        }
        self.metrics.barriers += 1;
        Ok(())
    }

    /// Wait on `bar`, giving up (with a typed error) if the world is
    /// poisoned or the watchdog elapses — a barrier must never outlive
    /// the world it synchronizes.
    fn barrier_wait_abortable(&self, bar: &VBarrier) -> Result<f64> {
        let registry = Arc::clone(&self.registry);
        bar.wait_abortable(
            self.vtime,
            || registry.is_poisoned(),
            POISON_POLL,
            self.watchdog,
        )
        .map_err(|abort| match abort {
            // secondary casualty: report as a disconnect so the harness's
            // root-cause preference keeps the originating rank's error
            BarrierAbort::Poisoned => Error::Disconnected {
                rank: self.rank,
                peer: self.rank,
            },
            BarrierAbort::TimedOut => {
                self.registry.poison();
                Error::PeerStalled {
                    rank: self.rank,
                    peer: self.rank,
                }
            }
        })
    }

    fn check_peer(&self, peer: usize) -> Result<()> {
        if peer >= self.size || peer == self.rank {
            return Err(Error::Config(format!(
                "rank {}: invalid peer {} (size {})",
                self.rank, peer, self.size
            )));
        }
        Ok(())
    }

    /// Next tracing sequence number for the `(self, peer)` stream in
    /// the given direction. Only called while tracing is enabled; the
    /// counters allocate on first use so untraced runs never pay.
    fn obs_next_seq(&mut self, peer: usize, send: bool) -> u64 {
        let size = self.size;
        let seqs = self
            .obs_seq
            .get_or_insert_with(|| Box::new(ObsSeqs { tx: vec![0; size], rx: vec![0; size] }));
        let slot = if send { &mut seqs.tx[peer] } else { &mut seqs.rx[peer] };
        let v = *slot;
        *slot += 1;
        v
    }

    /// Record the transfer-endpoint events of one completed p2p call:
    /// `send` = `(bytes, start_s, end_s)` for the outgoing half, `recv`
    /// likewise for the incoming half (start = the `ready` time).
    /// Callers guard with [`obs::enabled`]; `w0` is the wall stamp
    /// captured at op entry.
    fn obs_p2p(
        &mut self,
        send: Option<(usize, usize, f64, f64)>,
        recv: Option<(usize, usize, f64, f64)>,
        w0: u64,
    ) {
        use obs::{Event, EventKind};
        let (rank, tag) = (self.rank, self.tag);
        let w1 = obs::wall_now_ns();
        if let Some((peer, bytes, t0, t1)) = send {
            let seq = self.obs_next_seq(peer, true);
            let ev = Event::new(EventKind::SendStart, rank)
                .peer(peer)
                .tag(tag)
                .seq(seq)
                .bytes(bytes as u64);
            obs::record(ev.at_s(t0).wall(w0));
            obs::record(ev.at_s(t1).wall(w1).with_kind(EventKind::SendEnd));
        }
        if let Some((peer, bytes, t0, t1)) = recv {
            let seq = self.obs_next_seq(peer, false);
            let ev = Event::new(EventKind::RecvStart, rank)
                .peer(peer)
                .tag(tag)
                .seq(seq)
                .bytes(bytes as u64);
            obs::record(ev.at_s(t0).wall(w0));
            obs::record(ev.at_s(t1).wall(w1).with_kind(EventKind::RecvEnd));
        }
        obs::note_vtime_us(self.vtime * 1e6);
    }

    /// Sender-side fabric admission of one outgoing transfer of duration
    /// `dur`: virtual backpressure on the edge's bounded injection queue
    /// (the *simulating* thread wall-blocks until the needed slot's drain
    /// time exists), then an egress-port reservation on this rank's node
    /// NIC. Returns the transfer's start time — exactly the current
    /// clock when the fabric is inert, so the dedicated timing formulas
    /// are unchanged bit for bit.
    fn admit_send(&mut self, peer: usize, dur: f64) -> Result<f64> {
        if !self.registry.fabric().is_active() {
            return Ok(self.vtime);
        }
        let registry = Arc::clone(&self.registry);
        let fabric = registry.fabric();
        let (rank, tag) = (self.rank, self.tag);
        let edge =
            Arc::clone(self.tx[peer].get_or_insert_with(|| registry.edge(rank, peer, tag)));
        let cap = fabric.edge_capacity(rank, peer);
        let deadline = Instant::now() + self.watchdog;
        let grant = edge
            .queue
            .post(cap, &|| registry.is_poisoned(), deadline, POISON_POLL)
            .map_err(|e| match e {
                SlotError::Poisoned => Error::Disconnected { rank, peer },
                SlotError::TimedOut => {
                    // a full edge queue that never drains within the
                    // watchdog is a stalled consumer, whatever the cause
                    registry.poison();
                    Error::PeerStalled { rank, peer }
                }
            })?;
        self.metrics.max_queue_depth = self.metrics.max_queue_depth.max(grant.depth);
        let mut t = self.vtime;
        if let Some(freed) = grant.freed_at {
            if freed > t {
                // genuine backpressure: the queue was still full at this
                // rank's virtual post time
                self.metrics.queue_full_events += 1;
                self.metrics.stall_us += (freed - t) * 1e6;
                super::net::trace_stall(rank, peer, tag, obs::stall_cause::BACKPRESSURE, t, freed);
                t = freed;
            }
        }
        let start = fabric.reserve_egress(rank, peer, t, dur);
        if start > t {
            self.metrics.stall_us += (start - t) * 1e6;
            super::net::trace_stall(rank, peer, tag, obs::stall_cause::EGRESS_PORT, t, start);
        }
        Ok(start)
    }

    /// Receiver-side fabric completion of one incoming transfer that is
    /// ready (message posted and this rank free) at `ready`: an
    /// ingress-port reservation on this rank's node NIC, then the edge
    /// drain record that releases the sender's injection-queue slot.
    /// Returns the transfer's completion time — `ready + dur` exactly
    /// when the fabric is inert.
    fn finish_recv(&mut self, peer: usize, ready: f64, dur: f64) -> f64 {
        if !self.registry.fabric().is_active() {
            return ready + dur;
        }
        let registry = Arc::clone(&self.registry);
        let fabric = registry.fabric();
        let rank = self.rank;
        let start = fabric.reserve_ingress(peer, rank, ready, dur);
        if start > ready {
            self.metrics.stall_us += (start - ready) * 1e6;
            let cause = obs::stall_cause::INGRESS_PORT;
            super::net::trace_stall(rank, peer, self.tag, cause, ready, start);
        }
        let done = start + dur;
        let tag = self.tag;
        let edge =
            Arc::clone(self.rx_edges[peer].get_or_insert_with(|| registry.edge(peer, rank, tag)));
        edge.queue.drain(fabric.edge_capacity(peer, rank), done);
        done
    }

    /// Put one message on the wire to `peer` (no fault processing — the
    /// raw channel send shared by [`ThreadComm::post`] and the held-
    /// message flush paths).
    fn raw_send(&mut self, peer: usize, msg: Msg<E>) -> Result<()> {
        let (rank, tag, registry) = (self.rank, self.tag, &self.registry);
        let edge = self.tx[peer].get_or_insert_with(|| registry.edge(rank, peer, tag));
        edge.sender
            .send(msg)
            .map_err(|_| Error::Disconnected { rank, peer })
    }

    /// Post `data` to `peer`, stamped with the transfer's virtual start
    /// time (fabric-admitted by the caller; the current clock under real
    /// timing). Returns the *effective* sender-side stamp: with faults
    /// inert, exactly `stamp`; under an active [`FaultPlan`], straggler
    /// stalls and retransmit backoff push the sender's transfer later
    /// (and the caller's clock math with it), while in-flight delay,
    /// duplication, and reordering perturb only the message's arrival —
    /// sequence numbers let the receiver reassemble the exact stream.
    fn post(&mut self, peer: usize, data: DataBuf<E>, stamp: f64) -> Result<f64> {
        let bytes = data.bytes();
        if !self.faults.is_active() {
            self.raw_send(peer, Msg { vtime: stamp, seq: 0, data })?;
            self.metrics.bytes_sent += bytes as u64;
            return Ok(stamp);
        }
        let (rank, tag) = (self.rank, self.tag);
        let seq = self.tx_seq[peer];
        self.tx_seq[peer] += 1;
        let mut stamp = stamp;
        // straggler rank: every one of its sends leaves late
        if self.faults.stalled(rank) {
            stamp += self.faults.stall_us * 1e-6;
        }
        // transient drop: retransmit with linear backoff until an attempt
        // goes through; exhausting the budget is a typed teardown
        let mut attempt = 0u32;
        while self.faults.drops(rank, peer, tag, seq, attempt) {
            attempt += 1;
            if attempt > self.faults.max_retries {
                self.poison_world();
                return Err(Error::RetriesExhausted {
                    rank,
                    peer,
                    attempts: attempt,
                });
            }
            stamp += self.faults.backoff_us * attempt as f64 * 1e-6;
            self.metrics.retransmits += 1;
        }
        // in-flight delay pushes the arrival, not the sender
        let delay = self.faults.delay_for(rank, peer, tag, seq);
        if delay > 0.0 {
            self.metrics.fault_events += 1;
        }
        let msg = Msg {
            vtime: stamp + delay * 1e-6,
            seq,
            data,
        };
        // dup and reorder change what is physically on the channel, which
        // the congestion fabric's slot accounting assumes matches the
        // admitted posts — so both apply only on the inert fabric
        let inert_fabric = !self.registry.fabric().is_active();
        if inert_fabric
            && self.tx_held[peer].is_none()
            && self.faults.reorders(rank, peer, tag, seq)
        {
            // hold this message back: its successor (or the next flush
            // point) carries it out behind newer traffic
            self.metrics.fault_events += 1;
            self.tx_held[peer] = Some(msg);
            self.metrics.bytes_sent += bytes as u64;
            return Ok(stamp);
        }
        let dup = inert_fabric && self.faults.duplicates(rank, peer, tag, seq);
        let dup_msg = if dup {
            self.metrics.fault_events += 1;
            Some(Msg {
                vtime: msg.vtime,
                seq,
                data: msg.data.clone(),
            })
        } else {
            None
        };
        self.raw_send(peer, msg)?;
        if let Some(m) = dup_msg {
            self.raw_send(peer, m)?;
        }
        if let Some(held) = self.tx_held[peer].take() {
            self.raw_send(peer, held)?;
        }
        self.metrics.bytes_sent += bytes as u64;
        Ok(stamp)
    }

    /// Send out every reorder-held message. Called before any blocking
    /// receive or barrier (a held message must not starve a reply cycle
    /// this rank is about to wait on) and when the endpoint drops.
    fn flush_tx_held(&mut self) {
        if self.tx_held.is_empty() {
            return;
        }
        for peer in 0..self.size {
            if let Some(msg) = self.tx_held[peer].take() {
                // a dead peer is surfaced by the next blocking call; the
                // flush itself must never fail teardown
                let _ = self.raw_send(peer, msg);
            }
        }
    }

    /// One raw message off the wire from `peer` (fault-oblivious): blocks
    /// in [`POISON_POLL`] slices so a failed world tears down instead of
    /// hanging on receives whose sender died (the registry keeps the
    /// unclaimed `Sender` half alive, so disconnect alone is not enough),
    /// and so protocol deadlocks surface as [`Error::PeerStalled`]
    /// instead of hangs.
    fn take_raw(&mut self, peer: usize) -> Result<Msg<E>> {
        let (rank, tag, registry) = (self.rank, self.tag, &self.registry);
        if self.rx[peer].is_none() {
            self.rx[peer] = Some(registry.receiver(peer, rank, tag)?);
        }
        let rx = self.rx[peer].as_ref().expect("just claimed");
        let deadline = std::time::Instant::now() + self.watchdog;
        loop {
            match rx.recv_timeout(POISON_POLL) {
                Ok(msg) => return Ok(msg),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    if registry.is_poisoned() {
                        return Err(Error::Disconnected { rank, peer });
                    }
                    if std::time::Instant::now() > deadline {
                        registry.poison();
                        return Err(Error::PeerStalled { rank, peer });
                    }
                }
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    return Err(Error::Disconnected { rank, peer })
                }
            }
        }
    }

    /// The next in-order message from `peer`. With faults inert this is
    /// [`ThreadComm::take_raw`] plus byte accounting; under an active
    /// plan it reassembles the sequence-numbered stream — duplicates are
    /// dropped, early messages parked — so the payload stream the caller
    /// sees is bitwise identical to the fault-free run.
    fn take(&mut self, peer: usize) -> Result<Msg<E>> {
        self.flush_tx_held();
        if !self.faults.is_active() {
            let msg = self.take_raw(peer)?;
            self.metrics.bytes_recv += msg.data.bytes() as u64;
            return Ok(msg);
        }
        let want = self.rx_want[peer];
        if let Some(msg) = self.rx_held[peer].remove(&want) {
            self.rx_want[peer] = want + 1;
            self.metrics.bytes_recv += msg.data.bytes() as u64;
            return Ok(msg);
        }
        loop {
            let msg = self.take_raw(peer)?;
            if msg.seq < want {
                // duplicate of an already-delivered message
                self.metrics.fault_events += 1;
                continue;
            }
            if msg.seq == want {
                self.rx_want[peer] = want + 1;
                self.metrics.bytes_recv += msg.data.bytes() as u64;
                return Ok(msg);
            }
            // early successor: park until its predecessors arrive
            self.rx_held[peer].insert(msg.seq, msg);
        }
    }

    /// The *absolute* virtual clock (0 under real timing). Unlike
    /// [`Comm::time_us`] this is never rewound by `reset_time`: fabric
    /// reservations live on absolute timelines, so the clock only moves
    /// forward and the harness measures intervals against `origin`.
    pub fn vtime(&self) -> f64 {
        self.vtime
    }

    /// The timing mode this endpoint runs under.
    pub fn timing(&self) -> Timing {
        self.timing
    }
}

impl<E: Elem> Comm<E> for ThreadComm<E> {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn sendrecv(&mut self, peer: usize, send: DataBuf<E>) -> Result<DataBuf<E>> {
        self.check_peer(peer)?;
        let obs_w0 = if obs::enabled() { obs::wall_now_ns() } else { 0 };
        let sent_bytes = send.bytes();
        let stamp = match self.timing {
            Timing::Virtual(cost, _) => {
                let out_dur = cost.xfer(self.rank, peer, sent_bytes);
                self.admit_send(peer, out_dur)?
            }
            Timing::Real => self.vtime,
        };
        let stamp = self.post(peer, send, stamp)?;
        let msg = self.take(peer)?;
        let mut obs_ready = stamp;
        if let Timing::Virtual(cost, _) = self.timing {
            // Telephone model: both directions complete together; the cost
            // is driven by the larger payload, and both endpoints compute
            // the completion time max(t_a, t_b) + α + β·n (from the
            // fabric-admitted start times t_a, t_b; the ingress port may
            // push the shared transfer later still).
            let bytes = sent_bytes.max(msg.data.bytes());
            let dur = cost.xfer(self.rank, peer, bytes);
            let ready = stamp.max(msg.vtime);
            obs_ready = ready;
            self.vtime = self.finish_recv(peer, ready, dur);
        }
        self.metrics.exchanges += 1;
        self.metrics.sendrecvs += 1;
        if obs::enabled() {
            let end = self.vtime;
            self.obs_p2p(
                Some((peer, sent_bytes, stamp, end)),
                Some((peer, msg.data.bytes(), obs_ready, end)),
                obs_w0,
            );
        }
        Ok(msg.data)
    }

    fn sendrecv_pair(
        &mut self,
        send_to: usize,
        send: DataBuf<E>,
        recv_from: usize,
    ) -> Result<DataBuf<E>> {
        if send_to == recv_from {
            return self.sendrecv(send_to, send);
        }
        self.check_peer(send_to)?;
        self.check_peer(recv_from)?;
        let obs_w0 = if obs::enabled() { obs::wall_now_ns() } else { 0 };
        let sent_bytes = send.bytes();
        let (stamp, out_dur) = match self.timing {
            Timing::Virtual(cost, _) => {
                let out_dur = cost.xfer(self.rank, send_to, sent_bytes);
                (self.admit_send(send_to, out_dur)?, out_dur)
            }
            Timing::Real => (self.vtime, 0.0),
        };
        let stamp = self.post(send_to, send, stamp)?;
        let msg = self.take(recv_from)?;
        let (mut obs_ready, mut obs_in_done) = (stamp, stamp);
        if let Timing::Virtual(cost, _) = self.timing {
            // Full duplex: the outgoing and incoming transfers overlap; the
            // step ends when the longer of the two is done, and the incoming
            // one cannot start before the remote sender's transfer left.
            let out_done = stamp + out_dur;
            let inc_dur = cost.xfer(self.rank, recv_from, msg.data.bytes());
            let ready = stamp.max(msg.vtime);
            let in_done = self.finish_recv(recv_from, ready, inc_dur);
            (obs_ready, obs_in_done) = (ready, in_done);
            self.vtime = out_done.max(in_done);
        }
        self.metrics.exchanges += 1;
        self.metrics.sendrecvs += 1;
        if obs::enabled() {
            self.obs_p2p(
                Some((send_to, sent_bytes, stamp, stamp + out_dur)),
                Some((recv_from, msg.data.bytes(), obs_ready, obs_in_done)),
                obs_w0,
            );
        }
        Ok(msg.data)
    }

    fn send(&mut self, peer: usize, data: DataBuf<E>) -> Result<()> {
        self.check_peer(peer)?;
        let obs_w0 = if obs::enabled() { obs::wall_now_ns() } else { 0 };
        let bytes = data.bytes();
        let (stamp, dur) = match self.timing {
            Timing::Virtual(cost, _) => {
                let dur = cost.xfer(self.rank, peer, bytes);
                (self.admit_send(peer, dur)?, dur)
            }
            Timing::Real => (self.vtime, 0.0),
        };
        let stamp = self.post(peer, data, stamp)?;
        if self.timing.is_virtual() {
            // The sender's port is busy for the full transfer.
            self.vtime = stamp + dur;
        }
        self.metrics.exchanges += 1;
        if obs::enabled() {
            self.obs_p2p(Some((peer, bytes, stamp, stamp + dur)), None, obs_w0);
        }
        Ok(())
    }

    fn recv(&mut self, peer: usize) -> Result<DataBuf<E>> {
        self.check_peer(peer)?;
        let obs_w0 = if obs::enabled() { obs::wall_now_ns() } else { 0 };
        let msg = self.take(peer)?;
        let mut obs_ready = self.vtime;
        if let Timing::Virtual(cost, _) = self.timing {
            // Transfer starts when the sender's transfer left and the
            // receiver is ready — max(t_r, t_s) + α + β·n — possibly
            // pushed later by the ingress port.
            let dur = cost.xfer(self.rank, peer, msg.data.bytes());
            let ready = self.vtime.max(msg.vtime);
            obs_ready = ready;
            self.vtime = self.finish_recv(peer, ready, dur);
        }
        self.metrics.exchanges += 1;
        if obs::enabled() {
            let end = self.vtime;
            self.obs_p2p(None, Some((peer, msg.data.bytes(), obs_ready, end)), obs_w0);
        }
        Ok(msg.data)
    }

    fn barrier(&mut self) -> Result<()> {
        let obs_w0 = if obs::enabled() { obs::wall_now_ns() } else { 0 };
        let obs_v0 = self.vtime;
        self.flush_tx_held();
        // A tagged fork must not share the world barrier's generations
        // with the rank endpoints (or with forks of other tags): it
        // synchronizes through a barrier keyed by (world members, tag),
        // resolved once and cached on the endpoint.
        let bar = if self.tag == 0 {
            Arc::clone(&self.barrier)
        } else {
            if self.tagged_world_barrier.is_none() {
                let members: Vec<usize> = (0..self.size).collect();
                self.tagged_world_barrier =
                    Some(self.registry.group_barrier(&members, self.tag));
            }
            Arc::clone(self.tagged_world_barrier.as_ref().expect("just cached"))
        };
        let max = self.barrier_wait_abortable(&bar)?;
        if self.timing.is_virtual() {
            self.vtime = max;
        }
        self.metrics.barriers += 1;
        if obs::enabled() {
            let ev = obs::Event::new(obs::EventKind::Barrier, self.rank)
                .tag(self.tag)
                .span_s(obs_v0, self.vtime)
                .wall(obs_w0);
            obs::record(ev);
            obs::note_vtime_us(self.vtime * 1e6);
        }
        Ok(())
    }

    fn charge_compute(&mut self, bytes: usize) {
        if let Timing::Virtual(_, compute) = self.timing {
            let dur = compute.reduce(bytes);
            if obs::enabled() && dur > 0.0 {
                let ev = obs::Event::new(obs::EventKind::Reduce, self.rank)
                    .tag(self.tag)
                    .bytes(bytes as u64)
                    .span_s(self.vtime, self.vtime + dur)
                    .wall(obs::wall_now_ns());
                obs::record(ev);
                obs::note_vtime_us((self.vtime + dur) * 1e6);
            }
            self.vtime += dur;
        }
        self.metrics.reduce_bytes += bytes as u64;
    }

    fn time_us(&self) -> f64 {
        match self.timing {
            Timing::Real => self.start.elapsed().as_secs_f64() * 1e6,
            Timing::Virtual(..) => (self.vtime - self.origin) * 1e6,
        }
    }

    fn reset_time(&mut self) {
        // The virtual clock is not rewound — shared fabric timelines hold
        // absolute times, and after the harness's barrier every rank's
        // clock equals the same world maximum, so measuring from `origin`
        // is exactly the old reset-to-zero semantics (translation by a
        // common offset).
        self.origin = self.vtime;
        self.start = Instant::now();
    }

    fn metrics(&self) -> &RankMetrics {
        &self.metrics
    }
}

impl<E: Elem> Drop for ThreadComm<E> {
    fn drop(&mut self) {
        // a reorder-held message must not vanish with the endpoint: a
        // peer may still be blocked waiting for it (no-op when the fault
        // plan is inert — the held vector is empty)
        self.flush_tx_held();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::LinkCost;
    use std::thread;

    fn pair(timing: Timing) -> (ThreadComm<i32>, ThreadComm<i32>) {
        let reg = Arc::new(ShardedRegistry::new(2, None));
        let bar = Arc::new(VBarrier::new(2));
        (
            ThreadComm::new(0, 2, Arc::clone(&reg), Arc::clone(&bar), timing),
            ThreadComm::new(1, 2, reg, bar, timing),
        )
    }

    #[test]
    fn sendrecv_roundtrip() {
        let (mut a, mut b) = pair(Timing::Real);
        let h = thread::spawn(move || {
            let got = b.sendrecv(0, DataBuf::real(vec![7, 8])).unwrap();
            got.into_vec().unwrap()
        });
        let got = a.sendrecv(1, DataBuf::real(vec![1, 2, 3])).unwrap();
        assert_eq!(got.into_vec().unwrap(), vec![7, 8]);
        assert_eq!(h.join().unwrap(), vec![1, 2, 3]);
        assert_eq!(a.metrics().sendrecvs, 1);
    }

    #[test]
    fn zero_copy_views_cross_the_channel() {
        // a posted view shares its slab end to end: the receiver reads the
        // sender's storage, no copy in between
        let (mut a, mut b) = pair(Timing::Real);
        let h = thread::spawn(move || {
            let got = b.recv(0).unwrap();
            assert!(got.is_shared()); // still a view of the sender's slab
            got.into_vec().unwrap()
        });
        let y = DataBuf::real(vec![1, 2, 3, 4]);
        let blk = y.extract(1, 3).unwrap();
        a.send(1, blk).unwrap();
        assert_eq!(h.join().unwrap(), vec![2, 3]);
        drop(y);
    }

    #[test]
    fn virtual_clocks_agree_on_sendrecv() {
        let cost = CostModel::Uniform(LinkCost::new(1e-6, 1e-9));
        let timing = Timing::Virtual(cost, ComputeCost::new(0.0));
        let (mut a, mut b) = pair(timing);
        // skew the clocks, then exchange unequal payloads
        a.vtime = 5e-6;
        b.vtime = 2e-6;
        let h = thread::spawn(move || {
            b.sendrecv(0, DataBuf::real(vec![0i32; 100])).unwrap();
            b.vtime()
        });
        a.sendrecv(1, DataBuf::real(vec![0i32; 250])).unwrap();
        let tb = h.join().unwrap();
        // both: max(5µs, 2µs) + 1µs + 1000B·1e-9 = 7µs
        assert!((a.vtime() - 7e-6).abs() < 1e-12);
        assert!((tb - 7e-6).abs() < 1e-12);
    }

    #[test]
    fn one_sided_timing() {
        let cost = CostModel::Uniform(LinkCost::new(1e-6, 0.0));
        let timing = Timing::Virtual(cost, ComputeCost::new(0.0));
        let (mut a, mut b) = pair(timing);
        b.vtime = 10e-6;
        let h = thread::spawn(move || {
            let _ = b.recv(0).unwrap();
            b.vtime()
        });
        a.send(1, DataBuf::real(vec![1])).unwrap();
        assert!((a.vtime() - 1e-6).abs() < 1e-12); // sender: 0 + α
        let tb = h.join().unwrap();
        assert!((tb - 11e-6).abs() < 1e-12); // receiver: max(10, 0) + α
    }

    #[test]
    fn void_blocks_flow() {
        let (mut a, mut b) = pair(Timing::Real);
        let h = thread::spawn(move || {
            let got = b.sendrecv(0, DataBuf::real(vec![9])).unwrap();
            got.len()
        });
        let got = a.sendrecv(1, DataBuf::real(Vec::new())).unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(h.join().unwrap(), 0);
    }

    #[test]
    fn compute_charge() {
        let cost = CostModel::Uniform(LinkCost::new(0.0, 0.0));
        let timing = Timing::Virtual(cost, ComputeCost::new(2e-9));
        let (mut a, _b) = pair(timing);
        a.charge_compute(500);
        assert!((a.vtime() - 1e-6).abs() < 1e-15);
        assert_eq!(a.metrics().reduce_bytes, 500);
    }

    #[test]
    fn invalid_peer_rejected() {
        let (mut a, _b) = pair(Timing::Real);
        assert!(a.send(0, DataBuf::real(vec![1])).is_err()); // self
        assert!(a.send(2, DataBuf::real(vec![1])).is_err()); // out of range
    }

    #[test]
    fn edge_table_is_stable_across_posts() {
        // the same Edge must come back on every lookup (no re-init)
        let reg: ShardedRegistry<i32> = ShardedRegistry::new(3, None);
        let e1 = reg.edge(0, 2, 0);
        let e2 = reg.edge(0, 2, 0);
        assert!(Arc::ptr_eq(&e1, &e2));
        // distinct edges get distinct channels
        let e3 = reg.edge(2, 0, 0);
        assert!(!Arc::ptr_eq(&e1, &e3));
        // distinct tags get distinct channels on the same directed pair,
        // each stable across lookups
        let t1 = reg.edge(0, 2, 1);
        assert!(!Arc::ptr_eq(&e1, &t1));
        assert!(Arc::ptr_eq(&t1, &reg.edge(0, 2, 1)));
        assert!(!Arc::ptr_eq(&t1, &reg.edge(0, 2, 2)));
    }

    #[test]
    fn sharded_registry_translates_and_routes() {
        // 5 ranks, nodes of 2: shards {0,1} {2,3} {4}
        let mapping = Mapping::Block { ranks_per_node: 2 };
        let reg: ShardedRegistry<i32> = ShardedRegistry::new(5, Some(mapping));
        assert_eq!(reg.shard_count(), 3);
        assert_eq!(reg.shard_of(0), 0);
        assert_eq!(reg.shard_of(3), 1);
        assert_eq!(reg.shard_of(4), 2);
        // intra edge is stable and distinct per direction
        let a = reg.edge(2, 3, 0);
        assert!(Arc::ptr_eq(&a, &reg.edge(2, 3, 0)));
        assert!(!Arc::ptr_eq(&a, &reg.edge(3, 2, 0)));
        // cross-shard edge resolves through the sparse table, stably
        let x = reg.edge(1, 4, 0);
        assert!(Arc::ptr_eq(&x, &reg.edge(1, 4, 0)));
        assert!(!Arc::ptr_eq(&x, &reg.edge(4, 1, 0)));
        // a tagged intra-shard edge routes through the sparse table too
        // (the dense arenas stay a tag-0 fast path) and is its own channel
        let t = reg.edge(2, 3, 5);
        assert!(!Arc::ptr_eq(&a, &t));
        assert!(Arc::ptr_eq(&t, &reg.edge(2, 3, 5)));
    }

    #[test]
    fn sharded_world_exchanges_across_shards() {
        // messages must flow both intra-shard (dense table) and
        // cross-shard (sparse table) with identical semantics
        let mapping = Mapping::Block { ranks_per_node: 2 };
        let reg = Arc::new(ShardedRegistry::new(4, Some(mapping)));
        let bar = Arc::new(VBarrier::new(4));
        let mut comms: Vec<ThreadComm<i32>> = (0..4)
            .map(|r| ThreadComm::new(r, 4, Arc::clone(&reg), Arc::clone(&bar), Timing::Real))
            .collect();
        assert_eq!(comms[3].metrics().shard_id, 1);
        let c3 = comms.pop().unwrap();
        let c2 = comms.pop().unwrap();
        let c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();
        // pairs (0,1) intra, then (1,2) cross; 3 idles after its exchange
        let h = thread::spawn(move || {
            let mut c1 = c1;
            let intra = c1.sendrecv(0, DataBuf::real(vec![10])).unwrap();
            let cross = c1.sendrecv(2, DataBuf::real(vec![11])).unwrap();
            (intra.into_vec().unwrap(), cross.into_vec().unwrap())
        });
        let h2 = thread::spawn(move || {
            let mut c2 = c2;
            let cross = c2.sendrecv(1, DataBuf::real(vec![20])).unwrap();
            let intra = c2.sendrecv(3, DataBuf::real(vec![21])).unwrap();
            (cross.into_vec().unwrap(), intra.into_vec().unwrap())
        });
        let h3 = thread::spawn(move || {
            let mut c3 = c3;
            c3.sendrecv(2, DataBuf::real(vec![30])).unwrap().into_vec().unwrap()
        });
        let mut c0 = c0;
        let got = c0.sendrecv(1, DataBuf::real(vec![0])).unwrap();
        assert_eq!(got.into_vec().unwrap(), vec![10]);
        assert_eq!(h.join().unwrap(), (vec![0], vec![20]));
        assert_eq!(h2.join().unwrap(), (vec![11], vec![30]));
        assert_eq!(h3.join().unwrap(), vec![21]);
    }

    #[test]
    fn receiver_single_claim() {
        let reg: ShardedRegistry<i32> = ShardedRegistry::new(2, None);
        assert!(reg.receiver(0, 1, 0).is_ok());
        // a different tag is a different channel: claiming it is fine...
        assert!(reg.receiver(0, 1, 3).is_ok());
        // ...but re-claiming the same (src, dst, tag) is a typed error
        let err = reg.receiver(0, 1, 0).unwrap_err();
        assert!(err.to_string().contains("claimed twice"), "{err}");
    }

    #[test]
    fn reclaim_tags_returns_sparse_entries_and_rearms_claims() {
        let reg: ShardedRegistry<i32> = ShardedRegistry::new(2, None);
        let _ = reg.edge(0, 1, 5);
        let _ = reg.edge(1, 0, 5);
        let _ = reg.edge(0, 1, 6);
        assert!(reg.receiver(0, 1, 5).is_ok());
        assert_eq!(reg.tagged_entries(), 3);
        let tags: HashSet<u32> = [5].into_iter().collect();
        reg.reclaim_tags(&tags);
        assert_eq!(reg.tagged_entries(), 1); // only tag 6 survives
        // a reclaimed (src, dst, tag) comes back as a fresh edge with a
        // fresh, claimable receiver — exactly what tag recycling needs
        assert!(reg.receiver(0, 1, 5).is_ok());
        assert_eq!(reg.tagged_entries(), 2);
        reg.reclaim_tags(&tags); // idempotent
        assert_eq!(reg.tagged_entries(), 1);
    }

    fn faulty_pair(
        faults: FaultPlan,
        timing: Timing,
    ) -> (ThreadComm<i32>, ThreadComm<i32>) {
        let reg = Arc::new(ShardedRegistry::with_faults(
            2,
            None,
            Fabric::dedicated(),
            faults,
        ));
        let bar = Arc::new(VBarrier::new(2));
        (
            ThreadComm::new(0, 2, Arc::clone(&reg), Arc::clone(&bar), timing),
            ThreadComm::new(1, 2, reg, bar, timing),
        )
    }

    #[test]
    fn faulty_stream_reassembles_fifo() {
        // heavy duplication + reordering: sequence numbers must hand the
        // receiver the exact payload stream anyway
        let plan = FaultPlan::seeded(42).duplicate(0.5).reorder(0.5);
        let (mut a, mut b) = faulty_pair(plan, Timing::Real);
        let h = thread::spawn(move || {
            let mut got = Vec::new();
            for _ in 0..50 {
                got.push(b.recv(0).unwrap().into_vec().unwrap()[0]);
            }
            got
        });
        for i in 0..50 {
            a.send(1, DataBuf::real(vec![i])).unwrap();
        }
        drop(a); // the endpoint drop flushes a trailing held message
        assert_eq!(h.join().unwrap(), (0..50).collect::<Vec<i32>>());
    }

    #[test]
    fn transient_drop_is_deterministic_and_counted() {
        let cost = CostModel::Uniform(LinkCost::new(1e-6, 0.0));
        let timing = Timing::Virtual(cost, ComputeCost::new(0.0));
        let plan = FaultPlan::seeded(9).transient_drop(0.4, 16, 5.0);
        let run = || {
            let (mut a, mut b) = faulty_pair(plan, timing);
            let h = thread::spawn(move || {
                let mut times = Vec::new();
                for _ in 0..20 {
                    b.recv(0).unwrap();
                    times.push(b.vtime());
                }
                times
            });
            for i in 0..20 {
                a.send(1, DataBuf::real(vec![i])).unwrap();
            }
            (a.metrics().retransmits, h.join().unwrap())
        };
        let (r1, t1) = run();
        let (r2, t2) = run();
        assert!(r1 > 0, "drop prob 0.4 over 20 sends should retransmit");
        assert_eq!(r1, r2); // same seed, same faults
        for (x, y) in t1.iter().zip(&t2) {
            assert_eq!(x.to_bits(), y.to_bits()); // bitwise-identical clocks
        }
    }

    #[test]
    fn retries_exhausted_is_typed_and_poisons() {
        // certain drop: every attempt fails, the sender gives up with a
        // typed error and tears the world down (never a hang)
        let plan = FaultPlan::seeded(3).transient_drop(1.0, 2, 5.0);
        let (mut a, b) = faulty_pair(plan, Timing::Real);
        let err = a.send(1, DataBuf::real(vec![1])).unwrap_err();
        assert!(
            matches!(err, Error::RetriesExhausted { rank: 0, peer: 1, attempts: 3 }),
            "{err}"
        );
        assert!(b.world_poisoned());
    }

    #[test]
    fn straggler_rank_is_slow_on_the_virtual_clock() {
        let cost = CostModel::Uniform(LinkCost::new(1e-6, 0.0));
        let timing = Timing::Virtual(cost, ComputeCost::new(0.0));
        // stall_every = 2 marks rank 1 a straggler, +50 µs per send
        let plan = FaultPlan::seeded(1).stall(2, 50.0);
        let (mut a, mut b) = faulty_pair(plan, timing);
        let h = thread::spawn(move || {
            b.send(0, DataBuf::real(vec![1])).unwrap();
            b.vtime()
        });
        let got = a.recv(1).unwrap();
        assert_eq!(got.into_vec().unwrap(), vec![1]);
        let tb = h.join().unwrap();
        // sender leaves at 50 µs, port busy through 51 µs; receiver:
        // max(0, 50) + α = 51 µs
        assert!((tb - 51e-6).abs() < 1e-12, "b at {tb}");
        assert!((a.vtime() - 51e-6).abs() < 1e-12, "a at {}", a.vtime());
    }

    #[test]
    fn watchdog_scales_with_world_size() {
        assert_eq!(watchdog_secs(60, 2), 60);
        assert_eq!(watchdog_secs(60, 511), 60);
        assert_eq!(watchdog_secs(60, 512), 120);
        assert_eq!(watchdog_secs(60, 1152), 180);
        assert_eq!(watchdog_secs(2, 8), 2); // env-shrunk base stays small
        // huge bases mean "never fire": clamped so Instant + Duration
        // cannot overflow, not propagated
        assert_eq!(watchdog_secs(u64::MAX, 4096), MAX_WATCHDOG_SECS);
        assert_eq!(watchdog_secs(MAX_WATCHDOG_SECS, 10_000), MAX_WATCHDOG_SECS);
    }

    /// A congested pair: same formulas as the dedicated path when
    /// resources never contend, plus stall accounting when they do.
    fn congested_pair(
        net: NetParams,
        mapping: Mapping,
        timing: Timing,
    ) -> (ThreadComm<i32>, ThreadComm<i32>) {
        let fabric = Fabric::new(2, net, mapping);
        let reg = Arc::new(ShardedRegistry::with_fabric(2, None, fabric));
        let bar = Arc::new(VBarrier::new(2));
        (
            ThreadComm::new(0, 2, Arc::clone(&reg), Arc::clone(&bar), timing),
            ThreadComm::new(1, 2, reg, bar, timing),
        )
    }

    #[test]
    fn backpressure_advances_sender_clock_to_drain_time() {
        // two ranks on two nodes, inter edge capacity 1, no ports:
        // α = 1µs, β = 0. Rank 1 is busy (clock at 10µs) before receiving.
        let net = NetParams::dedicated().edge_capacity(1);
        let mapping = Mapping::Block { ranks_per_node: 1 };
        let cost = CostModel::Uniform(LinkCost::new(1e-6, 0.0)).with_net(net, mapping);
        let timing = Timing::Virtual(cost, ComputeCost::new(1e-6)); // 1 µs/byte γ
        let (mut a, mut b) = congested_pair(net, mapping, timing);
        let h = thread::spawn(move || {
            b.charge_compute(10); // clock → 10 µs before draining anything
            let mut times = Vec::new();
            for _ in 0..3 {
                b.recv(0).unwrap();
                times.push(b.vtime());
            }
            (times, b.metrics().clone())
        });
        for _ in 0..3 {
            a.send(1, DataBuf::real(vec![1i32])).unwrap();
        }
        // post 0: free slot, starts at 0, a's clock → 1µs.
        // post 1: needs drain 0 = max(10, 0) + 1 = 11 → stall to 11, clock 12.
        // post 2: needs drain 1 = max(11, 11) + 1 = 12 → no stall (clock
        //         already 12), clock 13.
        assert!((a.vtime() - 13e-6).abs() < 1e-12, "a at {}", a.vtime());
        assert_eq!(a.metrics().queue_full_events, 1);
        assert!((a.metrics().stall_us - 10.0).abs() < 1e-9);
        assert!(a.metrics().max_queue_depth >= 1);
        let (times, bm) = h.join().unwrap();
        let expect = [11e-6, 12e-6, 13e-6];
        for (t, e) in times.iter().zip(expect) {
            assert!((t - e).abs() < 1e-12, "recv times {times:?}");
        }
        assert_eq!(bm.queue_full_events, 0);
    }

    #[test]
    fn congested_with_unlimited_resources_matches_dedicated_bitwise() {
        // active fabric (effectively-unbounded queues), unlimited ports:
        // the sendrecv completion must equal the scalar scheme bit for bit
        let link = LinkCost::new(1e-6, 1e-9);
        let mapping = Mapping::Block { ranks_per_node: 1 };
        let net = NetParams::dedicated().edge_capacity(1 << 40);
        let base = CostModel::Uniform(link);
        let run = |timing: Timing, net: Option<NetParams>| -> (f64, f64) {
            let (mut a, mut b) = match net {
                Some(n) => congested_pair(n, mapping, timing),
                None => pair(timing),
            };
            a.vtime = 5e-6;
            b.vtime = 2e-6;
            let h = thread::spawn(move || {
                b.sendrecv(0, DataBuf::real(vec![0i32; 100])).unwrap();
                b.vtime()
            });
            a.sendrecv(1, DataBuf::real(vec![0i32; 250])).unwrap();
            (a.vtime(), h.join().unwrap())
        };
        let dedicated = run(Timing::Virtual(base, ComputeCost::new(0.0)), None);
        let congested = run(
            Timing::Virtual(base.with_net(net, mapping), ComputeCost::new(0.0)),
            Some(net),
        );
        assert_eq!(dedicated.0.to_bits(), congested.0.to_bits());
        assert_eq!(dedicated.1.to_bits(), congested.1.to_bits());
        // both: max(5µs, 2µs) + 1µs + 1000B·1e-9 = 7µs
        assert!((dedicated.0 - 7e-6).abs() < 1e-12);
    }

    #[test]
    fn tagged_forks_are_fifo_per_tag_and_independent() {
        // Two tags between the same pair: each tag's stream is FIFO and
        // never observes the other tag's messages, even when the sends
        // interleave and one side consumes the tags in the opposite order.
        let (a, b) = pair(Timing::Real);
        let mut a1 = a.fork_tagged(1);
        let mut a2 = a.fork_tagged(2);
        let mut b1 = b.fork_tagged(1);
        let mut b2 = b.fork_tagged(2);
        assert_eq!(a1.tag(), 1);
        a1.send(1, DataBuf::real(vec![10])).unwrap();
        a2.send(1, DataBuf::real(vec![20])).unwrap();
        a1.send(1, DataBuf::real(vec![11])).unwrap();
        a2.send(1, DataBuf::real(vec![21])).unwrap();
        // consume tag 2 first — tag 1's messages must still be waiting
        assert_eq!(b2.recv(0).unwrap().into_vec().unwrap(), vec![20]);
        assert_eq!(b2.recv(0).unwrap().into_vec().unwrap(), vec![21]);
        assert_eq!(b1.recv(0).unwrap().into_vec().unwrap(), vec![10]);
        assert_eq!(b1.recv(0).unwrap().into_vec().unwrap(), vec![11]);
        // forks kept their own metrics
        assert_eq!(a1.metrics().exchanges, 2);
        assert_eq!(a2.metrics().exchanges, 2);
        assert_eq!(a.metrics().exchanges, 0);
    }

    #[test]
    fn fork_inherits_clock_and_absorb_child_merges() {
        let cost = CostModel::Uniform(LinkCost::new(1e-6, 0.0));
        let timing = Timing::Virtual(cost, ComputeCost::new(2e-9));
        let (mut a, _b) = pair(timing);
        a.charge_compute(500); // clock → 1 µs
        let mut child = a.fork_tagged(9);
        assert!((child.vtime() - 1e-6).abs() < 1e-15); // inherited
        child.charge_compute(1500); // child clock → 4 µs
        a.charge_compute(500); // parent clock → 2 µs
        let child_metrics = child.metrics().clone();
        let child_vtime = child.vtime();
        a.absorb_child(&child_metrics, child_vtime);
        // wait semantics: the parent clock advances to the child's
        assert!((a.vtime() - 4e-6).abs() < 1e-15);
        assert_eq!(a.metrics().reduce_bytes, 2500);
        // absorbing an already-passed child never rewinds
        a.charge_compute(1000); // → 6 µs
        a.absorb_child(&RankMetrics::default(), 4e-6);
        assert!((a.vtime() - 6e-6).abs() < 1e-15);
    }

    #[test]
    fn tagged_forks_run_concurrent_exchanges() {
        // two concurrent "operations" (tags) between two ranks, each on
        // its own worker thread per rank, completing out of order
        let (a, b) = pair(Timing::Real);
        let spawn = |comm: &ThreadComm<i32>, tag: u32, val: i32| {
            let mut c = comm.fork_tagged(tag);
            thread::spawn(move || {
                let peer = 1 - c.rank();
                let got = c.sendrecv(peer, DataBuf::real(vec![val])).unwrap();
                got.into_vec().unwrap()[0]
            })
        };
        let a1 = spawn(&a, 1, 1);
        let a2 = spawn(&a, 2, 2);
        let b2 = spawn(&b, 2, 20);
        let b1 = spawn(&b, 1, 10);
        assert_eq!(a1.join().unwrap(), 10);
        assert_eq!(a2.join().unwrap(), 20);
        assert_eq!(b1.join().unwrap(), 1);
        assert_eq!(b2.join().unwrap(), 2);
    }

    #[test]
    fn reset_time_measures_from_origin_without_rewinding() {
        let cost = CostModel::Uniform(crate::model::LinkCost::new(1e-6, 0.0));
        let timing = Timing::Virtual(cost, ComputeCost::new(2e-9));
        let (mut a, _b) = pair(timing);
        a.charge_compute(500); // 1 µs
        assert!((a.time_us() - 1.0).abs() < 1e-9);
        a.reset_time();
        assert!((a.time_us() - 0.0).abs() < 1e-12);
        assert!((a.vtime() - 1e-6).abs() < 1e-15); // absolute clock kept
        a.charge_compute(500);
        assert!((a.time_us() - 1.0).abs() < 1e-9);
        assert!((a.vtime() - 2e-6).abs() < 1e-15);
    }
}
