//! The thread-backed communicator endpoint.
//!
//! Each rank owns a `ThreadComm`. Point-to-point channels (`std::sync::mpsc`,
//! one per directed pair) live in a [`ShardedRegistry`]: one dense, local
//! edge table per *node group* (shard) plus a sparse, striped table for the
//! cross-shard edges. A flat world is the one-shard special case. Endpoints
//! cache the `Arc<Edge>` per peer, so after the first touch of an edge a
//! post is a plain vector index — no registry mutex, no `HashMap` hashing,
//! and no `Sender` clone per post. Channels are unbounded, so `send` never
//! blocks and the blocking structure of the algorithms (which the paper
//! designed for `MPI_Sendrecv`) cannot deadlock as long as every posted
//! receive is eventually matched.
//!
//! Sharding matters at scale: the old single dense `p × p` table preallocates
//! `p²` slots from one arena (256 MiB of slots at p = 4096), while the
//! sharded form preallocates only `Σ kᵢ²` intra-node slots (one independent
//! arena per node group) and materializes cross-node edges on demand — the
//! collectives only ever touch O(p log p) of them.
//!
//! Messages carry [`DataBuf`]s directly — with the zero-copy buffer layer
//! (see [`crate::buffer`]) a posted block is a reference-counted view of
//! the sender's slab, so the steady-state block path moves no payload
//! bytes at all: the receiver reduces straight out of the sender's memory.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use super::barrier::{BarrierTable, VBarrier};
use super::group::{Group, SubComm};
use super::metrics::RankMetrics;
use super::Comm;
use crate::buffer::DataBuf;
use crate::error::{Error, Result};
use crate::model::{ComputeCost, CostModel};
use crate::ops::Elem;
use crate::topo::Mapping;

/// How time is accounted.
#[derive(Clone, Copy, Debug)]
pub enum Timing {
    /// Wall-clock (the run is the measurement).
    Real,
    /// Virtual clocks charged under the given cost model (the run is a
    /// simulation of the paper's cluster).
    Virtual(CostModel, ComputeCost),
}

impl Timing {
    /// Virtual timing with the calibrated "Hydra" uniform model and the
    /// default γ.
    pub fn hydra() -> Timing {
        Timing::Virtual(CostModel::hydra_uniform(), ComputeCost::new(0.25e-9))
    }

    pub fn is_virtual(&self) -> bool {
        matches!(self, Timing::Virtual(..))
    }
}

/// A message on the wire: payload plus the sender's virtual clock at the
/// time of posting (ignored under real timing). The payload is typically a
/// zero-copy view of the sender's slab.
struct Msg<E: Elem> {
    vtime: f64,
    data: DataBuf<E>,
}

/// One directed channel of the edge table.
///
/// The `Sender` sits here unguarded: `std::sync::mpsc::Sender` is `Sync`
/// (Rust ≥ 1.72), so endpoints send through a shared reference without
/// cloning. The `Receiver` half is claimed exactly once by the destination
/// rank.
struct Edge<E: Elem> {
    sender: Sender<Msg<E>>,
    receiver: Mutex<Option<Receiver<Msg<E>>>>,
}

fn new_edge<E: Elem>() -> Arc<Edge<E>> {
    let (s, r) = channel();
    Arc::new(Edge {
        sender: s,
        receiver: Mutex::new(Some(r)),
    })
}

/// One node group's dense intra-shard edge table over *local* indices —
/// its own independent allocation, so large worlds stop serializing p²
/// slots through a single arena. Slot `(ls, ld)` lives at `ls * k + ld`;
/// each slot is a lazily initialized `OnceLock` and lookup after first
/// touch is lock-free.
struct ShardTable<E: Elem> {
    size: usize,
    edges: Box<[OnceLock<Arc<Edge<E>>>]>,
}

impl<E: Elem> ShardTable<E> {
    fn new(size: usize) -> ShardTable<E> {
        ShardTable {
            size,
            edges: (0..size * size).map(|_| OnceLock::new()).collect(),
        }
    }

    fn edge(&self, ls: usize, ld: usize) -> &Arc<Edge<E>> {
        debug_assert!(ls < self.size && ld < self.size);
        self.edges[ls * self.size + ld].get_or_init(new_edge)
    }
}

/// Lock stripes of the sparse cross-shard edge table.
const INTER_STRIPES: usize = 64;

/// One stripe's worth of cross-shard edges, keyed by global `(src, dst)`.
type InterMap<E> = HashMap<(usize, usize), Arc<Edge<E>>>;

/// Cross-shard edges, keyed by global `(src, dst)` and created on first
/// touch. Sparse by design: tree collectives cross node boundaries on
/// O(p log p) pairs, a vanishing fraction of the p² a dense table would
/// preallocate. The stripe lock is only taken on an endpoint's *first*
/// touch of an edge — after that the endpoint's `Arc` cache serves lookups
/// without any shared state.
struct InterTable<E: Elem> {
    stripes: Box<[Mutex<InterMap<E>>]>,
}

impl<E: Elem> InterTable<E> {
    fn new() -> InterTable<E> {
        InterTable {
            stripes: (0..INTER_STRIPES)
                .map(|_| Mutex::new(InterMap::new()))
                .collect(),
        }
    }

    fn edge(&self, src: usize, dst: usize) -> Arc<Edge<E>> {
        let h = src.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(dst);
        let mut map = self.stripes[h % INTER_STRIPES].lock().unwrap();
        Arc::clone(map.entry((src, dst)).or_insert_with(new_edge))
    }
}

/// The channel registry backing one logical world: one [`ShardTable`] per
/// node group plus the sparse [`InterTable`] for cross-shard edges, with
/// rank → (shard, local index) translation, the per-group barrier table,
/// and the world poison flag.
///
/// `new(p, None)` is the flat world (a single shard — the previous dense
/// `Registry` exactly); `new(p, Some(mapping))` shards by the mapping's
/// node groups, which is how `run_world` aligns the transport's arenas
/// with the cost model's node layout.
pub(super) struct ShardedRegistry<E: Elem> {
    size: usize,
    /// Global rank → shard id.
    shard_of: Box<[u32]>,
    /// Global rank → local index within its shard.
    local_of: Box<[u32]>,
    shards: Box<[ShardTable<E>]>,
    inter: InterTable<E>,
    /// Per-group barriers for sub-communicators (see [`BarrierTable`]).
    barriers: BarrierTable,
    /// Set when any rank fails; blocked receivers notice within
    /// [`POISON_POLL`] and abort instead of waiting forever (the registry
    /// itself keeps unclaimed `Sender`s alive, so a dead peer would not
    /// disconnect the channel).
    poisoned: std::sync::atomic::AtomicBool,
}

/// Poll interval for poison detection on blocked receives.
const POISON_POLL: std::time::Duration = std::time::Duration::from_millis(20);

/// How long a receive may block before we declare a protocol deadlock.
/// Override with `DPDR_RECV_TIMEOUT_SECS` (legitimate waits in heavily
/// oversubscribed real-time worlds can be long).
fn recv_watchdog() -> std::time::Duration {
    static SECS: OnceLock<u64> = OnceLock::new();
    let secs = *SECS.get_or_init(|| {
        std::env::var("DPDR_RECV_TIMEOUT_SECS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(60)
    });
    std::time::Duration::from_secs(secs)
}

impl<E: Elem> ShardedRegistry<E> {
    pub(super) fn new(size: usize, mapping: Option<Mapping>) -> ShardedRegistry<E> {
        let groups: Vec<Vec<usize>> = match mapping {
            Some(m) => m.shards(size),
            None => vec![(0..size).collect()],
        };
        let mut shard_of = vec![0u32; size];
        let mut local_of = vec![0u32; size];
        let mut shards = Vec::with_capacity(groups.len());
        for (si, g) in groups.iter().enumerate() {
            for (li, &r) in g.iter().enumerate() {
                shard_of[r] = si as u32;
                local_of[r] = li as u32;
            }
            shards.push(ShardTable::new(g.len()));
        }
        ShardedRegistry {
            size,
            shard_of: shard_of.into_boxed_slice(),
            local_of: local_of.into_boxed_slice(),
            shards: shards.into_boxed_slice(),
            inter: InterTable::new(),
            barriers: BarrierTable::new(),
            poisoned: std::sync::atomic::AtomicBool::new(false),
        }
    }

    /// Number of shards (node groups) backing this world.
    pub(super) fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The shard (node group) hosting `rank`.
    pub(super) fn shard_of(&self, rank: usize) -> usize {
        self.shard_of[rank] as usize
    }

    /// Mark the world failed (called when a rank errors or panics).
    pub(super) fn poison(&self) {
        self.poisoned
            .store(true, std::sync::atomic::Ordering::Release);
    }

    pub(super) fn is_poisoned(&self) -> bool {
        self.poisoned.load(std::sync::atomic::Ordering::Acquire)
    }

    /// The edge `(src, dst)`, creating its channel on first touch: dense
    /// shard-local slot when both ends share a node group, sparse striped
    /// entry otherwise. Endpoints cache the returned `Arc` per peer, so
    /// this runs once per (endpoint, peer) pair.
    fn edge(&self, src: usize, dst: usize) -> Arc<Edge<E>> {
        debug_assert!(src < self.size && dst < self.size);
        let (ss, sd) = (self.shard_of[src], self.shard_of[dst]);
        if ss == sd {
            Arc::clone(self.shards[ss as usize].edge(
                self.local_of[src] as usize,
                self.local_of[dst] as usize,
            ))
        } else {
            self.inter.edge(src, dst)
        }
    }

    /// Claim the receive half of edge `(src, dst)`; each endpoint may do
    /// this exactly once.
    fn receiver(&self, src: usize, dst: usize) -> Receiver<Msg<E>> {
        self.edge(src, dst)
            .receiver
            .lock()
            .unwrap()
            .take()
            .expect("receiver claimed twice — one endpoint per rank")
    }

    /// The barrier shared by exactly the ranks in `members`.
    fn group_barrier(&self, members: &[usize]) -> Arc<VBarrier> {
        self.barriers.get(members)
    }
}

/// One rank's endpoint.
pub struct ThreadComm<E: Elem> {
    rank: usize,
    size: usize,
    registry: Arc<ShardedRegistry<E>>,
    barrier: Arc<VBarrier>,
    /// Cached outgoing edges, indexed by destination rank (first touch
    /// resolves through the registry; afterwards a post is a vector index).
    tx: Vec<Option<Arc<Edge<E>>>>,
    /// Claimed incoming channels, indexed by source rank.
    rx: Vec<Option<Receiver<Msg<E>>>>,
    timing: Timing,
    vtime: f64,
    start: Instant,
    metrics: RankMetrics,
}

impl<E: Elem> ThreadComm<E> {
    pub(super) fn new(
        rank: usize,
        size: usize,
        registry: Arc<ShardedRegistry<E>>,
        barrier: Arc<VBarrier>,
        timing: Timing,
    ) -> ThreadComm<E> {
        let shard_id = registry.shard_of(rank) as u32;
        ThreadComm {
            rank,
            size,
            registry,
            barrier,
            tx: (0..size).map(|_| None).collect(),
            rx: (0..size).map(|_| None).collect(),
            timing,
            vtime: 0.0,
            start: Instant::now(),
            metrics: RankMetrics {
                shard_id,
                ..RankMetrics::default()
            },
        }
    }

    /// Borrow a sub-communicator scoped to `group` (this rank must be a
    /// member). The sub-communicator relabels ranks to `0..group.size()`
    /// and shares this endpoint's clock, metrics, and channels — it is a
    /// view, not a second endpoint, so collectives written against
    /// [`Comm`] run unchanged on rank subsets.
    pub fn sub<'a>(&'a mut self, group: &'a Group) -> Result<SubComm<'a, E>> {
        SubComm::new(self, group)
    }

    /// Synchronize exactly the ranks in `members` (each must call this
    /// with the same list); under virtual timing the member clocks advance
    /// to the group maximum, mirroring the world [`Comm::barrier`].
    pub(super) fn group_barrier_wait(&mut self, members: &[usize]) -> Result<()> {
        let bar = self.registry.group_barrier(members);
        let max = bar.wait(self.vtime);
        if self.timing.is_virtual() {
            self.vtime = max;
        }
        self.metrics.barriers += 1;
        Ok(())
    }

    fn check_peer(&self, peer: usize) -> Result<()> {
        if peer >= self.size || peer == self.rank {
            return Err(Error::Config(format!(
                "rank {}: invalid peer {} (size {})",
                self.rank, peer, self.size
            )));
        }
        Ok(())
    }

    fn post(&mut self, peer: usize, data: DataBuf<E>) -> Result<usize> {
        let bytes = data.bytes();
        let msg = Msg {
            vtime: self.vtime,
            data,
        };
        let (rank, registry) = (self.rank, &self.registry);
        let edge = self.tx[peer].get_or_insert_with(|| registry.edge(rank, peer));
        edge.sender.send(msg).map_err(|_| Error::Disconnected {
            rank: self.rank,
            peer,
        })?;
        self.metrics.bytes_sent += bytes as u64;
        Ok(bytes)
    }

    fn take(&mut self, peer: usize) -> Result<Msg<E>> {
        let (rank, registry) = (self.rank, &self.registry);
        let rx = self.rx[peer].get_or_insert_with(|| registry.receiver(peer, rank));
        // Block in POISON_POLL slices so a failed world tears down instead
        // of hanging on receives whose sender died (the registry keeps the
        // unclaimed Sender half alive, so disconnect alone is not enough),
        // and so protocol deadlocks surface as errors instead of hangs.
        let deadline = std::time::Instant::now() + recv_watchdog();
        let msg = loop {
            match rx.recv_timeout(POISON_POLL) {
                Ok(msg) => break msg,
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    if registry.is_poisoned() {
                        return Err(Error::Disconnected {
                            rank: self.rank,
                            peer,
                        });
                    }
                    if std::time::Instant::now() > deadline {
                        registry.poison();
                        return Err(Error::Protocol(format!(
                            "rank {} recv from {} timed out — likely protocol deadlock",
                            self.rank, peer
                        )));
                    }
                }
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    return Err(Error::Disconnected {
                        rank: self.rank,
                        peer,
                    })
                }
            }
        };
        self.metrics.bytes_recv += msg.data.bytes() as u64;
        Ok(msg)
    }

    /// The virtual clock (0 under real timing).
    pub fn vtime(&self) -> f64 {
        self.vtime
    }

    /// The timing mode this endpoint runs under.
    pub fn timing(&self) -> Timing {
        self.timing
    }
}

impl<E: Elem> Comm<E> for ThreadComm<E> {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn sendrecv(&mut self, peer: usize, send: DataBuf<E>) -> Result<DataBuf<E>> {
        self.check_peer(peer)?;
        let sent_bytes = self.post(peer, send)?;
        let msg = self.take(peer)?;
        if let Timing::Virtual(cost, _) = self.timing {
            // Telephone model: both directions complete together; the cost
            // is driven by the larger payload, and both endpoints compute
            // the identical completion time max(t_a, t_b) + α + β·n.
            let bytes = sent_bytes.max(msg.data.bytes());
            self.vtime = self.vtime.max(msg.vtime) + cost.xfer(self.rank, peer, bytes);
        }
        self.metrics.exchanges += 1;
        self.metrics.sendrecvs += 1;
        Ok(msg.data)
    }

    fn sendrecv_pair(
        &mut self,
        send_to: usize,
        send: DataBuf<E>,
        recv_from: usize,
    ) -> Result<DataBuf<E>> {
        if send_to == recv_from {
            return self.sendrecv(send_to, send);
        }
        self.check_peer(send_to)?;
        self.check_peer(recv_from)?;
        let sent_bytes = self.post(send_to, send)?;
        let msg = self.take(recv_from)?;
        if let Timing::Virtual(cost, _) = self.timing {
            // Full duplex: the outgoing and incoming transfers overlap; the
            // step ends when the longer of the two is done, and the incoming
            // one cannot start before the remote sender posted.
            let out = cost.xfer(self.rank, send_to, sent_bytes);
            let inc = cost.xfer(self.rank, recv_from, msg.data.bytes());
            self.vtime = (self.vtime + out).max(self.vtime.max(msg.vtime) + inc);
        }
        self.metrics.exchanges += 1;
        self.metrics.sendrecvs += 1;
        Ok(msg.data)
    }

    fn send(&mut self, peer: usize, data: DataBuf<E>) -> Result<()> {
        self.check_peer(peer)?;
        let bytes = self.post(peer, data)?;
        if let Timing::Virtual(cost, _) = self.timing {
            // The sender's port is busy for the full transfer.
            self.vtime += cost.xfer(self.rank, peer, bytes);
        }
        self.metrics.exchanges += 1;
        Ok(())
    }

    fn recv(&mut self, peer: usize) -> Result<DataBuf<E>> {
        self.check_peer(peer)?;
        let msg = self.take(peer)?;
        if let Timing::Virtual(cost, _) = self.timing {
            // Transfer starts when the sender posted and the receiver is
            // ready: max(t_r, t_s) + α + β·n.
            let bytes = msg.data.bytes();
            self.vtime = self.vtime.max(msg.vtime) + cost.xfer(self.rank, peer, bytes);
        }
        self.metrics.exchanges += 1;
        Ok(msg.data)
    }

    fn barrier(&mut self) -> Result<()> {
        let max = self.barrier.wait(self.vtime);
        if self.timing.is_virtual() {
            self.vtime = max;
        }
        self.metrics.barriers += 1;
        Ok(())
    }

    fn charge_compute(&mut self, bytes: usize) {
        if let Timing::Virtual(_, compute) = self.timing {
            self.vtime += compute.reduce(bytes);
        }
        self.metrics.reduce_bytes += bytes as u64;
    }

    fn time_us(&self) -> f64 {
        match self.timing {
            Timing::Real => self.start.elapsed().as_secs_f64() * 1e6,
            Timing::Virtual(..) => self.vtime * 1e6,
        }
    }

    fn reset_time(&mut self) {
        self.vtime = 0.0;
        self.start = Instant::now();
    }

    fn metrics(&self) -> &RankMetrics {
        &self.metrics
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::LinkCost;
    use std::thread;

    fn pair(timing: Timing) -> (ThreadComm<i32>, ThreadComm<i32>) {
        let reg = Arc::new(ShardedRegistry::new(2, None));
        let bar = Arc::new(VBarrier::new(2));
        (
            ThreadComm::new(0, 2, Arc::clone(&reg), Arc::clone(&bar), timing),
            ThreadComm::new(1, 2, reg, bar, timing),
        )
    }

    #[test]
    fn sendrecv_roundtrip() {
        let (mut a, mut b) = pair(Timing::Real);
        let h = thread::spawn(move || {
            let got = b.sendrecv(0, DataBuf::real(vec![7, 8])).unwrap();
            got.into_vec().unwrap()
        });
        let got = a.sendrecv(1, DataBuf::real(vec![1, 2, 3])).unwrap();
        assert_eq!(got.into_vec().unwrap(), vec![7, 8]);
        assert_eq!(h.join().unwrap(), vec![1, 2, 3]);
        assert_eq!(a.metrics().sendrecvs, 1);
    }

    #[test]
    fn zero_copy_views_cross_the_channel() {
        // a posted view shares its slab end to end: the receiver reads the
        // sender's storage, no copy in between
        let (mut a, mut b) = pair(Timing::Real);
        let h = thread::spawn(move || {
            let got = b.recv(0).unwrap();
            assert!(got.is_shared()); // still a view of the sender's slab
            got.into_vec().unwrap()
        });
        let y = DataBuf::real(vec![1, 2, 3, 4]);
        let blk = y.extract(1, 3).unwrap();
        a.send(1, blk).unwrap();
        assert_eq!(h.join().unwrap(), vec![2, 3]);
        drop(y);
    }

    #[test]
    fn virtual_clocks_agree_on_sendrecv() {
        let cost = CostModel::Uniform(LinkCost::new(1e-6, 1e-9));
        let timing = Timing::Virtual(cost, ComputeCost::new(0.0));
        let (mut a, mut b) = pair(timing);
        // skew the clocks, then exchange unequal payloads
        a.vtime = 5e-6;
        b.vtime = 2e-6;
        let h = thread::spawn(move || {
            b.sendrecv(0, DataBuf::real(vec![0i32; 100])).unwrap();
            b.vtime()
        });
        a.sendrecv(1, DataBuf::real(vec![0i32; 250])).unwrap();
        let tb = h.join().unwrap();
        // both: max(5µs, 2µs) + 1µs + 1000B·1e-9 = 7µs
        assert!((a.vtime() - 7e-6).abs() < 1e-12);
        assert!((tb - 7e-6).abs() < 1e-12);
    }

    #[test]
    fn one_sided_timing() {
        let cost = CostModel::Uniform(LinkCost::new(1e-6, 0.0));
        let timing = Timing::Virtual(cost, ComputeCost::new(0.0));
        let (mut a, mut b) = pair(timing);
        b.vtime = 10e-6;
        let h = thread::spawn(move || {
            let _ = b.recv(0).unwrap();
            b.vtime()
        });
        a.send(1, DataBuf::real(vec![1])).unwrap();
        assert!((a.vtime() - 1e-6).abs() < 1e-12); // sender: 0 + α
        let tb = h.join().unwrap();
        assert!((tb - 11e-6).abs() < 1e-12); // receiver: max(10, 0) + α
    }

    #[test]
    fn void_blocks_flow() {
        let (mut a, mut b) = pair(Timing::Real);
        let h = thread::spawn(move || {
            let got = b.sendrecv(0, DataBuf::real(vec![9])).unwrap();
            got.len()
        });
        let got = a.sendrecv(1, DataBuf::real(Vec::new())).unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(h.join().unwrap(), 0);
    }

    #[test]
    fn compute_charge() {
        let cost = CostModel::Uniform(LinkCost::new(0.0, 0.0));
        let timing = Timing::Virtual(cost, ComputeCost::new(2e-9));
        let (mut a, _b) = pair(timing);
        a.charge_compute(500);
        assert!((a.vtime() - 1e-6).abs() < 1e-15);
        assert_eq!(a.metrics().reduce_bytes, 500);
    }

    #[test]
    fn invalid_peer_rejected() {
        let (mut a, _b) = pair(Timing::Real);
        assert!(a.send(0, DataBuf::real(vec![1])).is_err()); // self
        assert!(a.send(2, DataBuf::real(vec![1])).is_err()); // out of range
    }

    #[test]
    fn edge_table_is_stable_across_posts() {
        // the same Edge must come back on every lookup (no re-init)
        let reg: ShardedRegistry<i32> = ShardedRegistry::new(3, None);
        let e1 = reg.edge(0, 2);
        let e2 = reg.edge(0, 2);
        assert!(Arc::ptr_eq(&e1, &e2));
        // distinct edges get distinct channels
        let e3 = reg.edge(2, 0);
        assert!(!Arc::ptr_eq(&e1, &e3));
    }

    #[test]
    fn sharded_registry_translates_and_routes() {
        // 5 ranks, nodes of 2: shards {0,1} {2,3} {4}
        let mapping = Mapping::Block { ranks_per_node: 2 };
        let reg: ShardedRegistry<i32> = ShardedRegistry::new(5, Some(mapping));
        assert_eq!(reg.shard_count(), 3);
        assert_eq!(reg.shard_of(0), 0);
        assert_eq!(reg.shard_of(3), 1);
        assert_eq!(reg.shard_of(4), 2);
        // intra edge is stable and distinct per direction
        let a = reg.edge(2, 3);
        assert!(Arc::ptr_eq(&a, &reg.edge(2, 3)));
        assert!(!Arc::ptr_eq(&a, &reg.edge(3, 2)));
        // cross-shard edge resolves through the sparse table, stably
        let x = reg.edge(1, 4);
        assert!(Arc::ptr_eq(&x, &reg.edge(1, 4)));
        assert!(!Arc::ptr_eq(&x, &reg.edge(4, 1)));
    }

    #[test]
    fn sharded_world_exchanges_across_shards() {
        // messages must flow both intra-shard (dense table) and
        // cross-shard (sparse table) with identical semantics
        let mapping = Mapping::Block { ranks_per_node: 2 };
        let reg = Arc::new(ShardedRegistry::new(4, Some(mapping)));
        let bar = Arc::new(VBarrier::new(4));
        let mut comms: Vec<ThreadComm<i32>> = (0..4)
            .map(|r| ThreadComm::new(r, 4, Arc::clone(&reg), Arc::clone(&bar), Timing::Real))
            .collect();
        assert_eq!(comms[3].metrics().shard_id, 1);
        let c3 = comms.pop().unwrap();
        let c2 = comms.pop().unwrap();
        let c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();
        // pairs (0,1) intra, then (1,2) cross; 3 idles after its exchange
        let h = thread::spawn(move || {
            let mut c1 = c1;
            let intra = c1.sendrecv(0, DataBuf::real(vec![10])).unwrap();
            let cross = c1.sendrecv(2, DataBuf::real(vec![11])).unwrap();
            (intra.into_vec().unwrap(), cross.into_vec().unwrap())
        });
        let h2 = thread::spawn(move || {
            let mut c2 = c2;
            let cross = c2.sendrecv(1, DataBuf::real(vec![20])).unwrap();
            let intra = c2.sendrecv(3, DataBuf::real(vec![21])).unwrap();
            (cross.into_vec().unwrap(), intra.into_vec().unwrap())
        });
        let h3 = thread::spawn(move || {
            let mut c3 = c3;
            c3.sendrecv(2, DataBuf::real(vec![30])).unwrap().into_vec().unwrap()
        });
        let mut c0 = c0;
        let got = c0.sendrecv(1, DataBuf::real(vec![0])).unwrap();
        assert_eq!(got.into_vec().unwrap(), vec![10]);
        assert_eq!(h.join().unwrap(), (vec![0], vec![20]));
        assert_eq!(h2.join().unwrap(), (vec![11], vec![30]));
        assert_eq!(h3.join().unwrap(), vec![21]);
    }

    #[test]
    #[should_panic(expected = "claimed twice")]
    fn receiver_single_claim() {
        let reg: ShardedRegistry<i32> = ShardedRegistry::new(2, None);
        let _r = reg.receiver(0, 1);
        let _r2 = reg.receiver(0, 1);
    }
}
