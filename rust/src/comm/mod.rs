//! The message-passing substrate the collectives run on.
//!
//! Semantics follow the paper's implementation sketch (§1.3): blocking
//! point-to-point `send`/`recv`, the bidirectional (telephone-model)
//! [`Comm::sendrecv`] analogous to `MPI_Sendrecv`, variable-length messages
//! including zero-element "void" blocks, and a barrier (`MPI_Barrier`,
//! which the mpicroscope-style harness uses to synchronize measurements).
//!
//! Every rank runs as an OS thread. Two timing modes share the same
//! transport ([`Timing`]):
//!
//! * **Real** — wall-clock timing; used for in-process runs and unit tests.
//! * **Virtual** — each rank carries a *virtual clock* charged under the
//!   paper's linear cost model: a bidirectional exchange of `n` bytes
//!   between ranks whose clocks read `t_a`, `t_b` completes on both sides
//!   at `max(t_a, t_b) + α + β·n` (with `n` the larger of the two payload
//!   sizes), and each local ⊙ reduction adds `γ·n`. Message timestamps make
//!   both endpoints compute identical completion times without any global
//!   coordinator, so the simulation itself runs at full parallelism.
//!
//! This is the substitution for the paper's 36×32 OmniPath cluster: the
//! protocol (every message, every block boundary, every round) is executed
//! for real; only *time* is modelled — and the model is exactly the one the
//! paper's analysis (§1.2) is stated in.
//!
//! A third timing flavour sits between the two: **congestion-aware
//! virtual** ([`CostModel::Congested`](crate::model::CostModel)). The
//! scalar-clock scheme above assumes every link is dedicated; the
//! congested model routes virtual timing through a shared
//! network-resource layer ([`net`]) — per-node NIC port timelines that
//! serialize concurrent inter-node transfers from one node, and bounded
//! per-edge injection queues whose backpressure advances the sender's
//! clock to the drain time of the slot it reuses. With unlimited
//! resources the fabric is inert and the clocks are the scalar scheme
//! bit for bit; see `tests/congestion.rs` and
//! `benches/congestion_ablation.rs`.
//!
//! The transport itself is zero-copy: a posted block is a reference-counted
//! view of the sender's slab (see [`crate::buffer`]), channels live in a
//! sharded lock-free edge table (one dense arena per node group plus a
//! sparse cross-node table — see [`thread`]), and receive-side free lists
//! recycle slab storage — so the in-process steady state adds no allocator
//! or memcpy traffic the α-β-γ model doesn't account for. The cost model
//! sees identical messages either way; `RankMetrics::{bytes_copied, allocs,
//! pool_recycled}` make the remaining cold-path traffic observable.
//!
//! On top of the flat world sits the communicator-group layer ([`group`]):
//! [`Group`] rank subsets with MPI-style `split` and local ↔ global rank
//! translation, and [`SubComm`] sub-communicators that run any
//! [`Comm`]-written collective on a subset — the substrate of the
//! node-aware hierarchical allreduce (`collectives::hierarchical`).

pub mod barrier;
pub mod fault;
pub mod group;
pub mod metrics;
pub mod net;
pub mod thread;
pub mod world;

pub use fault::FaultPlan;
pub use group::{Group, SubComm};
pub use metrics::{BackendHits, RankMetrics};
pub use net::LinkOccupancy;
pub use thread::{ThreadComm, Timing};
pub use world::{run_world, run_world_faulty, run_world_sharded, WorldReport};

use crate::buffer::DataBuf;
use crate::error::Result;
use crate::ops::Elem;

/// The communicator interface the collectives are written against.
pub trait Comm<E: Elem> {
    /// This rank's id in `[0, size)`.
    fn rank(&self) -> usize;

    /// Number of ranks.
    fn size(&self) -> usize;

    /// Bidirectional exchange with `peer` (`MPI_Sendrecv`): sends `send`,
    /// returns the block received from `peer`'s matching call. Either
    /// direction may be a zero-element void block.
    fn sendrecv(&mut self, peer: usize, send: DataBuf<E>) -> Result<DataBuf<E>>;

    /// Full `MPI_Sendrecv` semantics with *distinct* partners: send `send`
    /// to `send_to` while receiving from `recv_from`, in one full-duplex
    /// step. `sendrecv(p, d)` is the special case `send_to == recv_from`.
    /// The pipelined single-tree baseline (User-Allreduce1) needs this to
    /// overlap its parent-bound send with the child-bound receive and reach
    /// the paper's `2(2h + 2(b−1))` step count.
    fn sendrecv_pair(
        &mut self,
        send_to: usize,
        send: DataBuf<E>,
        recv_from: usize,
    ) -> Result<DataBuf<E>>;

    /// One-directional blocking send.
    fn send(&mut self, peer: usize, data: DataBuf<E>) -> Result<()>;

    /// One-directional blocking receive from `peer`.
    fn recv(&mut self, peer: usize) -> Result<DataBuf<E>>;

    /// Synchronize all ranks; under virtual timing all clocks advance to
    /// the global maximum.
    fn barrier(&mut self) -> Result<()>;

    /// Charge local reduction work over `bytes` bytes (γ-term). No-op under
    /// real timing (the actual work takes the actual time).
    fn charge_compute(&mut self, bytes: usize);

    /// Current time in microseconds (virtual clock or wall clock).
    fn time_us(&self) -> f64;

    /// Reset the clock/stopwatch to zero (harness use, after a barrier).
    fn reset_time(&mut self);

    /// Per-rank traffic counters.
    fn metrics(&self) -> &RankMetrics;
}
