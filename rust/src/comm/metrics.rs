//! Per-rank traffic and work counters.

/// Counters accumulated by one rank across a collective run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RankMetrics {
    /// Number of point-to-point operations (a sendrecv counts once).
    pub exchanges: u64,
    /// Number of those that were bidirectional sendrecvs.
    pub sendrecvs: u64,
    /// Payload bytes sent (void blocks contribute 0).
    pub bytes_sent: u64,
    /// Payload bytes received.
    pub bytes_recv: u64,
    /// Bytes fed through ⊙ reductions (γ-charged work).
    pub reduce_bytes: u64,
    /// Barrier participations.
    pub barriers: u64,
}

impl RankMetrics {
    /// Merge another rank's counters (for world-level aggregation).
    pub fn merge(&mut self, other: &RankMetrics) {
        self.exchanges += other.exchanges;
        self.sendrecvs += other.sendrecvs;
        self.bytes_sent += other.bytes_sent;
        self.bytes_recv += other.bytes_recv;
        self.reduce_bytes += other.reduce_bytes;
        self.barriers += other.barriers;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_accumulates() {
        let mut a = RankMetrics {
            exchanges: 1,
            sendrecvs: 1,
            bytes_sent: 10,
            bytes_recv: 20,
            reduce_bytes: 5,
            barriers: 2,
        };
        let b = a.clone();
        a.merge(&b);
        assert_eq!(a.exchanges, 2);
        assert_eq!(a.bytes_sent, 20);
        assert_eq!(a.bytes_recv, 40);
        assert_eq!(a.reduce_bytes, 10);
        assert_eq!(a.barriers, 4);
    }
}
