//! Per-rank traffic and work counters.

/// Per-backend dispatch counts of the reduce layer: which kernel (scalar
/// loop, SIMD, PJRT) served each `reduce_into` call on this rank.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BackendHits {
    /// Calls served by the plain scalar loop (includes the default path of
    /// non-arithmetic operators such as `Mat2Op`).
    pub scalar: u64,
    /// Calls served by the chunk-unrolled SIMD kernels.
    pub simd: u64,
    /// Calls served by the PJRT engine.
    pub pjrt: u64,
}

impl BackendHits {
    fn merge(&mut self, other: &BackendHits) {
        self.scalar += other.scalar;
        self.simd += other.simd;
        self.pjrt += other.pjrt;
    }
}

/// Counters accumulated by one rank across a collective run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RankMetrics {
    /// The registry shard (node group) this rank belongs to — `0` in a
    /// flat (single-shard) world. Identification, not a counter: `merge`
    /// keeps the left-hand side's value, so aggregating a shard's ranks
    /// into a fresh record tagged with that shard id stays correctly
    /// labelled, and cross-shard totals read as shard 0.
    pub shard_id: u32,
    /// Number of point-to-point operations (a sendrecv counts once).
    pub exchanges: u64,
    /// Number of those that were bidirectional sendrecvs.
    pub sendrecvs: u64,
    /// Payload bytes sent (void blocks contribute 0).
    pub bytes_sent: u64,
    /// Payload bytes received.
    pub bytes_recv: u64,
    /// Bytes fed through ⊙ reductions (γ-charged work).
    pub reduce_bytes: u64,
    /// Barrier participations.
    pub barriers: u64,
    /// Buffer-layer memcpy traffic (copy-on-write, send-time snapshots,
    /// `into_vec` fallbacks) — zero on the steady-state zero-copy block
    /// path. Reduction work is counted in `reduce_bytes`, not here.
    pub bytes_copied: u64,
    /// Slab allocations that missed the rank's free list and hit the
    /// system allocator.
    pub allocs: u64,
    /// Slab allocations served from the rank's receive-side free list.
    pub pool_recycled: u64,
    /// Elements fed through ⊙ by the reduce-backend layer (real-mode only:
    /// phantom reductions are charged to the virtual clock as `reduce_bytes`
    /// but never executed).
    pub elems_reduced: u64,
    /// Which reduce backend served each `reduce_into` call.
    pub backend_hits: BackendHits,
    /// Virtual µs this rank's clock was pushed forward by *shared*
    /// network resources: backpressure on full edge queues plus NIC port
    /// contention (egress and ingress). Always 0 under a dedicated model.
    pub stall_us: f64,
    /// Posts that found their edge's virtual injection queue still full
    /// at the sender's post time (each advanced the clock to the drain).
    pub queue_full_events: u64,
    /// Peak posted-but-undrained depth observed across this rank's
    /// outgoing edges (tracked only while the congestion fabric is
    /// active; `merge` takes the max, not the sum).
    pub max_queue_depth: u64,
    /// Peak number of nonblocking collective operations outstanding at
    /// once on this rank (submitted through a `crate::nbc::Engine` and
    /// not yet completed; `merge` takes the max, not the sum). 0 for
    /// purely blocking runs.
    pub ops_in_flight_max: u64,
    /// Number of small allreduce operations that were coalesced into
    /// fused vectors by the nbc fusion layer on this rank.
    pub fused_ops: u64,
    /// Total elements those fused operations contributed (the lengths of
    /// the concatenated vectors actually reduced).
    pub fused_elems: u64,
    /// Faults injected by this world's [`FaultPlan`](super::FaultPlan)
    /// that touched this rank's traffic: delays, duplicates (counted at
    /// both ends), reorder holds. 0 when the plan is inert.
    pub fault_events: u64,
    /// Transmission attempts repeated because the transient-drop fault
    /// mode discarded them (each added backoff to the sender's clock).
    pub retransmits: u64,
    /// Allreduce dispatches on this rank that went through the autotuned
    /// selection oracle ([`AlgoKind::Auto`](crate::model::AlgoKind) —
    /// table-driven or model-predicted alike). 0 when algorithms were
    /// named explicitly.
    pub auto_picks: u64,
    /// Nbc epochs closed on this rank (each quiesce that reclaimed the
    /// epoch's tags counts once).
    pub epochs: u64,
    /// Nbc tags returned to the free pool by epoch reclamation.
    pub tags_recycled: u64,
    /// Schedule-engine steps this rank executed (each send-half,
    /// recv-half, or fused sendrecv completion counts once). 0 under the
    /// threaded engine.
    pub steps_executed: u64,
    /// Times this rank's progress loop woke up and scanned for ready
    /// steps while driving schedule-engine operations.
    pub progress_wakeups: u64,
    /// Peak number of runnable steps observed in one progress scan on
    /// this rank (`merge` takes the max, not the sum). 0 under the
    /// threaded engine.
    pub ready_queue_max: u64,
}

impl RankMetrics {
    /// Merge another rank's counters (for per-shard or world-level
    /// aggregation). `shard_id` is a label, not a counter: the left-hand
    /// side's id is kept, so each rank contributes its counters to exactly
    /// one aggregate and leader ranks are never double-counted.
    pub fn merge(&mut self, other: &RankMetrics) {
        self.exchanges += other.exchanges;
        self.sendrecvs += other.sendrecvs;
        self.bytes_sent += other.bytes_sent;
        self.bytes_recv += other.bytes_recv;
        self.reduce_bytes += other.reduce_bytes;
        self.barriers += other.barriers;
        self.bytes_copied += other.bytes_copied;
        self.allocs += other.allocs;
        self.pool_recycled += other.pool_recycled;
        self.elems_reduced += other.elems_reduced;
        self.backend_hits.merge(&other.backend_hits);
        self.stall_us += other.stall_us;
        self.queue_full_events += other.queue_full_events;
        self.max_queue_depth = self.max_queue_depth.max(other.max_queue_depth);
        self.ops_in_flight_max = self.ops_in_flight_max.max(other.ops_in_flight_max);
        self.fused_ops += other.fused_ops;
        self.fused_elems += other.fused_elems;
        self.fault_events += other.fault_events;
        self.retransmits += other.retransmits;
        self.auto_picks += other.auto_picks;
        self.epochs += other.epochs;
        self.tags_recycled += other.tags_recycled;
        self.steps_executed += other.steps_executed;
        self.progress_wakeups += other.progress_wakeups;
        self.ready_queue_max = self.ready_queue_max.max(other.ready_queue_max);
    }

    /// Fold one rank's buffer-layer counters (thread-local, harvested when
    /// the rank thread finishes) into this record.
    pub fn absorb_buffer_stats(&mut self, stats: &crate::buffer::BufStats) {
        self.bytes_copied += stats.bytes_copied;
        self.allocs += stats.allocs;
        self.pool_recycled += stats.pool_recycled;
    }

    /// Fold one rank's reduce-backend counters (thread-local, harvested
    /// when the rank thread finishes) into this record.
    pub fn absorb_backend_stats(&mut self, stats: &crate::ops::BackendStats) {
        self.elems_reduced += stats.elems_reduced;
        self.backend_hits.scalar += stats.scalar_hits;
        self.backend_hits.simd += stats.simd_hits;
        self.backend_hits.pjrt += stats.pjrt_hits;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_accumulates() {
        let mut a = RankMetrics {
            shard_id: 3,
            exchanges: 1,
            sendrecvs: 1,
            bytes_sent: 10,
            bytes_recv: 20,
            reduce_bytes: 5,
            barriers: 2,
            bytes_copied: 7,
            allocs: 3,
            pool_recycled: 1,
            elems_reduced: 9,
            backend_hits: BackendHits {
                scalar: 1,
                simd: 2,
                pjrt: 3,
            },
            stall_us: 1.5,
            queue_full_events: 4,
            max_queue_depth: 6,
            ops_in_flight_max: 3,
            fused_ops: 2,
            fused_elems: 100,
            fault_events: 11,
            retransmits: 3,
            auto_picks: 5,
            epochs: 2,
            tags_recycled: 7,
            steps_executed: 12,
            progress_wakeups: 30,
            ready_queue_max: 4,
        };
        let b = RankMetrics {
            max_queue_depth: 9,
            ops_in_flight_max: 5,
            ready_queue_max: 8,
            ..a.clone()
        };
        a.merge(&b);
        assert_eq!(a.shard_id, 3); // label, not summed
        assert_eq!(a.exchanges, 2);
        assert_eq!(a.bytes_sent, 20);
        assert_eq!(a.bytes_recv, 40);
        assert_eq!(a.reduce_bytes, 10);
        assert_eq!(a.barriers, 4);
        assert_eq!(a.bytes_copied, 14);
        assert_eq!(a.allocs, 6);
        assert_eq!(a.pool_recycled, 2);
        assert_eq!(a.elems_reduced, 18);
        assert_eq!(
            a.backend_hits,
            BackendHits {
                scalar: 2,
                simd: 4,
                pjrt: 6,
            }
        );
        assert!((a.stall_us - 3.0).abs() < 1e-12);
        assert_eq!(a.queue_full_events, 8);
        assert_eq!(a.max_queue_depth, 9); // max, not sum
        assert_eq!(a.ops_in_flight_max, 5); // max, not sum
        assert_eq!(a.fused_ops, 4);
        assert_eq!(a.fused_elems, 200);
        assert_eq!(a.fault_events, 22);
        assert_eq!(a.retransmits, 6);
        assert_eq!(a.auto_picks, 10);
        assert_eq!(a.epochs, 4);
        assert_eq!(a.tags_recycled, 14);
        assert_eq!(a.steps_executed, 24);
        assert_eq!(a.progress_wakeups, 60);
        assert_eq!(a.ready_queue_max, 8); // max, not sum
    }

    #[test]
    fn absorb_buffer_stats_folds_counters() {
        let mut m = RankMetrics::default();
        m.absorb_buffer_stats(&crate::buffer::BufStats {
            allocs: 2,
            pool_recycled: 5,
            bytes_copied: 128,
        });
        assert_eq!(m.allocs, 2);
        assert_eq!(m.pool_recycled, 5);
        assert_eq!(m.bytes_copied, 128);
    }

    #[test]
    fn absorb_backend_stats_folds_counters() {
        let mut m = RankMetrics::default();
        m.absorb_backend_stats(&crate::ops::BackendStats {
            elems_reduced: 1000,
            scalar_hits: 1,
            simd_hits: 2,
            pjrt_hits: 3,
        });
        assert_eq!(m.elems_reduced, 1000);
        assert_eq!(m.backend_hits.scalar, 1);
        assert_eq!(m.backend_hits.simd, 2);
        assert_eq!(m.backend_hits.pjrt, 3);
    }
}
