//! Communicator groups: ordered rank subsets with local ↔ global rank
//! translation, MPI-style `split`, and the borrowed sub-communicator
//! ([`SubComm`]) that runs any [`Comm`]-written collective on a subset of
//! a world.
//!
//! A [`Group`] is pure data — the same value is derived independently on
//! every member rank (from `p` and a [`Mapping`], or by splitting a parent
//! group), exactly like an `MPI_Group`: no communication is needed to
//! construct one, and agreement follows from determinism. A
//! [`SubComm`] then borrows a rank's [`ThreadComm`] endpoint and relabels
//! peers through the group, which is what `MPI_Comm_split` +
//! communicator-scoped collectives do, without duplicating any transport
//! state: the sub-communicator shares the endpoint's channels, virtual
//! clock, and metrics.

use super::metrics::RankMetrics;
use super::thread::ThreadComm;
use super::Comm;
use crate::buffer::DataBuf;
use crate::error::{Error, Result};
use crate::ops::Elem;
use crate::topo::Mapping;

/// An ordered subset of a world's ranks; position in the member list *is*
/// the local rank.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Group {
    members: Vec<usize>,
}

impl Group {
    /// A group over explicit members (position = local rank). Members must
    /// be non-empty and distinct; they need *not* be sorted — the order
    /// given is the reduction order a sub-communicator exposes.
    pub fn new(members: Vec<usize>) -> Result<Group> {
        if members.is_empty() {
            return Err(Error::Config("group must have at least one member".into()));
        }
        let mut seen = members.clone();
        seen.sort_unstable();
        if seen.windows(2).any(|w| w[0] == w[1]) {
            return Err(Error::Config("group members must be distinct".into()));
        }
        Ok(Group { members })
    }

    /// The full world `0..p` as a group.
    pub fn world(p: usize) -> Group {
        Group {
            members: (0..p).collect(),
        }
    }

    /// Number of members.
    pub fn size(&self) -> usize {
        self.members.len()
    }

    /// The members in local-rank order.
    pub fn members(&self) -> &[usize] {
        &self.members
    }

    /// True if `global` is a member.
    pub fn contains(&self, global: usize) -> bool {
        self.local_rank(global).is_some()
    }

    /// The local rank of `global` within this group, if a member.
    pub fn local_rank(&self, global: usize) -> Option<usize> {
        self.members.iter().position(|&g| g == global)
    }

    /// The global rank at local position `local`, if in range.
    pub fn global_rank(&self, local: usize) -> Option<usize> {
        self.members.get(local).copied()
    }

    /// `MPI_Comm_split` over this group: `color_key(global)` assigns every
    /// member a `(color, key)`; the result is one group per color (ordered
    /// by color), each ordered by `(key, global rank)` — so equal keys fall
    /// back to rank order, as in MPI. Every member lands in exactly one
    /// subgroup.
    pub fn split(&self, color_key: impl Fn(usize) -> (usize, i64)) -> Vec<Group> {
        let mut buckets: std::collections::BTreeMap<usize, Vec<(i64, usize)>> =
            std::collections::BTreeMap::new();
        for &g in &self.members {
            let (color, key) = color_key(g);
            buckets.entry(color).or_default().push((key, g));
        }
        buckets
            .into_values()
            .map(|mut v| {
                v.sort_unstable();
                Group {
                    members: v.into_iter().map(|(_, g)| g).collect(),
                }
            })
            .collect()
    }

    /// The node groups of a `p`-rank world: ordered by node id, members
    /// ascending. Built directly from [`Mapping::shards`] — the *same*
    /// partition the sharded registry uses for its edge-table and
    /// buffer-pool shards — so transport shards and hierarchical-allreduce
    /// node groups agree structurally, not by parallel construction.
    pub fn by_node(p: usize, mapping: Mapping) -> Vec<Group> {
        mapping
            .shards(p)
            .into_iter()
            .map(|members| {
                Group::new(members).expect("mapping shards are non-empty and disjoint")
            })
            .collect()
    }

    /// The leader group: local rank 0 of each given group, in group order.
    /// Errors if the groups share leaders (i.e. are not disjoint).
    pub fn leaders(groups: &[Group]) -> Result<Group> {
        Group::new(groups.iter().map(|g| g.members[0]).collect())
    }
}

/// A borrowed sub-communicator: `parent` restricted and relabelled to
/// `group`. Implements [`Comm`] by translating local peer ranks to global
/// ones, so every collective in this crate runs unchanged on the subset.
/// The virtual clock, wall stopwatch, and metrics are the *parent's* —
/// time spent inside a sub-communicator is time spent by the rank.
pub struct SubComm<'a, E: Elem> {
    parent: &'a mut ThreadComm<E>,
    group: &'a Group,
    local: usize,
}

impl<'a, E: Elem> SubComm<'a, E> {
    pub(super) fn new(parent: &'a mut ThreadComm<E>, group: &'a Group) -> Result<SubComm<'a, E>> {
        let world = parent.size();
        if let Some(&bad) = group.members().iter().find(|&&g| g >= world) {
            return Err(Error::Config(format!(
                "group member {bad} outside world of size {world}"
            )));
        }
        let local = group.local_rank(parent.rank()).ok_or_else(|| {
            Error::Config(format!(
                "rank {} is not a member of the group {:?}",
                parent.rank(),
                group.members()
            ))
        })?;
        Ok(SubComm {
            parent,
            group,
            local,
        })
    }

    /// The group this sub-communicator is scoped to.
    pub fn group(&self) -> &Group {
        self.group
    }

    fn global(&self, peer: usize) -> Result<usize> {
        self.group.global_rank(peer).ok_or_else(|| {
            Error::Config(format!(
                "peer {peer} out of range for group of size {}",
                self.group.size()
            ))
        })
    }
}

impl<E: Elem> Comm<E> for SubComm<'_, E> {
    fn rank(&self) -> usize {
        self.local
    }

    fn size(&self) -> usize {
        self.group.size()
    }

    fn sendrecv(&mut self, peer: usize, send: DataBuf<E>) -> Result<DataBuf<E>> {
        let peer = self.global(peer)?;
        self.parent.sendrecv(peer, send)
    }

    fn sendrecv_pair(
        &mut self,
        send_to: usize,
        send: DataBuf<E>,
        recv_from: usize,
    ) -> Result<DataBuf<E>> {
        let send_to = self.global(send_to)?;
        let recv_from = self.global(recv_from)?;
        self.parent.sendrecv_pair(send_to, send, recv_from)
    }

    fn send(&mut self, peer: usize, data: DataBuf<E>) -> Result<()> {
        let peer = self.global(peer)?;
        self.parent.send(peer, data)
    }

    fn recv(&mut self, peer: usize) -> Result<DataBuf<E>> {
        let peer = self.global(peer)?;
        self.parent.recv(peer)
    }

    fn barrier(&mut self) -> Result<()> {
        self.parent.group_barrier_wait(self.group.members())
    }

    fn charge_compute(&mut self, bytes: usize) {
        self.parent.charge_compute(bytes);
    }

    fn time_us(&self) -> f64 {
        self.parent.time_us()
    }

    fn reset_time(&mut self) {
        self.parent.reset_time();
    }

    fn metrics(&self) -> &RankMetrics {
        self.parent.metrics()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{run_world, Timing};

    #[test]
    fn world_and_translation() {
        let g = Group::world(5);
        assert_eq!(g.size(), 5);
        assert_eq!(g.local_rank(3), Some(3));
        assert_eq!(g.global_rank(4), Some(4));
        assert_eq!(g.global_rank(5), None);
        assert!(!g.contains(5));
    }

    #[test]
    fn new_rejects_bad_member_lists() {
        assert!(Group::new(vec![]).is_err());
        assert!(Group::new(vec![1, 3, 1]).is_err());
        // unsorted is fine — order is the local rank order
        let g = Group::new(vec![4, 0, 2]).unwrap();
        assert_eq!(g.local_rank(4), Some(0));
        assert_eq!(g.global_rank(2), Some(2));
    }

    #[test]
    fn split_partitions_by_color_and_orders_by_key() {
        let g = Group::world(7);
        // color = parity; key = descending rank for odds, rank for evens
        let parts = g.split(|r| {
            if r % 2 == 0 {
                (0, r as i64)
            } else {
                (1, -(r as i64))
            }
        });
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].members(), &[0, 2, 4, 6]);
        assert_eq!(parts[1].members(), &[5, 3, 1]); // key order, not rank
    }

    #[test]
    fn by_node_and_leaders() {
        let groups = Group::by_node(10, Mapping::Block { ranks_per_node: 4 });
        assert_eq!(groups.len(), 3);
        assert_eq!(groups[2].members(), &[8, 9]); // ragged tail
        let leaders = Group::leaders(&groups).unwrap();
        assert_eq!(leaders.members(), &[0, 4, 8]);
        // overlapping groups cannot form a leader group
        let overlap = [Group::world(2), Group::world(3)];
        assert!(Group::leaders(&overlap).is_err());
    }

    #[test]
    fn subcomm_relabels_and_exchanges() {
        // world of 6; the even-rank group {0, 2, 4} runs a local ring
        // exchange under its own rank labels
        let report = run_world::<i32, _, _>(6, Timing::Real, |comm| {
            let g = Group::new(vec![0, 2, 4]).unwrap();
            if !g.contains(comm.rank()) {
                return Ok(-1);
            }
            let mut sub = comm.sub(&g)?;
            let me = sub.rank();
            let right = (me + 1) % sub.size();
            let left = (me + sub.size() - 1) % sub.size();
            let got = sub.sendrecv_pair(right, DataBuf::real(vec![me as i32]), left)?;
            Ok(got.into_vec()?[0])
        })
        .unwrap();
        // each even rank receives its left neighbor's local id
        assert_eq!(report.results, vec![2, -1, 0, -1, 1, -1]);
    }

    #[test]
    fn subcomm_rejects_non_members_and_bad_peers() {
        let report = run_world::<i32, _, _>(3, Timing::Real, |comm| {
            let g = Group::new(vec![0, 2]).unwrap();
            match comm.rank() {
                1 => Ok(comm.sub(&g).is_err()),
                _ => {
                    let mut sub = comm.sub(&g)?;
                    Ok(sub.send(5, DataBuf::real(vec![1])).is_err())
                }
            }
        })
        .unwrap();
        assert_eq!(report.results, vec![true, true, true]);
    }

    #[test]
    fn subcomm_barrier_syncs_group_clocks_only() {
        use crate::model::{ComputeCost, CostModel, LinkCost};
        let timing = Timing::Virtual(
            CostModel::Uniform(LinkCost::new(1e-6, 0.0)),
            ComputeCost::new(1e-6), // 1 µs per reduced byte, to skew clocks
        );
        let report = run_world::<i32, _, _>(4, timing, |comm| {
            if comm.rank() < 2 {
                // skew the two clocks (0 µs vs 5 µs), then group-barrier
                comm.charge_compute(comm.rank() * 5);
                let g = Group::new(vec![0, 1]).unwrap();
                let mut sub = comm.sub(&g)?;
                sub.barrier()?;
            }
            Ok(comm.time_us())
        })
        .unwrap();
        // the group barrier advances exactly its members to the group max
        assert!((report.results[0] - 5.0).abs() < 1e-9, "{:?}", report.results);
        assert!((report.results[1] - 5.0).abs() < 1e-9);
        assert_eq!(report.results[2], 0.0);
        assert_eq!(report.results[3], 0.0);
    }
}
