//! `dpdr` — the command-line launcher.
//!
//! ```text
//! dpdr run        --algo dpdr --p 288 --m 1000000 [--block 16000] [--phantom] [--real-time]
//!                 [--hier] [--mapping block:8] [--trace out.json] [--trace-cap 65536]
//! dpdr concurrent --p 288 --m 1024 --k 8 [--algos dpdr,ring] [--fuse-threshold 1024]
//!                 [--fuse-max-ops 8]       K outstanding nonblocking allreduces per rank
//! dpdr soak       --p 8 --ops 100000 [--faults transient-drop,stall] [--seed 7]
//!                 [--deadline-us N] [--max-in-flight N] [--engine threaded|schedule]
//!                 [--trace out.json] [--json report.json]   serving-mode endurance run
//! dpdr critical-path TRACE.json [--json out.json] [--assert-model 0.30]
//!                 happens-before walk + alpha/beta/gamma/stall attribution of a trace
//! dpdr table2     [--p 288] [--block 16000] [--rounds 3] [--tsv out.tsv]  reproduce Table 2
//! dpdr fig1       [--tsv out.tsv]                                         Figure 1 series
//! dpdr latency    [--hmax 12]                                             §1.2 4h−3 check
//! dpdr blocksize  --p 288 --m 1000000                                     Pipelining-Lemma sweep
//! dpdr verify     [--all] [--m 40] [--blocks 1,3,8] [--caps 1,2,3] [--json FILE]
//!                 static schedule verification + trace checks
//! dpdr validate   [--pmax 16]                                             correctness battery
//! dpdr tune       [--check] [--write]                                     autotuning sweep
//! dpdr calibrate                                                          thread-transport α/β fit
//! dpdr sysinfo
//! ```
//!
//! `--algo hier` runs the node-aware hierarchical allreduce over the node
//! layout given by `--mapping` (`block:K` / `rr:N`); `--hier` switches the
//! *cost model* to two-level links over the same layout — they compose.

use dpdr::cli::Args;
use dpdr::collectives::RunSpec;
use dpdr::comm::Timing;
use dpdr::error::{Error, Result};
use dpdr::harness::{
    measure, measure_series, measure_with_metrics, render_markdown, render_tsv, TABLE2_COUNTS,
};
use dpdr::model::{
    paper_h, predicted_time_us, predicted_time_us_net, AlgoKind, ComputeCost, CostModel,
    LinkCost, NetParams,
};
use dpdr::pipeline::Blocks;

const BOOL_FLAGS: &[&str] = &[
    "phantom", "real-time", "hier", "markdown", "help", "no-fuse", "all", "check", "write",
];

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, BOOL_FLAGS)?;
    if args.switch("help") || args.subcommand().is_none() {
        print_help();
        return Ok(());
    }
    match args.subcommand().unwrap() {
        "run" => cmd_run(&args),
        "concurrent" => cmd_concurrent(&args),
        "soak" => cmd_soak(&args),
        "critical-path" => cmd_critical_path(&args),
        "table2" => cmd_table2(&args),
        "fig1" => cmd_fig1(&args),
        "latency" => cmd_latency(&args),
        "blocksize" => cmd_blocksize(&args),
        "verify" => cmd_verify(&args),
        "validate" => cmd_validate(&args),
        "tune" => cmd_tune(&args),
        "calibrate" => cmd_calibrate(&args),
        "sysinfo" => cmd_sysinfo(),
        other => Err(Error::Cli(format!("unknown subcommand '{other}'"))),
    }
}

fn print_help() {
    println!(
        "dpdr — doubly-pipelined dual-root reduction-to-all (Träff 2021 reproduction)

subcommands:
  run        one collective: --algo {{dpdr|dpsingle|pipetree|redbcast|native|twotree|ring|rd|rab|hier|scan|nonpipelined|auto}}
             --p N --m N [--block N] [--phantom] [--real-time] [--hier] [--rounds N]
             [--schedule fixed|lemma|greedy]  (pipeline partition: the fixed --block size,
             the Pipelining-Lemma optimum, or the greedy discrete optimum; auto picks the
             algorithm from the committed tune table or the analytic model)
             [--mapping block:K|rr:N]  (node layout for --algo hier / --hier cost model)
             [--ports-per-node N]      (congestion-aware timing: concurrent inter-node
             transfers per node and direction serialize through N NIC ports; 0 = dedicated)
             [--edge-capacity N] [--edge-capacity-intra N]  (virtual injection-queue depth
             per directed edge; posting to a full queue stalls the sender's clock; 0 = unbounded)
             [--reduce-backend auto|scalar|simd|pjrt]  (kernel for the block-wise reduction;
             pjrt needs AOT artifacts — set DPDR_ARTIFACTS — and falls back simd -> scalar)
             [--trace FILE]     (record one dedicated traced iteration after the timed
             rounds and write a Chrome-trace JSON — open in Perfetto, or feed to
             `dpdr critical-path`; virtual-time traces are bitwise run-to-run stable)
             [--trace-cap N]    (per-rank event ring capacity, default 65536; overflow
             drops oldest and is counted in the export)
  concurrent K outstanding nonblocking allreduces per rank through the nbc engine:
             --p N --m N [--k 8] [--algos dpdr,ring,...] (rotation over the K ops)
             [--fuse-threshold N]  (ops of <= N elements coalesce into one fused dpdr; 0 = off)
             [--fuse-max-ops N]    (fused batch size; batches also close on flush()/wait_all)
             plus the run timing/backend/congestion flags; verifies every op against its
             oracle and reports overlap/fusion metrics
  soak       serving-mode endurance run: a long stream of mixed-size nonblocking
             allreduces on one world, every payload verified against a closed-form
             oracle, registry memory held flat by epoch tag reclamation:
             --p N --ops N [--m-min 8] [--m-max 1024] [--batch 64] [--epoch-ops 256]
             [--max-in-flight N]  (admission budget; excess submissions shed with a
             typed Overloaded error, then drained and resubmitted)
             [--deadline-us X]    (per-op completion deadline; misses are counted)
             [--faults LIST]      (inject transport faults: delay,dup,reorder,
             transient-drop,stall,all,none — deterministic under --seed)
             [--seed N] [--window 1024] [--check-every 97] [--no-fuse] [--real-time]
             [--engine threaded|schedule]  (schedule: compile ops to per-rank step
             programs driven by the shared progress core — no thread per op, true
             deadline cancellation; implies --no-fuse)
             [--trace FILE]  (record the whole soak into a Chrome-trace JSON)
             [--trace-cap N] [--json FILE]  (write the SoakReport as JSON)
  critical-path  walk a recorded trace's happens-before DAG backwards from the
             last event and attribute the chain to alpha (latency), beta (bandwidth),
             gamma (reduction), stall (shared-NIC/backpressure), and wait buckets;
             compares against the paper's closed-form prediction when the trace
             carries a uniform virtual model:
             dpdr critical-path TRACE.json [--json FILE]
             [--assert-model TOL]  (exit nonzero if |measured-predicted|/predicted
             exceeds TOL; 0.30 matches the documented model tolerance)
  table2     reproduce the paper's Table 2 (4 algorithms x 30 counts)
             [--p 288] [--block 16000] [--rounds 3] [--tsv FILE] [--markdown]
  fig1       Figure 1 series (TSV for log-log plotting) [--tsv FILE]
  latency    validate the 4h-3 latency formula over p = 2^h - 2
  blocksize  Pipelining-Lemma sweep: measured vs analytic optimum
  verify     static schedule verification: prove matching, deadlock-freedom at
             bounded edge capacities, buffer/lease safety, and reduction-shape
             determinism for every compiled (algo, p, blocks) point, and
             trace-check the uncompiled algorithms through the same analysis:
             [--all]  (p = 2..64 instead of the quick sweep; what CI runs)
             [--m 40] [--blocks 1,3,8] [--caps 1,2,3] [--oracle-pmax 16]
             [--json FILE]  (write the ScheduleCert array)
  validate   correctness battery across algorithms/p/m
  tune       sweep the autotuning grid through the virtual-clock harness:
             (default)  print the winners
             [--check]  exit nonzero if the committed TUNE_table.json drifted
             [--write]  rewrite TUNE_table.json in place
  calibrate  fit alpha/beta of the real thread transport
  sysinfo    model constants and environment"
    );
}

/// The rank → node layout: `--mapping block:K|rr:N`, defaulting to the
/// paper's `block:<ppn>` (with `--ppn`, default 8).
fn mapping_of(args: &Args) -> Result<dpdr::topo::Mapping> {
    let ranks_per_node = args.get("ppn", 8usize)?;
    args.get_parsed(
        "mapping",
        dpdr::topo::Mapping::Block { ranks_per_node },
        dpdr::topo::Mapping::parse,
    )
}

/// Timing selection shared by the commands.
fn timing_of(args: &Args) -> Result<Timing> {
    if args.switch("real-time") {
        return Ok(Timing::Real);
    }
    let alpha = args.get("alpha", 1.0e-6)?;
    let beta = args.get("beta", 0.70e-9)?;
    let gamma = args.get("gamma", 0.25e-9)?;
    let model = if args.switch("hier") {
        CostModel::Hierarchical {
            intra: LinkCost::new(
                args.get("alpha-intra", 0.3e-6)?,
                args.get("beta-intra", 0.08e-9)?,
            ),
            inter: LinkCost::new(alpha, beta),
            mapping: mapping_of(args)?,
        }
    } else {
        CostModel::Uniform(LinkCost::new(alpha, beta))
    };
    Ok(Timing::Virtual(model, ComputeCost::new(gamma)))
}

/// The shared-network parameters from `--ports-per-node` /
/// `--edge-capacity` / `--edge-capacity-intra` (all default 0 =
/// unlimited, i.e. the dedicated model).
fn net_of(args: &Args) -> Result<NetParams> {
    let inter = args.get("edge-capacity", 0usize)?;
    Ok(NetParams {
        ports_per_node: args.get("ports-per-node", 0usize)?,
        edge_capacity_inter: inter,
        edge_capacity_intra: args.get("edge-capacity-intra", inter)?,
    })
}

fn cmd_run(args: &Args) -> Result<()> {
    let algo = AlgoKind::parse(args.raw("algo").unwrap_or("dpdr"))
        .ok_or_else(|| Error::Cli("bad --algo".into()))?;
    let p = args.get("p", 288usize)?;
    let m = args.get("m", 1_000_000usize)?;
    let block = args.get("block", dpdr::pipeline::PAPER_BLOCK_ELEMS)?;
    let rounds = args.get("rounds", 1usize)?;
    let backend = args.get_parsed(
        "reduce-backend",
        dpdr::ops::ReduceBackend::Auto,
        dpdr::ops::ReduceBackend::parse,
    )?;
    let sched = args.get_parsed(
        "schedule",
        dpdr::pipeline::SchedKind::Fixed,
        dpdr::pipeline::SchedKind::parse,
    )?;
    let net = net_of(args)?;
    let spec = RunSpec::new(p, m)
        .block_elems(block)
        .sched(sched)
        .phantom(args.switch("phantom"))
        .mapping(mapping_of(args)?)
        .reduce_backend(backend)
        .net(net);
    // the effective timing (the harness applies the same upgrade, so the
    // analytic printouts below see the model the run actually used)
    let timing = spec.effective_timing(timing_of(args)?);
    let (meas, totals) = measure_with_metrics(algo, &spec, timing, rounds)?;
    println!(
        "algo={} p={} m={} block={} rounds={} backend={} time_us={:.2}",
        algo.name(),
        p,
        m,
        block,
        rounds,
        backend.name(),
        meas.time_us
    );
    if !spec.phantom {
        // which kernels actually served the block reductions (same run as
        // the timing above, accumulated over all rounds)
        println!(
            "reduce_backend_hits: scalar={} simd={} pjrt={} elems_reduced={}",
            totals.backend_hits.scalar,
            totals.backend_hits.simd,
            totals.backend_hits.pjrt,
            totals.elems_reduced
        );
    }
    if !net.is_dedicated() {
        // how much third-party traffic cost this run (summed over ranks
        // and rounds)
        println!(
            "congestion: stall_us={:.2} queue_full_events={} max_queue_depth={}",
            totals.stall_us, totals.queue_full_events, totals.max_queue_depth
        );
    }
    if let Timing::Virtual(model, _) = timing {
        // the partition the run actually used (--schedule aware; Auto
        // resolves through the same oracle the harness consulted)
        let b = spec.blocks_for(algo, timing)?.count();
        if !model.net_params().is_dedicated() {
            let pred = predicted_time_us_net(algo, p, m * 4, b, &model);
            println!("analytic_us={pred:.2} (congestion-aware: dedicated form vs NIC floor)");
        } else if algo == AlgoKind::Hier {
            // two-level closed form over the actual link levels
            if let dpdr::topo::Mapping::Block { ranks_per_node } = spec.mapping {
                let (intra, inter) = model.link_levels();
                let pred =
                    dpdr::model::predicted_time_us_hier(p, ranks_per_node, m * 4, b, intra, inter);
                println!("analytic_us={pred:.2} (two-level node-aware form)");
            }
        } else if let Some(link) = model.as_uniform() {
            let pred = predicted_time_us(algo, p, m * 4, b, link);
            println!("analytic_us={pred:.2} (paper Sec. 1.2 formula)");
        }
    }
    if let Some(path) = args.raw("trace") {
        write_run_trace(path, trace_cap(args)?, algo, &spec, timing)?;
    }
    Ok(())
}

/// `--trace-cap`: per-rank event ring capacity.
fn trace_cap(args: &Args) -> Result<usize> {
    args.get("trace-cap", 65_536usize)
}

/// Self-describing metadata for an exported trace. Carries the resolved
/// block count and, for uniform virtual runs, the α/β/γ constants the
/// critical-path analyzer needs to rebuild the model comparison.
fn trace_meta(
    algo: Option<AlgoKind>,
    spec: &RunSpec,
    timing: Timing,
    source: &str,
) -> Result<dpdr::obs::TraceMeta> {
    let mut meta = dpdr::obs::TraceMeta {
        algo: algo.map(|a| a.name()).unwrap_or(source).to_string(),
        p: spec.p,
        m_elems: spec.m,
        elem_bytes: 4,
        blocks: 0,
        alpha: 0.0,
        beta: 0.0,
        gamma: 0.0,
        virtual_time: matches!(timing, Timing::Virtual(..)),
        source: source.to_string(),
    };
    if let Some(a) = algo {
        meta.blocks = spec.blocks_for(a, timing)?.count();
    }
    if let Timing::Virtual(model, compute) = timing {
        if let Some(link) = model.as_uniform() {
            meta.alpha = link.alpha;
            meta.beta = link.beta;
        }
        meta.gamma = compute.gamma;
    }
    Ok(meta)
}

/// One dedicated traced iteration, run *after* the timed rounds so the
/// recording overhead never pollutes the reported numbers, exported as
/// Chrome-trace JSON (Perfetto-loadable, `dpdr critical-path`-readable).
fn write_run_trace(
    path: &str,
    cap: usize,
    algo: AlgoKind,
    spec: &RunSpec,
    timing: Timing,
) -> Result<()> {
    if !dpdr::obs::start(spec.p, cap) {
        return Err(Error::Cli("a trace is already recording".into()));
    }
    let run = dpdr::collectives::run_allreduce_i32(algo, spec, timing);
    // stop (and thus disarm) the collector even when the run failed,
    // then surface the run's error first — it is the interesting one
    let trace = dpdr::obs::stop(trace_meta(Some(algo), spec, timing, "run")?);
    run?;
    let trace = trace.ok_or_else(|| Error::Protocol("trace collector vanished".into()))?;
    std::fs::write(path, dpdr::obs::export::to_chrome_json(&trace))?;
    eprintln!(
        "# wrote {path}: {} events ({} dropped) — Perfetto or `dpdr critical-path {path}`",
        trace.events.len(),
        trace.dropped
    );
    Ok(())
}

/// `dpdr concurrent`: every rank keeps `--k` nonblocking allreduces in
/// flight through an [`dpdr::nbc::Engine`], optionally fusing the small
/// ones, then verifies every operation against its sequential oracle.
fn cmd_concurrent(args: &Args) -> Result<()> {
    use dpdr::nbc::{run_concurrent_i32, ConcurrentSpec, FusePolicy};
    let p = args.get("p", 8usize)?;
    let m = args.get("m", 1024usize)?;
    let k = args.get("k", 8usize)?;
    let block = args.get("block", dpdr::pipeline::PAPER_BLOCK_ELEMS)?;
    let fuse_threshold = args.get("fuse-threshold", 0usize)?;
    let fuse_max_ops = args.get("fuse-max-ops", 8usize)?;
    let algos: Vec<AlgoKind> = match args.raw("algos") {
        None => vec![AlgoKind::Dpdr],
        Some(list) => list
            .split(',')
            .map(|s| {
                AlgoKind::parse(s.trim())
                    .ok_or_else(|| Error::Cli(format!("bad algo '{s}' in --algos")))
            })
            .collect::<Result<_>>()?,
    };
    let backend = args.get_parsed(
        "reduce-backend",
        dpdr::ops::ReduceBackend::Auto,
        dpdr::ops::ReduceBackend::parse,
    )?;
    let base = RunSpec::new(p, m)
        .block_elems(block)
        .phantom(args.switch("phantom"))
        .mapping(mapping_of(args)?)
        .reduce_backend(backend)
        .net(net_of(args)?);
    let fuse = if fuse_threshold > 0 {
        FusePolicy::new(fuse_threshold, fuse_max_ops)
    } else {
        FusePolicy::off()
    };
    let cspec = ConcurrentSpec::new(base, k).algos(algos.clone()).fuse(fuse);
    // the driver applies the spec's net upgrade itself; compute the
    // effective model here only for the analytic printout below, so the
    // executed and printed models cannot diverge
    let report = run_concurrent_i32(&cspec, timing_of(args)?)?;
    let timing = base.effective_timing(timing_of(args)?);
    // verify every op on every rank against its oracle (real mode only);
    // the oracles are O(p·m) each, so compute them once, not per rank
    let mut verified = 0usize;
    if !base.phantom {
        let oracles: Vec<Vec<i32>> = (0..k).map(|i| cspec.op_expected(i)).collect();
        for (rank, (bufs, _t)) in report.results.iter().enumerate() {
            for (i, buf) in bufs.iter().enumerate() {
                let got = buf.as_slice().expect("real payload");
                if got != &oracles[i][..] {
                    return Err(Error::Protocol(format!(
                        "op {i} ({}) wrong on rank {rank}",
                        cspec.op_algo(i).name()
                    )));
                }
                verified += 1;
            }
        }
    }
    let totals = report.total_metrics();
    let time_us = dpdr::nbc::driver::concurrent_time_us(&report);
    println!(
        "concurrent: p={p} m={m} k={k} algos={} time_us={time_us:.2} verified={verified}",
        algos.iter().map(|a| a.name()).collect::<Vec<_>>().join(","),
    );
    println!(
        "nbc: ops_in_flight_max={} fused_ops={} fused_elems={}",
        totals.ops_in_flight_max, totals.fused_ops, totals.fused_elems
    );
    if !base.net.is_dedicated() {
        println!(
            "congestion: stall_us={:.2} queue_full_events={} max_queue_depth={}",
            totals.stall_us, totals.queue_full_events, totals.max_queue_depth
        );
    }
    if let Timing::Virtual(model, _) = timing {
        // what the model says fusion should buy at this size
        let link = model.link_levels().1;
        let speedup = dpdr::model::predicted_fusion_speedup(p, m * 4, k, link);
        println!("analytic fused speedup (k ops of m, one alpha-chain): {speedup:.2}x");
    }
    Ok(())
}

/// `dpdr soak`: the serving-mode endurance run — a seeded stream of
/// mixed-size nonblocking allreduces on one long-lived world, optionally
/// under an injected fault plan, with every payload verified in the loop.
/// Exits nonzero on any corruption, hang-turned-typed-error, or registry
/// entries leaking past the final quiesce.
fn cmd_soak(args: &Args) -> Result<()> {
    use dpdr::comm::FaultPlan;
    use dpdr::nbc::{run_soak, SoakSpec};
    let p = args.get("p", 8usize)?;
    let ops = args.get("ops", 100_000u64)?;
    let seed = args.get("seed", 1u64)?;
    let mut spec = SoakSpec::new(p, ops);
    spec.seed = seed;
    spec.m_min = args.get("m-min", spec.m_min)?;
    spec.m_max = args.get("m-max", spec.m_max)?;
    spec.batch = args.get("batch", spec.batch)?;
    spec.epoch_ops = args.get("epoch-ops", spec.epoch_ops)?;
    spec.max_in_flight = args.get("max-in-flight", spec.max_in_flight)?;
    spec.window = args.get("window", spec.window)?;
    spec.check_every = args.get("check-every", spec.check_every)?;
    let dl = args.get("deadline-us", 0.0f64)?;
    spec.deadline_us = (dl > 0.0).then_some(dl);
    spec.fuse = !args.switch("no-fuse");
    spec.engine = args.raw("engine").unwrap_or("threaded").parse()?;
    if spec.engine == dpdr::nbc::EngineKind::Schedule {
        // fused batches ride worker threads; the point of --engine
        // schedule is to drive every op through the progress core
        spec.fuse = false;
    }
    spec.timing = timing_of(args)?;
    let faults = args.raw("faults").unwrap_or("none");
    spec.faults = FaultPlan::parse(faults, seed).ok_or_else(|| {
        Error::Cli(format!(
            "bad --faults '{faults}' (delay,dup,reorder,transient-drop,stall,all,none)"
        ))
    })?;
    eprintln!(
        "# soak: p={p} ops={ops} m={}..{} batch={} epoch_ops={} faults={faults} seed={seed} \
         engine={}",
        spec.m_min,
        spec.m_max,
        spec.batch,
        spec.epoch_ops,
        spec.engine.name()
    );
    let trace_path = args.raw("trace");
    if trace_path.is_some() && !dpdr::obs::start(p, trace_cap(args)?) {
        return Err(Error::Cli("a trace is already recording".into()));
    }
    let run = run_soak(&spec);
    if let Some(path) = trace_path {
        // mixed-size stream: no single (m, blocks), so those stay 0 and
        // the critical-path analyzer reports measured-only
        let meta = trace_meta(None, &RunSpec::new(p, 0), spec.timing, "soak")?;
        // always disarm the collector; only export when the soak passed
        // (its error, surfaced below, is the interesting one)
        let trace = dpdr::obs::stop(meta);
        if run.is_ok() {
            let trace =
                trace.ok_or_else(|| Error::Protocol("trace collector vanished".into()))?;
            std::fs::write(path, dpdr::obs::export::to_chrome_json(&trace))?;
            eprintln!(
                "# wrote {path}: {} events ({} dropped)",
                trace.events.len(),
                trace.dropped
            );
        }
    }
    let r = run?;
    println!(
        "soak: completed={}/{} per rank, deadline_misses={} overload_rejections={}",
        r.ops_completed, ops, r.deadline_misses, r.overload_rejections
    );
    println!(
        "epochs={} tags_recycled={} entries_high_water={} entries_final={}",
        r.epochs, r.tags_recycled, r.entries_high_water, r.entries_final
    );
    println!(
        "faults: retransmits={} fault_events={}",
        r.retransmits, r.fault_events
    );
    println!(
        "latency window: p50_us={:.2} p90_us={:.2} p99_us={:.2}; wall_us={:.0} vtime_us={:.2}",
        r.p50_us, r.p90_us, r.p99_us, r.wall_us, r.max_vtime_us
    );
    if let Some(path) = args.raw("json") {
        std::fs::write(path, format!("{}\n", r.to_json()))?;
        eprintln!("# wrote {path}");
    }
    if r.ops_completed != ops {
        return Err(Error::Protocol(format!(
            "soak lost operations: {}/{ops} completed",
            r.ops_completed
        )));
    }
    if r.entries_final != 0 {
        return Err(Error::Protocol(format!(
            "{} registry entries leaked past the final quiesce",
            r.entries_final
        )));
    }
    Ok(())
}

/// `dpdr critical-path TRACE.json`: rebuild the spans and metadata from
/// an exported Chrome trace, walk the happens-before DAG backwards from
/// the last event, and print the α/β/γ/stall/wait attribution next to
/// the paper's closed-form prediction (when the trace carries a uniform
/// virtual model). `--assert-model TOL` turns the comparison into a
/// gate: exit nonzero when |measured − predicted| / predicted > TOL —
/// 0.30 is the documented tolerance the virtual-time tests hold the
/// analytic formulas to.
fn cmd_critical_path(args: &Args) -> Result<()> {
    let path = args
        .positional
        .get(1)
        .ok_or_else(|| Error::Cli("usage: dpdr critical-path TRACE.json".into()))?;
    let text = std::fs::read_to_string(path)?;
    let (meta, spans) = dpdr::obs::export::read_chrome_json(&text)?;
    let report = dpdr::obs::critical::analyze(&meta, &spans);
    println!(
        "critical-path: algo={} p={} source={} spans={} hops={} measured_us={:.2}",
        report.algo,
        report.p,
        if meta.source.is_empty() { "?" } else { meta.source.as_str() },
        spans.len(),
        report.hops,
        report.measured_us
    );
    let b = &report.buckets;
    println!(
        "attribution: alpha_us={:.2} beta_us={:.2} gamma_us={:.2} stall_us={:.2} \
         wait_us={:.2} other_us={:.2}",
        b.alpha_us, b.beta_us, b.gamma_us, b.stall_us, b.wait_us, b.other_us
    );
    match (report.predicted_us, report.rel_err) {
        (Some(pred), Some(err)) => {
            println!("model: predicted_us={pred:.2} rel_err={:.1}%", err * 100.0)
        }
        _ => println!("model: no uniform virtual model in trace (measured-only)"),
    }
    if let Some(out) = args.raw("json") {
        std::fs::write(out, report.to_json())?;
        eprintln!("# wrote {out}");
    }
    let tol = args.get("assert-model", 0.0f64)?;
    if tol > 0.0 {
        let err = report.rel_err.ok_or_else(|| {
            Error::Protocol("--assert-model: trace carries no model to compare against".into())
        })?;
        if err > tol {
            return Err(Error::Protocol(format!(
                "critical-path drifted from the model: rel_err {:.1}% > {:.1}%",
                err * 100.0,
                tol * 100.0
            )));
        }
        println!("assert-model: ok (rel_err within {:.1}%)", tol * 100.0);
    }
    Ok(())
}

/// The paper's four evaluation columns.
fn table2_algos() -> Vec<AlgoKind> {
    vec![
        AlgoKind::NativeSwitch,
        AlgoKind::ReduceBcast,
        AlgoKind::PipeTree,
        AlgoKind::Dpdr,
    ]
}

fn cmd_table2(args: &Args) -> Result<()> {
    let p = args.get("p", 288usize)?;
    let block = args.get("block", dpdr::pipeline::PAPER_BLOCK_ELEMS)?;
    let rounds = args.get("rounds", 1usize)?;
    let spec = RunSpec::new(p, 0).block_elems(block).phantom(true);
    let timing = timing_of(args)?;
    let algos = table2_algos();
    eprintln!(
        "# table2: p={p} block={block} timing={} (runs {} experiments)",
        if args.switch("real-time") { "real" } else { "virtual" },
        algos.len() * TABLE2_COUNTS.len()
    );
    let rows = measure_series(&algos, &TABLE2_COUNTS, &spec, timing, rounds)?;
    let md = render_markdown(&algos, &rows);
    println!("{md}");
    if let Some(path) = args.raw("tsv") {
        std::fs::write(path, render_tsv(&algos, &rows))?;
        eprintln!("# wrote {path}");
    }
    Ok(())
}

fn cmd_fig1(args: &Args) -> Result<()> {
    let p = args.get("p", 288usize)?;
    let block = args.get("block", dpdr::pipeline::PAPER_BLOCK_ELEMS)?;
    let spec = RunSpec::new(p, 0).block_elems(block).phantom(true);
    let timing = timing_of(args)?;
    let algos = table2_algos();
    let rows = measure_series(&algos, &TABLE2_COUNTS, &spec, timing, 1)?;
    let tsv = render_tsv(&algos, &rows);
    match args.raw("tsv") {
        Some(path) => {
            std::fs::write(path, &tsv)?;
            eprintln!(
                "# wrote {path} (plot: gnuplot> set logscale xy; plot for [i=2:5] '{path}' u 1:i w lp)"
            );
        }
        None => println!("{tsv}"),
    }
    Ok(())
}

fn cmd_latency(args: &Args) -> Result<()> {
    let hmax = args.get("hmax", 10usize)?;
    // α = 1, β = 0, b = 1 block ⇒ the virtual time in µs *is* the number of
    // critical-path communication steps; compare against 4h − 3 (§1.2).
    let timing = Timing::Virtual(
        CostModel::Uniform(LinkCost::new(1e-6, 0.0)),
        ComputeCost::new(0.0),
    );
    println!("#p\th\tsteps_measured\tpaper_4h-3");
    for h in 2..=hmax {
        let p = (1usize << h) - 2;
        let spec = RunSpec::new(p, 1).block_elems(1).phantom(true);
        let meas = measure(AlgoKind::Dpdr, &spec, timing, 1)?;
        println!(
            "{p}\t{h}\t{:.0}\t{}",
            meas.time_us,
            4 * h as i64 - 3
        );
    }
    Ok(())
}

fn cmd_blocksize(args: &Args) -> Result<()> {
    let p = args.get("p", 288usize)?;
    let m = args.get("m", 1_000_000usize)?;
    let timing = timing_of(args)?;
    let link = match timing {
        Timing::Virtual(model, _) => model
            .as_uniform()
            .ok_or_else(|| Error::Cli("blocksize sweep needs the uniform model".into()))?,
        Timing::Real => return Err(Error::Cli("blocksize sweep is a model experiment".into())),
    };
    let (a, c) = AlgoKind::Dpdr.step_structure(p).unwrap();
    let (b_star, t_star) =
        dpdr::model::lemma::optimal_time(a, c, link.alpha, link.beta, (m * 4) as f64, m);
    println!("# p={p} m={m}: Pipelining-Lemma optimum b*={b_star} T*={:.2} us", t_star * 1e6);
    println!("#blocks\tblock_elems\tmeasured_us\tanalytic_us");
    let mut b = 1usize;
    while b <= m.min(1 << 16) {
        let block_elems = m.div_ceil(b);
        let spec = RunSpec::new(p, m).block_elems(block_elems).phantom(true);
        let meas = measure(AlgoKind::Dpdr, &spec, timing, 1)?;
        let analytic = predicted_time_us(AlgoKind::Dpdr, p, m * 4, b, link);
        println!("{b}\t{block_elems}\t{:.2}\t{:.2}", meas.time_us, analytic);
        b *= 2;
    }
    Ok(())
}

/// `dpdr verify`: run the static schedule verifier over the compiled
/// algorithms (matching, deadlock-freedom at the requested edge-queue
/// capacities, buffer/lease safety, reduction-shape determinism, and —
/// up to `--oracle-pmax` — agreement with the blocking oracle's combine
/// order), then trace-check the uncompiled algorithms through the same
/// analysis. Exits nonzero if any point has a violation.
fn cmd_verify(args: &Args) -> Result<()> {
    use dpdr::schedule::verify::{verify_compiled, verify_traced, ScheduleCert};
    let all = args.switch("all");
    let m = args.get("m", 40usize)?;
    let caps = args.get_usize_list("caps", &[1, 2, 3])?;
    let block_counts = args.get_usize_list("blocks", &[1, 3, 8])?;
    let oracle_pmax = args.get("oracle-pmax", 16usize)?;
    let ps: Vec<usize> = if all {
        (2..=64).collect()
    } else {
        vec![2, 3, 4, 5, 6, 8, 9, 14, 16]
    };
    // trace mode spawns a real p-thread world per point, so its sweep is
    // sparser; 24 and 33 cover past-a-node and non-power-of-two shapes
    let traced_ps: Vec<usize> = if all {
        vec![2, 3, 4, 5, 6, 7, 8, 9, 12, 16, 24, 33]
    } else {
        vec![2, 3, 4, 5, 7, 8, 12]
    };
    let compiled = [
        AlgoKind::Dpdr,
        AlgoKind::DpdrSingle,
        AlgoKind::Ring,
        AlgoKind::RecursiveDoubling,
    ];
    let traced = [
        AlgoKind::PipeTree,
        AlgoKind::ReduceBcast,
        AlgoKind::NativeSwitch,
        AlgoKind::TwoTree,
        AlgoKind::Rabenseifner,
        AlgoKind::NonPipelined,
    ];
    let mut certs: Vec<ScheduleCert> = Vec::new();
    let mut bad = 0usize;
    for algo in compiled {
        let before = certs.len();
        let mut ok = 0usize;
        for &p in &ps {
            for &b in &block_counts {
                let blocks = Blocks::by_count(m, b);
                let cert = verify_compiled(algo, p, &blocks, &caps, p <= oracle_pmax)?;
                report_cert(&cert, &mut bad);
                if cert.ok() {
                    ok += 1;
                }
                certs.push(cert);
            }
        }
        println!(
            "{:>10} [compiled]: {ok}/{} points ok (caps {caps:?}, oracle to p={oracle_pmax})",
            algo.name(),
            certs.len() - before
        );
    }
    // 300 ShapeElems = 9600 B pushes the count-based switcher onto its
    // ring branch, so both of its branches get trace-checked
    let trace_ms: Vec<usize> = if m == 300 { vec![300] } else { vec![m, 300] };
    for algo in traced {
        let before = certs.len();
        let mut ok = 0usize;
        let mut warns = 0usize;
        for &p in &traced_ps {
            for &tm in &trace_ms {
                let blocks = Blocks::by_count(tm, 4);
                let cert = verify_traced(algo, p, &blocks, &caps)?;
                report_cert(&cert, &mut bad);
                if cert.ok() {
                    ok += 1;
                }
                warns += cert.warnings.len();
                certs.push(cert);
            }
        }
        println!(
            "{:>10} [trace]: {ok}/{} points ok, {warns} capacity warnings",
            algo.name(),
            certs.len() - before
        );
    }
    if let Some(path) = args.raw("json") {
        let body: Vec<String> = certs.iter().map(ScheduleCert::to_json).collect();
        std::fs::write(path, format!("[\n{}\n]\n", body.join(",\n")))?;
        eprintln!("# wrote {path} ({} certificates)", certs.len());
    }
    println!("verify: {} certificates, {bad} with violations", certs.len());
    if bad > 0 {
        return Err(Error::Protocol(format!(
            "{bad} schedule verification points failed"
        )));
    }
    Ok(())
}

/// Print a failed certificate's violations to stderr.
fn report_cert(cert: &dpdr::schedule::verify::ScheduleCert, bad: &mut usize) {
    if cert.ok() {
        return;
    }
    *bad += 1;
    for v in &cert.violations {
        eprintln!(
            "FAIL {} [{}] p={} m={} b={}: {v}",
            cert.algo, cert.mode, cert.p, cert.m, cert.blocks
        );
    }
}

fn cmd_validate(args: &Args) -> Result<()> {
    let pmax = args.get("pmax", 16usize)?;
    let algos = [
        AlgoKind::Dpdr,
        AlgoKind::DpdrSingle,
        AlgoKind::PipeTree,
        AlgoKind::ReduceBcast,
        AlgoKind::NativeSwitch,
        AlgoKind::TwoTree,
        AlgoKind::Ring,
        AlgoKind::RecursiveDoubling,
        AlgoKind::Rabenseifner,
        AlgoKind::Hier,
        AlgoKind::Scan,
        AlgoKind::NonPipelined,
        AlgoKind::Auto,
    ];
    let mut checked = 0usize;
    for algo in algos {
        for p in 1..=pmax {
            for m in [0usize, 1, 7, 64, 1000] {
                let spec = RunSpec::new(p, m).block_elems(16);
                let report = dpdr::collectives::run_allreduce_i32(algo, &spec, Timing::Real)?;
                // one O(p·m) pass: rank prefixes for scan, the shared
                // sum for everything else
                let oracles = spec.expected_i32_per_rank(algo);
                for (rank, buf) in report.results.into_iter().enumerate() {
                    if buf.into_vec()? != oracles[rank] {
                        return Err(Error::Protocol(format!(
                            "{} p={p} m={m} rank={rank}: wrong result",
                            algo.name()
                        )));
                    }
                }
                checked += 1;
            }
        }
        println!("{:>10}: ok", algo.name());
    }
    println!("validate: {checked} configurations OK");
    Ok(())
}

/// `dpdr tune`: sweep the autotuning grid (`tuner::grid_p()` ×
/// `tuner::GRID_M_BYTES`) through the virtual-clock harness under the
/// Hydra model and print the winners. `--check` re-derives the table
/// and exits nonzero if the committed `TUNE_table.json` makes different
/// decisions (the CI drift gate); `--write` rewrites the file in place.
fn cmd_tune(args: &Args) -> Result<()> {
    use dpdr::model::tuner;
    let fresh = tuner::generate()?;
    let mut hist: Vec<(&'static str, usize)> = Vec::new();
    for e in &fresh.entries {
        match hist.iter_mut().find(|(n, _)| *n == e.algo.name()) {
            Some((_, c)) => *c += 1,
            None => hist.push((e.algo.name(), 1)),
        }
    }
    let summary: Vec<String> = hist.iter().map(|(n, c)| format!("{n}={c}")).collect();
    println!(
        "tune: {} grid points (version {}), winners: {}",
        fresh.entries.len(),
        fresh.version,
        summary.join(" ")
    );
    if args.switch("check") {
        let committed = tuner::embedded()?;
        if fresh.same_decisions(&committed) {
            println!("tune --check: committed TUNE_table.json matches the fresh sweep");
            return Ok(());
        }
        let mut drifted = 0usize;
        let n = fresh.entries.len().max(committed.entries.len());
        for i in 0..n {
            match (fresh.entries.get(i), committed.entries.get(i)) {
                (Some(f), Some(c)) if f.p == c.p && f.m_bytes == c.m_bytes && f.algo == c.algo => {}
                (f, c) => {
                    drifted += 1;
                    eprintln!("drift at entry {i}: fresh={f:?} committed={c:?}");
                }
            }
        }
        if drifted == 0 {
            // decisions agree entry-by-entry, so the header must differ
            eprintln!(
                "drift in header: fresh version={} alpha={:e} beta={:e} gamma={:e}, \
                 committed version={} alpha={:e} beta={:e} gamma={:e}",
                fresh.version,
                fresh.alpha,
                fresh.beta,
                fresh.gamma,
                committed.version,
                committed.alpha,
                committed.beta,
                committed.gamma
            );
        }
        return Err(Error::Protocol(
            "committed TUNE_table.json drifted from the fresh sweep — \
             run `dpdr tune --write` and commit the result"
                .into(),
        ));
    }
    if args.switch("write") {
        std::fs::write("TUNE_table.json", fresh.to_json())?;
        eprintln!("# wrote TUNE_table.json");
    }
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let iters = args.get("iters", 2_000usize)?;
    // ping-pong two real threads with small and large payloads; fit
    // t = α + β·bytes from the two points.
    let small = 64usize; // bytes
    let large = 1 << 20;
    let t_small = ping_pong_us(small / 4, iters)?;
    let t_large = ping_pong_us(large / 4, iters.min(200))?;
    let beta = (t_large - t_small) * 1e-6 / (large - small) as f64;
    let alpha = t_small * 1e-6 - beta * small as f64;
    println!("thread transport: one-way small={t_small:.3} us, large={t_large:.3} us");
    println!("fitted alpha={:.3e} s  beta={:.3e} s/B", alpha.max(0.0), beta);
    println!("(pass as --alpha/--beta to model an in-process 'cluster')");
    Ok(())
}

fn ping_pong_us(elems: usize, iters: usize) -> Result<f64> {
    use dpdr::buffer::DataBuf;
    use dpdr::comm::{run_world, Comm};
    let report = run_world::<i32, _, _>(2, Timing::Real, move |comm| {
        let peer = 1 - comm.rank();
        let payload = DataBuf::real(vec![0i32; elems]);
        comm.barrier()?;
        let start = std::time::Instant::now();
        for _ in 0..iters {
            let _ = comm.sendrecv(peer, payload.clone())?;
        }
        Ok(start.elapsed().as_secs_f64() * 1e6 / iters as f64)
    })?;
    Ok(report.results.iter().copied().fold(0.0, f64::max))
}

fn cmd_sysinfo() -> Result<()> {
    println!("dpdr {} — Träff 2021 reproduction", env!("CARGO_PKG_VERSION"));
    println!("simulated system (defaults): 36 nodes x 8 ranks = 288 ranks ('Hydra')");
    let model = CostModel::hydra_uniform();
    if let Some(l) = model.as_uniform() {
        println!("uniform link: alpha={:.2e} s, beta={:.2e} s/B", l.alpha, l.beta);
    }
    println!("paper h for p=288: {}", paper_h(288));
    println!(
        "threads available: {}",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
    match dpdr::runtime::ReduceEngine::with_default_dir() {
        Ok(engine) => {
            println!("PJRT: cpu client OK; artifacts dir: {}", engine.dir().display());
            let stem = dpdr::runtime::artifact_name(2, dpdr::ops::OpKind::Sum, "int32", 16_384);
            println!(
                "artifact {stem}: {}",
                if engine.has_artifact(&stem) {
                    "present"
                } else {
                    "MISSING (run `make artifacts`)"
                }
            );
        }
        Err(e) => println!("PJRT: unavailable ({e})"),
    }
    Ok(())
}
