//! A small command-line argument parser (the offline registry has no
//! `clap`): positional subcommand + `--key value` flags + `--switch`
//! booleans, with typed getters.

use crate::error::{Error, Result};
use std::collections::{HashMap, HashSet};
use std::str::FromStr;

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct Args {
    /// Positional arguments (the first is usually a subcommand).
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
    switches: HashSet<String>,
}

impl Args {
    /// Parse `argv[1..]`. `bool_flags` names the value-less switches;
    /// everything else starting with `--` consumes the next token.
    pub fn parse(argv: &[String], bool_flags: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                // --key=value form
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                    continue;
                }
                if bool_flags.contains(&name) {
                    out.switches.insert(name.to_string());
                } else {
                    let v = it.next().ok_or_else(|| {
                        Error::Cli(format!("flag --{name} expects a value"))
                    })?;
                    out.flags.insert(name.to_string(), v.clone());
                }
            } else {
                out.positional.push(tok.clone());
            }
        }
        Ok(out)
    }

    /// The subcommand (first positional), if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    /// True if the boolean switch was given.
    pub fn switch(&self, name: &str) -> bool {
        self.switches.contains(name)
    }

    /// Raw flag value.
    pub fn raw(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// Typed flag with a default.
    pub fn get<T: FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Cli(format!("flag --{name}: cannot parse '{v}'"))),
        }
    }

    /// Flag parsed with a custom parser (for non-`FromStr` values such as
    /// `--mapping block:8`); the default is used when the flag is absent.
    pub fn get_parsed<T>(
        &self,
        name: &str,
        default: T,
        parse: impl FnOnce(&str) -> Option<T>,
    ) -> Result<T> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => {
                parse(v).ok_or_else(|| Error::Cli(format!("flag --{name}: cannot parse '{v}'")))
            }
        }
    }

    /// Comma-separated list of `usize`s (`--caps 1,2,3`); the default is
    /// used when the flag is absent. Empty items are rejected.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.flags.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|item| {
                    item.trim().parse().map_err(|_| {
                        Error::Cli(format!("flag --{name}: cannot parse '{item}' in '{v}'"))
                    })
                })
                .collect(),
        }
    }

    /// Typed mandatory flag.
    pub fn require<T: FromStr>(&self, name: &str) -> Result<T> {
        let v = self
            .flags
            .get(name)
            .ok_or_else(|| Error::Cli(format!("missing required flag --{name}")))?;
        v.parse()
            .map_err(|_| Error::Cli(format!("flag --{name}: cannot parse '{v}'")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parse_mixed() {
        let a = Args::parse(&argv("run --p 36 --phantom --m=100 extra"), &["phantom"]).unwrap();
        assert_eq!(a.subcommand(), Some("run"));
        assert_eq!(a.get::<usize>("p", 0).unwrap(), 36);
        assert_eq!(a.get::<usize>("m", 0).unwrap(), 100);
        assert!(a.switch("phantom"));
        assert_eq!(a.positional, vec!["run", "extra"]);
    }

    #[test]
    fn defaults_and_require() {
        let a = Args::parse(&argv("bench"), &[]).unwrap();
        assert_eq!(a.get::<usize>("p", 288).unwrap(), 288);
        assert!(a.require::<usize>("p").is_err());
    }

    #[test]
    fn get_parsed_custom_values() {
        let a = Args::parse(&argv("run --mapping block:8"), &[]).unwrap();
        let parsed = a.get_parsed("mapping", 0usize, |s| {
            s.strip_prefix("block:").and_then(|n| n.parse().ok())
        });
        assert_eq!(parsed.unwrap(), 8);
        // default when absent
        assert_eq!(a.get_parsed("other", 3usize, |_| None).unwrap(), 3);
        // parse failure is a CLI error
        assert!(a.get_parsed("mapping", 0usize, |_| Option::<usize>::None).is_err());
    }

    #[test]
    fn usize_lists() {
        let a = Args::parse(&argv("verify --caps 1,2, 3"), &[]).unwrap();
        // note: "1,2," followed by a separate token is two flags' worth of
        // trouble — keep to one token
        let a2 = Args::parse(&argv("verify --caps 1,2,3"), &[]).unwrap();
        assert_eq!(a2.get_usize_list("caps", &[9]).unwrap(), vec![1, 2, 3]);
        assert_eq!(a2.get_usize_list("other", &[4, 5]).unwrap(), vec![4, 5]);
        assert!(a.get_usize_list("caps", &[]).is_err()); // trailing comma
    }

    #[test]
    fn errors() {
        assert!(Args::parse(&argv("x --p"), &[]).is_err());
        let a = Args::parse(&argv("x --p abc"), &[]).unwrap();
        assert!(a.get::<usize>("p", 1).is_err());
    }
}
