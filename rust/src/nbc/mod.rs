//! Nonblocking collective engine: tag-multiplexed concurrent allreduce
//! with small-message fusion.
//!
//! The paper's performance story is the α-vs-β trade: pipelining amortizes
//! start-up latency only when `m` is large (§1.2, Pipelining Lemma). Real
//! serving traffic issues many *small* concurrent reductions — gradient
//! buckets, per-request aggregates — where α dominates and a blocking,
//! one-at-a-time allreduce leaves the machine idle between latency chains.
//! This module adds the two missing levers:
//!
//! * **Overlap.** [`Engine::iallreduce`] returns a [`Request`] immediately;
//!   the operation runs on its own worker thread over a
//!   [`fork_tagged`](crate::comm::ThreadComm::fork_tagged) endpoint, so any
//!   number of independent collectives can be in flight on one world.
//!   Every in-flight operation leases a *disjoint tag* — its own channels,
//!   receive claims, and virtual injection queues — while sharing the
//!   world's congestion fabric: under a
//!   [`CostModel::Congested`](crate::model::CostModel) model, overlapped
//!   operations contend for the *same* per-node NIC ports, which is
//!   exactly the contention an overlap measurement is about.
//! * **Fusion.** Small operations (`m ≤ fuse_threshold`) submitted with
//!   [`AlgoKind::Dpdr`] are queued instead of launched; at a flush point
//!   the queue is coalesced into one concatenated vector, reduced by a
//!   *single* pipelined dpdr at the Pipelining-Lemma optimal block count
//!   for the fused length, and scattered back to the per-op requests. The
//!   α-chain is paid once per batch instead of once per op (see
//!   [`predicted_time_us_fused`](crate::model::predicted_time_us_fused)).
//!
//! ## Tag lifecycle: lease → epoch → quiesce → recycle
//!
//! * Each operation leases one tag from the engine's [`TagPool`]
//!   (recycled tags first, then the fresh counter starting at
//!   [`NbcConfig::tag_base`], default 1; tag 0 is the blocking world's).
//!   Within an *epoch* — the span between two quiesce points — a tag is
//!   never reused: its receive channels are claimed by the operation's
//!   endpoints, and a second claim is a typed protocol error.
//! * Tag allocation is **deterministic and local**: ranks agree on an
//!   operation's tag because they run the same (SPMD) program and submit
//!   in the same order — no communication, exactly like `MPI_Comm_split`
//!   agreement — and the free pool is popped LIFO, so recycled leases
//!   agree the same way. Two engines coexisting on one world must be
//!   given disjoint `tag_base` ranges.
//! * **Quiesce** ([`Engine::quiesce`], run automatically by
//!   [`Engine::wait_all`] once [`NbcConfig::epoch_ops`] operations have
//!   leased tags) closes the epoch: after draining every worker it runs
//!   a world barrier — so *all* ranks have joined *all* epoch workers
//!   before *any* rank recycles — then drops the epoch's channel and
//!   barrier entries from the registry and returns the tags to the free
//!   pool. Memory is therefore bounded by the epoch size, not the
//!   world's total op count: a serving loop can submit forever (the
//!   `soak` CLI subcommand drives millions of ops through one world
//!   this way). With `epoch_ops = 0` (the default) reclamation is off
//!   and the pre-epoch behavior — entries live for the world's lifetime
//!   — is preserved exactly.
//!
//! ## Serving mode: deadlines, admission control, typed failure
//!
//! Under always-on traffic an operation must never hang or panic; it
//! completes, or it fails *typed* and the caller degrades gracefully:
//!
//! * [`NbcConfig::max_in_flight`] caps unwaited submissions;
//!   [`Engine::iallreduce`] past the budget rejects with
//!   [`Error::Overloaded`] *before* mutating any engine state, so the
//!   rejection is SPMD-deterministic — every rank rejects the same op.
//! * [`Engine::iallreduce_deadline`] (or [`NbcConfig::deadline_us`])
//!   attaches a completion deadline; [`Engine::wait_timed`] returns the
//!   op's duration and [`Engine::wait`] surfaces [`Error::Deadline`] for
//!   an op that finished too late. The deadline is enforced at wait
//!   time — the collective itself always runs to completion, so peers
//!   never see a mid-protocol abort.
//! * Transport faults (a stalled peer, exhausted retransmits — see
//!   [`FaultPlan`](crate::comm::FaultPlan)) poison the world and surface
//!   as [`Error::PeerStalled`] / [`Error::RetriesExhausted`] through
//!   `wait`, bounded by the receive watchdog. Zero hangs by
//!   construction: every blocking wait in the transport polls the
//!   poison flag and a wall-clock deadline.
//!
//! ## Flush policy (what makes fusion SPMD-safe)
//!
//! Fused batches must be identical on every rank, so batches close only
//! at points every rank reaches *structurally* the same way: (1) a
//! submission that fills the queue to `fuse_max_ops`, (2) an explicit
//! [`Engine::flush`], (3) [`Engine::wait_all`] (including the engine's
//! join-on-drop). [`Engine::test`] deliberately does *not* flush —
//! polling frequency may legitimately differ across ranks — and a plain
//! [`Engine::wait`] on a still-queued request is a contract **error**
//! rather than a flush point: because wait order is free, a
//! wait-triggered flush could close different batches on different
//! ranks once submissions interleave with waits.
//!
//! ## Progress and completion
//!
//! Operations progress on their worker threads without any call into the
//! engine ("hardware progress", not test-driven). `wait` joins the worker,
//! folds its traffic counters into the rank's [`RankMetrics`], and — under
//! virtual timing — advances the rank's clock to the operation's
//! completion time (MPI wait semantics). Submission order across ranks
//! must agree, but **wait order is free**: joining is local.
//!
//! ## Engines: thread-per-op vs the event-driven progress core
//!
//! [`NbcConfig::engine`] selects how submitted operations execute:
//!
//! * [`EngineKind::Threaded`] (the default, and the semantic oracle) —
//!   each operation runs the blocking collective on its own worker
//!   thread, as described above.
//! * [`EngineKind::Schedule`] — statically-schedulable algorithms
//!   (`Dpdr`, `DpdrSingle`, `Ring`, `RecursiveDoubling`) are *compiled*
//!   to per-rank step programs ([`crate::schedule`]) and deposited into
//!   the world's shared progress core
//!   ([`crate::schedule::exec`]): no thread is spawned, K outstanding
//!   operations cost zero extra threads, and whichever ranks are waiting
//!   multiplex every outstanding op's ready steps. Payloads and virtual
//!   clocks are bitwise-identical to the threaded engine; under a
//!   congestion-aware model the core additionally makes the clocks
//!   run-to-run *deterministic* (committed in virtual-time order behind
//!   an all-ranks-parked seal) where racing worker threads are not.
//!   Algorithms without a compiler (`Hier`, `TwoTree`, …) and fused
//!   batches fall back to a threaded worker transparently. Progress is
//!   driver-based, so [`Engine::test`] reports `true` only once the op
//!   has been driven to completion by some wait on this rank. Deadlines
//!   become *true cancellation*: a virtual-timed op whose clock exceeds
//!   its deadline is abandoned by **all** ranks symmetrically at a step
//!   boundary ([`Error::Deadline`] from the wait, `took_us ==
//!   deadline_us` exactly), and its tag is recycled at the next
//!   symmetric point instead of after a run to completion.

pub mod driver;
pub mod soak;

pub use driver::{run_concurrent_i32, ConcurrentSpec};
pub use soak::{run_soak, SoakReport, SoakSpec};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;

use crate::buffer::DataBuf;
use crate::collectives::allreduce_on;
use crate::comm::{Comm, RankMetrics, ThreadComm, Timing};
use crate::error::{Error, Result};
use crate::model::{AlgoKind, LinkCost};
use crate::obs;
use crate::ops::{Elem, ReduceBackend, ReduceOp};
use crate::pipeline::Blocks;
use crate::schedule::exec::{Core, Outcome};
use crate::topo::Mapping;

/// How submitted operations execute (see the module docs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EngineKind {
    /// One worker thread per operation running the blocking collective
    /// (the original engine, and the semantic oracle).
    #[default]
    Threaded,
    /// Compile to per-rank step schedules executed by the world's shared
    /// event-driven progress core — no thread per op, deterministic
    /// virtual-time ordering, true deadline cancellation. Uncompilable
    /// algorithms and fused batches fall back to threaded workers.
    Schedule,
}

impl EngineKind {
    /// CLI-stable name.
    pub fn name(self) -> &'static str {
        match self {
            EngineKind::Threaded => "threaded",
            EngineKind::Schedule => "schedule",
        }
    }
}

impl std::str::FromStr for EngineKind {
    type Err = Error;

    fn from_str(s: &str) -> Result<EngineKind> {
        match s {
            "threaded" => Ok(EngineKind::Threaded),
            "schedule" => Ok(EngineKind::Schedule),
            other => Err(Error::Cli(format!(
                "unknown engine '{other}' (expected threaded|schedule)"
            ))),
        }
    }
}

/// Live nbc worker threads across all engines in the process, and the
/// high-water mark since the last [`reset_worker_peak`]. The schedule
/// engine's headline resource claim — K outstanding ops without K
/// threads — is asserted against this gauge.
static WORKERS_LIVE: AtomicU64 = AtomicU64::new(0);
static WORKERS_PEAK: AtomicU64 = AtomicU64::new(0);

/// Peak number of nbc worker threads alive at once since the last
/// [`reset_worker_peak`] (process-wide).
pub fn worker_peak() -> u64 {
    WORKERS_PEAK.load(Ordering::Relaxed)
}

/// Restart the [`worker_peak`] high-water mark at the current live count.
pub fn reset_worker_peak() {
    WORKERS_PEAK.store(WORKERS_LIVE.load(Ordering::Relaxed), Ordering::Relaxed);
}

/// When to coalesce queued small operations into one fused vector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FusePolicy {
    /// Operations of at most this many elements are queued for fusion
    /// (`0` disables fusion entirely — every op launches immediately).
    pub threshold_elems: usize,
    /// Close the batch when this many operations are queued (≥ 1).
    pub max_ops: usize,
}

impl FusePolicy {
    /// Fusion off: every operation launches on submission.
    pub fn off() -> FusePolicy {
        FusePolicy {
            threshold_elems: 0,
            max_ops: usize::MAX,
        }
    }

    /// Fuse operations of ≤ `threshold_elems` elements, closing batches
    /// at `max_ops` queued operations.
    pub fn new(threshold_elems: usize, max_ops: usize) -> FusePolicy {
        FusePolicy {
            threshold_elems,
            max_ops: max_ops.max(1),
        }
    }

    fn enabled(&self) -> bool {
        self.threshold_elems > 0
    }
}

/// Engine configuration.
#[derive(Clone, Copy, Debug)]
pub struct NbcConfig {
    /// First tag of this engine's lease range (tags `tag_base..` are
    /// handed to operations in submission order). Two engines on one
    /// world need disjoint ranges; tag 0 is reserved for blocking
    /// traffic.
    pub tag_base: u32,
    /// Small-message fusion policy.
    pub fuse: FusePolicy,
    /// Node layout handed to [`AlgoKind::Hier`] dispatch (other
    /// algorithms ignore it).
    pub mapping: Mapping,
    /// Reduce backend the worker threads dispatch block reductions
    /// through (worker threads do not inherit the submitting thread's
    /// scoped backend, so it is part of the config).
    pub backend: ReduceBackend,
    /// Close an epoch (quiesce + tag reclamation) once this many
    /// operations have leased tags, at the next [`Engine::wait_all`].
    /// `0` (the default) disables reclamation — entries then live for
    /// the world's lifetime, the pre-epoch behavior.
    pub epoch_ops: usize,
    /// Admission-control budget: submissions past this many unwaited
    /// operations are rejected with [`Error::Overloaded`]. `0` (the
    /// default) is unlimited.
    pub max_in_flight: usize,
    /// Default completion deadline in µs (virtual under virtual timing,
    /// wall-clock under real) attached to every submission; `None` (the
    /// default) means no deadline. Per-op override:
    /// [`Engine::iallreduce_deadline`]. Under [`EngineKind::Schedule`]
    /// with virtual timing the deadline additionally *cancels* the op
    /// mid-flight (see the module docs).
    pub deadline_us: Option<f64>,
    /// Execution engine (see the module docs): thread-per-op workers
    /// (the default) or the compiled-schedule progress core.
    pub engine: EngineKind,
    /// Statically verify every compiled schedule world before its first
    /// deposit ([`crate::schedule::verify`]): matching, capacity-1
    /// deadlock-freedom, lease safety, and reduction shape. Verified
    /// `(algo, p, blocks)` points are cached process-wide, so the cost
    /// is one pass per distinct shape. A violation fails the submission
    /// with [`Error::Protocol`] instead of depositing a broken program.
    pub verify_schedules: bool,
}

impl Default for NbcConfig {
    fn default() -> NbcConfig {
        NbcConfig {
            tag_base: 1,
            fuse: FusePolicy::off(),
            mapping: Mapping::Block { ranks_per_node: 8 },
            backend: ReduceBackend::Auto,
            epoch_ops: 0,
            max_in_flight: 0,
            deadline_us: None,
            engine: EngineKind::default(),
            verify_schedules: false,
        }
    }
}

/// Recover a result-cell lock even if the worker holding it panicked:
/// the single `Option` assignment under the guard is atomic enough that
/// the surviving value is always consistent.
fn relock<T>(r: Result<MutexGuard<'_, T>, PoisonError<MutexGuard<'_, T>>>) -> MutexGuard<'_, T> {
    r.unwrap_or_else(|p| p.into_inner())
}

/// The engine's SPMD-deterministic tag allocator: recycled tags first
/// (popped LIFO, so every rank draws the same sequence), then a fresh
/// counter. Exhaustion is a typed error, not a panic.
struct TagPool {
    next: u32,
    free: Vec<u32>,
}

impl TagPool {
    fn new(base: u32) -> TagPool {
        TagPool {
            next: base,
            free: Vec::new(),
        }
    }

    fn lease(&mut self) -> Result<u32> {
        if let Some(t) = self.free.pop() {
            return Ok(t);
        }
        let t = self.next;
        self.next = self.next.checked_add(1).ok_or(Error::TagsExhausted)?;
        Ok(t)
    }

    /// Return an epoch's tags to the free pool (drains `tags`).
    fn release(&mut self, tags: &mut Vec<u32>) {
        self.free.append(tags);
    }
}

/// One operation's result slot, shared between its worker thread and the
/// request handle: the payload (or typed error) plus how long the
/// operation took in µs (virtual under virtual timing, wall otherwise) —
/// what [`Engine::wait_timed`] checks deadlines against.
struct OpCell<E: Elem> {
    result: Mutex<Option<(Result<DataBuf<E>>, f64)>>,
}

impl<E: Elem> OpCell<E> {
    fn new() -> Arc<OpCell<E>> {
        Arc::new(OpCell {
            result: Mutex::new(None),
        })
    }

    fn put(&self, r: Result<DataBuf<E>>, took_us: f64) {
        *relock(self.result.lock()) = Some((r, took_us));
    }

    fn ready(&self) -> bool {
        relock(self.result.lock()).is_some()
    }

    fn take(&self) -> Option<(Result<DataBuf<E>>, f64)> {
        relock(self.result.lock()).take()
    }
}

/// A handle to one in-flight (or queued) operation. Redeem it with
/// [`Engine::wait`] / [`Engine::wait_timed`]; poll with [`Engine::test`].
/// Dropping an unredeemed request discards the operation's result (the
/// op itself still runs to completion — peers depend on it) and logs a
/// warning, since a lost handle under serving traffic is almost always
/// a leak in the caller's bookkeeping.
#[must_use = "redeem with Engine::wait (dropping discards the op's result)"]
pub struct Request<E: Elem> {
    id: u64,
    cell: Arc<OpCell<E>>,
    deadline_us: Option<f64>,
    redeemed: bool,
}

impl<E: Elem> Request<E> {
    /// The engine-local operation id (diagnostics).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The completion deadline attached at submission, if any.
    pub fn deadline_us(&self) -> Option<f64> {
        self.deadline_us
    }
}

impl<E: Elem> Drop for Request<E> {
    fn drop(&mut self) {
        if !self.redeemed && !std::thread::panicking() {
            eprintln!(
                "nbc: request {} dropped without wait — its result is discarded",
                self.id
            );
        }
    }
}

/// What a worker thread reports back at join time.
type WorkerOut = (RankMetrics, f64);

/// One spawned worker (a solo op or a fused batch) not yet joined. The
/// result cells are owned by the request handles and the worker closure;
/// the flight record only needs to know *which* requests it carries.
struct InFlight {
    ids: Vec<u64>,
    handle: JoinHandle<WorkerOut>,
}

/// A queued-not-yet-launched fusable operation. Keeps the submitted
/// block partition so a batch of one launches with exactly the pipeline
/// depth the caller asked for.
struct Pending<E: Elem> {
    id: u64,
    cell: Arc<OpCell<E>>,
    x: DataBuf<E>,
    blocks: Blocks,
}

/// One operation deposited into the schedule progress core and not yet
/// driven to resolution on this rank (the [`EngineKind::Schedule`]
/// analogue of [`InFlight`]).
struct SchedFlight<E: Elem> {
    tag: u32,
    /// The carried requests: `(op id, result cell, lo, hi)` — each
    /// request's slice of the program's final vector (`0..len` for a
    /// solo op).
    cells: Vec<(u64, Arc<OpCell<E>>, usize, usize)>,
    /// Deadline deposited for true cancellation (virtual timing only).
    deadline_us: Option<f64>,
    /// This rank's virtual clock at deposit.
    v0: f64,
    wall0: std::time::Instant,
}

/// The per-rank nonblocking collective engine. See the module docs for
/// the leasing and flush rules; see [`driver`] for a ready-made
/// concurrent-traffic driver.
pub struct Engine<'c, E: Elem, O: ReduceOp<E> + Clone + 'static> {
    comm: &'c mut ThreadComm<E>,
    op: O,
    cfg: NbcConfig,
    tags: TagPool,
    /// Tags leased in the current epoch, reclaimed at the next quiesce.
    epoch_tags: Vec<u32>,
    next_id: u64,
    in_flight: Vec<InFlight>,
    /// Operations living in the schedule progress core, oldest first.
    sched: Vec<SchedFlight<E>>,
    /// Tags of deadline-cancelled schedule ops, returned to the pool at
    /// the next SPMD-symmetric point (cancellation is op-global, so
    /// every rank collects the identical set).
    cancelled_tags: Vec<u32>,
    pending: Vec<Pending<E>>,
    /// Operations submitted and not yet delivered to a `wait`.
    outstanding: u64,
    outstanding_max: u64,
    /// Operations admitted since the last `wait_all`/`quiesce` — the
    /// counter [`NbcConfig::max_in_flight`] is checked against. Reset
    /// only at SPMD-symmetric points (never by rank-local `wait`s), so
    /// every rank accepts and rejects the identical op sequence.
    admitted: usize,
}

impl<'c, E: Elem, O: ReduceOp<E> + Clone + 'static> Engine<'c, E, O> {
    /// An engine over `comm` reducing with `op` under `cfg`.
    pub fn new(comm: &'c mut ThreadComm<E>, op: O, cfg: NbcConfig) -> Engine<'c, E, O> {
        let tag_base = cfg.tag_base.max(1); // tag 0 belongs to blocking traffic
        Engine {
            comm,
            op,
            cfg,
            tags: TagPool::new(tag_base),
            epoch_tags: Vec::new(),
            next_id: 0,
            in_flight: Vec::new(),
            sched: Vec::new(),
            cancelled_tags: Vec::new(),
            pending: Vec::new(),
            outstanding: 0,
            outstanding_max: 0,
            admitted: 0,
        }
    }

    /// The number of operations submitted and not yet waited on.
    pub fn outstanding(&self) -> u64 {
        self.outstanding
    }

    /// Live sparse (tagged) channel entries in the world registry —
    /// serving loops watch this stay flat across epochs.
    pub fn tagged_entries(&self) -> usize {
        self.comm.tagged_entries()
    }

    /// This rank's id (convenience passthrough while the engine holds the
    /// endpoint borrow).
    pub fn rank(&self) -> usize {
        self.comm.rank()
    }

    /// Lease the next tag (recycled first, then fresh; unique within the
    /// epoch) and record it for reclamation at the next quiesce.
    fn lease_tag(&mut self) -> Result<u32> {
        let t = self.tags.lease()?;
        self.epoch_tags.push(t);
        Ok(t)
    }

    fn note_submitted(&mut self) {
        self.outstanding += 1;
        self.outstanding_max = self.outstanding_max.max(self.outstanding);
        let m = self.comm.metrics_mut();
        m.ops_in_flight_max = m.ops_in_flight_max.max(self.outstanding_max);
    }

    /// Record one op-lifecycle instant (`seq` = op id) at this rank's
    /// current virtual clock. No-op unless tracing is enabled.
    fn obs_lifecycle(&self, kind: obs::EventKind, tag: u32, id: u64, bytes: u64) {
        if !obs::enabled() {
            return;
        }
        let ev = obs::Event::new(kind, self.comm.rank())
            .tag(tag)
            .seq(id)
            .bytes(bytes)
            .at_s(self.comm.vtime())
            .wall(obs::wall_now_ns());
        obs::record(ev);
    }

    /// Submit a nonblocking allreduce of `x` under `algo` (any flat
    /// [`AlgoKind`], or [`AlgoKind::Hier`] over the config's mapping;
    /// [`AlgoKind::Scan`] runs the prefix scan). Returns immediately.
    ///
    /// Small [`AlgoKind::Dpdr`] operations (`x.len() ≤
    /// fuse.threshold_elems`) are queued for fusion instead of launched —
    /// see the module docs for when queued batches close.
    pub fn iallreduce(
        &mut self,
        algo: AlgoKind,
        x: DataBuf<E>,
        blocks: &Blocks,
    ) -> Result<Request<E>> {
        let deadline = self.cfg.deadline_us;
        self.submit(algo, x, blocks, deadline)
    }

    /// [`Engine::iallreduce`] with an explicit per-op completion deadline
    /// in µs (overriding [`NbcConfig::deadline_us`]; `None` removes it).
    /// The collective always runs to completion — the deadline is
    /// enforced when the request is redeemed: [`Engine::wait`] returns
    /// [`Error::Deadline`] for a result that arrived too late, and
    /// [`Engine::wait_timed`] hands back the duration for callers that
    /// want the late payload anyway.
    pub fn iallreduce_deadline(
        &mut self,
        algo: AlgoKind,
        x: DataBuf<E>,
        blocks: &Blocks,
        deadline_us: Option<f64>,
    ) -> Result<Request<E>> {
        self.submit(algo, x, blocks, deadline_us)
    }

    fn submit(
        &mut self,
        algo: AlgoKind,
        x: DataBuf<E>,
        blocks: &Blocks,
        deadline_us: Option<f64>,
    ) -> Result<Request<E>> {
        // admission control first, before any state mutation: every rank
        // sees the same submission sequence, so every rank rejects the
        // same op and the SPMD tag agreement is untouched
        if self.cfg.max_in_flight > 0 && self.admitted >= self.cfg.max_in_flight {
            return Err(Error::Overloaded {
                in_flight: self.admitted,
                budget: self.cfg.max_in_flight,
            });
        }
        let fusable = self.cfg.fuse.enabled()
            && algo == AlgoKind::Dpdr
            && x.len() <= self.cfg.fuse.threshold_elems;
        // reject a real/phantom mode switch against the open batch up
        // front: concatenation cannot mix modes, and discovering that at
        // flush time would leave an unfixable batch in the queue
        let mode_conflict = self
            .pending
            .first()
            .is_some_and(|first| first.x.is_phantom() != x.is_phantom());
        if fusable && mode_conflict {
            return Err(Error::Config(
                "fusion cannot mix real and phantom inputs in one batch — flush() \
                 before switching payload modes"
                    .into(),
            ));
        }
        let id = self.next_id;
        self.next_id += 1;
        self.admitted += 1;
        let cell = OpCell::new();
        self.note_submitted();
        self.obs_lifecycle(obs::EventKind::OpSubmit, 0, id, (x.len() * E::BYTES) as u64);
        if fusable {
            self.pending.push(Pending {
                id,
                cell: Arc::clone(&cell),
                x,
                blocks: *blocks,
            });
            self.obs_lifecycle(obs::EventKind::OpQueue, 0, id, 0);
            if self.pending.len() >= self.cfg.fuse.max_ops {
                self.flush()?;
            }
        } else {
            self.spawn_solo(algo, x, *blocks, id, Arc::clone(&cell), deadline_us)?;
        }
        // the handle is built only once the op is queued or launched, so
        // a failed submission returns just the typed error — no orphan
        // request to drop-warn about
        Ok(Request {
            id,
            cell,
            deadline_us,
            redeemed: false,
        })
    }

    /// The world's shared progress core for this `(element, operator)`
    /// pair, anchored (created once, then shared) in the channel
    /// registry so every rank's engine drives the same instance.
    fn core(&self) -> Arc<Core<E, O>> {
        let size = self.comm.size();
        self.comm.registry().anchored(|| Core::new(size))
    }

    /// Launch one operation: deposit its compiled schedule into the
    /// progress core ([`EngineKind::Schedule`], when the algorithm
    /// compiles), or spawn a tagged worker thread running the blocking
    /// collective (the fallback, and [`EngineKind::Threaded`] always).
    fn spawn_solo(
        &mut self,
        algo: AlgoKind,
        x: DataBuf<E>,
        blocks: Blocks,
        id: u64,
        cell: Arc<OpCell<E>>,
        deadline_us: Option<f64>,
    ) -> Result<()> {
        if self.cfg.engine == EngineKind::Schedule && x.len() == blocks.total() {
            let (rank, size) = (self.comm.rank(), self.comm.size());
            if let Some(sched) = crate::schedule::compile(algo, rank, size, &blocks) {
                if self.cfg.verify_schedules {
                    // Same verdict on every rank (pure function of the
                    // schedules), so failing here is SPMD-symmetric.
                    crate::schedule::verify::verify_world_cached(algo, size, &blocks)?;
                }
                let tag = self.lease_tag()?;
                let v0 = self.comm.vtime();
                // true cancellation is a virtual-clock construct; under
                // real timing the threaded post-hoc semantics remain
                let deadline = match self.comm.timing() {
                    Timing::Virtual(..) => deadline_us,
                    Timing::Real => None,
                };
                self.core().deposit(
                    tag,
                    rank,
                    size,
                    sched,
                    x,
                    self.op.clone(),
                    self.cfg.backend,
                    self.comm.timing(),
                    self.comm.fault_plan(),
                    v0,
                    deadline,
                );
                self.sched.push(SchedFlight {
                    tag,
                    cells: vec![(id, cell, 0, blocks.total())],
                    deadline_us: deadline,
                    v0,
                    wall0: std::time::Instant::now(),
                });
                self.obs_lifecycle(obs::EventKind::OpLaunch, tag, id, 0);
                return Ok(());
            }
        }
        let tag = self.lease_tag()?;
        self.obs_lifecycle(obs::EventKind::OpLaunch, tag, id, 0);
        let child = self.comm.fork_tagged(tag);
        let op = self.op.clone();
        let mapping = self.cfg.mapping;
        let backend = self.cfg.backend;
        let handle = spawn_worker(child, tag, backend, move |comm| {
            let wall0 = std::time::Instant::now();
            let v0 = comm.vtime();
            let out = allreduce_on(algo, comm, x, &op, &blocks, mapping);
            let took = op_duration_us(comm, wall0, v0);
            obs_op_wait(comm.rank(), tag, id, v0, took);
            let ok = out.is_ok();
            cell.put(out, took);
            ok
        })?;
        self.in_flight.push(InFlight {
            ids: vec![id],
            handle,
        });
        Ok(())
    }

    /// Close the current fused batch: concatenate the queued inputs, run
    /// one allreduce for the fused length on a single leased tag, and
    /// scatter the result back to the per-op requests. The algorithm is
    /// chosen by the autotuned oracle over the *order-preserving*
    /// candidates ([`tuner::auto_pick_ordered`](crate::model::tuner) —
    /// fused float batches must not be reassociated across ranks), at the
    /// lemma-optimal block count when the pick is pipelined. A no-op on
    /// an empty queue; a queue of one simply launches that operation solo
    /// (nothing to fuse).
    pub fn flush(&mut self) -> Result<()> {
        if self.pending.is_empty() {
            return Ok(());
        }
        if self.pending.len() == 1 {
            // nothing to fuse: launch the lone op exactly as submitted
            // (queued ops are exempt from true cancellation — the
            // request's deadline still applies post hoc at wait)
            let p = self.pending.pop().unwrap();
            return self.spawn_solo(AlgoKind::Dpdr, p.x, p.blocks, p.id, p.cell, None);
        }
        let batch: Vec<Pending<E>> = std::mem::take(&mut self.pending);
        let total: usize = batch.iter().map(|p| p.x.len()).sum();
        // per-op offsets within the fused vector, in submission order
        let mut bounds = Vec::with_capacity(batch.len());
        let mut lo = 0usize;
        for p in &batch {
            bounds.push((lo, lo + p.x.len()));
            lo += p.x.len();
        }
        // the batch is mode-uniform: iallreduce rejects a real/phantom
        // switch against an open batch at submission
        let fused: DataBuf<E> = if batch[0].x.is_phantom() {
            DataBuf::phantom(total)
        } else {
            let mut v: Vec<E> = Vec::with_capacity(total);
            for p in &batch {
                // submit() rejects mode switches against an open batch,
                // so this is unreachable short of an engine bug — and an
                // engine bug should fail typed, not panic a worker's rank
                let s = p.x.as_slice().ok_or_else(|| {
                    Error::Protocol("fused batch mixed real and phantom inputs".into())
                })?;
                v.extend_from_slice(s);
            }
            DataBuf::real(v)
        };
        // oracle pick for the *fused* length (order-preserving candidates
        // only), then the Pipelining-Lemma optimal depth under the run's
        // inter-node link when the pick is pipelined
        let model = match self.comm.timing() {
            Timing::Virtual(model, _) => model,
            Timing::Real => crate::model::CostModel::hydra_uniform(),
        };
        let algo =
            crate::model::tuner::auto_pick_ordered(self.comm.size(), total * E::BYTES, &model);
        let blocks = match algo.step_structure(self.comm.size()) {
            Some((a, c)) => Blocks::lemma_optimal(total, E::BYTES, a, c, self.fuse_link()),
            None => Blocks::by_count(total, 1),
        };
        {
            let m = self.comm.metrics_mut();
            m.fused_ops += batch.len() as u64;
            m.fused_elems += total as u64;
            m.auto_picks += 1;
        }
        let tag = self.lease_tag()?;
        let child = self.comm.fork_tagged(tag);
        let op = self.op.clone();
        let mapping = self.cfg.mapping;
        let backend = self.cfg.backend;
        let (ids, worker_cells): (Vec<u64>, Vec<Arc<OpCell<E>>>) =
            batch.into_iter().map(|p| (p.id, p.cell)).unzip();
        let first_id = ids.first().copied().unwrap_or(0);
        if obs::enabled() {
            let ev = obs::Event::new(obs::EventKind::OpFuse, self.comm.rank())
                .tag(tag)
                .seq(first_id)
                .bytes((total * E::BYTES) as u64)
                .aux(ids.len() as u32)
                .at_s(self.comm.vtime())
                .wall(obs::wall_now_ns());
            obs::record(ev);
        }
        self.obs_lifecycle(obs::EventKind::OpLaunch, tag, first_id, 0);
        let handle = spawn_worker(child, tag, backend, move |comm| {
            let wall0 = std::time::Instant::now();
            let v0 = comm.vtime();
            let out = allreduce_on(algo, comm, fused, &op, &blocks, mapping);
            // one batch, one duration: every fused op completes when the
            // shared collective does, so each cell gets the batch's time
            let took = op_duration_us(comm, wall0, v0);
            obs_op_wait(comm.rank(), tag, first_id, v0, took);
            match out {
                Ok(y) => {
                    // scatter: each request gets its slice of the fused
                    // result (zero-copy views of the worker's slab)
                    for (cell, &(lo, hi)) in worker_cells.iter().zip(&bounds) {
                        cell.put(y.extract(lo, hi), took);
                    }
                    true
                }
                Err(e) => {
                    for cell in &worker_cells {
                        cell.put(
                            Err(Error::Protocol(format!("fused allreduce failed: {e}"))),
                            took,
                        );
                    }
                    false
                }
            }
        })?;
        self.in_flight.push(InFlight { ids, handle });
        Ok(())
    }

    /// The link cost the fusion layer optimizes block counts for: the
    /// inter-node level of the run's cost model (the paper's default
    /// "Hydra" link under real timing, where no model exists).
    fn fuse_link(&self) -> LinkCost {
        match self.comm.timing() {
            Timing::Virtual(model, _) => model.link_levels().1,
            // real timing carries no model: use the canonical Hydra
            // calibration rather than a private copy of its constants
            Timing::Real => crate::model::CostModel::hydra_uniform().link_levels().1,
        }
    }

    /// Nonblocking completion probe: true once the operation's result is
    /// delivered to its cell. Deliberately side-effect free — it neither
    /// flushes a queued batch (see the module docs) nor joins the worker,
    /// so virtual clocks never depend on how often a rank polls; the
    /// clock/metrics merge happens at [`Engine::wait`]. A queued request
    /// therefore tests `false` until a flush point launches it.
    pub fn test(&self, req: &Request<E>) -> Result<bool> {
        Ok(req.cell.ready())
    }

    /// Wait for one operation and return its payload: joins exactly the
    /// worker carrying the request (other operations keep flying).
    ///
    /// Waiting on a request that is still *queued for fusion* is a
    /// contract error, not a flush point: a flush here would close the
    /// batch with whatever happens to be queued on *this* rank at *this*
    /// wait — and since wait order is deliberately free, ranks
    /// interleaving submissions with waits could close different batches
    /// and deadlock. Close batches at the SPMD-symmetric points instead:
    /// `fuse_max_ops`, [`Engine::flush`], or [`Engine::wait_all`].
    pub fn wait(&mut self, req: Request<E>) -> Result<DataBuf<E>> {
        let op = req.id;
        let deadline = req.deadline_us;
        let (y, took_us) = self.wait_timed(req)?;
        if let Some(deadline_us) = deadline {
            if took_us > deadline_us {
                return Err(Error::Deadline {
                    op,
                    deadline_us,
                    took_us,
                });
            }
        }
        Ok(y)
    }

    /// [`Engine::wait`] plus the operation's duration in µs (virtual
    /// under virtual timing, wall-clock otherwise). Unlike `wait` it
    /// ignores the request's deadline — callers that want a late payload
    /// anyway redeem through here and judge `took_us` themselves.
    pub fn wait_timed(&mut self, mut req: Request<E>) -> Result<(DataBuf<E>, f64)> {
        req.redeemed = true; // handed to a wait: the drop warning is moot
        if self.pending.iter().any(|p| p.id == req.id) {
            return Err(Error::Config(
                "request is still queued for fusion — close the batch with flush() or \
                 wait_all() first (a wait-triggered flush would depend on rank-local \
                 wait order and break the SPMD batch contract)"
                    .into(),
            ));
        }
        // join the worker carrying the request (blocking if it is still
        // running), so its clock and metrics merge no later than result
        // delivery; already-reaped workers are simply not found
        if let Some(i) = self.in_flight.iter().position(|f| f.ids.contains(&req.id)) {
            self.join_one(i)?;
        }
        // a schedule-core flight instead: drive the core until this
        // rank's program for the op resolves (progressing every other
        // outstanding op along the way)
        if let Some(i) = self
            .sched
            .iter()
            .position(|f| f.cells.iter().any(|c| c.0 == req.id))
        {
            self.drive_sched(i)?;
        }
        self.outstanding = self.outstanding.saturating_sub(1);
        match req.cell.take() {
            Some((Ok(y), took_us)) => Ok((y, took_us)),
            Some((Err(e), _)) => Err(e),
            None => Err(Error::Protocol(
                "wait on an unknown or already-waited request".into(),
            )),
        }
    }

    /// Drive everything to completion: flush the queue and join every
    /// worker. Individual [`Engine::wait`] calls afterwards return
    /// instantly with the delivered payloads. An SPMD-symmetric point:
    /// the admission budget resets here, and once
    /// [`NbcConfig::epoch_ops`] tags have been leased the epoch is
    /// closed by an automatic [`Engine::quiesce`].
    pub fn wait_all(&mut self) -> Result<()> {
        self.flush()?;
        while !self.in_flight.is_empty() {
            self.join_one(self.in_flight.len() - 1)?;
        }
        while !self.sched.is_empty() {
            self.drive_sched(0)?;
        }
        self.recycle_cancelled();
        self.admitted = 0;
        if self.cfg.epoch_ops > 0 && self.epoch_tags.len() >= self.cfg.epoch_ops {
            self.quiesce()?;
        }
        Ok(())
    }

    /// Close the current epoch: drain every worker, then — in lockstep
    /// with all other ranks (a world barrier, so no rank recycles while
    /// any rank's workers still hold epoch channels) — drop the epoch
    /// tags' channel and barrier entries from the registry and return
    /// the tags to the free pool for the next leases. Must be called at
    /// the same structural point on every rank, like `wait_all` (which
    /// calls it automatically under [`NbcConfig::epoch_ops`]). A no-op
    /// beyond draining when the epoch leased nothing; on a poisoned
    /// world the barrier and reclamation are skipped — teardown owns the
    /// entries then, and peers may already be gone.
    pub fn quiesce(&mut self) -> Result<()> {
        self.flush()?;
        while !self.in_flight.is_empty() {
            self.join_one(self.in_flight.len() - 1)?;
        }
        while !self.sched.is_empty() {
            self.drive_sched(0)?;
        }
        self.recycle_cancelled();
        self.admitted = 0;
        if self.epoch_tags.is_empty() || self.comm.world_poisoned() {
            return Ok(());
        }
        self.comm.barrier()?;
        self.comm.reclaim_tags(&self.epoch_tags);
        let n = self.epoch_tags.len() as u64;
        {
            let m = self.comm.metrics_mut();
            m.epochs += 1;
            m.tags_recycled += n;
        }
        self.tags.release(&mut self.epoch_tags);
        Ok(())
    }

    /// Drive the schedule core until flight `i` resolves on this rank,
    /// deliver the payload (or typed error) to its request cells, and
    /// fold the program's metrics and completion clock into the rank
    /// endpoint — the schedule-core analogue of [`Engine::join_one`].
    fn drive_sched(&mut self, i: usize) -> Result<()> {
        let flight = self.sched.remove(i);
        let core = self.core();
        let rank = self.comm.rank();
        let out = core.drive(
            self.comm.registry(),
            rank,
            flight.tag,
            self.comm.watchdog(),
        );
        match out {
            Outcome::Done {
                y,
                metrics,
                vtime,
                wall_us,
            } => {
                self.comm.absorb_child(&metrics, vtime);
                let took_us = match self.comm.timing() {
                    Timing::Virtual(..) => (vtime - flight.v0) * 1e6,
                    Timing::Real => wall_us,
                };
                let first_id = flight.cells.first().map_or(0, |c| c.0);
                obs_op_wait(rank, flight.tag, first_id, flight.v0, took_us);
                if let [(_, cell, _, _)] = flight.cells.as_slice() {
                    cell.put(Ok(y), took_us);
                } else {
                    for (_, cell, lo, hi) in &flight.cells {
                        cell.put(y.extract(*lo, *hi), took_us);
                    }
                }
                Ok(())
            }
            Outcome::Cancelled { vtime } => {
                // symmetric mid-flight abandon: every rank resolves the
                // op to exactly its deadline, contributes no metrics,
                // and earmarks the tag for early recycling
                self.comm.absorb_child(&RankMetrics::default(), vtime);
                let deadline_us = flight.deadline_us.unwrap_or(0.0);
                for (id, cell, _, _) in &flight.cells {
                    cell.put(
                        Err(Error::Deadline {
                            op: *id,
                            deadline_us,
                            took_us: deadline_us,
                        }),
                        deadline_us,
                    );
                }
                self.cancelled_tags.push(flight.tag);
                Ok(())
            }
            Outcome::Failed { err, metrics, vtime } => {
                self.comm.absorb_child(&metrics, vtime);
                let took_us = match self.comm.timing() {
                    Timing::Virtual(..) => (vtime - flight.v0) * 1e6,
                    Timing::Real => flight.wall0.elapsed().as_secs_f64() * 1e6,
                };
                let mut err = Some(err);
                for (_, cell, _, _) in &flight.cells {
                    let e = err
                        .take()
                        .unwrap_or_else(|| Error::Protocol("schedule op failed".into()));
                    cell.put(Err(e), took_us);
                }
                Ok(())
            }
        }
    }

    /// Return deadline-cancelled tags to the pool. Only called at
    /// SPMD-symmetric points (`wait_all`/`quiesce`): cancellation is
    /// op-global, so every rank recycles the identical sorted set and
    /// the LIFO lease agreement holds.
    fn recycle_cancelled(&mut self) {
        if self.cancelled_tags.is_empty() {
            return;
        }
        let mut cancelled = std::mem::take(&mut self.cancelled_tags);
        cancelled.sort_unstable();
        // a cancelled tag must not also ride the epoch reclamation —
        // releasing a lease twice would hand one tag to two future ops
        self.epoch_tags.retain(|t| !cancelled.contains(t));
        self.tags.release(&mut cancelled);
    }

    /// Join in-flight entry `i`, folding its metrics and completion time
    /// into the rank endpoint.
    fn join_one(&mut self, i: usize) -> Result<()> {
        let flight = self.in_flight.swap_remove(i);
        match flight.handle.join() {
            Ok((metrics, vtime)) => {
                self.comm.absorb_child(&metrics, vtime);
                Ok(())
            }
            Err(_) => {
                self.comm.poison_world();
                Err(Error::Protocol("nbc worker thread panicked".into()))
            }
        }
    }
}

impl<E: Elem, O: ReduceOp<E> + Clone + 'static> Drop for Engine<'_, E, O> {
    /// Joining on drop keeps workers from outliving the world teardown;
    /// prefer an explicit [`Engine::wait_all`], which can also report
    /// errors.
    fn drop(&mut self) {
        let _ = self.wait_all();
    }
}

/// How long a worker's operation took in µs, in the units deadlines are
/// stated in: virtual-clock advance under virtual timing, wall time under
/// real (where the clock *is* the wall).
fn op_duration_us<E: Elem>(comm: &ThreadComm<E>, wall0: std::time::Instant, v0: f64) -> f64 {
    match comm.timing() {
        Timing::Virtual(..) => (comm.vtime() - v0) * 1e6,
        Timing::Real => wall0.elapsed().as_secs_f64() * 1e6,
    }
}

/// Record the [`OpWait`](obs::EventKind::OpWait) span of one completed
/// operation over its virtual lifetime `[v0, v0 + took_us]`. Stamped at
/// completion, not at the redeeming `wait` call, so traces are invariant
/// under wait-order permutations.
fn obs_op_wait(rank: usize, tag: u32, id: u64, v0: f64, took_us: f64) {
    if !obs::enabled() {
        return;
    }
    let ev = obs::Event::new(obs::EventKind::OpWait, rank)
        .tag(tag)
        .seq(id)
        .at_s(v0)
        .dur_us(took_us)
        .wall(obs::wall_now_ns());
    obs::record(ev);
}

/// Spawn one worker thread running `body` on the forked endpoint, then
/// harvesting the endpoint's metrics (plus the worker thread's buffer and
/// backend thread-locals) and final virtual clock. Errors inside `body`
/// (signalled by returning `false`) land in the op cells; the worker also
/// poisons the world so peers abort instead of hitting the watchdog.
fn spawn_worker<E: Elem>(
    mut child: ThreadComm<E>,
    tag: u32,
    backend: ReduceBackend,
    body: impl FnOnce(&mut ThreadComm<E>) -> bool + Send + 'static,
) -> Result<JoinHandle<WorkerOut>> {
    let name = format!("nbc-r{}-t{}", child.rank(), tag);
    // gauge the thread cost up front (counting inside the thread would
    // undercount a burst of spawns that have not been scheduled yet)
    let live = WORKERS_LIVE.fetch_add(1, Ordering::Relaxed) + 1;
    WORKERS_PEAK.fetch_max(live, Ordering::Relaxed);
    let spawned = std::thread::Builder::new()
        .name(name)
        .stack_size(1 << 20)
        .spawn(move || {
            let _backend = crate::ops::backend::scope(backend);
            crate::obs::bind_rank(child.rank());
            // fresh thread: reset the thread-local counters so the
            // harvest below covers exactly this operation
            let _ = crate::buffer::pool::take_stats();
            let _ = crate::ops::backend::take_stats();
            if !body(&mut child) {
                child.poison_world();
            }
            let mut metrics = child.metrics().clone();
            metrics.absorb_buffer_stats(&crate::buffer::pool::take_stats());
            metrics.absorb_backend_stats(&crate::ops::backend::take_stats());
            WORKERS_LIVE.fetch_sub(1, Ordering::Relaxed);
            (metrics, child.vtime())
        })
        .map_err(Error::Io);
    if spawned.is_err() {
        WORKERS_LIVE.fetch_sub(1, Ordering::Relaxed);
    }
    spawned
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::RunSpec;
    use crate::comm::{run_world, Comm};
    use crate::ops::SumOp;

    fn blocks_of(m: usize, b: usize) -> Blocks {
        Blocks::by_count(m, b)
    }

    #[test]
    fn single_nonblocking_op_roundtrip() {
        let spec = RunSpec::new(4, 40);
        let expected = spec.expected_sum_i32();
        let report = run_world::<i32, _, _>(4, Timing::Real, move |comm| {
            let x = DataBuf::real(spec.input_i32(comm.rank()));
            let mut eng = Engine::new(comm, SumOp, NbcConfig::default());
            let req = eng.iallreduce(AlgoKind::Dpdr, x, &blocks_of(40, 4))?;
            let y = eng.wait(req)?;
            y.into_vec()
        })
        .unwrap();
        for got in report.results {
            assert_eq!(got, expected);
        }
        let totals = report.total_metrics();
        assert_eq!(totals.ops_in_flight_max, 1);
        assert_eq!(totals.fused_ops, 0);
    }

    #[test]
    fn overlapped_ops_complete_out_of_order() {
        // submit 3 ops, wait newest-first: results must match per-op
        // oracles regardless of wait order
        let specs: Vec<RunSpec> = (0..3u64).map(|i| RunSpec::new(6, 30).seed(77 + i)).collect();
        let expected: Vec<Vec<i32>> = specs.iter().map(|s| s.expected_sum_i32()).collect();
        let specs2 = specs.clone();
        let report = run_world::<i32, _, _>(6, Timing::Real, move |comm| {
            let mut eng = Engine::new(comm, SumOp, NbcConfig::default());
            let mut reqs = Vec::new();
            for s in &specs2 {
                let x = DataBuf::real(s.input_i32(eng.rank()));
                reqs.push(eng.iallreduce(AlgoKind::Dpdr, x, &blocks_of(30, 3))?);
            }
            let mut out = vec![Vec::new(); 3];
            for (i, req) in reqs.into_iter().enumerate().rev() {
                out[i] = eng.wait(req)?.into_vec()?;
            }
            Ok(out)
        })
        .unwrap();
        for per_rank in report.results {
            for (i, got) in per_rank.into_iter().enumerate() {
                assert_eq!(got, expected[i], "op {i}");
            }
        }
        assert_eq!(report.total_metrics().ops_in_flight_max, 3);
    }

    #[test]
    fn fusion_scatters_correct_slices() {
        // 4 small ops fuse into one dpdr; each request gets its own slice
        let lens = [5usize, 9, 1, 7];
        let p = 5usize;
        let report = run_world::<i32, _, _>(p, Timing::Real, move |comm| {
            let rank = comm.rank() as i32;
            let cfg = NbcConfig {
                fuse: FusePolicy::new(16, 4),
                ..NbcConfig::default()
            };
            let mut eng = Engine::new(comm, SumOp, cfg);
            let mut reqs = Vec::new();
            for (i, &len) in lens.iter().enumerate() {
                let x = DataBuf::real((0..len).map(|j| rank + (i * 100 + j) as i32).collect());
                reqs.push(eng.iallreduce(AlgoKind::Dpdr, x, &blocks_of(len, 2))?);
            }
            let mut out = Vec::new();
            for req in reqs {
                out.push(eng.wait(req)?.into_vec()?);
            }
            Ok(out)
        })
        .unwrap();
        let rank_sum: i32 = (0..p as i32).sum();
        for per_rank in report.results {
            assert_eq!(per_rank.len(), lens.len());
            for (i, (got, &len)) in per_rank.into_iter().zip(&lens).enumerate() {
                let expected: Vec<i32> = (0..len)
                    .map(|j| rank_sum + p as i32 * (i * 100 + j) as i32)
                    .collect();
                assert_eq!(got, expected, "op {i}");
            }
        }
        let totals = report.total_metrics();
        assert_eq!(totals.fused_ops, 4 * p as u64);
        assert_eq!(totals.fused_elems, 22 * p as u64);
    }

    #[test]
    fn explicit_flush_and_partial_batches() {
        // threshold splits traffic: the big op launches solo while the
        // two smalls queue until the explicit flush() closes their batch
        let report = run_world::<i32, _, _>(3, Timing::Real, move |comm| {
            let cfg = NbcConfig {
                fuse: FusePolicy::new(8, 100),
                ..NbcConfig::default()
            };
            let mut eng = Engine::new(comm, SumOp, cfg);
            let rank = eng.rank() as i32;
            let big = eng.iallreduce(
                AlgoKind::Dpdr,
                DataBuf::real(vec![rank; 64]),
                &blocks_of(64, 4),
            )?;
            let s1 = eng.iallreduce(
                AlgoKind::Dpdr,
                DataBuf::real(vec![rank + 1; 4]),
                &blocks_of(4, 1),
            )?;
            // still queued: test must not flush, and must report pending
            assert!(!eng.test(&s1)?);
            let s2 = eng.iallreduce(
                AlgoKind::Dpdr,
                DataBuf::real(vec![rank + 2; 4]),
                &blocks_of(4, 1),
            )?;
            eng.flush()?;
            let a = eng.wait(big)?.into_vec()?;
            let b = eng.wait(s1)?.into_vec()?;
            let c = eng.wait(s2)?.into_vec()?;
            Ok((a, b, c))
        })
        .unwrap();
        for (a, b, c) in report.results {
            assert_eq!(a, vec![3i32; 64]); // 0+1+2
            assert_eq!(b, vec![6i32; 4]); // +1 per rank
            assert_eq!(c, vec![9i32; 4]); // +2 per rank
        }
    }

    #[test]
    fn wait_on_queued_request_is_a_contract_error_until_flushed() {
        // wait never flushes (rank-local wait order must not decide batch
        // composition); an explicit flush launches the batch of one with
        // exactly the submitted block partition
        let report = run_world::<i32, _, _>(2, Timing::Real, move |comm| {
            let cfg = NbcConfig {
                fuse: FusePolicy::new(8, 100),
                ..NbcConfig::default()
            };
            let mut eng = Engine::new(comm, SumOp, cfg);
            let rank = eng.rank() as i32;
            let r1 = eng.iallreduce(
                AlgoKind::Dpdr,
                DataBuf::real(vec![rank; 3]),
                &blocks_of(3, 1),
            )?;
            let r2 = eng.iallreduce(
                AlgoKind::Dpdr,
                DataBuf::real(vec![rank + 1; 3]),
                &blocks_of(3, 1),
            )?;
            // still queued: waiting is refused, nothing launches
            assert!(eng.wait(r1).is_err());
            eng.flush()?;
            eng.wait(r2)?.into_vec()
        })
        .unwrap();
        for got in report.results {
            assert_eq!(got, vec![3i32; 3]); // (0+1) + 1 per rank
        }
    }

    #[test]
    fn virtual_overlap_beats_sequential_on_the_clock() {
        // two ops overlap in virtual time under the dedicated model: the
        // engine finishes in ~one op's time, the blocking loop in two
        let m = 4_000usize;
        let blocking = run_world::<i32, _, _>(6, Timing::hydra(), move |comm| {
            for _ in 0..2 {
                let x = DataBuf::phantom(m);
                crate::collectives::allreduce(
                    AlgoKind::Dpdr,
                    comm,
                    x,
                    &SumOp,
                    &Blocks::by_count(m, 8),
                )?;
            }
            Ok(())
        })
        .unwrap();
        let overlapped = run_world::<i32, _, _>(6, Timing::hydra(), move |comm| {
            let blocks = Blocks::by_count(m, 8);
            let mut eng = Engine::new(comm, SumOp, NbcConfig::default());
            let r1 = eng.iallreduce(AlgoKind::Dpdr, DataBuf::phantom(m), &blocks)?;
            let r2 = eng.iallreduce(AlgoKind::Dpdr, DataBuf::phantom(m), &blocks)?;
            eng.wait(r1)?;
            eng.wait(r2)?;
            Ok(())
        })
        .unwrap();
        let t_seq = blocking.max_vtime_us;
        let t_ovl = overlapped.max_vtime_us;
        assert!(
            t_ovl < 0.75 * t_seq,
            "overlap {t_ovl} should beat sequential {t_seq}"
        );
    }

    #[test]
    fn sequential_engines_need_disjoint_tag_bases() {
        // two engines, one after the other, on the same world: disjoint
        // leases keep their channels apart
        let report = run_world::<i32, _, _>(3, Timing::Real, move |comm| {
            let a = {
                let mut eng = Engine::new(comm, SumOp, NbcConfig::default());
                let r = eng.iallreduce(
                    AlgoKind::Dpdr,
                    DataBuf::real(vec![1i32; 4]),
                    &blocks_of(4, 1),
                )?;
                eng.wait(r)?.into_vec()?
            };
            let cfg = NbcConfig {
                tag_base: 1000,
                ..NbcConfig::default()
            };
            let b = {
                let mut eng = Engine::new(comm, SumOp, cfg);
                let r = eng.iallreduce(
                    AlgoKind::Dpdr,
                    DataBuf::real(vec![2i32; 4]),
                    &blocks_of(4, 1),
                )?;
                eng.wait(r)?.into_vec()?
            };
            Ok((a, b))
        })
        .unwrap();
        for (a, b) in report.results {
            assert_eq!(a, vec![3i32; 4]);
            assert_eq!(b, vec![6i32; 4]);
        }
    }

    #[test]
    fn tag_pool_exhaustion_is_typed_and_release_revives() {
        let mut pool = TagPool::new(u32::MAX - 2);
        assert_eq!(pool.lease().unwrap(), u32::MAX - 2);
        assert_eq!(pool.lease().unwrap(), u32::MAX - 1);
        assert!(matches!(pool.lease(), Err(Error::TagsExhausted)));
        // recycled leases revive an exhausted pool, LIFO
        let mut epoch = vec![u32::MAX - 2, u32::MAX - 1];
        pool.release(&mut epoch);
        assert!(epoch.is_empty());
        assert_eq!(pool.lease().unwrap(), u32::MAX - 1);
        assert_eq!(pool.lease().unwrap(), u32::MAX - 2);
        assert!(matches!(pool.lease(), Err(Error::TagsExhausted)));
    }

    #[test]
    fn epoch_quiesce_reclaims_tags_and_keeps_entries_flat() {
        let rounds = 8i32;
        let p = 4usize;
        let report = run_world::<i32, _, _>(p, Timing::Real, move |comm| {
            let cfg = NbcConfig {
                epoch_ops: 1, // close an epoch at every wait_all
                ..NbcConfig::default()
            };
            let mut eng = Engine::new(comm, SumOp, cfg);
            for round in 0..rounds {
                let x = DataBuf::real(vec![round; 8]);
                let req = eng.iallreduce(AlgoKind::Dpdr, x, &blocks_of(8, 2))?;
                eng.wait_all()?;
                let y = eng.wait(req)?.into_vec()?;
                if y != vec![round * p as i32; 8] {
                    return Err(Error::Protocol(format!("round {round}: wrong payload")));
                }
                // the epoch's sparse channel entries were dropped by the
                // quiesce inside wait_all — the table never accumulates
                let live = eng.comm.tagged_entries();
                if live != 0 {
                    return Err(Error::Protocol(format!(
                        "round {round}: {live} tagged entries leaked past quiesce"
                    )));
                }
            }
            Ok(())
        })
        .unwrap();
        let totals = report.total_metrics();
        assert_eq!(totals.epochs, rounds as u64 * p as u64);
        assert_eq!(totals.tags_recycled, rounds as u64 * p as u64);
    }

    #[test]
    fn overload_rejects_spmd_and_wait_all_readmits() {
        let report = run_world::<i32, _, _>(2, Timing::Real, move |comm| {
            let cfg = NbcConfig {
                max_in_flight: 2,
                ..NbcConfig::default()
            };
            let mut eng = Engine::new(comm, SumOp, cfg);
            let mk = |v: i32| DataBuf::real(vec![v; 4]);
            let r1 = eng.iallreduce(AlgoKind::Dpdr, mk(1), &blocks_of(4, 1))?;
            let r2 = eng.iallreduce(AlgoKind::Dpdr, mk(2), &blocks_of(4, 1))?;
            let rejected = matches!(
                eng.iallreduce(AlgoKind::Dpdr, mk(3), &blocks_of(4, 1)),
                Err(Error::Overloaded {
                    in_flight: 2,
                    budget: 2
                })
            );
            let a = eng.wait(r1)?.into_vec()?;
            // a rank-local wait must NOT readmit: admission stays SPMD
            let still_rejected = matches!(
                eng.iallreduce(AlgoKind::Dpdr, mk(3), &blocks_of(4, 1)),
                Err(Error::Overloaded { .. })
            );
            let _ = eng.wait(r2)?;
            eng.wait_all()?; // symmetric point: the budget resets
            let r4 = eng.iallreduce(AlgoKind::Dpdr, mk(4), &blocks_of(4, 1))?;
            let d = eng.wait(r4)?.into_vec()?;
            Ok((rejected, still_rejected, a, d))
        })
        .unwrap();
        for (rejected, still_rejected, a, d) in report.results {
            assert!(rejected, "third submission must overflow the budget");
            assert!(still_rejected, "rank-local wait must not readmit");
            assert_eq!(a, vec![2i32; 4]);
            assert_eq!(d, vec![8i32; 4]);
        }
    }

    #[test]
    fn engine_kind_parses_cli_names() {
        assert_eq!(
            "threaded".parse::<EngineKind>().unwrap(),
            EngineKind::Threaded
        );
        assert_eq!(
            "schedule".parse::<EngineKind>().unwrap(),
            EngineKind::Schedule
        );
        assert!("turbo".parse::<EngineKind>().is_err());
        assert_eq!(EngineKind::default(), EngineKind::Threaded);
        assert_eq!(EngineKind::Schedule.name(), "schedule");
    }

    #[test]
    fn schedule_engine_roundtrip_matches_oracle() {
        let spec = RunSpec::new(4, 40);
        let expected = spec.expected_sum_i32();
        let report = run_world::<i32, _, _>(4, Timing::Real, move |comm| {
            let x = DataBuf::real(spec.input_i32(comm.rank()));
            let cfg = NbcConfig {
                engine: EngineKind::Schedule,
                ..NbcConfig::default()
            };
            let mut eng = Engine::new(comm, SumOp, cfg);
            let req = eng.iallreduce(AlgoKind::Dpdr, x, &blocks_of(40, 4))?;
            let y = eng.wait(req)?;
            y.into_vec()
        })
        .unwrap();
        for got in report.results {
            assert_eq!(got, expected);
        }
    }

    #[test]
    fn schedule_engine_overlaps_out_of_order_waits() {
        let specs: Vec<RunSpec> = (0..3u64).map(|i| RunSpec::new(5, 24).seed(19 + i)).collect();
        let expected: Vec<Vec<i32>> = specs.iter().map(|s| s.expected_sum_i32()).collect();
        let specs2 = specs.clone();
        let report = run_world::<i32, _, _>(5, Timing::Real, move |comm| {
            let cfg = NbcConfig {
                engine: EngineKind::Schedule,
                ..NbcConfig::default()
            };
            let mut eng = Engine::new(comm, SumOp, cfg);
            let mut reqs = Vec::new();
            for s in &specs2 {
                let x = DataBuf::real(s.input_i32(eng.rank()));
                reqs.push(eng.iallreduce(AlgoKind::Ring, x, &blocks_of(24, 2))?);
            }
            let mut out = vec![Vec::new(); 3];
            for (i, req) in reqs.into_iter().enumerate().rev() {
                out[i] = eng.wait(req)?.into_vec()?;
            }
            Ok(out)
        })
        .unwrap();
        for per_rank in report.results {
            for (i, got) in per_rank.into_iter().enumerate() {
                assert_eq!(got, expected[i], "op {i}");
            }
        }
    }

    #[test]
    fn schedule_engine_cancels_at_deadline_symmetrically() {
        // a deadline no exchange can beat: every rank abandons
        // mid-flight with took_us pinned to exactly the deadline, and
        // the engine (and its tag pool) keeps serving afterwards
        let m = 4_000usize;
        let report = run_world::<i32, _, _>(4, Timing::hydra(), move |comm| {
            let blocks = Blocks::by_count(m, 8);
            let cfg = NbcConfig {
                engine: EngineKind::Schedule,
                ..NbcConfig::default()
            };
            let mut eng = Engine::new(comm, SumOp, cfg);
            let r = eng.iallreduce_deadline(
                AlgoKind::Dpdr,
                DataBuf::phantom(m),
                &blocks,
                Some(1e-3),
            )?;
            let cancelled = matches!(
                eng.wait(r),
                Err(Error::Deadline {
                    op: 0,
                    deadline_us,
                    took_us,
                }) if deadline_us == 1e-3 && took_us == 1e-3
            );
            let r2 = eng.iallreduce(AlgoKind::Dpdr, DataBuf::phantom(m), &blocks)?;
            let ok_after = eng.wait(r2).is_ok();
            eng.wait_all()?;
            Ok((cancelled, ok_after))
        })
        .unwrap();
        for (cancelled, ok_after) in report.results {
            assert!(cancelled, "every rank must see the symmetric cancellation");
            assert!(ok_after, "engine must keep serving after a cancellation");
        }
    }

    #[test]
    fn deadline_miss_is_typed_and_engine_survives() {
        let m = 4_000usize;
        let report = run_world::<i32, _, _>(4, Timing::hydra(), move |comm| {
            let blocks = Blocks::by_count(m, 8);
            let mut eng = Engine::new(comm, SumOp, NbcConfig::default());
            // an impossible deadline: any exchange costs at least α
            let r = eng.iallreduce_deadline(AlgoKind::Dpdr, DataBuf::phantom(m), &blocks, Some(1e-3))?;
            let missed = matches!(eng.wait(r), Err(Error::Deadline { op: 0, .. }));
            // the op itself completed (peers saw no abort): the engine
            // and world keep serving after the miss
            let r2 = eng.iallreduce(AlgoKind::Dpdr, DataBuf::phantom(m), &blocks)?;
            let after_ok = eng.wait(r2).is_ok();
            // wait_timed hands back the late payload plus its duration
            let r3 = eng.iallreduce_deadline(AlgoKind::Dpdr, DataBuf::phantom(m), &blocks, Some(1e-3))?;
            let (_, took_us) = eng.wait_timed(r3)?;
            Ok((missed, after_ok, took_us))
        })
        .unwrap();
        for (missed, after_ok, took_us) in report.results {
            assert!(missed, "1 ns deadline must be missed");
            assert!(after_ok, "engine must keep serving after a miss");
            assert!(took_us > 1e-3, "late duration must be reported: {took_us}");
        }
    }
}
