//! Concurrent-traffic driver: every rank keeps `k` allreduces in flight
//! through an [`Engine`](super::Engine) — the serving-workload shape
//! (many small concurrent reductions) the blocking harness cannot
//! express. Used by the `dpdr concurrent` CLI mode, the concurrency
//! battery (`tests/nbc.rs`), and `benches/fusion_overlap.rs`.

use super::{Engine, EngineKind, FusePolicy, NbcConfig};
use crate::buffer::DataBuf;
use crate::collectives::RunSpec;
use crate::comm::{run_world, Comm, ThreadComm, Timing, WorldReport};
use crate::error::{Error, Result};
use crate::model::AlgoKind;
use crate::ops::SumOp;

/// One concurrent-traffic experiment: `k` outstanding i32 sum-allreduces
/// per rank, op `i` running `algos[i % algos.len()]` on input derived
/// from `base` with a per-op seed.
#[derive(Clone, Debug)]
pub struct ConcurrentSpec {
    /// World shape, payload length, block size, mapping, seed.
    pub base: RunSpec,
    /// Outstanding operations per rank.
    pub k: usize,
    /// Per-op algorithm rotation (flat allreduce kinds or `Hier`;
    /// `Scan` is rejected — its per-rank results are not an allreduce).
    pub algos: Vec<AlgoKind>,
    /// Fusion policy for the engines.
    pub fuse: FusePolicy,
    /// Execution engine: thread-per-op workers or the compiled-schedule
    /// progress core.
    pub engine: EngineKind,
}

impl ConcurrentSpec {
    pub fn new(base: RunSpec, k: usize) -> ConcurrentSpec {
        ConcurrentSpec {
            base,
            k,
            algos: vec![AlgoKind::Dpdr],
            fuse: FusePolicy::off(),
            engine: EngineKind::default(),
        }
    }

    pub fn algos(mut self, algos: Vec<AlgoKind>) -> ConcurrentSpec {
        self.algos = algos;
        self
    }

    pub fn fuse(mut self, fuse: FusePolicy) -> ConcurrentSpec {
        self.fuse = fuse;
        self
    }

    pub fn engine(mut self, engine: EngineKind) -> ConcurrentSpec {
        self.engine = engine;
        self
    }

    /// The [`RunSpec`] of operation `i`: the base with a per-op seed, so
    /// every operation reduces distinct data against a distinct oracle.
    pub fn op_spec(&self, i: usize) -> RunSpec {
        self.base
            .seed(self.base.seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 1)
    }

    /// The algorithm operation `i` runs.
    pub fn op_algo(&self, i: usize) -> AlgoKind {
        self.algos[i % self.algos.len()]
    }

    /// The sequential oracle of operation `i`.
    pub fn op_expected(&self, i: usize) -> Vec<i32> {
        self.op_spec(i).expected_sum_i32()
    }
}

/// Run the concurrent-traffic world: each rank submits all `k` operations
/// up front (same order everywhere — the SPMD contract), then waits for
/// them in a per-rank *rotated* order, exercising out-of-order completion.
/// Returns per-rank `(payloads in op order, measured time in µs)`.
///
/// The measured time spans submission through the last wait, from a
/// barrier-synchronized start (mpicroscope style) — under virtual timing
/// overlapped operations genuinely overlap on the clock, while sharing
/// NIC ports and edge queues under a congestion-aware model.
pub fn run_concurrent_i32(
    cspec: &ConcurrentSpec,
    timing: Timing,
) -> Result<WorldReport<(Vec<DataBuf<i32>>, f64)>> {
    if cspec.k == 0 || cspec.algos.is_empty() {
        return Err(Error::Config("concurrent run needs k >= 1 and algorithms".into()));
    }
    if cspec.algos.contains(&AlgoKind::Scan) {
        return Err(Error::Config(
            "scan is not an allreduce: its per-rank prefixes have no shared oracle here".into(),
        ));
    }
    let cspec = cspec.clone();
    let timing = cspec.base.effective_timing(timing);
    let blocks = cspec.base.blocks()?;
    run_world::<i32, _, _>(cspec.base.p, timing, move |comm: &mut ThreadComm<i32>| {
        let rank = comm.rank();
        let k = cspec.k;
        let cfg = NbcConfig {
            fuse: cspec.fuse,
            mapping: cspec.base.mapping,
            backend: cspec.base.reduce_backend,
            engine: cspec.engine,
            ..NbcConfig::default()
        };
        comm.barrier()?;
        comm.reset_time();
        let mut eng = Engine::new(comm, SumOp, cfg);
        let mut reqs = Vec::with_capacity(k);
        for i in 0..k {
            let spec = cspec.op_spec(i);
            let x = if spec.phantom {
                DataBuf::phantom(spec.m)
            } else {
                DataBuf::real(spec.input_i32(rank))
            };
            reqs.push(Some(eng.iallreduce(cspec.op_algo(i), x, &blocks)?));
        }
        // explicit SPMD flush point: close any partially filled fused
        // batch before the waits (wait itself never flushes)
        eng.flush()?;
        // wait in a rotated (per-rank) order: completion order is free
        let mut results: Vec<Option<DataBuf<i32>>> = (0..k).map(|_| None).collect();
        for j in 0..k {
            let i = (rank + j) % k;
            let req = reqs[i].take().expect("each op waited once");
            results[i] = Some(eng.wait(req)?);
        }
        drop(eng);
        let elapsed = comm.time_us();
        Ok((
            results.into_iter().map(|r| r.expect("all waited")).collect(),
            elapsed,
        ))
    })
}

/// The mpicroscope-style statistic of a concurrent run: max over ranks of
/// the per-rank elapsed time (one round — virtual runs are deterministic
/// up to congestion scheduling noise).
pub fn concurrent_time_us(report: &WorldReport<(Vec<DataBuf<i32>>, f64)>) -> f64 {
    report
        .results
        .iter()
        .map(|(_, t)| *t)
        .fold(f64::NEG_INFINITY, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn driver_matches_per_op_oracles() {
        let cspec = ConcurrentSpec::new(RunSpec::new(6, 48).block_elems(8), 3)
            .algos(vec![AlgoKind::Dpdr, AlgoKind::Ring]);
        let report = run_concurrent_i32(&cspec, Timing::Real).unwrap();
        assert_eq!(report.results.len(), 6);
        for (rank, (bufs, _t)) in report.results.iter().enumerate() {
            assert_eq!(bufs.len(), 3);
            for (i, buf) in bufs.iter().enumerate() {
                assert_eq!(
                    buf.as_slice().unwrap(),
                    &cspec.op_expected(i)[..],
                    "rank {rank} op {i}"
                );
            }
        }
        // distinct ops reduce distinct data
        assert_ne!(cspec.op_expected(0), cspec.op_expected(1));
        let totals = report.total_metrics();
        assert_eq!(totals.ops_in_flight_max, 3);
    }

    #[test]
    fn driver_rejects_degenerate_and_scan() {
        let c = ConcurrentSpec::new(RunSpec::new(2, 4), 0);
        assert!(run_concurrent_i32(&c, Timing::Real).is_err());
        let c = ConcurrentSpec::new(RunSpec::new(2, 4), 2).algos(vec![AlgoKind::Scan]);
        assert!(run_concurrent_i32(&c, Timing::Real).is_err());
    }
}
