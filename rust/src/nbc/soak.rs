//! Serving-mode soak harness: drive one long-lived world through a large
//! stream of mixed-size nonblocking allreduces — epochs reclaiming tags,
//! admission control shedding load, deadlines surfacing misses, and an
//! optional [`FaultPlan`] stressing the transport underneath — while
//! verifying every payload against an O(m) closed-form oracle and
//! watching registry memory stay flat.
//!
//! This is the always-on counterpart of the one-shot benchmark harness:
//! correctness is asserted *in the loop* (a soak that silently corrupts
//! payloads is worse than one that crashes), and the interesting outputs
//! are the degradation counters — deadline misses, overload rejections,
//! retransmits — not a single latency number. Reached via the `soak` CLI
//! subcommand; CI runs a bounded smoke (`soak --ops 50000 --faults
//! transient-drop,stall --seed 7`).
//!
//! Every rank derives the identical op stream from the seed (sizes,
//! coefficients, submission order), so admission decisions and epoch
//! boundaries stay SPMD-symmetric by construction — the soak would
//! deadlock, not silently pass, if they ever diverged.

use std::collections::VecDeque;

use super::{Engine, EngineKind, FusePolicy, NbcConfig, Request};
use crate::buffer::DataBuf;
use crate::comm::{run_world_faulty, Comm, FaultPlan, Timing};
use crate::error::{Error, Result};
use crate::model::AlgoKind;
use crate::ops::SumOp;
use crate::pipeline::Blocks;

/// One soak experiment. Defaults are a serving-shaped workload: small
/// mixed sizes, fusion on, an epoch every few batches.
#[derive(Clone, Debug)]
pub struct SoakSpec {
    /// World size (ranks).
    pub p: usize,
    /// Operations to run per rank.
    pub ops: u64,
    /// Smallest payload, in elements (≥ 1).
    pub m_min: usize,
    /// Largest payload, in elements (≥ `m_min`).
    pub m_max: usize,
    /// Operations submitted between wait_all drain points.
    pub batch: usize,
    /// [`NbcConfig::epoch_ops`]: quiesce + reclaim once this many tags
    /// are leased (0 disables reclamation until the final quiesce).
    pub epoch_ops: usize,
    /// [`NbcConfig::max_in_flight`]: admission budget (0 = unlimited).
    /// Set below `batch` to exercise overload shedding.
    pub max_in_flight: usize,
    /// Per-op completion deadline in µs (`None` = no deadline). Misses
    /// are *counted*, not fatal: the soak redeems through
    /// [`Engine::wait_timed`] so late payloads are still verified.
    pub deadline_us: Option<f64>,
    /// Stream seed: sizes, coefficients, and the fault plan's rolls.
    pub seed: u64,
    /// Transport fault plan (see [`FaultPlan::parse`]).
    pub faults: FaultPlan,
    /// Timing mode the world runs under.
    pub timing: Timing,
    /// Fuse small ops into batched dpdr launches.
    pub fuse: bool,
    /// Sliding latency window: the last `window` per-op durations feed
    /// the report's percentiles.
    pub window: usize,
    /// Verify the full payload every `check_every` ops (first and last
    /// element are checked on every op regardless).
    pub check_every: u64,
    /// Execution engine: thread-per-op workers (default) or the
    /// compiled-schedule progress core. Under the schedule engine a
    /// deadline *cancels* late ops mid-flight — those count as misses
    /// with no payload to verify. Fused batches still ride workers, so
    /// pair `engine: Schedule` with `fuse: false` to drive every op
    /// through the core.
    pub engine: EngineKind,
}

impl SoakSpec {
    /// A serving-shaped default stream: `ops` operations of 8..=1024
    /// elements on `p` ranks under virtual Hydra timing, fused, epoch
    /// every 256 tags, no faults.
    pub fn new(p: usize, ops: u64) -> SoakSpec {
        SoakSpec {
            p,
            ops,
            m_min: 8,
            m_max: 1024,
            batch: 64,
            epoch_ops: 256,
            max_in_flight: 0,
            deadline_us: None,
            seed: 1,
            faults: FaultPlan::none(),
            timing: Timing::hydra(),
            fuse: true,
            window: 1024,
            check_every: 97,
            engine: EngineKind::default(),
        }
    }
}

/// What a soak run observed, aggregated over ranks.
#[derive(Clone, Debug)]
pub struct SoakReport {
    /// Operations completed *per rank* (every submitted op is redeemed).
    pub ops_completed: u64,
    /// Deadline misses summed over ranks (ops whose duration exceeded
    /// the deadline; their payloads still verified).
    pub deadline_misses: u64,
    /// Submissions rejected with [`Error::Overloaded`], summed over
    /// ranks (each was drained and resubmitted successfully).
    pub overload_rejections: u64,
    /// High-water mark of live registry entries (sparse channel + tagged
    /// barrier tables) observed at the sample points.
    pub entries_high_water: usize,
    /// Live registry entries after the final quiesce — flat means 0.
    pub entries_final: usize,
    /// Epochs closed (from [`RankMetrics`](crate::comm::RankMetrics)).
    pub epochs: u64,
    /// Tags returned to the free pool by reclamation.
    pub tags_recycled: u64,
    /// Transmissions repeated by the transient-drop fault mode.
    pub retransmits: u64,
    /// Other injected fault events (delays, duplicates, reorder holds).
    pub fault_events: u64,
    /// Median per-op duration over rank 0's sliding window, in µs
    /// (exact sample, [`Stats::p50`](crate::metrics::Stats::p50)).
    pub p50_us: f64,
    /// 90th-percentile per-op duration over rank 0's window, in µs.
    pub p90_us: f64,
    /// 99th-percentile per-op duration over rank 0's window, in µs.
    pub p99_us: f64,
    /// Wall-clock duration of the whole soak, in µs.
    pub wall_us: f64,
    /// Final virtual clock (0 under real timing), in µs.
    pub max_vtime_us: f64,
}

impl SoakReport {
    /// Serialize the report as a single JSON object (`dpdr soak --json`).
    /// Same hand-rolled style as
    /// [`ScheduleCert::to_json`](crate::schedule::verify::ScheduleCert::to_json):
    /// flat keys, no dependencies, floats via `{:.3}` so runs diff cleanly.
    pub fn to_json(&self) -> String {
        format!(
            "{{\"ops_completed\":{},\"deadline_misses\":{},\"overload_rejections\":{},\
             \"entries_high_water\":{},\"entries_final\":{},\"epochs\":{},\
             \"tags_recycled\":{},\"retransmits\":{},\"fault_events\":{},\
             \"p50_us\":{:.3},\"p90_us\":{:.3},\"p99_us\":{:.3},\
             \"wall_us\":{:.3},\"max_vtime_us\":{:.3}}}",
            self.ops_completed,
            self.deadline_misses,
            self.overload_rejections,
            self.entries_high_water,
            self.entries_final,
            self.epochs,
            self.tags_recycled,
            self.retransmits,
            self.fault_events,
            self.p50_us,
            self.p90_us,
            self.p99_us,
            self.wall_us,
            self.max_vtime_us,
        )
    }
}

/// splitmix64 finalizer — the same stateless generator the fault plan
/// rolls with, so the op stream is identical on every rank.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Op `i`'s shape: payload length in `m_min..=m_max` and the affine
/// coefficient of its input.
fn op_shape(spec: &SoakSpec, i: u64) -> (usize, i32) {
    let h = mix(spec.seed ^ i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let span = (spec.m_max - spec.m_min + 1) as u64;
    let m = spec.m_min + (h % span) as usize;
    let a = 1 + ((h >> 32) % 7) as i32;
    (m, a)
}

/// Op `i`'s input on `rank`: `x[j] = a·j + rank`. The allreduce oracle is
/// closed-form — `y[j] = p·a·j + p(p−1)/2` — so verification is O(m) with
/// no reference reduction. Magnitudes stay far from i32 overflow for any
/// plausible `p`/`m_max`.
fn op_input(rank: usize, m: usize, a: i32) -> Vec<i32> {
    (0..m).map(|j| a * j as i32 + rank as i32).collect()
}

/// Check `y` against the oracle; full scan every `check_every` ops, end
/// points otherwise.
fn verify(y: &[i32], i: u64, m: usize, a: i32, p: usize, check_every: u64) -> Result<()> {
    let pa = p as i32 * a;
    let rank_sum = (p * (p - 1) / 2) as i32;
    let expect = |j: usize| pa * j as i32 + rank_sum;
    let mismatch = |j: usize, got: i32| {
        Err(Error::Protocol(format!(
            "soak op {i}: payload mismatch at element {j}: got {got}, want {}",
            expect(j)
        )))
    };
    if y.len() != m {
        return Err(Error::Protocol(format!(
            "soak op {i}: length {} != {m}",
            y.len()
        )));
    }
    if check_every > 0 && i % check_every == 0 {
        for (j, &got) in y.iter().enumerate() {
            if got != expect(j) {
                return mismatch(j, got);
            }
        }
    } else {
        for j in [0, m - 1] {
            if y[j] != expect(j) {
                return mismatch(j, y[j]);
            }
        }
    }
    Ok(())
}

/// Per-rank soak outcome, folded into the [`SoakReport`] afterwards.
struct RankSoak {
    completed: u64,
    misses: u64,
    rejections: u64,
    high_water: usize,
    final_entries: usize,
    window: Vec<f64>,
}

/// Run the soak and aggregate the report. Any hang would be broken by
/// the transport watchdog into a typed error; any payload corruption
/// fails the run immediately.
pub fn run_soak(spec: &SoakSpec) -> Result<SoakReport> {
    if spec.p < 2 || spec.ops == 0 || spec.m_min == 0 || spec.m_min > spec.m_max {
        return Err(Error::Config(
            "soak needs p >= 2, ops >= 1, and 1 <= m_min <= m_max".into(),
        ));
    }
    let spec = spec.clone();
    let timing = spec.timing;
    let faults = spec.faults;
    let p = spec.p;
    let report = run_world_faulty::<i32, _, _>(p, timing, faults, move |comm| {
        let batch = spec.batch.max(1);
        let cfg = NbcConfig {
            fuse: if spec.fuse {
                FusePolicy::new(spec.m_max, batch)
            } else {
                FusePolicy::off()
            },
            epoch_ops: spec.epoch_ops,
            max_in_flight: spec.max_in_flight,
            engine: spec.engine,
            ..NbcConfig::default()
        };
        let rank = comm.rank();
        let mut eng = Engine::new(comm, SumOp, cfg);
        let mut stats = RankSoak {
            completed: 0,
            misses: 0,
            rejections: 0,
            high_water: 0,
            final_entries: 0,
            window: Vec::new(),
        };
        let mut lat: VecDeque<f64> = VecDeque::with_capacity(spec.window.max(1));
        let sample_high = |eng: &Engine<'_, i32, SumOp>, high: &mut usize| {
            let live = eng.comm.tagged_entries() + eng.comm.barrier_entries();
            *high = (*high).max(live);
        };
        let mut next = 0u64;
        while next < spec.ops {
            let end = (next + batch as u64).min(spec.ops);
            let mut reqs = Vec::with_capacity((end - next) as usize);
            for i in next..end {
                let (m, a) = op_shape(&spec, i);
                let blocks = Blocks::by_count(m, m.min(4));
                let x = DataBuf::real(op_input(rank, m, a));
                let dl = spec.deadline_us;
                let req = match eng.iallreduce_deadline(AlgoKind::Dpdr, x, &blocks, dl) {
                    Ok(r) => r,
                    Err(Error::Overloaded { .. }) => {
                        // shed load at the same op on every rank (the
                        // admission counter is SPMD), drain to the
                        // symmetric point, then the retry is admitted
                        stats.rejections += 1;
                        eng.wait_all()?;
                        for (j, r) in reqs.drain(..) {
                            redeem(&mut eng, &spec, p, j, r, &mut stats, &mut lat)?;
                        }
                        let x = DataBuf::real(op_input(rank, m, a));
                        eng.iallreduce_deadline(AlgoKind::Dpdr, x, &blocks, dl)?
                    }
                    Err(e) => return Err(e),
                };
                reqs.push((i, req));
            }
            sample_high(&eng, &mut stats.high_water);
            eng.wait_all()?;
            for (i, r) in reqs {
                redeem(&mut eng, &spec, p, i, r, &mut stats, &mut lat)?;
            }
            sample_high(&eng, &mut stats.high_water);
            next = end;
        }
        // final epoch close: with reclamation on this is a formality;
        // with epoch_ops = 0 it is the run's only reclamation
        eng.quiesce()?;
        stats.final_entries = eng.comm.tagged_entries() + eng.comm.barrier_entries();
        stats.window = lat.into_iter().collect();
        Ok(stats)
    })?;

    let totals = report.total_metrics();
    let mut out = SoakReport {
        ops_completed: 0,
        deadline_misses: 0,
        overload_rejections: 0,
        entries_high_water: 0,
        entries_final: 0,
        epochs: totals.epochs,
        tags_recycled: totals.tags_recycled,
        retransmits: totals.retransmits,
        fault_events: totals.fault_events,
        p50_us: 0.0,
        p90_us: 0.0,
        p99_us: 0.0,
        wall_us: report.wall_us,
        max_vtime_us: report.max_vtime_us,
    };
    for (rank, s) in report.results.iter().enumerate() {
        if rank == 0 {
            out.ops_completed = s.completed;
            if !s.window.is_empty() {
                let mut lat = crate::metrics::Stats::new();
                for &v in &s.window {
                    lat.push(v);
                }
                out.p50_us = lat.p50();
                out.p90_us = lat.p90();
                out.p99_us = lat.p99();
            }
        }
        out.deadline_misses += s.misses;
        out.overload_rejections += s.rejections;
        out.entries_high_water = out.entries_high_water.max(s.high_water);
        out.entries_final = out.entries_final.max(s.final_entries);
    }
    Ok(out)
}

/// Redeem one request: verify its payload against the oracle, record its
/// latency, and count a deadline miss if it came in late.
fn redeem(
    eng: &mut Engine<'_, i32, SumOp>,
    spec: &SoakSpec,
    p: usize,
    i: u64,
    req: Request<i32>,
    stats: &mut RankSoak,
    lat: &mut VecDeque<f64>,
) -> Result<()> {
    let (y, took_us) = match eng.wait_timed(req) {
        Ok(out) => out,
        // the schedule engine's true cancellation: the op was abandoned
        // mid-flight at its deadline on every rank — a *counted* miss
        // (there is no late payload to verify), not a soak failure
        Err(Error::Deadline { took_us, .. }) => {
            stats.misses += 1;
            stats.completed += 1;
            if spec.window > 0 {
                if lat.len() == spec.window {
                    lat.pop_front();
                }
                lat.push_back(took_us);
            }
            return Ok(());
        }
        Err(e) => return Err(e),
    };
    if let Some(dl) = spec.deadline_us {
        if took_us > dl {
            stats.misses += 1;
        }
    }
    let (m, a) = op_shape(spec, i);
    let ys = y
        .as_slice()
        .ok_or_else(|| Error::Protocol("soak payload is not a real buffer".into()))?;
    verify(ys, i, m, a, p, spec.check_every)?;
    stats.completed += 1;
    if spec.window > 0 {
        if lat.len() == spec.window {
            lat.pop_front();
        }
        lat.push_back(took_us);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn soak_smoke_fault_free() {
        let mut spec = SoakSpec::new(4, 300);
        spec.m_min = 4;
        spec.m_max = 64;
        spec.batch = 16;
        spec.epoch_ops = 32;
        let r = run_soak(&spec).unwrap();
        assert_eq!(r.ops_completed, 300);
        assert_eq!(r.deadline_misses, 0);
        assert_eq!(r.overload_rejections, 0);
        assert_eq!(r.entries_final, 0, "final quiesce must drain the tables");
        assert!(r.epochs > 0 && r.tags_recycled > 0);
        assert!(r.p50_us > 0.0 && r.p90_us >= r.p50_us && r.p99_us >= r.p90_us);
        let json = r.to_json();
        assert!(json.starts_with('{') && json.ends_with('}'));
        assert!(json.contains("\"ops_completed\":300"));
        assert!(json.contains("\"p90_us\":"));
        // the exporter's own parser must round-trip the report
        let v = crate::obs::json::parse(&json).expect("report is valid JSON");
        assert_eq!(v.get("ops_completed").and_then(|n| n.as_f64()), Some(300.0));
    }

    #[test]
    fn soak_under_full_fault_plan_is_deterministic() {
        let mut spec = SoakSpec::new(4, 200);
        spec.m_min = 4;
        spec.m_max = 32;
        spec.batch = 16;
        spec.epoch_ops = 32;
        spec.seed = 7;
        spec.faults = FaultPlan::parse("all", 7).unwrap();
        let a = run_soak(&spec).unwrap();
        let b = run_soak(&spec).unwrap();
        assert_eq!(a.ops_completed, 200);
        assert!(a.retransmits + a.fault_events > 0, "plan must actually fire");
        // same seed, same stream: the virtual clock and fault counters
        // are bitwise reproducible
        assert_eq!(a.max_vtime_us.to_bits(), b.max_vtime_us.to_bits());
        assert_eq!(a.retransmits, b.retransmits);
        assert_eq!(a.fault_events, b.fault_events);
        assert_eq!(a.entries_final, 0);
    }

    #[test]
    fn soak_sheds_load_and_counts_misses() {
        let mut spec = SoakSpec::new(2, 120);
        spec.m_min = 4;
        spec.m_max = 32;
        spec.batch = 24;
        spec.max_in_flight = 8; // below batch: forced overload shedding
        spec.epoch_ops = 16;
        spec.deadline_us = Some(1e-6); // impossibly tight: every op late
        let r = run_soak(&spec).unwrap();
        assert_eq!(r.ops_completed, 120, "shed ops are resubmitted, not lost");
        assert!(r.overload_rejections > 0, "budget below batch must shed");
        assert_eq!(r.deadline_misses, 120 * 2, "every op on both ranks is late");
    }

    #[test]
    fn soak_under_schedule_engine_matches_counts() {
        // the whole stream through the progress core (fusion off so no
        // op falls back to a worker), under the full fault plan
        let mut spec = SoakSpec::new(4, 200);
        spec.m_min = 4;
        spec.m_max = 32;
        spec.batch = 16;
        spec.epoch_ops = 32;
        spec.seed = 7;
        spec.fuse = false;
        spec.engine = EngineKind::Schedule;
        spec.faults = FaultPlan::parse("all", 7).unwrap();
        let a = run_soak(&spec).unwrap();
        let b = run_soak(&spec).unwrap();
        assert_eq!(a.ops_completed, 200);
        assert!(a.retransmits + a.fault_events > 0, "plan must actually fire");
        assert_eq!(a.max_vtime_us.to_bits(), b.max_vtime_us.to_bits());
        assert_eq!(a.retransmits, b.retransmits);
        assert_eq!(a.fault_events, b.fault_events);
        assert_eq!(a.entries_final, 0);
    }

    #[test]
    fn soak_schedule_engine_cancels_and_counts_misses() {
        let mut spec = SoakSpec::new(2, 60);
        spec.m_min = 4;
        spec.m_max = 32;
        spec.batch = 12;
        spec.epoch_ops = 16;
        spec.fuse = false;
        spec.engine = EngineKind::Schedule;
        spec.deadline_us = Some(1e-6); // impossibly tight: every op cancels
        let r = run_soak(&spec).unwrap();
        assert_eq!(r.ops_completed, 60, "cancelled ops are redeemed, not lost");
        assert_eq!(r.deadline_misses, 60 * 2, "every op on both ranks cancels");
    }

    #[test]
    fn soak_rejects_degenerate_specs() {
        assert!(run_soak(&SoakSpec::new(1, 10)).is_err());
        assert!(run_soak(&SoakSpec::new(4, 0)).is_err());
        let mut s = SoakSpec::new(4, 10);
        s.m_min = 9;
        s.m_max = 8;
        assert!(run_soak(&s).is_err());
    }
}
