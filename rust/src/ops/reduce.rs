//! Reduction operators over [`Elem`] slices.

use super::elem::{Elem, Mat2, Span};

/// Which side of ⊙ the *incoming* (received) block stands on.
///
/// Algorithm 1 computes `Y[j] ← t ⊙ Y[j]` for blocks received from children
/// (incoming on the **left**) and `Y[j] ← Y[j] ⊙ t` at the lower-numbered
/// dual root (incoming on the **right**). Getting this wrong is invisible
/// with `MPI_SUM` but breaks non-commutative operators — the test suite
/// covers both sides via [`Mat2Op`] and [`SeqCheckOp`].
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Side {
    /// `acc ← incoming ⊙ acc`
    Left,
    /// `acc ← acc ⊙ incoming`
    Right,
}

/// An associative binary reduction operator over element type `E`.
pub trait ReduceOp<E: Elem>: Send + Sync {
    /// The identity element of ⊙ (also used for padding partial blocks).
    fn identity(&self) -> E;

    /// `a ⊙ b` — order is significant for non-commutative operators.
    fn combine(&self, a: E, b: E) -> E;

    /// Whether ⊙ commutes; purely informational (algorithms never rely on it).
    fn commutative(&self) -> bool {
        false
    }

    /// Stable operator name, used for artifact lookup and reports.
    fn name(&self) -> &'static str;

    /// Element-wise in-place reduction of `incoming` into `acc`.
    ///
    /// Hot path: the default implementation is a plain loop; the four
    /// arithmetic operators override it per concrete element type to
    /// dispatch through the pluggable backend layer
    /// ([`backend::reduce_arith`](super::backend::reduce_arith) — scalar /
    /// SIMD / PJRT kernels, all bitwise identical).
    ///
    /// The length check is a hard `assert_eq!`, not a `debug_assert`: a
    /// mismatch would make `zip` silently drop the longer tail and corrupt
    /// results — in `--release` benches of all places — so it must fail
    /// loudly in every profile.
    fn reduce_into(&self, acc: &mut [E], incoming: &[E], side: Side) {
        assert_eq!(
            acc.len(),
            incoming.len(),
            "reduce_into length mismatch: acc {} vs incoming {}",
            acc.len(),
            incoming.len()
        );
        match side {
            Side::Left => {
                for (a, t) in acc.iter_mut().zip(incoming) {
                    *a = self.combine(*t, *a);
                }
            }
            Side::Right => {
                for (a, t) in acc.iter_mut().zip(incoming) {
                    *a = self.combine(*a, *t);
                }
            }
        }
        super::backend::record_scalar(acc.len());
    }

    /// Fused two-incoming reduction: `acc ← t1 ⊙ (t0 ⊙ acc)` element-wise —
    /// exactly two successive [`Side::Left`] `reduce_into` calls collapsed
    /// into one pass. This is the inner-node shape of Algorithm 1: a rank
    /// with two children folds both received blocks into its partial result
    /// every round. Bitwise-identical to the two-call sequence by
    /// construction (same combines, same order), so collectives may use
    /// either form freely.
    fn reduce_into3(&self, acc: &mut [E], t0: &[E], t1: &[E]) {
        assert_eq!(
            acc.len(),
            t0.len(),
            "reduce_into3 length mismatch: acc {} vs t0 {}",
            acc.len(),
            t0.len()
        );
        assert_eq!(
            acc.len(),
            t1.len(),
            "reduce_into3 length mismatch: acc {} vs t1 {}",
            acc.len(),
            t1.len()
        );
        for ((a, x0), x1) in acc.iter_mut().zip(t0).zip(t1) {
            *a = self.combine(*x1, self.combine(*x0, *a));
        }
        super::backend::record_scalar(2 * acc.len());
    }
}

/// The operator vocabulary the CLI / harness can name.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum OpKind {
    Sum,
    Prod,
    Max,
    Min,
}

impl OpKind {
    pub fn parse(s: &str) -> Option<OpKind> {
        match s {
            "sum" => Some(OpKind::Sum),
            "prod" => Some(OpKind::Prod),
            "max" => Some(OpKind::Max),
            "min" => Some(OpKind::Min),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            OpKind::Sum => "sum",
            OpKind::Prod => "prod",
            OpKind::Max => "max",
            OpKind::Min => "min",
        }
    }
}

// ---------------------------------------------------------------------------
// Arithmetic ops (MPI_SUM / MPI_PROD / MPI_MAX / MPI_MIN analogues)
// ---------------------------------------------------------------------------

/// Element-wise addition (`MPI_SUM`). Wrapping for integers, IEEE for floats.
#[derive(Clone, Copy, Default, Debug)]
pub struct SumOp;

/// Element-wise product (`MPI_PROD`).
#[derive(Clone, Copy, Default, Debug)]
pub struct ProdOp;

/// Element-wise maximum (`MPI_MAX`).
#[derive(Clone, Copy, Default, Debug)]
pub struct MaxOp;

/// Element-wise minimum (`MPI_MIN`).
#[derive(Clone, Copy, Default, Debug)]
pub struct MinOp;

/// Implement one arithmetic operator over one concrete element type, with
/// `reduce_into` routed through the pluggable backend layer (scalar / SIMD
/// / PJRT kernels — see [`super::backend`]).
macro_rules! arith_op_impl {
    ($op:ty, $kind:expr, $name:literal, $t:ty, $ident:expr, $combine:expr) => {
        impl ReduceOp<$t> for $op {
            fn identity(&self) -> $t {
                $ident
            }
            fn combine(&self, a: $t, b: $t) -> $t {
                const F: fn($t, $t) -> $t = $combine;
                F(a, b)
            }
            fn commutative(&self) -> bool {
                true
            }
            fn name(&self) -> &'static str {
                $name
            }
            fn reduce_into(&self, acc: &mut [$t], incoming: &[$t], side: Side) {
                super::backend::reduce_arith($kind, acc, incoming, side);
            }
            fn reduce_into3(&self, acc: &mut [$t], t0: &[$t], t1: &[$t]) {
                super::backend::reduce_arith3($kind, acc, t0, t1);
            }
        }
    };
}

macro_rules! arith_ops_int {
    ($($t:ty),*) => {$(
        arith_op_impl!(SumOp, OpKind::Sum, "sum", $t, 0, |a, b| a.wrapping_add(b));
        arith_op_impl!(ProdOp, OpKind::Prod, "prod", $t, 1, |a, b| a.wrapping_mul(b));
        arith_op_impl!(MaxOp, OpKind::Max, "max", $t, <$t>::MIN, |a, b| a.max(b));
        arith_op_impl!(MinOp, OpKind::Min, "min", $t, <$t>::MAX, |a, b| a.min(b));
    )*};
}
arith_ops_int!(i32, i64);

// Float Max/Min use the NaN-propagating, order-stable IEEE-754
// maximum/minimum (`backend::fmax_f32` family), NOT `f32::max`/`min`:
// std's max/min silently *drop* NaN operands, which makes the reduction
// result depend on combine order and breaks the hier≡dpdr bitwise
// equivalence on NaN-laced inputs.
macro_rules! arith_ops_float {
    ($t:ty, $fmax:path, $fmin:path) => {
        arith_op_impl!(SumOp, OpKind::Sum, "sum", $t, 0.0, |a, b| a + b);
        arith_op_impl!(ProdOp, OpKind::Prod, "prod", $t, 1.0, |a, b| a * b);
        arith_op_impl!(MaxOp, OpKind::Max, "max", $t, <$t>::NEG_INFINITY, $fmax);
        arith_op_impl!(MinOp, OpKind::Min, "min", $t, <$t>::INFINITY, $fmin);
    };
}
arith_ops_float!(f32, super::backend::fmax_f32, super::backend::fmin_f32);
arith_ops_float!(f64, super::backend::fmax_f64, super::backend::fmin_f64);

// ---------------------------------------------------------------------------
// Non-commutative test operators
// ---------------------------------------------------------------------------

/// 2×2 wrapping-u32 matrix multiplication — associative, non-commutative.
#[derive(Clone, Copy, Default, Debug)]
pub struct Mat2Op;

impl ReduceOp<Mat2> for Mat2Op {
    fn identity(&self) -> Mat2 {
        Mat2::IDENT
    }
    fn combine(&self, a: Mat2, b: Mat2) -> Mat2 {
        a.mul(b)
    }
    fn name(&self) -> &'static str {
        "mat2"
    }
}

/// Ordered interval concatenation over [`Span`] — associative, and an
/// executable *order witness*: any out-of-order or non-adjacent combination
/// poisons the result, so `allreduce(…) == Span::of(0, p-1)` proves the
/// implementation reduced in exact rank order.
#[derive(Clone, Copy, Default, Debug)]
pub struct SeqCheckOp;

impl ReduceOp<Span> for SeqCheckOp {
    fn identity(&self) -> Span {
        Span::IDENT
    }
    fn combine(&self, a: Span, b: Span) -> Span {
        a.concat(b)
    }
    fn name(&self) -> &'static str {
        "seqcheck"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduce_into_left_vs_right() {
        let op = Mat2Op;
        let a = Mat2([1, 2, 3, 4]);
        let t = Mat2([5, 6, 7, 8]);
        let mut acc = [a];
        op.reduce_into(&mut acc, &[t], Side::Left);
        assert_eq!(acc[0], t.mul(a));
        let mut acc = [a];
        op.reduce_into(&mut acc, &[t], Side::Right);
        assert_eq!(acc[0], a.mul(t));
    }

    #[test]
    fn sum_reduce_into() {
        let op = SumOp;
        let mut acc = vec![1i32, 2, 3];
        op.reduce_into(&mut acc, &[10, 20, 30], Side::Left);
        assert_eq!(acc, vec![11, 22, 33]);
    }

    #[test]
    fn reduce_into3_matches_two_left_reduces() {
        // non-commutative witness: the fused form must equal exactly
        // t1 ⊙ (t0 ⊙ y), i.e. two successive Side::Left reduces
        let op = Mat2Op;
        let y = Mat2([1, 2, 3, 4]);
        let t0 = Mat2([5, 6, 7, 8]);
        let t1 = Mat2([9, 10, 11, 12]);
        let mut two = [y];
        op.reduce_into(&mut two, &[t0], Side::Left);
        op.reduce_into(&mut two, &[t1], Side::Left);
        let mut fused = [y];
        op.reduce_into3(&mut fused, &[t0], &[t1]);
        assert_eq!(fused, two);

        // arithmetic override path (backend-dispatched)
        let mut two = vec![1i32, 2, 3];
        SumOp.reduce_into(&mut two, &[10, 20, 30], Side::Left);
        SumOp.reduce_into(&mut two, &[100, 200, 300], Side::Left);
        let mut fused = vec![1i32, 2, 3];
        SumOp.reduce_into3(&mut fused, &[10, 20, 30], &[100, 200, 300]);
        assert_eq!(fused, two);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn reduce_into3_length_mismatch_is_a_hard_error() {
        let op = Mat2Op;
        let mut acc = [Mat2::IDENT, Mat2::IDENT];
        op.reduce_into3(&mut acc, &[Mat2::IDENT, Mat2::IDENT], &[Mat2::IDENT]);
    }

    #[test]
    fn identities() {
        assert_eq!(ReduceOp::<i32>::identity(&SumOp), 0);
        assert_eq!(ReduceOp::<i32>::identity(&ProdOp), 1);
        assert_eq!(ReduceOp::<i32>::identity(&MaxOp), i32::MIN);
        assert_eq!(ReduceOp::<i32>::identity(&MinOp), i32::MAX);
        assert_eq!(ReduceOp::<f64>::identity(&MaxOp), f64::NEG_INFINITY);
    }

    #[test]
    fn float_ops() {
        assert_eq!(ReduceOp::<f32>::combine(&SumOp, 1.5, 2.5), 4.0);
        assert_eq!(ReduceOp::<f64>::combine(&MinOp, 1.5, 2.5), 1.5);
        assert_eq!(ReduceOp::<f64>::combine(&ProdOp, 3.0, 2.0), 6.0);
    }

    #[test]
    fn float_max_min_propagate_nan_order_stably() {
        // std's f32::max silently drops NaN; ours must propagate it from
        // either side, with canonical bits, so combine order cannot leak
        // into the result.
        for (a, b) in [(f32::NAN, 1.0f32), (1.0, f32::NAN), (f32::NAN, f32::NAN)] {
            assert!(ReduceOp::<f32>::combine(&MaxOp, a, b).is_nan());
            assert!(ReduceOp::<f32>::combine(&MinOp, a, b).is_nan());
            assert_eq!(
                ReduceOp::<f32>::combine(&MaxOp, a, b).to_bits(),
                ReduceOp::<f32>::combine(&MaxOp, b, a).to_bits()
            );
        }
        assert!(ReduceOp::<f64>::combine(&MaxOp, f64::NAN, f64::INFINITY).is_nan());
        assert!(ReduceOp::<f64>::combine(&MinOp, f64::NEG_INFINITY, f64::NAN).is_nan());
        // non-NaN behavior unchanged
        assert_eq!(ReduceOp::<f32>::combine(&MaxOp, 2.0, 3.0), 3.0);
        assert_eq!(ReduceOp::<f64>::combine(&MinOp, 2.0, 3.0), 2.0);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn reduce_into_length_mismatch_is_a_hard_error() {
        // the guard must be a hard assert (not debug_assert): a silent zip
        // truncation in --release corrupts results
        let op = Mat2Op;
        let mut acc = [Mat2::IDENT, Mat2::IDENT];
        op.reduce_into(&mut acc, &[Mat2::IDENT], Side::Left);
    }

    #[test]
    fn opkind_parse() {
        assert_eq!(OpKind::parse("sum"), Some(OpKind::Sum));
        assert_eq!(OpKind::parse("min"), Some(OpKind::Min));
        assert_eq!(OpKind::parse("xor"), None);
        assert_eq!(OpKind::Prod.name(), "prod");
    }

    #[test]
    fn seqcheck_detects_out_of_order() {
        let op = SeqCheckOp;
        let ordered = op.combine(op.combine(Span::rank(0), Span::rank(1)), Span::rank(2));
        assert_eq!(ordered, Span::of(0, 2));
        let swapped = op.combine(Span::rank(1), Span::rank(0));
        assert!(swapped.is_poison());
    }
}
