//! Reduction operators over [`Elem`] slices.

use super::elem::{Elem, Mat2, Span};

/// Which side of ⊙ the *incoming* (received) block stands on.
///
/// Algorithm 1 computes `Y[j] ← t ⊙ Y[j]` for blocks received from children
/// (incoming on the **left**) and `Y[j] ← Y[j] ⊙ t` at the lower-numbered
/// dual root (incoming on the **right**). Getting this wrong is invisible
/// with `MPI_SUM` but breaks non-commutative operators — the test suite
/// covers both sides via [`Mat2Op`] and [`SeqCheckOp`].
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Side {
    /// `acc ← incoming ⊙ acc`
    Left,
    /// `acc ← acc ⊙ incoming`
    Right,
}

/// An associative binary reduction operator over element type `E`.
pub trait ReduceOp<E: Elem>: Send + Sync {
    /// The identity element of ⊙ (also used for padding partial blocks).
    fn identity(&self) -> E;

    /// `a ⊙ b` — order is significant for non-commutative operators.
    fn combine(&self, a: E, b: E) -> E;

    /// Whether ⊙ commutes; purely informational (algorithms never rely on it).
    fn commutative(&self) -> bool {
        false
    }

    /// Stable operator name, used for artifact lookup and reports.
    fn name(&self) -> &'static str;

    /// Element-wise in-place reduction of `incoming` into `acc`.
    ///
    /// Hot path: the default implementation is a plain loop; `SumOp` etc.
    /// override nothing because LLVM auto-vectorizes the loop given the
    /// concrete element type after monomorphization. The PJRT runtime
    /// backend (see `runtime::ReduceEngine`) substitutes an XLA executable
    /// for this call when enabled.
    fn reduce_into(&self, acc: &mut [E], incoming: &[E], side: Side) {
        debug_assert_eq!(acc.len(), incoming.len());
        match side {
            Side::Left => {
                for (a, t) in acc.iter_mut().zip(incoming) {
                    *a = self.combine(*t, *a);
                }
            }
            Side::Right => {
                for (a, t) in acc.iter_mut().zip(incoming) {
                    *a = self.combine(*a, *t);
                }
            }
        }
    }
}

/// The operator vocabulary the CLI / harness can name.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum OpKind {
    Sum,
    Prod,
    Max,
    Min,
}

impl OpKind {
    pub fn parse(s: &str) -> Option<OpKind> {
        match s {
            "sum" => Some(OpKind::Sum),
            "prod" => Some(OpKind::Prod),
            "max" => Some(OpKind::Max),
            "min" => Some(OpKind::Min),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            OpKind::Sum => "sum",
            OpKind::Prod => "prod",
            OpKind::Max => "max",
            OpKind::Min => "min",
        }
    }
}

// ---------------------------------------------------------------------------
// Arithmetic ops (MPI_SUM / MPI_PROD / MPI_MAX / MPI_MIN analogues)
// ---------------------------------------------------------------------------

/// Element-wise addition (`MPI_SUM`). Wrapping for integers, IEEE for floats.
#[derive(Clone, Copy, Default, Debug)]
pub struct SumOp;

/// Element-wise product (`MPI_PROD`).
#[derive(Clone, Copy, Default, Debug)]
pub struct ProdOp;

/// Element-wise maximum (`MPI_MAX`).
#[derive(Clone, Copy, Default, Debug)]
pub struct MaxOp;

/// Element-wise minimum (`MPI_MIN`).
#[derive(Clone, Copy, Default, Debug)]
pub struct MinOp;

macro_rules! arith_ops_int {
    ($($t:ty),*) => {$(
        impl ReduceOp<$t> for SumOp {
            fn identity(&self) -> $t { 0 }
            fn combine(&self, a: $t, b: $t) -> $t { a.wrapping_add(b) }
            fn commutative(&self) -> bool { true }
            fn name(&self) -> &'static str { "sum" }
        }
        impl ReduceOp<$t> for ProdOp {
            fn identity(&self) -> $t { 1 }
            fn combine(&self, a: $t, b: $t) -> $t { a.wrapping_mul(b) }
            fn commutative(&self) -> bool { true }
            fn name(&self) -> &'static str { "prod" }
        }
        impl ReduceOp<$t> for MaxOp {
            fn identity(&self) -> $t { <$t>::MIN }
            fn combine(&self, a: $t, b: $t) -> $t { a.max(b) }
            fn commutative(&self) -> bool { true }
            fn name(&self) -> &'static str { "max" }
        }
        impl ReduceOp<$t> for MinOp {
            fn identity(&self) -> $t { <$t>::MAX }
            fn combine(&self, a: $t, b: $t) -> $t { a.min(b) }
            fn commutative(&self) -> bool { true }
            fn name(&self) -> &'static str { "min" }
        }
    )*};
}
arith_ops_int!(i32, i64);

macro_rules! arith_ops_float {
    ($($t:ty),*) => {$(
        impl ReduceOp<$t> for SumOp {
            fn identity(&self) -> $t { 0.0 }
            fn combine(&self, a: $t, b: $t) -> $t { a + b }
            fn commutative(&self) -> bool { true }
            fn name(&self) -> &'static str { "sum" }
        }
        impl ReduceOp<$t> for ProdOp {
            fn identity(&self) -> $t { 1.0 }
            fn combine(&self, a: $t, b: $t) -> $t { a * b }
            fn commutative(&self) -> bool { true }
            fn name(&self) -> &'static str { "prod" }
        }
        impl ReduceOp<$t> for MaxOp {
            fn identity(&self) -> $t { <$t>::NEG_INFINITY }
            fn combine(&self, a: $t, b: $t) -> $t { a.max(b) }
            fn commutative(&self) -> bool { true }
            fn name(&self) -> &'static str { "max" }
        }
        impl ReduceOp<$t> for MinOp {
            fn identity(&self) -> $t { <$t>::INFINITY }
            fn combine(&self, a: $t, b: $t) -> $t { a.min(b) }
            fn commutative(&self) -> bool { true }
            fn name(&self) -> &'static str { "min" }
        }
    )*};
}
arith_ops_float!(f32, f64);

// ---------------------------------------------------------------------------
// Non-commutative test operators
// ---------------------------------------------------------------------------

/// 2×2 wrapping-u32 matrix multiplication — associative, non-commutative.
#[derive(Clone, Copy, Default, Debug)]
pub struct Mat2Op;

impl ReduceOp<Mat2> for Mat2Op {
    fn identity(&self) -> Mat2 {
        Mat2::IDENT
    }
    fn combine(&self, a: Mat2, b: Mat2) -> Mat2 {
        a.mul(b)
    }
    fn name(&self) -> &'static str {
        "mat2"
    }
}

/// Ordered interval concatenation over [`Span`] — associative, and an
/// executable *order witness*: any out-of-order or non-adjacent combination
/// poisons the result, so `allreduce(…) == Span::of(0, p-1)` proves the
/// implementation reduced in exact rank order.
#[derive(Clone, Copy, Default, Debug)]
pub struct SeqCheckOp;

impl ReduceOp<Span> for SeqCheckOp {
    fn identity(&self) -> Span {
        Span::IDENT
    }
    fn combine(&self, a: Span, b: Span) -> Span {
        a.concat(b)
    }
    fn name(&self) -> &'static str {
        "seqcheck"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduce_into_left_vs_right() {
        let op = Mat2Op;
        let a = Mat2([1, 2, 3, 4]);
        let t = Mat2([5, 6, 7, 8]);
        let mut acc = [a];
        op.reduce_into(&mut acc, &[t], Side::Left);
        assert_eq!(acc[0], t.mul(a));
        let mut acc = [a];
        op.reduce_into(&mut acc, &[t], Side::Right);
        assert_eq!(acc[0], a.mul(t));
    }

    #[test]
    fn sum_reduce_into() {
        let op = SumOp;
        let mut acc = vec![1i32, 2, 3];
        op.reduce_into(&mut acc, &[10, 20, 30], Side::Left);
        assert_eq!(acc, vec![11, 22, 33]);
    }

    #[test]
    fn identities() {
        assert_eq!(ReduceOp::<i32>::identity(&SumOp), 0);
        assert_eq!(ReduceOp::<i32>::identity(&ProdOp), 1);
        assert_eq!(ReduceOp::<i32>::identity(&MaxOp), i32::MIN);
        assert_eq!(ReduceOp::<i32>::identity(&MinOp), i32::MAX);
        assert_eq!(ReduceOp::<f64>::identity(&MaxOp), f64::NEG_INFINITY);
    }

    #[test]
    fn float_ops() {
        assert_eq!(ReduceOp::<f32>::combine(&SumOp, 1.5, 2.5), 4.0);
        assert_eq!(ReduceOp::<f64>::combine(&MinOp, 1.5, 2.5), 1.5);
        assert_eq!(ReduceOp::<f64>::combine(&ProdOp, 3.0, 2.0), 6.0);
    }

    #[test]
    fn opkind_parse() {
        assert_eq!(OpKind::parse("sum"), Some(OpKind::Sum));
        assert_eq!(OpKind::parse("min"), Some(OpKind::Min));
        assert_eq!(OpKind::parse("xor"), None);
        assert_eq!(OpKind::Prod.name(), "prod");
    }

    #[test]
    fn seqcheck_detects_out_of_order() {
        let op = SeqCheckOp;
        let ordered = op.combine(op.combine(Span::rank(0), Span::rank(1)), Span::rank(2));
        assert_eq!(ordered, Span::of(0, 2));
        let swapped = op.combine(Span::rank(1), Span::rank(0));
        assert!(swapped.is_poison());
    }
}
