//! Element types and reduction operators.
//!
//! The paper reduces vectors of `MPI_INT` with `MPI_SUM`, but the algorithm
//! only requires an *associative* (not necessarily commutative) operator ⊙,
//! and its post-order tree construction is specifically designed so that all
//! partial reductions happen in rank order. We therefore keep the operator
//! abstract ([`ReduceOp`]) and ship, besides the MPI-style arithmetic ops,
//! two deliberately non-commutative operators used by the test suite to
//! prove the implementation respects reduction order:
//!
//! * [`Mat2Op`] — 2×2 wrapping integer matrix multiplication;
//! * [`SeqCheckOp`] — interval concatenation over [`Span`], which *poisons*
//!   the result if two non-adjacent rank intervals are ever combined, i.e.
//!   it is an executable witness of "reduced exactly in rank order".

pub mod backend;
pub mod elem;
pub mod reduce;

pub use backend::{ArithElem, BackendStats, ReduceBackend};
pub use elem::{Elem, Mat2, Span};
pub use reduce::{MaxOp, MinOp, OpKind, ProdOp, ReduceOp, SeqCheckOp, Side, SumOp};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sum_is_commutative_mat2_is_not() {
        let s = SumOp;
        assert!(ReduceOp::<i32>::commutative(&s));
        let m = Mat2Op;
        assert!(!ReduceOp::<Mat2>::commutative(&m));
    }
}

pub use reduce::Mat2Op;
