//! Pluggable reduce backends for the arithmetic operators.
//!
//! With the transport copy-free (PR 1) and the hierarchy sharded (PR 2),
//! the `3βm` compute term of the paper's bound lives almost entirely in the
//! block-wise `⊙` of [`ReduceOp::reduce_into`]. This module makes that hot
//! loop *pluggable*: every `reduce_into` of `SumOp` / `ProdOp` / `MaxOp` /
//! `MinOp` over `i32` / `i64` / `f32` / `f64` routes through
//! [`reduce_arith`], which dispatches to one of three kernels:
//!
//! * [`ReduceBackend::Scalar`] — the plain reference loop;
//! * [`ReduceBackend::Simd`] — chunked 16-lane unrolled loops with scalar
//!   tails (stable Rust; fixed-size array chunks give LLVM clean vector
//!   bodies without `portable_simd`);
//! * [`ReduceBackend::Pjrt`] — the AOT-compiled JAX/Pallas kernels via
//!   [`ReduceEngine`](crate::runtime::ReduceEngine), chunked at the
//!   compiled block sizes.
//!
//! Every backend is **bitwise identical** to the scalar path: the kernels
//! are element-wise (lanes never interact), and the float `Max`/`Min`
//! combine is the NaN-propagating, order-stable [`fmax_f32`]-family — so a
//! backend can be swapped under a running collective without perturbing
//! the hier≡dpdr equivalence guarantees (`tests/property.rs` pins this).
//!
//! Selection is per rank thread via [`scope`] (the collectives install the
//! [`RunSpec`](crate::collectives::RunSpec) choice; default
//! [`ReduceBackend::Auto`]), and the fallback order is always
//! Pjrt → Simd → Scalar: an explicitly selected backend that cannot serve
//! a call (missing artifacts, unsupported dtype) degrades to the next one
//! instead of failing. Dispatch outcomes are counted per thread
//! ([`stats`] / [`take_stats`]) and harvested into
//! [`RankMetrics`](crate::comm::RankMetrics) by `run_world`.

use std::cell::{Cell, RefCell};
use std::path::PathBuf;

use super::reduce::{OpKind, Side};
use crate::runtime::{PjrtElem, ReduceEngine};

/// Which kernel executes the block-wise ⊙ of the arithmetic operators.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub enum ReduceBackend {
    /// Policy default: SIMD, with PJRT taking over blocks of at least
    /// [`PJRT_AUTO_MIN_ELEMS`] elements when its artifacts are present.
    #[default]
    Auto,
    /// The plain per-element reference loop.
    Scalar,
    /// Chunk-unrolled stable-Rust vector loops.
    Simd,
    /// AOT-compiled JAX/Pallas kernels through the PJRT engine.
    Pjrt,
}

impl ReduceBackend {
    pub fn parse(s: &str) -> Option<ReduceBackend> {
        match s {
            "auto" => Some(ReduceBackend::Auto),
            "scalar" => Some(ReduceBackend::Scalar),
            "simd" => Some(ReduceBackend::Simd),
            "pjrt" => Some(ReduceBackend::Pjrt),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ReduceBackend::Auto => "auto",
            ReduceBackend::Scalar => "scalar",
            ReduceBackend::Simd => "simd",
            ReduceBackend::Pjrt => "pjrt",
        }
    }
}

/// Smallest block the `Auto` policy hands to PJRT (the largest compiled
/// kernel size): below this the per-call literal-copy + dispatch overhead
/// of the engine outweighs kernel quality, and the SIMD loops win.
pub const PJRT_AUTO_MIN_ELEMS: usize = 131_072;

/// Per-thread dispatch counters (one record per rank thread; `run_world`
/// folds them into that rank's [`RankMetrics`](crate::comm::RankMetrics)).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BackendStats {
    /// Elements fed through ⊙ by any backend.
    pub elems_reduced: u64,
    /// `reduce_into` calls served by the scalar loop.
    pub scalar_hits: u64,
    /// Calls served by the SIMD kernels.
    pub simd_hits: u64,
    /// Calls served by the PJRT engine.
    pub pjrt_hits: u64,
}

thread_local! {
    /// The backend this rank thread currently dispatches to.
    static CHOICE: Cell<ReduceBackend> = const { Cell::new(ReduceBackend::Auto) };
    /// Dispatch counters, harvested per world run.
    static STATS: Cell<BackendStats> = const { Cell::new(BackendStats::new()) };
    /// Artifact directory override for this thread's engine (tests use
    /// this instead of the process-wide `DPDR_ARTIFACTS`).
    static PJRT_DIR: RefCell<Option<PathBuf>> = const { RefCell::new(None) };
    /// Lazily created PJRT engine: `None` = not yet tried,
    /// `Some(None)` = unavailable, `Some(Some(_))` = ready.
    static ENGINE: RefCell<Option<Option<ReduceEngine>>> = const { RefCell::new(None) };
}

impl BackendStats {
    const fn new() -> BackendStats {
        BackendStats {
            elems_reduced: 0,
            scalar_hits: 0,
            simd_hits: 0,
            pjrt_hits: 0,
        }
    }
}

/// Select `choice` for this thread until the returned guard drops (the
/// previous selection is restored — scopes nest).
pub fn scope(choice: ReduceBackend) -> BackendGuard {
    BackendGuard {
        prev: CHOICE.with(|c| c.replace(choice)),
    }
}

/// Scope guard of [`scope`].
pub struct BackendGuard {
    prev: ReduceBackend,
}

impl Drop for BackendGuard {
    fn drop(&mut self) {
        CHOICE.with(|c| c.set(self.prev));
    }
}

/// The backend currently selected on this thread.
pub fn current() -> ReduceBackend {
    CHOICE.with(Cell::get)
}

/// Read this thread's dispatch counters without resetting them.
pub fn stats() -> BackendStats {
    STATS.with(Cell::get)
}

/// Read and reset this thread's dispatch counters.
pub fn take_stats() -> BackendStats {
    STATS.with(|s| s.replace(BackendStats::new()))
}

/// Record a [`ReduceKernel`](crate::obs::EventKind::ReduceKernel) trace
/// event for one dispatched kernel: `aux` = the backend that actually
/// ran, `bytes` = the element count it combined. The virtual stamp is
/// the worker's last transport clock hint (kernels themselves are
/// wall-time work; the γ-charge has its own `Reduce` span). Skipped on
/// threads not bound to a rank.
fn obs_kernel(which: ReduceBackend, elems: usize) {
    let Some(rank) = crate::obs::bound_rank() else {
        return;
    };
    let id = match which {
        ReduceBackend::Scalar => 0,
        ReduceBackend::Simd => 1,
        ReduceBackend::Pjrt => 2,
        ReduceBackend::Auto => 3,
    };
    let ev = crate::obs::Event::new(crate::obs::EventKind::ReduceKernel, rank)
        .bytes(elems as u64)
        .aux(id)
        .at_us(crate::obs::vtime_hint_us())
        .wall(crate::obs::wall_now_ns());
    crate::obs::record(ev);
}

fn record(which: ReduceBackend, elems: usize) {
    if crate::obs::enabled() {
        obs_kernel(which, elems);
    }
    STATS.with(|s| {
        let mut v = s.get();
        v.elems_reduced += elems as u64;
        match which {
            ReduceBackend::Scalar => v.scalar_hits += 1,
            ReduceBackend::Simd => v.simd_hits += 1,
            ReduceBackend::Pjrt => v.pjrt_hits += 1,
            ReduceBackend::Auto => {}
        }
        s.set(v);
    });
}

/// Count a reduction that ran through the default (scalar) `reduce_into`
/// of a non-arithmetic operator, so `elems_reduced` covers every ⊙.
pub(crate) fn record_scalar(elems: usize) {
    record(ReduceBackend::Scalar, elems);
}

/// Point this thread's lazily created PJRT engine at `dir` (`None`
/// restores the `DPDR_ARTIFACTS` / `./artifacts` default). Drops the
/// cached engine so the next PJRT dispatch re-initializes.
pub fn set_pjrt_dir(dir: Option<PathBuf>) {
    PJRT_DIR.with(|d| *d.borrow_mut() = dir);
    ENGINE.with(|e| *e.borrow_mut() = None);
}

/// Run `f` on this thread's engine, creating it on first use. `None` when
/// the engine cannot be constructed (the graceful-fallback signal).
fn with_engine<R>(f: impl FnOnce(&mut ReduceEngine) -> R) -> Option<R> {
    ENGINE.with(|cell| {
        let mut slot = cell.borrow_mut();
        if slot.is_none() {
            let engine = match PJRT_DIR.with(|d| d.borrow().clone()) {
                Some(dir) => ReduceEngine::new(dir),
                None => ReduceEngine::with_default_dir(),
            };
            *slot = Some(engine.ok());
        }
        slot.as_mut().unwrap().as_mut().map(f)
    })
}

// ---------------------------------------------------------------------------
// Order-stable float max/min
// ---------------------------------------------------------------------------

macro_rules! nan_stable_minmax {
    ($fmax:ident, $fmin:ident, $t:ty) => {
        /// IEEE-754 `maximum` semantics: any NaN operand yields the
        /// canonical NaN (never `std`'s NaN-dropping `max`), and
        /// `+0.0 > -0.0` — so the result is bitwise independent of combine
        /// order and the hier≡dpdr equivalence holds on NaN-laced inputs.
        #[inline(always)]
        pub fn $fmax(a: $t, b: $t) -> $t {
            if a.is_nan() || b.is_nan() {
                <$t>::NAN
            } else if a > b {
                a
            } else if b > a {
                b
            } else if a.is_sign_positive() {
                a
            } else {
                b
            }
        }

        /// IEEE-754 `minimum` semantics; see the matching maximum.
        #[inline(always)]
        pub fn $fmin(a: $t, b: $t) -> $t {
            if a.is_nan() || b.is_nan() {
                <$t>::NAN
            } else if a < b {
                a
            } else if b < a {
                b
            } else if a.is_sign_negative() {
                a
            } else {
                b
            }
        }
    };
}

nan_stable_minmax!(fmax_f32, fmin_f32, f32);
nan_stable_minmax!(fmax_f64, fmin_f64, f64);

// ---------------------------------------------------------------------------
// SIMD kernels
// ---------------------------------------------------------------------------

/// Unroll width of the vector kernels, in elements.
const LANES: usize = 16;

/// Apply `acc[i] ← f(incoming[i], acc[i])` over `LANES`-wide fixed-size
/// array chunks with a scalar tail. The arrays give LLVM loop bodies of
/// known trip count over independent lanes, which vectorize on stable
/// Rust; bitwise parity with the scalar path is structural (same `f` per
/// element, lanes never interact).
#[inline(always)]
fn chunked<E: Copy, F: Fn(E, E) -> E>(acc: &mut [E], incoming: &[E], f: F) {
    assert_eq!(
        acc.len(),
        incoming.len(),
        "simd reduce length mismatch: acc {} vs incoming {}",
        acc.len(),
        incoming.len()
    );
    let mut a_chunks = acc.chunks_exact_mut(LANES);
    let mut t_chunks = incoming.chunks_exact(LANES);
    for (a, t) in (&mut a_chunks).zip(&mut t_chunks) {
        let a: &mut [E; LANES] = a.try_into().unwrap();
        let t: &[E; LANES] = t.try_into().unwrap();
        for (x, y) in a.iter_mut().zip(t.iter()) {
            *x = f(*y, *x);
        }
    }
    for (x, y) in a_chunks
        .into_remainder()
        .iter_mut()
        .zip(t_chunks.remainder())
    {
        *x = f(*y, *x);
    }
}

// ---------------------------------------------------------------------------
// Per-element-type backend stacks
// ---------------------------------------------------------------------------

/// Element types with the full backend stack (scalar / SIMD / PJRT) for
/// the four arithmetic operators.
pub trait ArithElem: PjrtElem {
    /// `a ⊙ b` — the scalar reference semantics every backend must
    /// reproduce bitwise.
    fn scalar_combine(kind: OpKind, a: Self, b: Self) -> Self;

    /// Chunk-unrolled in-place kernel: `acc ← incoming ⊙ acc` (Left) or
    /// `acc ← acc ⊙ incoming` (Right).
    fn simd_reduce(kind: OpKind, acc: &mut [Self], incoming: &[Self], side: Side);
}

macro_rules! arith_elem_int {
    ($t:ty) => {
        impl ArithElem for $t {
            #[inline(always)]
            fn scalar_combine(kind: OpKind, a: $t, b: $t) -> $t {
                match kind {
                    OpKind::Sum => a.wrapping_add(b),
                    OpKind::Prod => a.wrapping_mul(b),
                    OpKind::Max => a.max(b),
                    OpKind::Min => a.min(b),
                }
            }

            fn simd_reduce(kind: OpKind, acc: &mut [$t], incoming: &[$t], side: Side) {
                match (kind, side) {
                    (OpKind::Sum, Side::Left) => chunked(acc, incoming, |t, a| t.wrapping_add(a)),
                    (OpKind::Sum, Side::Right) => chunked(acc, incoming, |t, a| a.wrapping_add(t)),
                    (OpKind::Prod, Side::Left) => chunked(acc, incoming, |t, a| t.wrapping_mul(a)),
                    (OpKind::Prod, Side::Right) => chunked(acc, incoming, |t, a| a.wrapping_mul(t)),
                    (OpKind::Max, Side::Left) => chunked(acc, incoming, |t, a| t.max(a)),
                    (OpKind::Max, Side::Right) => chunked(acc, incoming, |t, a| a.max(t)),
                    (OpKind::Min, Side::Left) => chunked(acc, incoming, |t, a| t.min(a)),
                    (OpKind::Min, Side::Right) => chunked(acc, incoming, |t, a| a.min(t)),
                }
            }
        }
    };
}

macro_rules! arith_elem_float {
    ($t:ty, $fmax:ident, $fmin:ident) => {
        impl ArithElem for $t {
            #[inline(always)]
            fn scalar_combine(kind: OpKind, a: $t, b: $t) -> $t {
                match kind {
                    OpKind::Sum => a + b,
                    OpKind::Prod => a * b,
                    OpKind::Max => $fmax(a, b),
                    OpKind::Min => $fmin(a, b),
                }
            }

            fn simd_reduce(kind: OpKind, acc: &mut [$t], incoming: &[$t], side: Side) {
                match (kind, side) {
                    (OpKind::Sum, Side::Left) => chunked(acc, incoming, |t, a| t + a),
                    (OpKind::Sum, Side::Right) => chunked(acc, incoming, |t, a| a + t),
                    (OpKind::Prod, Side::Left) => chunked(acc, incoming, |t, a| t * a),
                    (OpKind::Prod, Side::Right) => chunked(acc, incoming, |t, a| a * t),
                    (OpKind::Max, Side::Left) => chunked(acc, incoming, |t, a| $fmax(t, a)),
                    (OpKind::Max, Side::Right) => chunked(acc, incoming, |t, a| $fmax(a, t)),
                    (OpKind::Min, Side::Left) => chunked(acc, incoming, |t, a| $fmin(t, a)),
                    (OpKind::Min, Side::Right) => chunked(acc, incoming, |t, a| $fmin(a, t)),
                }
            }
        }
    };
}

arith_elem_int!(i32);
arith_elem_int!(i64);
arith_elem_float!(f32, fmax_f32, fmin_f32);
arith_elem_float!(f64, fmax_f64, fmin_f64);

// ---------------------------------------------------------------------------
// Dispatch
// ---------------------------------------------------------------------------

/// Element-wise `acc ← incoming ⊙ acc` (Left) / `acc ← acc ⊙ incoming`
/// (Right) for an arithmetic operator, routed through the backend selected
/// by [`scope`]. This is the hot path behind every
/// `DataBuf::reduce_at` of the collectives.
pub fn reduce_arith<E: ArithElem>(kind: OpKind, acc: &mut [E], incoming: &[E], side: Side) {
    assert_eq!(
        acc.len(),
        incoming.len(),
        "reduce length mismatch: acc {} vs incoming {}",
        acc.len(),
        incoming.len()
    );
    let n = acc.len();
    if n == 0 {
        // void blocks: nothing to dispatch (and no engine probe)
        return;
    }
    match current() {
        ReduceBackend::Scalar => scalar_reduce(kind, acc, incoming, side),
        ReduceBackend::Simd => {
            E::simd_reduce(kind, acc, incoming, side);
            record(ReduceBackend::Simd, n);
        }
        ReduceBackend::Pjrt => {
            if pjrt_reduce(kind, acc, incoming, side) {
                record(ReduceBackend::Pjrt, n);
            } else {
                E::simd_reduce(kind, acc, incoming, side);
                record(ReduceBackend::Simd, n);
            }
        }
        ReduceBackend::Auto => {
            if n >= PJRT_AUTO_MIN_ELEMS && pjrt_reduce(kind, acc, incoming, side) {
                record(ReduceBackend::Pjrt, n);
            } else {
                E::simd_reduce(kind, acc, incoming, side);
                record(ReduceBackend::Simd, n);
            }
        }
    }
}

/// Fused two-incoming `acc ← t1 ⊙ (t0 ⊙ acc)` for an arithmetic operator,
/// routed through the backend selected by [`scope`]. Semantically exactly
/// two successive [`Side::Left`] [`reduce_arith`] calls — and bitwise
/// identical to them on every backend — but one dispatch, and a single
/// kernel launch on PJRT (the `combine3` artifacts). Counts `2n` elements
/// so `elems_reduced` matches the two-call accounting.
pub fn reduce_arith3<E: ArithElem>(kind: OpKind, acc: &mut [E], t0: &[E], t1: &[E]) {
    assert_eq!(
        acc.len(),
        t0.len(),
        "reduce3 length mismatch: acc {} vs t0 {}",
        acc.len(),
        t0.len()
    );
    assert_eq!(
        acc.len(),
        t1.len(),
        "reduce3 length mismatch: acc {} vs t1 {}",
        acc.len(),
        t1.len()
    );
    let n = acc.len();
    if n == 0 {
        return;
    }
    match current() {
        ReduceBackend::Scalar => {
            for ((a, x0), x1) in acc.iter_mut().zip(t0).zip(t1) {
                *a = E::scalar_combine(kind, *x1, E::scalar_combine(kind, *x0, *a));
            }
            record(ReduceBackend::Scalar, 2 * n);
        }
        ReduceBackend::Simd => {
            E::simd_reduce(kind, acc, t0, Side::Left);
            E::simd_reduce(kind, acc, t1, Side::Left);
            record(ReduceBackend::Simd, 2 * n);
        }
        ReduceBackend::Pjrt => {
            if pjrt_reduce3(kind, acc, t0, t1) {
                record(ReduceBackend::Pjrt, 2 * n);
            } else {
                E::simd_reduce(kind, acc, t0, Side::Left);
                E::simd_reduce(kind, acc, t1, Side::Left);
                record(ReduceBackend::Simd, 2 * n);
            }
        }
        ReduceBackend::Auto => {
            if n >= PJRT_AUTO_MIN_ELEMS && pjrt_reduce3(kind, acc, t0, t1) {
                record(ReduceBackend::Pjrt, 2 * n);
            } else {
                E::simd_reduce(kind, acc, t0, Side::Left);
                E::simd_reduce(kind, acc, t1, Side::Left);
                record(ReduceBackend::Simd, 2 * n);
            }
        }
    }
}

fn scalar_reduce<E: ArithElem>(kind: OpKind, acc: &mut [E], incoming: &[E], side: Side) {
    match side {
        Side::Left => {
            for (a, t) in acc.iter_mut().zip(incoming) {
                *a = E::scalar_combine(kind, *t, *a);
            }
        }
        Side::Right => {
            for (a, t) in acc.iter_mut().zip(incoming) {
                *a = E::scalar_combine(kind, *a, *t);
            }
        }
    }
    record(ReduceBackend::Scalar, acc.len());
}

/// Blockwise ⊙ through this thread's PJRT engine. `false` when the engine
/// or the needed artifacts are unavailable, or execution fails — `acc` is
/// untouched and the caller falls back to the SIMD kernel.
fn pjrt_reduce<E: ArithElem>(kind: OpKind, acc: &mut [E], incoming: &[E], side: Side) -> bool {
    let n = acc.len();
    with_engine(|engine| {
        if !engine.supports::<E>(2, kind, n) {
            return false;
        }
        let mut out = vec![E::zero(); n];
        let res = match side {
            Side::Left => engine.combine2::<E>(kind, incoming, acc, &mut out),
            Side::Right => engine.combine2::<E>(kind, acc, incoming, &mut out),
        };
        match res {
            Ok(()) => {
                acc.copy_from_slice(&out);
                true
            }
            Err(_) => false,
        }
    })
    .unwrap_or(false)
}

/// Fused `acc ← t1 ⊙ (t0 ⊙ acc)` through this thread's PJRT engine via the
/// arity-3 `combine3` artifacts. `false` when unavailable — `acc` is
/// untouched and the caller falls back to two SIMD passes.
fn pjrt_reduce3<E: ArithElem>(kind: OpKind, acc: &mut [E], t0: &[E], t1: &[E]) -> bool {
    let n = acc.len();
    with_engine(|engine| {
        if !engine.supports::<E>(3, kind, n) {
            return false;
        }
        let mut out = vec![E::zero(); n];
        match engine.combine3::<E>(kind, t1, t0, acc, &mut out) {
            Ok(()) => {
                acc.copy_from_slice(&out);
                true
            }
            Err(_) => false,
        }
    })
    .unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_name_roundtrip() {
        for b in [
            ReduceBackend::Auto,
            ReduceBackend::Scalar,
            ReduceBackend::Simd,
            ReduceBackend::Pjrt,
        ] {
            assert_eq!(ReduceBackend::parse(b.name()), Some(b));
        }
        assert_eq!(ReduceBackend::parse("gpu"), None);
        assert_eq!(ReduceBackend::default(), ReduceBackend::Auto);
    }

    #[test]
    fn scope_nests_and_restores() {
        assert_eq!(current(), ReduceBackend::Auto);
        {
            let _a = scope(ReduceBackend::Scalar);
            assert_eq!(current(), ReduceBackend::Scalar);
            {
                let _b = scope(ReduceBackend::Simd);
                assert_eq!(current(), ReduceBackend::Simd);
            }
            assert_eq!(current(), ReduceBackend::Scalar);
        }
        assert_eq!(current(), ReduceBackend::Auto);
    }

    #[test]
    fn stats_count_dispatches() {
        let _ = take_stats();
        let _g = scope(ReduceBackend::Simd);
        let mut acc = vec![1i32; 100];
        let inc = vec![2i32; 100];
        reduce_arith(OpKind::Sum, &mut acc, &inc, Side::Left);
        let s = take_stats();
        assert_eq!(s.elems_reduced, 100);
        assert_eq!(s.simd_hits, 1);
        assert_eq!(s.scalar_hits, 0);
        assert_eq!(stats(), BackendStats::default()); // reset
    }

    #[test]
    fn simd_matches_scalar_all_ops_int() {
        let mut vals = Vec::new();
        for i in 0..97i64 {
            vals.push((i * 37 % 41) - 20);
        }
        let inc: Vec<i64> = vals.iter().map(|v| v * 3 - 7).collect();
        for kind in [OpKind::Sum, OpKind::Prod, OpKind::Max, OpKind::Min] {
            for side in [Side::Left, Side::Right] {
                let mut a = vals.clone();
                let mut b = vals.clone();
                i64::simd_reduce(kind, &mut a, &inc, side);
                {
                    let _g = scope(ReduceBackend::Scalar);
                    reduce_arith(kind, &mut b, &inc, side);
                }
                assert_eq!(a, b, "{kind:?} {side:?}");
            }
        }
    }

    #[test]
    fn simd_matches_scalar_f32_bitwise_with_nans() {
        let specials = [
            f32::NAN,
            -f32::NAN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            0.0,
            -0.0,
            1.5,
            -2.25,
        ];
        let base: Vec<f32> = (0..83).map(|i| specials[i % specials.len()]).collect();
        let inc: Vec<f32> = (0..83).map(|i| specials[(i * 5 + 3) % specials.len()]).collect();
        for kind in [OpKind::Sum, OpKind::Prod, OpKind::Max, OpKind::Min] {
            for side in [Side::Left, Side::Right] {
                let mut a = base.clone();
                let mut b = base.clone();
                f32::simd_reduce(kind, &mut a, &inc, side);
                {
                    let _g = scope(ReduceBackend::Scalar);
                    reduce_arith(kind, &mut b, &inc, side);
                }
                let abits: Vec<u32> = a.iter().map(|v| v.to_bits()).collect();
                let bbits: Vec<u32> = b.iter().map(|v| v.to_bits()).collect();
                assert_eq!(abits, bbits, "{kind:?} {side:?}");
            }
        }
    }

    #[test]
    fn nan_stable_max_min_laws() {
        // NaN propagates regardless of side or payload
        assert!(fmax_f32(f32::NAN, 1.0).is_nan());
        assert!(fmax_f32(1.0, f32::NAN).is_nan());
        assert!(fmin_f64(f64::NAN, f64::NEG_INFINITY).is_nan());
        // canonical NaN: bitwise order-independent
        let ab = fmax_f32(-f32::NAN, f32::NAN);
        let ba = fmax_f32(f32::NAN, -f32::NAN);
        assert_eq!(ab.to_bits(), ba.to_bits());
        // signed zero ordering
        assert_eq!(fmax_f32(0.0, -0.0).to_bits(), 0.0f32.to_bits());
        assert_eq!(fmax_f32(-0.0, 0.0).to_bits(), 0.0f32.to_bits());
        assert_eq!(fmin_f32(0.0, -0.0).to_bits(), (-0.0f32).to_bits());
        // plain ordering still works
        assert_eq!(fmax_f64(2.0, 3.0), 3.0);
        assert_eq!(fmin_f64(2.0, 3.0), 2.0);
    }

    #[test]
    fn reduce3_matches_two_left_reduces_all_backends() {
        let base: Vec<f32> = (0..83).map(|i| (i as f32) * 0.5 - 7.0).collect();
        let t0: Vec<f32> = (0..83).map(|i| (i as f32) * 1.25 + 1.0).collect();
        let t1: Vec<f32> = (0..83).map(|i| 11.0 - (i as f32)).collect();
        for kind in [OpKind::Sum, OpKind::Prod, OpKind::Max, OpKind::Min] {
            let mut want = base.clone();
            {
                let _g = scope(ReduceBackend::Scalar);
                reduce_arith(kind, &mut want, &t0, Side::Left);
                reduce_arith(kind, &mut want, &t1, Side::Left);
            }
            for backend in [
                ReduceBackend::Scalar,
                ReduceBackend::Simd,
                ReduceBackend::Pjrt, // no artifacts in tests: exercises fallback
                ReduceBackend::Auto,
            ] {
                let mut got = base.clone();
                {
                    let _g = scope(backend);
                    reduce_arith3(kind, &mut got, &t0, &t1);
                }
                let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
                let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                assert_eq!(gb, wb, "{kind:?} {backend:?}");
            }
        }
    }

    #[test]
    fn reduce3_counts_two_call_equivalent_elems() {
        let _ = take_stats();
        let _g = scope(ReduceBackend::Simd);
        let mut acc = vec![1i32; 50];
        reduce_arith3(OpKind::Sum, &mut acc, &vec![2i32; 50], &vec![3i32; 50]);
        let s = take_stats();
        assert_eq!(s.elems_reduced, 100, "2n: same accounting as two calls");
        assert_eq!(acc, vec![6i32; 50]);
    }

    #[test]
    #[should_panic(expected = "reduce length mismatch")]
    fn length_mismatch_panics_in_release_too() {
        let mut acc = vec![1i32; 4];
        reduce_arith(OpKind::Sum, &mut acc, &[1, 2, 3], Side::Left);
    }

    #[test]
    fn pjrt_without_artifacts_falls_back_to_simd() {
        set_pjrt_dir(Some(std::path::PathBuf::from("/nonexistent/artifacts")));
        let _ = take_stats();
        let _g = scope(ReduceBackend::Pjrt);
        let mut acc = vec![1.0f64; 33];
        let inc = vec![2.0f64; 33];
        reduce_arith(OpKind::Sum, &mut acc, &inc, Side::Left);
        assert_eq!(acc, vec![3.0f64; 33]);
        let s = take_stats();
        assert_eq!(s.pjrt_hits, 0);
        assert_eq!(s.simd_hits, 1);
        set_pjrt_dir(None);
    }
}
