//! Element types that can flow through the collectives.

use std::fmt::Debug;

/// An element of the vectors being reduced.
///
/// `BYTES` feeds the cost model (the β term is per byte on the wire);
/// `zero()` provides a fill value for receive buffers (it is *not* the
/// reduction identity — that lives on the operator).
pub trait Elem: Copy + Send + Sync + PartialEq + Debug + 'static {
    /// Wire size of one element in bytes.
    const BYTES: usize;
    /// Short dtype name used for artifact lookup and table headers.
    const DTYPE: &'static str;
    /// A fill value for freshly allocated buffers.
    fn zero() -> Self;
}

impl Elem for i32 {
    const BYTES: usize = 4;
    const DTYPE: &'static str = "int32";
    fn zero() -> Self {
        0
    }
}

impl Elem for i64 {
    const BYTES: usize = 8;
    const DTYPE: &'static str = "int64";
    fn zero() -> Self {
        0
    }
}

impl Elem for f32 {
    const BYTES: usize = 4;
    const DTYPE: &'static str = "float32";
    fn zero() -> Self {
        0.0
    }
}

impl Elem for f64 {
    const BYTES: usize = 8;
    const DTYPE: &'static str = "float64";
    fn zero() -> Self {
        0.0
    }
}

/// A 2×2 matrix over wrapping u32 — the classic example of an associative,
/// non-commutative monoid. Used by tests to verify reduction ordering.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct Mat2(pub [u32; 4]);

impl Mat2 {
    /// Identity matrix.
    pub const IDENT: Mat2 = Mat2([1, 0, 0, 1]);

    /// Wrapping matrix product `self * rhs`.
    pub fn mul(self, rhs: Mat2) -> Mat2 {
        let a = self.0;
        let b = rhs.0;
        Mat2([
            a[0].wrapping_mul(b[0]).wrapping_add(a[1].wrapping_mul(b[2])),
            a[0].wrapping_mul(b[1]).wrapping_add(a[1].wrapping_mul(b[3])),
            a[2].wrapping_mul(b[0]).wrapping_add(a[3].wrapping_mul(b[2])),
            a[2].wrapping_mul(b[1]).wrapping_add(a[3].wrapping_mul(b[3])),
        ])
    }
}

impl Elem for Mat2 {
    const BYTES: usize = 16;
    const DTYPE: &'static str = "mat2u32";
    fn zero() -> Self {
        Mat2([0; 4])
    }
}

/// A contiguous rank interval `[lo, hi]`, or the poison / identity markers.
///
/// `SeqCheckOp` concatenates adjacent intervals and poisons everything else,
/// so a final value of `Span::of(0, p-1)` proves the reduction visited the
/// ranks in exactly ascending order using only associativity.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct Span {
    pub lo: u32,
    pub hi: u32,
}

impl Span {
    /// The identity element (empty interval).
    pub const IDENT: Span = Span {
        lo: u32::MAX,
        hi: u32::MAX,
    };
    /// The absorbing poison element (order violation witness).
    pub const POISON: Span = Span { lo: u32::MAX - 1, hi: u32::MAX - 1 };

    /// Interval `[lo, hi]`.
    pub fn of(lo: u32, hi: u32) -> Span {
        Span { lo, hi }
    }

    /// Singleton interval for one rank.
    pub fn rank(r: u32) -> Span {
        Span::of(r, r)
    }

    pub fn is_ident(self) -> bool {
        self == Span::IDENT
    }

    pub fn is_poison(self) -> bool {
        self == Span::POISON
    }

    /// Ordered concatenation; poison on non-adjacency.
    pub fn concat(self, rhs: Span) -> Span {
        if self.is_poison() || rhs.is_poison() {
            return Span::POISON;
        }
        if self.is_ident() {
            return rhs;
        }
        if rhs.is_ident() {
            return self;
        }
        if self.hi.wrapping_add(1) == rhs.lo {
            Span::of(self.lo, rhs.hi)
        } else {
            Span::POISON
        }
    }
}

impl Elem for Span {
    const BYTES: usize = 8;
    const DTYPE: &'static str = "span";
    fn zero() -> Self {
        Span::IDENT
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat2_identity_and_assoc() {
        let a = Mat2([1, 2, 3, 4]);
        let b = Mat2([5, 6, 7, 8]);
        let c = Mat2([2, 0, 1, 2]);
        assert_eq!(a.mul(Mat2::IDENT), a);
        assert_eq!(Mat2::IDENT.mul(a), a);
        assert_eq!(a.mul(b).mul(c), a.mul(b.mul(c)));
        assert_ne!(a.mul(b), b.mul(a)); // non-commutative
    }

    #[test]
    fn span_concat_rules() {
        let a = Span::of(0, 3);
        let b = Span::of(4, 9);
        assert_eq!(a.concat(b), Span::of(0, 9));
        assert_eq!(b.concat(a), Span::POISON); // wrong order
        assert_eq!(a.concat(Span::IDENT), a);
        assert_eq!(Span::IDENT.concat(b), b);
        assert_eq!(Span::POISON.concat(a), Span::POISON);
        // gap poisons
        assert_eq!(Span::of(0, 1).concat(Span::of(3, 4)), Span::POISON);
    }

    #[test]
    fn span_assoc_on_adjacent_chain() {
        let (a, b, c) = (Span::rank(0), Span::rank(1), Span::rank(2));
        assert_eq!(a.concat(b).concat(c), a.concat(b.concat(c)));
        assert_eq!(a.concat(b).concat(c), Span::of(0, 2));
    }
}
