//! [`ReduceOp`] adapters over the PJRT engine, so the collectives can run
//! their block reductions through the AOT-compiled JAX/Pallas kernels with
//! zero changes to algorithm code.

use std::sync::{Arc, Mutex};

use super::engine::ReduceEngine;
use crate::ops::{OpKind, ReduceOp, Side};

/// A `Send` cell around the engine.
///
/// SAFETY: the `xla` crate's `PjRtClient` wraps the C++ client in an `Rc`,
/// which makes it `!Send`, but the underlying PJRT CPU client is
/// thread-safe and the `Rc` reference counter is only ever touched while
/// the owning [`Mutex`] is held (we never clone the client out of the
/// cell), so moving the cell between threads is sound.
pub struct EngineCell(pub ReduceEngine);
unsafe impl Send for EngineCell {}

/// Which implementation performs the block-wise ⊙.
#[derive(Clone)]
pub enum ReduceBackend {
    /// The plain (auto-vectorized) Rust loop.
    Native,
    /// The AOT-compiled JAX/Pallas kernel via PJRT.
    Pjrt(Arc<Mutex<EngineCell>>),
}

impl ReduceBackend {
    /// A PJRT backend over the default artifact directory.
    pub fn pjrt_default() -> crate::error::Result<ReduceBackend> {
        Ok(ReduceBackend::Pjrt(Arc::new(Mutex::new(EngineCell(
            ReduceEngine::with_default_dir()?,
        )))))
    }

    pub fn name(&self) -> &'static str {
        match self {
            ReduceBackend::Native => "native",
            ReduceBackend::Pjrt(_) => "pjrt",
        }
    }
}

/// An i32 reduction operator whose `reduce_into` dispatches to the chosen
/// backend. Scalar `combine` is always native (tree bookkeeping only).
#[derive(Clone)]
pub struct PjrtOp {
    kind: OpKind,
    backend: ReduceBackend,
}

impl PjrtOp {
    pub fn new(kind: OpKind, backend: ReduceBackend) -> PjrtOp {
        PjrtOp { kind, backend }
    }

    pub fn kind(&self) -> OpKind {
        self.kind
    }

    fn scalar(&self, a: i32, b: i32) -> i32 {
        match self.kind {
            OpKind::Sum => a.wrapping_add(b),
            OpKind::Prod => a.wrapping_mul(b),
            OpKind::Max => a.max(b),
            OpKind::Min => a.min(b),
        }
    }
}

impl ReduceOp<i32> for PjrtOp {
    fn identity(&self) -> i32 {
        match self.kind {
            OpKind::Sum => 0,
            OpKind::Prod => 1,
            OpKind::Max => i32::MIN,
            OpKind::Min => i32::MAX,
        }
    }

    fn combine(&self, a: i32, b: i32) -> i32 {
        self.scalar(a, b)
    }

    fn commutative(&self) -> bool {
        true
    }

    fn name(&self) -> &'static str {
        self.kind.name()
    }

    fn reduce_into(&self, acc: &mut [i32], incoming: &[i32], side: Side) {
        match &self.backend {
            ReduceBackend::Native => {
                // the default element loop (side matters only for
                // non-commutative ops; these four are commutative)
                match side {
                    Side::Left => {
                        for (a, t) in acc.iter_mut().zip(incoming) {
                            *a = self.scalar(*t, *a);
                        }
                    }
                    Side::Right => {
                        for (a, t) in acc.iter_mut().zip(incoming) {
                            *a = self.scalar(*a, *t);
                        }
                    }
                }
            }
            ReduceBackend::Pjrt(engine) => {
                let mut cell = engine.lock().unwrap();
                let engine = &mut cell.0;
                // combine2(lhs, rhs) = lhs ⊙ rhs
                let (lhs, rhs): (&[i32], Vec<i32>) = match side {
                    Side::Left => (incoming, acc.to_vec()),
                    Side::Right => {
                        let a = acc.to_vec();
                        // borrow juggling: lhs must outlive; use acc copy as lhs
                        let mut out = vec![0i32; acc.len()];
                        engine
                            .combine2_i32(self.kind, &a, incoming, &mut out)
                            .expect("pjrt combine2 failed");
                        acc.copy_from_slice(&out);
                        return;
                    }
                };
                let mut out = vec![0i32; acc.len()];
                engine
                    .combine2_i32(self.kind, lhs, &rhs, &mut out)
                    .expect("pjrt combine2 failed");
                acc.copy_from_slice(&out);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_backend_matches_sum() {
        let op = PjrtOp::new(OpKind::Sum, ReduceBackend::Native);
        let mut acc = vec![1, 2, 3];
        op.reduce_into(&mut acc, &[10, 20, 30], Side::Left);
        assert_eq!(acc, vec![11, 22, 33]);
        assert_eq!(op.identity(), 0);
        assert_eq!(op.combine(3, 4), 7);
        assert_eq!(ReduceBackend::Native.name(), "native");
    }

    #[test]
    fn min_max_prod_native() {
        for (kind, a, b, want) in [
            (OpKind::Min, 3, -1, -1),
            (OpKind::Max, 3, -1, 3),
            (OpKind::Prod, 3, -2, -6),
        ] {
            let op = PjrtOp::new(kind, ReduceBackend::Native);
            let mut acc = vec![a];
            op.reduce_into(&mut acc, &[b], Side::Left);
            assert_eq!(acc, vec![want], "{kind:?}");
        }
    }
}
