//! The PJRT runtime: loads the AOT-compiled JAX/Pallas reduction kernels
//! (HLO text under `artifacts/`, produced once by `make artifacts`) and
//! executes them from the Rust hot path. Python never runs at request time.
//!
//! The artifacts implement the block-wise `MPI_Reduce_local` of the
//! algorithm — `combine2(x, y) = x ⊙ y` element-wise over a fixed-size
//! block — for each (arity, op, dtype, block size) variant. Arbitrary
//! block lengths are handled by padding with the operator identity up to
//! the smallest compiled size (see [`ReduceEngine::pick_size`]) and
//! chunking at the largest.
//!
//! The engine is wired into the collectives through the pluggable backend
//! layer ([`ops::backend`](crate::ops::backend)): select it with
//! `--reduce-backend pjrt` (or `RunSpec::reduce_backend`), and it serves
//! large blocks under the `auto` policy whenever its artifacts are
//! present, falling back to the SIMD kernels otherwise.
//!
//! The [`xla`] module is a self-contained stand-in for the `xla` crate
//! (the offline build cannot link the real PJRT C++ client): it interprets
//! the combine-kernel HLO text with bitwise-identical semantics. Swapping
//! the real crate back in is a dependency change only.

pub mod engine;
pub mod xla;

pub use engine::{artifact_name, PjrtElem, ReduceEngine, COMPILED_SIZES};
