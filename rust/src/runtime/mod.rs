//! The PJRT runtime: loads the AOT-compiled JAX/Pallas reduction kernels
//! (HLO text under `artifacts/`, produced once by `make artifacts`) and
//! executes them from the Rust hot path. Python never runs at request time.
//!
//! The artifacts implement the block-wise `MPI_Reduce_local` of the
//! algorithm — `combine2(x, y) = x ⊙ y` element-wise over a fixed-size
//! block — for each (arity, op, dtype, block size) variant. Arbitrary
//! block lengths are handled by padding with the operator identity up to
//! the smallest compiled size (see [`ReduceEngine::pick_size`]).

pub mod engine;
pub mod ops;

pub use engine::{artifact_name, ReduceEngine, COMPILED_SIZES};
pub use ops::{EngineCell, PjrtOp, ReduceBackend};
