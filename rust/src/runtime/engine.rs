//! PJRT engine: artifact loading, compilation caching, execution.

use std::borrow::Cow;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use super::xla;
use crate::error::{Error, Result};
use crate::ops::{Elem, OpKind};

/// Block sizes the AOT pipeline compiles kernels for (elements). Must stay
/// in sync with `python/compile/aot.py::SIZES` (ascending) — pinned by the
/// `compiled_sizes_match_python_aot_pipeline` test in
/// `tests/pjrt_runtime.rs`.
pub const COMPILED_SIZES: [usize; 3] = [1_024, 16_384, 131_072];

/// Canonical artifact stem for a kernel variant, e.g.
/// `combine2_sum_int32_16384`.
pub fn artifact_name(arity: usize, op: OpKind, dtype: &str, n: usize) -> String {
    format!("combine{arity}_{}_{dtype}_{n}", op.name())
}

/// Element types the engine can feed through compiled kernels: the
/// artifact dtype is `Elem::DTYPE`, and `op_identity` provides the padding
/// value for partial blocks.
pub trait PjrtElem: Elem + xla::NativeType {
    /// The identity of ⊙ (used to pad a partial block up to the compiled
    /// size without perturbing the result).
    fn op_identity(op: OpKind) -> Self;
}

macro_rules! pjrt_elem_int {
    ($t:ty) => {
        impl PjrtElem for $t {
            fn op_identity(op: OpKind) -> $t {
                match op {
                    OpKind::Sum => 0,
                    OpKind::Prod => 1,
                    OpKind::Max => <$t>::MIN,
                    OpKind::Min => <$t>::MAX,
                }
            }
        }
    };
}

macro_rules! pjrt_elem_float {
    ($t:ty) => {
        impl PjrtElem for $t {
            fn op_identity(op: OpKind) -> $t {
                match op {
                    OpKind::Sum => 0.0,
                    OpKind::Prod => 1.0,
                    OpKind::Max => <$t>::NEG_INFINITY,
                    OpKind::Min => <$t>::INFINITY,
                }
            }
        }
    };
}

pjrt_elem_int!(i32);
pjrt_elem_int!(i64);
pjrt_elem_float!(f32);
pjrt_elem_float!(f64);

/// A PJRT CPU client plus a cache of compiled executables.
pub struct ReduceEngine {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Artifact-file presence, stat'd at most once per stem — the backend
    /// layer probes availability on the hot path.
    present: HashMap<String, bool>,
}

impl ReduceEngine {
    /// Create an engine reading artifacts from `dir`.
    pub fn new<P: AsRef<Path>>(dir: P) -> Result<ReduceEngine> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| Error::Runtime(format!("PjRtClient::cpu: {e}")))?;
        Ok(ReduceEngine {
            client,
            dir: dir.as_ref().to_path_buf(),
            cache: HashMap::new(),
            present: HashMap::new(),
        })
    }

    /// Engine over `$DPDR_ARTIFACTS` or `./artifacts`.
    pub fn with_default_dir() -> Result<ReduceEngine> {
        let dir = std::env::var("DPDR_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        ReduceEngine::new(dir)
    }

    /// The artifact directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// True if the artifact directory contains the given kernel.
    pub fn has_artifact(&self, stem: &str) -> bool {
        self.dir.join(format!("{stem}.hlo.txt")).is_file()
    }

    /// [`ReduceEngine::has_artifact`] with the answer memoized, so the
    /// per-call availability probe of the backend layer costs a map lookup
    /// instead of a stat.
    fn artifact_present(&mut self, stem: &str) -> bool {
        if let Some(&p) = self.present.get(stem) {
            return p;
        }
        let p = self.has_artifact(stem);
        self.present.insert(stem.to_string(), p);
        p
    }

    /// True when every chunk of a length-`len`, arity-`arity` combine for
    /// `E` has its compiled artifact present — the backend layer's
    /// graceful-fallback probe.
    pub fn supports<E: PjrtElem>(&mut self, arity: usize, op: OpKind, len: usize) -> bool {
        let max = *COMPILED_SIZES.last().unwrap();
        let mut lo = 0;
        while lo < len {
            let hi = (lo + max).min(len);
            let stem = artifact_name(arity, op, E::DTYPE, ReduceEngine::pick_size(hi - lo));
            if !self.artifact_present(&stem) {
                return false;
            }
            lo = hi;
        }
        true
    }

    /// The smallest compiled size ≥ `len`, or the largest available if
    /// `len` exceeds them all (callers then chunk).
    pub fn pick_size(len: usize) -> usize {
        for &s in &COMPILED_SIZES {
            if len <= s {
                return s;
            }
        }
        *COMPILED_SIZES.last().unwrap()
    }

    /// Load (and cache) the executable for `stem`. A load *failure* is
    /// memoized as the artifact being unusable (`supports` turns false),
    /// so a present-but-rejected artifact — e.g. real Pallas output under
    /// the offline stand-in — costs one file read, not one per reduce
    /// call on the hot path.
    pub fn load(&mut self, stem: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(stem) {
            let path = self.dir.join(format!("{stem}.hlo.txt"));
            let loaded = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| Error::Runtime(format!("loading {}: {e}", path.display())))
                .and_then(|proto| {
                    let comp = xla::XlaComputation::from_proto(&proto);
                    self.client
                        .compile(&comp)
                        .map_err(|e| Error::Runtime(format!("compiling {stem}: {e}")))
                });
            let exe = match loaded {
                Ok(exe) => exe,
                Err(e) => {
                    self.present.insert(stem.to_string(), false);
                    return Err(e);
                }
            };
            self.cache.insert(stem.to_string(), exe);
        }
        Ok(self.cache.get(stem).unwrap())
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }

    /// Execute `out ← lhs ⊙ rhs` element-wise via the compiled `combine2`
    /// kernel for `E`, chunking at the largest compiled size and padding
    /// partial chunks with the operator identity. `lhs`/`rhs`/`out` must
    /// have equal length.
    pub fn combine2<E: PjrtElem>(
        &mut self,
        op: OpKind,
        lhs: &[E],
        rhs: &[E],
        out: &mut [E],
    ) -> Result<()> {
        assert_eq!(lhs.len(), rhs.len(), "combine2 operand length mismatch");
        assert_eq!(lhs.len(), out.len(), "combine2 output length mismatch");
        let ident = E::op_identity(op);
        run_chunks(lhs.len(), |lo, hi, n| {
            let a = padded(&lhs[lo..hi], n, ident);
            let b = padded(&rhs[lo..hi], n, ident);
            let stem = artifact_name(2, op, E::DTYPE, n);
            let exe = self.load(&stem)?;
            let result = exec1(
                exe,
                &[xla::Literal::vec1(a.as_ref()), xla::Literal::vec1(b.as_ref())],
            )?;
            let v = result
                .to_vec::<E>()
                .map_err(|e| Error::Runtime(format!("to_vec: {e}")))?;
            out[lo..hi].copy_from_slice(&v[..hi - lo]);
            Ok(())
        })
    }

    /// The fused 3-input kernel `t1 ⊙ (t0 ⊙ y)` of the inner tree node
    /// (one kernel call instead of two).
    pub fn combine3<E: PjrtElem>(
        &mut self,
        op: OpKind,
        t1: &[E],
        t0: &[E],
        y: &[E],
        out: &mut [E],
    ) -> Result<()> {
        assert_eq!(t0.len(), y.len(), "combine3 operand length mismatch");
        assert_eq!(t1.len(), y.len(), "combine3 operand length mismatch");
        assert_eq!(out.len(), y.len(), "combine3 output length mismatch");
        let ident = E::op_identity(op);
        run_chunks(y.len(), |lo, hi, n| {
            let a = padded(&t1[lo..hi], n, ident);
            let b = padded(&t0[lo..hi], n, ident);
            let c = padded(&y[lo..hi], n, ident);
            let stem = artifact_name(3, op, E::DTYPE, n);
            let exe = self.load(&stem)?;
            let result = exec1(
                exe,
                &[
                    xla::Literal::vec1(a.as_ref()),
                    xla::Literal::vec1(b.as_ref()),
                    xla::Literal::vec1(c.as_ref()),
                ],
            )?;
            let v = result
                .to_vec::<E>()
                .map_err(|e| Error::Runtime(format!("to_vec: {e}")))?;
            out[lo..hi].copy_from_slice(&v[..hi - lo]);
            Ok(())
        })
    }
}

/// Drive `f(lo, hi, compiled_size)` over chunks of at most the largest
/// compiled size.
fn run_chunks<F>(len: usize, mut f: F) -> Result<()>
where
    F: FnMut(usize, usize, usize) -> Result<()>,
{
    if len == 0 {
        return Ok(());
    }
    let max = *COMPILED_SIZES.last().unwrap();
    let mut lo = 0;
    while lo < len {
        let hi = (lo + max).min(len);
        let n = ReduceEngine::pick_size(hi - lo);
        f(lo, hi, n)?;
        lo = hi;
    }
    Ok(())
}

/// Execute and unwrap the single tupled output as a Literal.
fn exec1(exe: &xla::PjRtLoadedExecutable, args: &[xla::Literal]) -> Result<xla::Literal> {
    let outs = exe
        .execute::<xla::Literal>(args)
        .map_err(|e| Error::Runtime(format!("execute: {e}")))?;
    let lit = outs[0][0]
        .to_literal_sync()
        .map_err(|e| Error::Runtime(format!("to_literal_sync: {e}")))?;
    // aot.py lowers with return_tuple=True → a 1-tuple
    lit.to_tuple1()
        .map_err(|e| Error::Runtime(format!("to_tuple1: {e}")))
}

/// Borrow the slice when it already matches the compiled size; otherwise
/// pad a copy with the operator identity (perf: the exact-size case — the
/// steady state for full pipeline blocks — skips one buffer copy per
/// operand per call).
fn padded<E: Elem>(src: &[E], n: usize, ident: E) -> Cow<'_, [E]> {
    if src.len() == n {
        Cow::Borrowed(src)
    } else {
        let mut v = Vec::with_capacity(n);
        v.extend_from_slice(src);
        v.resize(n, ident);
        Cow::Owned(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_naming() {
        assert_eq!(
            artifact_name(2, OpKind::Sum, "int32", 16_384),
            "combine2_sum_int32_16384"
        );
        assert_eq!(
            artifact_name(3, OpKind::Max, "float32", 1_024),
            "combine3_max_float32_1024"
        );
    }

    #[test]
    fn size_picking() {
        assert_eq!(ReduceEngine::pick_size(0), 1_024);
        assert_eq!(ReduceEngine::pick_size(1_024), 1_024);
        assert_eq!(ReduceEngine::pick_size(1_025), 16_384);
        assert_eq!(ReduceEngine::pick_size(16_000), 16_384);
        assert_eq!(ReduceEngine::pick_size(1 << 20), 131_072);
    }

    #[test]
    fn identities() {
        assert_eq!(<i32 as PjrtElem>::op_identity(OpKind::Sum), 0);
        assert_eq!(<i32 as PjrtElem>::op_identity(OpKind::Min), i32::MAX);
        assert_eq!(<i64 as PjrtElem>::op_identity(OpKind::Max), i64::MIN);
        assert_eq!(<f32 as PjrtElem>::op_identity(OpKind::Max), f32::NEG_INFINITY);
        assert_eq!(<f64 as PjrtElem>::op_identity(OpKind::Prod), 1.0);
    }

    #[test]
    fn padding() {
        assert_eq!(padded(&[1, 2], 4, 0).as_ref(), &[1, 2, 0, 0]);
        assert_eq!(padded(&[1.0f32], 2, 9.0).as_ref(), &[1.0, 9.0]);
        // exact size borrows (no copy)
        assert!(matches!(padded(&[1, 2], 2, 0), Cow::Borrowed(_)));
    }

    #[test]
    fn supports_is_false_without_artifacts() {
        let mut engine = ReduceEngine::new("/nonexistent/artifact/dir").unwrap();
        assert!(!engine.supports::<i32>(2, OpKind::Sum, 1_000));
        // zero-length combines need no artifact at all
        assert!(engine.supports::<i32>(2, OpKind::Sum, 0));
        // and the probe is memoized
        assert!(!engine.supports::<i32>(2, OpKind::Sum, 1_000));
    }
}
