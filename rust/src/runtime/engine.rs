//! PJRT engine: artifact loading, compilation caching, execution.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::ops::OpKind;

/// Block sizes the AOT pipeline compiles kernels for (elements). Must stay
/// in sync with `python/compile/aot.py::SIZES`; ascending.
pub const COMPILED_SIZES: [usize; 3] = [1_024, 16_384, 131_072];

/// Canonical artifact stem for a kernel variant, e.g.
/// `combine2_sum_int32_16384`.
pub fn artifact_name(arity: usize, op: OpKind, dtype: &str, n: usize) -> String {
    format!("combine{arity}_{}_{dtype}_{n}", op.name())
}

/// A PJRT CPU client plus a cache of compiled executables.
pub struct ReduceEngine {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl ReduceEngine {
    /// Create an engine reading artifacts from `dir`.
    pub fn new<P: AsRef<Path>>(dir: P) -> Result<ReduceEngine> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PjRtClient::cpu: {e}")))?;
        Ok(ReduceEngine {
            client,
            dir: dir.as_ref().to_path_buf(),
            cache: HashMap::new(),
        })
    }

    /// Engine over `$DPDR_ARTIFACTS` or `./artifacts`.
    pub fn with_default_dir() -> Result<ReduceEngine> {
        let dir = std::env::var("DPDR_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        ReduceEngine::new(dir)
    }

    /// The artifact directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// True if the artifact directory contains the given kernel.
    pub fn has_artifact(&self, stem: &str) -> bool {
        self.dir.join(format!("{stem}.hlo.txt")).is_file()
    }

    /// The smallest compiled size ≥ `len`, or the largest available if
    /// `len` exceeds them all (callers then chunk).
    pub fn pick_size(len: usize) -> usize {
        for &s in &COMPILED_SIZES {
            if len <= s {
                return s;
            }
        }
        *COMPILED_SIZES.last().unwrap()
    }

    /// Load (and cache) the executable for `stem`.
    pub fn load(&mut self, stem: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(stem) {
            let path = self.dir.join(format!("{stem}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(&path).map_err(|e| {
                Error::Runtime(format!("loading {}: {e}", path.display()))
            })?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| Error::Runtime(format!("compiling {stem}: {e}")))?;
            self.cache.insert(stem.to_string(), exe);
        }
        Ok(self.cache.get(stem).unwrap())
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }

    /// Execute `acc ← lhs ⊙ rhs` element-wise over i32 blocks via the
    /// compiled `combine2` kernel, padding to the compiled size with the
    /// operator identity. `lhs`/`rhs` must have equal length; the result is
    /// written into `out` (same length).
    pub fn combine2_i32(
        &mut self,
        op: OpKind,
        lhs: &[i32],
        rhs: &[i32],
        out: &mut [i32],
    ) -> Result<()> {
        debug_assert_eq!(lhs.len(), rhs.len());
        debug_assert_eq!(lhs.len(), out.len());
        let ident = identity_i32(op);
        self.run_chunks(op, "int32", lhs.len(), |eng, lo, hi, n| {
            let a = padded_i32(&lhs[lo..hi], n, ident);
            let b = padded_i32(&rhs[lo..hi], n, ident);
            let stem = artifact_name(2, op, "int32", n);
            let exe = eng.load(&stem)?;
            let la = xla::Literal::vec1(&a);
            let lb = xla::Literal::vec1(&b);
            let result = exec1(exe, &[la, lb])?;
            let v = result
                .to_vec::<i32>()
                .map_err(|e| Error::Runtime(format!("to_vec: {e}")))?;
            out[lo..hi].copy_from_slice(&v[..hi - lo]);
            Ok(())
        })
    }

    /// Same for f32.
    pub fn combine2_f32(
        &mut self,
        op: OpKind,
        lhs: &[f32],
        rhs: &[f32],
        out: &mut [f32],
    ) -> Result<()> {
        debug_assert_eq!(lhs.len(), rhs.len());
        debug_assert_eq!(lhs.len(), out.len());
        let ident = identity_f32(op);
        self.run_chunks(op, "float32", lhs.len(), |eng, lo, hi, n| {
            let a = padded_f32(&lhs[lo..hi], n, ident);
            let b = padded_f32(&rhs[lo..hi], n, ident);
            let stem = artifact_name(2, op, "float32", n);
            let exe = eng.load(&stem)?;
            let la = xla::Literal::vec1(&a);
            let lb = xla::Literal::vec1(&b);
            let result = exec1(exe, &[la, lb])?;
            let v = result
                .to_vec::<f32>()
                .map_err(|e| Error::Runtime(format!("to_vec: {e}")))?;
            out[lo..hi].copy_from_slice(&v[..hi - lo]);
            Ok(())
        })
    }

    /// The fused 3-input kernel `t1 ⊙ (t0 ⊙ y)` of the inner tree node
    /// (one XLA call instead of two).
    pub fn combine3_i32(
        &mut self,
        op: OpKind,
        t1: &[i32],
        t0: &[i32],
        y: &[i32],
        out: &mut [i32],
    ) -> Result<()> {
        debug_assert_eq!(t0.len(), y.len());
        debug_assert_eq!(t1.len(), y.len());
        debug_assert_eq!(out.len(), y.len());
        let ident = identity_i32(op);
        self.run_chunks(op, "int32", y.len(), |eng, lo, hi, n| {
            let a = padded_i32(&t1[lo..hi], n, ident);
            let b = padded_i32(&t0[lo..hi], n, ident);
            let c = padded_i32(&y[lo..hi], n, ident);
            let stem = artifact_name(3, op, "int32", n);
            let exe = eng.load(&stem)?;
            let result = exec1(
                exe,
                &[
                    xla::Literal::vec1(&a),
                    xla::Literal::vec1(&b),
                    xla::Literal::vec1(&c),
                ],
            )?;
            let v = result
                .to_vec::<i32>()
                .map_err(|e| Error::Runtime(format!("to_vec: {e}")))?;
            out[lo..hi].copy_from_slice(&v[..hi - lo]);
            Ok(())
        })
    }

    /// Drive `f` over chunks of at most the largest compiled size.
    fn run_chunks<F>(&mut self, _op: OpKind, _dtype: &str, len: usize, mut f: F) -> Result<()>
    where
        F: FnMut(&mut ReduceEngine, usize, usize, usize) -> Result<()>,
    {
        if len == 0 {
            return Ok(());
        }
        let max = *COMPILED_SIZES.last().unwrap();
        let mut lo = 0;
        while lo < len {
            let hi = (lo + max).min(len);
            let n = ReduceEngine::pick_size(hi - lo);
            f(self, lo, hi, n)?;
            lo = hi;
        }
        Ok(())
    }
}

/// Execute and unwrap the single tupled output as a Literal.
fn exec1(exe: &xla::PjRtLoadedExecutable, args: &[xla::Literal]) -> Result<xla::Literal> {
    let outs = exe
        .execute::<xla::Literal>(args)
        .map_err(|e| Error::Runtime(format!("execute: {e}")))?;
    let lit = outs[0][0]
        .to_literal_sync()
        .map_err(|e| Error::Runtime(format!("to_literal_sync: {e}")))?;
    // aot.py lowers with return_tuple=True → a 1-tuple
    lit.to_tuple1()
        .map_err(|e| Error::Runtime(format!("to_tuple1: {e}")))
}

fn identity_i32(op: OpKind) -> i32 {
    match op {
        OpKind::Sum => 0,
        OpKind::Prod => 1,
        OpKind::Max => i32::MIN,
        OpKind::Min => i32::MAX,
    }
}

fn identity_f32(op: OpKind) -> f32 {
    match op {
        OpKind::Sum => 0.0,
        OpKind::Prod => 1.0,
        OpKind::Max => f32::NEG_INFINITY,
        OpKind::Min => f32::INFINITY,
    }
}

/// Borrow the slice when it already matches the compiled size; otherwise
/// pad a copy with the operator identity (perf: the exact-size case — the
/// steady state for full pipeline blocks — skips one buffer copy per
/// operand per call).
fn padded_i32<'a>(src: &'a [i32], n: usize, ident: i32) -> std::borrow::Cow<'a, [i32]> {
    if src.len() == n {
        std::borrow::Cow::Borrowed(src)
    } else {
        let mut v = Vec::with_capacity(n);
        v.extend_from_slice(src);
        v.resize(n, ident);
        std::borrow::Cow::Owned(v)
    }
}

fn padded_f32<'a>(src: &'a [f32], n: usize, ident: f32) -> std::borrow::Cow<'a, [f32]> {
    if src.len() == n {
        std::borrow::Cow::Borrowed(src)
    } else {
        let mut v = Vec::with_capacity(n);
        v.extend_from_slice(src);
        v.resize(n, ident);
        std::borrow::Cow::Owned(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_naming() {
        assert_eq!(
            artifact_name(2, OpKind::Sum, "int32", 16_384),
            "combine2_sum_int32_16384"
        );
        assert_eq!(
            artifact_name(3, OpKind::Max, "float32", 1_024),
            "combine3_max_float32_1024"
        );
    }

    #[test]
    fn size_picking() {
        assert_eq!(ReduceEngine::pick_size(0), 1_024);
        assert_eq!(ReduceEngine::pick_size(1_024), 1_024);
        assert_eq!(ReduceEngine::pick_size(1_025), 16_384);
        assert_eq!(ReduceEngine::pick_size(16_000), 16_384);
        assert_eq!(ReduceEngine::pick_size(1 << 20), 131_072);
    }

    #[test]
    fn identities() {
        assert_eq!(identity_i32(OpKind::Sum), 0);
        assert_eq!(identity_i32(OpKind::Min), i32::MAX);
        assert_eq!(identity_f32(OpKind::Max), f32::NEG_INFINITY);
    }

    #[test]
    fn padding() {
        assert_eq!(padded_i32(&[1, 2], 4, 0).as_ref(), &[1, 2, 0, 0]);
        assert_eq!(padded_f32(&[1.0], 2, 9.0).as_ref(), &[1.0, 9.0]);
        // exact size borrows (no copy)
        assert!(matches!(
            padded_i32(&[1, 2], 2, 0),
            std::borrow::Cow::Borrowed(_)
        ));
    }
}
