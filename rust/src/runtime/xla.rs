//! Self-contained stand-in for the `xla` crate's PJRT CPU client.
//!
//! The build environment is offline by design, so the real PJRT C++
//! client cannot be linked. This module implements the minimal API surface
//! [`ReduceEngine`](super::ReduceEngine) uses — client, HLO-text module
//! loading, "compilation", executable execution, literals — as a tiny
//! interpreter over the only programs the AOT pipeline
//! (`python/compile/aot.py`) exports: element-wise combine kernels
//! `combine2 = p0 ⊙ p1` and `combine3 = p0 ⊙ (p1 ⊙ p2)` over one
//! fixed-size 1-D operand shape.
//!
//! The HLO **text** artifact stays the interchange format: it is parsed
//! for its parameter count, element type, block length, and combine op,
//! then executed with exactly the scalar semantics of
//! [`ops::backend`](crate::ops::backend) — including the NaN-propagating
//! `maximum`/`minimum` — so results are bitwise identical to the scalar
//! and SIMD reduce paths. Loading rejects anything that is not the
//! canonical elementwise combine form, which keeps the contract honest:
//! an artifact the stand-in cannot faithfully execute fails loudly at
//! load time instead of being silently misinterpreted. In particular,
//! `make artifacts` output from the *Pallas* lowering (a tiled while-loop
//! program with `select`/loop-counter ops, not a bare combine) is beyond
//! this stand-in — it is rejected at load and the reduce backend falls
//! back to SIMD; executing those artifacts requires the real `xla` crate.
//!
//! Swapping the real `xla` crate back in is a dependency change, not an
//! engine change: the type and method shapes here mirror the crate the
//! engine was written against.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

use crate::ops::backend::{fmax_f32, fmax_f64, fmin_f32, fmin_f64};

/// Error type standing in for the `xla` crate's.
#[derive(Debug)]
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn err<T>(msg: impl Into<String>) -> Result<T, XlaError> {
    Err(XlaError(msg.into()))
}

/// Element type of a kernel, from the HLO shape token.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub(crate) enum Dtype {
    S32,
    S64,
    F32,
    F64,
}

impl Dtype {
    fn token(self) -> &'static str {
        match self {
            Dtype::S32 => "s32[",
            Dtype::S64 => "s64[",
            Dtype::F32 => "f32[",
            Dtype::F64 => "f64[",
        }
    }
}

/// The element-wise combine of a kernel, from the HLO instruction name.
/// Public only because it appears in [`NativeType::combine`]'s signature;
/// not part of the supported API.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Comb {
    Add,
    Mul,
    Max,
    Min,
}

impl Comb {
    fn token(self) -> &'static str {
        match self {
            Comb::Add => " add(",
            Comb::Mul => " multiply(",
            Comb::Max => " maximum(",
            Comb::Min => " minimum(",
        }
    }
}

/// What an artifact computes: `p0 ⊙ p1` (arity 2) or `p0 ⊙ (p1 ⊙ p2)`
/// (arity 3) element-wise over `n`-element vectors of `dtype`.
#[derive(Clone, Copy, Debug)]
struct KernelSpec {
    arity: usize,
    dtype: Dtype,
    n: usize,
    op: Comb,
}

fn parse_hlo(text: &str) -> Result<KernelSpec, XlaError> {
    let arity = text.matches("parameter(").count();
    if !(2..=3).contains(&arity) {
        return err(format!("expected a combine2/combine3 kernel, found {arity} parameters"));
    }
    let mut dtype = None;
    for d in [Dtype::S32, Dtype::S64, Dtype::F32, Dtype::F64] {
        if text.contains(d.token()) && dtype.replace(d).is_some() {
            return err("mixed element types in kernel");
        }
    }
    let Some(dtype) = dtype else {
        return err("no supported element type (s32/s64/f32/f64) in kernel");
    };
    let mut op = None;
    for c in [Comb::Add, Comb::Mul, Comb::Max, Comb::Min] {
        if text.contains(c.token()) && op.replace(c).is_some() {
            return err("mixed combine ops in kernel");
        }
    }
    let Some(op) = op else {
        return err("no supported combine op (add/multiply/maximum/minimum) in kernel");
    };
    // the operand length from the first shape token, e.g. `s32[16384]{0}`
    let shape_at = text
        .find(dtype.token())
        .expect("dtype token was found above");
    let digits: String = text[shape_at + dtype.token().len()..]
        .chars()
        .take_while(|c| c.is_ascii_digit())
        .collect();
    let n: usize = match digits.parse() {
        Ok(n) if n > 0 => n,
        _ => return err("cannot parse operand length from kernel shape"),
    };
    Ok(KernelSpec { arity, dtype, n, op })
}

/// Stand-in for `xla::HloModuleProto`: a parsed combine-kernel spec.
pub struct HloModuleProto {
    spec: KernelSpec,
}

impl HloModuleProto {
    /// Parse an HLO-text artifact.
    pub fn from_text_file(path: &Path) -> Result<HloModuleProto, XlaError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| XlaError(format!("{}: {e}", path.display())))?;
        Ok(HloModuleProto {
            spec: parse_hlo(&text)?,
        })
    }
}

/// Stand-in for `xla::XlaComputation`.
pub struct XlaComputation {
    spec: KernelSpec,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { spec: proto.spec }
    }
}

/// Stand-in for `xla::PjRtClient` (CPU).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Ok(PjRtClient)
    }

    /// "Compile" a computation: validation happened at parse time, so this
    /// just seals the spec into an executable.
    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Ok(PjRtLoadedExecutable { spec: comp.spec })
    }
}

/// A dtype-tagged host literal (1-D, or a tuple of literals).
#[derive(Clone, Debug)]
pub enum Literal {
    S32(Vec<i32>),
    S64(Vec<i64>),
    F32(Vec<f32>),
    F64(Vec<f64>),
    Tuple(Vec<Literal>),
}

/// Rust element types that convert to/from [`Literal`] vectors.
pub trait NativeType: Copy {
    fn to_literal(v: &[Self]) -> Literal;
    fn from_literal(lit: &Literal) -> Option<Vec<Self>>;
    #[doc(hidden)]
    fn combine(op: Comb, a: Self, b: Self) -> Self;
}

macro_rules! native_type {
    ($t:ty, $variant:ident, $add:expr, $mul:expr, $max:expr, $min:expr) => {
        impl NativeType for $t {
            fn to_literal(v: &[$t]) -> Literal {
                Literal::$variant(v.to_vec())
            }
            fn from_literal(lit: &Literal) -> Option<Vec<$t>> {
                match lit {
                    Literal::$variant(v) => Some(v.clone()),
                    _ => None,
                }
            }
            fn combine(op: Comb, a: $t, b: $t) -> $t {
                const ADD: fn($t, $t) -> $t = $add;
                const MUL: fn($t, $t) -> $t = $mul;
                const MAX: fn($t, $t) -> $t = $max;
                const MIN: fn($t, $t) -> $t = $min;
                match op {
                    Comb::Add => ADD(a, b),
                    Comb::Mul => MUL(a, b),
                    Comb::Max => MAX(a, b),
                    Comb::Min => MIN(a, b),
                }
            }
        }
    };
}

native_type!(
    i32,
    S32,
    |a, b| a.wrapping_add(b),
    |a, b| a.wrapping_mul(b),
    |a, b| a.max(b),
    |a, b| a.min(b)
);
native_type!(
    i64,
    S64,
    |a, b| a.wrapping_add(b),
    |a, b| a.wrapping_mul(b),
    |a, b| a.max(b),
    |a, b| a.min(b)
);
native_type!(f32, F32, |a, b| a + b, |a, b| a * b, fmax_f32, fmin_f32);
native_type!(f64, F64, |a, b| a + b, |a, b| a * b, fmax_f64, fmin_f64);

impl Literal {
    /// A 1-D literal from a native slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        T::to_literal(v)
    }

    /// Copy out as a native vector; errors on dtype mismatch.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        T::from_literal(self).ok_or_else(|| XlaError("literal dtype mismatch".into()))
    }

    /// Unwrap a 1-tuple (the AOT pipeline lowers with `return_tuple=True`).
    pub fn to_tuple1(self) -> Result<Literal, XlaError> {
        match self {
            Literal::Tuple(mut v) if v.len() == 1 => Ok(v.pop().unwrap()),
            Literal::Tuple(v) => err(format!("expected a 1-tuple, got {} elements", v.len())),
            _ => err("expected a tuple literal"),
        }
    }

    fn dtype(&self) -> Option<Dtype> {
        match self {
            Literal::S32(_) => Some(Dtype::S32),
            Literal::S64(_) => Some(Dtype::S64),
            Literal::F32(_) => Some(Dtype::F32),
            Literal::F64(_) => Some(Dtype::F64),
            Literal::Tuple(_) => None,
        }
    }

    fn len(&self) -> usize {
        match self {
            Literal::S32(v) => v.len(),
            Literal::S64(v) => v.len(),
            Literal::F32(v) => v.len(),
            Literal::F64(v) => v.len(),
            Literal::Tuple(v) => v.len(),
        }
    }
}

/// Stand-in for a device buffer holding an execution result.
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Ok(self.lit.clone())
    }
}

/// Stand-in for `xla::PjRtLoadedExecutable`: interprets the combine kernel.
pub struct PjRtLoadedExecutable {
    spec: KernelSpec,
}

impl PjRtLoadedExecutable {
    /// Execute over host literals; returns per-device, per-output buffers
    /// (always 1×1 here) like the real client.
    pub fn execute<L: Borrow<Literal>>(
        &self,
        args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        let spec = self.spec;
        if args.len() != spec.arity {
            return err(format!(
                "kernel expects {} operands, got {}",
                spec.arity,
                args.len()
            ));
        }
        for (i, a) in args.iter().enumerate() {
            let a = a.borrow();
            if a.dtype() != Some(spec.dtype) || a.len() != spec.n {
                return err(format!("operand {i} does not match kernel shape"));
            }
        }
        let out = match spec.dtype {
            Dtype::S32 => run_typed::<i32, L>(spec, args)?,
            Dtype::S64 => run_typed::<i64, L>(spec, args)?,
            Dtype::F32 => run_typed::<f32, L>(spec, args)?,
            Dtype::F64 => run_typed::<f64, L>(spec, args)?,
        };
        Ok(vec![vec![PjRtBuffer {
            lit: Literal::Tuple(vec![out]),
        }]])
    }
}

/// `p0 ⊙ p1` (arity 2) or `p0 ⊙ (p1 ⊙ p2)` (arity 3), element-wise.
fn run_typed<T: NativeType, L: Borrow<Literal>>(
    spec: KernelSpec,
    args: &[L],
) -> Result<Literal, XlaError> {
    let p0 = args[0].borrow().to_vec::<T>()?;
    let p1 = args[1].borrow().to_vec::<T>()?;
    let out: Vec<T> = if spec.arity == 2 {
        p0.iter()
            .zip(&p1)
            .map(|(&a, &b)| T::combine(spec.op, a, b))
            .collect()
    } else {
        let p2 = args[2].borrow().to_vec::<T>()?;
        p0.iter()
            .zip(&p1)
            .zip(&p2)
            .map(|((&a, &b), &c)| T::combine(spec.op, a, T::combine(spec.op, b, c)))
            .collect()
    };
    Ok(T::to_literal(&out))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE2: &str = "\
HloModule combine2_sum_int32_4, entry_computation_layout={(s32[4]{0}, s32[4]{0})->(s32[4]{0})}

ENTRY main.4 {
  Arg_0.1 = s32[4]{0} parameter(0)
  Arg_1.2 = s32[4]{0} parameter(1)
  add.3 = s32[4]{0} add(Arg_0.1, Arg_1.2)
  ROOT tuple.4 = (s32[4]{0}) tuple(add.3)
}
";

    const SAMPLE3: &str = "\
HloModule combine3_max_float32_4

ENTRY main.6 {
  Arg_0.1 = f32[4]{0} parameter(0)
  Arg_1.2 = f32[4]{0} parameter(1)
  Arg_2.3 = f32[4]{0} parameter(2)
  maximum.4 = f32[4]{0} maximum(Arg_1.2, Arg_2.3)
  maximum.5 = f32[4]{0} maximum(Arg_0.1, maximum.4)
  ROOT tuple.6 = (f32[4]{0}) tuple(maximum.5)
}
";

    #[test]
    fn parses_combine2() {
        let spec = parse_hlo(SAMPLE2).unwrap();
        assert_eq!(spec.arity, 2);
        assert_eq!(spec.dtype, Dtype::S32);
        assert_eq!(spec.n, 4);
        assert_eq!(spec.op, Comb::Add);
    }

    #[test]
    fn rejects_non_combine_programs() {
        assert!(parse_hlo("ENTRY { ROOT c = s32[] constant(1) }").is_err());
        assert!(parse_hlo(SAMPLE2.replace("add", "subtract").as_str()).is_err());
    }

    #[test]
    fn executes_combine2_elementwise() {
        let spec = parse_hlo(SAMPLE2).unwrap();
        let exe = PjRtLoadedExecutable { spec };
        let a = Literal::vec1(&[1i32, 2, 3, 4]);
        let b = Literal::vec1(&[10i32, 20, 30, 40]);
        let outs = exe.execute(&[a, b]).unwrap();
        let lit = outs[0][0].to_literal_sync().unwrap().to_tuple1().unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![11, 22, 33, 44]);
    }

    #[test]
    fn executes_combine3_with_nan_propagation() {
        let spec = parse_hlo(SAMPLE3).unwrap();
        let exe = PjRtLoadedExecutable { spec };
        let t1 = Literal::vec1(&[1.0f32, f32::NAN, 3.0, 4.0]);
        let t0 = Literal::vec1(&[5.0f32, 1.0, f32::NAN, 2.0]);
        let y = Literal::vec1(&[2.0f32, 2.0, 2.0, 9.0]);
        let outs = exe.execute(&[t1, t0, y]).unwrap();
        let lit = outs[0][0].to_literal_sync().unwrap().to_tuple1().unwrap();
        let v = lit.to_vec::<f32>().unwrap();
        assert_eq!(v[0], 5.0);
        assert!(v[1].is_nan());
        assert!(v[2].is_nan());
        assert_eq!(v[3], 9.0);
    }

    #[test]
    fn shape_mismatch_is_an_error() {
        let spec = parse_hlo(SAMPLE2).unwrap();
        let exe = PjRtLoadedExecutable { spec };
        let short = Literal::vec1(&[1i32]);
        let ok = Literal::vec1(&[1i32, 2, 3, 4]);
        assert!(exe.execute(&[short, ok.clone()]).is_err());
        let f = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert!(exe.execute(&[f, ok]).is_err());
    }
}
