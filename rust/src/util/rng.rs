//! Deterministic xorshift64* RNG.
//!
//! Used by tests, the property-testing substrate, and workload generators.
//! We cannot pull `rand` from the offline registry, and a 20-line xorshift
//! is all the randomness this project needs; determinism-by-seed is a
//! feature for reproducible experiments.

/// xorshift64* generator (Vigna 2016). Never yields state 0.
#[derive(Clone, Debug)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    /// Create a generator from a seed; seed 0 is mapped to a fixed non-zero
    /// constant because the all-zero state is a fixed point of xorshift.
    pub fn new(seed: u64) -> Self {
        XorShift64 {
            state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, bound)`; `bound` must be > 0.
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Multiply-shift bounded sampling; bias is < 2^-64 per draw which is
        // irrelevant for test workloads.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn unit_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Random i32 in a small symmetric range, handy for overflow-safe sums.
    pub fn small_i32(&mut self) -> i32 {
        self.range(0, 200) as i32 - 100
    }

    /// Fill a vector with small i32 values.
    pub fn small_i32_vec(&mut self, n: usize) -> Vec<i32> {
        (0..n).map(|_| self.small_i32()).collect()
    }

    /// Random f32 in [-1, 1).
    pub fn small_f32(&mut self) -> f32 {
        (self.unit_f64() * 2.0 - 1.0) as f32
    }

    /// Fill a vector with small f32 values.
    pub fn small_f32_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.small_f32()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = XorShift64::new(7);
        let mut b = XorShift64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn zero_seed_is_usable() {
        let mut r = XorShift64::new(0);
        let v = r.next_u64();
        assert_ne!(v, 0);
    }

    #[test]
    fn below_in_range() {
        let mut r = XorShift64::new(42);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = XorShift64::new(42);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn unit_f64_in_unit_interval() {
        let mut r = XorShift64::new(1);
        for _ in 0..10_000 {
            let v = r.unit_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }
}
