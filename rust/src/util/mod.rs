//! Small shared utilities: deterministic RNG, formatting helpers.

pub mod rng;

pub use rng::XorShift64;

/// Format a number of elements / bytes with thousands separators, as the
/// paper's tables do implicitly ("8 388 608").
pub fn with_thousands(n: u64) -> String {
    let s = n.to_string();
    let bytes = s.as_bytes();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, c) in bytes.iter().enumerate() {
        if i > 0 && (bytes.len() - i) % 3 == 0 {
            out.push(' ');
        }
        out.push(*c as char);
    }
    out
}

/// Format a duration given in microseconds the way the paper's Table 2
/// reports times (two decimals, microseconds).
pub fn fmt_us(us: f64) -> String {
    format!("{us:.2}")
}

/// Integer ceiling division.
pub fn div_ceil(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// `floor(log2(n))` for `n >= 1`.
pub fn log2_floor(n: usize) -> u32 {
    debug_assert!(n >= 1);
    usize::BITS - 1 - n.leading_zeros()
}

/// `ceil(log2(n))` for `n >= 1`.
pub fn log2_ceil(n: usize) -> u32 {
    if n <= 1 {
        0
    } else {
        usize::BITS - (n - 1).leading_zeros()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thousands() {
        assert_eq!(with_thousands(0), "0");
        assert_eq!(with_thousands(999), "999");
        assert_eq!(with_thousands(1000), "1 000");
        assert_eq!(with_thousands(8388608), "8 388 608");
    }

    #[test]
    fn ceil_div() {
        assert_eq!(div_ceil(0, 3), 0);
        assert_eq!(div_ceil(1, 3), 1);
        assert_eq!(div_ceil(3, 3), 1);
        assert_eq!(div_ceil(4, 3), 2);
    }

    #[test]
    fn logs() {
        assert_eq!(log2_floor(1), 0);
        assert_eq!(log2_floor(2), 1);
        assert_eq!(log2_floor(3), 1);
        assert_eq!(log2_floor(4), 2);
        assert_eq!(log2_ceil(1), 0);
        assert_eq!(log2_ceil(2), 1);
        assert_eq!(log2_ceil(3), 2);
        assert_eq!(log2_ceil(5), 3);
    }

    #[test]
    fn fmt_us_two_decimals() {
        assert_eq!(fmt_us(0.194), "0.19");
        assert_eq!(fmt_us(56249.239), "56249.24");
    }
}
