//! Critical-path extraction and α/β/γ/stall attribution over a
//! recorded trace.
//!
//! The happens-before relation of a run is implicit in the spans: on
//! one rank they are totally ordered by the (virtual) clock, and every
//! receive depends on the matching send on the peer — the same
//! `(src, dst, tag, seq)` key the exporter uses for flow arrows. The
//! analyzer walks this DAG backwards from the globally latest span:
//! at each receive it asks whether the *local* predecessor or the
//! *sender's readiness* was the binding constraint, and hops ranks when
//! it was the sender. The result is the longest dependency chain — the
//! paper's critical path — with every microsecond on it attributed to
//! one of the cost-model buckets:
//!
//! * `alpha_us` — per-message latency (α per transfer on the path),
//! * `beta_us` — serialization (β · bytes per transfer),
//! * `gamma_us` — reduction compute (the γ-charges),
//! * `stall_us` — congestion: queue backpressure and port contention,
//!   both inside transfers (residual over α + βm) and in gaps covered
//!   by recorded `Stall` spans,
//! * `wait_us` — idle gaps not explained by any recorded cause,
//! * `other_us` — barriers and spans with no model (real-time runs).
//!
//! For uniform virtual-model traces the report also recomputes
//! `model::predicted_time_us` for the run's `(algo, p, m, blocks)` and
//! states the relative error — the paper's model-validation loop
//! (§1.2), per-run instead of per-benchmark. The documented tolerance
//! is the one the model tests pin: the analytic forms idealize away
//! tree imbalance and hold within ~30% of the simulation.

use super::export::{Span, SpanKind};
use super::{Trace, TraceMeta};
use crate::model::{AlgoKind, LinkCost};
use std::collections::HashMap;

/// Where the critical path's time went, µs.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Buckets {
    pub alpha_us: f64,
    pub beta_us: f64,
    pub gamma_us: f64,
    pub stall_us: f64,
    pub wait_us: f64,
    pub other_us: f64,
}

impl Buckets {
    /// Total attributed time.
    pub fn total_us(&self) -> f64 {
        self.alpha_us + self.beta_us + self.gamma_us + self.stall_us + self.wait_us + self.other_us
    }
}

/// One link of the critical chain, in time order.
#[derive(Clone, Debug, PartialEq)]
pub struct CritStep {
    pub rank: usize,
    pub kind: SpanKind,
    pub peer: i32,
    pub tag: u32,
    pub seq: u64,
    pub bytes: u64,
    pub t0_us: f64,
    pub t1_us: f64,
}

/// The analyzer's result.
#[derive(Clone, Debug, PartialEq)]
pub struct CritReport {
    pub algo: String,
    pub p: usize,
    /// End-to-end span of the run (latest end − earliest start), µs.
    pub measured_us: f64,
    /// `model::predicted_time_us` for the run's parameters, when the
    /// trace carries a uniform virtual model.
    pub predicted_us: Option<f64>,
    /// |measured − predicted| / predicted.
    pub rel_err: Option<f64>,
    pub buckets: Buckets,
    /// Rank hops along the chain (sender-side constraints).
    pub hops: usize,
    pub path: Vec<CritStep>,
}

impl CritReport {
    /// Machine-readable form (same hand-rolled JSON idiom as the
    /// schedule certs).
    pub fn to_json(&self) -> String {
        let opt = |v: Option<f64>| v.map(|x| x.to_string()).unwrap_or_else(|| "null".into());
        let steps: Vec<String> = self
            .path
            .iter()
            .map(|s| {
                format!(
                    "{{\"rank\":{},\"kind\":\"{}\",\"peer\":{},\"tag\":{},\"seq\":{},\
                     \"bytes\":{},\"t0_us\":{},\"t1_us\":{}}}",
                    s.rank,
                    s.kind.name(),
                    s.peer,
                    s.tag,
                    s.seq,
                    s.bytes,
                    s.t0_us,
                    s.t1_us
                )
            })
            .collect();
        format!(
            "{{\n\"algo\":\"{}\",\"p\":{},\"measured_us\":{},\"predicted_us\":{},\
             \"rel_err\":{},\n\"buckets\":{{\"alpha_us\":{},\"beta_us\":{},\"gamma_us\":{},\
             \"stall_us\":{},\"wait_us\":{},\"other_us\":{}}},\n\"hops\":{},\"steps\":{},\n\
             \"path\":[\n{}\n]\n}}\n",
            self.algo,
            self.p,
            self.measured_us,
            opt(self.predicted_us),
            opt(self.rel_err),
            self.buckets.alpha_us,
            self.buckets.beta_us,
            self.buckets.gamma_us,
            self.buckets.stall_us,
            self.buckets.wait_us,
            self.buckets.other_us,
            self.hops,
            self.path.len(),
            steps.join(",\n")
        )
    }
}

/// Spans that advance a rank's clock and therefore carry dependencies.
fn on_path(kind: SpanKind) -> bool {
    matches!(
        kind,
        SpanKind::Send | SpanKind::Recv | SpanKind::Reduce | SpanKind::Barrier
    )
}

/// Walk the happens-before DAG of `spans` backwards from the latest
/// span and attribute the chain. `spans` come from
/// [`super::export::spans_of`] or [`super::export::read_chrome_json`].
pub fn analyze(meta: &TraceMeta, spans: &[Span]) -> CritReport {
    let model_known = meta.virtual_time && (meta.alpha > 0.0 || meta.beta > 0.0);
    let mut buckets = Buckets::default();
    // Per-rank clock-ordered indices of path spans and stall spans.
    let p = spans.iter().map(|s| s.rank + 1).max().unwrap_or(meta.p).max(meta.p);
    let mut by_rank: Vec<Vec<usize>> = vec![Vec::new(); p];
    let mut stalls: Vec<Vec<usize>> = vec![Vec::new(); p];
    let mut send_at: HashMap<(usize, usize, u32, u64), usize> = HashMap::new();
    for (i, s) in spans.iter().enumerate() {
        if on_path(s.kind) {
            by_rank[s.rank].push(i);
            if s.kind == SpanKind::Send && s.peer >= 0 {
                send_at.insert((s.rank, s.peer as usize, s.tag, s.seq), i);
            }
        } else if s.kind == SpanKind::Stall {
            stalls[s.rank].push(i);
        }
    }
    // Virtual clocks start at 0 by construction; real-time traces
    // start wherever the first event landed on the wall clock.
    let min_t0 = spans.iter().map(|s| s.t0_us).fold(f64::INFINITY, f64::min);
    let t_start = if meta.virtual_time || !min_t0.is_finite() { 0.0 } else { min_t0 };
    // Terminal: the latest-ending path span (ties broken toward the
    // lowest rank, then earliest start — a total, deterministic order).
    let mut terminal: Option<usize> = None;
    for &i in by_rank.iter().flatten() {
        let better = match terminal {
            None => true,
            Some(j) => {
                let (a, b) = (&spans[i], &spans[j]);
                (a.t1_us, b.rank, b.t0_us.to_bits()) > (b.t1_us, a.rank, a.t0_us.to_bits())
            }
        };
        if better {
            terminal = Some(i);
        }
    }
    let measured_us = terminal.map(|i| spans[i].t1_us - t_start).unwrap_or(0.0);
    let eps = 1e-9 + measured_us * 1e-12;
    // Position of each path span within its rank's clock-ordered list;
    // predecessor search walks strictly earlier positions, which makes
    // the backwards walk terminate even through zero-duration spans.
    let mut pos_of: HashMap<usize, usize> = HashMap::new();
    for list in &by_rank {
        for (pos, &i) in list.iter().enumerate() {
            pos_of.insert(i, pos);
        }
    }
    // Latest path span on `rank` before list position `before` that
    // ends at or before `tlim`.
    let latest_before = |rank: usize, tlim: f64, before: usize| -> Option<usize> {
        by_rank[rank][..before]
            .iter()
            .rev()
            .copied()
            .find(|&i| spans[i].t1_us <= tlim + eps)
    };
    // Attribute an idle gap [from, to] on `rank`: stall where a Stall
    // span covers it, wait otherwise.
    let gap_buckets = |buckets: &mut Buckets, rank: usize, from: f64, to: f64| {
        if to - from <= eps {
            return;
        }
        let mut covered = 0.0;
        for &i in &stalls[rank] {
            let s = &spans[i];
            let lo = s.t0_us.max(from);
            let hi = s.t1_us.min(to);
            if hi > lo {
                covered += hi - lo;
            }
        }
        let gap = to - from;
        buckets.stall_us += covered.min(gap);
        buckets.wait_us += (gap - covered).max(0.0);
    };
    let mut path_rev: Vec<usize> = Vec::new();
    let mut hops = 0usize;
    let mut cur = terminal;
    let budget = 4 * spans.len() + 16;
    while let Some(ci) = cur {
        if path_rev.len() > budget {
            break;
        }
        path_rev.push(ci);
        let s = &spans[ci];
        let d = (s.t1_us - s.t0_us).max(0.0);
        match s.kind {
            SpanKind::Send | SpanKind::Recv => {
                if model_known {
                    let a_us = meta.alpha * 1e6;
                    let b_us = meta.beta * 1e6 * s.bytes as f64;
                    let alpha_part = d.min(a_us);
                    let beta_part = (d - alpha_part).min(b_us);
                    buckets.alpha_us += alpha_part;
                    buckets.beta_us += beta_part;
                    buckets.stall_us += d - alpha_part - beta_part;
                } else {
                    buckets.other_us += d;
                }
            }
            SpanKind::Reduce => buckets.gamma_us += d,
            _ => buckets.other_us += d,
        }
        // Choose the binding predecessor.
        let local = latest_before(s.rank, s.t0_us, pos_of[&ci]);
        let local_end = local.map(|i| spans[i].t1_us).unwrap_or(f64::NEG_INFINITY);
        let sender = (s.kind == SpanKind::Recv && s.peer >= 0)
            .then(|| send_at.get(&(s.peer as usize, s.rank, s.tag, s.seq)).copied())
            .flatten();
        cur = match sender {
            Some(si) if spans[si].t0_us > local_end + eps => {
                // The sender posted after we were ready: the chain runs
                // through the peer. Continue before its send; the time
                // between `local_end` and our start belongs to the
                // sender's chain, not to this rank.
                hops += 1;
                let snd = &spans[si];
                let prev = latest_before(snd.rank, snd.t0_us, pos_of[&si]);
                if let Some(pi) = prev {
                    gap_buckets(&mut buckets, snd.rank, spans[pi].t1_us, snd.t0_us);
                } else {
                    gap_buckets(&mut buckets, snd.rank, t_start, snd.t0_us);
                }
                prev
            }
            _ => {
                match local {
                    Some(pi) => gap_buckets(&mut buckets, s.rank, spans[pi].t1_us, s.t0_us),
                    None => gap_buckets(&mut buckets, s.rank, t_start, s.t0_us),
                }
                local
            }
        };
    }
    path_rev.reverse();
    let path: Vec<CritStep> = path_rev
        .iter()
        .map(|&i| {
            let s = &spans[i];
            CritStep {
                rank: s.rank,
                kind: s.kind,
                peer: s.peer,
                tag: s.tag,
                seq: s.seq,
                bytes: s.bytes,
                t0_us: s.t0_us,
                t1_us: s.t1_us,
            }
        })
        .collect();
    let predicted_us = (model_known && meta.blocks > 0 && meta.m_elems > 0)
        .then(|| {
            AlgoKind::parse(&meta.algo).map(|algo| {
                predicted(
                    algo,
                    meta.p,
                    meta.m_elems * meta.elem_bytes,
                    meta.blocks,
                    LinkCost::new(meta.alpha, meta.beta),
                )
            })
        })
        .flatten();
    let rel_err = predicted_us
        .filter(|&pr| pr > 0.0)
        .map(|pr| (measured_us - pr).abs() / pr);
    CritReport {
        algo: meta.algo.clone(),
        p: meta.p,
        measured_us,
        predicted_us,
        rel_err,
        buckets,
        hops,
        path,
    }
}

fn predicted(algo: AlgoKind, p: usize, m_bytes: usize, b: usize, link: LinkCost) -> f64 {
    crate::model::predicted_time_us(algo, p, m_bytes, b, link)
}

/// Convenience: pair a recorded trace's events and analyze.
pub fn analyze_trace(trace: &Trace) -> CritReport {
    let spans = super::export::spans_of(&trace.events);
    analyze(&trace.meta, &spans)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{Event, EventKind, Trace};

    fn meta(virtual_time: bool) -> TraceMeta {
        TraceMeta {
            algo: "dpdr".into(),
            p: 2,
            m_elems: 8,
            elem_bytes: 4,
            blocks: 1,
            alpha: 1e-6,
            beta: 0.0,
            gamma: 1e-9,
            virtual_time,
            source: "test".into(),
        }
    }

    /// rank 0 posts a send at t=0 ([0,1]); rank 1 receives it ([0,1])
    /// and reduces ([1, 1.5]). The chain is recv → reduce; the send
    /// half is the same transfer, not a second cost.
    fn two_rank_trace() -> Trace {
        let events = vec![
            Event::new(EventKind::SendStart, 0).peer(1).bytes(32).at_us(0.0),
            Event::new(EventKind::SendEnd, 0).peer(1).bytes(32).at_us(1.0),
            Event::new(EventKind::RecvStart, 1).peer(0).bytes(32).at_us(0.0),
            Event::new(EventKind::RecvEnd, 1).peer(0).bytes(32).at_us(1.0),
            Event::new(EventKind::Reduce, 1).bytes(32).at_us(1.0).dur_us(0.5),
        ];
        Trace {
            meta: meta(true),
            events,
            dropped: 0,
            recorded: 5,
        }
    }

    #[test]
    fn chain_and_buckets() {
        let r = analyze_trace(&two_rank_trace());
        assert_eq!(r.measured_us, 1.5);
        assert_eq!(r.path.len(), 2);
        assert_eq!(r.path[0].kind, SpanKind::Recv);
        assert_eq!(r.path[1].kind, SpanKind::Reduce);
        // α = 1 µs explains the transfer; γ the reduce; nothing idle.
        assert!((r.buckets.alpha_us - 1.0).abs() < 1e-9);
        assert!((r.buckets.gamma_us - 0.5).abs() < 1e-9);
        assert!(r.buckets.wait_us.abs() < 1e-9);
        assert!((r.buckets.total_us() - r.measured_us).abs() < 1e-6);
    }

    #[test]
    fn sender_hop_crosses_ranks() {
        // rank 0 computes [0, 3] then sends [3, 4]; rank 1 was ready at
        // 0 and receives [3, 4.5] (0.5 µs of port contention inside the
        // transfer): the chain must hop from the receive to rank 0's
        // reduce, and the receiver's idle [0, 3] must cost nothing — it
        // is the sender's chain that explains it.
        let events = vec![
            Event::new(EventKind::Reduce, 0).bytes(8).at_us(0.0).dur_us(3.0),
            Event::new(EventKind::SendStart, 0).peer(1).bytes(8).at_us(3.0),
            Event::new(EventKind::SendEnd, 0).peer(1).bytes(8).at_us(4.0),
            Event::new(EventKind::RecvStart, 1).peer(0).bytes(8).at_us(3.0),
            Event::new(EventKind::RecvEnd, 1).peer(0).bytes(8).at_us(4.5),
        ];
        let trace = Trace {
            meta: meta(true),
            events,
            dropped: 0,
            recorded: 5,
        };
        let r = analyze_trace(&trace);
        assert_eq!(r.hops, 1);
        assert_eq!(r.path.len(), 2);
        assert_eq!((r.path[0].rank, r.path[0].kind), (0, SpanKind::Reduce));
        assert_eq!((r.path[1].rank, r.path[1].kind), (1, SpanKind::Recv));
        assert!((r.buckets.gamma_us - 3.0).abs() < 1e-9);
        assert!((r.buckets.alpha_us - 1.0).abs() < 1e-9);
        assert!((r.buckets.stall_us - 0.5).abs() < 1e-9);
        assert!(r.buckets.wait_us.abs() < 1e-9);
        assert!((r.measured_us - 4.5).abs() < 1e-9);
    }

    #[test]
    fn unexplained_gap_becomes_wait() {
        let events = vec![
            Event::new(EventKind::Reduce, 0).bytes(8).at_us(2.0).dur_us(1.0),
        ];
        let trace = Trace {
            meta: meta(true),
            events,
            dropped: 0,
            recorded: 1,
        };
        let r = analyze_trace(&trace);
        assert!((r.buckets.wait_us - 2.0).abs() < 1e-9);
        assert!((r.buckets.gamma_us - 1.0).abs() < 1e-9);
    }

    #[test]
    fn report_json_is_deterministic_and_parses() {
        let a = analyze_trace(&two_rank_trace()).to_json();
        let b = analyze_trace(&two_rank_trace()).to_json();
        assert_eq!(a, b);
        let v = crate::obs::json::parse(&a).unwrap();
        assert_eq!(v.num("steps"), Some(2.0));
        assert!(v.get("buckets").unwrap().num("alpha_us").is_some());
    }

    #[test]
    fn real_time_traces_fall_into_other() {
        let mut t = two_rank_trace();
        t.meta.virtual_time = false;
        let r = analyze_trace(&t);
        assert_eq!(r.predicted_us, None);
        assert!(r.buckets.alpha_us == 0.0 && r.buckets.other_us > 0.0);
    }
}
