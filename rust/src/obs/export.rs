//! Chrome-trace / Perfetto JSON export of a recorded [`Trace`], and the
//! matching reader used by `dpdr critical-path`.
//!
//! The export follows the Trace Event Format (the JSON flavor both
//! `chrome://tracing` and <https://ui.perfetto.dev> load): one process,
//! one named track (`tid`) per rank, paired `SendStart`/`SendEnd` and
//! `RecvStart`/`RecvEnd` events folded into complete (`ph:"X"`) spans,
//! self-timed spans (reduce, stall, barrier, nbc waits) emitted
//! directly, lifecycle marks as instants (`ph:"i"`), and every matched
//! message drawn as a flow arrow (`ph:"s"`/`ph:"f"`) from the send
//! span's start on the sender track to the recv span's end on the
//! receiver track.
//!
//! Timestamps are µs. Virtual traces use the simulated clock and omit
//! wall fields entirely, so the bytes are run-to-run deterministic;
//! real-time traces use the wall clock. `otherData` carries the run
//! metadata ([`TraceMeta`]) that the critical-path analyzer needs to
//! rebuild the α-β model comparison.

use super::json::{self, Value};
use super::{Event, EventKind, Trace, TraceMeta};
use crate::error::{Error, Result};
use std::collections::HashMap;

/// A paired or self-contained interval reconstructed from the event
/// stream — the unit the exporter and the critical-path walk share.
#[derive(Clone, Debug, PartialEq)]
pub struct Span {
    pub kind: SpanKind,
    pub rank: usize,
    pub peer: i32,
    pub tag: u32,
    pub seq: u64,
    pub bytes: u64,
    pub aux: u32,
    /// Virtual interval, µs (for real-time traces these carry the wall
    /// interval instead, converted to µs — one uniform time axis).
    pub t0_us: f64,
    pub t1_us: f64,
    /// Wall interval, ns since trace start (0 in loaded traces).
    pub w0_ns: u64,
    pub w1_ns: u64,
}

/// Span flavors after pairing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    Send,
    Recv,
    Reduce,
    ReduceKernel,
    Stall,
    Barrier,
    OpSubmit,
    OpQueue,
    OpFuse,
    OpLaunch,
    OpWait,
    Step,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Send => "send",
            SpanKind::Recv => "recv",
            SpanKind::Reduce => "reduce",
            SpanKind::ReduceKernel => "reduce_kernel",
            SpanKind::Stall => "stall",
            SpanKind::Barrier => "barrier",
            SpanKind::OpSubmit => "op_submit",
            SpanKind::OpQueue => "op_queue",
            SpanKind::OpFuse => "op_fuse",
            SpanKind::OpLaunch => "op_launch",
            SpanKind::OpWait => "op_wait",
            SpanKind::Step => "step",
        }
    }

    pub fn parse(s: &str) -> Option<SpanKind> {
        Some(match s {
            "send" => SpanKind::Send,
            "recv" => SpanKind::Recv,
            "reduce" => SpanKind::Reduce,
            "reduce_kernel" => SpanKind::ReduceKernel,
            "stall" => SpanKind::Stall,
            "barrier" => SpanKind::Barrier,
            "op_submit" => SpanKind::OpSubmit,
            "op_queue" => SpanKind::OpQueue,
            "op_fuse" => SpanKind::OpFuse,
            "op_launch" => SpanKind::OpLaunch,
            "op_wait" => SpanKind::OpWait,
            "step" => SpanKind::Step,
            _ => return None,
        })
    }

    /// Zero-duration marks (exported as `ph:"i"`).
    pub fn is_instant(self) -> bool {
        matches!(
            self,
            SpanKind::OpSubmit | SpanKind::OpQueue | SpanKind::OpLaunch | SpanKind::Step
        )
    }

    fn category(self) -> &'static str {
        match self {
            SpanKind::Send | SpanKind::Recv => "p2p",
            SpanKind::Reduce | SpanKind::ReduceKernel => "compute",
            SpanKind::Stall => "stall",
            SpanKind::Barrier => "sync",
            SpanKind::Step => "sched",
            _ => "nbc",
        }
    }
}

/// Fold the sorted event stream into spans: start/end pairs matched by
/// `(rank, peer, tag, seq)`, everything else taken as-is. Unpaired
/// endpoints (ring overflow, trace stopped mid-op) become zero-length
/// spans rather than being dropped.
pub fn spans_of(events: &[Event]) -> Vec<Span> {
    let mut open: HashMap<(u8, u32, i32, u32, u64), Event> = HashMap::new();
    let mut spans = Vec::with_capacity(events.len());
    let span_from = |kind: SpanKind, ev: &Event, t1_us: f64, w1_ns: u64| Span {
        kind,
        rank: ev.rank as usize,
        peer: ev.peer,
        tag: ev.tag,
        seq: ev.seq,
        bytes: ev.bytes,
        aux: ev.aux,
        t0_us: ev.t_us,
        t1_us,
        w0_ns: ev.wall_ns,
        w1_ns,
    };
    for ev in events {
        match ev.kind {
            EventKind::SendStart | EventKind::RecvStart => {
                let dir = (ev.kind == EventKind::SendStart) as u8;
                open.insert((dir, ev.rank, ev.peer, ev.tag, ev.seq), *ev);
            }
            EventKind::SendEnd | EventKind::RecvEnd => {
                let dir = (ev.kind == EventKind::SendEnd) as u8;
                let kind = if dir == 1 { SpanKind::Send } else { SpanKind::Recv };
                match open.remove(&(dir, ev.rank, ev.peer, ev.tag, ev.seq)) {
                    Some(start) => spans.push(span_from(kind, &start, ev.t_us, ev.wall_ns)),
                    // End without a start (start dropped from the ring):
                    // keep it as a zero-length span.
                    None => spans.push(span_from(kind, ev, ev.t_us, ev.wall_ns)),
                }
            }
            other => {
                let kind = SpanKind::parse(other.name()).expect("span kinds mirror event kinds");
                spans.push(span_from(kind, ev, ev.t_us + ev.dur_us, ev.wall_ns));
            }
        }
    }
    // Starts whose end never arrived: keep as zero-length spans.
    let mut orphans: Vec<Event> = open.into_values().collect();
    orphans.sort_by_key(Event::sort_key);
    for ev in orphans {
        let kind = if ev.kind == EventKind::SendStart { SpanKind::Send } else { SpanKind::Recv };
        spans.push(span_from(kind, &ev, ev.t_us, ev.wall_ns));
    }
    spans.sort_by(|a, b| {
        (a.rank, a.t0_us.to_bits(), a.t1_us.to_bits(), a.tag, a.peer, a.seq)
            .cmp(&(b.rank, b.t0_us.to_bits(), b.t1_us.to_bits(), b.tag, b.peer, b.seq))
    });
    spans
}

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Serialize a trace to Chrome trace-event JSON. Deterministic for
/// virtual-time traces (see module docs).
pub fn to_chrome_json(trace: &Trace) -> String {
    let meta = &trace.meta;
    let spans = spans_of(&trace.events);
    let virt = meta.virtual_time;
    // One uniform timestamp axis: the simulated clock for virtual
    // traces, the wall clock for real ones.
    let ts_of = |t_us: f64, w_ns: u64| if virt { t_us } else { w_ns as f64 / 1000.0 };
    let mut ev_json: Vec<String> = Vec::with_capacity(spans.len() + trace.meta.p + 4);
    ev_json.push(format!(
        "{{\"ph\":\"M\",\"pid\":0,\"name\":\"process_name\",\"args\":{{\"name\":\"dpdr {} {} p={}\"}}}}",
        esc(&meta.source),
        esc(&meta.algo),
        meta.p
    ));
    for r in 0..meta.p {
        ev_json.push(format!(
            "{{\"ph\":\"M\",\"pid\":0,\"tid\":{r},\"name\":\"thread_name\",\"args\":{{\"name\":\"rank {r}\"}}}}"
        ));
        ev_json.push(format!(
            "{{\"ph\":\"M\",\"pid\":0,\"tid\":{r},\"name\":\"thread_sort_index\",\"args\":{{\"sort_index\":{r}}}}}"
        ));
    }
    // Index recv spans by (src, dst, tag, seq) for the flow arrows.
    let mut recv_at: HashMap<(i32, usize, u32, u64), &Span> = HashMap::new();
    for s in &spans {
        if s.kind == SpanKind::Recv && s.peer >= 0 {
            recv_at.insert((s.peer, s.rank, s.tag, s.seq), s);
        }
    }
    let mut flows: Vec<String> = Vec::new();
    for s in &spans {
        let name = display_name(s);
        let ts = ts_of(s.t0_us, s.w0_ns);
        let args = format!(
            "{{\"kind\":\"{}\",\"peer\":{},\"tag\":{},\"seq\":{},\"bytes\":{},\"aux\":{}}}",
            s.kind.name(),
            s.peer,
            s.tag,
            s.seq,
            s.bytes,
            s.aux
        );
        if s.kind.is_instant() {
            ev_json.push(format!(
                "{{\"name\":\"{name}\",\"cat\":\"{}\",\"ph\":\"i\",\"s\":\"t\",\"pid\":0,\"tid\":{},\"ts\":{ts},\"args\":{args}}}",
                s.kind.category(),
                s.rank
            ));
        } else {
            let dur = ts_of(s.t1_us, s.w1_ns) - ts;
            ev_json.push(format!(
                "{{\"name\":\"{name}\",\"cat\":\"{}\",\"ph\":\"X\",\"pid\":0,\"tid\":{},\"ts\":{ts},\"dur\":{dur},\"args\":{args}}}",
                s.kind.category(),
                s.rank
            ));
        }
        // Flow arrow: send span start → matching recv span end.
        if s.kind == SpanKind::Send && s.peer >= 0 {
            if let Some(rv) = recv_at.get(&(s.rank as i32, s.peer as usize, s.tag, s.seq)) {
                let id = format!("{}-{}-t{}-{}", s.rank, s.peer, s.tag, s.seq);
                flows.push(format!(
                    "{{\"name\":\"msg\",\"cat\":\"p2p\",\"ph\":\"s\",\"id\":\"{id}\",\"pid\":0,\"tid\":{},\"ts\":{ts}}}",
                    s.rank
                ));
                flows.push(format!(
                    "{{\"name\":\"msg\",\"cat\":\"p2p\",\"ph\":\"f\",\"bp\":\"e\",\"id\":\"{id}\",\"pid\":0,\"tid\":{},\"ts\":{}}}",
                    rv.rank,
                    ts_of(rv.t1_us, rv.w1_ns)
                ));
            }
        }
    }
    ev_json.extend(flows);
    let other = format!(
        "{{\"tool\":\"dpdr\",\"source\":\"{}\",\"algo\":\"{}\",\"p\":{},\"m_elems\":{},\
         \"elem_bytes\":{},\"blocks\":{},\"alpha_s\":{},\"beta_s_per_b\":{},\"gamma_s_per_b\":{},\
         \"timing\":\"{}\",\"recorded\":{},\"dropped\":{}}}",
        esc(&meta.source),
        esc(&meta.algo),
        meta.p,
        meta.m_elems,
        meta.elem_bytes,
        meta.blocks,
        meta.alpha,
        meta.beta,
        meta.gamma,
        if virt { "virtual" } else { "real" },
        trace.recorded,
        trace.dropped
    );
    format!(
        "{{\n\"traceEvents\":[\n{}\n],\n\"displayTimeUnit\":\"ms\",\n\"otherData\":{other}\n}}\n",
        ev_json.join(",\n")
    )
}

fn display_name(s: &Span) -> String {
    match s.kind {
        SpanKind::Send => format!("send->{}", s.peer),
        SpanKind::Recv => format!("recv<-{}", s.peer),
        SpanKind::Reduce => "reduce".into(),
        SpanKind::ReduceKernel => format!(
            "kernel:{}",
            match s.aux {
                0 => "scalar",
                1 => "simd",
                _ => "pjrt",
            }
        ),
        SpanKind::Stall => format!("stall:{}", super::stall_cause::name(s.aux)),
        SpanKind::Barrier => "barrier".into(),
        SpanKind::OpSubmit => format!("submit t{}", s.tag),
        SpanKind::OpQueue => format!("queue t{}", s.tag),
        SpanKind::OpFuse => format!("fuse x{}", s.aux),
        SpanKind::OpLaunch => format!("launch t{}", s.tag),
        SpanKind::OpWait => format!("wait t{}", s.tag),
        SpanKind::Step => format!("step {}", s.aux),
    }
}

/// Load a Chrome-trace JSON file produced by [`to_chrome_json`] back
/// into `(meta, spans)` for analysis.
pub fn read_chrome_json(text: &str) -> Result<(TraceMeta, Vec<Span>)> {
    let root = json::parse(text)?;
    let other = root
        .get("otherData")
        .ok_or_else(|| Error::Protocol("trace: missing otherData".into()))?;
    let meta = TraceMeta {
        algo: other.str("algo").unwrap_or("").to_string(),
        p: other.num("p").unwrap_or(0.0) as usize,
        m_elems: other.num("m_elems").unwrap_or(0.0) as usize,
        elem_bytes: other.num("elem_bytes").unwrap_or(0.0) as usize,
        blocks: other.num("blocks").unwrap_or(0.0) as usize,
        alpha: other.num("alpha_s").unwrap_or(0.0),
        beta: other.num("beta_s_per_b").unwrap_or(0.0),
        gamma: other.num("gamma_s_per_b").unwrap_or(0.0),
        virtual_time: other.str("timing") == Some("virtual"),
        source: other.str("source").unwrap_or("").to_string(),
    };
    let events = root
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| Error::Protocol("trace: missing traceEvents".into()))?;
    let mut spans = Vec::new();
    for ev in events {
        let ph = ev.str("ph").unwrap_or("");
        if ph != "X" && ph != "i" {
            continue;
        }
        let args = match ev.get("args") {
            Some(a) => a,
            None => continue,
        };
        let kind = match args.str("kind").and_then(SpanKind::parse) {
            Some(k) => k,
            None => continue,
        };
        let t0 = ev.num("ts").unwrap_or(0.0);
        let dur = ev.num("dur").unwrap_or(0.0);
        spans.push(Span {
            kind,
            rank: ev.num("tid").unwrap_or(0.0) as usize,
            peer: args.num("peer").unwrap_or(-1.0) as i32,
            tag: args.num("tag").unwrap_or(0.0) as u32,
            seq: args.num("seq").unwrap_or(0.0) as u64,
            bytes: args.num("bytes").unwrap_or(0.0) as u64,
            aux: args.num("aux").unwrap_or(0.0) as u32,
            t0_us: t0,
            t1_us: t0 + dur,
            w0_ns: 0,
            w1_ns: 0,
        });
    }
    Ok((meta, spans))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{Event, EventKind, Trace, TraceMeta};

    fn meta() -> TraceMeta {
        TraceMeta {
            algo: "dpdr".into(),
            p: 2,
            m_elems: 8,
            elem_bytes: 4,
            blocks: 2,
            alpha: 1e-6,
            beta: 0.7e-9,
            gamma: 0.0,
            virtual_time: true,
            source: "test".into(),
        }
    }

    fn small_trace() -> Trace {
        // rank 0 sends 32 B to rank 1 at t=0, transfer takes 1 µs on
        // each side; rank 1 also reduces for 0.5 µs.
        let events = vec![
            Event::new(EventKind::SendStart, 0).peer(1).bytes(32).at_us(0.0),
            Event::new(EventKind::SendEnd, 0).peer(1).bytes(32).at_us(1.0),
            Event::new(EventKind::RecvStart, 1).peer(0).bytes(32).at_us(0.0),
            Event::new(EventKind::RecvEnd, 1).peer(0).bytes(32).at_us(1.0),
            Event::new(EventKind::Reduce, 1).bytes(32).at_us(1.0).dur_us(0.5),
        ];
        Trace {
            meta: meta(),
            events,
            dropped: 0,
            recorded: 5,
        }
    }

    #[test]
    fn pairing_folds_endpoints_into_spans() {
        let t = small_trace();
        let spans = spans_of(&t.events);
        assert_eq!(spans.len(), 3);
        assert_eq!(spans[0].kind, SpanKind::Send);
        assert_eq!((spans[0].t0_us, spans[0].t1_us), (0.0, 1.0));
        assert_eq!(spans[1].kind, SpanKind::Recv);
        assert_eq!(spans[2].kind, SpanKind::Reduce);
        assert_eq!(spans[2].t1_us, 1.5);
    }

    #[test]
    fn export_round_trips_through_reader() {
        let t = small_trace();
        let text = to_chrome_json(&t);
        let (m, spans) = read_chrome_json(&text).unwrap();
        assert_eq!(m, t.meta);
        assert_eq!(spans.len(), 3);
        let orig = spans_of(&t.events);
        for (a, b) in orig.iter().zip(&spans) {
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.rank, b.rank);
            assert_eq!(a.peer, b.peer);
            assert_eq!(a.bytes, b.bytes);
            assert_eq!(a.t0_us.to_bits(), b.t0_us.to_bits());
            assert_eq!(a.t1_us.to_bits(), b.t1_us.to_bits());
        }
    }

    #[test]
    fn export_has_flow_pair_and_track_names() {
        let text = to_chrome_json(&small_trace());
        let root = crate::obs::json::parse(&text).unwrap();
        let evs = root.get("traceEvents").unwrap().as_arr().unwrap();
        let n_s = evs.iter().filter(|e| e.str("ph") == Some("s")).count();
        let n_f = evs.iter().filter(|e| e.str("ph") == Some("f")).count();
        assert_eq!((n_s, n_f), (1, 1));
        let names = evs
            .iter()
            .filter(|e| e.str("name") == Some("thread_name"))
            .count();
        assert_eq!(names, 2);
        // Flow ids match between the s and f halves.
        let sid = evs.iter().find(|e| e.str("ph") == Some("s")).unwrap().str("id");
        let fid = evs.iter().find(|e| e.str("ph") == Some("f")).unwrap().str("id");
        assert_eq!(sid, fid);
    }

    #[test]
    fn unpaired_endpoints_survive_as_zero_spans() {
        let events = vec![Event::new(EventKind::SendStart, 0).peer(1).bytes(8).at_us(2.0)];
        let spans = spans_of(&events);
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].kind, SpanKind::Send);
        assert_eq!(spans[0].t0_us, spans[0].t1_us);
    }
}
