//! # obs — event-level tracing: per-rank timelines, Perfetto export, and
//! critical-path attribution against the α-β model.
//!
//! An always-compiled, runtime-toggled observability layer. When enabled
//! (`obs::start`), every rank records typed [`Event`]s — p2p transfer
//! endpoints (`SendStart`/`SendEnd`, `RecvStart`/`RecvEnd`), reduction
//! charges (`Reduce`) and kernel invocations (`ReduceKernel`), congestion
//! stalls (`Stall`), barriers, nbc op-lifecycle marks
//! (`OpSubmit`/`OpQueue`/`OpFuse`/`OpLaunch`/`OpWait`), and
//! schedule-engine step retirements (`Step`) — into a bounded per-rank
//! ring buffer, each stamped with both the virtual clock (µs) and a wall
//! clock (ns since trace start). Matching send/recv pairs share a
//! per-`(endpoint, peer)` sequence number, which is what lets the
//! exporter draw sender→receiver flow arrows and the critical-path
//! analyzer hop across ranks.
//!
//! Design constraints, in order:
//!
//! 1. **Zero cost when off.** Every instrumentation hook in the hot
//!    paths (`comm/thread.rs`, `comm/net.rs`, `schedule/exec.rs`,
//!    `nbc/mod.rs`, `ops/backend.rs`) is guarded by [`enabled`] — a
//!    single relaxed atomic load. No allocation, no locking, no time
//!    query happens on the disabled path, so the alloc-flatness
//!    property tests hold with the tracing layer compiled in.
//! 2. **Deterministic under `Timing::Virtual`.** Virtual stamps come
//!    from the simulated clock, sequence numbers from per-endpoint
//!    program order, and [`stop`] sorts the stream by a total key that
//!    excludes wall time; the exporter omits wall fields for virtual
//!    traces. Two runs of the same spec therefore export bitwise
//!    identical JSON — traces are diffable artifacts, like the
//!    schedule certs.
//! 3. **Bounded memory.** Rings drop their oldest events once full and
//!    count the drops; [`Trace::dropped`] makes truncation visible
//!    instead of silent.
//!
//! See [`export`] for the Chrome-trace/Perfetto serialization and
//! [`critical`] for the happens-before walk and α/β/γ/stall
//! attribution.

pub mod critical;
pub mod export;
pub mod json;

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// What happened. Transfer endpoints come in start/end pairs matched by
/// `(rank, peer, tag, seq)`; the remaining kinds are self-contained
/// spans (nonzero `dur_us`) or instants.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum EventKind {
    /// Outgoing transfer admitted to the link (post-backpressure).
    SendStart,
    /// Outgoing transfer complete (sender side).
    SendEnd,
    /// Incoming transfer began (message available and port granted).
    RecvStart,
    /// Incoming transfer delivered.
    RecvEnd,
    /// Virtual γ-charge for a block reduction (span).
    Reduce,
    /// A reduce kernel dispatch in `ops::backend` (stamped at kernel
    /// completion; `aux` is the backend that ran: 0 scalar, 1 simd,
    /// 2 pjrt; `bytes` holds the combined element count).
    ReduceKernel,
    /// Clock stall (span; `aux` is the cause: 0 edge-queue
    /// backpressure, 1 egress port contention, 2 ingress port
    /// contention).
    Stall,
    /// Barrier (span from entry to group release).
    Barrier,
    /// Nonblocking op submitted to the engine (instant).
    OpSubmit,
    /// Op parked in the fusion queue (instant).
    OpQueue,
    /// Fusion batch closed (`aux` = ops in the batch; `bytes` = fused
    /// payload bytes).
    OpFuse,
    /// Op (or fused batch) launched onto a worker / the progress core
    /// (instant).
    OpLaunch,
    /// Op waited on and retired (span over the op's virtual lifetime).
    OpWait,
    /// Schedule-engine half-step retired (`aux` = program counter).
    Step,
}

impl EventKind {
    /// Stable lowercase name (used in exported JSON `args.kind`).
    pub fn name(self) -> &'static str {
        match self {
            EventKind::SendStart => "send_start",
            EventKind::SendEnd => "send_end",
            EventKind::RecvStart => "recv_start",
            EventKind::RecvEnd => "recv_end",
            EventKind::Reduce => "reduce",
            EventKind::ReduceKernel => "reduce_kernel",
            EventKind::Stall => "stall",
            EventKind::Barrier => "barrier",
            EventKind::OpSubmit => "op_submit",
            EventKind::OpQueue => "op_queue",
            EventKind::OpFuse => "op_fuse",
            EventKind::OpLaunch => "op_launch",
            EventKind::OpWait => "op_wait",
            EventKind::Step => "step",
        }
    }

    /// Inverse of [`EventKind::name`].
    pub fn parse(s: &str) -> Option<EventKind> {
        Some(match s {
            "send_start" => EventKind::SendStart,
            "send_end" => EventKind::SendEnd,
            "recv_start" => EventKind::RecvStart,
            "recv_end" => EventKind::RecvEnd,
            "reduce" => EventKind::Reduce,
            "reduce_kernel" => EventKind::ReduceKernel,
            "stall" => EventKind::Stall,
            "barrier" => EventKind::Barrier,
            "op_submit" => EventKind::OpSubmit,
            "op_queue" => EventKind::OpQueue,
            "op_fuse" => EventKind::OpFuse,
            "op_launch" => EventKind::OpLaunch,
            "op_wait" => EventKind::OpWait,
            "step" => EventKind::Step,
            _ => return None,
        })
    }

    fn order(self) -> u8 {
        self as u8
    }
}

/// Stall causes (the `aux` code of [`EventKind::Stall`]).
pub mod stall_cause {
    /// Sender blocked on a full virtual edge queue (backpressure).
    pub const BACKPRESSURE: u32 = 0;
    /// Sender serialized behind other transfers on its NIC ports.
    pub const EGRESS_PORT: u32 = 1;
    /// Receiver serialized behind other transfers on its NIC ports.
    pub const INGRESS_PORT: u32 = 2;

    /// Human-readable cause name.
    pub fn name(aux: u32) -> &'static str {
        match aux {
            BACKPRESSURE => "backpressure",
            EGRESS_PORT => "egress_port",
            INGRESS_PORT => "ingress_port",
            _ => "stall",
        }
    }
}

/// One recorded event. 64 bytes; copied into the ring by value.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Event {
    pub kind: EventKind,
    /// Recording rank.
    pub rank: u32,
    /// Peer rank for p2p events, -1 when not applicable.
    pub peer: i32,
    /// Communicator tag (0 = the blocking world channel).
    pub tag: u32,
    /// Per-`(endpoint, peer, direction)` sequence number linking the
    /// k-th send on an edge to the k-th receive.
    pub seq: u64,
    /// Payload size in bytes (0 when not applicable).
    pub bytes: u64,
    /// Virtual-clock stamp, µs (0 under `Timing::Real`).
    pub t_us: f64,
    /// Virtual duration for span kinds, µs.
    pub dur_us: f64,
    /// Wall-clock stamp, ns since `obs::start` (excluded from virtual
    /// exports and from the deterministic sort key).
    pub wall_ns: u64,
    /// Kind-specific payload (stall cause, backend id, batch size,
    /// program counter).
    pub aux: u32,
}

impl Event {
    /// A fresh event with every optional field zeroed.
    pub fn new(kind: EventKind, rank: usize) -> Event {
        Event {
            kind,
            rank: rank as u32,
            peer: -1,
            tag: 0,
            seq: 0,
            bytes: 0,
            t_us: 0.0,
            dur_us: 0.0,
            wall_ns: 0,
            aux: 0,
        }
    }

    pub fn peer(mut self, peer: usize) -> Event {
        self.peer = peer as i32;
        self
    }

    pub fn tag(mut self, tag: u32) -> Event {
        self.tag = tag;
        self
    }

    pub fn seq(mut self, seq: u64) -> Event {
        self.seq = seq;
        self
    }

    pub fn bytes(mut self, bytes: u64) -> Event {
        self.bytes = bytes;
        self
    }

    /// Virtual stamp in µs from a clock in seconds.
    pub fn at_s(mut self, t_s: f64) -> Event {
        self.t_us = t_s * 1e6;
        self
    }

    pub fn at_us(mut self, t_us: f64) -> Event {
        self.t_us = t_us;
        self
    }

    /// Virtual duration in µs from a span in seconds.
    pub fn span_s(mut self, from_s: f64, to_s: f64) -> Event {
        self.t_us = from_s * 1e6;
        self.dur_us = (to_s - from_s) * 1e6;
        self
    }

    pub fn dur_us(mut self, dur_us: f64) -> Event {
        self.dur_us = dur_us;
        self
    }

    pub fn wall(mut self, wall_ns: u64) -> Event {
        self.wall_ns = wall_ns;
        self
    }

    pub fn aux(mut self, aux: u32) -> Event {
        self.aux = aux;
        self
    }

    /// Rewrite the kind (for deriving an `*End` event from its start).
    pub fn with_kind(mut self, kind: EventKind) -> Event {
        self.kind = kind;
        self
    }

    /// Total deterministic order: rank, then virtual time, then kind /
    /// addressing fields. Wall time is deliberately excluded so the
    /// sorted stream is run-to-run stable under `Timing::Virtual`.
    fn sort_key(&self) -> (u32, u64, u8, u32, i32, u64, u64, u64) {
        (
            self.rank,
            self.t_us.to_bits(),
            self.kind.order(),
            self.tag,
            self.peer,
            self.seq,
            self.bytes,
            self.dur_us.to_bits(),
        )
    }
}

/// Bounded drop-oldest event ring.
struct Ring {
    cap: usize,
    buf: Vec<Event>,
    /// Index of the oldest event once the ring has wrapped.
    start: usize,
    dropped: u64,
}

impl Ring {
    fn new(cap: usize) -> Ring {
        Ring {
            cap: cap.max(1),
            buf: Vec::new(),
            start: 0,
            dropped: 0,
        }
    }

    fn push(&mut self, ev: Event) {
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            self.buf[self.start] = ev;
            self.start = (self.start + 1) % self.cap;
            self.dropped += 1;
        }
    }

    fn drain(mut self) -> (Vec<Event>, u64) {
        self.buf.rotate_left(self.start);
        (self.buf, self.dropped)
    }
}

/// The active collector: one ring per rank.
struct Collector {
    rings: Vec<Mutex<Ring>>,
    recorded: AtomicU64,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static SINK: Mutex<Option<Arc<Collector>>> = Mutex::new(None);
static EPOCH: OnceLock<Instant> = OnceLock::new();

thread_local! {
    /// Rank bound to this thread (for hooks below the comm layer, e.g.
    /// reduce kernels). -1 = unbound.
    static BOUND_RANK: Cell<i32> = const { Cell::new(-1) };
    /// Last virtual clock seen by this thread's comm hooks, µs. Used to
    /// place events from layers that have no clock of their own.
    static VTIME_HINT: Cell<f64> = const { Cell::new(0.0) };
}

/// Is tracing on? One relaxed atomic load — this is the entire cost of
/// every instrumentation hook while tracing is disabled.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Begin recording into fresh per-rank rings of `cap_per_rank` events.
/// Returns false (and leaves the running collector untouched) if a
/// trace is already active.
pub fn start(p: usize, cap_per_rank: usize) -> bool {
    let mut sink = SINK.lock().unwrap();
    if sink.is_some() {
        return false;
    }
    EPOCH.get_or_init(Instant::now);
    let rings = (0..p).map(|_| Mutex::new(Ring::new(cap_per_rank))).collect();
    *sink = Some(Arc::new(Collector {
        rings,
        recorded: AtomicU64::new(0),
    }));
    ENABLED.store(true, Ordering::SeqCst);
    true
}

/// Stop recording and return the collected trace (events sorted by the
/// deterministic key). Returns `None` when no trace was active.
pub fn stop(meta: TraceMeta) -> Option<Trace> {
    ENABLED.store(false, Ordering::SeqCst);
    let collector = SINK.lock().unwrap().take()?;
    // A racing `record` may still hold a clone for an instant; spin
    // until we are the sole owner rather than lose the buffers.
    let mut collector = collector;
    let collector = loop {
        match Arc::try_unwrap(collector) {
            Ok(c) => break c,
            Err(arc) => {
                collector = arc;
                std::thread::yield_now();
            }
        }
    };
    let recorded = collector.recorded.load(Ordering::SeqCst);
    let mut events = Vec::new();
    let mut dropped = 0u64;
    for ring in collector.rings {
        let (evs, d) = ring.into_inner().unwrap().drain();
        events.extend(evs);
        dropped += d;
    }
    events.sort_by_key(Event::sort_key);
    Some(Trace {
        meta,
        events,
        dropped,
        recorded,
    })
}

/// Append an event to its rank's ring. Cheap no-op when tracing is off;
/// callers on hot paths should still guard with [`enabled`] so the
/// event-construction work is skipped too.
pub fn record(ev: Event) {
    if !enabled() {
        return;
    }
    let sink = SINK.lock().unwrap().clone();
    if let Some(c) = sink {
        if let Some(ring) = c.rings.get(ev.rank as usize) {
            ring.lock().unwrap().push(ev);
            c.recorded.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Events recorded by the active trace so far (0 when none active).
pub fn recorded_count() -> u64 {
    SINK.lock()
        .unwrap()
        .as_ref()
        .map(|c| c.recorded.load(Ordering::Relaxed))
        .unwrap_or(0)
}

/// Wall clock in ns since the first trace started (0 before any).
pub fn wall_now_ns() -> u64 {
    EPOCH
        .get()
        .map(|e| e.elapsed().as_nanos() as u64)
        .unwrap_or(0)
}

/// Bind the calling thread to a rank so hooks below the comm layer
/// (reduce kernels) can attribute their events. Rank threads and nbc
/// workers call this on spawn when tracing is on.
pub fn bind_rank(rank: usize) {
    BOUND_RANK.with(|r| r.set(rank as i32));
}

/// The rank bound to this thread, if any.
pub fn bound_rank() -> Option<usize> {
    let r = BOUND_RANK.with(|r| r.get());
    (r >= 0).then_some(r as usize)
}

/// Note the thread's current virtual clock (µs); comm hooks call this
/// so clock-less layers can place their events nearby.
pub fn note_vtime_us(t_us: f64) {
    VTIME_HINT.with(|v| v.set(t_us));
}

/// Latest virtual clock seen on this thread, µs.
pub fn vtime_hint_us() -> f64 {
    VTIME_HINT.with(|v| v.get())
}

/// Run metadata carried into the export so traces are self-describing
/// and the critical-path analyzer can rebuild the model comparison.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TraceMeta {
    /// Algorithm name (`AlgoKind::name`), or "soak"/"mixed".
    pub algo: String,
    pub p: usize,
    /// Element count of the collective (0 when mixed).
    pub m_elems: usize,
    pub elem_bytes: usize,
    /// Pipeline block count (0 when unknown/mixed).
    pub blocks: usize,
    /// Uniform-model α in seconds (0 when not uniform virtual).
    pub alpha: f64,
    /// Uniform-model β in s/B.
    pub beta: f64,
    /// γ in s/B.
    pub gamma: f64,
    /// True when the run used `Timing::Virtual` — wall fields are then
    /// omitted from the export to keep it deterministic.
    pub virtual_time: bool,
    /// Producing subcommand ("run", "soak", ...).
    pub source: String,
}

/// A completed recording: sorted events plus bookkeeping.
#[derive(Clone, Debug)]
pub struct Trace {
    pub meta: TraceMeta,
    pub events: Vec<Event>,
    /// Events lost to ring overflow (oldest-first).
    pub dropped: u64,
    /// Total events offered to the rings.
    pub recorded: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_drops_oldest() {
        let mut r = Ring::new(3);
        for i in 0..5u64 {
            r.push(Event::new(EventKind::Step, 0).seq(i));
        }
        let (evs, dropped) = r.drain();
        assert_eq!(dropped, 2);
        let seqs: Vec<u64> = evs.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![2, 3, 4]);
    }

    #[test]
    fn kind_names_round_trip() {
        let kinds = [
            EventKind::SendStart,
            EventKind::SendEnd,
            EventKind::RecvStart,
            EventKind::RecvEnd,
            EventKind::Reduce,
            EventKind::ReduceKernel,
            EventKind::Stall,
            EventKind::Barrier,
            EventKind::OpSubmit,
            EventKind::OpQueue,
            EventKind::OpFuse,
            EventKind::OpLaunch,
            EventKind::OpWait,
            EventKind::Step,
        ];
        for k in kinds {
            assert_eq!(EventKind::parse(k.name()), Some(k));
        }
        assert_eq!(EventKind::parse("nope"), None);
    }

    #[test]
    fn sort_key_ignores_wall_time() {
        let a = Event::new(EventKind::SendStart, 1).at_us(2.0).wall(7);
        let b = Event::new(EventKind::SendStart, 1).at_us(2.0).wall(99);
        assert_eq!(a.sort_key(), b.sort_key());
        let later = Event::new(EventKind::SendStart, 1).at_us(3.0);
        assert!(later.sort_key() > a.sort_key());
        let other_rank = Event::new(EventKind::SendStart, 0).at_us(9.0);
        assert!(other_rank.sort_key() < a.sort_key());
    }

    // The start/stop lifecycle itself is covered by the world-level
    // integration tests in `tests/obs_trace.rs`, which serialize access
    // to the process-global collector.
}
