//! A minimal JSON reader for the trace tooling. The crate writes its
//! JSON artifacts by hand (no serde in the offline registry); this is
//! the matching reader — a small recursive-descent parser producing a
//! dynamically-typed [`Value`] tree, enough to load Chrome-trace files
//! back for critical-path analysis and for the test suite to validate
//! exported artifacts.

use crate::error::{Error, Result};
use std::collections::HashMap;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(HashMap<String, Value>),
}

impl Value {
    /// Object member lookup (`None` for non-objects/missing keys).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric member as f64 (missing/mistyped → `None`).
    pub fn num(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Value::as_f64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    /// String member (missing/mistyped → `None`).
    pub fn str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Value::as_str)
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a.as_slice()),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parse a complete JSON document; trailing non-whitespace is an error.
pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after JSON document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn err(&self, msg: &str) -> Error {
        Error::Protocol(format!("json: {msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = HashMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .and_then(|h| u32::from_str_radix(h, 16).ok())
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // Surrogate pairs are not produced by our
                            // own writer; map lone surrogates to the
                            // replacement character.
                            out.push(char::from_u32(hex).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(first) => {
                    // Consume one UTF-8 scalar. The input came from a
                    // &str, so byte positions stay on char boundaries;
                    // decode the (≤ 4 byte) head safely.
                    let len = match first {
                        b if b < 0x80 => 1,
                        b if b >= 0xF0 => 4,
                        b if b >= 0xE0 => 3,
                        _ => 2,
                    };
                    let end = (self.pos + len).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[self.pos..end])
                        .map_err(|_| self.err("invalid utf-8 in string"))?;
                    let ch = chunk.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse(" true ").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("-12.5e2").unwrap(), Value::Num(-1250.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn nested() {
        let v = parse(r#"{"a":[1,2,{"b":"x"}],"c":{"d":null}}"#).unwrap();
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0], Value::Num(1.0));
        assert_eq!(arr[2].str("b"), Some("x"));
        assert_eq!(v.get("c").unwrap().get("d"), Some(&Value::Null));
        assert_eq!(v.num("missing"), None);
    }

    #[test]
    fn unicode_escapes_and_utf8() {
        assert_eq!(parse("\"\\u0041µ\"").unwrap(), Value::Str("Aµ".into()));
    }

    #[test]
    fn errors() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":1} extra").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn round_trips_f64_display() {
        // The writers print f64 with `{}` (shortest round-trip); the
        // reader must recover the exact bits.
        for x in [0.1, 1.0 / 3.0, 123456.789012345, 1e-9, 7.25] {
            let v = parse(&format!("{x}")).unwrap();
            assert_eq!(v.as_f64().unwrap().to_bits(), x.to_bits());
        }
    }
}
