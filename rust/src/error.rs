//! Crate-wide error type.
//!
//! We deliberately keep a small, explicit error enum instead of threading
//! `anyhow` through the library API: collective algorithms have a small set
//! of well-defined failure modes (bad topology parameters, transport
//! disconnect, artifact problems) and callers (benches, the CLI, tests)
//! match on them.

use std::fmt;

/// Errors produced by the dpdr library.
#[derive(Debug)]
pub enum Error {
    /// Invalid run configuration (p, m, block size, ...).
    Config(String),
    /// A transport endpoint disappeared (peer thread panicked / dropped).
    Disconnected { rank: usize, peer: usize },
    /// Message arrived that does not match protocol expectations.
    Protocol(String),
    /// Mismatch between a real and a phantom buffer in the same exchange.
    BufferMode(String),
    /// PJRT runtime / artifact loading problems.
    Runtime(String),
    /// CLI parse errors.
    Cli(String),
    /// I/O errors (artifact files, TSV output).
    Io(std::io::Error),
    /// The nbc tag counter ran off the end of its `u32` space and the
    /// free pool was empty (pre-reclamation safety net; with epochs
    /// enabled, recycled tags make this unreachable in practice).
    TagsExhausted,
    /// A nonblocking operation finished after its deadline. The op
    /// completed (the world is intact); the caller chose not to use a
    /// result this late.
    Deadline {
        op: u64,
        deadline_us: f64,
        took_us: f64,
    },
    /// A peer made no progress within the receive watchdog — the moral
    /// equivalent of a deadlock or a dead rank under serving traffic.
    PeerStalled { rank: usize, peer: usize },
    /// Admission control: the engine already holds its in-flight budget
    /// of unwaited operations; quiesce (`wait_all`) and resubmit.
    Overloaded { in_flight: usize, budget: usize },
    /// The transient-drop fault mode dropped every retransmit attempt of
    /// one message (bounded retries with backoff all failed).
    RetriesExhausted {
        rank: usize,
        peer: usize,
        attempts: u32,
    },
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(s) => write!(f, "configuration error: {s}"),
            Error::Disconnected { rank, peer } => {
                write!(f, "rank {rank}: transport to peer {peer} disconnected")
            }
            Error::Protocol(s) => write!(f, "protocol error: {s}"),
            Error::BufferMode(s) => write!(f, "buffer mode error: {s}"),
            Error::Runtime(s) => write!(f, "runtime error: {s}"),
            Error::Cli(s) => write!(f, "cli error: {s}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::TagsExhausted => {
                write!(f, "nbc tag space exhausted (no free tags; enable epoch reclamation)")
            }
            Error::Deadline {
                op,
                deadline_us,
                took_us,
            } => write!(
                f,
                "op {op} missed its deadline: took {took_us:.2} us, deadline {deadline_us:.2} us"
            ),
            Error::PeerStalled { rank, peer } => write!(
                f,
                "rank {rank}: peer {peer} stalled past the watchdog — likely protocol deadlock or dead rank"
            ),
            Error::Overloaded { in_flight, budget } => write!(
                f,
                "engine overloaded: {in_flight} ops in flight at budget {budget}; wait_all and resubmit"
            ),
            Error::RetriesExhausted {
                rank,
                peer,
                attempts,
            } => write!(
                f,
                "rank {rank}: gave up sending to peer {peer} after {attempts} retransmit attempts"
            ),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = Error::Config("p must be > 0".into());
        assert!(e.to_string().contains("p must be > 0"));
        let e = Error::Disconnected { rank: 3, peer: 7 };
        assert!(e.to_string().contains("rank 3"));
        assert!(e.to_string().contains("peer 7"));
    }

    #[test]
    fn serving_variants_format() {
        // the watchdog keyword contract: stall reports must read as a
        // deadlock diagnosis (tests/failure_injection.rs matches on it)
        let e = Error::PeerStalled { rank: 1, peer: 0 };
        assert!(e.to_string().contains("deadlock"), "{e}");
        let e = Error::Deadline {
            op: 7,
            deadline_us: 10.0,
            took_us: 25.5,
        };
        assert!(e.to_string().contains("deadline"), "{e}");
        let e = Error::Overloaded {
            in_flight: 64,
            budget: 64,
        };
        assert!(e.to_string().contains("overloaded"), "{e}");
        let e = Error::RetriesExhausted {
            rank: 2,
            peer: 3,
            attempts: 6,
        };
        assert!(e.to_string().contains("retransmit"), "{e}");
        assert!(Error::TagsExhausted.to_string().contains("tag space"));
    }

    #[test]
    fn io_conversion() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
