//! Crate-wide error type.
//!
//! We deliberately keep a small, explicit error enum instead of threading
//! `anyhow` through the library API: collective algorithms have a small set
//! of well-defined failure modes (bad topology parameters, transport
//! disconnect, artifact problems) and callers (benches, the CLI, tests)
//! match on them.

use std::fmt;

/// Errors produced by the dpdr library.
#[derive(Debug)]
pub enum Error {
    /// Invalid run configuration (p, m, block size, ...).
    Config(String),
    /// A transport endpoint disappeared (peer thread panicked / dropped).
    Disconnected { rank: usize, peer: usize },
    /// Message arrived that does not match protocol expectations.
    Protocol(String),
    /// Mismatch between a real and a phantom buffer in the same exchange.
    BufferMode(String),
    /// PJRT runtime / artifact loading problems.
    Runtime(String),
    /// CLI parse errors.
    Cli(String),
    /// I/O errors (artifact files, TSV output).
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(s) => write!(f, "configuration error: {s}"),
            Error::Disconnected { rank, peer } => {
                write!(f, "rank {rank}: transport to peer {peer} disconnected")
            }
            Error::Protocol(s) => write!(f, "protocol error: {s}"),
            Error::BufferMode(s) => write!(f, "buffer mode error: {s}"),
            Error::Runtime(s) => write!(f, "runtime error: {s}"),
            Error::Cli(s) => write!(f, "cli error: {s}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = Error::Config("p must be > 0".into());
        assert!(e.to_string().contains("p must be > 0"));
        let e = Error::Disconnected { rank: 3, peer: 7 };
        assert!(e.to_string().contains("rank 3"));
        assert!(e.to_string().contains("peer 7"));
    }

    #[test]
    fn io_conversion() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
