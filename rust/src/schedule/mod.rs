//! Compile collectives to per-rank step schedules.
//!
//! The blocking collectives in [`crate::collectives`] are *statically
//! schedulable*: for a fixed `(algo, rank, p, blocks)` the exact sequence
//! of point-to-point calls — peers, payload block ranges, reduction
//! sinks — is known before the first byte moves. This module lowers that
//! structure into an explicit [`Schedule`]: a linear program of
//! [`Step`]s whose only dependencies are program order within a rank and
//! the messages between ranks.
//!
//! Schedules are what the event-driven progress core
//! ([`exec`]) executes: instead of one OS thread per in-flight
//! nonblocking collective, a single per-rank progress loop multiplexes
//! the ready steps of *all* outstanding operations. The blocking
//! implementations stay in place as the oracle — the compiler is
//! verified step-for-step against them by tracing ([`TraceComm`]) every
//! communicator call a blocking run makes and comparing against
//! [`expected_events`] of the compiled schedules.
//!
//! Covered algorithms: [`AlgoKind::Dpdr`], [`AlgoKind::DpdrSingle`],
//! [`AlgoKind::Ring`], [`AlgoKind::RecursiveDoubling`]. Everything else
//! (`Hier` needs sub-communicators, `TwoTree`/`Scan`/the non-pipelined
//! baselines are rarely issued through the nonblocking engine) returns
//! `None` from [`compile`] and falls back to the threaded worker path.

pub mod exec;
pub mod verify;

use crate::model::AlgoKind;
use crate::ops::Side;
use crate::pipeline::Blocks;
use crate::topo::{DualRootForest, NodeRole, PostOrderTree, TreeId};

/// Where a step's outgoing payload comes from, relative to the rank's
/// working vector `y`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Src {
    /// A zero-element void block (`y.empty_like()`): the step is a pure
    /// receive dressed as an exchange.
    Void,
    /// Zero-copy view `y[lo..hi]`.
    Block { lo: usize, hi: usize },
    /// Owned (pooled) copy of `y[lo..hi]` — the dual-root exchange sends
    /// an owned block because both roots reduce into the same range in
    /// the same round (see `collectives::dpdr`).
    OwnedBlock { lo: usize, hi: usize },
    /// Send-time snapshot of the whole vector (the recursive-doubling
    /// butterfly overwrites `y` while the sent copy is in flight).
    Snapshot,
    /// Reference-counted clone of the whole vector (pre/post-fold
    /// forwarding in recursive doubling).
    CloneY,
}

/// What happens to a step's received payload `t`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sink {
    /// Drop it (the receive direction was void or synchronization-only).
    Discard,
    /// `y.write_at(lo, &t)` — final result block flowing down, no γ
    /// charge (matching the blocking implementations).
    WriteAt { lo: usize },
    /// Charge γ for `t`, then `y.reduce_at(lo, &t, op, side)`.
    ReduceAt { lo: usize, side: Side },
    /// Charge γ for `t`, then stash it as `t0` for the following
    /// [`Sink::Reduce3At`] — the first half of a fused dpdr inner round.
    StashCharged,
    /// Charge γ for `t`, then `y.reduce_at3(lo, &stash, &t, op)` — the
    /// fused `t1 ⊙ (t0 ⊙ Y[j])` inner round.
    Reduce3At { lo: usize },
    /// Charge γ for `t`, then `y.reduce_all(&t, op, side)`.
    ReduceAll { side: Side },
    /// Replace the whole vector with `t` (post-fold), no γ charge.
    ReplaceY,
}

/// One communicator call of a rank's program. Dependencies are implicit:
/// steps of one rank run in program order, and a receive waits for the
/// matching send of the peer's program.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Step {
    /// Bidirectional exchange with one peer (`Comm::sendrecv`).
    SendRecv { peer: usize, send: Src, sink: Sink },
    /// Full-duplex exchange with distinct partners
    /// (`Comm::sendrecv_pair`). The compiler never emits this with
    /// `send_to == recv_from` — that case lowers to [`Step::SendRecv`],
    /// mirroring the transport's own delegation.
    SendRecvPair {
        send_to: usize,
        recv_from: usize,
        send: Src,
        sink: Sink,
    },
    /// One-directional send.
    Send { peer: usize, send: Src },
    /// One-directional receive.
    Recv { peer: usize, sink: Sink },
}

impl Step {
    /// The peer this step receives from, if it receives at all.
    pub fn recv_from(&self) -> Option<usize> {
        match *self {
            Step::SendRecv { peer, .. } => Some(peer),
            Step::SendRecvPair { recv_from, .. } => Some(recv_from),
            Step::Recv { peer, .. } => Some(peer),
            Step::Send { .. } => None,
        }
    }
}

/// One rank's compiled program for one collective operation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Schedule {
    pub rank: usize,
    pub size: usize,
    pub steps: Vec<Step>,
}

/// Lower `(algo, rank, size, blocks)` to a [`Schedule`], or `None` when
/// the algorithm is not statically compiled (the caller falls back to
/// the threaded blocking path). `blocks.total()` must equal the payload
/// length — the nonblocking engine checks this before scheduling.
pub fn compile(algo: AlgoKind, rank: usize, size: usize, blocks: &Blocks) -> Option<Schedule> {
    let compiled = matches!(
        algo,
        AlgoKind::Dpdr | AlgoKind::DpdrSingle | AlgoKind::Ring | AlgoKind::RecursiveDoubling
    );
    if !compiled {
        return None;
    }
    let m = blocks.total();
    // the blocking implementations all short-circuit to the identity
    let steps = if size == 1 || m == 0 {
        Vec::new()
    } else {
        match algo {
            AlgoKind::Dpdr => {
                let forest = DualRootForest::new(size).ok()?;
                let role = forest.role(rank).ok()?;
                dpdr_steps(blocks, &role)
            }
            AlgoKind::DpdrSingle => {
                let tree = PostOrderTree::new(0, size - 1).ok()?;
                let role = NodeRole {
                    tree: TreeId::A,
                    depth: tree.depth(rank),
                    children: tree.children(rank),
                    parent: tree.parent(rank),
                    dual: None,
                    lower_root: false,
                };
                dpdr_steps(blocks, &role)
            }
            AlgoKind::Ring => ring_steps(rank, size, m),
            AlgoKind::RecursiveDoubling => rd_steps(rank, size),
            _ => unreachable!("guarded above"),
        }
    };
    Some(Schedule { rank, size, steps })
}

/// The round loop of Algorithm 1 (`collectives::dpdr::run_rounds`),
/// lowered to steps. Mirrors the blocking code line for line: same round
/// bound, same activity predicates, same fused inner-round shape.
fn dpdr_steps(blocks: &Blocks, role: &NodeRole) -> Vec<Step> {
    let d = role.depth;
    let b = blocks.count();
    let src_or_void = |k: isize| -> Src {
        if k < 0 || k as usize >= b {
            Src::Void
        } else {
            let (lo, hi) = blocks.range(k as usize);
            Src::Block { lo, hi }
        }
    };
    let mut steps = Vec::new();
    for j in 0..=(b + d) {
        // --- steps 1 & 2: the two children ---------------------------
        let up_active = j < b;
        let down_idx = j as isize - (d as isize + 1);
        let down_active = down_idx >= 0 && (down_idx as usize) < b;
        if let (true, Some(c0), Some(c1)) = (up_active, role.children[0], role.children[1]) {
            // fused inner round: Y[j] ← t1 ⊙ (t0 ⊙ Y[j])
            let (lo, _hi) = blocks.range(j);
            steps.push(Step::SendRecv {
                peer: c0,
                send: src_or_void(down_idx),
                sink: Sink::StashCharged,
            });
            steps.push(Step::SendRecv {
                peer: c1,
                send: src_or_void(down_idx),
                sink: Sink::Reduce3At { lo },
            });
        } else {
            for child in role.children.into_iter().flatten() {
                if !up_active && !down_active {
                    continue; // both directions void — skipped symmetrically
                }
                let sink = if up_active {
                    let (lo, _hi) = blocks.range(j);
                    Sink::ReduceAt {
                        lo,
                        side: Side::Left,
                    }
                } else {
                    Sink::Discard
                };
                steps.push(Step::SendRecv {
                    peer: child,
                    send: src_or_void(down_idx),
                    sink,
                });
            }
        }

        // --- step 3: dual root, or parent ----------------------------
        if let Some(dual) = role.dual {
            if j < b {
                let (lo, hi) = blocks.range(j);
                let side = if role.lower_root { Side::Right } else { Side::Left };
                steps.push(Step::SendRecv {
                    peer: dual,
                    send: Src::OwnedBlock { lo, hi },
                    sink: Sink::ReduceAt { lo, side },
                });
            }
        } else if let Some(parent) = role.parent {
            let up = j < b;
            let didx = j as isize - d as isize;
            let dact = didx >= 0 && (didx as usize) < b;
            if up || dact {
                let send = if up { src_or_void(j as isize) } else { Src::Void };
                let sink = if dact {
                    let (lo, _hi) = blocks.range(didx as usize);
                    Sink::WriteAt { lo }
                } else {
                    Sink::Discard
                };
                steps.push(Step::SendRecv { peer: parent, send, sink });
            }
        }
    }
    steps
}

/// Ring allreduce (`collectives::ring`): reduce-scatter then allgather
/// around the ring, `p − 1` full-duplex exchanges each. Ring segments
/// come from the payload length, not the pipeline blocks — exactly like
/// the blocking implementation.
fn ring_steps(rank: usize, p: usize, m: usize) -> Vec<Step> {
    let right = (rank + 1) % p;
    let left = (rank + p - 1) % p;
    let segs = Blocks::segments(m, p);
    let pair = |send: Src, sink: Sink| -> Step {
        if right == left {
            // p == 2: the transport delegates sendrecv_pair with equal
            // partners to sendrecv, so the compiled form does too
            Step::SendRecv { peer: right, send, sink }
        } else {
            Step::SendRecvPair {
                send_to: right,
                recv_from: left,
                send,
                sink,
            }
        }
    };
    let mut steps = Vec::new();
    // reduce-scatter: after it, rank owns the full product of segment rank
    for t in 0..p - 1 {
        let send_seg = (rank + p - t) % p;
        let recv_seg = (rank + p - t - 1) % p;
        let (slo, shi) = segs.range(send_seg);
        let (rlo, _rhi) = segs.range(recv_seg);
        steps.push(pair(
            Src::Block { lo: slo, hi: shi },
            Sink::ReduceAt {
                lo: rlo,
                side: Side::Left,
            },
        ));
    }
    // allgather: circulate the finished segments
    for t in 0..p - 1 {
        let send_seg = (rank + 1 + p - t) % p;
        let recv_seg = (rank + p - t) % p;
        let (slo, shi) = segs.range(send_seg);
        let (rlo, _rhi) = segs.range(recv_seg);
        steps.push(pair(
            Src::Block { lo: slo, hi: shi },
            Sink::WriteAt { lo: rlo },
        ));
    }
    steps
}

/// Recursive doubling (`collectives::recursive_doubling`): fold the
/// non-power-of-two remainder, butterfly over the 2^k core, unfold.
fn rd_steps(rank: usize, p: usize) -> Vec<Step> {
    let k = crate::util::log2_floor(p) as usize;
    let pow = 1usize << k;
    let rem = p - pow;
    let carrier = |e: usize| if e < rem { 2 * e } else { e + rem };
    let mut steps = Vec::new();
    let eff = if rank < 2 * rem {
        if rank % 2 == 0 {
            steps.push(Step::Recv {
                peer: rank + 1,
                sink: Sink::ReduceAll { side: Side::Right },
            });
            Some(rank / 2)
        } else {
            steps.push(Step::Send {
                peer: rank - 1,
                send: Src::CloneY,
            });
            None
        }
    } else {
        Some(rank - rem)
    };
    if let Some(e) = eff {
        for bit in 0..k {
            let pe = e ^ (1 << bit);
            let partner = carrier(pe);
            let side = if pe < e { Side::Left } else { Side::Right };
            steps.push(Step::SendRecv {
                peer: partner,
                send: Src::Snapshot,
                sink: Sink::ReduceAll { side },
            });
        }
    }
    if rank < 2 * rem {
        if rank % 2 == 0 {
            steps.push(Step::Send {
                peer: rank + 1,
                send: Src::CloneY,
            });
        } else {
            steps.push(Step::Recv {
                peer: rank - 1,
                sink: Sink::ReplaceY,
            });
        }
    }
    steps
}

// ---------------------------------------------------------------------
// Step-for-step verification against the blocking oracles
// ---------------------------------------------------------------------

/// One logged communicator call (see [`TraceComm`]). Payloads are
/// summarized by element count — the full payload equivalence is pinned
/// separately by the engine-level bitwise tests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceEvent {
    /// Bidirectional exchange, logged at completion so `recv_elems` is
    /// the element count the receive half *actually delivered* (exactly
    /// like [`TraceEvent::Recv`] — no fused call's incoming length can
    /// hide behind a peer-only match).
    SendRecv {
        peer: usize,
        send_elems: usize,
        recv_elems: usize,
    },
    /// Full-duplex exchange, logged at completion (see
    /// [`TraceEvent::SendRecv`] for the `recv_elems` contract).
    SendRecvPair {
        send_to: usize,
        recv_from: usize,
        send_elems: usize,
        recv_elems: usize,
    },
    Send { peer: usize, send_elems: usize },
    /// A blocking receive and the element count it *actually delivered* —
    /// logged at completion, so trace comparison pins received lengths
    /// exactly (a sender shipping the wrong block size cannot hide behind
    /// a peer-only match).
    Recv { peer: usize, elems: usize },
    Charge { bytes: usize },
}

/// A [`Comm`](crate::comm::Comm) wrapper that logs every call it
/// delegates — the oracle side of the step-for-step compiler tests.
pub struct TraceComm<'a, E: crate::ops::Elem, C: crate::comm::Comm<E>> {
    inner: &'a mut C,
    /// The logged call sequence, in program order.
    pub events: Vec<TraceEvent>,
    _marker: std::marker::PhantomData<E>,
}

impl<'a, E: crate::ops::Elem, C: crate::comm::Comm<E>> TraceComm<'a, E, C> {
    pub fn new(inner: &'a mut C) -> Self {
        TraceComm {
            inner,
            events: Vec::new(),
            _marker: std::marker::PhantomData,
        }
    }
}

impl<E: crate::ops::Elem, C: crate::comm::Comm<E>> crate::comm::Comm<E> for TraceComm<'_, E, C> {
    fn rank(&self) -> usize {
        self.inner.rank()
    }

    fn size(&self) -> usize {
        self.inner.size()
    }

    fn sendrecv(
        &mut self,
        peer: usize,
        send: crate::buffer::DataBuf<E>,
    ) -> crate::error::Result<crate::buffer::DataBuf<E>> {
        // delegate first: the event records the delivered length (the
        // call is blocking, so the log position per rank is unchanged)
        let send_elems = send.len();
        let got = self.inner.sendrecv(peer, send)?;
        self.events.push(TraceEvent::SendRecv {
            peer,
            send_elems,
            recv_elems: got.len(),
        });
        Ok(got)
    }

    fn sendrecv_pair(
        &mut self,
        send_to: usize,
        send: crate::buffer::DataBuf<E>,
        recv_from: usize,
    ) -> crate::error::Result<crate::buffer::DataBuf<E>> {
        let send_elems = send.len();
        let got = self.inner.sendrecv_pair(send_to, send, recv_from)?;
        // the transport delegates equal partners to sendrecv — log the
        // call the same way the compiler lowers it
        if send_to == recv_from {
            self.events.push(TraceEvent::SendRecv {
                peer: send_to,
                send_elems,
                recv_elems: got.len(),
            });
        } else {
            self.events.push(TraceEvent::SendRecvPair {
                send_to,
                recv_from,
                send_elems,
                recv_elems: got.len(),
            });
        }
        Ok(got)
    }

    fn send(&mut self, peer: usize, data: crate::buffer::DataBuf<E>) -> crate::error::Result<()> {
        self.events.push(TraceEvent::Send {
            peer,
            send_elems: data.len(),
        });
        self.inner.send(peer, data)
    }

    fn recv(&mut self, peer: usize) -> crate::error::Result<crate::buffer::DataBuf<E>> {
        // delegate first: the event records the length actually received
        // (same log position — a blocking recv admits no interleaving on
        // this rank between call and return)
        let got = self.inner.recv(peer)?;
        self.events.push(TraceEvent::Recv {
            peer,
            elems: got.len(),
        });
        Ok(got)
    }

    fn barrier(&mut self) -> crate::error::Result<()> {
        self.inner.barrier()
    }

    fn charge_compute(&mut self, bytes: usize) {
        self.events.push(TraceEvent::Charge { bytes });
        self.inner.charge_compute(bytes)
    }

    fn time_us(&self) -> f64 {
        self.inner.time_us()
    }

    fn reset_time(&mut self) {
        self.inner.reset_time()
    }

    fn metrics(&self) -> &crate::comm::RankMetrics {
        self.inner.metrics()
    }
}

/// The per-rank [`TraceEvent`] sequences a set of compiled schedules
/// *should* produce, derived by a single-threaded lockstep simulation
/// over message *sizes* (payload contents never influence control flow).
/// `m` is the per-rank vector length, `elem_bytes` the wire size of one
/// element (for γ-charge byte counts).
///
/// Fails with `Error::Protocol` if the schedules deadlock — a compiler
/// bug by construction, since the blocking algorithms they mirror are
/// deadlock-free. (The static pass in [`verify`] proves the absence of
/// such cycles independently of this simulation.)
pub fn try_expected_events(
    scheds: &[Schedule],
    m: usize,
    elem_bytes: usize,
) -> crate::error::Result<Vec<Vec<TraceEvent>>> {
    use std::collections::{HashMap, VecDeque};
    let p = scheds.len();
    let mut pc = vec![0usize; p];
    // true once the current step's event is logged and its send (if any)
    // is in flight; the step then only waits on its receive
    let mut half_done = vec![false; p];
    let mut events: Vec<Vec<TraceEvent>> = vec![Vec::new(); p];
    let mut mail: HashMap<(usize, usize), VecDeque<usize>> = HashMap::new();
    let src_elems = |s: Src| match s {
        Src::Void => 0,
        Src::Block { lo, hi } | Src::OwnedBlock { lo, hi } => hi - lo,
        Src::Snapshot | Src::CloneY => m,
    };
    let sink_charge = |sink: Sink, n: usize, log: &mut Vec<TraceEvent>| {
        match sink {
            Sink::ReduceAt { .. }
            | Sink::StashCharged
            | Sink::Reduce3At { .. }
            | Sink::ReduceAll { .. } => log.push(TraceEvent::Charge {
                bytes: n * elem_bytes,
            }),
            Sink::Discard | Sink::WriteAt { .. } | Sink::ReplaceY => {}
        }
    };
    loop {
        let mut progressed = false;
        let mut all_done = true;
        for r in 0..p {
            let steps = &scheds[r].steps;
            if pc[r] >= steps.len() {
                continue;
            }
            all_done = false;
            let step = steps[pc[r]];
            if !half_done[r] {
                // launch the send half; one-directional sends log here
                // (exchanges log at completion, when the delivered
                // length is known — mirroring TraceComm)
                match step {
                    Step::SendRecv { peer, send, .. } => {
                        mail.entry((r, peer)).or_default().push_back(src_elems(send));
                    }
                    Step::SendRecvPair { send_to, send, .. } => {
                        mail.entry((r, send_to)).or_default().push_back(src_elems(send));
                    }
                    Step::Send { peer, send } => {
                        events[r].push(TraceEvent::Send {
                            peer,
                            send_elems: src_elems(send),
                        });
                        mail.entry((r, peer)).or_default().push_back(src_elems(send));
                    }
                    // a Recv logs at completion (with the delivered
                    // length), mirroring TraceComm
                    Step::Recv { .. } => {}
                }
                half_done[r] = true;
                progressed = true;
            }
            // complete the receive half if the message is there
            let (from, sink) = match step {
                Step::SendRecv { peer, sink, .. } => (peer, sink),
                Step::SendRecvPair {
                    recv_from, sink, ..
                } => (recv_from, sink),
                Step::Recv { peer, sink } => (peer, sink),
                Step::Send { .. } => {
                    pc[r] += 1;
                    half_done[r] = false;
                    continue;
                }
            };
            if let Some(n) = mail.get_mut(&(from, r)).and_then(|q| q.pop_front()) {
                match step {
                    Step::SendRecv { peer, send, .. } => {
                        events[r].push(TraceEvent::SendRecv {
                            peer,
                            send_elems: src_elems(send),
                            recv_elems: n,
                        });
                    }
                    Step::SendRecvPair {
                        send_to,
                        recv_from,
                        send,
                        ..
                    } => {
                        events[r].push(TraceEvent::SendRecvPair {
                            send_to,
                            recv_from,
                            send_elems: src_elems(send),
                            recv_elems: n,
                        });
                    }
                    Step::Recv { .. } => {
                        events[r].push(TraceEvent::Recv {
                            peer: from,
                            elems: n,
                        });
                    }
                    Step::Send { .. } => unreachable!("send halves retire above"),
                }
                sink_charge(sink, n, &mut events[r]);
                pc[r] += 1;
                half_done[r] = false;
                progressed = true;
            }
        }
        if all_done {
            return Ok(events);
        }
        if !progressed {
            return Err(crate::error::Error::Protocol(
                "compiled schedules deadlocked — compiler bug".to_string(),
            ));
        }
    }
}

/// Panicking wrapper of [`try_expected_events`], for test oracles where
/// a deadlocked compilation should abort loudly.
pub fn expected_events(scheds: &[Schedule], m: usize, elem_bytes: usize) -> Vec<Vec<TraceEvent>> {
    // A deadlock here is a compiler bug, not a runtime condition — the
    // typed variant exists for callers that must not panic.
    try_expected_events(scheds, m, elem_bytes).expect("schedule simulation deadlocked")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::buffer::DataBuf;
    use crate::collectives::{
        allreduce_dpdr, allreduce_dpdr_single, allreduce_recursive_doubling, allreduce_ring,
    };
    use crate::comm::{run_world, Timing};
    use crate::ops::SumOp;

    const ALGOS: [AlgoKind; 4] = [
        AlgoKind::Dpdr,
        AlgoKind::DpdrSingle,
        AlgoKind::Ring,
        AlgoKind::RecursiveDoubling,
    ];

    fn input(rank: usize, m: usize) -> Vec<i32> {
        (0..m).map(|i| (rank * 31 + i) as i32).collect()
    }

    /// Run the blocking oracle under a [`TraceComm`] and return the
    /// per-rank event logs plus the per-rank results.
    fn trace_blocking(
        algo: AlgoKind,
        p: usize,
        m: usize,
        block_elems: usize,
    ) -> Vec<(Vec<TraceEvent>, Vec<i32>)> {
        let report = run_world::<i32, _, _>(p, Timing::Real, move |comm| {
            let blocks = Blocks::by_size(m, block_elems)?;
            let x = DataBuf::real(input(comm.rank(), m));
            let mut tc = TraceComm::new(comm);
            let y = match algo {
                AlgoKind::Dpdr => allreduce_dpdr(&mut tc, x, &SumOp, &blocks)?,
                AlgoKind::DpdrSingle => allreduce_dpdr_single(&mut tc, x, &SumOp, &blocks)?,
                AlgoKind::Ring => allreduce_ring(&mut tc, x, &SumOp)?,
                AlgoKind::RecursiveDoubling => allreduce_recursive_doubling(&mut tc, x, &SumOp)?,
                _ => unreachable!(),
            };
            let events = std::mem::take(&mut tc.events);
            Ok((events, y.into_vec()?))
        })
        .unwrap();
        report.results
    }

    fn check_trace(algo: AlgoKind, p: usize, m: usize, block_elems: usize) {
        let blocks = Blocks::by_size(m, block_elems).unwrap();
        let scheds: Vec<Schedule> = (0..p)
            .map(|r| compile(algo, r, p, &blocks).expect("compiled algo"))
            .collect();
        let expected = expected_events(&scheds, m, 4);
        let traced = trace_blocking(algo, p, m, block_elems);
        let mut want = vec![0i32; m];
        for r in 0..p {
            for (a, v) in want.iter_mut().zip(input(r, m)) {
                *a = a.wrapping_add(v);
            }
        }
        for (r, (events, result)) in traced.into_iter().enumerate() {
            assert_eq!(
                events, expected[r],
                "{} p={p} m={m} be={block_elems} rank={r}: step trace diverged",
                algo.name()
            );
            assert_eq!(result, want, "{} rank {r} payload", algo.name());
        }
    }

    #[test]
    fn compiled_schedules_match_blocking_traces() {
        for algo in ALGOS {
            for p in [2usize, 3, 4, 7, 8, 14] {
                for (m, be) in [(3usize, 1usize), (17, 5), (40, 8)] {
                    check_trace(algo, p, m, be);
                }
            }
        }
    }

    #[test]
    fn empty_payload_compiles_to_empty_schedule() {
        let blocks = Blocks::by_size(0, 4).unwrap();
        for algo in ALGOS {
            for r in 0..6 {
                let s = compile(algo, r, 6, &blocks).unwrap();
                assert!(s.steps.is_empty(), "{} rank {r}", algo.name());
            }
        }
        // blocking oracles agree: zero calls
        for algo in ALGOS {
            for (events, result) in trace_blocking(algo, 6, 0, 4) {
                assert!(events.is_empty());
                assert!(result.is_empty());
            }
        }
    }

    #[test]
    fn single_rank_is_identity() {
        let blocks = Blocks::by_size(8, 4).unwrap();
        for algo in ALGOS {
            let s = compile(algo, 0, 1, &blocks).unwrap();
            assert!(s.steps.is_empty());
        }
    }

    #[test]
    fn uncompiled_algos_return_none() {
        let blocks = Blocks::by_size(16, 4).unwrap();
        for algo in [
            AlgoKind::Hier,
            AlgoKind::TwoTree,
            AlgoKind::Scan,
            AlgoKind::PipeTree,
            AlgoKind::Rabenseifner,
            AlgoKind::NonPipelined,
        ] {
            assert!(compile(algo, 0, 4, &blocks).is_none(), "{}", algo.name());
        }
    }

    #[test]
    fn dpdr_inner_rounds_use_fused_sinks() {
        // p = 14: both trees perfect with inner nodes; an inner node with
        // two children must emit StashCharged → Reduce3At pairs
        let blocks = Blocks::by_count(24, 4);
        let forest = DualRootForest::new(14).unwrap();
        let mut saw_fused = false;
        for r in 0..14 {
            let role = forest.role(r).unwrap();
            let s = compile(AlgoKind::Dpdr, r, 14, &blocks).unwrap();
            let stashes = s
                .steps
                .iter()
                .filter(|st| matches!(st, Step::SendRecv { sink: Sink::StashCharged, .. }))
                .count();
            let fused = s
                .steps
                .iter()
                .filter(|st| matches!(st, Step::SendRecv { sink: Sink::Reduce3At { .. }, .. }))
                .count();
            assert_eq!(stashes, fused, "rank {r}: stash/fuse pairing");
            if role.children[0].is_some() && role.children[1].is_some() {
                assert_eq!(fused, blocks.count(), "rank {r}: one fused round per block");
                saw_fused = true;
            } else {
                assert_eq!(fused, 0, "rank {r}: leaf/one-child ranks never fuse");
            }
        }
        assert!(saw_fused);
    }

    #[test]
    fn ring_p2_normalizes_to_sendrecv() {
        let blocks = Blocks::by_size(8, 4).unwrap();
        for r in 0..2 {
            let s = compile(AlgoKind::Ring, r, 2, &blocks).unwrap();
            assert!(!s.steps.is_empty());
            for st in &s.steps {
                assert!(
                    matches!(st, Step::SendRecv { .. }),
                    "p=2 ring must lower pair calls to sendrecv"
                );
            }
        }
    }

    #[test]
    fn rd_non_power_of_two_folds_remainder() {
        // p = 7: pow = 4, rem = 3 → ranks 0..6 fold pairwise
        let s0 = compile(AlgoKind::RecursiveDoubling, 0, 7, &Blocks::by_count(8, 2)).unwrap();
        assert!(matches!(s0.steps[0], Step::Recv { peer: 1, .. }));
        assert!(matches!(s0.steps[s0.steps.len() - 1], Step::Send { peer: 1, .. }));
        let s1 = compile(AlgoKind::RecursiveDoubling, 1, 7, &Blocks::by_count(8, 2)).unwrap();
        assert!(matches!(s1.steps[0], Step::Send { peer: 0, .. }));
        assert!(matches!(
            s1.steps[1],
            Step::Recv { peer: 0, sink: Sink::ReplaceY }
        ));
        assert_eq!(s1.steps.len(), 2, "folded-away rank only forwards");
    }
}
