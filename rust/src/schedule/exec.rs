//! The per-world event-driven progress core.
//!
//! One [`Core`] is anchored in each world's channel registry
//! ([`ShardedRegistry::anchored`]); the nonblocking engine deposits a
//! compiled [`Schedule`] per `(op, rank)` and every rank that waits on a
//! schedule-engine operation *drives* the core: a single progress loop
//! multiplexes the ready steps of **all** outstanding operations of all
//! ranks, replacing the threaded engine's thread-per-op workers. Payload
//! movement needs no channels at all — a "send" pushes the buffer into
//! an in-core per-edge FIFO mailbox, a "receive" pops it.
//!
//! # Clock fidelity
//!
//! Every virtual-clock formula of the threaded transport
//! ([`crate::comm::thread`]) is reproduced verbatim: fabric admission
//! (bounded edge queues + egress ports), the telephone/full-duplex
//! sendrecv completion rules, ingress reservation and drain recording,
//! and the whole fault pipeline (straggler stalls, retransmit backoff,
//! in-flight delay, duplication and reorder **counting** — the payload
//! stream itself stays in send order, exactly what the threaded
//! receiver's sequence reassembly delivers). Under `Timing::Real` and
//! under dedicated virtual models the engine is bitwise-identical to the
//! threaded path in payloads and clocks (pinned by `tests/nbc.rs`).
//!
//! # Deterministic virtual-time order
//!
//! Under a congestion-aware model the NIC port timelines are shared
//! mutable state, so *execution order* is observable in the clocks. The
//! core makes it deterministic: while the fabric is active, steps only
//! execute when every rank with unfinished armed work is parked inside
//! [`Core::drive`] (the *seal*), and each scan executes the single
//! runnable half with the least `(vtime, rank, tag)` key. Given the
//! SPMD batch pattern — all ranks submit a batch, then wait in any
//! per-rank order — the armed set at seal time is the whole batch, so
//! congested clocks are run-to-run deterministic even under rotated
//! wait orders (threaded workers race wall-clock for the same
//! reservations and are not).
//!
//! # True deadline cancellation
//!
//! An operation deposited with a deadline (virtual timing only) is
//! checked at every step boundary: once any rank's program clock
//! exceeds `v0 + deadline`, the whole operation is cancelled — every
//! rank abandons symmetrically at a step boundary, harvests
//! `Error::Deadline` with `took_us == deadline_us` exactly, and the
//! engine releases the operation's tag early instead of carrying the
//! work to completion first.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use super::{Schedule, Sink, Src, Step};
use crate::buffer::{pool, DataBuf};
use crate::comm::net::Fabric;
use crate::comm::thread::ShardedRegistry;
use crate::comm::{FaultPlan, RankMetrics, Timing};
use crate::error::Error;
use crate::obs;
use crate::ops::{backend, Elem, ReduceBackend, ReduceOp};

/// Condvar poll slice while waiting for peers (mirrors the transport's
/// poison poll).
const DRIVE_POLL: Duration = Duration::from_millis(20);

/// Mirrors the transport's `EFFECTIVELY_UNBOUNDED`: capacities at or
/// above this never record drains.
const EFFECTIVELY_UNBOUNDED: u64 = 1 << 32;

fn records_drains(capacity: usize) -> bool {
    capacity > 0 && (capacity as u64) < EFFECTIVELY_UNBOUNDED
}

fn relock<T>(r: Result<MutexGuard<'_, T>, PoisonError<MutexGuard<'_, T>>>) -> MutexGuard<'_, T> {
    r.unwrap_or_else(|p| p.into_inner())
}

/// A cloneable projection of [`Error`] for fan-out to every waiting
/// rank (the original is not `Clone`; variants that cannot be
/// reproduced field-for-field degrade to `Protocol`).
fn clone_error(e: &Error) -> Error {
    match e {
        Error::RetriesExhausted { rank, peer, attempts } => Error::RetriesExhausted {
            rank: *rank,
            peer: *peer,
            attempts: *attempts,
        },
        Error::PeerStalled { rank, peer } => Error::PeerStalled {
            rank: *rank,
            peer: *peer,
        },
        Error::Disconnected { rank, peer } => Error::Disconnected {
            rank: *rank,
            peer: *peer,
        },
        other => Error::Protocol(other.to_string()),
    }
}

/// The virtual twin of the transport's per-edge bounded injection queue
/// (`EdgeQueue`), minus the wall-blocking: a post that would have to
/// wait for an unknown drain time is simply *not runnable* yet.
#[derive(Default)]
pub(crate) struct VirtQueue {
    posted: u64,
    drained: u64,
    drains: VecDeque<f64>,
}

impl VirtQueue {
    /// Would a post complete immediately? (Unbounded, under capacity, or
    /// the reused slot's drain time already recorded.)
    fn can_post(&self, capacity: usize) -> bool {
        !records_drains(capacity) || self.posted < capacity as u64 || !self.drains.is_empty()
    }

    /// Mirrors `EdgeQueue::post`: returns `(freed_at, depth)`. Callers
    /// must have checked [`VirtQueue::can_post`].
    fn post(&mut self, capacity: usize) -> (Option<f64>, u64) {
        let index = self.posted;
        self.posted += 1;
        let depth = self.posted - self.drained;
        if !records_drains(capacity) || index < capacity as u64 {
            return (None, depth);
        }
        // Infallible: `index >= capacity` here, and can_post (the caller's
        // contract) required a recorded drain when the queue is full.
        let freed = self.drains.pop_front().expect("can_post checked");
        (Some(freed), self.posted - self.drained)
    }

    /// Mirrors `EdgeQueue::drain`.
    fn drain(&mut self, capacity: usize, vtime: f64) {
        self.drained += 1;
        if records_drains(capacity) {
            self.drains.push_back(vtime);
        }
    }
}

/// One in-flight message of one operation's `(src, dst)` edge.
struct Packet<E: Elem> {
    /// Virtual arrival stamp (send stamp + in-flight fault delay).
    vtime: f64,
    data: DataBuf<E>,
    /// Duplicate copies the threaded receiver would consume (and count
    /// as fault events) immediately before delivering this message.
    dups_before: u32,
}

/// Per-edge FIFO mailbox. Program sends to a peer happen in sequence
/// order, so FIFO order here *is* the threaded receiver's reassembled
/// order.
struct Mailbox<E: Elem> {
    fifo: VecDeque<Packet<E>>,
    /// Duplicate count carried by the next packet pushed (see
    /// [`Packet::dups_before`] — a trailing duplicate is never consumed,
    /// hence never counted, exactly like the threaded receiver).
    pending_dup: u32,
}

impl<E: Elem> Default for Mailbox<E> {
    fn default() -> Self {
        Mailbox {
            fifo: VecDeque::new(),
            pending_dup: 0,
        }
    }
}

/// Execution position within the current step.
#[derive(Clone, Copy)]
enum Half {
    /// Nothing of the step has run.
    Start,
    /// The send half ran; the step is waiting on its receive.
    Posted {
        stamp: f64,
        out_dur: f64,
        sent_bytes: usize,
    },
}

/// One rank's program state for one operation.
struct Prog<E: Elem> {
    /// The owning rank (trace attribution; execution is keyed by the
    /// program's position in [`OpState::progs`]).
    rank: usize,
    steps: Vec<Step>,
    pc: usize,
    half: Half,
    y: DataBuf<E>,
    /// The charged first child of a fused dpdr inner round
    /// ([`Sink::StashCharged`] → [`Sink::Reduce3At`]).
    stash: Option<DataBuf<E>>,
    /// Virtual clock at submit (the threaded worker inherits the same).
    v0: f64,
    vtime: f64,
    wall0: Instant,
    done_wall: Option<Instant>,
    metrics: RankMetrics,
    /// Next fault sequence number per destination peer.
    tx_seq: Vec<u64>,
    /// Reorder-hold emulation per destination peer (counting only — the
    /// mailbox stays in send order; see [`Mailbox`]).
    reorder_held: Vec<bool>,
    /// Per-peer tracing sequence counters, allocated only while tracing
    /// is enabled (mirrors the transport's lazy counters).
    obs_seq: Option<Box<ObsSeqs>>,
}

/// Per-peer send/receive sequence counters for trace flow pairing.
struct ObsSeqs {
    tx: Vec<u64>,
    rx: Vec<u64>,
}

impl<E: Elem> Prog<E> {
    fn retire(&mut self, tag: u32) {
        if obs::enabled() {
            let ev = obs::Event::new(obs::EventKind::Step, self.rank)
                .tag(tag)
                .aux(self.pc as u32)
                .at_s(self.vtime)
                .wall(obs::wall_now_ns());
            obs::record(ev);
        }
        self.pc += 1;
        self.half = Half::Start;
    }

    fn charge(&mut self, timing: Timing, bytes: usize) {
        if let Timing::Virtual(_, compute) = timing {
            let dur = compute.reduce(bytes);
            if obs::enabled() && dur > 0.0 {
                let ev = obs::Event::new(obs::EventKind::Reduce, self.rank)
                    .bytes(bytes as u64)
                    .span_s(self.vtime, self.vtime + dur)
                    .wall(obs::wall_now_ns());
                obs::record(ev);
                obs::note_vtime_us((self.vtime + dur) * 1e6);
            }
            self.vtime += dur;
        }
        self.metrics.reduce_bytes += bytes as u64;
    }

    /// Next tracing sequence number for the `(self, peer)` stream in
    /// the given direction (only called while tracing is enabled).
    fn obs_next_seq(&mut self, peer: usize, send: bool) -> u64 {
        let size = self.tx_seq.len();
        let seqs = self
            .obs_seq
            .get_or_insert_with(|| Box::new(ObsSeqs { tx: vec![0; size], rx: vec![0; size] }));
        let slot = if send { &mut seqs.tx[peer] } else { &mut seqs.rx[peer] };
        let v = *slot;
        *slot += 1;
        v
    }

    /// Record the transfer-endpoint events of one completed exchange
    /// half (mirrors the transport's hook; guarded by the caller).
    fn obs_p2p(
        &mut self,
        tag: u32,
        send: Option<(usize, usize, f64, f64)>,
        recv: Option<(usize, usize, f64, f64)>,
    ) {
        use obs::{Event, EventKind};
        let rank = self.rank;
        let w = obs::wall_now_ns();
        if let Some((peer, bytes, t0, t1)) = send {
            let seq = self.obs_next_seq(peer, true);
            let ev = Event::new(EventKind::SendStart, rank)
                .peer(peer)
                .tag(tag)
                .seq(seq)
                .bytes(bytes as u64);
            obs::record(ev.at_s(t0).wall(w));
            obs::record(ev.at_s(t1).wall(w).with_kind(EventKind::SendEnd));
        }
        if let Some((peer, bytes, t0, t1)) = recv {
            let seq = self.obs_next_seq(peer, false);
            let ev = Event::new(EventKind::RecvStart, rank)
                .peer(peer)
                .tag(tag)
                .seq(seq)
                .bytes(bytes as u64);
            obs::record(ev.at_s(t0).wall(w));
            obs::record(ev.at_s(t1).wall(w).with_kind(EventKind::RecvEnd));
        }
        obs::note_vtime_us(self.vtime * 1e6);
    }

    /// Mirrors the transport's `flush_tx_held` at every blocking
    /// receive: all held flags clear (the held messages are already in
    /// the mailbox in restored order; only the counting state resets).
    fn clear_reorder_held(&mut self) {
        for h in self.reorder_held.iter_mut() {
            *h = false;
        }
    }
}

/// One outstanding operation: the per-rank programs plus the edge
/// mailboxes and virtual injection queues they exchange through. Each
/// operation owns its tag's edges outright — exactly the threaded
/// transport, where every `(src, dst, tag)` triple has its own channel
/// and `EdgeQueue`.
struct OpState<E: Elem, O> {
    op: O,
    backend: ReduceBackend,
    timing: Timing,
    faults: FaultPlan,
    /// Cancellation budget in virtual µs from each rank's `v0` (virtual
    /// timing only; fused and real-timed operations deposit `None` and
    /// keep the threaded post-hoc deadline semantics).
    deadline_us: Option<f64>,
    deposited: usize,
    cancelled: bool,
    failed: Option<(usize, Error)>,
    progs: Vec<Option<Prog<E>>>,
    done: Vec<bool>,
    harvested: Vec<bool>,
    mail: HashMap<(usize, usize), Mailbox<E>>,
    queues: HashMap<(usize, usize), VirtQueue>,
}

impl<E: Elem, O: ReduceOp<E>> OpState<E, O> {
    fn new(
        size: usize,
        op: O,
        backend: ReduceBackend,
        timing: Timing,
        faults: FaultPlan,
        deadline_us: Option<f64>,
    ) -> Self {
        OpState {
            op,
            backend,
            timing,
            faults,
            deadline_us,
            deposited: 0,
            cancelled: false,
            failed: None,
            progs: (0..size).map(|_| None).collect(),
            done: vec![false; size],
            harvested: vec![false; size],
            mail: HashMap::new(),
            queues: HashMap::new(),
        }
    }

    fn armed(&self) -> bool {
        self.deposited == self.progs.len() && !self.cancelled && self.failed.is_none()
    }

    /// Is rank `r`'s current half executable right now?
    fn runnable(&self, r: usize, fabric: &Fabric) -> bool {
        let Some(prog) = self.progs[r].as_ref() else {
            return false;
        };
        if self.done[r] {
            return false;
        }
        let step = prog.steps[prog.pc];
        match prog.half {
            Half::Start => match step {
                Step::Recv { peer, .. } => self.has_mail(peer, r),
                Step::Send { peer, .. } | Step::SendRecv { peer, .. } => {
                    self.can_admit(r, peer, fabric)
                }
                Step::SendRecvPair { send_to, .. } => self.can_admit(r, send_to, fabric),
            },
            Half::Posted { .. } => {
                let from = step.recv_from().expect("posted step receives");
                self.has_mail(from, r)
            }
        }
    }

    fn has_mail(&self, src: usize, dst: usize) -> bool {
        self.mail.get(&(src, dst)).is_some_and(|m| !m.fifo.is_empty())
    }

    fn can_admit(&self, src: usize, dst: usize, fabric: &Fabric) -> bool {
        if !fabric.is_active() {
            return true;
        }
        let cap = fabric.edge_capacity(src, dst);
        self.queues.get(&(src, dst)).map_or(true, |q| q.can_post(cap))
    }

    /// Execute rank `r`'s current half (the caller checked
    /// [`OpState::runnable`]). Each half is exactly one threaded
    /// transport operation's worth of clock math.
    fn exec_half(&mut self, tag: u32, r: usize, fabric: &Fabric) -> crate::error::Result<()> {
        let OpState {
            op,
            backend,
            timing,
            faults,
            progs,
            done,
            mail,
            queues,
            ..
        } = self;
        let (backend, timing, faults) = (*backend, *timing, *faults);
        let prog = progs[r].as_mut().expect("runnable prog");
        let step = prog.steps[prog.pc];
        match prog.half {
            Half::Start => match step {
                Step::Recv { peer, sink } => {
                    prog.clear_reorder_held();
                    let pkt = pop_mail(mail, peer, r);
                    prog.metrics.fault_events += pkt.dups_before as u64;
                    prog.metrics.bytes_recv += pkt.data.bytes() as u64;
                    let mut obs_ready = prog.vtime;
                    if let Timing::Virtual(cost, _) = timing {
                        let dur = cost.xfer(r, peer, pkt.data.bytes());
                        let ready = prog.vtime.max(pkt.vtime);
                        obs_ready = ready;
                        let m = &mut prog.metrics;
                        prog.vtime = finish_recv(fabric, queues, m, tag, peer, r, ready, dur);
                    }
                    prog.metrics.exchanges += 1;
                    prog.metrics.steps_executed += 1;
                    if obs::enabled() {
                        let bytes = pkt.data.bytes();
                        let end = prog.vtime;
                        prog.obs_p2p(tag, None, Some((peer, bytes, obs_ready, end)));
                    }
                    apply_sink(prog, sink, pkt.data, &*op, backend, timing)?;
                    prog.retire(tag);
                }
                Step::SendRecv { peer, send, .. }
                | Step::SendRecvPair { send_to: peer, send, .. }
                | Step::Send { peer, send } => {
                    let data = materialize(&prog.y, send)?;
                    let sent_bytes = data.bytes();
                    let (stamp, out_dur) = match timing {
                        Timing::Virtual(cost, _) => {
                            let dur = cost.xfer(r, peer, sent_bytes);
                            let vt = prog.vtime;
                            let m = &mut prog.metrics;
                            (admit_send(fabric, queues, m, tag, vt, r, peer, dur), dur)
                        }
                        Timing::Real => (prog.vtime, 0.0),
                    };
                    let stamp = post_mail(mail, prog, &faults, fabric, tag, r, peer, data, stamp)?;
                    prog.metrics.steps_executed += 1;
                    if matches!(step, Step::Send { .. }) {
                        if timing.is_virtual() {
                            prog.vtime = stamp + out_dur;
                        }
                        prog.metrics.exchanges += 1;
                        if obs::enabled() {
                            let sp = (peer, sent_bytes, stamp, stamp + out_dur);
                            prog.obs_p2p(tag, Some(sp), None);
                        }
                        prog.retire(tag);
                    } else {
                        prog.half = Half::Posted {
                            stamp,
                            out_dur,
                            sent_bytes,
                        };
                    }
                }
            },
            Half::Posted {
                stamp,
                out_dur,
                sent_bytes,
            } => {
                let (from, send_to, sink, is_pair) = match step {
                    Step::SendRecv { peer, sink, .. } => (peer, peer, sink, false),
                    Step::SendRecvPair {
                        send_to,
                        recv_from,
                        sink,
                        ..
                    } => (recv_from, send_to, sink, true),
                    _ => unreachable!("only exchanges post"),
                };
                prog.clear_reorder_held();
                let pkt = pop_mail(mail, from, r);
                prog.metrics.fault_events += pkt.dups_before as u64;
                prog.metrics.bytes_recv += pkt.data.bytes() as u64;
                let (mut obs_ready, mut obs_in_done) = (prog.vtime, prog.vtime);
                if let Timing::Virtual(cost, _) = timing {
                    if is_pair {
                        // full duplex: the two transfers overlap
                        let out_done = stamp + out_dur;
                        let inc_dur = cost.xfer(r, from, pkt.data.bytes());
                        let ready = stamp.max(pkt.vtime);
                        let m = &mut prog.metrics;
                        let in_done = finish_recv(fabric, queues, m, tag, from, r, ready, inc_dur);
                        (obs_ready, obs_in_done) = (ready, in_done);
                        prog.vtime = out_done.max(in_done);
                    } else {
                        // telephone model: both directions complete together
                        let bytes = sent_bytes.max(pkt.data.bytes());
                        let dur = cost.xfer(r, from, bytes);
                        let ready = stamp.max(pkt.vtime);
                        let m = &mut prog.metrics;
                        prog.vtime = finish_recv(fabric, queues, m, tag, from, r, ready, dur);
                        (obs_ready, obs_in_done) = (ready, prog.vtime);
                    }
                }
                prog.metrics.exchanges += 1;
                prog.metrics.sendrecvs += 1;
                prog.metrics.steps_executed += 1;
                if obs::enabled() {
                    // mirror the transport: telephone exchanges complete
                    // both directions together, pairs overlap
                    let send_end = if is_pair { stamp + out_dur } else { prog.vtime };
                    let recv_bytes = pkt.data.bytes();
                    let sp = (send_to, sent_bytes, stamp, send_end);
                    prog.obs_p2p(tag, Some(sp), Some((from, recv_bytes, obs_ready, obs_in_done)));
                }
                apply_sink(prog, sink, pkt.data, &*op, backend, timing)?;
                prog.retire(tag);
            }
        }
        if prog.pc == prog.steps.len() {
            done[r] = true;
            prog.done_wall = Some(Instant::now());
        }
        Ok(())
    }
}

fn materialize<E: Elem>(y: &DataBuf<E>, src: Src) -> crate::error::Result<DataBuf<E>> {
    match src {
        Src::Void => Ok(y.empty_like()),
        Src::Block { lo, hi } => y.block(lo, hi),
        Src::OwnedBlock { lo, hi } => {
            let _site = pool::cow_site("dpdr/dual-exchange");
            y.extract_owned(lo, hi)
        }
        Src::Snapshot => {
            let _site = pool::cow_site("rd/butterfly-snapshot");
            Ok(y.snapshot())
        }
        Src::CloneY => Ok(y.clone()),
    }
}

fn apply_sink<E: Elem, O: ReduceOp<E> + ?Sized>(
    prog: &mut Prog<E>,
    sink: Sink,
    data: DataBuf<E>,
    op: &O,
    choice: ReduceBackend,
    timing: Timing,
) -> crate::error::Result<()> {
    match sink {
        Sink::Discard => {}
        Sink::WriteAt { lo } => prog.y.write_at(lo, &data)?,
        Sink::ReduceAt { lo, side } => {
            prog.charge(timing, data.bytes());
            let _b = backend::scope(choice);
            prog.y.reduce_at(lo, &data, op, side)?;
        }
        Sink::StashCharged => {
            prog.charge(timing, data.bytes());
            prog.stash = Some(data);
        }
        Sink::Reduce3At { lo } => {
            prog.charge(timing, data.bytes());
            let t0 = prog.stash.take().ok_or_else(|| {
                Error::Protocol("fused reduce3 with no stashed first child".into())
            })?;
            let _b = backend::scope(choice);
            prog.y.reduce_at3(lo, &t0, &data, op)?;
        }
        Sink::ReduceAll { side } => {
            prog.charge(timing, data.bytes());
            let _b = backend::scope(choice);
            prog.y.reduce_all(&data, op, side)?;
        }
        Sink::ReplaceY => prog.y = data,
    }
    Ok(())
}

/// Verbatim `ThreadComm::admit_send` over the virtual queue twin.
#[allow(clippy::too_many_arguments)]
fn admit_send(
    fabric: &Fabric,
    queues: &mut HashMap<(usize, usize), VirtQueue>,
    metrics: &mut RankMetrics,
    tag: u32,
    vtime: f64,
    src: usize,
    dst: usize,
    dur: f64,
) -> f64 {
    use crate::comm::net::trace_stall;
    use obs::stall_cause::{BACKPRESSURE, EGRESS_PORT};
    if !fabric.is_active() {
        return vtime;
    }
    let cap = fabric.edge_capacity(src, dst);
    let (freed_at, depth) = queues.entry((src, dst)).or_default().post(cap);
    metrics.max_queue_depth = metrics.max_queue_depth.max(depth);
    let mut t = vtime;
    if let Some(freed) = freed_at {
        if freed > t {
            metrics.queue_full_events += 1;
            metrics.stall_us += (freed - t) * 1e6;
            trace_stall(src, dst, tag, BACKPRESSURE, t, freed);
            t = freed;
        }
    }
    let start = fabric.reserve_egress(src, dst, t, dur);
    if start > t {
        metrics.stall_us += (start - t) * 1e6;
        trace_stall(src, dst, tag, EGRESS_PORT, t, start);
    }
    start
}

/// Verbatim `ThreadComm::finish_recv`.
#[allow(clippy::too_many_arguments)]
fn finish_recv(
    fabric: &Fabric,
    queues: &mut HashMap<(usize, usize), VirtQueue>,
    metrics: &mut RankMetrics,
    tag: u32,
    src: usize,
    dst: usize,
    ready: f64,
    dur: f64,
) -> f64 {
    if !fabric.is_active() {
        return ready + dur;
    }
    let start = fabric.reserve_ingress(src, dst, ready, dur);
    if start > ready {
        metrics.stall_us += (start - ready) * 1e6;
        let cause = obs::stall_cause::INGRESS_PORT;
        crate::comm::net::trace_stall(dst, src, tag, cause, ready, start);
    }
    let done = start + dur;
    queues
        .entry((src, dst))
        .or_default()
        .drain(fabric.edge_capacity(src, dst), done);
    done
}

/// Verbatim `ThreadComm::post` fault pipeline over the mailbox, with
/// the reorder/duplicate *delivery* protocol replaced by its exact
/// counting emulation (the mailbox stays in send order, which is the
/// order the threaded receiver's sequence reassembly delivers).
#[allow(clippy::too_many_arguments)]
fn post_mail<E: Elem>(
    mail: &mut HashMap<(usize, usize), Mailbox<E>>,
    prog: &mut Prog<E>,
    faults: &FaultPlan,
    fabric: &Fabric,
    tag: u32,
    src: usize,
    dst: usize,
    data: DataBuf<E>,
    stamp: f64,
) -> crate::error::Result<f64> {
    let bytes = data.bytes();
    let mb = mail.entry((src, dst)).or_default();
    if !faults.is_active() {
        mb.fifo.push_back(Packet {
            vtime: stamp,
            data,
            dups_before: 0,
        });
        prog.metrics.bytes_sent += bytes as u64;
        return Ok(stamp);
    }
    let seq = prog.tx_seq[dst];
    prog.tx_seq[dst] += 1;
    let mut stamp = stamp;
    if faults.stalled(src) {
        stamp += faults.stall_us * 1e-6;
    }
    let mut attempt = 0u32;
    while faults.drops(src, dst, tag, seq, attempt) {
        attempt += 1;
        if attempt > faults.max_retries {
            return Err(Error::RetriesExhausted {
                rank: src,
                peer: dst,
                attempts: attempt,
            });
        }
        stamp += faults.backoff_us * attempt as f64 * 1e-6;
        prog.metrics.retransmits += 1;
    }
    let delay = faults.delay_for(src, dst, tag, seq);
    if delay > 0.0 {
        prog.metrics.fault_events += 1;
    }
    let arrival = stamp + delay * 1e-6;
    // dup and reorder apply only on the inert fabric (the congestion
    // fabric's slot accounting assumes the channel matches the admitted
    // posts) — identical gate to the threaded post
    let mut dup_pending = 0u32;
    if !fabric.is_active() {
        if !prog.reorder_held[dst] && faults.reorders(src, dst, tag, seq) {
            // held back behind its successor: the sender counts the
            // event; a held message is never dup-rolled (the threaded
            // post returns before its duplicate branch)
            prog.metrics.fault_events += 1;
            prog.reorder_held[dst] = true;
        } else {
            let flushing = prog.reorder_held[dst];
            prog.reorder_held[dst] = false;
            if faults.duplicates(src, dst, tag, seq) {
                prog.metrics.fault_events += 1;
                // the receiver consumes (and counts) a duplicate only
                // when it trails the delivered original on the wire; a
                // copy sent ahead of a flushed hold is absorbed into the
                // reassembly buffer uncounted
                if !flushing {
                    dup_pending = 1;
                }
            }
        }
    }
    let dups_before = mb.pending_dup;
    mb.pending_dup = dup_pending;
    mb.fifo.push_back(Packet {
        vtime: arrival,
        data,
        dups_before,
    });
    prog.metrics.bytes_sent += bytes as u64;
    Ok(stamp)
}

fn pop_mail<E: Elem>(
    mail: &mut HashMap<(usize, usize), Mailbox<E>>,
    src: usize,
    dst: usize,
) -> Packet<E> {
    // Infallible: the drive loop only dispatches a recv half after
    // `runnable` saw `has_mail(src, dst)`, and nothing pops between the
    // check and this call (single driving thread per engine step).
    mail.get_mut(&(src, dst))
        .and_then(|m| m.fifo.pop_front())
        .expect("runnable recv-half has mail")
}

/// Progress-loop counters accumulated per *driving* rank and folded
/// into that rank's metrics at its next completed harvest.
#[derive(Default)]
struct DriveStats {
    wakeups: u64,
    ready_max: u64,
}

struct CoreState<E: Elem, O> {
    parked: Vec<bool>,
    drive_stats: Vec<DriveStats>,
    /// Outstanding operations keyed by tag (unique per op within a world
    /// epoch — the engine's tag leases guarantee it).
    ops: BTreeMap<u32, OpState<E, O>>,
}

/// What [`Core::drive`] resolves an operation to for one rank.
pub(crate) enum Outcome<E: Elem> {
    Done {
        y: DataBuf<E>,
        metrics: RankMetrics,
        vtime: f64,
        wall_us: f64,
    },
    /// Deadline cancellation: the rank's clock is pinned to exactly
    /// `v0 + deadline` and the operation contributed no metrics.
    Cancelled { vtime: f64 },
    Failed {
        err: Error,
        metrics: RankMetrics,
        vtime: f64,
    },
}

/// The world-shared progress core (see the module docs). Anchored once
/// per `(element, operator)` pair in the world's registry.
pub(crate) struct Core<E: Elem, O> {
    state: Mutex<CoreState<E, O>>,
    cv: Condvar,
}

impl<E: Elem, O: ReduceOp<E>> Core<E, O> {
    pub(crate) fn new(size: usize) -> Self {
        Core {
            state: Mutex::new(CoreState {
                parked: vec![false; size],
                drive_stats: (0..size).map(|_| DriveStats::default()).collect(),
                ops: BTreeMap::new(),
            }),
            cv: Condvar::new(),
        }
    }

    /// Deposit one rank's compiled program for the operation on `tag`.
    /// The operation arms (becomes executable) when all ranks deposited.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn deposit(
        &self,
        tag: u32,
        rank: usize,
        size: usize,
        sched: Schedule,
        x: DataBuf<E>,
        op: O,
        backend: ReduceBackend,
        timing: Timing,
        faults: FaultPlan,
        v0: f64,
        deadline_us: Option<f64>,
    ) {
        let mut st = relock(self.state.lock());
        let entry = st
            .ops
            .entry(tag)
            .or_insert_with(|| OpState::new(size, op, backend, timing, faults, deadline_us));
        let done_now = sched.steps.is_empty();
        let now = Instant::now();
        entry.progs[rank] = Some(Prog {
            rank,
            steps: sched.steps,
            pc: 0,
            half: Half::Start,
            y: x,
            stash: None,
            v0,
            vtime: v0,
            wall0: now,
            done_wall: done_now.then_some(now),
            metrics: RankMetrics::default(),
            tx_seq: vec![0; size],
            reorder_held: vec![false; size],
            obs_seq: None,
        });
        entry.done[rank] = done_now;
        entry.deposited += 1;
        drop(st);
        self.cv.notify_all();
    }

    /// Drive the core until this rank's program for the operation on
    /// `tag` resolves. Any rank's drive progresses *all* armed
    /// operations; parked ranks are what the congested-fabric seal
    /// counts.
    pub(crate) fn drive(
        &self,
        registry: &ShardedRegistry<E>,
        rank: usize,
        tag: u32,
        watchdog: Duration,
    ) -> Outcome<E> {
        let mut st = relock(self.state.lock());
        st.parked[rank] = true;
        self.cv.notify_all();
        let mut last_progress = Instant::now();
        loop {
            st.drive_stats[rank].wakeups += 1;
            if let Some(out) = self.harvest(&mut st, rank, tag) {
                st.parked[rank] = false;
                drop(st);
                self.cv.notify_all();
                return out;
            }
            if Self::pump(&mut st, registry, rank) {
                last_progress = Instant::now();
                self.cv.notify_all();
                continue;
            }
            if registry.is_poisoned() {
                let out = Self::harvest_err(
                    &mut st,
                    rank,
                    tag,
                    Error::Disconnected { rank, peer: rank },
                );
                st.parked[rank] = false;
                drop(st);
                self.cv.notify_all();
                return out;
            }
            if last_progress.elapsed() >= watchdog {
                registry.poison();
                let out =
                    Self::harvest_err(&mut st, rank, tag, Error::PeerStalled { rank, peer: rank });
                st.parked[rank] = false;
                drop(st);
                self.cv.notify_all();
                return out;
            }
            st = self
                .cv
                .wait_timeout(st, DRIVE_POLL)
                .unwrap_or_else(|p| p.into_inner())
                .0;
        }
    }

    /// Every rank with an unfinished program in an armed op is parked —
    /// the gate for deterministic execution on the shared fabric.
    fn sealed(st: &CoreState<E, O>) -> bool {
        for op in st.ops.values() {
            if !op.armed() {
                continue;
            }
            for r in 0..op.progs.len() {
                if op.progs[r].is_some() && !op.done[r] && !st.parked[r] {
                    return false;
                }
            }
        }
        true
    }

    /// Execute ready halves until none is runnable: each scan picks the
    /// least `(vtime, rank, tag)` runnable half across every armed op.
    /// Returns whether anything ran (or an op was cancelled).
    fn pump(st: &mut CoreState<E, O>, registry: &ShardedRegistry<E>, stats_rank: usize) -> bool {
        let fabric = registry.fabric();
        let mut progressed = false;
        loop {
            if fabric.is_active() && !Self::sealed(st) {
                break;
            }
            let mut best: Option<(f64, usize, u32)> = None;
            let mut ready = 0u64;
            for (&tag, op) in st.ops.iter() {
                if !op.armed() {
                    continue;
                }
                for r in 0..op.progs.len() {
                    if !op.runnable(r, fabric) {
                        continue;
                    }
                    ready += 1;
                    let vt = op.progs[r].as_ref().expect("runnable prog").vtime;
                    let better = match best {
                        None => true,
                        Some((bv, br, bt)) => match vt.total_cmp(&bv) {
                            std::cmp::Ordering::Less => true,
                            std::cmp::Ordering::Greater => false,
                            std::cmp::Ordering::Equal => (r, tag) < (br, bt),
                        },
                    };
                    if better {
                        best = Some((vt, r, tag));
                    }
                }
            }
            let ds = &mut st.drive_stats[stats_rank];
            ds.ready_max = ds.ready_max.max(ready);
            let Some((_, r, tag)) = best else { break };
            let op = st.ops.get_mut(&tag).expect("selected op exists");
            if let Some(dl) = op.deadline_us {
                let prog = op.progs[r].as_ref().expect("selected prog");
                if (prog.vtime - prog.v0) * 1e6 > dl {
                    // step-boundary cancellation: the whole op abandons
                    op.cancelled = true;
                    progressed = true;
                    continue;
                }
            }
            if let Err(e) = op.exec_half(tag, r, fabric) {
                op.failed = Some((r, e));
                registry.poison();
            }
            progressed = true;
        }
        progressed
    }

    /// Resolve this rank's program if it reached a terminal state.
    fn harvest(&self, st: &mut CoreState<E, O>, rank: usize, tag: u32) -> Option<Outcome<E>> {
        let out = {
            let op = st.ops.get_mut(&tag)?;
            if op.cancelled {
                let dl = op.deadline_us.unwrap_or(0.0);
                let v0 = op.progs[rank].take().map_or(0.0, |p| p.v0);
                Some(Outcome::Cancelled {
                    vtime: v0 + dl * 1e-6,
                })
            } else if op.done[rank]
                && (op.deadline_us.is_none() || op.done.iter().all(|&d| d))
            {
                // a deadline op resolves Done only once the WHOLE op
                // finished: until then a later step on another rank can
                // still cancel it, and a rank that already took Ok
                // while its peers take Err(Deadline) would split the
                // engines' cancelled-tag recycling (SPMD divergence)
                let prog = op.progs[rank].take().expect("done prog present");
                let wall_us = prog
                    .done_wall
                    .expect("done prog stamped")
                    .duration_since(prog.wall0)
                    .as_secs_f64()
                    * 1e6;
                Some(Outcome::Done {
                    y: prog.y,
                    metrics: prog.metrics,
                    vtime: prog.vtime,
                    wall_us,
                })
            } else if let Some((origin, err)) = &op.failed {
                let e = if *origin == rank {
                    clone_error(err)
                } else {
                    Error::Disconnected { rank, peer: rank }
                };
                let (metrics, vtime) = op.progs[rank]
                    .take()
                    .map_or((RankMetrics::default(), 0.0), |p| (p.metrics, p.vtime));
                Some(Outcome::Failed {
                    err: e,
                    metrics,
                    vtime,
                })
            } else {
                None
            }
        };
        let mut out = out?;
        match &mut out {
            Outcome::Done { metrics, .. } | Outcome::Failed { metrics, .. } => {
                let ds = std::mem::take(&mut st.drive_stats[rank]);
                metrics.progress_wakeups += ds.wakeups;
                metrics.ready_queue_max = metrics.ready_queue_max.max(ds.ready_max);
            }
            Outcome::Cancelled { .. } => {}
        }
        Self::release(st, rank, tag);
        Some(out)
    }

    /// Resolve this rank's program as failed with `err` (world poison or
    /// watchdog expiry), salvaging any partial metrics.
    fn harvest_err(
        st: &mut CoreState<E, O>,
        rank: usize,
        tag: u32,
        err: Error,
    ) -> Outcome<E> {
        let (mut metrics, vtime) = st
            .ops
            .get_mut(&tag)
            .and_then(|op| op.progs[rank].take())
            .map_or((RankMetrics::default(), 0.0), |p| (p.metrics, p.vtime));
        let ds = std::mem::take(&mut st.drive_stats[rank]);
        metrics.progress_wakeups += ds.wakeups;
        metrics.ready_queue_max = metrics.ready_queue_max.max(ds.ready_max);
        Self::release(st, rank, tag);
        Outcome::Failed { err, metrics, vtime }
    }

    /// Mark this rank's harvest and drop the op once every rank took its
    /// result.
    fn release(st: &mut CoreState<E, O>, rank: usize, tag: u32) {
        if let Some(op) = st.ops.get_mut(&tag) {
            op.harvested[rank] = true;
            if op.harvested.iter().all(|&h| h) {
                st.ops.remove(&tag);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virt_queue_mirrors_edge_queue() {
        let mut q = VirtQueue::default();
        // unbounded: always postable, never records drains
        assert!(q.can_post(0));
        assert_eq!(q.post(0), (None, 1));
        q.drain(0, 1.0);
        assert!(q.drains.is_empty());

        // capacity 2: third post reuses the first slot's drain time
        let mut q = VirtQueue::default();
        assert_eq!(q.post(2), (None, 1));
        assert_eq!(q.post(2), (None, 2));
        assert!(!q.can_post(2), "full and no drain recorded yet");
        q.drain(2, 5.0);
        assert!(q.can_post(2));
        assert_eq!(q.post(2), (Some(5.0), 2));
    }

    #[test]
    fn effectively_unbounded_capacity_never_blocks() {
        let mut q = VirtQueue::default();
        let cap = EFFECTIVELY_UNBOUNDED as usize;
        assert!(!records_drains(cap));
        assert_eq!(q.post(cap), (None, 1));
        assert!(q.can_post(cap));
    }

    #[test]
    fn clone_error_preserves_typed_variants() {
        let e = clone_error(&Error::RetriesExhausted {
            rank: 1,
            peer: 2,
            attempts: 7,
        });
        assert!(matches!(
            e,
            Error::RetriesExhausted {
                rank: 1,
                peer: 2,
                attempts: 7
            }
        ));
        let e = clone_error(&Error::Config("x".into()));
        assert!(matches!(e, Error::Protocol(_)));
    }
}
