//! Static verification of compiled collective schedules.
//!
//! [`compile`] lowers a collective to per-rank [`Schedule`]s; this module
//! *proves* properties of the whole world of schedules without executing
//! them, and emits a [`ScheduleCert`] per `(algo, p, blocks)` point:
//!
//! 1. **Communication matching** — every send half has exactly one
//!    matching receive, FIFO-consistent per `(src, dst)` edge (all steps
//!    of one operation share the operation's tag, so per-edge FIFO *is*
//!    per-`(src, dst, tag)` FIFO). Element-count agreement is enforced
//!    through the receiver's sink bounds in the symbolic simulation.
//! 2. **Deadlock-freedom** — the cross-rank happens-before graph over
//!    step half-actions (send half, receive completion) is acyclic. The
//!    graph is parameterized by the per-edge injection-queue capacity
//!    `k` of the bounded regime ([`crate::schedule::exec`]'s `VirtQueue`,
//!    mirroring `CostModel::Congested`): posting the `j`-th send on an
//!    edge requires the receiver to have completed message `j − k`, so
//!    proving capacity 1 proves every capacity ≥ 1 (the capacity-(k+1)
//!    edge is implied by the capacity-k edge plus program order).
//! 3. **Buffer/lease safety** — the COW-hazard class PR 1 patched by
//!    hand: a step must not overwrite a range of `y` while a zero-copy
//!    view of that range ([`Src::Block`], [`Src::CloneY`]) may still be
//!    in flight. Vector clocks over the unbounded happens-before graph
//!    prove every overlapping write is ordered after the receiver
//!    consumed the view ([`Src::OwnedBlock`] and [`Src::Snapshot`] are
//!    owned payloads and exempt — they exist precisely where a view
//!    would race). Def-before-use of result blocks falls out of the
//!    shape check: a sink reading an undefined region would poison the
//!    rank-interval witness below.
//! 4. **Reduction-shape determinism** — a symbolic lockstep run over
//!    [`ShapeElem`] (rank-interval [`Span`] + leaf-coverage mask + a
//!    non-commutative combine fingerprint) proves every element of every
//!    rank's result combines each leaf exactly once, in ascending rank
//!    order for order-preserving algorithms, with the *same* combine
//!    tree on every rank; [`verify_compiled`] can additionally replay
//!    the blocking oracle over [`ShapeElem`] and require fingerprint
//!    equality, pinning the compiled order to the oracle's.
//!
//! Uncompiled algorithms are covered post-hoc: [`verify_traced`] runs
//! the blocking implementation under [`TraceComm`] with [`ShapeElem`]
//! payloads and feeds the captured [`TraceEvent`] streams through the
//! same matching and graph checks ([`check_trace`]), plus the shape
//! check on the real results. `Recv` events log the element count they
//! actually delivered, so trace matching is length-exact on every plain
//! receive: per-edge channels are FIFO, and after the count check the
//! k-th receive on an edge must carry the k-th send's logged length
//! (fused sendrecv receive-halves consume their queue slot unchecked —
//! their delivered sizes are not logged). Bounded-capacity results are
//! reported as *warnings*, not violations: the threaded blocking engine never
//! schedules against a bounded injection queue (a full queue only
//! advances the virtual clock), so capacity analysis of a trace is
//! advisory — it says whether the algorithm *would* be safe if compiled
//! onto the event-driven core. `Hier` is excluded (it runs on
//! sub-communicators and a barrier, which traces cannot express), and
//! fused batches are one compiled dpdr at the fused length plus local
//! scatter, so dpdr certificates cover them.
//!
//! Verification is cheap (milliseconds per point) and pure; the
//! nonblocking engine can gate compilation on it via
//! [`verify_world_cached`] (`NbcConfig::verify_schedules`), and the
//! `dpdr verify` CLI sweeps the full algo × p × blocks matrix.

use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};
use std::fmt;
use std::sync::{Mutex, OnceLock};

use super::{compile, Schedule, Sink, Src, Step, TraceComm, TraceEvent};
use crate::buffer::DataBuf;
use crate::comm::{run_world, Comm, Timing};
use crate::error::{Error, Result};
use crate::model::AlgoKind;
use crate::ops::{Elem, ReduceOp, Side, Span};
use crate::pipeline::Blocks;

// ---------------------------------------------------------------------
// Diagnostics
// ---------------------------------------------------------------------

/// Which half of a step an event refers to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Half {
    /// The send is posted (logged before the receive is awaited).
    Send,
    /// The receive completes and the sink is applied.
    Recv,
}

/// One half-action of one rank's program — the nodes of the
/// happens-before graph and the vocabulary of cycle diagnostics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EventRef {
    pub rank: usize,
    pub step: usize,
    pub half: Half,
}

impl fmt::Display for EventRef {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let h = match self.half {
            Half::Send => "send",
            Half::Recv => "recv",
        };
        write!(f, "r{}.s{}.{}", self.rank, self.step, h)
    }
}

/// A typed verification failure. Every mutation class of the test
/// battery maps to exactly one of these; [`Violation::kind`] is the
/// stable name used in `ScheduleCert` JSON.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Violation {
    /// The schedule set itself is malformed (rank/size fields, peer out
    /// of range, or an internal invariant breach).
    World { detail: String },
    /// A step addresses its own rank — self-messages are not a thing
    /// the transport or the progress core support.
    SelfMessage { rank: usize, step: usize },
    /// A directed edge posts more sends than the peer receives (or vice
    /// versa): a dropped receive, a retargeted peer, a tag swap.
    CountMismatch { src: usize, dst: usize, sends: usize, recvs: usize },
    /// A payload length is incompatible with the receiver's sink or a
    /// send range is out of bounds.
    LengthMismatch { rank: usize, step: usize, detail: String },
    /// The fused-round stash protocol is broken: `Reduce3At` without a
    /// stash, a stash overwritten, or a stash never consumed.
    StashProtocol { rank: usize, step: usize, detail: &'static str },
    /// The happens-before graph has a cycle at the given edge-queue
    /// capacity (`0` means unbounded queues — a true protocol deadlock).
    Deadlock { capacity: usize, cycle: Vec<EventRef> },
    /// A step overwrites `y[lo..hi]` while a zero-copy view of that
    /// range, sent at `view_step`, may still be in flight.
    OverwriteHazard { rank: usize, step: usize, lo: usize, hi: usize, view_step: usize },
    /// A write sink runs after `ReplaceY`: the working vector is then a
    /// borrowed view of a peer's buffer, so every write would CoW.
    NonExclusiveWrite { rank: usize, step: usize },
    /// A rank's final vector has the wrong length.
    FinalLength { rank: usize, got: usize, want: usize },
    /// An element of a rank's result has the wrong reduction shape
    /// (missing/duplicated leaves or an out-of-rank-order combine).
    ShapeOrder { rank: usize, elem: usize, detail: String },
    /// Two ranks built different combine trees for the same element.
    ShapeDivergence { rank: usize, elem: usize },
    /// The compiled schedule's combine tree differs from the blocking
    /// oracle's for this element.
    OracleDivergence { rank: usize, elem: usize },
}

impl Violation {
    /// Stable kind tag (used by the JSON report and the test battery).
    pub fn kind(&self) -> &'static str {
        match self {
            Violation::World { .. } => "world",
            Violation::SelfMessage { .. } => "self-message",
            Violation::CountMismatch { .. } => "count-mismatch",
            Violation::LengthMismatch { .. } => "length-mismatch",
            Violation::StashProtocol { .. } => "stash-protocol",
            Violation::Deadlock { .. } => "deadlock",
            Violation::OverwriteHazard { .. } => "overwrite-hazard",
            Violation::NonExclusiveWrite { .. } => "non-exclusive-write",
            Violation::FinalLength { .. } => "final-length",
            Violation::ShapeOrder { .. } => "shape-order",
            Violation::ShapeDivergence { .. } => "shape-divergence",
            Violation::OracleDivergence { .. } => "oracle-divergence",
        }
    }
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Violation::World { detail } => {
                write!(f, "malformed world: {detail}")
            }
            Violation::SelfMessage { rank, step } => {
                write!(f, "rank {rank} step {step}: message addressed to self")
            }
            Violation::CountMismatch { src, dst, sends, recvs } => {
                write!(f, "edge {src}->{dst}: {sends} send(s) vs {recvs} recv(s)")
            }
            Violation::LengthMismatch { rank, step, detail } => {
                write!(f, "rank {rank} step {step}: {detail}")
            }
            Violation::StashProtocol { rank, step, detail } => {
                write!(f, "rank {rank} step {step}: {detail}")
            }
            Violation::Deadlock { capacity, cycle } => {
                if *capacity == 0 {
                    write!(f, "deadlock under unbounded queues: cycle")?;
                } else {
                    write!(f, "deadlock at edge-queue capacity {capacity}: cycle")?;
                }
                for (i, e) in cycle.iter().take(12).enumerate() {
                    let sep = if i == 0 { ' ' } else { '>' };
                    write!(f, "{sep}{e}")?;
                }
                if cycle.len() > 12 {
                    write!(f, ">… ({} events)", cycle.len())?;
                }
                Ok(())
            }
            Violation::OverwriteHazard { rank, step, lo, hi, view_step } => {
                write!(
                    f,
                    "rank {rank} step {step}: overwrites y[{lo}..{hi}] while the view sent at \
                     step {view_step} may still be in flight"
                )
            }
            Violation::NonExclusiveWrite { rank, step } => {
                write!(f, "rank {rank} step {step}: write after ReplaceY (y is a borrowed view)")
            }
            Violation::FinalLength { rank, got, want } => {
                write!(f, "rank {rank}: final vector length {got}, expected {want}")
            }
            Violation::ShapeOrder { rank, elem, detail } => {
                write!(f, "rank {rank} element {elem}: {detail}")
            }
            Violation::ShapeDivergence { rank, elem } => {
                write!(f, "rank {rank} element {elem}: reduction tree differs from rank 0")
            }
            Violation::OracleDivergence { rank, elem } => {
                write!(
                    f,
                    "rank {rank} element {elem}: compiled reduction order differs from the \
                     blocking oracle"
                )
            }
        }
    }
}

// ---------------------------------------------------------------------
// Shape witness element
// ---------------------------------------------------------------------

/// Fingerprint identity (absorbed by [`fp_combine`] on either side).
const FP_IDENT: u64 = 0x1dea_0000_0000_0001;

fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Non-commutative, non-associative hash mix: equal fingerprints mean
/// equal combine *trees* (same leaves, same order, same parenthesization),
/// up to 2⁻⁶⁴ collisions.
fn fp_combine(a: u64, b: u64) -> u64 {
    if a == FP_IDENT {
        return b;
    }
    if b == FP_IDENT {
        return a;
    }
    splitmix64(a ^ b.rotate_left(17))
}

/// The symbolic element the verifier reduces instead of numbers: a rank
/// interval ([`Span`] — poisons on out-of-order concatenation), a leaf
/// coverage bitmask (ranks 0..64), a leaf count, and a combine-tree
/// fingerprint. Usable both by the static lockstep simulation and by
/// real blocking runs (it implements [`Elem`], and [`ShapeOp`] is an
/// ordinary [`ReduceOp`]), which is what lets [`verify_compiled`]
/// compare the compiled order against the blocking oracle's.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShapeElem {
    pub span: Span,
    pub cover: u64,
    pub count: u32,
    pub fp: u64,
}

impl ShapeElem {
    /// The identity of [`ShapeOp`] (also the buffer fill value).
    pub const IDENTITY: ShapeElem =
        ShapeElem { span: Span::IDENT, cover: 0, count: 0, fp: FP_IDENT };

    /// The leaf contributed by `rank`'s input vector.
    pub fn leaf(rank: usize) -> ShapeElem {
        ShapeElem {
            span: Span::rank(rank as u32),
            cover: if rank < 64 { 1u64 << rank } else { 0 },
            count: 1,
            fp: splitmix64(0x5eed ^ ((rank as u64) << 1)),
        }
    }
}

impl Elem for ShapeElem {
    const BYTES: usize = 32;
    const DTYPE: &'static str = "shape";
    fn zero() -> Self {
        ShapeElem::IDENTITY
    }
}

/// The reduction operator over [`ShapeElem`]: span concatenation,
/// coverage union, leaf count sum, fingerprint mix. Associative only in
/// the components the checks rely on being associative (span, cover,
/// count); the fingerprint is deliberately *not* associative — it is a
/// tree witness, not a value.
pub struct ShapeOp;

impl ReduceOp<ShapeElem> for ShapeOp {
    fn identity(&self) -> ShapeElem {
        ShapeElem::IDENTITY
    }

    fn combine(&self, a: ShapeElem, b: ShapeElem) -> ShapeElem {
        ShapeElem {
            span: a.span.concat(b.span),
            cover: a.cover | b.cover,
            count: a.count.wrapping_add(b.count),
            fp: fp_combine(a.fp, b.fp),
        }
    }

    fn name(&self) -> &'static str {
        "shape"
    }
}

// ---------------------------------------------------------------------
// Call shapes and the happens-before event graph
// ---------------------------------------------------------------------

/// The communication silhouette of one step or traced call.
#[derive(Clone, Copy, Debug)]
struct CallShape {
    send_to: Option<usize>,
    recv_from: Option<usize>,
}

fn step_shape(s: &Step) -> CallShape {
    match *s {
        Step::SendRecv { peer, .. } => CallShape { send_to: Some(peer), recv_from: Some(peer) },
        Step::SendRecvPair { send_to, recv_from, .. } => {
            CallShape { send_to: Some(send_to), recv_from: Some(recv_from) }
        }
        Step::Send { peer, .. } => CallShape { send_to: Some(peer), recv_from: None },
        Step::Recv { peer, .. } => CallShape { send_to: None, recv_from: Some(peer) },
    }
}

/// What a step sends, if anything.
fn step_send(s: Step) -> Option<(usize, Src)> {
    match s {
        Step::SendRecv { peer, send, .. } => Some((peer, send)),
        Step::SendRecvPair { send_to, send, .. } => Some((send_to, send)),
        Step::Send { peer, send } => Some((peer, send)),
        Step::Recv { .. } => None,
    }
}

/// What a step receives, if anything.
fn step_recv(s: Step) -> Option<(usize, Sink)> {
    match s {
        Step::SendRecv { peer, sink, .. } => Some((peer, sink)),
        Step::SendRecvPair { recv_from, sink, .. } => Some((recv_from, sink)),
        Step::Recv { peer, sink } => Some((peer, sink)),
        Step::Send { .. } => None,
    }
}

/// Rank/peer sanity: fields consistent, peers in range, no self-messages.
fn check_world(calls: &[Vec<CallShape>]) -> Vec<Violation> {
    let p = calls.len();
    let mut viol = Vec::new();
    for (r, list) in calls.iter().enumerate() {
        for (i, c) in list.iter().enumerate() {
            for peer in [c.send_to, c.recv_from].into_iter().flatten() {
                if peer == r {
                    viol.push(Violation::SelfMessage { rank: r, step: i });
                } else if peer >= p {
                    viol.push(Violation::World {
                        detail: format!("rank {r} step {i}: peer {peer} out of range for p={p}"),
                    });
                }
            }
        }
    }
    viol
}

/// Per-directed-edge send/recv count matching.
fn check_matching(calls: &[Vec<CallShape>]) -> Vec<Violation> {
    let mut edges: BTreeMap<(usize, usize), (usize, usize)> = BTreeMap::new();
    for (r, list) in calls.iter().enumerate() {
        for c in list {
            if let Some(to) = c.send_to {
                edges.entry((r, to)).or_insert((0, 0)).0 += 1;
            }
            if let Some(from) = c.recv_from {
                edges.entry((from, r)).or_insert((0, 0)).1 += 1;
            }
        }
    }
    edges
        .into_iter()
        .filter(|&(_, (s, v))| s != v)
        .map(|((src, dst), (sends, recvs))| Violation::CountMismatch { src, dst, sends, recvs })
        .collect()
}

/// The flattened event set: ids, program order, FIFO message pairing.
struct Events {
    /// Event metadata by id.
    info: Vec<EventRef>,
    /// Event ids per rank, in program order.
    rank_events: Vec<Vec<usize>>,
    /// Send event id of `[rank][call]`, if the call sends.
    send_ev: Vec<Vec<Option<usize>>>,
    /// Recv event id of `[rank][call]`, if the call receives.
    recv_ev: Vec<Vec<Option<usize>>>,
    /// Per directed edge: `(send_event, recv_event)` per message, in
    /// FIFO order. Only built once counts match.
    edge_msgs: BTreeMap<(usize, usize), Vec<(usize, usize)>>,
    /// Total message count.
    messages: usize,
}

/// Number events (send half before recv half within a step) and pair
/// the i-th send on each edge with the i-th receive from that peer.
/// Requires matching counts (checked by the caller).
fn build_events(calls: &[Vec<CallShape>]) -> Events {
    let p = calls.len();
    let mut info = Vec::new();
    let mut rank_events = vec![Vec::new(); p];
    let mut send_ev = vec![Vec::new(); p];
    let mut recv_ev = vec![Vec::new(); p];
    let mut edge_sends: BTreeMap<(usize, usize), Vec<usize>> = BTreeMap::new();
    let mut edge_recvs: BTreeMap<(usize, usize), Vec<usize>> = BTreeMap::new();
    for (r, list) in calls.iter().enumerate() {
        for (i, c) in list.iter().enumerate() {
            let mut se = None;
            let mut re = None;
            if let Some(to) = c.send_to {
                let id = info.len();
                info.push(EventRef { rank: r, step: i, half: Half::Send });
                rank_events[r].push(id);
                edge_sends.entry((r, to)).or_default().push(id);
                se = Some(id);
            }
            if let Some(from) = c.recv_from {
                let id = info.len();
                info.push(EventRef { rank: r, step: i, half: Half::Recv });
                rank_events[r].push(id);
                edge_recvs.entry((from, r)).or_default().push(id);
                re = Some(id);
            }
            send_ev[r].push(se);
            recv_ev[r].push(re);
        }
    }
    let mut edge_msgs = BTreeMap::new();
    let mut messages = 0;
    for (edge, sends) in edge_sends {
        let recvs = edge_recvs.remove(&edge).unwrap_or_default();
        debug_assert_eq!(sends.len(), recvs.len(), "caller must check matching first");
        messages += sends.len();
        edge_msgs.insert(edge, sends.into_iter().zip(recvs).collect());
    }
    Events { info, rank_events, send_ev, recv_ev, edge_msgs, messages }
}

/// Successor/predecessor adjacency of the happens-before graph at the
/// given edge-queue `capacity` (0 = unbounded). Edges:
/// program order within a rank; message `send → recv`; and, bounded
/// regime, `recv(msg j−k) → send(msg j)` per edge — the `VirtQueue`
/// admission rule of the progress core.
fn graph_edges(ev: &Events, capacity: usize) -> (Vec<Vec<usize>>, Vec<Vec<usize>>) {
    let n = ev.info.len();
    let mut succs = vec![Vec::new(); n];
    let mut preds = vec![Vec::new(); n];
    let mut push = |a: usize, b: usize| {
        succs[a].push(b);
        preds[b].push(a);
    };
    for list in &ev.rank_events {
        for w in list.windows(2) {
            push(w[0], w[1]);
        }
    }
    for msgs in ev.edge_msgs.values() {
        for &(s, r) in msgs {
            push(s, r);
        }
        if capacity > 0 {
            for j in capacity..msgs.len() {
                push(msgs[j - capacity].1, msgs[j].0);
            }
        }
    }
    (succs, preds)
}

/// Kahn topological sort: `Ok(order)` or `Err(cycle)` with the cycle's
/// events in happens-before direction.
fn topo_sort(
    succs: &[Vec<usize>],
    preds: &[Vec<usize>],
) -> std::result::Result<Vec<usize>, Vec<usize>> {
    let n = succs.len();
    let mut indeg: Vec<usize> = preds.iter().map(Vec::len).collect();
    let mut queue: VecDeque<usize> = (0..n).filter(|&e| indeg[e] == 0).collect();
    let mut order = Vec::with_capacity(n);
    while let Some(e) = queue.pop_front() {
        order.push(e);
        for &s in &succs[e] {
            indeg[s] -= 1;
            if indeg[s] == 0 {
                queue.push_back(s);
            }
        }
    }
    if order.len() == n {
        return Ok(order);
    }
    // every unprocessed event still has an unprocessed predecessor:
    // walk predecessors until one repeats, then cut the loop out
    let start = (0..n).find(|&e| indeg[e] > 0).expect("cycle exists when Kahn is incomplete");
    let mut seen_at: HashMap<usize, usize> = HashMap::new();
    let mut path = Vec::new();
    let mut cur = start;
    loop {
        if let Some(&i) = seen_at.get(&cur) {
            let mut cycle = path.split_off(i);
            cycle.reverse(); // predecessor walk → happens-before direction
            return Err(cycle);
        }
        seen_at.insert(cur, path.len());
        path.push(cur);
        cur = *preds[cur]
            .iter()
            .find(|&&q| indeg[q] > 0)
            .expect("unprocessed event keeps an unprocessed predecessor");
    }
}

/// Vector clocks over the unbounded graph, in topological order.
/// `vc[e][q]` = 1-based index of the latest event of rank `q` that
/// happens before (or is) `e`; 0 if none.
fn vector_clocks(ev: &Events, preds: &[Vec<usize>], topo: &[usize]) -> Vec<Vec<u32>> {
    let p = ev.rank_events.len();
    let mut pos = vec![0u32; ev.info.len()];
    for list in &ev.rank_events {
        for (i, &e) in list.iter().enumerate() {
            pos[e] = i as u32 + 1;
        }
    }
    let mut vc = vec![Vec::new(); ev.info.len()];
    for &e in topo {
        let mut acc = vec![0u32; p];
        for &pe in &preds[e] {
            for (a, &b) in acc.iter_mut().zip(&vc[pe]) {
                *a = (*a).max(b);
            }
        }
        let r = ev.info[e].rank;
        acc[r] = acc[r].max(pos[e]);
        vc[e] = acc;
    }
    vc
}

// ---------------------------------------------------------------------
// Symbolic lockstep simulation (matching lengths, stash protocol, shapes)
// ---------------------------------------------------------------------

type Mail = HashMap<(usize, usize), VecDeque<Vec<ShapeElem>>>;

struct SimRank {
    y: Vec<ShapeElem>,
    stash: Option<Vec<ShapeElem>>,
    replaced: bool,
}

fn materialize(
    y: &[ShapeElem],
    src: Src,
    rank: usize,
    step: usize,
    viol: &mut Vec<Violation>,
) -> Vec<ShapeElem> {
    match src {
        Src::Void => Vec::new(),
        Src::Block { lo, hi } | Src::OwnedBlock { lo, hi } => {
            if lo > hi || hi > y.len() {
                viol.push(Violation::LengthMismatch {
                    rank,
                    step,
                    detail: format!(
                        "send range {lo}..{hi} out of bounds for y of length {}",
                        y.len()
                    ),
                });
                let lo = lo.min(y.len());
                let hi = hi.clamp(lo, y.len());
                y[lo..hi].to_vec()
            } else {
                y[lo..hi].to_vec()
            }
        }
        Src::Snapshot | Src::CloneY => y.to_vec(),
    }
}

fn apply_sink(
    st: &mut SimRank,
    sink: Sink,
    t: Vec<ShapeElem>,
    rank: usize,
    step: usize,
    viol: &mut Vec<Violation>,
) {
    let op = ShapeOp;
    let n = t.len();
    let bounds_ok = |lo: usize, st: &SimRank, viol: &mut Vec<Violation>| -> bool {
        if lo + n > st.y.len() {
            viol.push(Violation::LengthMismatch {
                rank,
                step,
                detail: format!(
                    "sink of {n} element(s) at offset {lo} overflows y of length {}",
                    st.y.len()
                ),
            });
            false
        } else {
            true
        }
    };
    let writes_y = matches!(
        sink,
        Sink::WriteAt { .. }
            | Sink::ReduceAt { .. }
            | Sink::Reduce3At { .. }
            | Sink::ReduceAll { .. }
    );
    if st.replaced && writes_y {
        viol.push(Violation::NonExclusiveWrite { rank, step });
    }
    match sink {
        Sink::Discard => {}
        Sink::WriteAt { lo } => {
            if bounds_ok(lo, st, viol) {
                st.y[lo..lo + n].copy_from_slice(&t);
            }
        }
        Sink::ReduceAt { lo, side } => {
            if bounds_ok(lo, st, viol) {
                op.reduce_into(&mut st.y[lo..lo + n], &t, side);
            }
        }
        Sink::StashCharged => {
            if st.stash.is_some() {
                viol.push(Violation::StashProtocol {
                    rank,
                    step,
                    detail: "stash overwritten before Reduce3At consumed it",
                });
            }
            st.stash = Some(t);
        }
        Sink::Reduce3At { lo } => match st.stash.take() {
            None => {
                viol.push(Violation::StashProtocol {
                    rank,
                    step,
                    detail: "Reduce3At with no stashed block",
                });
            }
            Some(t0) => {
                if t0.len() != n {
                    viol.push(Violation::LengthMismatch {
                        rank,
                        step,
                        detail: format!(
                            "fused reduce lengths differ: stash {} vs incoming {n}",
                            t0.len()
                        ),
                    });
                } else if bounds_ok(lo, st, viol) {
                    op.reduce_into3(&mut st.y[lo..lo + n], &t0, &t);
                }
            }
        },
        Sink::ReduceAll { side } => {
            if n != st.y.len() {
                viol.push(Violation::LengthMismatch {
                    rank,
                    step,
                    detail: format!(
                        "ReduceAll of {n} element(s) against y of length {}",
                        st.y.len()
                    ),
                });
            } else {
                op.reduce_into(&mut st.y, &t, side);
            }
        }
        Sink::ReplaceY => {
            st.y = t;
            st.replaced = true;
        }
    }
}

/// Single-threaded lockstep run of the schedules over [`ShapeElem`],
/// mirroring `expected_events`' half-step loop. Returns the final
/// symbolic vectors; length/stash violations are recorded as they
/// occur. The caller must have proven unbounded acyclicity first, so
/// the loop cannot stall (a stall is reported defensively anyway).
fn simulate(scheds: &[Schedule], m: usize, viol: &mut Vec<Violation>) -> Vec<Vec<ShapeElem>> {
    let p = scheds.len();
    let mut ranks: Vec<SimRank> = (0..p)
        .map(|r| SimRank { y: vec![ShapeElem::leaf(r); m], stash: None, replaced: false })
        .collect();
    let mut pc = vec![0usize; p];
    let mut sent = vec![false; p];
    let mut mail: Mail = HashMap::new();
    loop {
        let mut progressed = false;
        let mut all_done = true;
        for r in 0..p {
            let steps = &scheds[r].steps;
            if pc[r] >= steps.len() {
                continue;
            }
            all_done = false;
            let step = steps[pc[r]];
            if !sent[r] {
                if let Some((to, src)) = step_send(step) {
                    let payload = materialize(&ranks[r].y, src, r, pc[r], viol);
                    mail.entry((r, to)).or_default().push_back(payload);
                }
                sent[r] = true;
                progressed = true;
            }
            let (from, sink) = match step_recv(step) {
                Some(x) => x,
                None => {
                    pc[r] += 1;
                    sent[r] = false;
                    continue;
                }
            };
            if let Some(t) = mail.get_mut(&(from, r)).and_then(|q| q.pop_front()) {
                apply_sink(&mut ranks[r], sink, t, r, pc[r], viol);
                pc[r] += 1;
                sent[r] = false;
                progressed = true;
            }
        }
        if all_done {
            break;
        }
        if !progressed {
            viol.push(Violation::World {
                detail: "internal: lockstep simulation stalled after acyclicity was proven"
                    .to_string(),
            });
            break;
        }
    }
    for (r, st) in ranks.iter().enumerate() {
        if st.stash.is_some() {
            viol.push(Violation::StashProtocol {
                rank: r,
                step: scheds[r].steps.len(),
                detail: "stashed block never consumed by Reduce3At",
            });
        }
    }
    ranks.into_iter().map(|st| st.y).collect()
}

// ---------------------------------------------------------------------
// COW-hazard analysis (Pass C)
// ---------------------------------------------------------------------

/// Wire element count of a source (same rule as `expected_events`).
fn src_elems(s: Src, m: usize) -> usize {
    match s {
        Src::Void => 0,
        Src::Block { lo, hi } | Src::OwnedBlock { lo, hi } => hi.saturating_sub(lo),
        Src::Snapshot | Src::CloneY => m,
    }
}

/// Half-open write range of a sink receiving `n` elements, if it
/// mutates `y` in place (`ReplaceY` swaps buffers — the old slab is
/// released, not written, so it is not a hazard source).
fn sink_write_range(sink: Sink, n: usize, m: usize) -> Option<(usize, usize)> {
    match sink {
        Sink::WriteAt { lo } | Sink::ReduceAt { lo, .. } | Sink::Reduce3At { lo } => {
            Some((lo, lo + n))
        }
        Sink::ReduceAll { .. } => Some((0, m)),
        Sink::Discard | Sink::StashCharged | Sink::ReplaceY => None,
    }
}

/// Prove no rank overwrites a range of `y` while a zero-copy view of it
/// is still in flight. Views are [`Src::Block`] and [`Src::CloneY`]
/// sends; a view is consumed at the receiver's recv-completion event —
/// deferred to the following `Reduce3At` when the sink stashes, never
/// when the sink is `ReplaceY` (the receiver keeps the view as its
/// working vector). Every program-order-later overlapping write on the
/// sender must be ordered after that consumption in the *unbounded*
/// happens-before graph (bounded capacities only add ordering, so this
/// is sound for every capacity).
fn check_hazards(
    scheds: &[Schedule],
    m: usize,
    ev: &Events,
    preds: &[Vec<usize>],
    topo: &[usize],
    viol: &mut Vec<Violation>,
) {
    let vc = vector_clocks(ev, preds, topo);
    let mut pos = vec![0u32; ev.info.len()];
    for list in &ev.rank_events {
        for (i, &e) in list.iter().enumerate() {
            pos[e] = i as u32 + 1;
        }
    }
    // message pairing, both directions
    let mut send_of_recv: HashMap<usize, usize> = HashMap::new();
    let mut recv_of_send: HashMap<usize, usize> = HashMap::new();
    for msgs in ev.edge_msgs.values() {
        for &(s, r) in msgs {
            send_of_recv.insert(r, s);
            recv_of_send.insert(s, r);
        }
    }
    // receiver-side consumption event of the message arriving at recv
    // event `re` on rank `q`, call `c`
    let consumption = |q: usize, c: usize, re: usize| -> Option<usize> {
        let sink = step_recv(scheds[q].steps[c]).map(|(_, sink)| sink);
        match sink {
            Some(Sink::ReplaceY) => None,
            Some(Sink::StashCharged) => {
                let next = scheds[q].steps[c + 1..].iter().position(|s| {
                    matches!(step_recv(*s), Some((_, Sink::Reduce3At { .. })))
                });
                next.and_then(|off| ev.recv_ev[q][c + 1 + off])
            }
            _ => Some(re),
        }
    };
    for (r, sched) in scheds.iter().enumerate() {
        // in-flight views this rank has sent: (call, lo, hi, consume_ev)
        let mut leases: Vec<(usize, usize, usize, Option<usize>)> = Vec::new();
        for (c, step) in sched.steps.iter().enumerate() {
            if let Some((_, src)) = step_send(*step) {
                let range = match src {
                    Src::Block { lo, hi } if hi > lo => Some((lo, hi)),
                    Src::CloneY if m > 0 => Some((0, m)),
                    _ => None,
                };
                if let Some((lo, hi)) = range {
                    let se = ev.send_ev[r][c].expect("sending call has a send event");
                    let re = recv_of_send[&se];
                    let q = ev.info[re].rank;
                    let consume = consumption(q, ev.info[re].step, re);
                    leases.push((c, lo, hi, consume));
                }
            }
            if let Some((_, sink)) = step_recv(*step) {
                let re = ev.recv_ev[r][c].expect("receiving call has a recv event");
                let n = send_of_recv
                    .get(&re)
                    .map(|&se| {
                        let s = &scheds[ev.info[se].rank];
                        step_send(s.steps[ev.info[se].step])
                            .map(|(_, src)| src_elems(src, m))
                            .unwrap_or(0)
                    })
                    .unwrap_or(0);
                if let Some((wlo, whi)) = sink_write_range(sink, n, m) {
                    for &(vc_call, lo, hi, consume) in &leases {
                        if wlo < hi && lo < whi {
                            let safe = match consume {
                                None => false,
                                Some(ce) => {
                                    let q = ev.info[ce].rank;
                                    vc[re][q] >= pos[ce]
                                }
                            };
                            if !safe {
                                viol.push(Violation::OverwriteHazard {
                                    rank: r,
                                    step: c,
                                    lo: wlo,
                                    hi: whi,
                                    view_step: vc_call,
                                });
                            }
                        }
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Shape checks
// ---------------------------------------------------------------------

/// Check final [`ShapeElem`] vectors: every element of every rank must
/// combine each of the `p` leaves exactly once (coverage mask + count),
/// in ascending rank order when `require_rank_order` (contiguous
/// [`Span`]), and every rank must have built the *same* combine tree
/// (fingerprint equality against rank 0). The coverage mask saturates
/// at 64 ranks; the count and span checks hold for any `p`.
pub fn check_shapes(
    finals: &[Vec<ShapeElem>],
    p: usize,
    m: usize,
    require_rank_order: bool,
) -> Vec<Violation> {
    let mut viol = Vec::new();
    let full = match p {
        0..=63 => (1u64 << p) - 1,
        64 => u64::MAX,
        _ => 0,
    };
    for (r, y) in finals.iter().enumerate() {
        if y.len() != m {
            viol.push(Violation::FinalLength { rank: r, got: y.len(), want: m });
            continue;
        }
        for (i, e) in y.iter().enumerate() {
            let bad = if e.count as usize != p {
                Some(format!("element combines {} leaves, expected {p}", e.count))
            } else if p <= 64 && e.cover != full {
                Some(format!("leaf coverage mask {:#x}, expected {full:#x}", e.cover))
            } else if require_rank_order && e.span != Span::of(0, p as u32 - 1) {
                Some(format!(
                    "reduction span {:?}, expected the contiguous rank interval [0, {}]",
                    e.span,
                    p - 1
                ))
            } else {
                None
            };
            if let Some(detail) = bad {
                viol.push(Violation::ShapeOrder { rank: r, elem: i, detail });
                break; // one diagnostic per rank is enough
            }
        }
    }
    for r in 1..finals.len() {
        if finals[r].len() != finals[0].len() {
            continue; // already reported as FinalLength
        }
        if let Some(i) = (0..finals[0].len()).find(|&i| finals[r][i] != finals[0][i]) {
            viol.push(Violation::ShapeDivergence { rank: r, elem: i });
        }
    }
    viol
}

fn compare_to_oracle(finals: &[Vec<ShapeElem>], oracle: &[Vec<ShapeElem>]) -> Vec<Violation> {
    let mut viol = Vec::new();
    for (r, (a, b)) in finals.iter().zip(oracle).enumerate() {
        if a.len() != b.len() {
            viol.push(Violation::FinalLength { rank: r, got: a.len(), want: b.len() });
            continue;
        }
        if let Some(i) = (0..a.len()).find(|&i| a[i] != b[i]) {
            viol.push(Violation::OracleDivergence { rank: r, elem: i });
        }
    }
    viol
}

// ---------------------------------------------------------------------
// Top-level passes
// ---------------------------------------------------------------------

/// Knobs of [`verify_schedules`].
#[derive(Clone, Debug)]
pub struct VerifyOptions {
    /// Bounded edge-queue capacities to prove deadlock-free, besides
    /// the always-checked unbounded graph. Capacity 1 implies every
    /// larger capacity; 1/2/3 are checked explicitly because they are
    /// the `CostModel::Congested` presets.
    pub capacities: Vec<usize>,
    /// Require ascending rank order (contiguous spans) in the result —
    /// true for every compiled algorithm except ring, which reduces
    /// each segment in rotated ring order and is commutative-only.
    pub require_rank_order: bool,
}

impl Default for VerifyOptions {
    fn default() -> Self {
        VerifyOptions { capacities: vec![1, 2, 3], require_rank_order: true }
    }
}

/// The result of one verification pass.
#[derive(Clone, Debug)]
pub struct VerifyOutcome {
    /// Hard failures — empty means every checked property is proven.
    pub violations: Vec<Violation>,
    /// Advisory findings (trace mode demotes bounded-capacity cycles
    /// here, since the threaded engine never runs against bounded
    /// queues); always empty for compiled schedules.
    pub warnings: Vec<Violation>,
    /// Capacities whose happens-before graph is acyclic (0 = unbounded).
    pub capacities_proven: Vec<usize>,
    /// Total messages exchanged.
    pub messages: usize,
    /// Total steps (or traced calls) across ranks.
    pub steps_total: usize,
    /// Final symbolic vectors of the lockstep simulation (compiled mode
    /// only) — the left-hand side of the blocking-oracle comparison.
    pub finals: Option<Vec<Vec<ShapeElem>>>,
}

impl VerifyOutcome {
    fn bail(violations: Vec<Violation>, steps_total: usize) -> VerifyOutcome {
        VerifyOutcome {
            violations,
            warnings: Vec::new(),
            capacities_proven: Vec::new(),
            messages: 0,
            steps_total,
            finals: None,
        }
    }

    /// True when no hard violation was found.
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Statically verify a full world of per-rank schedules for a payload
/// of `m` elements: matching, deadlock-freedom (unbounded plus each
/// requested capacity), buffer/lease safety, and reduction shape. See
/// the module docs for what each check proves.
pub fn verify_schedules(scheds: &[Schedule], m: usize, opts: &VerifyOptions) -> VerifyOutcome {
    let p = scheds.len();
    let steps_total = scheds.iter().map(|s| s.steps.len()).sum();
    if p == 0 {
        return VerifyOutcome::bail(
            vec![Violation::World { detail: "empty schedule set".to_string() }],
            0,
        );
    }
    for (r, s) in scheds.iter().enumerate() {
        if s.rank != r || s.size != p {
            return VerifyOutcome::bail(
                vec![Violation::World {
                    detail: format!(
                        "schedule at index {r} claims rank {} of {} in a world of {p}",
                        s.rank, s.size
                    ),
                }],
                steps_total,
            );
        }
    }
    let calls: Vec<Vec<CallShape>> =
        scheds.iter().map(|s| s.steps.iter().map(step_shape).collect()).collect();
    let world = check_world(&calls);
    if !world.is_empty() {
        return VerifyOutcome::bail(world, steps_total);
    }
    let matching = check_matching(&calls);
    if !matching.is_empty() {
        return VerifyOutcome::bail(matching, steps_total);
    }
    let ev = build_events(&calls);
    let mut viol = Vec::new();
    let (succ0, pred0) = graph_edges(&ev, 0);
    let topo0 = match topo_sort(&succ0, &pred0) {
        Ok(order) => order,
        Err(cycle) => {
            let cycle = cycle.into_iter().map(|e| ev.info[e]).collect();
            let mut out = VerifyOutcome::bail(
                vec![Violation::Deadlock { capacity: 0, cycle }],
                steps_total,
            );
            out.messages = ev.messages;
            return out;
        }
    };
    let mut proven = vec![0usize];
    for &k in &opts.capacities {
        if k == 0 {
            continue;
        }
        let (succs, preds) = graph_edges(&ev, k);
        match topo_sort(&succs, &preds) {
            Ok(_) => proven.push(k),
            Err(cycle) => {
                let cycle = cycle.into_iter().map(|e| ev.info[e]).collect();
                viol.push(Violation::Deadlock { capacity: k, cycle });
            }
        }
    }
    let finals = simulate(scheds, m, &mut viol);
    check_hazards(scheds, m, &ev, &pred0, &topo0, &mut viol);
    viol.extend(check_shapes(&finals, p, m, opts.require_rank_order));
    VerifyOutcome {
        violations: viol,
        warnings: Vec::new(),
        capacities_proven: proven,
        messages: ev.messages,
        steps_total,
        finals: Some(finals),
    }
}

/// FIFO received-length check over a trace. Point-to-point channels
/// deliver in order per directed edge, so once [`check_matching`] has
/// proven the per-edge counts agree, the k-th receive on edge `(s, d)`
/// carries the k-th send's payload. Every receive half logs the element
/// count it actually delivered — [`TraceEvent::Recv`] directly, and the
/// `SendRecv` / `SendRecvPair` exchange events via their `recv_elems`
/// field — and each must match the matching send's logged length
/// exactly, so no call shape can hide a wrong block size behind a
/// peer-only match.
fn check_trace_lengths(traces: &[Vec<TraceEvent>]) -> Vec<Violation> {
    let mut sent: HashMap<(usize, usize), VecDeque<usize>> = HashMap::new();
    for (r, events) in traces.iter().enumerate() {
        for e in events {
            match *e {
                TraceEvent::Send { peer, send_elems }
                | TraceEvent::SendRecv { peer, send_elems, .. } => {
                    sent.entry((r, peer)).or_default().push_back(send_elems);
                }
                TraceEvent::SendRecvPair { send_to, send_elems, .. } => {
                    sent.entry((r, send_to)).or_default().push_back(send_elems);
                }
                TraceEvent::Recv { .. } | TraceEvent::Charge { .. } => {}
            }
        }
    }
    let mut viol = Vec::new();
    for (r, events) in traces.iter().enumerate() {
        for (i, e) in events.iter().enumerate() {
            let (from, got) = match *e {
                TraceEvent::Recv { peer, elems } => (peer, elems),
                TraceEvent::SendRecv { peer, recv_elems, .. } => (peer, recv_elems),
                TraceEvent::SendRecvPair {
                    recv_from,
                    recv_elems,
                    ..
                } => (recv_from, recv_elems),
                TraceEvent::Send { .. } | TraceEvent::Charge { .. } => continue,
            };
            // count matching already passed, so the queue cannot run dry
            let Some(want) = sent.get_mut(&(from, r)).and_then(VecDeque::pop_front) else {
                continue;
            };
            if got != want {
                viol.push(Violation::LengthMismatch {
                    rank: r,
                    step: i,
                    detail: format!(
                        "recv from {from} delivered {got} elems but the matching send logged {want}"
                    ),
                });
            }
        }
    }
    viol
}

/// Run the matching, received-length, and happens-before checks over
/// captured per-rank [`TraceEvent`] streams (`Recv` events carry their
/// delivered element count, so matching is length-exact on plain
/// receives; shapes are checked separately on the run's results).
/// Bounded-capacity cycles are *warnings* here — see the module docs.
pub fn check_trace(traces: &[Vec<TraceEvent>], capacities: &[usize]) -> VerifyOutcome {
    let calls: Vec<Vec<CallShape>> = traces
        .iter()
        .map(|events| {
            events
                .iter()
                .filter_map(|e| match *e {
                    TraceEvent::SendRecv { peer, .. } => {
                        Some(CallShape { send_to: Some(peer), recv_from: Some(peer) })
                    }
                    TraceEvent::SendRecvPair { send_to, recv_from, .. } => {
                        Some(CallShape { send_to: Some(send_to), recv_from: Some(recv_from) })
                    }
                    TraceEvent::Send { peer, .. } => {
                        Some(CallShape { send_to: Some(peer), recv_from: None })
                    }
                    TraceEvent::Recv { peer, .. } => {
                        Some(CallShape { send_to: None, recv_from: Some(peer) })
                    }
                    TraceEvent::Charge { .. } => None,
                })
                .collect()
        })
        .collect();
    let steps_total = calls.iter().map(Vec::len).sum();
    let world = check_world(&calls);
    if !world.is_empty() {
        return VerifyOutcome::bail(world, steps_total);
    }
    let matching = check_matching(&calls);
    if !matching.is_empty() {
        return VerifyOutcome::bail(matching, steps_total);
    }
    let lengths = check_trace_lengths(traces);
    if !lengths.is_empty() {
        return VerifyOutcome::bail(lengths, steps_total);
    }
    let ev = build_events(&calls);
    let (succ0, pred0) = graph_edges(&ev, 0);
    if let Err(cycle) = topo_sort(&succ0, &pred0) {
        let cycle = cycle.into_iter().map(|e| ev.info[e]).collect();
        let mut out =
            VerifyOutcome::bail(vec![Violation::Deadlock { capacity: 0, cycle }], steps_total);
        out.messages = ev.messages;
        return out;
    }
    let mut proven = vec![0usize];
    let mut warnings = Vec::new();
    for &k in capacities {
        if k == 0 {
            continue;
        }
        let (succs, preds) = graph_edges(&ev, k);
        match topo_sort(&succs, &preds) {
            Ok(_) => proven.push(k),
            Err(cycle) => {
                let cycle = cycle.into_iter().map(|e| ev.info[e]).collect();
                warnings.push(Violation::Deadlock { capacity: k, cycle });
            }
        }
    }
    VerifyOutcome {
        violations: Vec::new(),
        warnings,
        capacities_proven: proven,
        messages: ev.messages,
        steps_total,
        finals: None,
    }
}

// ---------------------------------------------------------------------
// Entry points
// ---------------------------------------------------------------------

/// Compile every rank of a world, or `Error::Config` if the algorithm
/// is not statically compiled.
pub fn compile_world(algo: AlgoKind, p: usize, blocks: &Blocks) -> Result<Vec<Schedule>> {
    (0..p)
        .map(|r| {
            compile(algo, r, p, blocks).ok_or_else(|| {
                Error::Config(format!("{} does not compile to schedules (p={p})", algo.name()))
            })
        })
        .collect()
}

/// Verify one compiled `(algo, p, blocks)` point and emit its
/// certificate. With `with_oracle`, additionally run the *blocking*
/// implementation over [`ShapeElem`] on a real thread world and require
/// its combine trees to match the static simulation's exactly — this is
/// the "matches the blocking oracle's order" half of property 4 and is
/// only skipped for sweeps where spawning p threads per point would
/// dominate (the static checks do not need threads).
pub fn verify_compiled(
    algo: AlgoKind,
    p: usize,
    blocks: &Blocks,
    capacities: &[usize],
    with_oracle: bool,
) -> Result<ScheduleCert> {
    let scheds = compile_world(algo, p, blocks)?;
    let opts = VerifyOptions {
        capacities: capacities.to_vec(),
        require_rank_order: algo.order_preserving(),
    };
    let mut out = verify_schedules(&scheds, blocks.total(), &opts);
    let mut oracle_checked = false;
    if with_oracle && out.ok() {
        if let Some(finals) = &out.finals {
            let oracle = oracle_shapes(algo, p, blocks)?;
            let diffs = compare_to_oracle(finals, &oracle);
            out.violations.extend(diffs);
            oracle_checked = true;
        }
    }
    Ok(ScheduleCert {
        algo: algo.name(),
        mode: "compiled",
        p,
        m: blocks.total(),
        blocks: blocks.count(),
        steps_total: out.steps_total,
        messages: out.messages,
        capacities_proven: out.capacities_proven,
        oracle_checked,
        violations: out.violations,
        warnings: out.warnings,
    })
}

/// Final [`ShapeElem`] vectors of the *blocking* implementation on a
/// real `p`-thread world — the oracle side of the order comparison.
pub fn oracle_shapes(algo: AlgoKind, p: usize, blocks: &Blocks) -> Result<Vec<Vec<ShapeElem>>> {
    let blocks = *blocks;
    let report = run_world::<ShapeElem, _, _>(p, Timing::Real, move |comm| {
        let x = DataBuf::real(vec![ShapeElem::leaf(comm.rank()); blocks.total()]);
        let y = crate::collectives::allreduce(algo, comm, x, &ShapeOp, &blocks)?;
        y.into_vec()
    })?;
    Ok(report.results)
}

/// Whether a traced run of `algo` over `m` [`ShapeElem`]s should
/// produce contiguous rank spans. The count-based switcher takes the
/// ring branch above its byte threshold, and the ring reduces segments
/// in rotated order.
fn trace_rank_order_expected(algo: AlgoKind, m: usize) -> bool {
    use crate::collectives::native_switch::{native_branch, NativeBranch};
    match algo {
        AlgoKind::NativeSwitch => {
            native_branch(m * ShapeElem::BYTES) == NativeBranch::RecursiveDoubling
        }
        _ => algo.order_preserving(),
    }
}

/// Trace-check an uncompiled algorithm: run the blocking implementation
/// over [`ShapeElem`] under [`TraceComm`] on a real thread world, then
/// feed the captured call streams through [`check_trace`] and the final
/// vectors through [`check_shapes`]. See the module docs for what this
/// does and does not prove compared to compiled-mode verification.
pub fn verify_traced(
    algo: AlgoKind,
    p: usize,
    blocks: &Blocks,
    capacities: &[usize],
) -> Result<ScheduleCert> {
    let blocks_v = *blocks;
    let report = run_world::<ShapeElem, _, _>(p, Timing::Real, move |comm| {
        let x = DataBuf::real(vec![ShapeElem::leaf(comm.rank()); blocks_v.total()]);
        let mut tc = TraceComm::new(comm);
        let y = crate::collectives::allreduce(algo, &mut tc, x, &ShapeOp, &blocks_v)?;
        let events = std::mem::take(&mut tc.events);
        Ok((events, y.into_vec()?))
    })?;
    let (traces, finals): (Vec<Vec<TraceEvent>>, Vec<Vec<ShapeElem>>) =
        report.results.into_iter().unzip();
    let m = blocks.total();
    let mut out = check_trace(&traces, capacities);
    let require = trace_rank_order_expected(algo, m);
    out.violations.extend(check_shapes(&finals, p, m, require));
    Ok(ScheduleCert {
        algo: algo.name(),
        mode: "trace",
        p,
        m,
        blocks: blocks.count(),
        steps_total: out.steps_total,
        messages: out.messages,
        capacities_proven: out.capacities_proven,
        oracle_checked: false,
        violations: out.violations,
        warnings: out.warnings,
    })
}

type VerifiedKey = (&'static str, usize, usize, usize);

static VERIFIED: OnceLock<Mutex<HashSet<VerifiedKey>>> = OnceLock::new();

/// Verify a compiled world once per `(algo, p, m, blocks)` process-wide
/// — the gate the nonblocking engine applies when
/// `NbcConfig::verify_schedules` is set. Capacity 1 is the strongest
/// bounded check (it implies every capacity ≥ 1), so it is the only one
/// proven here. Failures are returned as `Error::Protocol` and are
/// deterministic and SPMD-symmetric: every rank computes the same
/// verdict from the same schedules. Only successes are cached.
pub fn verify_world_cached(algo: AlgoKind, size: usize, blocks: &Blocks) -> Result<()> {
    let key: VerifiedKey = (algo.name(), size, blocks.total(), blocks.count());
    let cache = VERIFIED.get_or_init(|| Mutex::new(HashSet::new()));
    if cache.lock().map(|g| g.contains(&key)).unwrap_or(false) {
        return Ok(());
    }
    let scheds = compile_world(algo, size, blocks)?;
    let opts = VerifyOptions {
        capacities: vec![1],
        require_rank_order: algo.order_preserving(),
    };
    let out = verify_schedules(&scheds, blocks.total(), &opts);
    if let Some(v) = out.violations.first() {
        return Err(Error::Protocol(format!(
            "schedule verification failed for {} p={size}: {v}",
            algo.name()
        )));
    }
    if let Ok(mut guard) = cache.lock() {
        guard.insert(key);
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Certificates
// ---------------------------------------------------------------------

/// The verification certificate of one `(algo, p, blocks)` point —
/// what `dpdr verify` prints and CI uploads as `SCHEDULE_CERTS.json`.
#[derive(Clone, Debug)]
pub struct ScheduleCert {
    /// Algorithm name.
    pub algo: &'static str,
    /// `"compiled"` (static proof over schedules) or `"trace"`
    /// (post-hoc check over a captured blocking run).
    pub mode: &'static str,
    pub p: usize,
    pub m: usize,
    /// Pipeline block count of the verified point.
    pub blocks: usize,
    /// Steps (compiled) or non-charge calls (trace) across all ranks.
    pub steps_total: usize,
    /// Messages exchanged.
    pub messages: usize,
    /// Edge-queue capacities proven deadlock-free (0 = unbounded).
    pub capacities_proven: Vec<usize>,
    /// Whether the blocking-oracle order comparison ran.
    pub oracle_checked: bool,
    /// Hard failures; empty means the point is certified.
    pub violations: Vec<Violation>,
    /// Advisory findings (trace mode only).
    pub warnings: Vec<Violation>,
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn json_violations(list: &[Violation]) -> String {
    let items: Vec<String> = list
        .iter()
        .map(|v| {
            format!(
                "{{\"kind\":\"{}\",\"detail\":\"{}\"}}",
                v.kind(),
                json_escape(&v.to_string())
            )
        })
        .collect();
    items.join(",")
}

impl ScheduleCert {
    /// True when the point is certified (no hard violations).
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }

    /// Hand-written JSON object (the crate has no serde by design).
    pub fn to_json(&self) -> String {
        let caps: Vec<String> = self.capacities_proven.iter().map(usize::to_string).collect();
        format!(
            "{{\"algo\":\"{}\",\"mode\":\"{}\",\"p\":{},\"m\":{},\"blocks\":{},\"steps\":{},\
             \"messages\":{},\"capacities_proven\":[{}],\"oracle_checked\":{},\
             \"violations\":[{}],\"warnings\":[{}]}}",
            self.algo,
            self.mode,
            self.p,
            self.m,
            self.blocks,
            self.steps_total,
            self.messages,
            caps.join(","),
            self.oracle_checked,
            json_violations(&self.violations),
            json_violations(&self.warnings),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn world(steps_per_rank: Vec<Vec<Step>>) -> Vec<Schedule> {
        let size = steps_per_rank.len();
        steps_per_rank
            .into_iter()
            .enumerate()
            .map(|(rank, steps)| Schedule { rank, size, steps })
            .collect()
    }

    #[test]
    fn shape_combine_tracks_span_cover_count() {
        let op = ShapeOp;
        let mut acc = [ShapeElem::leaf(1)];
        let t = [ShapeElem::leaf(0)];
        op.reduce_into(&mut acc, &t, Side::Left);
        assert_eq!(acc[0].span, Span::of(0, 1));
        assert_eq!(acc[0].cover, 0b11);
        assert_eq!(acc[0].count, 2);
        // Out-of-order concatenation poisons the span but keeps the mask.
        let mut acc = [ShapeElem::leaf(3)];
        op.reduce_into(&mut acc, &[ShapeElem::leaf(0)], Side::Left);
        assert_eq!(acc[0].span, Span::POISON);
        assert_eq!(acc[0].cover, 0b1001);
    }

    #[test]
    fn self_message_is_rejected() {
        let s = world(vec![vec![Step::Send { peer: 0, send: Src::CloneY }]]);
        let out = verify_schedules(&s, 4, &VerifyOptions::default());
        assert!(out.violations.iter().any(|v| v.kind() == "self-message"));
    }

    #[test]
    fn unbalanced_edge_is_a_count_mismatch() {
        let s = world(vec![
            vec![
                Step::Send { peer: 1, send: Src::CloneY },
                Step::Send { peer: 1, send: Src::CloneY },
            ],
            vec![Step::Recv { peer: 0, sink: Sink::Discard }],
        ]);
        let out = verify_schedules(&s, 3, &VerifyOptions::default());
        assert!(out.violations.iter().any(|v| v.kind() == "count-mismatch"));
    }

    #[test]
    fn double_send_head_cycles_at_capacity_one_only() {
        // Both ranks post two sends before any recv: fine unbounded and at
        // capacity 2, a cycle at capacity 1 (second send waits on a recv
        // that is program-ordered after it on both sides).
        let steps = |peer: usize| {
            vec![
                Step::Send { peer, send: Src::CloneY },
                Step::Send { peer, send: Src::CloneY },
                Step::Recv { peer, sink: Sink::Discard },
                Step::Recv { peer, sink: Sink::Discard },
            ]
        };
        let s = world(vec![steps(1), steps(0)]);
        let opts = VerifyOptions { capacities: vec![1, 2], require_rank_order: false };
        let out = verify_schedules(&s, 2, &opts);
        let deadlocks: Vec<usize> = out
            .violations
            .iter()
            .filter_map(|v| match v {
                Violation::Deadlock { capacity, .. } => Some(*capacity),
                _ => None,
            })
            .collect();
        assert_eq!(deadlocks, vec![1]);
        assert!(out.capacities_proven.contains(&0));
        assert!(out.capacities_proven.contains(&2));
        assert!(!out.capacities_proven.contains(&1));
    }

    #[test]
    fn compiled_dpdr_verifies_clean() {
        let blocks = Blocks::by_count(8, 2);
        let scheds = compile_world(AlgoKind::Dpdr, 4, &blocks).expect("dpdr compiles");
        let out = verify_schedules(&scheds, 8, &VerifyOptions::default());
        assert!(out.ok(), "violations: {:?}", out.violations);
        assert_eq!(out.capacities_proven, vec![0, 1, 2, 3]);
        assert!(out.finals.is_some());
    }

    #[test]
    fn ring_needs_relaxed_rank_order() {
        let blocks = Blocks::by_count(6, 3);
        let scheds = compile_world(AlgoKind::Ring, 3, &blocks).expect("ring compiles");
        let strict = verify_schedules(&scheds, 6, &VerifyOptions::default());
        assert!(strict.violations.iter().any(|v| v.kind() == "shape-order"));
        let opts = VerifyOptions { require_rank_order: false, ..VerifyOptions::default() };
        let relaxed = verify_schedules(&scheds, 6, &opts);
        assert!(relaxed.ok(), "violations: {:?}", relaxed.violations);
    }

    #[test]
    fn cert_json_is_wellformed() {
        let cert = verify_compiled(AlgoKind::Ring, 3, &Blocks::by_count(6, 2), &[1], false)
            .expect("ring point verifies");
        assert!(cert.ok());
        let js = cert.to_json();
        assert!(js.contains("\"algo\":\"ring\""));
        assert!(js.contains("\"mode\":\"compiled\""));
        assert!(js.contains("\"violations\":[]"));
        assert_eq!(json_escape("a\"b\\c\n"), "a\\\"b\\\\c\\n");
    }

    #[test]
    fn world_cache_accepts_and_remembers() {
        let blocks = Blocks::by_count(8, 2);
        verify_world_cached(AlgoKind::DpdrSingle, 4, &blocks).expect("first pass");
        verify_world_cached(AlgoKind::DpdrSingle, 4, &blocks).expect("cached pass");
    }

    #[test]
    fn traced_nonpipelined_verifies_length_exact() {
        // Non-power-of-two p with an uneven partition: the circulant
        // reduce-scatter ships different run lengths every round, so a
        // count-only match would pass even if a length were wrong.
        let cert = verify_traced(AlgoKind::NonPipelined, 5, &Blocks::by_count(7, 1), &[1])
            .expect("trace runs");
        assert!(cert.ok(), "violations: {:?}", cert.violations);
    }

    #[test]
    fn trace_length_mismatch_is_reported() {
        // One send of 3 elems, the matching recv logs 2 delivered — the
        // counts agree, so only the FIFO length check can catch it.
        let bad = vec![
            vec![TraceEvent::Send { peer: 1, send_elems: 3 }],
            vec![TraceEvent::Recv { peer: 0, elems: 2 }],
        ];
        let out = check_trace(&bad, &[]);
        assert!(out.violations.iter().any(|v| v.kind() == "length-mismatch"));
        let good = vec![
            vec![TraceEvent::Send { peer: 1, send_elems: 3 }],
            vec![TraceEvent::Recv { peer: 0, elems: 3 }],
        ];
        assert!(check_trace(&good, &[]).violations.is_empty());
    }

    #[test]
    fn trace_exchange_recv_length_mismatch_is_reported() {
        // A symmetric exchange whose message counts balance perfectly:
        // rank 1 ships 3 elems but rank 0's fused receive half logs only
        // 2 delivered. Only the logged recv_elems can catch that.
        let bad = vec![
            vec![TraceEvent::SendRecv { peer: 1, send_elems: 3, recv_elems: 2 }],
            vec![TraceEvent::SendRecv { peer: 0, send_elems: 3, recv_elems: 3 }],
        ];
        let out = check_trace(&bad, &[]);
        assert!(
            out.violations.iter().any(|v| v.kind() == "length-mismatch"),
            "violations: {:?}",
            out.violations
        );
        // Delivered lengths equal to the shipped lengths verify clean,
        // for both exchange flavors (asymmetric lengths on purpose).
        let good = vec![
            vec![TraceEvent::SendRecv { peer: 1, send_elems: 3, recv_elems: 2 }],
            vec![TraceEvent::SendRecv { peer: 0, send_elems: 2, recv_elems: 3 }],
        ];
        assert!(check_trace(&good, &[]).violations.is_empty());
        let paired = vec![
            vec![TraceEvent::SendRecvPair {
                send_to: 1,
                recv_from: 1,
                send_elems: 3,
                recv_elems: 2,
            }],
            vec![TraceEvent::SendRecvPair {
                send_to: 0,
                recv_from: 0,
                send_elems: 2,
                recv_elems: 3,
            }],
        ];
        assert!(check_trace(&paired, &[]).violations.is_empty());
    }
}
