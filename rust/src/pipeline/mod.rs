//! Pipeline block partitioning and block-count selection.
//!
//! The paper divides each m-element vector into `b` successive blocks,
//! `0 < b ≤ m`, of roughly `m/b` elements (§1.1). The evaluation fixes the
//! *block size* at 16000 elements instead (§2), i.e. `b = ⌈m / 16000⌉`;
//! [`Blocks`] supports both parameterizations, and
//! [`Blocks::lemma_optimal`] applies the Pipelining Lemma of §1.2.

use crate::error::{Error, Result};
use crate::model::{lemma, LinkCost};
use crate::util::div_ceil;

/// The paper's compile-time pipeline block size (elements), §2.
pub const PAPER_BLOCK_ELEMS: usize = 16_000;

/// Which block-count schedule a run uses for the pipelined algorithms
/// (non-pipelined algorithms ignore it).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedKind {
    /// The spec's fixed block size (the paper's 16000-element default).
    Fixed,
    /// Pipelining-Lemma optimal uniform count (§1.2, continuous optimum
    /// rounded to the better neighbour) — [`Blocks::lemma_optimal`].
    Lemma,
    /// Greedy discrete optimum (Lowery–Langou, arXiv 1310.4645): exact
    /// scan of the integer block counts — [`Blocks::greedy_optimal`].
    Greedy,
}

impl SchedKind {
    pub fn parse(s: &str) -> Option<SchedKind> {
        Some(match s {
            "fixed" => SchedKind::Fixed,
            "lemma" => SchedKind::Lemma,
            "greedy" => SchedKind::Greedy,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            SchedKind::Fixed => "fixed",
            SchedKind::Lemma => "lemma",
            SchedKind::Greedy => "greedy",
        }
    }
}

/// Exact discrete pipeline time (seconds) of `b` balanced blocks of an
/// `m`-element vector under step structure `A + C·b`: the α-chain
/// `(A + C·b)·α`, plus every byte forwarded on each of the `C` per-block
/// steps (`C·β·M`) and the *largest* block (`⌈m/b⌉` elements — the one
/// every fixed step waits for) paid `A` times. The Pipelining Lemma
/// minimizes the continuous relaxation `(A + C·b)(α + β·M/b)`; this is
/// the integer objective the greedy schedule scans.
pub fn predicted_pipeline_time(
    m: usize,
    elem_bytes: usize,
    a_steps: f64,
    c_steps: f64,
    link: LinkCost,
    b: usize,
) -> f64 {
    let b = b.clamp(1, m.max(1));
    let max_block_bytes = (div_ceil(m.max(1), b) * elem_bytes) as f64;
    let total_bytes = (m * elem_bytes) as f64;
    (a_steps + c_steps * b as f64) * link.alpha
        + link.beta * (c_steps * total_bytes + a_steps * max_block_bytes)
}

/// A balanced partition of an `m`-element vector into `b` blocks.
///
/// Block `k` covers `[k·m/b, (k+1)·m/b)` (integer arithmetic), so sizes
/// differ by at most one element and concatenation is exact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Blocks {
    m: usize,
    b: usize,
}

impl Blocks {
    /// Partition into exactly `b` blocks (clamped to `[1, max(m,1)]`).
    pub fn by_count(m: usize, b: usize) -> Blocks {
        let b = b.clamp(1, m.max(1));
        Blocks { m, b }
    }

    /// Partition into *exactly* `b` segments, allowing empty ones (`m < b`).
    /// Used by the segment-based algorithms (ring, Rabenseifner), where the
    /// segment count is fixed by the rank count, not the data size.
    pub fn segments(m: usize, b: usize) -> Blocks {
        Blocks { m, b: b.max(1) }
    }

    /// Partition into blocks of at most `block_elems` elements
    /// (the paper's parameterization; `b = ⌈m / block_elems⌉`).
    pub fn by_size(m: usize, block_elems: usize) -> Result<Blocks> {
        if block_elems == 0 {
            return Err(Error::Config("block size must be > 0".into()));
        }
        Ok(Blocks::by_count(m, div_ceil(m.max(1), block_elems)))
    }

    /// The Pipelining-Lemma optimal block count for a pipelined algorithm
    /// with step structure `A + C·b` (§1.2) under `link`, for elements of
    /// `elem_bytes` bytes.
    pub fn lemma_optimal(
        m: usize,
        elem_bytes: usize,
        a_steps: f64,
        c_steps: f64,
        link: LinkCost,
    ) -> Blocks {
        let (b, _t) = lemma::optimal_time(
            a_steps,
            c_steps,
            link.alpha,
            link.beta,
            (m * elem_bytes) as f64,
            m.max(1),
        );
        Blocks::by_count(m, b)
    }

    /// The greedy discrete-optimal block count (Lowery–Langou,
    /// arXiv 1310.4645): scan the integer counts against the exact
    /// discrete objective [`predicted_pipeline_time`] instead of rounding
    /// the Lemma's continuous optimum. The scan always includes the
    /// Lemma's own pick, so the greedy schedule is never worse under the
    /// discrete model — and strictly better exactly where rounding `√·`
    /// or ragged `⌈m/b⌉` block sizes cost the uniform schedule.
    pub fn greedy_optimal(
        m: usize,
        elem_bytes: usize,
        a_steps: f64,
        c_steps: f64,
        link: LinkCost,
    ) -> Blocks {
        let m1 = m.max(1);
        let lemma_b = Blocks::lemma_optimal(m, elem_bytes, a_steps, c_steps, link).count();
        // small vectors: exhaustive; large: a window around the lemma
        // optimum (the objective is unimodal up to ⌈m/b⌉ plateaus, and the
        // discrete optimum stays within a small factor of the continuous
        // one — the window always contains lemma_b, preserving ≤).
        let cap = if m1 <= 4096 {
            m1
        } else {
            m1.min(4 * lemma_b + 16)
        };
        let mut best = (1usize, f64::INFINITY);
        for b in 1..=cap {
            let t = predicted_pipeline_time(m, elem_bytes, a_steps, c_steps, link, b);
            if t < best.1 {
                best = (b, t);
            }
        }
        Blocks::by_count(m, best.0)
    }

    /// Total element count.
    pub fn total(&self) -> usize {
        self.m
    }

    /// Number of blocks (≥ 1).
    pub fn count(&self) -> usize {
        self.b
    }

    /// Element range `[lo, hi)` of block `k` (`k < count()`).
    pub fn range(&self, k: usize) -> (usize, usize) {
        debug_assert!(k < self.b);
        (k * self.m / self.b, (k + 1) * self.m / self.b)
    }

    /// Size of block `k` in elements.
    pub fn len(&self, k: usize) -> usize {
        let (lo, hi) = self.range(k);
        hi - lo
    }

    /// Largest block size (the `m/b` the cost formulas use).
    pub fn max_len(&self) -> usize {
        div_ceil(self.m, self.b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_is_exact_and_balanced() {
        for m in [0usize, 1, 5, 16, 100, 16001] {
            for b in [1usize, 2, 3, 7, 16, 100] {
                let blocks = Blocks::by_count(m, b);
                let mut covered = 0;
                let mut prev_hi = 0;
                for k in 0..blocks.count() {
                    let (lo, hi) = blocks.range(k);
                    assert_eq!(lo, prev_hi, "m={m} b={b} k={k}");
                    assert!(hi >= lo);
                    covered += hi - lo;
                    prev_hi = hi;
                    // balanced within one element
                    assert!(blocks.len(k) + 1 >= blocks.max_len());
                }
                assert_eq!(covered, m);
            }
        }
    }

    #[test]
    fn segments_allow_empty() {
        let s = Blocks::segments(3, 8);
        assert_eq!(s.count(), 8);
        let total: usize = (0..8).map(|k| s.len(k)).sum();
        assert_eq!(total, 3);
        assert_eq!(Blocks::segments(0, 4).count(), 4);
        assert_eq!(Blocks::segments(5, 0).count(), 1);
    }

    #[test]
    fn clamping() {
        assert_eq!(Blocks::by_count(5, 100).count(), 5); // b ≤ m
        assert_eq!(Blocks::by_count(5, 0).count(), 1); // b ≥ 1
        assert_eq!(Blocks::by_count(0, 4).count(), 1); // m = 0 still one (empty) block
        assert_eq!(Blocks::by_count(0, 4).len(0), 0);
    }

    #[test]
    fn by_size_matches_paper() {
        // the paper's fixed 16000-element blocks
        let blocks = Blocks::by_size(8_388_608, PAPER_BLOCK_ELEMS).unwrap();
        assert_eq!(blocks.count(), div_ceil(8_388_608, 16_000));
        assert!(blocks.max_len() <= PAPER_BLOCK_ELEMS);
        assert!(Blocks::by_size(10, 0).is_err());
    }

    #[test]
    fn greedy_never_worse_than_lemma_on_grid() {
        // the scan includes the lemma's own count, so under the discrete
        // objective greedy ≤ lemma at every grid point
        let link = LinkCost::new(1e-6, 0.7e-9);
        for m in [1usize, 7, 100, 1024, 16_000, 1_000_000] {
            for &(a, c) in &[(6.0f64, 3.0f64), (30.0, 3.0), (44.0, 4.0), (12.0, 2.0)] {
                let bl = Blocks::lemma_optimal(m, 4, a, c, link).count();
                let bg = Blocks::greedy_optimal(m, 4, a, c, link).count();
                let tl = predicted_pipeline_time(m, 4, a, c, link, bl);
                let tg = predicted_pipeline_time(m, 4, a, c, link, bg);
                assert!(tg <= tl + 1e-15, "m={m} A={a} C={c}: {tg} > {tl}");
            }
        }
    }

    #[test]
    fn greedy_matches_lemma_at_exact_optimum() {
        // β chosen so the continuous optimum b* = √(A·β·M / (C·α)) = 16
        // exactly, and 16 divides m = 1024 — no rounding, no ragged
        // blocks: the two schedules must agree (count and time).
        let link = LinkCost::new(1e-6, 1.5625e-8);
        let (a, c) = (12.0, 3.0);
        let lemma = Blocks::lemma_optimal(1024, 4, a, c, link);
        let greedy = Blocks::greedy_optimal(1024, 4, a, c, link);
        assert_eq!(lemma.count(), 16);
        assert_eq!(greedy.count(), 16);
        let tl = predicted_pipeline_time(1024, 4, a, c, link, lemma.count());
        let tg = predicted_pipeline_time(1024, 4, a, c, link, greedy.count());
        assert_eq!(tl, tg);
    }

    #[test]
    fn schedkind_parse_roundtrip() {
        for s in [SchedKind::Fixed, SchedKind::Lemma, SchedKind::Greedy] {
            assert_eq!(SchedKind::parse(s.name()), Some(s));
        }
        assert_eq!(SchedKind::parse("nope"), None);
    }

    #[test]
    fn lemma_optimal_reasonable() {
        let link = LinkCost::new(1e-6, 0.7e-9);
        // dpdr at p = 286: A = 4h−6 = 30, C = 3
        let blocks = Blocks::lemma_optimal(1_000_000, 4, 30.0, 3.0, link);
        let b = blocks.count() as f64;
        let ideal = (30.0_f64 * 0.7e-9 * 4e6 / (3.0 * 1e-6)).sqrt();
        assert!((b - ideal).abs() <= 1.0, "b={b} ideal={ideal}");
    }
}
