//! Pipeline block partitioning and block-count selection.
//!
//! The paper divides each m-element vector into `b` successive blocks,
//! `0 < b ≤ m`, of roughly `m/b` elements (§1.1). The evaluation fixes the
//! *block size* at 16000 elements instead (§2), i.e. `b = ⌈m / 16000⌉`;
//! [`Blocks`] supports both parameterizations, and
//! [`Blocks::lemma_optimal`] applies the Pipelining Lemma of §1.2.

use crate::error::{Error, Result};
use crate::model::{lemma, LinkCost};
use crate::util::div_ceil;

/// The paper's compile-time pipeline block size (elements), §2.
pub const PAPER_BLOCK_ELEMS: usize = 16_000;

/// A balanced partition of an `m`-element vector into `b` blocks.
///
/// Block `k` covers `[k·m/b, (k+1)·m/b)` (integer arithmetic), so sizes
/// differ by at most one element and concatenation is exact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Blocks {
    m: usize,
    b: usize,
}

impl Blocks {
    /// Partition into exactly `b` blocks (clamped to `[1, max(m,1)]`).
    pub fn by_count(m: usize, b: usize) -> Blocks {
        let b = b.clamp(1, m.max(1));
        Blocks { m, b }
    }

    /// Partition into *exactly* `b` segments, allowing empty ones (`m < b`).
    /// Used by the segment-based algorithms (ring, Rabenseifner), where the
    /// segment count is fixed by the rank count, not the data size.
    pub fn segments(m: usize, b: usize) -> Blocks {
        Blocks { m, b: b.max(1) }
    }

    /// Partition into blocks of at most `block_elems` elements
    /// (the paper's parameterization; `b = ⌈m / block_elems⌉`).
    pub fn by_size(m: usize, block_elems: usize) -> Result<Blocks> {
        if block_elems == 0 {
            return Err(Error::Config("block size must be > 0".into()));
        }
        Ok(Blocks::by_count(m, div_ceil(m.max(1), block_elems)))
    }

    /// The Pipelining-Lemma optimal block count for a pipelined algorithm
    /// with step structure `A + C·b` (§1.2) under `link`, for elements of
    /// `elem_bytes` bytes.
    pub fn lemma_optimal(
        m: usize,
        elem_bytes: usize,
        a_steps: f64,
        c_steps: f64,
        link: LinkCost,
    ) -> Blocks {
        let (b, _t) = lemma::optimal_time(
            a_steps,
            c_steps,
            link.alpha,
            link.beta,
            (m * elem_bytes) as f64,
            m.max(1),
        );
        Blocks::by_count(m, b)
    }

    /// Total element count.
    pub fn total(&self) -> usize {
        self.m
    }

    /// Number of blocks (≥ 1).
    pub fn count(&self) -> usize {
        self.b
    }

    /// Element range `[lo, hi)` of block `k` (`k < count()`).
    pub fn range(&self, k: usize) -> (usize, usize) {
        debug_assert!(k < self.b);
        (k * self.m / self.b, (k + 1) * self.m / self.b)
    }

    /// Size of block `k` in elements.
    pub fn len(&self, k: usize) -> usize {
        let (lo, hi) = self.range(k);
        hi - lo
    }

    /// Largest block size (the `m/b` the cost formulas use).
    pub fn max_len(&self) -> usize {
        div_ceil(self.m, self.b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_is_exact_and_balanced() {
        for m in [0usize, 1, 5, 16, 100, 16001] {
            for b in [1usize, 2, 3, 7, 16, 100] {
                let blocks = Blocks::by_count(m, b);
                let mut covered = 0;
                let mut prev_hi = 0;
                for k in 0..blocks.count() {
                    let (lo, hi) = blocks.range(k);
                    assert_eq!(lo, prev_hi, "m={m} b={b} k={k}");
                    assert!(hi >= lo);
                    covered += hi - lo;
                    prev_hi = hi;
                    // balanced within one element
                    assert!(blocks.len(k) + 1 >= blocks.max_len());
                }
                assert_eq!(covered, m);
            }
        }
    }

    #[test]
    fn segments_allow_empty() {
        let s = Blocks::segments(3, 8);
        assert_eq!(s.count(), 8);
        let total: usize = (0..8).map(|k| s.len(k)).sum();
        assert_eq!(total, 3);
        assert_eq!(Blocks::segments(0, 4).count(), 4);
        assert_eq!(Blocks::segments(5, 0).count(), 1);
    }

    #[test]
    fn clamping() {
        assert_eq!(Blocks::by_count(5, 100).count(), 5); // b ≤ m
        assert_eq!(Blocks::by_count(5, 0).count(), 1); // b ≥ 1
        assert_eq!(Blocks::by_count(0, 4).count(), 1); // m = 0 still one (empty) block
        assert_eq!(Blocks::by_count(0, 4).len(0), 0);
    }

    #[test]
    fn by_size_matches_paper() {
        // the paper's fixed 16000-element blocks
        let blocks = Blocks::by_size(8_388_608, PAPER_BLOCK_ELEMS).unwrap();
        assert_eq!(blocks.count(), div_ceil(8_388_608, 16_000));
        assert!(blocks.max_len() <= PAPER_BLOCK_ELEMS);
        assert!(Blocks::by_size(10, 0).is_err());
    }

    #[test]
    fn lemma_optimal_reasonable() {
        let link = LinkCost::new(1e-6, 0.7e-9);
        // dpdr at p = 286: A = 4h−6 = 30, C = 3
        let blocks = Blocks::lemma_optimal(1_000_000, 4, 30.0, 3.0, link);
        let b = blocks.count() as f64;
        let ideal = (30.0_f64 * 0.7e-9 * 4e6 / (3.0 * 1e-6)).sqrt();
        assert!((b - ideal).abs() <= 1.0, "b={b} ideal={ideal}");
    }
}
