//! A minimal property-testing substrate (the offline registry has no
//! `proptest`/`quickcheck`, so we roll the 100 lines we need).
//!
//! Properties are closures over a [`Gen`]; [`forall`] drives N cases from a
//! base seed and, on failure, retries the failing case with progressively
//! *smaller* size hints (a crude but effective shrink), then panics with
//! the reproducing seed.

use crate::util::XorShift64;

/// A source of sized random values for one test case.
pub struct Gen {
    rng: XorShift64,
    /// Size hint in (0, 1]: shrunken re-runs scale ranges down by this.
    size: f64,
}

impl Gen {
    pub fn new(seed: u64) -> Gen {
        Gen {
            rng: XorShift64::new(seed),
            size: 1.0,
        }
    }

    fn sized(seed: u64, size: f64) -> Gen {
        Gen {
            rng: XorShift64::new(seed),
            size,
        }
    }

    /// usize in `[lo, hi]`, with the upper end scaled by the size hint.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        let span = hi - lo;
        let scaled_hi = lo + ((span as f64 * self.size).ceil() as usize).min(span);
        self.rng.range(lo, scaled_hi)
    }

    /// An **odd** usize in `[lo, hi]` (`hi > lo`). Odd block sizes are the
    /// adversarial case for the block partitioners (unbalanced blocks,
    /// ragged tails), so transport-parity properties fuzz with these.
    pub fn odd_usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        let v = self.usize_in(lo, hi);
        if v % 2 == 1 {
            v
        } else if v < hi {
            v + 1
        } else {
            v - 1 // v == hi > lo, so v - 1 >= lo, and v even makes it odd
        }
    }

    /// One of the provided choices.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.range(0, items.len() - 1)]
    }

    /// A small i32 (overflow-safe for summation tests).
    pub fn small_i32(&mut self) -> i32 {
        self.rng.small_i32()
    }

    /// A vector of small i32 of the given length.
    pub fn vec_i32(&mut self, len: usize) -> Vec<i32> {
        self.rng.small_i32_vec(len)
    }

    /// A raw u64.
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// A bool with probability 1/2.
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }
}

/// Run `cases` property cases derived from `base_seed`. The property
/// returns `Err(description)` to signal failure.
///
/// On failure the case is re-run at smaller size hints; the smallest still-
/// failing configuration is reported. Panics with a message embedding the
/// seed so failures are reproducible.
pub fn forall<F>(name: &str, cases: usize, base_seed: u64, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64);
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            // shrink: retry with smaller size hints, keep the last failure
            let mut final_msg = msg;
            let mut final_size = 1.0;
            for &size in &[0.5, 0.25, 0.1, 0.05] {
                let mut g = Gen::sized(seed, size);
                if let Err(m) = prop(&mut g) {
                    final_msg = m;
                    final_size = size;
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}, size {final_size}): {final_msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        forall("add-commutes", 50, 42, |g| {
            let a = g.small_i32();
            let b = g.small_i32();
            if a.wrapping_add(b) == b.wrapping_add(a) {
                Ok(())
            } else {
                Err(format!("{a} + {b}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'must-fail' failed")]
    fn failing_property_panics_with_seed() {
        forall("must-fail", 10, 1, |g| {
            let v = g.usize_in(0, 100);
            if v <= 100 {
                Err("always".to_string())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn odd_usize_is_odd_and_in_range() {
        let mut g = Gen::new(11);
        for _ in 0..1000 {
            let v = g.odd_usize_in(2, 9);
            assert!(v % 2 == 1 && (2..=9).contains(&v), "v={v}");
            let w = g.odd_usize_in(4, 5);
            assert_eq!(w, 5);
        }
    }

    #[test]
    fn gen_ranges_respected() {
        let mut g = Gen::new(7);
        for _ in 0..1000 {
            let v = g.usize_in(3, 9);
            assert!((3..=9).contains(&v));
        }
        let items = [10, 20, 30];
        for _ in 0..100 {
            assert!(items.contains(g.choose(&items)));
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let mut a = Gen::new(5);
        let mut b = Gen::new(5);
        for _ in 0..100 {
            assert_eq!(a.u64(), b.u64());
        }
    }
}
